"""Property-based tests (hypothesis) over the system's invariants."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

pytest.importorskip(
    "hypothesis",
    reason="optional dev dependency (see requirements-dev.txt); "
           "property tests degrade to a skip without it")
from hypothesis import given, settings, strategies as st, HealthCheck

from repro.sde import VPSDE, CLD, BDM
from repro.models import rwkv6, common
from repro.kernels.ei_update.ref import ei_update_ref
from repro.kernels.ei_update.kernel import ei_update

# example budget comes from the active hypothesis profile (tests/conftest.py:
# `dev` = small local budget, `ci` = the CI job's pinned derandomized budget)
SLOW = dict(deadline=None,
            suppress_health_check=[HealthCheck.too_slow])

ts_strategy = st.floats(min_value=1e-3, max_value=0.999)


class TestSDEInvariants:
    @given(t=ts_strategy)
    @settings(**SLOW)
    def test_cld_R_factorizes_sigma(self, t):
        sde = CLD()
        R = sde.R_np(t)
        S = sde.Sigma_np(t)
        np.testing.assert_allclose(R @ R.T, S, rtol=1e-4, atol=1e-8)

    @given(t=ts_strategy, s=ts_strategy, r=ts_strategy)
    @settings(**SLOW)
    def test_cld_psi_group_property(self, t, s, r):
        sde = CLD()
        lhs = sde.Psi_np(t, s) @ sde.Psi_np(s, r)
        rhs = sde.Psi_np(t, r)
        np.testing.assert_allclose(lhs, rhs, rtol=1e-6, atol=1e-10)

    @given(t=ts_strategy, s=ts_strategy)
    @settings(**SLOW)
    def test_bdm_psi_group_property(self, t, s):
        sde = BDM(data_shape=(8, 8, 1))
        lhs = sde.Psi_np(t, s) * sde.Psi_np(s, 0.5)
        rhs = sde.Psi_np(t, 0.5)
        np.testing.assert_allclose(lhs, rhs, rtol=1e-6, atol=1e-10)

    @given(t=ts_strategy)
    @settings(**SLOW)
    def test_vpsde_eq17(self, t):
        """dR/dt == (F + G2/(2 Sigma)) R (the paper's Eq. 17), FD check."""
        sde = VPSDE()
        h = 1e-6
        t = min(max(t, 1e-3 + h), 0.999 - h)
        dR = (sde.R_np(t + h) - sde.R_np(t - h)) / (2 * h)
        rhs = (sde.F_np(t) + 0.5 * sde.G2_np(t) / sde.Sigma_np(t)) * sde.R_np(t)
        np.testing.assert_allclose(dR, rhs, rtol=1e-3)

    @given(t=st.floats(min_value=0.05, max_value=0.95))
    @settings(**SLOW)
    def test_bdm_g2_nonnegative(self, t):
        sde = BDM(data_shape=(8, 8, 1))
        assert (sde.G2_np(t) >= 0).all()


class TestRecurrenceProperties:
    @given(
        s_chunks=st.integers(min_value=1, max_value=4),
        chunk=st.sampled_from([8, 16]),
        h=st.integers(min_value=1, max_value=3),
        dk=st.sampled_from([4, 8]),
        seed=st.integers(min_value=0, max_value=2**30),
    )
    @settings(**SLOW)
    def test_rwkv_chunked_equals_sequential(self, s_chunks, chunk, h, dk, seed):
        B, S = 1, s_chunks * chunk
        ks = jax.random.split(jax.random.PRNGKey(seed), 5)
        r = jax.random.normal(ks[0], (B, S, h, dk))
        k = jax.random.normal(ks[1], (B, S, h, dk))
        v = jax.random.normal(ks[2], (B, S, h, dk))
        w_log = -jnp.exp(jax.random.normal(ks[3], (B, S, h, dk)))
        u = jax.random.normal(ks[4], (h, dk)) * 0.5
        y1, s1 = rwkv6.rwkv6_chunked(r, k, v, w_log, u, chunk=chunk)
        y2, s2 = rwkv6.rwkv6_sequential(r, k, v, w_log, u)
        np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                                   rtol=1e-3, atol=1e-3)

    @given(
        e=st.sampled_from([4, 8]),
        k=st.integers(min_value=1, max_value=3),
        seed=st.integers(min_value=0, max_value=2**30),
    )
    @settings(**SLOW)
    def test_moe_sorted_equals_dense(self, e, k, seed):
        B, S, D = 1, 8, 16
        p = common.moe_params(jax.random.PRNGKey(seed), D, 32, e, jnp.float32)
        x = jax.random.normal(jax.random.PRNGKey(seed + 1), (B, S, D))
        dense = common.moe_apply(p, x, top_k=k)
        srt = common.moe_sorted_apply(p, x, top_k=k, capacity_factor=float(e))
        np.testing.assert_allclose(np.asarray(srt), np.asarray(dense),
                                   rtol=1e-4, atol=1e-4)


class TestKernelProperties:
    @given(
        B=st.integers(min_value=1, max_value=3),
        k=st.sampled_from([1, 2]),
        D=st.sampled_from([64, 100, 256]),
        q=st.integers(min_value=1, max_value=3),
        seed=st.integers(min_value=0, max_value=2**30),
    )
    @settings(**SLOW)
    def test_ei_update_kernel(self, B, k, D, q, seed):
        ks = jax.random.split(jax.random.PRNGKey(seed), 4)
        u = jax.random.normal(ks[0], (B, k, D))
        eh = jax.random.normal(ks[1], (q, B, k, D))
        psi = jax.random.normal(ks[2], (k, k))
        C = jax.random.normal(ks[3], (q, k, k))
        ref = ei_update_ref(u, eh, psi, C)
        out = ei_update(u, eh, psi, C, block_d=64, interpret=True)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=1e-5, atol=1e-5)

    @given(
        B=st.integers(min_value=1, max_value=3),
        k=st.sampled_from([1, 2]),
        D=st.sampled_from([64, 100, 256]),
        seed=st.integers(min_value=0, max_value=2**30),
    )
    @settings(**SLOW)
    def test_apply_factored_kernel(self, B, k, D, seed):
        """The fused factored-coefficient Pallas kernel (block contraction
        + diagonal scale in one VMEM pass) matches the reference path."""
        from repro.kernels.ei_update.kernel import apply_factored
        from repro.kernels.ei_update.ref import apply_factored_ref
        ks = jax.random.split(jax.random.PRNGKey(seed), 3)
        z = jax.random.normal(ks[0], (B, k, D))
        blk = jax.random.normal(ks[1], (B, k, k))
        diag = jax.random.normal(ks[2], (B, D))
        ref = apply_factored_ref(blk, diag, z)
        out = apply_factored(blk, diag, z, block_d=64, interpret=True)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=1e-5, atol=1e-5)


class TestPackingProperties:
    """The family-generic packing layer behind multi-family serving
    (kernels/ei_update/ops.py): canonical (B, k, D) layout + the dense
    embedded coefficient application."""

    @given(
        B=st.integers(min_value=1, max_value=3),
        k=st.sampled_from([1, 2]),
        pad=st.integers(min_value=0, max_value=2),
        data_shape=st.sampled_from([(4,), (8,), (3, 5), (4, 4, 3),
                                    (2, 3, 2, 2)]),
        seed=st.integers(min_value=0, max_value=2**30),
    )
    @settings(**SLOW)
    def test_pack_unpack_round_trip(self, B, k, pad, data_shape, seed):
        from repro.kernels.ei_update.ops import pack_state, unpack_state
        shape = (B,) + ((k,) if k > 1 else ()) + data_shape
        u = jax.random.normal(jax.random.PRNGKey(seed), shape)
        k_pad = k + pad
        z, orig = pack_state(u, k, k_pad=k_pad)
        D = int(np.prod(data_shape))
        assert z.shape == (B, k_pad, D)
        # padding rows are identically zero
        assert not np.asarray(z[:, k:]).any()
        np.testing.assert_array_equal(np.asarray(unpack_state(z, orig, k=k)),
                                      np.asarray(u))

    @given(
        B=st.integers(min_value=1, max_value=3),
        seed=st.integers(min_value=0, max_value=2**30),
        family=st.sampled_from(["scalar", "block", "freqdiag"]),
    )
    @settings(**SLOW)
    def test_factored_coeff_matches_family_native_apply(self, B, seed,
                                                        family):
        """factor_coeff's (k_max, k_max)-block x pooled-(D,)-diagonal pair
        applied via apply_factored equals the family's native structured
        apply AND the dense embedding it replaced (the full bit-exact
        differential tier lives in tests/test_factored_bank.py)."""
        from dense_reference import pack_coeff
        from repro.core import factor_coeff
        from repro.kernels.ei_update.ops import (apply_factored,
                                                 apply_packed, pad_channels)
        data_shape, k_max = (4, 4, 3), 2
        D = int(np.prod(data_shape))
        rng = np.random.default_rng(seed)
        if family == "scalar":
            sde, coeff = VPSDE(), np.float64(rng.standard_normal())
        elif family == "block":
            sde, coeff = CLD(), rng.standard_normal((2, 2))
        else:
            sde = BDM(data_shape=data_shape)
            coeff = rng.standard_normal((4, 4, 1))
        u = jax.random.normal(jax.random.PRNGKey(seed),
                              (B,) + sde.state_shape(data_shape))
        ref = sde.apply(jnp.asarray(coeff, jnp.float32), u)
        blk64, diag64 = factor_coeff(sde.ops, coeff, data_shape, k_max)
        blk = jnp.broadcast_to(jnp.asarray(blk64, jnp.float32),
                               (B, k_max, k_max))
        diag = jnp.ones((D,), jnp.float32) if diag64 is None \
            else jnp.asarray(diag64, jnp.float32)
        diag = jnp.broadcast_to(diag, (B, D))
        # canonicalize (BDM: DCT basis), apply the factor pair, decanonicalize
        z = pad_channels(sde.canonicalize(u), k_max)
        out = apply_factored(blk, diag, z, impl="ref")
        got = sde.decanonicalize(out[:, :sde.packed_k], data_shape)
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                   rtol=1e-4, atol=1e-5)
        # bitwise vs the dense oracle layout
        packed = jnp.asarray(pack_coeff(sde.ops, coeff, data_shape, k_max),
                             jnp.float32)
        dense = apply_packed(jnp.broadcast_to(packed, (B,) + packed.shape), z)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(dense))


class TestSchedulerProperties:
    @given(
        seq=st.lists(st.tuples(st.sampled_from(["vpsde", "cld", "bdm"]),
                               st.booleans()),
                     min_size=0, max_size=24),
        free=st.integers(min_value=1, max_value=8),
        seed=st.integers(min_value=0, max_value=2**30),
    )
    @settings(**SLOW)
    def test_family_corrector_waves_never_mix_classes(self, seq, free, seed):
        """For ANY request order and free-slot budget, admission waves are
        homogeneous in the (family, corrector) cost class, FIFO order is
        preserved, and nothing is dropped or duplicated."""
        from repro.serve import SampleRequest, Scheduler
        sched = Scheduler(group_key=lambda r: (r.family, bool(r.corrector)))
        reqs = [SampleRequest(rid=i, family=f, corrector=c)
                for i, (f, c) in enumerate(seq)]
        sched.submit_all(reqs)
        admitted = []
        while sched.has_pending():
            wave = sched.take_group(free)
            assert wave, "pending queue must always yield a head wave"
            classes = {(r.family, bool(r.corrector)) for r in wave}
            assert len(classes) == 1, \
                f"wave mixed cost classes: {sorted(classes)}"
            assert len(wave) <= free
            admitted.extend(r.rid for r in wave)
        assert admitted == [r.rid for r in reqs]


class TestOnlineServingProperties:
    """serve_stream invariants (ISSUE 7), driven through the pure-host
    simulation rig (tests/sim_clock.py) — the same ServeLoop machinery
    the real engines inherit, so these run the actual admission /
    preemption / parking / poll code at hypothesis example counts."""

    @given(
        jobs=st.lists(st.tuples(st.integers(0, 20),     # arrival time
                                st.integers(1, 8)),     # work
                      min_size=1, max_size=3),
        seed=st.integers(min_value=0, max_value=2**30),
    )
    @settings(**SLOW)
    def test_no_starvation_when_capacity_suffices(self, jobs, seed):
        """With at most B concurrent requests (capacity always suffices),
        integer arrivals and unit rounds, a request is admitted the moment
        it arrives and completes at exactly t_arrival + work — so even the
        *tight* deadline t_arrival + work is always met, nothing is
        preempted, and nothing starves."""
        from tests.sim_clock import HostSimEngine, SimRequest, trace_of
        from repro.serve import serving_metrics
        eng = HostSimEngine(batch_size=3, sync_every=4, greedy=True)
        trace = trace_of(*[
            (float(t), SimRequest(rid=i, work=w, deadline=float(t + w)))
            for i, (t, w) in enumerate(jobs)])
        results = eng.serve_stream(trace)
        assert len(results) == len(jobs)
        assert eng.n_preemptions == 0
        for i, (t, w) in enumerate(jobs):
            timing = eng.request_log[i]
            assert timing.t_admit == float(t)
            assert timing.t_done == float(t + w)
            assert timing.met_slo
        assert serving_metrics(eng.request_log)["deadline_misses"] == 0

    @given(
        jobs=st.lists(st.tuples(st.integers(0, 12),     # arrival time
                                st.integers(1, 6),      # work
                                st.integers(0, 3),      # priority
                                st.sampled_from(["a", "b"])),
                      min_size=1, max_size=14),
        seed=st.integers(min_value=0, max_value=2**30),
    )
    @settings(**SLOW)
    def test_preemption_strictness_waves_and_drain(self, jobs, seed):
        """For ANY arrival stream over a 2-slot engine with mixed
        priorities and cost classes:

          * every preemption evicts a *strictly* lower-priority victim
            (equal priority never churns),
          * every admission wave — preemption-driven or not — is
            homogeneous in the cost class,
          * every suspension is eventually resumed and every request
            completes (the parking table drains; no starvation by churn),
          * the replay is deterministic (same stream -> same logs)."""
        from tests.sim_clock import HostSimEngine, SimRequest, trace_of

        def run():
            eng = HostSimEngine(batch_size=2, sync_every=4)
            trace = trace_of(*[
                (float(t), SimRequest(rid=i, work=w, priority=p, cls=c))
                for i, (t, w, p, c) in enumerate(jobs)])
            return eng, eng.serve_stream(trace)

        eng, results = run()
        assert set(results) == set(range(len(jobs)))
        for preemptor_rid, p_prio, victim_rid, v_prio in eng.preemption_log:
            assert v_prio < p_prio, eng.preemption_log
        for wave in eng.wave_log:
            assert len(set(wave)) == 1, eng.wave_log
        assert eng.n_resumes == eng.n_preemptions
        assert len(eng.parking) == 0
        for i, (t, w, p, c) in enumerate(jobs):
            assert int(results[i]) == w      # full work done exactly once
        eng2, results2 = run()
        assert results == results2
        assert eng.preemption_log == eng2.preemption_log
        assert eng.wave_log == eng2.wave_log
        assert eng.request_log == eng2.request_log

    @given(
        B=st.integers(min_value=1, max_value=4),
        extra_dims=st.lists(st.sampled_from([(), (3,), (2, 4), (5,)]),
                            min_size=1, max_size=4),
        seed=st.integers(min_value=0, max_value=2**30),
    )
    @settings(**SLOW)
    def test_parked_row_save_restore_round_trips_pytrees(self, B,
                                                         extra_dims, seed):
        """row_fetch -> host -> row_restore is the bitwise identity on the
        written row of an arbitrary batch-leading pytree (mixed dtypes,
        mixed ranks) and leaves every other row of the destination
        untouched — the generic mechanism preemption parking rides."""
        from repro.serve import row_fetch, row_restore
        rng = np.random.default_rng(seed)
        dtypes = [np.float32, np.int32, np.bool_, np.uint32]

        def tree_of(rng):
            leaves = {}
            for li, dims in enumerate(extra_dims):
                dt = dtypes[li % len(dtypes)]
                raw = rng.standard_normal((B,) + dims) * 100
                leaves[f"leaf{li}"] = jnp.asarray(raw.astype(dt))
            return {"nested": leaves, "flat": jnp.asarray(
                rng.integers(0, 2**31, size=(B,)).astype(np.int32))}

        src = tree_of(rng)
        dst = tree_of(rng)
        i = int(rng.integers(0, B))
        j = int(rng.integers(0, B))
        payload = jax.device_get(row_fetch(src, np.int32(i)))  # like park()
        restored = row_restore(dst, payload, np.int32(j))
        flat_src = jax.tree.leaves(src)
        flat_dst = jax.tree.leaves(dst)
        flat_out = jax.tree.leaves(restored)
        for s, d, o in zip(flat_src, flat_dst, flat_out):
            np.testing.assert_array_equal(np.asarray(o[j]), np.asarray(s[i]))
            for b in range(B):
                if b != j:
                    np.testing.assert_array_equal(np.asarray(o[b]),
                                                  np.asarray(d[b]))


class TestDataProperties:
    @given(step=st.integers(min_value=0, max_value=10_000),
           seed=st.integers(min_value=0, max_value=2**30))
    @settings(**SLOW)
    def test_token_pipeline_pure_function_of_step(self, step, seed):
        from repro.data.pipeline import TokenPipeline
        p1 = TokenPipeline(vocab=64, seq_len=8, global_batch=2, seed=seed)
        p2 = TokenPipeline(vocab=64, seq_len=8, global_batch=2, seed=seed)
        a, _ = p1.batch_at(step)
        b, _ = p2.batch_at(step)
        np.testing.assert_array_equal(a, b)
        assert (a >= 0).all() and (a < 64).all()


class TestCoeffProperties:
    @given(n=st.integers(min_value=2, max_value=12),
           seed=st.integers(min_value=0, max_value=2**30))
    @settings(**SLOW)
    def test_vpsde_gddim_coeff_matches_ddim(self, n, seed):
        """Prop 2 as a property: for any grid size, the q=1 quadrature
        coefficient equals the closed-form DDIM coefficient."""
        from repro.core import build_sampler_coeffs, time_grid, \
            ddim_closed_form_check
        sde = VPSDE()
        ts = time_grid(sde, n)
        co = build_sampler_coeffs(sde, ts, q=1)
        ddim = ddim_closed_form_check(sde, ts)
        np.testing.assert_allclose(np.asarray(co.pC[:, 0]), ddim,
                                   rtol=1e-4, atol=1e-6)


class TestRoundFusedProperties:
    """The fused round megakernel (kernels/round_fused) as a property,
    mirroring `test_apply_factored_kernel`: for any family / batch /
    corrector flag / seed, one interpret-mode launch of the commit kernel
    reproduces the jitted reference chain — bitwise for the kf=1 families
    (VPSDE/BDM, in-kernel threefry noise included), and within one
    rounding of the kf=2 (CLD) block contraction (the ref's XLA-lowered
    dot_general accumulates with FMA; see `apply_factored_ref`)."""

    @staticmethod
    def _parts():
        import functools
        from repro.core import CoeffCache, SamplerConfig

        @functools.lru_cache(maxsize=1)
        def build():
            shape = (4, 4, 3)
            cache = CoeffCache({"vpsde": VPSDE(), "cld": CLD(),
                                "bdm": BDM(data_shape=shape)},
                               data_shape=shape)
            cfgs = [SamplerConfig(nfe=4), SamplerConfig(nfe=5, q=2),
                    SamplerConfig(nfe=6, lam=0.7),
                    SamplerConfig(nfe=4, family="cld"),
                    SamplerConfig(nfe=4, family="cld", q=2, corrector=True),
                    SamplerConfig(nfe=4, family="bdm", q=2),
                    SamplerConfig(nfe=3, family="bdm", lam=0.5)]
            idx = [cache.index_of(c) for c in cfgs]
            return cache, cfgs, idx, shape
        if not hasattr(TestRoundFusedProperties, "_cached"):
            TestRoundFusedProperties._cached = build()
        return TestRoundFusedProperties._cached

    @given(
        B=st.integers(min_value=1, max_value=3),
        family=st.sampled_from(["vpsde", "cld", "bdm"]),
        with_corrector=st.booleans(),
        seed=st.integers(min_value=0, max_value=2**30),
    )
    @settings(**SLOW)
    def test_round_fused_kernel_matches_ref(self, B, family, with_corrector,
                                            seed):
        import functools
        from repro.kernels.round_fused import ops as rf
        cache, cfgs, idx, shape = self._parts()
        bank = cache.factored_bank
        sde = cache.sdes[family]
        kf = sde.packed_k
        fi = cache.fam_index(family)
        K, D = cache.k_max, int(np.prod(shape))
        Qb = bank.pC_blk.shape[2]
        slots = [c for c, cfg in zip(idx, cfgs)
                 if cache.resolve(cfg) == family]
        rng = np.random.default_rng(seed)
        cfg_ids = jnp.asarray(rng.choice(slots, B), jnp.int32)
        k = jnp.asarray(rng.integers(0, 5, B), jnp.int32)
        kc = jnp.clip(k, 0, bank.n_steps[cfg_ids] - 1)
        u = jnp.asarray(rng.standard_normal((B, K, D)), jnp.float32)
        hist = jnp.asarray(rng.standard_normal((B, Qb, K, D)), jnp.float32)
        eps_c = jnp.asarray(rng.standard_normal((B, kf, D)), jnp.float32)
        eps_n_c = jnp.asarray(rng.standard_normal((B, kf, D)), jnp.float32)
        keys = jnp.asarray(rng.integers(0, 2**32, (B, 2), dtype=np.uint64),
                           jnp.uint32)
        fam_ids = jnp.full((B,), fi, jnp.int32)
        prec = jnp.zeros((B,), jnp.int32)
        active = jnp.asarray(rng.integers(0, 2, B, dtype=np.int64) > 0)
        call = functools.partial(
            rf.round_update, sde=sde, state_shape=sde.state_shape(shape),
            kf=kf, fam_index=fi, prec_index=0,
            with_corrector=with_corrector)
        out_ref = jax.jit(functools.partial(call, impl="ref"))(
            u, hist, k, kc, cfg_ids, fam_ids, prec, keys, active, bank,
            eps_c, eps_n_c=eps_n_c)
        out_pl = call(u, hist, k, kc, cfg_ids, fam_ids, prec, keys, active,
                      bank, eps_c, eps_n_c=eps_n_c,
                      impl="pallas_interpret", block_d=64)
        for nm, a, b in zip(("u", "hist", "k", "active"), out_ref, out_pl):
            a, b = np.asarray(a), np.asarray(b)
            if kf == 1:
                np.testing.assert_array_equal(
                    a, b, err_msg=f"{family} {nm}: kf=1 must be bitwise")
            else:
                np.testing.assert_allclose(
                    a, b, rtol=1e-5, atol=1e-5,
                    err_msg=f"{family} {nm}: beyond the FMA gap")

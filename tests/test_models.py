"""Model zoo: chunked-vs-sequential oracles, full/decode parity, and the
per-arch reduced-config smoke tests (assignment deliverable (f))."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.models import rwkv6, ssm, zoo, common, transformer, score_net
from repro.models.registry import Arch, SHAPES
from repro.configs import get_arch, ARCH_IDS


# ---------------------------------------------------------------------------
# recurrence oracles
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("chunk", [8, 16, 32])
def test_rwkv6_chunked_equals_sequential(chunk):
    B, S, H, Dk = 2, 64, 2, 16
    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    r = jax.random.normal(ks[0], (B, S, H, Dk))
    k = jax.random.normal(ks[1], (B, S, H, Dk))
    v = jax.random.normal(ks[2], (B, S, H, Dk))
    w_log = -jnp.exp(jax.random.normal(ks[3], (B, S, H, Dk)))
    u = jax.random.normal(ks[4], (H, Dk)) * 0.5
    y1, s1 = rwkv6.rwkv6_chunked(r, k, v, w_log, u, chunk=chunk)
    y2, s2 = rwkv6.rwkv6_sequential(r, k, v, w_log, u)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("chunk", [16, 32])
def test_ssd_chunked_equals_sequential(chunk):
    B, S, H, P, N = 2, 64, 2, 8, 4
    ks = jax.random.split(jax.random.PRNGKey(1), 5)
    x = jax.random.normal(ks[0], (B, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)))
    Bm = jax.random.normal(ks[3], (B, S, H, N))
    C = jax.random.normal(ks[4], (B, S, H, N))
    y1, s1 = ssm.ssd_chunked(x, dt, A, Bm, C, chunk)
    y2, s2 = ssm.ssd_sequential(x, dt, A, Bm, C)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), rtol=1e-4, atol=1e-4)


def test_moe_sorted_equals_dense():
    """Sorted dispatch == dense one-hot dispatch when capacity is ample."""
    B, S, D, E, k = 2, 16, 32, 8, 2
    p = common.moe_params(jax.random.PRNGKey(2), D, 64, E, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(3), (B, S, D))
    dense = common.moe_apply(p, x, top_k=k)
    srt = common.moe_sorted_apply(p, x, top_k=k, capacity_factor=8.0)
    np.testing.assert_allclose(np.asarray(srt), np.asarray(dense),
                               rtol=1e-4, atol=1e-4)


def test_moe_capacity_drops_gracefully():
    B, S, D, E, k = 2, 16, 32, 4, 2
    p = common.moe_params(jax.random.PRNGKey(2), D, 64, E, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(3), (B, S, D))
    out = common.moe_sorted_apply(p, x, top_k=k, capacity_factor=0.25)
    assert np.isfinite(np.asarray(out)).all()


# ---------------------------------------------------------------------------
# decode parity: prefill+decode == full forward (every family)
# ---------------------------------------------------------------------------
def _batch_for(spec, B, S, key):
    batch = {"labels": jax.random.randint(key, (B, S), 0, 8)}
    if spec.family == "encdec":
        batch["tokens"] = jax.random.randint(key, (B, S), 0, 8)
        batch["frames"] = jax.random.normal(key, (B, spec.frontend_ctx,
                                                  spec.cfg.d_model))
    elif spec.input_mode == "embeddings":
        batch["embeddings"] = jax.random.normal(key, (B, S, spec.cfg.d_model))
    else:
        batch["tokens"] = jax.random.randint(key, (B, S), 0, 8)
    return batch


DECODE_ARCHS = ["gemma3-1b", "rwkv6-7b", "zamba2-2.7b", "whisper-base",
                "qwen3-moe-30b-a3b"]


@pytest.mark.parametrize("name", DECODE_ARCHS)
def test_decode_matches_full_forward(name):
    spec = get_arch(name, reduced=True)
    arch = Arch(spec)
    key = jax.random.PRNGKey(0)
    params = arch.init(key)
    B, S = 2, 16
    toks = jax.random.randint(key, (B, S), 2, spec.cfg.vocab)
    mem = None
    if spec.family == "encdec":
        frames = jax.random.normal(key, (B, spec.frontend_ctx, spec.cfg.d_model))
        mem = zoo.encode(params, spec.cfg, frames)
        full, _ = zoo.decode_forward(params, spec.cfg, toks, mem)
    elif spec.family == "rwkv":
        full, _ = zoo.rwkv_forward(params, spec.cfg, toks)
    elif spec.family == "zamba":
        full, _ = zoo.zamba_forward(params, spec.cfg, toks)
    else:
        full, _ = transformer.forward(params, spec.cfg, toks)
    caches = arch.init_cache(B, S)
    outs = []
    for t in range(S):
        logits, caches = arch.decode(params, toks[:, t:t + 1], caches,
                                     jnp.int32(t), memory=mem)
        outs.append(logits)
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full),
                               rtol=2e-3, atol=2e-3)


# ---------------------------------------------------------------------------
# per-arch smoke: one train step on CPU, shapes + no NaNs (deliverable (f))
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("name", ARCH_IDS)
def test_arch_smoke_train_step(name):
    from repro.launch.steps import make_train_step
    from repro.optim.adamw import AdamWCfg, adamw_init
    spec = get_arch(name, reduced=True)
    arch = Arch(spec)
    key = jax.random.PRNGKey(0)
    params = arch.init(key)
    opt_cfg = AdamWCfg(warmup_steps=1, total_steps=10)
    opt = adamw_init(params, opt_cfg)
    B, S = 2, 32
    batch = _batch_for(spec, B, S, key)
    step = jax.jit(make_train_step(arch, opt_cfg))
    new_params, new_opt, metrics = step(params, opt, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert int(new_opt.step) == 1
    # shapes preserved, params actually moved
    moved = 0.0
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(new_params)):
        assert a.shape == b.shape and a.dtype == b.dtype
        moved += float(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32)).sum())
    assert moved > 0.0


@pytest.mark.parametrize("name", ARCH_IDS)
def test_arch_smoke_serve_step(name):
    from repro.launch.steps import make_serve_step
    spec = get_arch(name, reduced=True)
    arch = Arch(spec)
    key = jax.random.PRNGKey(0)
    params = arch.init(key)
    B = 2
    caches = arch.init_cache(B, 16)
    tok = jnp.ones((B, 1), jnp.int32)
    step = make_serve_step(arch)
    mem = None
    if spec.family == "encdec":
        frames = jax.random.normal(key, (B, spec.frontend_ctx, spec.cfg.d_model))
        mem = zoo.encode(params, spec.cfg, frames)
    nxt, logits, caches = step(params, tok, caches, jnp.int32(0), mem)
    assert nxt.shape == (B, 1) and logits.shape == (B, spec.cfg.vocab)
    assert np.isfinite(np.asarray(logits)).all()


# ---------------------------------------------------------------------------
# score nets
# ---------------------------------------------------------------------------
def test_dit_shapes_cld_and_vp():
    t = jnp.array([0.3, 0.7])
    for mult, shape in [(2, (2, 2, 8, 8, 3)), (1, (2, 8, 8, 3))]:
        cfg = score_net.DiTCfg(img_size=8, channels=3, state_mult=mult,
                               patch=4, d_model=32, n_layers=2, n_heads=2,
                               remat=False)
        p = score_net.dit_init(jax.random.PRNGKey(0), cfg)
        u = jax.random.normal(jax.random.PRNGKey(1), shape)
        out = score_net.dit_apply(p, cfg, u, t)
        assert out.shape == u.shape
        assert np.isfinite(np.asarray(out)).all()


def test_mlp_score_shapes():
    cfg = score_net.MLPScoreCfg(state_shape=(2, 2))
    p = score_net.mlp_score_init(jax.random.PRNGKey(0), cfg)
    u = jax.random.normal(jax.random.PRNGKey(1), (4, 2, 2))
    out = score_net.mlp_score_apply(p, cfg, u, jnp.linspace(0.1, 0.9, 4))
    assert out.shape == u.shape

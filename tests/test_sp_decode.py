"""Sequence-parallel flash-decode (shard_map) vs the replicated reference —
run on a forced 4-device host in a subprocess."""
import os
import subprocess
import sys
import textwrap


def run_with_devices(n, code):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n}"
    env["PYTHONPATH"] = "src"
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, env=env,
                       cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                       timeout=600)
    assert r.returncode == 0, r.stdout + r.stderr
    return r.stdout


def test_sp_decode_matches_ref_and_update_is_local():
    out = run_with_devices(4, """
        import numpy as np, jax, jax.numpy as jnp
        from repro.kernels.decode_attention.sp import sp_decode_attention, sp_cache_update
        from repro.kernels.decode_attention.ref import decode_attention_ref

        mesh = jax.make_mesh((1, 4), ("data", "model"))
        B, Hq, Hkv, Dh, S = 2, 8, 2, 16, 64
        ks = jax.random.split(jax.random.PRNGKey(0), 3)
        q = jax.random.normal(ks[0], (B, Hq, Dh))
        k = jax.random.normal(ks[1], (B, S, Hkv, Dh))
        v = jax.random.normal(ks[2], (B, S, Hkv, Dh))
        for clen in (1, 17, 48, 64):
            ref = decode_attention_ref(q, k, v, jnp.int32(clen))
            with mesh:
                out = jax.jit(lambda q,k,v,c: sp_decode_attention(
                    q, k, v, c, mesh=mesh))(q, k, v, jnp.int32(clen))
            err = float(jnp.abs(out - ref).max())
            assert err < 1e-5, (clen, err)

        # cache update: write at position 17, verify only that slot changed
        kn = jax.random.normal(jax.random.PRNGKey(5), (B, Hkv, Dh))
        vn = jax.random.normal(jax.random.PRNGKey(6), (B, Hkv, Dh))
        with mesh:
            k2, v2 = jax.jit(lambda kc,vc,kn,vn,c: sp_cache_update(
                kc, vc, kn, vn, c, mesh=mesh))(k, v, kn, vn, jnp.int32(17))
        assert float(jnp.abs(k2[:, 17] - kn).max()) < 1e-6
        mask = jnp.arange(S) != 17
        assert float(jnp.abs(k2[:, mask] - k[:, mask]).max()) == 0.0
        print("SP_DECODE_OK")
    """)
    assert "SP_DECODE_OK" in out

"""Mesh-sharded serving: the engines on a forced multi-device host.

The contract under test (ISSUE 3 acceptance): on a 2-device `data` mesh,

  * a mixed-config diffusion batch and an interleaved token-decode batch
    both produce **bitwise-identical** outputs to the single-device engine
    (slots are batch rows; per-row computation is row-independent, and the
    serve sharding rules only split the slot axis), and
  * retire-and-refill after warmup triggers **zero recompiles** (pinned
    out_shardings keep every round/merge program at one jit entry).

Multi-device behaviour runs in a subprocess with
XLA_FLAGS=--xla_force_host_platform_device_count=2 (same pattern as
test_distributed.py) so the main test process keeps the real 1-device
view; the CI serve-mesh job additionally runs the whole serve test suite
under a forced 2-device main process.
"""
import os
import subprocess
import sys
import textwrap

import pytest

from repro.launch.mesh import parse_mesh_spec


def run_with_devices(n, code):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n}"
    env["PYTHONPATH"] = "src"
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, env=env,
                       cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
    assert r.returncode == 0, r.stdout + r.stderr
    return r.stdout


# ---------------------------------------------------------------------------
# --mesh flag parsing (no devices needed)
# ---------------------------------------------------------------------------
def test_parse_mesh_spec():
    assert parse_mesh_spec("data=2") == {"data": 2, "model": 1}
    assert parse_mesh_spec("data=2,model=4") == {"data": 2, "model": 4}
    assert parse_mesh_spec("2") == {"data": 2, "model": 1}
    assert parse_mesh_spec("2x4") == {"data": 2, "model": 4}
    assert parse_mesh_spec("auto")["model"] == 1
    for bad in ("pods=2", "data=x", "2x2x2", "data=0"):
        with pytest.raises(ValueError):
            parse_mesh_spec(bad)


# ---------------------------------------------------------------------------
# 2-device data mesh == single device, bitwise; zero recompiles after warmup
# ---------------------------------------------------------------------------
def test_mesh_serve_bitwise_equals_single_device():
    out = run_with_devices(2, """
        import numpy as np, jax
        from repro.configs import get_arch, get_diffusion
        from repro.models.registry import Arch
        from repro.launch.mesh import make_local_mesh
        from repro.serve import (DiffusionEngine, Request, SampleRequest,
                                 TokenEngine)

        mesh = make_local_mesh(data=2)

        # ---- mixed-config diffusion batch ----
        spec = get_diffusion("cifar10-ddpm", reduced=True)
        params = spec.init(jax.random.PRNGKey(0))
        reqs = [SampleRequest(rid=0, seed=0),
                SampleRequest(rid=1, seed=1, nfe=4),
                SampleRequest(rid=2, seed=2, nfe=5, q=2, corrector=True),
                SampleRequest(rid=3, seed=3, nfe=8, lam=0.5)]
        single = DiffusionEngine(spec, params, batch_size=4, nfe=6)
        ref = single.serve(reqs)
        sharded = DiffusionEngine(spec, params, batch_size=4, nfe=6,
                                  mesh=mesh)
        assert sharded.n_shards == 2, sharded.n_shards
        got = sharded.serve(reqs)
        for rid in ref:
            np.testing.assert_array_equal(
                ref[rid], got[rid],
                err_msg=f"diffusion rid {rid}: sharded != single-device")
        warm = sharded.compile_stats()
        # refill with fresh traffic incl. an unseen NFE inside the bucket
        got2 = sharded.serve([SampleRequest(rid=10, seed=7, nfe=4),
                              SampleRequest(rid=11, seed=8)])
        assert sharded.compile_stats() == warm, (
            "mesh retire-and-refill recompiled", warm,
            sharded.compile_stats())

        # steady-state rounds move nothing host->device on the mesh either
        sharded.scheduler.submit_all([SampleRequest(rid=20, seed=9),
                                      SampleRequest(rid=21, seed=10)])
        sharded._admit()
        sharded._round()
        with jax.transfer_guard_host_to_device("disallow"):
            for _ in range(3):
                sharded._round()
        print("DIFFUSION_MESH_OK")

        # ---- interleaved token-decode batch ----
        aspec = get_arch("gemma3-1b", reduced=True)
        arch = Arch(aspec)
        ap = arch.init(jax.random.PRNGKey(0))
        rng = np.random.default_rng(0)
        treqs = [Request(rid=i,
                         tokens=rng.integers(2, arch.cfg.vocab,
                                             L).astype(np.int32),
                         max_new=m)
                 for i, (L, m) in enumerate(zip([6, 6, 9, 9, 6],
                                                [7, 4, 6, 3, 5]))]
        tref = TokenEngine(arch, ap, batch_size=4, max_len=48).serve(treqs)
        teng = TokenEngine(arch, ap, batch_size=4, max_len=48, mesh=mesh)
        assert teng.n_shards == 2
        tgot = teng.serve(treqs)
        for rid in tref:
            np.testing.assert_array_equal(
                tref[rid], tgot[rid],
                err_msg=f"token rid {rid}: sharded != single-device")
        warm = teng.compile_stats()
        # refill with traffic matching the warmed (length, width) buckets:
        # two len-6 and two len-9 prompts arrive as two width-2 waves
        teng.serve([Request(rid=100 + i,
                            tokens=rng.integers(2, arch.cfg.vocab,
                                                L).astype(np.int32),
                            max_new=4)
                    for i, L in enumerate([6, 6, 9, 9])])
        assert teng.compile_stats() == warm, (
            "token mesh refill recompiled", warm, teng.compile_stats())
        print("TOKEN_MESH_OK")
    """)
    assert "DIFFUSION_MESH_OK" in out
    assert "TOKEN_MESH_OK" in out


def test_mesh_multi_family_bitwise_equals_single_device():
    """The multi-family engine (VPSDE + CLD + BDM in one packed slot pool)
    on a 2-device data mesh: bitwise-equal to the single-device engine and
    recompile-free across a refill after warmup."""
    out = run_with_devices(2, """
        import numpy as np, jax
        from repro.configs import get_diffusion
        from repro.launch.mesh import make_local_mesh
        from repro.serve import DiffusionEngine, SampleRequest

        specs, params = {}, {}
        for i, (fam, name) in enumerate((("vpsde", "cifar10-ddpm"),
                                         ("cld", "cifar10-cld"),
                                         ("bdm", "cifar10-bdm"))):
            specs[fam] = get_diffusion(name, reduced=True)
            params[fam] = specs[fam].init(jax.random.PRNGKey(100 + i))
        reqs = [SampleRequest(rid=0, seed=0),
                SampleRequest(rid=1, seed=1, family="cld", nfe=5),
                SampleRequest(rid=2, seed=2, family="bdm", nfe=4),
                SampleRequest(rid=3, seed=3, family="cld", nfe=6,
                              corrector=True)]
        single = DiffusionEngine(specs, params, batch_size=4, nfe=6)
        ref = single.serve(reqs)
        sharded = DiffusionEngine(specs, params, batch_size=4, nfe=6,
                                  mesh=make_local_mesh(data=2))
        assert sharded.n_shards == 2
        got = sharded.serve(reqs)
        for rid in ref:
            np.testing.assert_array_equal(
                ref[rid], got[rid],
                err_msg=f"family-mix rid {rid}: sharded != single-device")
        warm = sharded.compile_stats()
        # refill with fresh seeds over the warmed config menu (a NEW config
        # would be fine too as long as it fits the warmed buckets; these
        # four sit at the C bucket boundary, so stay inside the menu)
        sharded.serve([SampleRequest(rid=10, seed=7, family="bdm", nfe=4),
                       SampleRequest(rid=11, seed=8)])
        assert sharded.compile_stats() == warm, (
            "multi-family mesh refill recompiled", warm,
            sharded.compile_stats())
        print("FAMILY_MESH_OK")
    """)
    assert "FAMILY_MESH_OK" in out


def test_mesh_admission_spreads_across_shards():
    """Free-slot selection targets per-shard rows round-robin, so an
    admission wave lands evenly over the data shards instead of piling
    onto shard 0."""
    out = run_with_devices(2, """
        import numpy as np, jax
        from repro.configs import get_diffusion
        from repro.launch.mesh import make_local_mesh
        from repro.serve import DiffusionEngine, SampleRequest

        spec = get_diffusion("cifar10-ddpm", reduced=True)
        params = spec.init(jax.random.PRNGKey(0))
        eng = DiffusionEngine(spec, params, batch_size=4, nfe=4,
                              mesh=make_local_mesh(data=2))
        eng.scheduler.submit_all([SampleRequest(rid=0, seed=0),
                                  SampleRequest(rid=1, seed=1)])
        eng._admit()
        occupied = sorted(eng.slots.active_ids())
        # slots 0-1 live on shard 0, slots 2-3 on shard 1: a 2-request
        # wave must take one row from each shard
        assert occupied == [0, 2], occupied
        print("SPREAD_OK")
    """)
    assert "SPREAD_OK" in out


# ---------------------------------------------------------------------------
# online serving (preemption + resume) on a 2-device mesh
# ---------------------------------------------------------------------------
def test_mesh_online_preempt_resume_bitwise():
    """ISSUE 7 satellite: the online path on a 2-device data mesh — a
    priority arrival preempts a sharded slot, the parked row round-trips
    through the host, and every sample is still bitwise-equal to the
    single-device engine's *offline* solo run.  A second stream after
    warmup compiles nothing new (park/resume/restore included)."""
    out = run_with_devices(2, """
        import numpy as np, jax
        from repro.configs import get_diffusion
        from repro.launch.mesh import make_local_mesh
        from repro.serve import (Arrival, DiffusionEngine, SampleRequest,
                                 TraceTraffic, VirtualClock)

        spec = get_diffusion("cifar10-ddpm", reduced=True)
        params = spec.init(jax.random.PRNGKey(0))

        def trace(base):
            return TraceTraffic([
                Arrival(0.0, SampleRequest(rid=base, seed=base)),
                Arrival(0.0, SampleRequest(rid=base + 1, seed=base + 1)),
                Arrival(2.0, SampleRequest(rid=base + 2, seed=base + 2,
                                           priority=5, deadline=12.0)),
            ])

        sharded = DiffusionEngine(spec, params, batch_size=2, nfe=8,
                                  sync_every=4, mesh=make_local_mesh(data=2))
        assert sharded.n_shards == 2
        got = sharded.serve_stream(trace(0), clock=VirtualClock())
        assert sharded.n_preemptions == 1 and sharded.n_resumes == 1, (
            sharded.n_preemptions, sharded.n_resumes)

        solo = DiffusionEngine(spec, params, batch_size=2, nfe=8)
        for rid in (0, 1, 2):
            ref = solo.serve([SampleRequest(rid=rid, seed=rid)])[rid]
            np.testing.assert_array_equal(
                got[rid], ref,
                err_msg=f"rid {rid}: mesh online run != single-device solo")

        warm = sharded.compile_stats()
        sharded.serve_stream(trace(10), clock=VirtualClock())
        assert sharded.n_preemptions == 2
        assert sharded.compile_stats() == warm, (
            "mesh online replay recompiled", warm, sharded.compile_stats())
        print("MESH_ONLINE_OK")
    """)
    assert "MESH_ONLINE_OK" in out

"""Shared test configuration: hypothesis example-budget profiles.

The property/differential suites (test_properties.py,
test_factored_bank.py) leave `max_examples` unset in their per-test
`@settings(...)` so the *active profile* governs the budget:

  * ``dev`` (default) — small budget, random seeds: the local tier-1 run.
  * ``ci``            — pinned larger budget, **derandomized** (fixed
                        example sequence, reproducible across runs): the
                        CI hypothesis job selects it via
                        ``HYPOTHESIS_PROFILE=ci``.

hypothesis is an optional dev dependency (requirements-dev.txt); without
it this module is a no-op and the suites skip themselves.
"""
import os

try:
    from hypothesis import HealthCheck, settings
except ImportError:                                   # pragma: no cover
    pass
else:
    _common = dict(deadline=None,
                   suppress_health_check=[HealthCheck.too_slow])
    settings.register_profile("dev", max_examples=12, **_common)
    settings.register_profile("ci", max_examples=40, derandomize=True,
                              print_blob=True, **_common)
    settings.load_profile(os.environ.get("HYPOTHESIS_PROFILE", "dev"))

"""Golden closed-form lockdown of the per-request algorithm axis (PR 10).

`SamplerConfig.algorithm` selects the *update rule* a request runs with —
'gddim' (the paper), 'gmm' (Gabbur's moment-matched K=2 Gaussian-mixture
reverse kernel, arXiv:2311.04938) or 'accel' (Li et al.'s provable
single-step acceleration, arXiv:2403.03852) — all three riding the same
FactoredBank rows and the same fused round step.  Four layers:

  * coefficient goldens — the algorithm transform
    (`core.coeffs.algorithm_coeff_stacks`) against each paper's closed
    form, in float64: accel's extra row is exactly -pM/(2 delta) with pM
    the first moment of the EI kernel (checked against an independent
    fine-grid Simpson quadrature), and the two accel slots sum back to
    the untransformed gDDIM row; gmm scales only the P_chol rows, by
    sqrt(1 - rho^2), satisfying the mixture moment identity
    (1 - rho^2)(1 + c^2) = 1.
  * the noise-keying law — `draw_step_noise` (kernels/round_fused/ref.py,
    THE shared noise function of the serving tier) equals the explicit
    jax.random chain key -> fold_in(alg) -> fold_in(k) bitwise, keys
    distinct streams per algorithm id at the same (seed, k), and the gmm
    innovation z + c*sign(s) has the matched moments empirically.
  * config validation — the algorithm axis's constraint surface.
  * engine level — a mixed-algorithm batch is bitwise identical, per
    request, to each request's solo run, with ZERO recompiles after a
    warmup that has seen each algorithm once (the tentpole claim: the
    algorithm id is an int lane of the bank, not a compile bucket).

The factored-vs-dense and fused-vs-stitched differentials for algorithm
configs live in tests/test_factored_bank.py / tests/test_round_fused.py
(their config menus include gmm/accel rows).
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import (ALG_ACCEL, ALG_GDDIM, ALG_GMM, ALGORITHMS,
                        GMM_C, GMM_RHO, GMM_SALT, GMM_SCALE,
                        SamplerConfig, algorithm_coeff_stacks,
                        build_sampler_coeffs, effective_q, time_grid)
from repro.kernels.round_fused.ref import draw_step_noise
from repro.sde import VPSDE, solve


# ---------------------------------------------------------------------------
# coefficient goldens: accel (Li et al. 2024, arXiv:2403.03852)
# ---------------------------------------------------------------------------
def _vpsde_coeffs(nfe, lam=0.0):
    sde = VPSDE()
    ts = time_grid(sde, nfe)
    co = build_sampler_coeffs(sde, ts, q=1, lam=lam)
    coeff_shape = np.shape(np.asarray(sde.ops.eye()))
    return sde, ts, co, coeff_shape


def test_accel_rows_widen_and_sum_to_gddim_row():
    """The accel transform splits the single gDDIM predictor row into
    (row + corr, -corr): summed over the widened q_eff = 2 axis it
    reproduces the untransformed row, so accel differs from gddim only
    through the backward difference eps_i - eps_{i+1} it weights."""
    nfe = 6
    sde, ts, co, coeff_shape = _vpsde_coeffs(nfe)
    cfg = SamplerConfig(nfe=nfe, algorithm="accel")
    assert effective_q(cfg) == 2 and cfg.q == 1
    pC_a, cC_a, P_a = algorithm_coeff_stacks(co, cfg, coeff_shape)
    pC64 = np.asarray(co.pC, np.float64)
    assert pC_a.shape == (nfe, 2) + coeff_shape
    np.testing.assert_allclose(pC_a[:, 0] + pC_a[:, 1], pC64[:, 0],
                               rtol=1e-12, atol=0.0)
    # k = 0 (the first step from t_N) has no history: plain gDDIM row
    np.testing.assert_array_equal(pC_a[0, 0], pC64[0, 0])
    np.testing.assert_array_equal(pC_a[0, 1], np.zeros(coeff_shape))
    # corrector rows are zero-padded to q_eff, P untouched (deterministic)
    np.testing.assert_array_equal(cC_a[:, :1],
                                  np.asarray(co.cC, np.float64))
    np.testing.assert_array_equal(cC_a[:, 1], np.zeros_like(cC_a[:, 1]))
    np.testing.assert_array_equal(P_a, np.asarray(co.P_chol, np.float64))


def test_accel_slot_is_first_moment_over_step_gap():
    """Closed form of the correction weight (Li et al. Sec. 4, midpoint
    rule on the EI kernel): slot 1 at step k is exactly
    -pM_k / (2 (t_i - t_{i+1})) with pM_k the stored first moment."""
    nfe = 5
    sde, ts, co, coeff_shape = _vpsde_coeffs(nfe)
    cfg = SamplerConfig(nfe=nfe, algorithm="accel")
    pC_a, _, _ = algorithm_coeff_stacks(co, cfg, coeff_shape)
    ts64 = np.asarray(co.ts, np.float64)          # the transform's grid
    pM64 = np.asarray(co.pM, np.float64)
    for k in range(1, nfe):
        i = nfe - k
        delta = float(ts64[i] - ts64[i + 1])
        assert delta < 0.0                         # ts increases with i
        np.testing.assert_array_equal(pC_a[k, 1], -0.5 * pM64[k] / delta)


def test_accel_first_moment_matches_independent_quadrature():
    """The stored pM really is int_{t_i}^{t_{i-1}} ei_core(t_{i-1}, tau)
    (tau - t_i) dtau: recompute it with an independent fixed fine-grid
    Simpson rule from the SDE's public Psi/G2/Sigma/R surfaces."""
    nfe = 4
    sde, ts, co, coeff_shape = _vpsde_coeffs(nfe)
    ops = sde.ops

    def ei_core(t_end, tau):
        KinvT = ops.mul(ops.inv(sde.Sigma_np(tau)), sde.R_np(tau))
        return 0.5 * ops.mul(ops.mul(sde.Psi_np(t_end, tau),
                                     sde.G2_np(tau)), KinvT)

    pM64 = np.asarray(co.pM, np.float64)
    for k in range(nfe):
        i = nfe - k
        t_i, t_im1 = float(ts[i]), float(ts[i - 1])
        xs, w = solve.simpson_nodes(t_i, t_im1, 4096)
        ref = sum(wx * np.asarray(ei_core(t_im1, float(x)) * (x - t_i),
                                  np.float64)
                  for x, wx in zip(xs, w))
        np.testing.assert_allclose(pM64[k], ref, rtol=2e-5,
                                   atol=1e-12,
                                   err_msg=f"pM[{k}] != independent "
                                           "first-moment quadrature")


# ---------------------------------------------------------------------------
# coefficient goldens: gmm (Gabbur 2023, arXiv:2311.04938)
# ---------------------------------------------------------------------------
def test_gmm_moment_identity():
    """Moment matching of the K=2 mixture: the innovation z + c*s (s a
    fair sign) has variance 1 + c^2, and the bank's Cholesky rescale
    sqrt(1 - rho^2) restores unit variance — so the product
    (1 - rho^2)(1 + c^2) must be 1 (up to GMM_C's f32 storage)."""
    assert GMM_SCALE == float(np.sqrt(1.0 - GMM_RHO * GMM_RHO))
    prod = (1.0 - GMM_RHO * GMM_RHO) * (1.0 + float(GMM_C) ** 2)
    assert abs(prod - 1.0) < 1e-6
    # and GMM_C is exactly the f32 of rho / sqrt(1 - rho^2)
    assert GMM_C == np.float32(GMM_RHO / np.sqrt(1.0 - GMM_RHO * GMM_RHO))


def test_gmm_transform_scales_only_the_cholesky_rows():
    nfe = 6
    sde, ts, co, coeff_shape = _vpsde_coeffs(nfe, lam=0.7)
    cfg = SamplerConfig(nfe=nfe, lam=0.7, algorithm="gmm")
    assert effective_q(cfg) == 1
    pC_a, cC_a, P_a = algorithm_coeff_stacks(co, cfg, coeff_shape)
    np.testing.assert_array_equal(pC_a, np.asarray(co.pC, np.float64))
    np.testing.assert_array_equal(cC_a, np.asarray(co.cC, np.float64))
    np.testing.assert_array_equal(
        P_a, GMM_SCALE * np.asarray(co.P_chol, np.float64))
    assert np.any(P_a != np.asarray(co.P_chol, np.float64))


def test_gmm_innovation_moments_empirical():
    """The gmm draw is z + c * sign(second stream): empirically the signs
    are fair, the mean is ~0 and the variance ~1 + c^2 — which the bank's
    sqrt(1 - rho^2) row scale maps back to exactly 1."""
    sde = VPSDE()
    B, shape = 64, (1, 1024)
    rng = np.random.default_rng(7)
    keys = jnp.asarray(rng.integers(0, 2**32, (B, 2), dtype=np.uint64),
                       jnp.uint32)
    kc = jnp.zeros((B,), jnp.int32)
    alg = jnp.full((B,), ALG_GMM, jnp.int32)
    x = np.asarray(draw_step_noise(sde, keys, kc, alg, shape, jnp.float32),
                   np.float64).ravel()
    n = x.size
    c = float(GMM_C)
    assert abs(x.mean()) < 5.0 / np.sqrt(n)
    np.testing.assert_allclose(x.var(), 1.0 + c * c, rtol=2e-2)
    np.testing.assert_allclose(GMM_SCALE**2 * x.var(), 1.0, rtol=2e-2)
    # recover the sign stream from the second fold and check it is fair
    signs = []
    for b in range(B):
        step_key = jax.random.fold_in(
            jax.random.fold_in(keys[b], ALG_GMM), kc[b])
        s_norm = sde.noise_like(jax.random.fold_in(step_key, GMM_SALT),
                                shape, jnp.float32)
        signs.append(np.asarray(s_norm) >= 0)
    frac = np.mean(np.stack(signs))
    assert 0.45 < frac < 0.55


# ---------------------------------------------------------------------------
# the noise-keying law (satellite 2: algorithm id enters the stream)
# ---------------------------------------------------------------------------
def test_draw_step_noise_equals_explicit_chain():
    """`draw_step_noise` IS the chain key -> fold_in(alg) -> fold_in(k),
    bitwise, for every algorithm — the one law shared by the ref chain,
    the stitched serve step, the BDM outside-kernel stream and the dense
    oracle."""
    sde = VPSDE()
    shape = (1, 48)
    rng = np.random.default_rng(3)
    keys = jnp.asarray(rng.integers(0, 2**32, (6, 2), dtype=np.uint64),
                       jnp.uint32)
    kc = jnp.asarray([0, 1, 2, 3, 1, 2], jnp.int32)
    alg = jnp.asarray([ALG_GDDIM, ALG_GMM, ALG_ACCEL,
                       ALG_GDDIM, ALG_GMM, ALG_ACCEL], jnp.int32)
    got = np.asarray(draw_step_noise(sde, keys, kc, alg, shape,
                                     jnp.float32))
    for b in range(6):
        step_key = jax.random.fold_in(
            jax.random.fold_in(keys[b], alg[b]), kc[b])
        z = sde.noise_like(step_key, shape, jnp.float32)
        if int(alg[b]) == ALG_GMM:
            s_norm = sde.noise_like(jax.random.fold_in(step_key, GMM_SALT),
                                    shape, jnp.float32)
            s = jnp.where(s_norm >= 0, jnp.float32(1.0), jnp.float32(-1.0))
            z = z + GMM_C * s
        np.testing.assert_array_equal(
            got[b], np.asarray(z),
            err_msg=f"slot {b} (alg={ALGORITHMS[int(alg[b])]}) diverged "
                    "from the explicit fold chain")


def test_algorithm_ids_key_distinct_noise_streams():
    """Same seed, same step index, different algorithm => different noise
    (the PR-10 keying bugfix: previously only (seed, k) entered the
    stream, so same-seed co-residents of different algorithms shared
    noise)."""
    sde = VPSDE()
    shape = (1, 64)
    key = jnp.asarray([17, 42], jnp.uint32)
    keys = jnp.stack([key, key, key])
    kc = jnp.zeros((3,), jnp.int32)
    alg = jnp.asarray([ALG_GDDIM, ALG_GMM, ALG_ACCEL], jnp.int32)
    z = np.asarray(draw_step_noise(sde, keys, kc, alg, shape, jnp.float32))
    assert np.any(z[0] != z[1]) and np.any(z[0] != z[2]) \
        and np.any(z[1] != z[2])
    # and the gddim stream is the alg-folded one, not the legacy
    # fold_in(key, k)-only chain
    legacy = sde.noise_like(jax.random.fold_in(key, 0), shape, jnp.float32)
    assert np.any(z[0] != np.asarray(legacy))


# ---------------------------------------------------------------------------
# config validation: the constraint surface of the axis
# ---------------------------------------------------------------------------
def test_algorithm_validation():
    with pytest.raises(ValueError, match="unknown algorithm"):
        SamplerConfig(nfe=8, algorithm="ddpmx")
    with pytest.raises(ValueError, match="gmm"):
        SamplerConfig(nfe=8, algorithm="gmm")             # needs lam > 0
    with pytest.raises(ValueError, match="accel"):
        SamplerConfig(nfe=8, algorithm="accel", lam=0.5)  # deterministic
    with pytest.raises(ValueError, match="accel"):
        SamplerConfig(nfe=8, algorithm="accel", q=2)      # q stays 1
    with pytest.raises(ValueError, match="accel"):
        SamplerConfig(nfe=8, algorithm="accel", corrector=True)
    # the valid corners construct
    assert SamplerConfig(nfe=8, algorithm="gmm", lam=0.5).algorithm == "gmm"
    assert SamplerConfig(nfe=8, algorithm="accel").algorithm == "accel"
    assert effective_q(SamplerConfig(nfe=8, algorithm="accel")) == 2
    assert effective_q(SamplerConfig(nfe=5, q=2)) == 2
    assert effective_q(SamplerConfig(nfe=8, algorithm="gmm", lam=0.5)) == 1


# ---------------------------------------------------------------------------
# engine level: mixed-algorithm batch == solo runs, zero recompiles
# ---------------------------------------------------------------------------
class _TanhSpec:
    """A get_diffusion spec with the score net swapped for a cheap
    u/t-varying closed form.  The reduced checkpoints' eps is *constant*
    in (u, t) (zero-init output head), which collapses every
    eps-difference-based term — multistep history, the accel backward
    difference — to exactly zero; a varying eps is what makes the
    algorithm axis observable end to end."""

    def __init__(self, spec):
        self.__dict__["_spec"] = spec

    def __getattr__(self, nm):
        return getattr(self._spec, nm)

    def eps_model(self, params, u, t):
        tb = t.reshape((-1,) + (1,) * (u.ndim - 1)).astype(u.dtype)
        return jnp.tanh(u) * (0.5 + tb)


def test_mixed_algorithm_serve_bitwise_and_zero_recompiles():
    """One engine, one batch, all three algorithms co-resident: every
    request's sample is bitwise identical to its solo run, same-seed
    requests of different algorithms get different samples (the keying
    fix), and after a warmup that has seen each algorithm once the mixed
    serve triggers ZERO new compiles — the algorithm id is a bank int
    lane, not a compile bucket."""
    from repro.configs import get_diffusion
    from repro.serve import DiffusionEngine, SampleRequest

    spec = _TanhSpec(get_diffusion("cifar10-ddpm", reduced=True))
    params = spec.init(jax.random.PRNGKey(0))
    B = 2
    engine = DiffusionEngine(spec, params, batch_size=B, nfe=6)
    # warmup sizes every bucket (accel widens history to q_eff = 2)
    warm_out = engine.serve(
        [SampleRequest(rid=90, seed=9),
         SampleRequest(rid=91, seed=9, algorithm="accel"),
         SampleRequest(rid=92, seed=9, lam=0.5, algorithm="gmm")])
    warm = engine.compile_stats()
    assert warm["step"] == 1

    # same seed, different algorithm => different sample
    assert not np.array_equal(warm_out[90], warm_out[91])
    assert not np.array_equal(warm_out[90], warm_out[92])

    # a fresh traffic mix over the warmed algorithms plus ONE new config
    # (4 total: inside the warmed config bucket, like the nfe sweep of
    # test_diffusion_engine_zero_recompiles_across_nfe)
    reqs = [SampleRequest(rid=0, seed=0),
            SampleRequest(rid=1, seed=1, algorithm="accel"),
            SampleRequest(rid=2, seed=2, lam=0.5, algorithm="gmm"),
            SampleRequest(rid=3, seed=3, nfe=8, lam=0.5),
            SampleRequest(rid=4, seed=1, algorithm="accel")]
    mixed = engine.serve(reqs)
    assert engine.compile_stats() == warm, \
        "new algorithm mixes inside the warmed buckets must not recompile"
    assert set(mixed) == {r.rid for r in reqs}
    for r in reqs:
        solo = DiffusionEngine(spec, params, batch_size=B,
                               nfe=6).serve([r])
        np.testing.assert_array_equal(
            mixed[r.rid], solo[r.rid],
            err_msg=f"request {r.rid} (algorithm={r.algorithm or 'gddim'})"
                    " depends on neighbour algorithms")

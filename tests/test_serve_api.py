"""Tier: serve-api — the unified wire-level request surface (serve/api.py).

The contract under test is the one the router and the multi-host launch
harness stand on: `from_wire(to_wire(r)) == r` EXACTLY for every
constructible request (hypothesis sweeps the space when available), the
wire form is plain JSON (a real json.dumps/loads round-trip preserves
it), version/unknown-key traffic fails loudly at the boundary, requests
are frozen, and the historical `Request`/`SampleRequest` spellings are
true aliases — same fields, same wire form, value-equal across spellings.
"""
import dataclasses
import json

import numpy as np
import pytest

from repro.serve import Request, SampleRequest, ServeRequest, WIRE_VERSION
from repro.serve.api import WORKLOADS


def _diffusion_req(**kw):
    base = dict(rid=1, workload="diffusion", seed=7, nfe=20, q=2,
                corrector=True, lam=0.5, grid="uniform", family="cld",
                priority=2, deadline=40.0)
    base.update(kw)
    return ServeRequest(**base)


def _token_req(**kw):
    base = dict(rid=2, workload="token", seed=0,
                tokens=np.array([3, 1, 4, 1, 5], dtype=np.int32),
                max_new=8,
                frames=np.arange(6, dtype=np.float32).reshape(2, 3))
    base.update(kw)
    return ServeRequest(**base)


class TestWireRoundTrip:
    @pytest.mark.parametrize("req", [
        ServeRequest(rid=0),
        _diffusion_req(),
        _token_req(),
        _token_req(frames=None, deadline=None),
        SampleRequest(rid=3, seed=9, nfe=10),
        SampleRequest(rid=5, seed=2, nfe=8, lam=0.5, algorithm="gmm"),
        SampleRequest(rid=6, seed=3, algorithm="accel"),
        Request(rid=4, tokens=np.zeros(3, np.int32), max_new=1),
    ])
    def test_exact_round_trip(self, req):
        wire = req.to_wire()
        assert ServeRequest.from_wire(wire) == req

    def test_wire_is_plain_json(self):
        # the dict must survive a REAL serialize/parse — this is the form
        # the router writes to disk and launchgate ships across processes
        wire = _token_req().to_wire()
        back = json.loads(json.dumps(wire))
        req = ServeRequest.from_wire(back)
        assert req == _token_req()
        assert req.tokens.dtype == np.int32
        assert req.frames.dtype == np.float32

    def test_wire_carries_schema_version(self):
        assert _diffusion_req().to_wire()["v"] == WIRE_VERSION

    def test_unknown_version_rejected(self):
        wire = _diffusion_req().to_wire()
        wire["v"] = WIRE_VERSION + 1
        with pytest.raises(ValueError, match="schema version"):
            ServeRequest.from_wire(wire)
        wire.pop("v")
        with pytest.raises(ValueError, match="schema version"):
            ServeRequest.from_wire(wire)

    def test_unknown_key_rejected(self):
        wire = _diffusion_req().to_wire()
        wire["negative_prompt"] = "blurry"
        with pytest.raises(ValueError, match="negative_prompt"):
            ServeRequest.from_wire(wire)


class TestRequestSemantics:
    def test_frozen(self):
        req = _diffusion_req()
        with pytest.raises(dataclasses.FrozenInstanceError):
            req.seed = 99

    def test_replace_still_works(self):
        # the online tests build config variants with dataclasses.replace;
        # the alias subclasses must keep that working
        req = SampleRequest(rid=0, seed=0, nfe=10)
        assert dataclasses.replace(req, nfe=20).nfe == 20

    def test_workload_validated(self):
        with pytest.raises(ValueError, match="workload"):
            ServeRequest(rid=0, workload="video")

    def test_token_workload_needs_tokens(self):
        with pytest.raises(ValueError, match="tokens"):
            ServeRequest(rid=0, workload="token")

    def test_array_fields_normalized(self):
        req = ServeRequest(rid=0, workload="token",
                           tokens=[1, 2, 3], frames=[[0.5, 1.5]])
        assert req.tokens.dtype == np.int32
        assert req.frames.dtype == np.float32
        assert req.prompt_len == 3

    def test_equality_is_value_and_alias_blind(self):
        a = SampleRequest(rid=5, seed=1, nfe=10)
        b = ServeRequest(rid=5, workload="diffusion", seed=1, nfe=10)
        assert a == b and b == a
        assert a != dataclasses.replace(b, seed=2)
        assert _token_req() != _token_req(
            tokens=np.array([9, 9, 9], np.int32))

    def test_aliases_share_fields_and_wire_form(self):
        names = [f.name for f in dataclasses.fields(ServeRequest)]
        for alias, workload in ((Request, "token"),
                                (SampleRequest, "diffusion")):
            assert [f.name for f in dataclasses.fields(alias)] == names
            assert alias.__dataclass_fields__["workload"].default == workload
        tok = Request(rid=0, tokens=np.ones(2, np.int32))
        assert ServeRequest.from_wire(tok.to_wire()) == tok
        assert tok.workload == "token"


class TestWireRoundTripProperty:
    """Hypothesis sweep of the constructible request space (skipped where
    hypothesis isn't installed — CI's differential job has it)."""

    def test_round_trip_property(self):
        hyp = pytest.importorskip("hypothesis")
        st = pytest.importorskip("hypothesis.strategies")

        opt_int = st.none() | st.integers(min_value=1, max_value=1000)
        samplers = st.fixed_dictionaries({
            "nfe": opt_int, "q": st.none() | st.integers(1, 4),
            "corrector": st.none() | st.booleans(),
            "lam": st.none() | st.floats(0.0, 1.0,
                                         allow_nan=False, width=32),
            "grid": st.none() | st.sampled_from(["quadratic", "uniform"]),
            "family": st.none() | st.sampled_from(["vpsde", "cld", "bdm"]),
            "precision": st.none() | st.sampled_from(["f32", "bf16",
                                                      "int8"]),
            "algorithm": st.none() | st.sampled_from(["gddim", "gmm",
                                                      "accel"]),
        })

        @st.composite
        def requests(draw):
            workload = draw(st.sampled_from(WORKLOADS))
            kw = dict(rid=draw(st.integers(-10, 10**6)),
                      workload=workload,
                      seed=draw(st.integers(0, 2**31 - 1)),
                      priority=draw(st.integers(-3, 3)),
                      deadline=draw(st.none() | st.floats(
                          0.0, 1e6, allow_nan=False, width=32)),
                      max_new=draw(st.integers(1, 64)),
                      **draw(samplers))
            if workload == "token" or draw(st.booleans()):
                n = draw(st.integers(1, 8))
                kw["tokens"] = np.asarray(
                    draw(st.lists(st.integers(0, 2**31 - 1),
                                  min_size=n, max_size=n)), np.int32)
            if draw(st.booleans()):
                kw["frames"] = np.asarray(
                    draw(st.lists(st.lists(
                        st.floats(-1e6, 1e6, allow_nan=False, width=32),
                        min_size=3, max_size=3),
                        min_size=2, max_size=2)), np.float32)
            return ServeRequest(**kw)

        @hyp.settings(max_examples=200, deadline=None)
        @hyp.given(requests())
        def prop(req):
            wire = json.loads(json.dumps(req.to_wire()))
            back = ServeRequest.from_wire(wire)
            assert back == req
            # exactness, not tolerance: arrays bitwise, scalars identical
            if req.tokens is not None:
                assert back.tokens.tobytes() == req.tokens.tobytes()
            if req.frames is not None:
                assert back.frames.tobytes() == req.frames.tobytes()

        prop()

"""gDDIM generality (arbitrary anisotropic SDE) + the App. C.8 likelihood."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.sde import VPSDE, CLD, GaussianMixture, ExactScore
from repro.sde.general import GeneralSDE
from repro.core import build_sampler_coeffs, time_grid, sample_gddim
from repro.core.likelihood import log_likelihood


@pytest.fixture(scope="module")
def gsde():
    return GeneralSDE()


class TestGeneralSDE:
    def test_R_factorizes_sigma(self, gsde):
        for t in (0.05, 0.3, 0.7, 1.0):
            R = gsde.R_np(t)
            np.testing.assert_allclose(R @ R.T, gsde.Sigma_np(t),
                                       rtol=1e-3, atol=1e-8)

    def test_R_differs_from_L(self, gsde):
        """Away from every special case, the Cholesky choice is NOT Eq. 17."""
        R, L = gsde.R_np(0.5), gsde.L_np(0.5)
        assert np.abs(R - L).max() > 1e-3

    def test_eps_constancy_prop4(self, gsde):
        """eps_GT = -R^T score is constant along exact prob-flow solutions."""
        mix = GaussianMixture(np.array([[0.6]]), np.array([1e-4]), np.array([1.0]))
        oracle = ExactScore(gsde, mix)
        ts = time_grid(gsde, 64, "uniform")
        co = build_sampler_coeffs(gsde, ts, q=1)
        eps_fn, _ = oracle.eps_fn_for_grid(ts)
        u = gsde.prior_sample(jax.random.PRNGKey(0), 8, (1,))
        N = co.psi.shape[0]
        eps0 = eps_fn(u, jnp.int32(N))
        for k in range(N):
            i = N - k
            e = eps_fn(u, jnp.int32(i))
            np.testing.assert_allclose(np.asarray(e), np.asarray(eps0),
                                       rtol=2e-2, atol=2e-3)
            u = gsde.apply(co.psi[k], u) + gsde.apply(co.pC[k, 0], e)

    def test_one_step_dirac_recovery(self):
        """Prop 2/4: exact score + K=R recovers the data point in ONE step.

        The achievable accuracy is floored by the diffusion width at the
        stopping time — the flow transports the prior to p_{t_min}, whose
        x-channel std is sqrt(Sigma_x(t_min)) ~ sqrt(G2_xx * t_min)
        (verified: the residual spread tracks this scale exactly and is
        NFE-independent, i.e. it is not sampler error).  The default
        t_min=1e-3 gives a 0.025 floor, wider than these bounds, so the
        recovery test stops at t_min=1e-4 (floor 0.008)."""
        gsde = GeneralSDE(t_min=1e-4)
        mix = GaussianMixture(np.array([[0.37]]), np.array([1e-5]), np.array([1.0]))
        oracle = ExactScore(gsde, mix)
        ts = np.array([gsde.t_min, gsde.T])
        co = build_sampler_coeffs(gsde, ts, q=1)
        eps_fn, _ = oracle.eps_fn_for_grid(ts)
        u_T = gsde.prior_sample(jax.random.PRNGKey(1), 16, (1,))
        u0 = sample_gddim(gsde, co, eps_fn, u_T, q=1)
        x0 = np.asarray(gsde.project_data(u0))
        # ONE step from pure noise lands within a few percent of the data
        # point (grid-interpolated R_t on a fully anisotropic SDE); a
        # one-step Euler from N(0, Sigma_T) would leave O(1) spread.
        assert np.abs(x0 - 0.37).mean() < 0.025, x0.ravel()
        assert np.abs(x0 - 0.37).max() < 0.06, x0.ravel()
        assert np.std(x0) < 0.05  # collapsed onto the Dirac, not spread

    def test_R_smoother_than_L(self, gsde):
        """The paper's mechanism on the general SDE: eps under K=R_t is
        markedly smoother along prob-flow solutions than under the Cholesky
        L_t (the property that lets multistep EI take large steps).
        Measured: TV_L ~ 1.03 vs TV_R ~ 0.47 at these coefficients."""
        from repro.core.coeffs import _K_fn
        mix = GaussianMixture(np.array([[1.0], [-1.0]]), np.array([0.05, 0.05]),
                              np.array([1.0, 1.0]))
        oracle = ExactScore(gsde, mix)
        tv = {}
        for kt in ("L", "R"):
            ts = time_grid(gsde, 100, "uniform")
            co = build_sampler_coeffs(gsde, ts, q=1, kt=kt)
            eps_fn, _ = oracle.eps_fn_for_grid(ts, _K_fn(gsde, kt))
            u = gsde.prior_sample(jax.random.PRNGKey(2), 32, (1,))
            N = co.psi.shape[0]
            prev, acc = None, 0.0
            for k in range(N):
                e = eps_fn(u, jnp.int32(N - k))
                if prev is not None:
                    acc += float(jnp.abs(e - prev).mean())
                prev = e
                u = gsde.apply(co.psi[k], u) + gsde.apply(co.pC[k, 0], e)
            tv[kt] = acc
        assert tv["R"] < 0.7 * tv["L"], tv


class TestLikelihood:
    def test_vpsde_gaussian_exact(self):
        """Single tight Gaussian: prob-flow NLL == analytic log-density."""
        sde = VPSDE()
        std = 0.3
        mix = GaussianMixture(np.array([[0.2, -0.4]]), np.array([std]),
                              np.array([1.0]))
        oracle = ExactScore(sde, mix)
        x = jnp.asarray(np.array([[0.2, -0.4], [0.5, 0.0], [-0.1, -0.7]],
                                 np.float32))
        ll = log_likelihood(sde, lambda u, t: oracle.score(u, t), x,
                            n_steps=150)
        # analytic: N(mu, (std^2 + t_min-ish smoothing)) — compare at the
        # sde-smoothed time t_min
        a = sde.alpha(sde.t_min)
        var = a * std**2 + (1 - a)
        mu = np.sqrt(a) * np.array([0.2, -0.4])
        d = np.asarray(x) - mu
        ref = -0.5 * (d**2).sum(-1) / var - np.log(2 * np.pi * var)
        np.testing.assert_allclose(np.asarray(ll), ref, rtol=1e-2, atol=5e-2)

    def test_hutchinson_matches_exact(self):
        sde = VPSDE()
        mix = GaussianMixture(np.array([[0.0, 0.0]]), np.array([0.5]),
                              np.array([1.0]))
        oracle = ExactScore(sde, mix)
        x = jnp.asarray(np.array([[0.1, 0.2]], np.float32))
        exact = log_likelihood(sde, lambda u, t: oracle.score(u, t), x,
                               n_steps=100)
        keys = jax.random.split(jax.random.PRNGKey(0), 16)
        hut = jnp.mean(jnp.stack([
            log_likelihood(sde, lambda u, t: oracle.score(u, t), x,
                           n_steps=100, hutchinson=True, key=k)
            for k in keys]), axis=0)
        np.testing.assert_allclose(np.asarray(hut), np.asarray(exact),
                                   rtol=5e-2, atol=0.1)

"""Differential lockdown of the fused round megakernel (the PR-8 tier).

The fused post-score-eval update (`kernels/round_fused`) replaces the
XLA-stitched chain the engine ran through PR 7.  The old chain survives as
`make_diffusion_round_step_stitched`, and this suite locks the swap at
three levels, mirroring the PR-5 factored-bank lockdown:

  1. coefficient level — `ops._stage_factors`'s stacked SMEM slots are
     exactly the stitched chain's per-term gathers (same rows, same
     diag-pool ids, slot for slot);
  2. round-step level — `make_diffusion_round_step` (ref impl: the CPU
     serving path) is BITWISE equal to the stitched step on co-resident
     mixed-config states, across family x q x corrector x stochastic,
     including frozen other-family / retired slots;
  3. engine level — a mixed-family serve on the fused-step engine equals,
     bitwise per request, the same engine running the stitched steps
     (staggered admission, retire-and-refill, q=2, corrector, lambda>0).

The Pallas kernel itself is parity-tested in tests/test_kernels.py
(bitwise for kf=1 families; the CLD kf=2 block contraction is allowed the
documented one-rounding FMA gap — see `apply_factored_ref`'s docstring).
"""
import functools
import zlib

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import CoeffCache, SamplerConfig
from repro.launch.steps import (make_diffusion_round_step,
                                make_diffusion_round_step_stitched)
from repro.sde import BDM, CLD, VPSDE
from repro.serve.state import DiffusionState
from repro.kernels.round_fused import ops as rf_ops

DATA_SHAPE = (4, 4, 3)
FAMILIES = ["vpsde", "cld", "bdm"]


@functools.lru_cache(maxsize=1)
def _bank_parts():
    cache = CoeffCache({"vpsde": VPSDE(), "cld": CLD(),
                        "bdm": BDM(data_shape=DATA_SHAPE)},
                       data_shape=DATA_SHAPE)
    cfgs = [SamplerConfig(nfe=4),
            SamplerConfig(nfe=5, q=2),
            SamplerConfig(nfe=4, family="cld"),
            SamplerConfig(nfe=4, family="cld", q=2, corrector=True),
            SamplerConfig(nfe=4, family="bdm"),
            SamplerConfig(nfe=4, family="bdm", q=2, corrector=True),
            SamplerConfig(nfe=6, lam=0.7),
            SamplerConfig(nfe=3, family="bdm", lam=0.5),
            # the PR-10 algorithm axis rides the same differential: accel
            # widens its rows to effective q=2, gmm transforms P_chol and
            # the noise law — both must track the stitched chain bitwise
            SamplerConfig(nfe=4, algorithm="accel"),
            SamplerConfig(nfe=6, lam=0.7, algorithm="gmm"),
            SamplerConfig(nfe=3, family="bdm", lam=0.5, algorithm="gmm"),
            SamplerConfig(nfe=5, family="cld", algorithm="accel")]
    idx = [cache.index_of(c) for c in cfgs]
    return cache, cfgs, idx, cache.factored_bank


class _ToySpec:
    """Cheap deterministic eps model: the differential isolates the
    post-eval chain, not the net."""

    def __init__(self, sde, data_shape):
        self.sde = sde
        self.data_shape = tuple(data_shape)

    def eps_model(self, params, u, t):
        tb = t.reshape((-1,) + (1,) * (u.ndim - 1)).astype(u.dtype)
        return jnp.tanh(u) * (0.5 + tb)


def _mixed_state(fam, B, seed, *, other_retired=False):
    """A co-resident state: B slots of `fam` (cycled over its configs)
    plus one slot of another family and one retired slot — the step must
    freeze both verbatim."""
    cache, cfgs, idx, bank = _bank_parts()
    rng = np.random.default_rng(seed)
    K, D = cache.k_max, int(np.prod(DATA_SHAPE))
    Qb = bank.pC_blk.shape[2]
    slots = [(c, cfg) for c, cfg in zip(idx, cfgs)
             if cache.resolve(cfg) == fam]
    other = [(c, cfg) for c, cfg in zip(idx, cfgs)
             if cache.resolve(cfg) != fam][0]
    rows = [slots[i % len(slots)] for i in range(B)] + [other, slots[0]]
    Bt = len(rows)
    fam_ids = [cache.fam_index(cache.resolve(cfg)) for _, cfg in rows]
    active = [True] * (Bt - 1) + [False]
    return DiffusionState(
        u=jnp.asarray(rng.standard_normal((Bt, K, D)), jnp.float32),
        hist=jnp.asarray(rng.standard_normal((Bt, Qb, K, D)), jnp.float32),
        k=jnp.asarray(rng.integers(0, 4, Bt), jnp.int32),
        cfg=jnp.asarray([c for c, _ in rows], jnp.int32),
        fam=jnp.asarray(fam_ids, jnp.int32),
        prec=jnp.zeros((Bt,), jnp.int32),
        keys=jnp.asarray(rng.integers(0, 2**32, (Bt, 2), dtype=np.uint64),
                         jnp.uint32),
        active=jnp.asarray(active))


# ---------------------------------------------------------------------------
# level 1: the staged SMEM factor slots ARE the stitched chain's gathers
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("fam,kf", [("vpsde", 1), ("cld", 2), ("bdm", 1)])
@pytest.mark.parametrize("with_corrector", [False, True])
def test_staged_factors_equal_stitched_gathers(fam, kf, with_corrector):
    cache, cfgs, idx, bank = _bank_parts()
    state = _mixed_state(fam, 3, zlib.crc32(fam.encode()) % 997)
    kc = jnp.clip(state.k, 0, bank.n_steps[state.cfg] - 1)
    blks, dis = rf_ops._stage_factors(bank, state.cfg, kc, kf,
                                      with_corrector)
    Qb = bank.pC_blk.shape[2]
    names = [("psi", None), ("B", None), ("P_chol", None)] \
        + [("pC", j) for j in range(Qb)] \
        + ([("cC", j) for j in range(Qb)] if with_corrector else [])
    assert blks.shape[1] == len(names) == dis.shape[1]
    for s, (nm, j) in enumerate(names):
        blk = getattr(bank, nm + "_blk")[state.cfg, kc]
        di = getattr(bank, nm + "_di")[state.cfg, kc]
        if j is not None:
            blk, di = blk[:, j], di[:, j]
        np.testing.assert_array_equal(np.asarray(blks[:, s]),
                                      np.asarray(blk[:, :kf, :kf]))
        np.testing.assert_array_equal(np.asarray(dis[:, s]),
                                      np.asarray(di))


# ---------------------------------------------------------------------------
# level 2: fused round step == stitched round step, bitwise
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("fam", FAMILIES)
@pytest.mark.parametrize("with_corrector", [False, True])
def test_round_step_bitwise_equals_stitched(fam, with_corrector):
    cache, cfgs, idx, bank = _bank_parts()
    spec = _ToySpec(cache.sdes[fam], DATA_SHAPE)
    fi = cache.fam_index(fam)
    step_f = jax.jit(make_diffusion_round_step(spec, fam_index=fi),
                     static_argnames=("with_corrector",))
    step_s = jax.jit(make_diffusion_round_step_stitched(spec, fam_index=fi),
                     static_argnames=("with_corrector",))
    seed = zlib.crc32(repr((fam, with_corrector)).encode()) % 997
    state = _mixed_state(fam, 4, seed)
    out_f = step_f(None, state, bank, with_corrector=with_corrector)
    out_s = step_s(None, state, bank, with_corrector=with_corrector)
    for nm, a, b in zip(DiffusionState._fields, out_f, out_s):
        np.testing.assert_array_equal(
            np.asarray(a), np.asarray(b),
            err_msg=f"{fam} corr={with_corrector}: fused {nm} != stitched")


def test_round_step_chains_bitwise_over_trajectory():
    """Not just one step: iterating the fused step from admission to
    retirement tracks the stitched chain bitwise the whole way (the
    history shift / k-advance / retire feedback loop is exact too)."""
    cache, cfgs, idx, bank = _bank_parts()
    spec = _ToySpec(cache.sdes["vpsde"], DATA_SHAPE)
    fi = cache.fam_index("vpsde")
    step_f = jax.jit(make_diffusion_round_step(spec, fam_index=fi),
                     static_argnames=("with_corrector",))
    step_s = jax.jit(make_diffusion_round_step_stitched(spec, fam_index=fi),
                     static_argnames=("with_corrector",))
    state = _mixed_state("vpsde", 3, 11)
    state = state._replace(k=jnp.zeros_like(state.k))
    sf = ss = state
    for _ in range(7):                       # past the nfe=4 retirements
        sf = step_f(None, sf, bank, with_corrector=False)
        ss = step_s(None, ss, bank, with_corrector=False)
    for nm, a, b in zip(DiffusionState._fields, sf, ss):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                      err_msg=f"trajectory {nm} diverged")


# ---------------------------------------------------------------------------
# level 3: fused-step engine == stitched-step engine, end to end
# ---------------------------------------------------------------------------
def _stitched_engine(specs, params, **kw):
    """A DiffusionEngine whose round variants run the PRE-FUSION chain —
    the end-to-end oracle (f32 only: the stitched chain predates the
    precision axis)."""
    from repro.serve import DiffusionEngine
    from repro.serve.engine import _jit_state_update
    eng = DiffusionEngine(specs, params, **kw)
    eng._steps = {
        (n, "f32"): _jit_state_update(
            make_diffusion_round_step_stitched(
                s, fam_index=eng.cache.fam_index(n)),
            (1,), eng._state_sh, static_argnames=("with_corrector",))
        for n, s in eng.specs.items()}
    return eng


def test_mixed_family_serve_bitwise_equals_stitched_engine():
    from repro.configs import get_diffusion
    from repro.serve import DiffusionEngine, SampleRequest
    specs, params = {}, {}
    for i, (fam, name) in enumerate((("vpsde", "cifar10-ddpm"),
                                     ("cld", "cifar10-cld"),
                                     ("bdm", "cifar10-bdm"))):
        specs[fam] = get_diffusion(name, reduced=True)
        params[fam] = specs[fam].init(jax.random.PRNGKey(100 + i))
    reqs = [SampleRequest(rid=0, seed=0),                          # vpsde
            SampleRequest(rid=1, seed=1, family="cld", nfe=5),
            SampleRequest(rid=2, seed=2, family="bdm", nfe=4),
            SampleRequest(rid=3, seed=3, family="cld", nfe=6, q=2,
                          corrector=True),
            SampleRequest(rid=4, seed=4, family="vpsde", nfe=8, lam=0.5)]
    out = DiffusionEngine(specs, params, batch_size=2, nfe=6).serve(reqs)
    ref = _stitched_engine(specs, params, batch_size=2, nfe=6).serve(reqs)
    assert set(out) == set(ref) == {r.rid for r in reqs}
    for rid in sorted(out):
        np.testing.assert_array_equal(
            out[rid], ref[rid],
            err_msg=f"rid {rid}: fused engine != stitched engine")

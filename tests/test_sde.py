"""SDE substrate tests: closed forms, solver invariants, forward-marginal
agreement (Monte Carlo), and the exact-score oracle."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.sde import VPSDE, CLD, BDM, GaussianMixture, ExactScore, dct_nd, idct_nd


# ---------------------------------------------------------------------------
# VPSDE closed forms
# ---------------------------------------------------------------------------
class TestVPSDE:
    def test_alpha_endpoints(self):
        vp = VPSDE()
        assert vp.alpha(0.0) == pytest.approx(1.0)
        assert vp.alpha(vp.T) < 1e-4  # essentially pure noise at T

    def test_psi_group_property(self):
        vp = VPSDE()
        for (t, s, r) in [(0.9, 0.5, 0.2), (1.0, 0.7, 0.1)]:
            assert vp.Psi_np(t, s) * vp.Psi_np(s, r) == pytest.approx(vp.Psi_np(t, r))

    def test_R_is_sqrt_sigma(self):
        vp = VPSDE()
        for t in [0.1, 0.5, 0.9]:
            assert vp.R_np(t) ** 2 == pytest.approx(vp.Sigma_np(t))

    def test_R_solves_eq17(self):
        # dR/dt = (F + 0.5 G2 / Sigma) R  — finite-difference check
        vp = VPSDE()
        t, h = 0.5, 1e-6
        dR = (vp.R_np(t + h) - vp.R_np(t - h)) / (2 * h)
        rhs = (vp.F_np(t) + 0.5 * vp.G2_np(t) / vp.Sigma_np(t)) * vp.R_np(t)
        assert dR == pytest.approx(rhs, rel=1e-4)


# ---------------------------------------------------------------------------
# CLD: Lyapunov / Eq. 17 invariants + Monte-Carlo marginal agreement
# ---------------------------------------------------------------------------
class TestCLD:
    def test_RRt_equals_sigma_on_range(self):
        cld = CLD()
        for t in [1e-3, 0.01, 0.05, 0.2, 0.5, 0.8, 1.0]:
            S, R = cld.Sigma_np(t), cld.R_np(t)
            assert np.abs(R @ R.T - S).max() < 5e-4, t

    def test_L_is_cholesky(self):
        cld = CLD()
        L = cld.L_np(0.4)
        assert L[0, 1] == pytest.approx(0.0)
        assert np.abs(L @ L.T - cld.Sigma_np(0.4)).max() < 1e-12

    def test_R_differs_from_L(self):
        # the paper's whole point: the gDDIM branch is NOT the Cholesky factor
        cld = CLD()
        assert np.abs(cld.R_np(0.5) - cld.L_np(0.5)).max() > 0.5

    def test_sigma_solves_lyapunov(self):
        cld = CLD()
        t, h = 0.3, 1e-6
        dS = (cld.Sigma_np(t + h) - cld.Sigma_np(t - h)) / (2 * h)
        S = cld.Sigma_np(t)
        rhs = cld.A @ S + S @ cld.A.T + cld.G2_np(t)
        assert np.abs(dS - rhs).max() < 1e-5

    def test_psi_transition_ode(self):
        cld = CLD()
        t, h = 0.6, 1e-6
        dP = (cld.Psi_np(t + h, 0.0) - cld.Psi_np(t - h, 0.0)) / (2 * h)
        assert np.abs(dP - cld.A @ cld.Psi_np(t, 0.0)).max() < 1e-4

    def test_forward_marginal_monte_carlo(self):
        """Simulate the forward CLD with EM; sample mean/cov must match
        Psi(t,0) u0 / Sigma_t.  This validates F, G, Psi, Sigma jointly."""
        cld = CLD()
        rng = np.random.default_rng(0)
        n, t_end, n_steps = 20000, 0.5, 400
        x0 = np.array([1.3])
        u = np.zeros((n, 2, 1))
        u[:, 0, 0] = x0
        u[:, 1, 0] = rng.normal(0, np.sqrt(cld.gamma / cld.M_inv), n)
        dt = t_end / n_steps
        g = np.sqrt(2 * cld.Gamma * cld.beta * dt)
        for _ in range(n_steps):
            drift = np.einsum("ij,bjd->bid", cld.A, u)
            u = u + drift * dt
            u[:, 1, 0] += g * rng.normal(size=n)
        mean_mc = u.mean(0)[:, 0]
        cov_mc = np.cov(u[:, :, 0].T)
        mean_an = (cld.Psi_np(t_end, 0.0) @ np.array([x0[0], 0.0]))
        cov_an = cld.Sigma_np(t_end)
        assert np.abs(mean_mc - mean_an).max() < 0.03
        assert np.abs(cov_mc - cov_an).max() < 0.03


# ---------------------------------------------------------------------------
# BDM: DCT basis + frequency schedule
# ---------------------------------------------------------------------------
class TestBDM:
    def test_dct_orthonormal(self):
        x = jnp.asarray(np.random.default_rng(1).normal(size=(2, 8, 8, 3)), jnp.float32)
        y = idct_nd(dct_nd(x, (1, 2)), (1, 2))
        assert jnp.abs(y - x).max() < 1e-5

    def test_g2_nonnegative(self):
        bdm = BDM(data_shape=(8, 8, 1))
        for t in np.linspace(1e-3, 1 - 1e-3, 50):
            assert bdm.G2_np(t).min() >= 0.0

    def test_psi_is_alpha_ratio(self):
        bdm = BDM(data_shape=(8, 8, 1))
        p = bdm.Psi_np(0.3, 0.7)
        assert np.allclose(p, bdm.alpha_k(0.3) / bdm.alpha_k(0.7))

    def test_high_freq_blurs_faster(self):
        # blur dissipation must shrink high frequencies more than DC
        bdm = BDM(data_shape=(8, 8, 1))
        a = bdm.alpha_k(0.5)
        assert a.flat[0] == a.max()          # DC least attenuated
        assert a[-1, -1, 0] == a.min()       # highest frequency most attenuated

    def test_sigma_isotropic_R_equals_L(self):
        bdm = BDM(data_shape=(8, 8, 1))
        assert np.allclose(bdm.R_np(0.4), bdm.L_np(0.4))

    def test_forward_marginal_monte_carlo(self):
        """EM-simulate the BDM SDE on a tiny 1-D signal; marginal mean must
        match Psi(t,0) x0 (i.e. blur+scale) and variance sigma_t^2."""
        bdm = BDM(data_shape=(4, 1))  # 4-pixel 1-D signal
        rng = np.random.default_rng(2)
        n, t_end, n_steps = 20000, 0.4, 600
        x0 = np.array([1.0, -0.5, 0.25, 0.8])[:, None]
        u = np.tile(x0[None], (n, 1, 1))
        dt = t_end / n_steps
        from repro.sde.base import dct_matrix
        C = dct_matrix(4)
        for k in range(n_steps):
            t = k * dt
            F = bdm.F_np(t)[:, 0]  # (4,) freq diag
            G2 = bdm.G2_np(t)[:, 0]
            y = np.einsum("fk,bkc->bfc", C, u)
            y = y + F[None, :, None] * y * dt
            y = y + np.sqrt(np.maximum(G2, 0) * dt)[None, :, None] * rng.normal(size=y.shape)
            u = np.einsum("kf,bfc->bkc", C.T, y)
        mean_mc = u.mean(0)
        # analytic: V diag(alpha_t/alpha_0) V^T x0
        ratio = bdm.alpha_k(t_end)[:, 0] / bdm.alpha_k(0.0)[:, 0]
        mean_an = C.T @ (ratio[:, None] * (C @ x0))
        assert np.abs(mean_mc - mean_an).max() < 0.03
        var_mc = u.var(0).mean()
        assert abs(var_mc - bdm.sigma2(t_end)) < 0.03


# ---------------------------------------------------------------------------
# Exact-score oracle
# ---------------------------------------------------------------------------
class TestExactScore:
    def _fd_check(self, sde, mix, u, t):
        """Finite-difference the mixture log-density and compare to score."""
        oracle = ExactScore(sde, mix)
        s = oracle.score_np(u, t)
        # log density via mode constants
        _, consts = oracle._mode_constants(t)

        def logp(uu):
            vals = []
            for mu, Cinv, logdet, logw in consts:
                d = (uu - mu).reshape(-1)
                if sde.ops.family == "block":
                    dd = (uu - mu)
                    tmp = np.einsum("ij,j...->i...", Cinv, dd)
                    qf = float(np.sum(dd * tmp))
                elif sde.ops.family == "scalar":
                    qf = float(Cinv * np.sum(d * d))
                else:
                    dh = oracle._dct_np((uu - mu)[None])[0]
                    qf = float(np.sum(dh * dh * Cinv))
                vals.append(logw - 0.5 * qf - 0.5 * logdet)
            m = max(vals)
            return m + np.log(sum(np.exp(v - m) for v in vals))

        h = 1e-5
        g = np.zeros_like(u[0])
        it = np.nditer(u[0], flags=["multi_index"])
        while not it.finished:
            idx = it.multi_index
            up, dn = u[0].copy(), u[0].copy()
            up[idx] += h
            dn[idx] -= h
            g[idx] = (logp(up) - logp(dn)) / (2 * h)
            it.iternext()
        assert np.abs(g - s[0]).max() < 1e-3 * max(1.0, np.abs(s).max())

    def test_score_vs_fd_vpsde(self):
        vp = VPSDE()
        mix = GaussianMixture(np.array([[1.0, -1.0], [-1.0, 0.5]]),
                              np.array([0.3, 0.2]), np.array([0.6, 0.4]))
        u = np.array([[0.3, 0.1]])
        self._fd_check(vp, mix, u, 0.4)

    def test_score_vs_fd_cld(self):
        cld = CLD()
        mix = GaussianMixture(np.array([[1.0, -1.0]]), np.array([0.3]), np.array([1.0]))
        u = np.array([[[0.3, 0.1], [-0.2, 0.4]]])  # (1, 2, 2)
        self._fd_check(cld, mix, u, 0.4)

    def test_score_vs_fd_bdm(self):
        bdm = BDM(data_shape=(4, 1))
        mix = GaussianMixture(np.array([[[1.0], [-0.5], [0.2], [0.8]]]),
                              np.array([0.3]), np.array([1.0]))
        u = np.array([[[0.3], [0.1], [-0.2], [0.5]]])
        self._fd_check(bdm, mix, u, 0.4)

    def test_device_score_matches_host(self):
        vp = VPSDE()
        mix = GaussianMixture(np.array([[1.0, -1.0], [-1.0, 0.5]]),
                              np.array([0.3, 0.2]), np.array([0.5, 0.5]))
        oracle = ExactScore(vp, mix)
        u = np.random.default_rng(3).normal(size=(16, 2))
        s_host = oracle.score_np(u, 0.3)
        s_dev = np.asarray(oracle.score(jnp.asarray(u, jnp.float32), 0.3))
        assert np.abs(s_host - s_dev).max() < 1e-3

    def test_mixture_sample_moments(self):
        mix = GaussianMixture(np.array([[2.0], [-2.0]]), np.array([0.1, 0.1]),
                              np.array([0.5, 0.5]))
        x = np.asarray(mix.sample(jax.random.PRNGKey(0), 40000))
        assert abs(x.mean()) < 0.05
        assert abs(x.var() - (4.0 + 0.01)) < 0.1

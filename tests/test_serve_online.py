"""Online serving: streaming arrivals, deadline-aware preemption, and the
virtual-clock simulation rig that proves it.

Three layers, cheapest first:

  * traffic unit level — `VirtualClock` monotonicity, `TraceTraffic`
    consumption order, seeded `poisson_trace` replay, and the pure-Python
    `percentile` against numpy's.
  * simulation level (tests/sim_clock.py) — the *golden* tests: a
    hand-written trace through the real `ServeLoop.serve_stream`
    machinery with a pure-host engine, where every timestamp, preemption,
    poll and latency percentile is computed by hand in the comments and
    asserted exactly.  Also: deterministic replay of a seeded Poisson
    stream, and the poll-cadence bound (an arrival-dense trace must not
    degrade to per-round syncing).
  * engine level — the real `DiffusionEngine` / `TokenEngine` under
    preemption: a suspended+resumed request's output is **bitwise**
    identical to an uninterrupted solo run (plain, mid-multistep q=2
    eps-history, mixed VPSDE/CLD co-residency, token decode with KV
    caches), and a warmed engine replays a fresh online stream with zero
    recompiles.  The 2-device mesh variant lives in test_serve_mesh.py.
"""
import math

import numpy as np
import jax
import pytest

from repro.configs import get_arch, get_diffusion
from repro.models.registry import Arch
from repro.serve import (Arrival, DiffusionEngine, Request, SampleRequest,
                         TokenEngine, TraceTraffic, VirtualClock,
                         poisson_trace, serving_metrics)
from repro.serve.traffic import percentile

from tests.sim_clock import (HostSimEngine, RecordingClock, SimRequest,
                             trace_of)


# ---------------------------------------------------------------------------
# traffic unit level
# ---------------------------------------------------------------------------
def test_virtual_clock_monotone():
    c = VirtualClock()
    c.advance(2.5)
    assert c.now() == 2.5
    c.advance_to(2.0)                       # no-op for past times
    assert c.now() == 2.5
    c.advance_to(4.0)
    assert c.now() == 4.0
    with pytest.raises(ValueError):
        c.advance(-1.0)


def test_trace_traffic_consumption():
    t = trace_of((1.0, SimRequest(rid=0)), (0.5, SimRequest(rid=1)),
                 (3.0, SimRequest(rid=2)))
    assert t.next_time() == 0.5             # sorted regardless of input order
    assert [a.request.rid for a in t.due(1.0)] == [1, 0]
    assert t.due(1.0) == []                 # popped, not re-delivered
    assert t.next_time() == 3.0 and t.remaining() == 1
    assert [a.request.rid for a in t.due(10.0)] == [2]
    assert t.next_time() is None


def test_poisson_trace_is_seed_deterministic():
    mk = lambda i, rng: SimRequest(rid=i, work=int(rng.integers(1, 5)),
                                   priority=int(rng.integers(0, 3)))
    a = poisson_trace(mk, n=20, rate=0.5, seed=7)
    b = poisson_trace(mk, n=20, rate=0.5, seed=7)
    c = poisson_trace(mk, n=20, rate=0.5, seed=8)
    ta = [x.t for x in a.due(float("inf"))]
    tb = [x.t for x in b.due(float("inf"))]
    tc = [x.t for x in c.due(float("inf"))]
    assert ta == tb and ta != tc
    assert all(isinstance(t, float) for t in ta)   # plain host floats
    ra = [x.request for x in a._queue]
    rb = [x.request for x in b._queue]
    assert [(r.work, r.priority) for r in ra] == \
           [(r.work, r.priority) for r in rb]
    with pytest.raises(ValueError):
        poisson_trace(mk, n=3, rate=0.0, seed=0)


def test_percentile_matches_numpy():
    rng = np.random.default_rng(0)
    for n in (1, 2, 5, 17):
        xs = rng.uniform(0, 10, size=n).tolist()
        for p in (0.0, 25.0, 50.0, 99.0, 100.0):
            assert math.isclose(percentile(xs, p),
                                float(np.percentile(xs, p)))
    with pytest.raises(ValueError):
        percentile([], 50.0)


def test_serving_metrics_zero_completion_log():
    """Regression (PR 10 bugfix): a log in which nothing completed —
    every request shed or still queued — is a valid input.  The latency
    percentiles must be None (NOT 0.0, which read as "instant"), goodput
    0.0, and every unfinished request that carried a deadline counts as
    a miss (it has already lost its SLO).  `percentile([])` itself keeps
    raising — the guard lives in `serving_metrics`, not the primitive."""
    from repro.serve.traffic import RequestTiming

    m = serving_metrics({})
    assert m["n_arrived"] == m["n_done"] == 0
    assert m["p50_latency"] is None and m["p99_latency"] is None
    assert m["goodput_slo"] == 0.0 and m["deadline_misses"] == 0

    log = {0: RequestTiming(t_arrival=0.0, deadline=4.0),
           1: RequestTiming(t_arrival=1.0),                 # no deadline
           2: RequestTiming(t_arrival=2.0, deadline=100.0, t_admit=3.0)}
    m = serving_metrics(log)
    assert m["n_arrived"] == 3 and m["n_done"] == 0
    assert m["p50_latency"] is None and m["p99_latency"] is None
    assert m["goodput_slo"] == 0.0 and m["span"] == 0.0
    assert m["deadline_misses"] == 2


def test_shed_everything_trace_zero_completion_metrics():
    """A trace every request of which is shed (sole replica faulted for
    the whole run) must produce valid metrics end to end: the router
    counts the sheds, the empty residue drains through the real
    `ServeLoop.serve_stream` without dispatching a round, and
    `serving_metrics` on the zero-completion log reports None/0.0
    instead of raising."""
    from repro.serve.router import ReplicaSpec, Router, RouterConfig

    reqs = [SampleRequest(rid=i, seed=i, nfe=4, deadline=float(5 + i))
            for i in range(4)]
    trace = TraceTraffic([Arrival(float(i), r)
                          for i, r in enumerate(reqs)])
    router = Router([ReplicaSpec(index=0, batch=2,
                                 fault_windows=((0.0, 1e9),))],
                    RouterConfig(default_nfe=4))
    eng = HostSimEngine(batch_size=2)
    results, plan = router.serve(trace, [eng])
    assert results == {}
    assert plan.counters["n_shed"] == len(reqs)
    assert {s["rid"] for s in plan.shed} == {r.rid for r in reqs}
    assert plan.sub_traces[0] == []

    # the shed-everything residue still runs through the real loop
    out = eng.serve_stream(router.replica_trace(plan, 0),
                           clock=VirtualClock())
    assert out == {}
    assert eng.n_rounds == 0
    m = serving_metrics(eng.request_log)
    assert m["n_arrived"] == m["n_done"] == 0
    assert m["p50_latency"] is None and m["p99_latency"] is None
    assert m["goodput_slo"] == 0.0 and m["deadline_misses"] == 0


# ---------------------------------------------------------------------------
# golden simulation: every number below is hand-computed from the trace
# ---------------------------------------------------------------------------
def test_golden_schedule_and_metrics():
    """B=2 slots, sync_every=4, round_cost=1.  Trace:

        t=0   r0 (work 4)        r1 (work 6)         -> both admitted at 0
        t=3   r2 (work 2, priority 2, deadline 6)
        t=20  r3 (work 3)

    Hand-computed schedule:
      * rounds at t=1,2,3 (window capped by r2's arrival).
      * t=3: r2 preempts — victims are both prio-0 slots; r1 has the most
        remaining work (3 vs r0's 1), so r1 is parked at k=3 and r2 takes
        its slot.  One more round (r0's retirement bound) to t=4, then the
        poll at t_mark=4 with a look-ahead round to t=5: r0 retires with
        t_done=4 (4 rounds, t0->4); r2 finished *inside* the look-ahead
        (k=2 at t=5) so it is not observed yet.
      * t=5: r1 resumes into the freed slot (k=3 preserved).  r2's bound
        is exhausted -> poll at t_mark=5 retires r2 (t_done=5: rounds
        t3->4, t4->5; deadline 6 met), look-ahead round to t=6.
      * rounds to t=8; poll retires r1 at t_done=8 (3 rounds before the
        park + 3 after: t5->6 look-ahead, t6->8).
      * idle skip 8->20; r3 runs t20->23, retires at t_done=23.

    Latencies [4, 8, 2, 3] -> sorted [2, 3, 4, 8]:
      p50 = 3.5 (rank 1.5), p99 = 4*0.03 + 8*0.97 = 7.88 (rank 2.97).
    All four met their SLO -> goodput = 4 / span(23).
    """
    eng = HostSimEngine(batch_size=2, sync_every=4)
    clock = RecordingClock()
    trace = trace_of(
        (0.0, SimRequest(rid=0, work=4)),
        (0.0, SimRequest(rid=1, work=6)),
        (3.0, SimRequest(rid=2, work=2, priority=2, deadline=6.0)),
        (20.0, SimRequest(rid=3, work=3)))
    results = eng.serve_stream(trace, clock=clock)

    assert {rid: int(v) for rid, v in results.items()} == \
           {0: 4, 1: 6, 2: 2, 3: 3}
    log = eng.request_log
    assert [(log[r].t_admit, log[r].t_done, log[r].n_preempted)
            for r in range(4)] == \
           [(0.0, 4.0, 0), (0.0, 8.0, 1), (3.0, 5.0, 0), (20.0, 23.0, 0)]
    assert eng.preemption_log == [(2, 2, 1, 0)]
    assert eng.n_preemptions == 1 and eng.n_resumes == 1
    assert eng.parking.n_parked_total == 1 and len(eng.parking) == 0
    assert eng.n_polls == 4 and eng.n_rounds == 11

    # the exact clock journal: 11 rounds + the one idle skip
    assert clock.events == [
        ("round", 1.0), ("round", 2.0), ("round", 3.0), ("round", 4.0),
        ("round", 5.0), ("round", 6.0), ("round", 7.0), ("round", 8.0),
        ("skip", 20.0), ("round", 21.0), ("round", 22.0), ("round", 23.0)]

    m = serving_metrics(log)
    assert m["n_arrived"] == 4 and m["n_done"] == 4
    assert m["p50_latency"] == 3.5
    assert math.isclose(m["p99_latency"], 7.88)
    assert m["deadline_misses"] == 0
    assert m["span"] == 23.0
    assert math.isclose(m["goodput_slo"], 4 / 23)


def test_golden_deadline_miss_excluded_from_goodput():
    """B=1: r0 (work 4, deadline 2 — unmeetable) then r1 (work 4, no
    deadline) queued behind it.  r0 finishes at t=4 (missed), r1 at t=8.
    Goodput counts only the SLO-met completion: 1 / span(8)."""
    eng = HostSimEngine(batch_size=1, sync_every=8)
    trace = trace_of((0.0, SimRequest(rid=0, work=4, deadline=2.0)),
                     (0.0, SimRequest(rid=1, work=4)))
    eng.serve_stream(trace)
    log = eng.request_log
    assert log[0].t_done == 4.0 and not log[0].met_slo
    assert log[1].t_done == 8.0 and log[1].met_slo
    m = serving_metrics(log)
    assert m["deadline_misses"] == 1
    assert math.isclose(m["goodput_slo"], 1 / 8)


def test_poisson_stream_replays_identically():
    """The whole online run — timestamps, preemptions, waves, metrics — is
    a pure function of (trace seed, engine config): two replays agree on
    everything, field for field."""
    mk = lambda i, rng: SimRequest(
        rid=i, work=int(rng.integers(2, 8)),
        priority=int(rng.integers(0, 3)),
        deadline=None if rng.integers(0, 2) == 0
        else float(rng.integers(10, 60)))

    def run():
        eng = HostSimEngine(batch_size=3, sync_every=4)
        res = eng.serve_stream(poisson_trace(mk, n=24, rate=0.7, seed=11))
        return eng, res

    a, res_a = run()
    b, res_b = run()
    assert res_a == res_b
    assert a.request_log == b.request_log
    assert a.preemption_log == b.preemption_log
    assert a.wave_log == b.wave_log
    assert (a.n_preemptions, a.n_resumes, a.n_polls, a.n_rounds) == \
           (b.n_preemptions, b.n_resumes, b.n_polls, b.n_rounds)
    assert serving_metrics(a.request_log) == serving_metrics(b.request_log)
    # the run exercised what it claims to: work queued beyond capacity
    # with mixed priorities forces preemptions
    assert a.n_preemptions > 0
    assert serving_metrics(a.request_log)["n_done"] == 24


def test_arrival_dense_stream_does_not_poll_per_round():
    """Satellite: arrival-capped round windows end with no slot at its
    retirement bound; the loop must *skip* the poll there (frozen rows
    make late observation safe), not regress to per-round syncing.  With
    work=16 and an arrival every round for a while, polls stay paced by
    `sync_every`/retirements — far below one per round."""
    eng = HostSimEngine(batch_size=2, sync_every=8)
    arrivals = [(0.0, SimRequest(rid=0, work=16)),
                (0.0, SimRequest(rid=1, work=16))]
    arrivals += [(float(t), SimRequest(rid=2 + t, work=16))
                 for t in range(1, 7)]
    eng.serve_stream(trace_of(*arrivals))
    assert serving_metrics(eng.request_log)["n_done"] == 8
    # 8 requests x 16 rounds of work on 2 slots ~= 64+ occupied rounds;
    # a per-round-sync regression would put n_polls within a couple of
    # n_rounds.  Paced correctly it is bounded by forced syncs plus one
    # poll per retirement bound.
    assert eng.n_rounds >= 64
    assert eng.n_polls <= eng.n_rounds // eng.sync_every + 8 + 1
    assert 4 * eng.n_polls < eng.n_rounds


def test_preemption_only_evicts_strictly_lower_priority():
    """Equal priority never preempts (no churn): two prio-1 residents and
    a stream of prio-1 arrivals -> zero preemptions, FIFO-by-urgency."""
    eng = HostSimEngine(batch_size=2, sync_every=4)
    trace = trace_of(*[(float(i), SimRequest(rid=i, work=4, priority=1))
                       for i in range(6)])
    eng.serve_stream(trace)
    assert eng.n_preemptions == 0
    assert serving_metrics(eng.request_log)["n_done"] == 6


# ---------------------------------------------------------------------------
# real engines: preemption is bitwise-invisible, replay is compile-free
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def diff_parts():
    spec = get_diffusion("cifar10-ddpm", reduced=True)
    params = spec.init(jax.random.PRNGKey(0))
    return spec, params


def _preempt_trace(**extra):
    """Two prio-0 residents from t=0, one prio-5 arrival at t=2 that must
    preempt (batch of 2 is full and both residents are strictly lower
    priority)."""
    return TraceTraffic([
        Arrival(0.0, SampleRequest(rid=0, seed=0, **extra)),
        Arrival(0.0, SampleRequest(rid=1, seed=1, **extra)),
        Arrival(2.0, SampleRequest(rid=2, seed=2, priority=5, deadline=12.0,
                                   **extra)),
    ])


def test_diffusion_preempt_resume_bitwise_and_compile_free(diff_parts):
    spec, params = diff_parts
    eng = DiffusionEngine(spec, params, batch_size=2, nfe=8, sync_every=4)
    results = eng.serve_stream(_preempt_trace(), clock=VirtualClock())

    assert eng.n_preemptions == 1 and eng.n_resumes == 1
    victim = eng.preemption_log[0][2]
    assert eng.request_log[victim].n_preempted == 1
    assert eng.request_log[2].met_slo       # the urgent render made its SLO

    solo = DiffusionEngine(spec, params, batch_size=2, nfe=8)
    for rid in (0, 1, 2):
        ref = solo.serve([SampleRequest(rid=rid, seed=rid)])[rid]
        np.testing.assert_array_equal(
            results[rid], ref,
            err_msg=f"rid {rid}: online (preempting) run != solo")

    # replaying a fresh stream — new seeds, preemption + resume again —
    # must not compile anything new: park/resume/steps are all warmed
    warm = eng.compile_stats()
    eng.serve_stream(TraceTraffic([
        Arrival(0.0, SampleRequest(rid=10, seed=10)),
        Arrival(0.0, SampleRequest(rid=11, seed=11)),
        Arrival(3.0, SampleRequest(rid=12, seed=12, priority=2)),
    ]), clock=VirtualClock())
    assert eng.n_preemptions == 2           # cumulative: preempted again
    assert eng.compile_stats() == warm


def test_diffusion_preempt_mid_multistep_q2_bitwise(diff_parts):
    """Preemption lands mid-flight with a populated q=2 eps history (the
    victim is past k=2 when suspended), so the parked row carries live
    multistep state — restored bitwise, the resumed trajectory must equal
    the uninterrupted one."""
    spec, params = diff_parts
    eng = DiffusionEngine(spec, params, batch_size=2, nfe=8, sync_every=4)
    trace = _preempt_trace(q=2)
    results = eng.serve_stream(trace, clock=VirtualClock())
    assert eng.n_preemptions == 1
    victim = eng.preemption_log[0][2]
    assert eng.request_log[victim].n_preempted == 1

    solo = DiffusionEngine(spec, params, batch_size=2, nfe=8)
    for rid in (0, 1, 2):
        ref = solo.serve([SampleRequest(rid=rid, seed=rid, q=2)])[rid]
        np.testing.assert_array_equal(
            results[rid], ref,
            err_msg=f"rid {rid} (q=2): online (preempting) run != solo")


def test_diffusion_preempt_mixed_family_bitwise():
    """VPSDE and CLD co-resident when the preemption hits: the parked and
    resumed row is a CLD (K=2) render suspended next to a VPSDE slot, and
    every sample still equals its solo single-family run bitwise.  Waves
    never mix (family, corrector) classes, preemption or not."""
    specs = {"vpsde": get_diffusion("cifar10-ddpm", reduced=True),
             "cld": get_diffusion("cifar10-cld", reduced=True)}
    params = {n: specs[n].init(jax.random.PRNGKey(100 + i))
              for i, n in enumerate(specs)}
    eng = DiffusionEngine(specs, params, batch_size=2, nfe=8, sync_every=4)
    trace = TraceTraffic([
        Arrival(0.0, SampleRequest(rid=0, seed=0, family="cld")),
        Arrival(1.0, SampleRequest(rid=1, seed=1, family="vpsde")),
        Arrival(3.0, SampleRequest(rid=2, seed=2, family="vpsde",
                                   priority=5)),
    ])
    results = eng.serve_stream(trace, clock=VirtualClock())
    assert eng.n_preemptions >= 1 and eng.n_resumes == eng.n_preemptions
    for wave in eng.wave_log:               # class-homogeneous, always
        assert len(set(wave)) == 1, eng.wave_log

    for rid, fam in ((0, "cld"), (1, "vpsde"), (2, "vpsde")):
        solo = DiffusionEngine(specs[fam], params[fam], batch_size=2, nfe=8)
        ref = solo.serve([SampleRequest(rid=rid, seed=rid)])[rid]
        np.testing.assert_array_equal(
            results[rid], ref,
            err_msg=f"rid {rid} ({fam}): mixed-family online run != solo")


def test_token_preempt_resume_bitwise_and_compile_free():
    """Token decode under preemption: the parked payload spans the
    TokenState row *and* the KV-cache rows; the resumed continuation must
    reproduce the uninterrupted token stream exactly, and a second online
    stream must not compile anything new (snapshot/park/resume warmed)."""
    spec = get_arch("gemma3-1b", reduced=True)
    arch = Arch(spec)
    params = arch.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    mk = lambda rid, L, m, **kw: Request(
        rid=rid, tokens=rng.integers(2, arch.cfg.vocab, size=L)
        .astype(np.int32), max_new=m, **kw)

    eng = TokenEngine(arch, params, batch_size=2, max_len=48, sync_every=4)
    reqs = [mk(0, 6, 12), mk(1, 6, 12), mk(2, 6, 8, priority=3,
                                           deadline=20.0)]
    trace = TraceTraffic([Arrival(0.0, reqs[0]), Arrival(0.0, reqs[1]),
                          Arrival(3.0, reqs[2])])
    results = eng.serve_stream(trace, clock=VirtualClock())
    assert eng.n_preemptions == 1 and eng.n_resumes == 1
    assert eng.compile_stats()["snapshot"] == 1     # double-buffered poll

    solo = TokenEngine(arch, params, batch_size=2, max_len=48)
    for r in reqs:
        ref = solo.serve([Request(rid=90, tokens=r.tokens,
                                  max_new=r.max_new)])[90]
        np.testing.assert_array_equal(
            results[r.rid], ref,
            err_msg=f"rid {r.rid}: online (preempting) run != solo")

    warm = eng.compile_stats()
    reqs2 = [mk(10, 6, 12), mk(11, 6, 12), mk(12, 6, 8, priority=3)]
    eng.serve_stream(TraceTraffic([Arrival(0.0, reqs2[0]),
                                   Arrival(0.0, reqs2[1]),
                                   Arrival(3.0, reqs2[2])]),
                     clock=VirtualClock())
    assert eng.n_preemptions == 2
    assert eng.compile_stats() == warm

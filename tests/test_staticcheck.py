"""Tier: staticcheck — the analysis subsystem analysed.

Layer 1 (AST lint): every rule fires exactly at the tagged lines of the
seeded fixtures under tests/staticcheck_fixtures/, the negative cases in
the same files stay silent, and a full pass over src/ is finding-free
(the repo is the no-false-positives corpus).

Layer 2 (jaxpr/HLO sanitizer): the seeded bad BlockSpec trips PL201 and
PL202, a host callback trips JX101, float64 avals trip JX102, and the
donation audit distinguishes a donation XLA honors from one it silently
drops (JX103).
"""
import os
import pathlib
import subprocess
import sys

import pytest

from tools.staticcheck.astlint import lint_paths, lint_source
from tools.staticcheck.findings import (Finding, apply_allowlist,
                                        parse_allowlist)

REPO = pathlib.Path(__file__).resolve().parents[1]
FIXTURES = REPO / "tests" / "staticcheck_fixtures"


def _hits(name):
    """(rule, line) pairs from linting one fixture file."""
    path = FIXTURES / name
    return {(f.rule, f.line)
            for f in lint_source(path.read_text(), str(path))}


def _tagged_lines(name, tag="fires here"):
    """Lines carrying the `# <RULE> fires here` marker in a fixture."""
    return {i for i, line in
            enumerate((FIXTURES / name).read_text().splitlines(), start=1)
            if tag in line}


# ---------------------------------------------------------------- layer 1

class TestFixturesFire:
    """Each seeded violation anchors at exactly its tagged line."""

    @pytest.mark.parametrize("name,rule", [
        ("key_reuse.py", "SC101"),
        ("raw_key.py", "SC102"),
        ("host_sync.py", "SC103"),
        ("f64_literal.py", "SC104"),
        ("donation.py", "SC105"),
    ])
    def test_rule_fires_at_tagged_lines_only(self, name, rule):
        hits = _hits(name)
        want = {(rule, ln) for ln in _tagged_lines(name)}
        assert want, f"fixture {name} lost its tags"
        assert hits == want, (
            f"{name}: expected exactly {sorted(want)}, got {sorted(hits)}")

    def test_negatives_documented(self):
        # every fixture carries at least one NOT-a-violation case, so the
        # exact-match assertions above double as false-positive tests
        for name in ("key_reuse.py", "raw_key.py", "host_sync.py",
                     "f64_literal.py", "donation.py"):
            assert "NOT " in (FIXTURES / name).read_text(), name


class TestOnlineHotPathRegistration:
    """The online-serving modules (serve/traffic.py, serve/parking.py)
    are registered hot paths: SC103 fires for sources linted under those
    *paths* with no pragma in the file, and the real parking module's one
    sanctioned fetch carries an allowlist justification."""

    NEW_SUFFIXES = ("src/repro/serve/traffic.py",
                    "src/repro/serve/parking.py")

    def test_suffixes_registered_in_default_config(self):
        from tools.staticcheck.astlint import DEFAULT_CONFIG
        for suffix in self.NEW_SUFFIXES:
            assert suffix in DEFAULT_CONFIG.hot_path_suffixes, suffix

    @pytest.mark.parametrize("suffix", NEW_SUFFIXES)
    def test_sc103_fires_by_path_at_tagged_lines(self, suffix):
        src = (FIXTURES / "online_hot_path.py").read_text()
        assert "staticcheck: module=" not in src  # path does the scoping
        hits = {(f.rule, f.line) for f in lint_source(src, suffix)}
        want = {("SC103", ln) for ln in _tagged_lines("online_hot_path.py")}
        assert want, "fixture lost its tags"
        assert hits == want, (
            f"{suffix}: expected exactly {sorted(want)}, got {sorted(hits)}")

    def test_same_source_is_silent_off_the_hot_path(self):
        src = (FIXTURES / "online_hot_path.py").read_text()
        assert lint_source(src, "src/repro/eval/metrics.py") == []

    def test_sc105_fires_for_parked_row_donation_misuse(self):
        # the parking restore pattern done wrong: `state` is donated into
        # the jitted restore, then read again instead of reassigned
        bad = ("import jax\n"
               "def resume(state, row):\n"
               "    restore = jax.jit(lambda s, r: s, donate_argnums=(0,))\n"
               "    new = restore(state, row)\n"
               "    return state.active\n")
        for suffix in self.NEW_SUFFIXES:
            rules = {(f.rule, f.line) for f in lint_source(bad, suffix)}
            assert ("SC105", 5) in rules, (suffix, rules)
        good = ("import jax\n"
                "def resume(state, row):\n"
                "    restore = jax.jit(lambda s, r: s, donate_argnums=(0,))\n"
                "    state = restore(state, row)\n"
                "    return state.active\n")
        assert lint_source(good, self.NEW_SUFFIXES[1]) == []

    def test_repo_parking_fetch_is_allowlisted_with_reason(self):
        src = (REPO / "src" / "repro" / "serve" / "parking.py").read_text()
        assert "staticcheck: disable=SC103" in src


class TestRouterHotPathRegistration:
    """The router-tier modules (serve/api.py, serve/router.py) are
    registered hot paths: the router's per-arrival plan loop and the
    request type's wire path must stay pure host Python, so SC103 fires
    for sources linted under those *paths* with no pragma in the file,
    and api.py's one construction-time dtype normalization carries an
    allowlist justification."""

    NEW_SUFFIXES = ("src/repro/serve/api.py",
                    "src/repro/serve/router.py")

    def test_suffixes_registered_in_default_config(self):
        from tools.staticcheck.astlint import DEFAULT_CONFIG
        for suffix in self.NEW_SUFFIXES:
            assert suffix in DEFAULT_CONFIG.hot_path_suffixes, suffix

    @pytest.mark.parametrize("suffix", NEW_SUFFIXES)
    def test_sc103_fires_by_path_at_tagged_lines(self, suffix):
        src = (FIXTURES / "router_hot_path.py").read_text()
        assert "staticcheck: module=" not in src  # path does the scoping
        hits = {(f.rule, f.line) for f in lint_source(src, suffix)}
        want = {("SC103", ln) for ln in _tagged_lines("router_hot_path.py")}
        assert want, "fixture lost its tags"
        assert hits == want, (
            f"{suffix}: expected exactly {sorted(want)}, got {sorted(hits)}")

    def test_same_source_is_silent_off_the_hot_path(self):
        src = (FIXTURES / "router_hot_path.py").read_text()
        assert lint_source(src, "src/repro/eval/metrics.py") == []

    def test_sc105_fires_for_replica_state_donation_misuse(self):
        # a routed replica done wrong: slot state donated into the jitted
        # round, then the *stale* reference read for the result harvest
        bad = ("import jax\n"
               "def round_and_harvest(state, coeffs):\n"
               "    step = jax.jit(lambda s, c: s, donate_argnums=(0,))\n"
               "    new = step(state, coeffs)\n"
               "    return state.outputs\n")
        for suffix in self.NEW_SUFFIXES:
            rules = {(f.rule, f.line) for f in lint_source(bad, suffix)}
            assert ("SC105", 5) in rules, (suffix, rules)

    def test_repo_api_normalization_is_allowlisted_with_reason(self):
        src = (REPO / "src" / "repro" / "serve" / "api.py").read_text()
        assert "staticcheck: disable=SC103" in src

    def test_repo_router_and_api_lint_clean_as_hot_paths(self):
        findings = lint_paths(
            [str(REPO / "src" / "repro" / "serve" / "api.py"),
             str(REPO / "src" / "repro" / "serve" / "router.py")])
        assert findings == [], "\n".join(f.text() for f in findings)


class TestAllowlist:
    def test_disable_with_reason_suppresses(self):
        src = ("import jax\n"
               "def f(n):\n"
               "    k = jax.random.PRNGKey(0)  "
               "# staticcheck: disable=SC102 (test helper)\n"
               "    return jax.random.normal(k, (n,))\n"
               "# staticcheck: module=library\n")
        assert lint_source(src, "x.py") == []

    def test_disable_without_reason_is_sc000(self):
        src = "x = 1  # staticcheck: disable=SC103\n"
        findings = lint_source(src, "x.py")
        assert [(f.rule, f.line) for f in findings] == [("SC000", 1)]

    def test_multiple_rules_one_comment(self):
        disabled, bad = parse_allowlist(
            "y  # staticcheck: disable=SC101,SC105 (both intended)\n", "x.py")
        assert disabled == {1: {"SC101", "SC105"}}
        assert bad == []

    def test_apply_allowlist_is_line_scoped(self):
        f1 = Finding("SC103", "x.py", 3, "m")
        f2 = Finding("SC103", "x.py", 4, "m")
        kept = apply_allowlist([f1, f2], {3: {"SC103"}})
        assert kept == [f2]

    def test_syntax_error_is_sc900(self):
        findings = lint_source("def f(:\n", "x.py")
        assert [f.rule for f in findings] == ["SC900"]


class TestRepoIsClean:
    """The no-false-positives corpus: src/ and tools/ lint clean."""

    def test_src_tree_has_no_findings(self):
        findings = lint_paths([str(REPO / "src")])
        assert findings == [], "\n".join(f.text() for f in findings)

    def test_tools_tree_has_no_findings(self):
        findings = lint_paths([str(REPO / "tools")])
        assert findings == [], "\n".join(f.text() for f in findings)

    def test_cli_exit_codes(self):
        env = dict(os.environ, PYTHONPATH=str(REPO / "src"))
        clean = subprocess.run(
            [sys.executable, "-m", "tools.staticcheck", "src/"],
            cwd=REPO, env=env, capture_output=True, text=True)
        assert clean.returncode == 0, clean.stdout + clean.stderr
        for name in ("key_reuse.py", "raw_key.py", "host_sync.py",
                     "f64_literal.py", "donation.py"):
            seeded = subprocess.run(
                [sys.executable, "-m", "tools.staticcheck",
                 f"tests/staticcheck_fixtures/{name}"],
                cwd=REPO, env=env, capture_output=True, text=True)
            assert seeded.returncode == 1, (name, seeded.stdout)


# ---------------------------------------------------------------- layer 2

class TestSanitizer:
    def test_bad_blockspec_trips_pl201_and_pl202(self):
        from tests.staticcheck_fixtures import bad_blockspec
        from tools.staticcheck import pallas_check as plc
        closed = bad_blockspec.bad_blockspec_trace()
        eqns = plc.find_pallas_eqns(closed.jaxpr)
        assert len(eqns) == 1
        rules = {f.rule for f in plc.check_pallas_eqn(eqns[0], "fixture")}
        assert "PL201" in rules          # 32 does not divide 48
        assert "PL202" in rules          # index map walks off the array

    def test_bad_round_fused_trips_pl201_and_pl202(self):
        # the real megakernel body behind a launch that drops the
        # padding contract: ragged block + an overshooting d-tile
        from tests.staticcheck_fixtures import bad_round_fused
        from tools.staticcheck import pallas_check as plc
        closed = bad_round_fused.bad_round_fused_trace()
        eqns = plc.find_pallas_eqns(closed.jaxpr)
        assert len(eqns) == 1
        findings = plc.check_pallas_eqn(eqns[0], "fixture")
        rules = {f.rule for f in findings}
        assert "PL201" in rules          # 32 does not divide 48
        assert "PL202" in rules          # second d-tile spans [32, 64)
        # the SMEM scalar rows are exempt: only VMEM state streams flagged
        assert all("SMEM" not in f.message for f in findings
                   if f.rule in ("PL201", "PL202"))

    def test_clean_kernels_have_no_findings(self):
        from tools.staticcheck import menu
        from tools.staticcheck import pallas_check as plc
        entries = menu.kernel_entries()
        labels = [label for label, _ in entries]
        # the fused-round megakernel is a registered layer-2 entry
        assert any("round_fused/round_fused[" in l for l in labels)
        assert any("round_fused/round_fused+corr[" in l for l in labels)
        assert any("round_fused/round_predict[" in l for l in labels)
        findings = []
        for label, closed in entries:
            findings += plc.check_traced(closed.jaxpr, label)
        assert findings == [], "\n".join(f.text() for f in findings)

    def test_callback_trips_jx101(self):
        from tests.staticcheck_fixtures import bad_blockspec
        from tools.staticcheck import jaxprcheck as jxc
        closed = bad_blockspec.callback_step_trace()
        rules = {f.rule for f in jxc.check_no_callbacks(closed.jaxpr, "fx")}
        assert rules == {"JX101"}

    def test_f64_trips_jx102(self):
        import jax
        import jax.numpy as jnp
        from tools.staticcheck import jaxprcheck as jxc
        with jax.experimental.enable_x64():
            closed = jax.make_jaxpr(lambda x: x * 2.0)(
                jnp.zeros((2,), jnp.float64))
        rules = {f.rule for f in jxc.check_dtypes(closed.jaxpr, "fx")}
        assert rules == {"JX102"}
        # and bf16 is fine in general mode but not under f32_only
        closed16 = jax.make_jaxpr(lambda x: x * 2)(
            jnp.zeros((2,), jnp.bfloat16))
        assert jxc.check_dtypes(closed16.jaxpr, "fx") == []
        strict = jxc.check_dtypes(closed16.jaxpr, "fx", f32_only=True)
        assert {f.rule for f in strict} == {"JX102"}

    def test_donation_audit_jx103(self):
        from tests.staticcheck_fixtures import bad_blockspec
        from tools.staticcheck import jaxprcheck as jxc
        low, comp = bad_blockspec.dropped_donation_artifacts()
        dropped = jxc.check_donation(low, comp, "fx", expect_donation=True)
        assert {f.rule for f in dropped} == {"JX103"}
        low, comp = bad_blockspec.honored_donation_artifacts()
        assert jxc.check_donation(low, comp, "fx",
                                  expect_donation=True) == []

    def test_jaxpr_hash_is_stable_and_shape_sensitive(self):
        import jax
        import jax.numpy as jnp
        from tools.staticcheck import jaxprcheck as jxc
        f = lambda x: jnp.tanh(x) + 1
        a = jxc.jaxpr_hash(jax.make_jaxpr(f)(jnp.zeros((4,))).jaxpr)
        b = jxc.jaxpr_hash(jax.make_jaxpr(f)(jnp.zeros((4,))).jaxpr)
        c = jxc.jaxpr_hash(jax.make_jaxpr(f)(jnp.zeros((8,))).jaxpr)
        assert a == b
        assert a != c
        assert len(a) == 16

    def test_hash_stability_reports_bucket_escape(self):
        from tools.staticcheck import jaxprcheck as jxc
        same = {"v": "aa"}
        assert jxc.check_hash_stability(same, {"v": "aa"}, "t") == []
        drift = jxc.check_hash_stability({"v": "aa"}, {"v": "bb"}, "t")
        assert {f.rule for f in drift} == {"JX105"}


@pytest.mark.slow
def test_quick_sanitizer_end_to_end():
    """The real serve menu, traced and sanitized: zero findings."""
    env = dict(os.environ, PYTHONPATH=str(REPO / "src"))
    proc = subprocess.run(
        [sys.executable, "-m", "tools.staticcheck", "--sanitize", "--quick"],
        cwd=REPO, env=env, capture_output=True, text=True, timeout=1200)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "ok (0 finding(s))" in proc.stdout

"""gDDIM core tests: every proposition/theorem of the paper has a check.

Prop 1/4  eps-constancy along exact prob-flow solutions (R_t vs L_t)
Prop 2    deterministic DDIM == exponential integrator on VPSDE (exact coeff)
Prop 3/5  one score evaluation recovers the score everywhere (Gaussian data)
Thm 1     stochastic gDDIM == DDIM update on VPSDE (mean + variance coeffs)
Prop 7    stochastic gDDIM with lambda=0 == deterministic gDDIM
plus multistep-order convergence and end-to-end exact recovery.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.sde import VPSDE, CLD, BDM, GaussianMixture, ExactScore
from repro.core import (build_sampler_coeffs, time_grid, ddim_closed_form_check,
                        sample_gddim, sample_gddim_stochastic, sample_em,
                        sample_heun, sample_ancestral_bdm)


@pytest.fixture(scope="module")
def vp():
    return VPSDE()


@pytest.fixture(scope="module")
def cld():
    return CLD()


# ---------------------------------------------------------------------------
# Prop 2 / DDIM equivalence on VPSDE
# ---------------------------------------------------------------------------
class TestProp2DDIM:
    def test_q1_coeff_matches_ddim_closed_form(self, vp):
        ts = time_grid(vp, 20)
        co = build_sampler_coeffs(vp, ts, q=1)
        ddim = ddim_closed_form_check(vp, ts)
        assert np.abs(np.asarray(co.pC[:, 0]) - ddim).max() < 1e-5

    def test_psi_matches_alpha_ratio(self, vp):
        ts = time_grid(vp, 10)
        co = build_sampler_coeffs(vp, ts, q=1)
        N = len(ts) - 1
        for k in range(N):
            i = N - k
            assert float(co.psi[k]) == pytest.approx(
                np.sqrt(vp.alpha(ts[i - 1]) / vp.alpha(ts[i])), rel=1e-5)

    def test_sampler_step_equals_ddim_reference(self, vp):
        """One full grid of gDDIM(q=1) steps == iterated closed-form DDIM."""
        ts = time_grid(vp, 8)
        co = build_sampler_coeffs(vp, ts, q=1)
        mix = GaussianMixture(np.array([[0.7, -0.3]]), np.array([1e-6]), np.array([1.0]))
        oracle = ExactScore(vp, mix)
        eps_fn, _ = oracle.eps_fn_for_grid(ts)
        uT = vp.prior_sample(jax.random.PRNGKey(0), 8, (2,))
        out = sample_gddim(vp, co, eps_fn, uT, q=1)
        # manual DDIM iteration with the same eps oracle
        u = uT
        N = len(ts) - 1
        for k in range(N):
            i = N - k
            eps = eps_fn(u, i)
            u = vp.ddim_step_reference(u, eps, float(ts[i]), float(ts[i - 1]))
        assert float(jnp.abs(out - u).max()) < 1e-4


# ---------------------------------------------------------------------------
# Prop 1 / Prop 4: eps-constancy along exact solutions
# ---------------------------------------------------------------------------
class TestEpsConstancy:
    def _trajectory_eps_std(self, sde, K_fn, n_steps=300):
        mix = GaussianMixture(np.array([[0.8, -1.2, 0.3]]), np.array([1e-9]),
                              np.array([1.0]))
        oracle = ExactScore(sde, mix)
        uT = np.asarray(sde.prior_sample(jax.random.PRNGKey(1), 4, (3,)), np.float64)
        ts = np.linspace(sde.T, 0.01, n_steps)
        u = uT.copy()

        def rhs(t, u):
            sc = oracle.score_np(u, t)
            F, G2 = sde.F_np(t), sde.G2_np(t)
            if sde.ops.family == "block":
                return (np.einsum("ij,bj...->bi...", F, u)
                        - 0.5 * np.einsum("ij,bj...->bi...", G2, sc))
            return F * u - 0.5 * G2 * sc

        eps = []
        for k in range(len(ts) - 1):
            t, tn = ts[k], ts[k + 1]
            h = tn - t
            k1 = rhs(t, u); k2 = rhs(t + h / 2, u + h / 2 * k1)
            k3 = rhs(t + h / 2, u + h / 2 * k2); k4 = rhs(tn, u + h * k3)
            u = u + h / 6 * (k1 + 2 * k2 + 2 * k3 + k4)
            sc = oracle.score_np(u, tn)
            K = K_fn(tn)
            if sde.ops.family == "block":
                eps.append(-np.einsum("ij,bj...->bi...", np.asarray(K).T, sc))
            else:
                eps.append(-K * sc)
        return np.stack(eps).std(axis=0).max()

    def test_prop1_vpsde_constant(self, vp):
        assert self._trajectory_eps_std(vp, vp.R_np) < 5e-3

    def test_prop4_cld_R_constant_L_oscillates(self, cld):
        std_R = self._trajectory_eps_std(cld, cld.R_np)
        std_L = self._trajectory_eps_std(cld, cld.L_np)
        assert std_R < 5e-3
        assert std_L > 0.5
        assert std_L / std_R > 100.0  # Fig. 1's contrast, quantified


# ---------------------------------------------------------------------------
# Prop 3 / Prop 5: score recovery from a single evaluation
# ---------------------------------------------------------------------------
class TestScoreRecovery:
    def _check(self, sde):
        mix = GaussianMixture(np.array([[0.5, -0.9]]), np.array([1e-9]), np.array([1.0]))
        oracle = ExactScore(sde, mix)
        rng = np.random.default_rng(0)
        s_t, t = sde.T, 0.3
        shape = (1,) + sde.state_shape((2,))
        u_s = rng.normal(size=shape)
        u = rng.normal(size=shape)
        score_s = oracle.score_np(u_s, s_t)
        # Eq. 20: score_t(u) = Sigma_t^{-1} Psi(t,s) Sigma_s score_s - Sigma_t^{-1}(u - Psi u_s)
        ops = sde.ops
        Sig_t, Sig_s = sde.Sigma_np(t), sde.Sigma_np(s_t)
        Psi_ts = sde.Psi_np(t, s_t)
        Sit = ops.inv(Sig_t)
        A = ops.mul(Sit, ops.mul(Psi_ts, Sig_s))

        def ap(M, x):
            if ops.family == "block":
                return np.einsum("ij,bj...->bi...", M, x)
            return M * x

        rec = ap(A, score_s) - ap(Sit, u - ap(Psi_ts, u_s))
        truth = oracle.score_np(u, t)
        assert np.abs(rec - truth).max() < 1e-4 * max(1.0, np.abs(truth).max())

    def test_prop3_vpsde(self, vp):
        self._check(vp)

    def test_prop5_cld(self, cld):
        self._check(cld)


# ---------------------------------------------------------------------------
# Thm 1: stochastic gDDIM == stochastic DDIM on VPSDE
# ---------------------------------------------------------------------------
class TestThm1:
    @pytest.mark.parametrize("lam", [0.3, 1.0])
    def test_psi_hat_closed_form(self, vp, lam):
        ts = time_grid(vp, 10)
        co = build_sampler_coeffs(vp, ts, q=1, lam=lam)
        N = len(ts) - 1
        for k in [0, 3, N - 1]:
            i = N - k
            ph = vp.Psi_hat_np(float(ts[i - 1]), float(ts[i]), lam)
            assert float(co.psi_hat[k]) == pytest.approx(ph, rel=1e-3)

    @pytest.mark.parametrize("lam", [0.3, 1.0])
    def test_variance_closed_form(self, vp, lam):
        ts = time_grid(vp, 10)
        co = build_sampler_coeffs(vp, ts, q=1, lam=lam)
        N = len(ts) - 1
        for k in [0, 3, N - 1]:
            i = N - k
            P = vp.P_np(float(ts[i]), float(ts[i - 1]), lam)
            assert float(co.P_chol[k]) ** 2 == pytest.approx(P, rel=2e-3, abs=1e-8)

    @pytest.mark.parametrize("lam", [0.5])
    def test_mean_coeff_is_ddim_eq9(self, vp, lam):
        """B = (Psi_hat - Psi) R_s must equal the DDIM eps coefficient
        sqrt(1 - a_{t-1} - sigma^2) - sqrt(a_{t-1}/a_t) sqrt(1 - a_t)."""
        ts = time_grid(vp, 10)
        co = build_sampler_coeffs(vp, ts, q=1, lam=lam)
        N = len(ts) - 1
        for k in [0, 4, N - 1]:
            i = N - k
            t, s = float(ts[i]), float(ts[i - 1])
            a_t, a_s = vp.alpha(t), vp.alpha(s)
            sig2 = vp.P_np(t, s, lam)
            expect = np.sqrt(1 - a_s - sig2) - np.sqrt(a_s / a_t) * np.sqrt(1 - a_t)
            assert float(co.B[k]) == pytest.approx(expect, rel=2e-3, abs=1e-6)


# ---------------------------------------------------------------------------
# Prop 7: lambda=0 stochastic == deterministic
# ---------------------------------------------------------------------------
class TestProp7:
    def test_lambda0_reduces_to_deterministic(self, cld):
        ts = time_grid(cld, 12)
        co = build_sampler_coeffs(cld, ts, q=1, lam=0.0)
        # Lemma 2: int 1/2 Psi G2 R^{-T} == (Psi_hat - Psi) R_s  elementwise
        assert np.abs(np.asarray(co.pC[:, 0]) - np.asarray(co.B)).max() < 2e-3
        # and P == 0
        assert np.abs(np.asarray(co.P_chol)).max() < 1e-6

    def test_stochastic_sampler_matches_deterministic(self, cld):
        ts = time_grid(cld, 8)
        co = build_sampler_coeffs(cld, ts, q=1, lam=0.0)
        mix = GaussianMixture(np.array([[0.4, -0.6]]), np.array([1e-6]), np.array([1.0]))
        oracle = ExactScore(cld, mix)
        eps_fn, _ = oracle.eps_fn_for_grid(ts)
        uT = cld.prior_sample(jax.random.PRNGKey(2), 8, (2,))
        det = sample_gddim(cld, co, eps_fn, uT, q=1)
        sto = sample_gddim_stochastic(cld, co, eps_fn, uT, jax.random.PRNGKey(3))
        assert float(jnp.abs(det - sto).max()) < 5e-3


# ---------------------------------------------------------------------------
# Exact recovery & multistep order
# ---------------------------------------------------------------------------
class TestExactRecovery:
    def test_one_step_dirac_recovery_vpsde(self, vp):
        """Prop 2: with the exact eps, ONE gDDIM step solves the ODE exactly
        (up to the stop-time contraction)."""
        x0 = np.array([[1.5, -0.7]])
        mix = GaussianMixture(x0, np.array([1e-9]), np.array([1.0]))
        oracle = ExactScore(vp, mix)
        ts = time_grid(vp, 1, kind="uniform")
        co = build_sampler_coeffs(vp, ts, q=1)
        eps_fn, _ = oracle.eps_fn_for_grid(ts)
        uT = vp.prior_sample(jax.random.PRNGKey(0), 32, (2,))
        out = sample_gddim(vp, co, eps_fn, uT, q=1)
        # invert the t_min contraction: x0_hat = (u - sqrt(1-a) eps)/sqrt(a)
        t0 = float(ts[0])
        eps0 = eps_fn(out, 0)
        x0_hat = (out - np.sqrt(1 - vp.alpha(t0)) * eps0) / np.sqrt(vp.alpha(t0))
        assert float(jnp.abs(x0_hat - jnp.asarray(x0)).max()) < 5e-3

    def test_few_step_gaussian_recovery_cld(self, cld):
        """Prop 4: for Gaussian data the CLD prob-flow is solved exactly by
        gDDIM steps of any size when K_t = R_t."""
        mix = GaussianMixture(np.array([[0.9, -0.4]]), np.array([1e-9]), np.array([1.0]))
        oracle = ExactScore(cld, mix)
        ts = time_grid(cld, 3, kind="uniform")
        co = build_sampler_coeffs(cld, ts, q=1)
        eps_fn, _ = oracle.eps_fn_for_grid(ts)
        uT = cld.prior_sample(jax.random.PRNGKey(5), 16, (2,))
        out = sample_gddim(cld, co, eps_fn, uT, q=1)
        # reference: dense-grid host RK4 of the prob-flow ODE from same uT
        ref = np.asarray(uT, np.float64)

        def rhs(t, u):
            sc = oracle.score_np(u, t)
            return (np.einsum("ij,bj...->bi...", cld.F_np(t), u)
                    - 0.5 * np.einsum("ij,bj...->bi...", cld.G2_np(t), sc))

        tgrid = np.linspace(cld.T, float(ts[0]), 600)
        for k in range(len(tgrid) - 1):
            t, tn = tgrid[k], tgrid[k + 1]
            h = tn - t
            k1 = rhs(t, ref); k2 = rhs(t + h / 2, ref + h / 2 * k1)
            k3 = rhs(t + h / 2, ref + h / 2 * k2); k4 = rhs(tn, ref + h * k3)
            ref = ref + h / 6 * (k1 + 2 * k2 + 2 * k3 + k4)
        assert np.abs(np.asarray(out, np.float64) - ref).max() < 5e-3

    def test_multistep_order_improves_accuracy(self, vp):
        """On mixture data (eps NOT constant) higher q should track the exact
        ODE better at fixed NFE — Tab. 5's trend."""
        mix = GaussianMixture(np.array([[2.0, 0.0], [-2.0, 0.5]]),
                              np.array([0.15, 0.1]), np.array([0.5, 0.5]))
        oracle = ExactScore(vp, mix)
        uT = vp.prior_sample(jax.random.PRNGKey(7), 64, (2,))
        # reference: fine-grid host RK4
        ref = np.asarray(uT, np.float64)

        def rhs(t, u):
            return vp.F_np(t) * u - 0.5 * vp.G2_np(t) * oracle.score_np(u, t)

        tgrid = np.linspace(vp.T, vp.t_min, 1200)
        for k in range(len(tgrid) - 1):
            t, tn = tgrid[k], tgrid[k + 1]
            h = tn - t
            k1 = rhs(t, ref); k2 = rhs(t + h / 2, ref + h / 2 * k1)
            k3 = rhs(t + h / 2, ref + h / 2 * k2); k4 = rhs(tn, ref + h * k3)
            ref = ref + h / 6 * (k1 + 2 * k2 + 2 * k3 + k4)
        errs = {}
        ts = time_grid(vp, 12)
        eps_fn, _ = oracle.eps_fn_for_grid(ts)
        for q in (1, 2, 3):
            co = build_sampler_coeffs(vp, ts, q=q)
            out = sample_gddim(vp, co, eps_fn, uT, q=q)
            errs[q] = float(np.abs(np.asarray(out, np.float64) - ref).mean())
        assert errs[2] < errs[1]
        assert errs[3] < errs[1]

    def test_corrector_improves_over_predictor(self, vp):
        mix = GaussianMixture(np.array([[2.0, 0.0], [-2.0, 0.5]]),
                              np.array([0.15, 0.1]), np.array([0.5, 0.5]))
        oracle = ExactScore(vp, mix)
        uT = vp.prior_sample(jax.random.PRNGKey(9), 64, (2,))
        ref = np.asarray(uT, np.float64)

        def rhs(t, u):
            return vp.F_np(t) * u - 0.5 * vp.G2_np(t) * oracle.score_np(u, t)

        tgrid = np.linspace(vp.T, vp.t_min, 1200)
        for k in range(len(tgrid) - 1):
            t, tn = tgrid[k], tgrid[k + 1]
            h = tn - t
            k1 = rhs(t, ref); k2 = rhs(t + h / 2, ref + h / 2 * k1)
            k3 = rhs(t + h / 2, ref + h / 2 * k2); k4 = rhs(tn, ref + h * k3)
            ref = ref + h / 6 * (k1 + 2 * k2 + 2 * k3 + k4)
        ts = time_grid(vp, 8)
        eps_fn, _ = oracle.eps_fn_for_grid(ts)
        co = build_sampler_coeffs(vp, ts, q=2)
        out_p = sample_gddim(vp, co, eps_fn, uT, q=2, corrector=False)
        out_pc = sample_gddim(vp, co, eps_fn, uT, q=2, corrector=True)
        err_p = float(np.abs(np.asarray(out_p, np.float64) - ref).mean())
        err_pc = float(np.abs(np.asarray(out_pc, np.float64) - ref).mean())
        assert err_pc < err_p  # Tab. 8's trend


# ---------------------------------------------------------------------------
# Golden values: closed forms / slow quadrature, independent of any bank
# ---------------------------------------------------------------------------
class TestGoldenValues:
    """The sampler-coefficient layer's anchor tests: Stage-I output pinned
    directly against the analytic DDIM update of Song et al. (2010.02502)
    and against an independent slow float64 quadrature — no CoeffCache, no
    bank (dense or factored) in the loop, so a defect in either bank
    implementation cannot mask a defect in the coefficients themselves."""

    def test_vpsde_lambda0_step_coefficients_match_song_ddim(self, vp):
        """Per step t_i -> t_{i-1}, the gDDIM (lam=0, q=1) update on VPSDE
        must be exactly Song et al.'s Eq. 12 deterministic DDIM update
          u <- sqrt(a_{i-1}/a_i) u + (sqrt(1-a_{i-1})
                                      - sqrt(1-a_i) sqrt(a_{i-1}/a_i)) eps
        (paper Prop 2): psi is the closed-form signal ratio and the
        quadrature eps coefficient reproduces the closed form."""
        ts = time_grid(vp, 12)
        co = build_sampler_coeffs(vp, ts, q=1)
        N = len(ts) - 1
        i = N - np.arange(N)
        a_t, a_s = vp.alpha(ts[i]), vp.alpha(ts[i - 1])
        psi_gold = np.sqrt(a_s / a_t)
        eps_gold = np.sqrt(1 - a_s) - np.sqrt(1 - a_t) * np.sqrt(a_s / a_t)
        np.testing.assert_allclose(np.asarray(co.psi), psi_gold, rtol=1e-6)
        np.testing.assert_allclose(np.asarray(co.pC[:, 0]), eps_gold,
                                   rtol=2e-5, atol=1e-7)

    def test_eq45_corrector_rows_match_slow_float64_quadrature(self, vp):
        """Eq. 46's corrector constants (the Eq. 45 update's weights) must
        match an independent slow reference: dense-trapezoid float64
        quadrature of 1/2 Psi(t_{i-1}, tau) G2(tau) R(tau)^{-T} ell_j(tau)
        with an inline Lagrange basis — nothing shared with the production
        quadrature (composite Simpson + solve.lagrange_basis)."""
        nfe, q = 6, 2
        ts = time_grid(vp, nfe)
        co = build_sampler_coeffs(vp, ts, q=q)

        def core(t_end, tau):                      # the Eq. 41/46 integrand
            return (0.5 * vp.Psi_np(t_end, tau) * vp.G2_np(tau)
                    * vp.R_np(tau) / vp.Sigma_np(tau))

        for k in (0, 2, nfe - 1):
            i = nfe - k
            t_i, t_im1 = float(ts[i]), float(ts[i - 1])
            q_corr = min(q, nfe - i + 2)
            nodes = [t_im1] + [float(ts[min(i + j, nfe)])
                               for j in range(q_corr - 1)]
            tau = np.linspace(t_i, t_im1, 20001)
            for j in range(q_corr):
                ell = np.ones_like(tau)
                for m, tm in enumerate(nodes):
                    if m != j:
                        ell *= (tau - tm) / (nodes[j] - tm)
                vals = core(t_im1, tau) * ell
                ref = 0.5 * float(np.sum((vals[1:] + vals[:-1])
                                         * np.diff(tau)))
                assert float(co.cC[k, j]) == pytest.approx(
                    ref, rel=5e-5, abs=1e-7), (k, j)
            # beyond the warm-start order the rows are zero-padded
            assert not np.asarray(co.cC[k, q_corr:]).any()


# ---------------------------------------------------------------------------
# Baselines behave
# ---------------------------------------------------------------------------
class TestBaselines:
    def test_em_converges_with_many_steps(self, vp):
        mix = GaussianMixture(np.array([[1.0, -1.0]]), np.array([0.05]), np.array([1.0]))
        oracle = ExactScore(vp, mix)
        ts = time_grid(vp, 200)
        co = build_sampler_coeffs(vp, ts, q=1)
        eps_fn, _ = oracle.eps_fn_for_grid(ts)
        uT = vp.prior_sample(jax.random.PRNGKey(11), 256, (2,))
        out = sample_em(vp, co, eps_fn, uT, jax.random.PRNGKey(12), lam=0.0)
        mean = np.asarray(out).mean(0)
        assert np.abs(mean - np.array([1.0, -1.0])).max() < 0.1

    def test_heun_beats_euler_at_fixed_grid(self, vp):
        mix = GaussianMixture(np.array([[2.0, 0.0], [-2.0, 0.5]]),
                              np.array([0.15, 0.1]), np.array([0.5, 0.5]))
        oracle = ExactScore(vp, mix)
        uT = vp.prior_sample(jax.random.PRNGKey(13), 64, (2,))
        ref = np.asarray(uT, np.float64)

        def rhs(t, u):
            return vp.F_np(t) * u - 0.5 * vp.G2_np(t) * oracle.score_np(u, t)

        tg = np.linspace(vp.T, vp.t_min, 1200)
        for k in range(len(tg) - 1):
            t, tn = tg[k], tg[k + 1]
            h = tn - t
            k1 = rhs(t, ref); k2 = rhs(t + h / 2, ref + h / 2 * k1)
            k3 = rhs(t + h / 2, ref + h / 2 * k2); k4 = rhs(tn, ref + h * k3)
            ref = ref + h / 6 * (k1 + 2 * k2 + 2 * k3 + k4)
        ts = time_grid(vp, 16)
        eps_fn, _ = oracle.eps_fn_for_grid(ts)
        co = build_sampler_coeffs(vp, ts, q=1)
        out_e = sample_heun(vp, co, eps_fn, uT, second_order=False)
        out_h = sample_heun(vp, co, eps_fn, uT, second_order=True)
        err_e = np.abs(np.asarray(out_e, np.float64) - ref).mean()
        err_h = np.abs(np.asarray(out_h, np.float64) - ref).mean()
        assert err_h < err_e

    def test_bdm_ancestral_runs_and_gddim_beats_it(self):
        bdm = BDM(data_shape=(4, 1))
        x0 = np.array([[[1.0], [-0.5], [0.2], [0.8]]])
        mix = GaussianMixture(x0, np.array([1e-6]), np.array([1.0]))
        oracle = ExactScore(bdm, mix)
        ts = time_grid(bdm, 10)
        co = build_sampler_coeffs(bdm, ts, q=1)
        eps_fn, _ = oracle.eps_fn_for_grid(ts)
        uT = bdm.prior_sample(jax.random.PRNGKey(15), 128, (4, 1))
        out_g = sample_gddim(bdm, co, eps_fn, uT, q=1)
        out_a = sample_ancestral_bdm(bdm, eps_fn, uT, np.asarray(ts), jax.random.PRNGKey(16))
        err_g = np.abs(np.asarray(out_g).mean(0) - x0[0]).max()
        err_a = np.abs(np.asarray(out_a).mean(0) - x0[0]).max()
        assert err_g < 0.05
        assert err_g <= err_a + 0.05  # gDDIM at least as good at 10 NFE

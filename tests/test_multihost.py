"""Tier: multihost — the fleet bring-up (distributed/multihost.py).

Unit layer: context validation, mode selection (CPU backends cannot run
cross-process XLA computations, so the fleet falls back to process-
sharded SPMD), the global serve mesh's divisibility checks, and the
round-robin request sharding.

Integration layer (the real thing, in the style of test_serve_mesh.py's
spawned subprocesses): TWO processes joined through an actual
`jax.distributed.initialize` coordination service — barrier fan-in, KV
round-trip, and each process serving its request shard on a local
engine with the union of per-process results **bitwise identical** to
one engine serving the whole list.  That equality is the invariant the
router tier and the launchgate harness stand on.
"""
import os
import socket
import subprocess
import sys
import textwrap

import jax
import pytest

from repro.distributed import multihost


# ---------------------------------------------------------------- unit

class TestContext:
    def test_single_process_is_noop(self):
        ctx = multihost.initialize()
        assert (ctx.process_id, ctx.num_processes) == (0, 1)
        assert ctx.is_coordinator

    def test_validation(self):
        with pytest.raises(ValueError, match="num_processes"):
            multihost.initialize(num_processes=0)
        with pytest.raises(ValueError, match="process_id"):
            multihost.initialize(coordinator_address="h:1", num_processes=2,
                                 process_id=2)
        with pytest.raises(ValueError, match="coordinator_address"):
            multihost.initialize(num_processes=2, process_id=0)

    def test_mode_on_this_backend(self):
        one = multihost.MultihostContext(0, 1)
        two = multihost.MultihostContext(0, 2, "h:1")
        assert multihost.mode_of(one) == "global"
        if jax.default_backend() == "cpu":
            assert not multihost.multiprocess_jit_supported()
            assert multihost.mode_of(two) == "spmd"
        else:
            assert multihost.mode_of(two) == "global"

    def test_coordination_requires_initialize(self):
        with pytest.raises(RuntimeError, match="initialize"):
            multihost.barrier("nope")


class TestGlobalServeMesh:
    def test_defaults_to_all_devices_on_data(self):
        mesh = multihost.global_serve_mesh()
        assert dict(mesh.shape) == {"data": jax.device_count(), "model": 1}

    def test_divisibility_checked(self):
        n = jax.device_count()
        with pytest.raises(ValueError):
            multihost.global_serve_mesh(model=n + 1)
        with pytest.raises(ValueError):
            multihost.global_serve_mesh(data=n + 1, model=1)


class TestShardRequests:
    def test_round_robin_partition(self):
        reqs = list(range(10))
        shards = [multihost.shard_requests(reqs, 3, p) for p in range(3)]
        assert shards[0] == [0, 3, 6, 9]
        assert shards[1] == [1, 4, 7]
        assert shards[2] == [2, 5, 8]
        assert sorted(sum(shards, [])) == reqs

    def test_validation(self):
        with pytest.raises(ValueError, match="process_id"):
            multihost.shard_requests([1], 2, 2)


# ---------------------------------------------------------- integration

_WORKER = """
    import hashlib, json, os, sys
    sys.path.insert(0, "src")
    import jax
    from repro.configs import get_diffusion
    from repro.distributed import multihost
    from repro.serve import DiffusionEngine, SampleRequest

    pid = int(sys.argv[1]); nproc = int(sys.argv[2]); coord = sys.argv[3]
    ctx = multihost.initialize(coordinator_address=coord,
                               num_processes=nproc, process_id=pid)
    assert multihost.mode_of(ctx) in ("global", "spmd")

    # KV round-trip: every process publishes, process 0 reads all back
    multihost.kv_set(f"mh-test/hello/{pid}", f"from-{pid}")
    multihost.barrier("mh-test-kv")
    if ctx.is_coordinator:
        got = [multihost.kv_get(f"mh-test/hello/{p}") for p in range(nproc)]
        assert got == [f"from-{p}" for p in range(nproc)], got
        print("KV-OK", flush=True)

    # SPMD serve: this process's request shard on a local engine
    requests = [SampleRequest(rid=i, seed=i, nfe=5) for i in range(6)]
    mine = multihost.shard_requests(requests, nproc, pid)
    spec = get_diffusion("cifar10-ddpm", reduced=True)
    params = spec.init(jax.random.PRNGKey(0))
    engine = DiffusionEngine(spec, params, batch_size=2, nfe=5)
    results = engine.serve(mine)
    digests = {r.rid: hashlib.sha256(results[r.rid].tobytes()).hexdigest()
               for r in mine}
    multihost.kv_set(f"mh-test/digests/{pid}", json.dumps(digests))
    multihost.barrier("mh-test-served")
    if ctx.is_coordinator:
        union = {}
        for p in range(nproc):
            union.update(json.loads(
                multihost.kv_get(f"mh-test/digests/{p}")))
        assert sorted(union) == [str(i) for i in range(6)], sorted(union)
        solo = DiffusionEngine(spec, spec.init(jax.random.PRNGKey(0)),
                               batch_size=2, nfe=5)
        want = solo.serve(requests)
        for i in range(6):
            w = hashlib.sha256(want[i].tobytes()).hexdigest()
            assert union[str(i)] == w, f"rid {i} diverged across the fleet"
        print("UNION-BITWISE-OK", flush=True)
    multihost.barrier("mh-test-done")
    print(f"DONE-{pid}", flush=True)
"""


@pytest.mark.slow
def test_two_process_fleet_kv_barrier_and_bitwise_union(tmp_path):
    """2 real processes through jax.distributed: coordination-service KV
    and barriers work, and the union of the per-process SPMD serves is
    bitwise equal to the single-host serve."""
    with socket.socket() as s:
        s.bind(("127.0.0.1", 0))
        port = s.getsockname()[1]
    coord = f"127.0.0.1:{port}"
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ, PYTHONPATH="src")

    procs = [subprocess.Popen(
        [sys.executable, "-c", textwrap.dedent(_WORKER),
         str(p), "2", coord],
        cwd=repo, env=env, stdout=subprocess.PIPE,
        stderr=subprocess.STDOUT, text=True) for p in range(2)]
    outs = []
    for p, proc in enumerate(procs):
        out, _ = proc.communicate(timeout=600)
        outs.append(out)
        assert proc.returncode == 0, f"process {p}:\n{out}"
    assert "KV-OK" in outs[0]
    assert "UNION-BITWISE-OK" in outs[0]
    for p in range(2):
        assert f"DONE-{p}" in outs[p]

"""Dry-run harness smoke: one real cell through the full path (512 forced
host devices, production mesh, lower+compile+analyze) in a subprocess so
the main test process keeps its 1-device view."""
import json
import os
import subprocess
import sys

import pytest


def _run(args):
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)           # dryrun sets its own, first thing
    env["PYTHONPATH"] = "src"
    r = subprocess.run([sys.executable, "-m", "repro.launch.dryrun"] + args,
                       capture_output=True, text=True, env=env,
                       cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                       timeout=900)
    assert r.returncode == 0, r.stdout[-2000:] + r.stderr[-2000:]
    return r.stdout


@pytest.mark.slow
def test_dryrun_decode_cell_single_pod():
    out = _run(["--arch", "whisper-base", "--shape", "decode_32k",
                "--mesh", "single"])
    rec = json.loads([l for l in out.splitlines() if l.startswith("{")][0])
    assert rec["status"] == "ok"
    assert rec["devices"] == 256
    ro = rec["roofline"]
    assert ro["t_memory_s"] > 0 and ro["bottleneck"] in (
        "compute", "memory", "collective")
    assert rec["cost"]["flops_per_dev"] > 0
    assert rec["memory"]["argument_bytes"] > 0


@pytest.mark.slow
def test_dryrun_skip_policy():
    out = _run(["--arch", "deepseek-coder-33b", "--shape", "long_500k",
                "--mesh", "single"])
    rec = json.loads([l for l in out.splitlines() if l.startswith("{")][0])
    assert rec["status"] == "skipped"
    assert "sub-quadratic" in rec["reason"]

"""Optimizer, checkpoint store, and data pipeline substrate tests."""
import os
import tempfile

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.optim.adamw import (AdamWCfg, adamw_init, adamw_update, lr_at,
                               clip_by_global_norm, global_norm,
                               ema_init, ema_update)
from repro.ckpt.store import CheckpointStore, SENTINEL
from repro.data.pipeline import TokenPipeline, MixturePipeline


class TestAdamW:
    def test_converges_on_quadratic(self):
        cfg = AdamWCfg(lr=0.1, weight_decay=0.0, warmup_steps=1,
                       total_steps=200, clip_norm=0.0)
        params = {"w": jnp.array([3.0, -2.0])}
        opt = adamw_init(params, cfg)
        target = jnp.array([1.0, 1.0])
        for _ in range(200):
            g = {"w": 2 * (params["w"] - target)}
            params, opt, _ = adamw_update(g, opt, params, cfg)
        np.testing.assert_allclose(np.asarray(params["w"]), [1.0, 1.0], atol=1e-2)

    def test_bf16_params_f32_master(self):
        """bf16 compute params + f32 masters: updates accumulate precisely
        even when each delta underflows bf16 (the mixed-precision contract)."""
        cfg = AdamWCfg(lr=1e-4, weight_decay=0.0, warmup_steps=0,
                       total_steps=1000, clip_norm=0.0, schedule="constant")
        params = {"w": jnp.ones((4,), jnp.bfloat16) * 100.0}
        opt = adamw_init(params, cfg)
        for _ in range(50):
            g = {"w": jnp.ones((4,), jnp.bfloat16)}
            params, opt, _ = adamw_update(g, opt, params, cfg)
        master = np.asarray(opt.master["w"])
        assert params["w"].dtype == jnp.bfloat16
        assert (master < 100.0).all()          # masters moved
        assert np.unique(master).size == 1

    def test_clip_by_global_norm(self):
        g = {"a": jnp.ones((10,)) * 10.0}
        clipped, norm = clip_by_global_norm(g, 1.0)
        assert float(norm) == pytest.approx(np.sqrt(1000.0), rel=1e-5)
        assert float(global_norm(clipped)) == pytest.approx(1.0, rel=1e-5)

    def test_lr_schedule_shapes(self):
        cfg = AdamWCfg(lr=1.0, warmup_steps=10, total_steps=100,
                       schedule="cosine", min_lr_frac=0.1)
        assert float(lr_at(cfg, jnp.int32(0))) == 0.0
        assert float(lr_at(cfg, jnp.int32(10))) == pytest.approx(1.0)
        assert float(lr_at(cfg, jnp.int32(100))) == pytest.approx(0.1, rel=1e-5)

    def test_ema(self):
        p = {"w": jnp.ones((2,))}
        e = ema_init(p)
        e = ema_update(e, {"w": jnp.zeros((2,))}, 0.9)
        np.testing.assert_allclose(np.asarray(e["w"]), 0.9)


class TestCheckpointStore:
    def _tree(self, seed=0):
        k = jax.random.PRNGKey(seed)
        return {"a": jax.random.normal(k, (4, 4)),
                "b": {"c": jnp.arange(10, dtype=jnp.int32)}}

    def test_roundtrip(self):
        with tempfile.TemporaryDirectory() as d:
            s = CheckpointStore(d)
            t = self._tree()
            s.save(5, t, blocking=True)
            step, r = s.restore_latest(jax.tree.map(
                lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), t))
            assert step == 5
            for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(r)):
                np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_async_then_wait(self):
        with tempfile.TemporaryDirectory() as d:
            s = CheckpointStore(d)
            s.save(1, self._tree())
            s.wait()
            assert s.latest_step() == 1

    def test_uncommitted_ignored(self):
        """A crash mid-write (no COMMITTED sentinel) must be invisible."""
        with tempfile.TemporaryDirectory() as d:
            s = CheckpointStore(d)
            s.save(1, self._tree(), blocking=True)
            # simulate a crashed later write: dir without sentinel + stale latest
            os.makedirs(os.path.join(d, "step_2"))
            with open(os.path.join(d, "latest"), "w") as f:
                f.write("step_2")
            assert s.latest_step() == 1

    def test_keep_gc(self):
        with tempfile.TemporaryDirectory() as d:
            s = CheckpointStore(d, keep=2)
            for i in range(1, 5):
                s.save(i, self._tree(), blocking=True)
            kept = sorted(n for n in os.listdir(d) if n.startswith("step_"))
            assert kept == ["step_3", "step_4"]


class TestDataPipelines:
    def test_deterministic_re_entry(self):
        p = TokenPipeline(vocab=100, seq_len=16, global_batch=4, seed=7)
        a1, b1 = p.batch_at(12)
        a2, b2 = p.batch_at(12)
        np.testing.assert_array_equal(a1, a2)
        it = p.iterator(start_step=12)
        batch = next(it)
        np.testing.assert_array_equal(np.asarray(batch["tokens"]), a1)

    def test_labels_are_next_tokens(self):
        p = TokenPipeline(vocab=100, seq_len=16, global_batch=2, seed=0)
        toks, labels = p.batch_at(0)
        np.testing.assert_array_equal(toks[:, 1:], labels[:, :-1])

    def test_host_sharding_disjoint(self):
        full = TokenPipeline(vocab=50, seq_len=8, global_batch=8, seed=3)
        s0 = TokenPipeline(vocab=50, seq_len=8, global_batch=8, seed=3,
                           n_process=2, process_index=0)
        s1 = TokenPipeline(vocab=50, seq_len=8, global_batch=8, seed=3,
                           n_process=2, process_index=1)
        a0, _ = s0.batch_at(0)
        a1, _ = s1.batch_at(0)
        assert a0.shape == (4, 8) and a1.shape == (4, 8)
        assert not np.array_equal(a0, a1)

    def test_mixture_pipeline_stats(self):
        means = np.array([[0.0, 0.0], [10.0, 10.0]])
        p = MixturePipeline(means=means, stds=np.array([0.1, 0.1]),
                            weights=np.array([0.5, 0.5]), global_batch=512, seed=0)
        x = p.batch_at(0)
        frac_hi = (x[:, 0] > 5).mean()
        assert 0.3 < frac_hi < 0.7

"""Virtual-clock simulation rig for the online serving tests.

The online loop (`ServeLoop.serve_stream`) is already deterministic — its
clock is explicit and advances only with dispatched rounds — so "simulate"
here means running the *same* loop against either

  * `RecordingClock` — a `VirtualClock` that journals every advance, so a
    golden test can assert the exact schedule the loop executed, not just
    its end state; and
  * `HostSimEngine` — a pure-host `ServeLoop` whose "device" is a dict of
    integer progress counters.  One round of work is one unit; a request
    with `work=n` retires after exactly n rounds.  No jax device work at
    all, so the scheduling/preemption/latency properties (golden metrics
    in test_serve_online.py, the hypothesis properties in
    test_properties.py) run in milliseconds while exercising the very
    loop code the real engines inherit — admission, urgency, preemption
    into the real `ParkingTable`, the double-buffered poll skeleton, the
    poll cadence, and the latency accounting.

`trace_of(...)` builds hand-written traces tersely:

    trace_of((0.0, SimRequest(rid=0, work=4)),
             (2.5, SimRequest(rid=1, work=2, priority=1)))
"""
from typing import Optional

import dataclasses

import numpy as np

from repro.serve import Arrival, ServeLoop, Scheduler, TraceTraffic, \
    VirtualClock


class RecordingClock(VirtualClock):
    """VirtualClock that journals its own movement: `events` holds
    ("round", t_after) per `advance` and ("skip", t_after) per effective
    `advance_to`, so tests can assert exactly when the loop worked and
    when it idled."""

    def __init__(self, t0: float = 0.0):
        super().__init__(t0)
        self.events = []

    def advance(self, dt: float) -> None:
        super().advance(dt)
        self.events.append(("round", self.now()))

    def advance_to(self, t: float) -> None:
        moved = t > self.now()
        super().advance_to(t)
        if moved:
            self.events.append(("skip", self.now()))


@dataclasses.dataclass
class SimRequest:
    """One unit-cost-per-round request for the host simulator.  `cls` is
    the admission cost class (the `group_key`), standing in for the real
    engines' prompt-length / (family, corrector) classes."""
    rid: int
    work: int = 4
    cls: str = "a"
    priority: int = 0
    deadline: Optional[float] = None
    seed: int = 0


def trace_of(*pairs) -> TraceTraffic:
    return TraceTraffic([Arrival(t, r) for t, r in pairs])


class HostSimEngine(ServeLoop):
    """Pure-host ServeLoop: slot rows are {"done": int} dicts, a round
    adds 1 to every active row, and a request retires once its row
    reaches `work`.  Suspend/resume move the row dict through the real
    `ParkingTable` (`jax.device_get` on python ints is the identity), so
    a preempted request's progress is preserved exactly — the integer
    analogue of the engines' bitwise row round-trip."""

    def __init__(self, batch_size: int, sync_every: int = 8,
                 greedy: bool = False):
        super().__init__(batch_size,
                         Scheduler(group_key=lambda r: r.cls),
                         sync_every=sync_every)
        self.greedy_admit = greedy
        self.rows = {}                  # slot index -> {"done": int}
        self.n_rounds = 0

    # ---- ServeLoop hooks --------------------------------------------------
    def _validate(self, r: SimRequest) -> None:
        if r.work < 1:
            raise ValueError(f"request {r.rid}: work must be >= 1")

    def _admit_wave(self, group, free) -> None:
        for req in group:
            i = free.pop(0)
            self.rows[i] = {"done": 0}
            self.slots.assign(i, req, k=0, work=req.work, cls=req.cls)

    def _round(self) -> None:
        for s in self.slots.active():
            if s.data["k"] < s.data["work"]:    # frozen once finished,
                self.rows[s.index]["done"] += 1  # like a retired device row
            s.data["k"] += 1  # shadow advances regardless (DiffusionEngine)
        self.n_rounds += 1

    def _poll(self, results, snap=None, lag: int = 0) -> int:
        # `k - lag` reconstructs the pre-look-ahead observation point,
        # exactly like DiffusionEngine._poll
        done = [s for s in self.slots.active()
                if s.data["k"] - lag >= s.data["work"]]
        for s in done:
            results[s.request.rid] = np.int32(self.rows.pop(s.index)["done"])
            self.slots.release(s.index)
        return len(done)

    def _suspend_slot(self, slot):
        return self.rows.pop(slot.index)

    def _resume_slot(self, request, shadow, payload, index: int) -> None:
        self.rows[index] = dict(payload)

    def _remaining_lb(self, slot) -> int:
        return slot.data["work"] - slot.data["k"]

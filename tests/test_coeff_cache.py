"""Stage-I coefficient cache: memoization, bank stacking, and bucketing.

The cache is the host half of heterogeneous-config serving: Stage-I
quadrature runs once per distinct (sde family, grid, NFE, q, corrector,
lambda) key, and the stacked `CoeffBank` pads every entry to shared
bucketed shapes so the device step program is reused across any traffic
mix (see tests/test_serve_engine.py for the engine-level lockdown).
"""
import numpy as np
import pytest

from dense_reference import pack_coeff
from repro.core import (CoeffCache, SamplerConfig, bucket_size,
                        build_sampler_coeffs, time_grid)
from repro.core.coeffs import (C_BUCKET_MIN, DIAG_BUCKET_MIN, N_BUCKET_MIN,
                               Q_BUCKET_MIN)
from repro.sde import VPSDE, CLD, BDM


def test_cache_hit_returns_identical_bank_object():
    cache = CoeffCache(VPSDE())
    cfg = SamplerConfig(nfe=6, q=2)
    co1 = cache.get(cfg)
    co2 = cache.get(SamplerConfig(nfe=6, q=2))    # equal key, fresh object
    assert co1 is co2
    # a different key is a different bank
    assert cache.get(SamplerConfig(nfe=6, q=1)) is not co1
    assert cache.get(SamplerConfig(nfe=6, q=2, grid="uniform")) is not co1


def test_cached_coeffs_match_direct_stage1():
    sde = VPSDE()
    cache = CoeffCache(sde)
    cfg = SamplerConfig(nfe=5, q=2)
    co = cache.get(cfg)
    ref = build_sampler_coeffs(sde, time_grid(sde, 5), q=2)
    for a, b in zip(co[:-1], ref[:-1]):           # skip the lam float
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_index_of_is_stable_and_len_counts_configs():
    cache = CoeffCache(VPSDE())
    a = cache.index_of(SamplerConfig(nfe=4))
    b = cache.index_of(SamplerConfig(nfe=8, q=2))
    assert (a, b) == (0, 1)
    assert cache.index_of(SamplerConfig(nfe=4)) == 0      # hit, no growth
    assert len(cache) == 2


def test_bank_rows_reproduce_unstacked_coeffs():
    """Bank slot c must carry config c's Stage-I arrays verbatim, padded
    with zero coefficients (so out-of-order terms vanish) beyond N_c/q_c."""
    sde = VPSDE()
    cache = CoeffCache(sde)
    cfgs = [SamplerConfig(nfe=4), SamplerConfig(nfe=6, q=2),
            SamplerConfig(nfe=5, lam=0.5)]
    idx = [cache.index_of(c) for c in cfgs]
    bank = cache.bank

    for c, cfg in zip(idx, cfgs):
        co = cache.get(cfg)
        N, q = cfg.nfe, cfg.q
        ts = np.asarray(co.ts)
        np.testing.assert_array_equal(np.asarray(bank.psi[c, :N]),
                                      np.asarray(co.psi))
        np.testing.assert_array_equal(np.asarray(bank.pC[c, :N, :q]),
                                      np.asarray(co.pC))
        np.testing.assert_array_equal(np.asarray(bank.cC[c, :N, :q]),
                                      np.asarray(co.cC))
        np.testing.assert_array_equal(np.asarray(bank.B[c, :N]),
                                      np.asarray(co.B))
        np.testing.assert_array_equal(np.asarray(bank.P_chol[c, :N]),
                                      np.asarray(co.P_chol))
        # time rows follow the step convention k: t_i with i = N - k
        np.testing.assert_array_equal(np.asarray(bank.t_cur[c, :N]),
                                      ts[N - np.arange(N)])
        np.testing.assert_array_equal(np.asarray(bank.t_nxt[c, :N]),
                                      ts[N - 1 - np.arange(N)])
        assert int(bank.n_steps[c]) == N
        assert bool(bank.stochastic[c]) == (cfg.lam > 0)
        # padding beyond N_c is zero coefficients
        assert not np.asarray(bank.pC[c, N:]).any()
        assert not np.asarray(bank.pC[c, :N, q:]).any()


def test_bank_bucket_shapes_and_stability():
    cache = CoeffCache(VPSDE())
    cache.index_of(SamplerConfig(nfe=5, q=2))
    bank = cache.bank
    Cb, Nb, Qb = bank.shape_key
    assert Cb == C_BUCKET_MIN and Nb == N_BUCKET_MIN and Qb == Q_BUCKET_MIN

    # anything inside the buckets reuses the shape (same compiled step)
    cache.index_of(SamplerConfig(nfe=8))
    cache.index_of(SamplerConfig(nfe=3, corrector=True))
    assert cache.bank.shape_key == (Cb, Nb, Qb)

    # overflow doubles only the overflowing axis
    cache.index_of(SamplerConfig(nfe=2 * N_BUCKET_MIN - 1))
    assert cache.bank.shape_key == (Cb, 2 * N_BUCKET_MIN, Qb)


def test_bucket_size():
    assert bucket_size(1, 8) == 8
    assert bucket_size(8, 8) == 8
    assert bucket_size(9, 8) == 16
    assert bucket_size(33, 8) == 64


def test_bank_works_for_block_family():
    """CLD's (2,2) block coefficients stack with trailing coeff dims."""
    cache = CoeffCache(CLD())
    cache.index_of(SamplerConfig(nfe=4, q=2))
    bank = cache.bank
    assert bank.psi.shape[2:] == (2, 2)
    assert bank.pC.shape[3:] == (2, 2)


@pytest.mark.parametrize("bad", [
    dict(nfe=0),
    dict(nfe=4, q=0),
    dict(nfe=4, lam=-0.1),
    dict(nfe=4, lam=0.5, q=2),             # stochastic is single-step
    dict(nfe=4, lam=0.5, corrector=True),
    dict(nfe=4, grid="geometric"),
])
def test_sampler_config_validation(bad):
    with pytest.raises(ValueError):
        SamplerConfig(**bad)


# ---------------------------------------------------------------------------
# multi-family cache: one FactoredBank stacking VPSDE + CLD + BDM configs
# ---------------------------------------------------------------------------
DATA_SHAPE = (4, 4, 3)


def _multi_cache():
    return CoeffCache({"vpsde": VPSDE(), "cld": CLD(),
                       "bdm": BDM(data_shape=DATA_SHAPE)},
                      data_shape=DATA_SHAPE)


def test_multi_family_keys_and_resolution():
    cache = _multi_cache()
    assert cache.families == ["vpsde", "cld", "bdm"]
    assert cache.default_family == "vpsde"
    assert cache.k_max == 2                        # CLD's (x, v) channels
    # an unset family resolves to the default and shares its slot with the
    # explicit spelling
    a = cache.index_of(SamplerConfig(nfe=4))
    b = cache.index_of(SamplerConfig(nfe=4, family="vpsde"))
    c = cache.index_of(SamplerConfig(nfe=4, family="cld"))
    assert a == b and a != c
    with pytest.raises(ValueError, match="family"):
        cache.resolve(SamplerConfig(nfe=4, family="edm"))


def test_multi_family_bank_requires_factored():
    cache = _multi_cache()
    cache.index_of(SamplerConfig(nfe=4))
    with pytest.raises(ValueError, match="factored_bank"):
        cache.bank                                  # family-native shapes
    bank = cache.factored_bank                      # canonical shapes work
    D = int(np.prod(DATA_SHAPE))
    assert bank.psi_blk.shape[2:] == (2, 2)
    assert bank.diag.shape[1] == D
    Cb, Nb = bank.psi_blk.shape[:2]
    Qb, Pb = bank.pC_blk.shape[2], bank.diag.shape[0]
    assert bank.shape_key == (Cb, Nb, Qb, 2, D, Pb)


def test_factored_bank_rows_embed_family_coeffs():
    """Materialized factored rows must be `pack_coeff` embeddings of the
    family-native Stage-I arrays, with `fam` recording each config's
    family index.  (The full bit-exact differential against the dense
    PR-4 bank lives in tests/test_factored_bank.py.)"""
    cache = _multi_cache()
    cfgs = [SamplerConfig(nfe=4),
            SamplerConfig(nfe=5, family="cld", q=2),
            SamplerConfig(nfe=4, family="bdm"),
            SamplerConfig(nfe=4, family="vpsde", lam=0.5)]
    idx = [cache.index_of(c) for c in cfgs]
    bank = cache.factored_bank
    K = cache.k_max
    for c, cfg in zip(idx, cfgs):
        name = cache.resolve(cfg)
        ops = cache.sdes[name].ops
        co = cache.get(cfg)
        assert int(bank.fam[c]) == cache.fam_index(name)
        assert int(bank.n_steps[c]) == cfg.nfe
        for k in range(cfg.nfe):
            np.testing.assert_allclose(
                bank.materialize("psi", c, k),
                pack_coeff(ops, np.asarray(co.psi, np.float64)[k],
                           DATA_SHAPE, K).astype(np.float32))
            for j in range(cfg.q):
                np.testing.assert_allclose(
                    bank.materialize("pC", c, k, j),
                    pack_coeff(ops, np.asarray(co.pC, np.float64)[k, j],
                               DATA_SHAPE, K).astype(np.float32))
            if cfg.lam > 0.0:                 # stochastic rows stay exact
                np.testing.assert_allclose(
                    bank.materialize("B", c, k),
                    pack_coeff(ops, np.asarray(co.B, np.float64)[k],
                               DATA_SHAPE, K).astype(np.float32))
            else:                             # Eq. 22 branch masked off
                assert not bank.materialize("B", c, k).any()
        # padding beyond this config's rows is zero (block factor zero)
        assert not np.asarray(bank.pC_blk[c, cfg.nfe:]).any()
        assert not np.asarray(bank.pC_blk[c, :cfg.nfe, cfg.q:]).any()


def test_single_family_cache_keeps_native_bank():
    """Back-compat: a single-family cache still exposes the family-native
    CoeffBank AND (given data_shape) the factored bank."""
    cache = CoeffCache(CLD(), data_shape=DATA_SHAPE)
    cache.index_of(SamplerConfig(nfe=4))
    assert cache.bank.psi.shape[2:] == (2, 2)
    D = int(np.prod(DATA_SHAPE))
    bank = cache.factored_bank
    assert bank.psi_blk.shape[2:] == (2, 2)
    # a pure scalar/block cache needs only the shared all-ones pool row
    assert bank.diag.shape == (DIAG_BUCKET_MIN, D)
    assert cache.sde is cache.sdes["cld"]


def test_factored_bank_registration_is_incremental():
    """Satellite lockdown: registration appends factored rows (memoized
    per config) instead of restacking the whole bank; only a bucket
    overflow re-pads every row.  `bank_restack_rows` counts the rows
    (re)written — the deterministic counter the perf guard gates."""
    cache = _multi_cache()
    cache.index_of(SamplerConfig(nfe=4))
    b1 = cache.factored_bank
    assert cache.bank_restack_rows == 1
    assert cache.factored_bank is b1          # no growth -> identical obj

    # three more configs inside every bucket: pure appends (3 new rows),
    # and the already-registered config is NOT rewritten
    for cfg in (SamplerConfig(nfe=8), SamplerConfig(nfe=6, q=2),
                SamplerConfig(nfe=5, family="cld")):
        cache.index_of(cfg)
    b2 = cache.factored_bank
    assert b2 is not b1
    assert cache.bank_restack_rows == 4
    assert b2.shape_key == b1.shape_key

    # C-bucket overflow (5th config): every row re-padded once
    cache.index_of(SamplerConfig(nfe=3))
    b3 = cache.factored_bank
    assert cache.bank_restack_rows == 4 + 5
    assert b3.shape_key != b2.shape_key

    # a first-seen BDM config appends rows AND grows the diag pool; the
    # block/index layout is untouched (no re-pad of existing rows)
    cache.index_of(SamplerConfig(nfe=4, family="bdm"))
    b4 = cache.factored_bank
    assert cache.bank_restack_rows == 4 + 5 + 1
    assert b4.diag.shape[0] > b3.diag.shape[0]


def test_kt_mapping_must_cover_families():
    with pytest.raises(ValueError, match="missing"):
        CoeffCache({"vpsde": VPSDE(), "cld": CLD()}, kt={"vpsde": "R"})

"""Distribution layer: sharding rules (divisibility fallback), cache specs,
and multi-device behaviours (pipeline, FSDP) via subprocesses with forced
host device counts — the main test process keeps the real 1-device view."""
import os
import subprocess
import sys
import textwrap

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import AbstractMesh, PartitionSpec as P

from repro.distributed.sharding import (ShardCfg, bank_shardings, param_spec,
                                        batch_spec, kv_cache_spec)

MESH = AbstractMesh((("data", 16), ("model", 16)))
MESH3 = AbstractMesh((("pod", 2), ("data", 16), ("model", 16)))
CFG = ShardCfg()


class TestParamRules:
    def test_column_parallel_qkv(self):
        s = param_spec("layer_stacks/0/attn/wq", (4, 7168, 7168), MESH, CFG)
        assert s[2] == "model"          # TP on output dim
        assert s[0] is None             # stack axis never sharded

    def test_row_parallel_wo(self):
        s = param_spec("layer_stacks/0/attn/wo", (4, 7168, 7168), MESH, CFG)
        assert s[1] == "model"

    def test_divisibility_fallback(self):
        # a TP dim that does not divide the 16-way model axis falls back to
        # replication on that axis (FSDP may still claim another dim)
        s = param_spec("layer_stacks/0/attn/wq", (2, 896, 904), MESH, CFG)
        assert "model" not in [a for a in s if isinstance(a, str)]

    def test_vocab_tp_and_fallback(self):
        ok = param_spec("embed", (32000, 4096), MESH, CFG)
        assert ok[0] == "model"
        bad = param_spec("embed", (51865, 512), MESH, CFG)   # whisper vocab
        assert bad[0] != "model"

    def test_moe_expert_parallel(self):
        s = param_spec("layer_stacks/0/moe/w_gate", (3, 128, 2048, 768), MESH, CFG)
        assert s[1] == "model"          # expert axis

    def test_norms_replicated(self):
        s = param_spec("layer_stacks/0/ln_attn", (4, 4096), MESH, CFG)
        assert all(a is None for a in s)

    def test_fsdp_on_largest_free_dim(self):
        s = param_spec("layer_stacks/0/mlp/w_up", (4, 1024, 4096), MESH, CFG)
        assert s[2] == "model" and s[1] == "data"

    def test_multipod_params_not_sharded_over_pod(self):
        s = param_spec("layer_stacks/0/mlp/w_up", (4, 1024, 4096), MESH3, CFG)
        assert "pod" not in [a for a in s if isinstance(a, str)]


class TestActivationRules:
    def test_batch_spec_single_pod(self):
        s = batch_spec(MESH, CFG, 2, 256)
        assert s[0] == "data"

    def test_batch_spec_multi_pod(self):
        s = batch_spec(MESH3, CFG, 2, 256)
        assert s[0] == ("pod", "data")

    def test_batch_one_unsharded(self):
        s = batch_spec(MESH, CFG, 2, 1)
        assert s[0] is None

    def test_bank_shardings_replicate_with_optional_diag_split(self):
        """FactoredBank placement: every factor/index leaf replicates; the
        (P, D) diag pool — the only D-scaled leaf — replicates by default
        and D-shards over the tp axis only on opt-in when divisible."""
        from repro.core import CoeffCache, SamplerConfig
        from repro.sde import VPSDE
        cache = CoeffCache(VPSDE(), data_shape=(8, 8, 3))   # D=192
        cache.index_of(SamplerConfig(nfe=4))
        bank = cache.factored_bank
        sh = bank_shardings(MESH, CFG, bank)
        assert all(getattr(sh, f).spec == P() for f in bank._fields)
        sh = bank_shardings(MESH, CFG, bank, shard_diag=True)
        assert sh.diag.spec == P(None, "model")             # 192 % 16 == 0
        assert sh.psi_blk.spec == P()
        # indivisible D falls back to replication
        odd = bank._replace(diag=jnp.zeros((1, 7), jnp.float32))
        sh = bank_shardings(MESH, CFG, odd, shard_diag=True)
        assert sh.diag.spec == P()

    def test_kv_cache_heads_or_seq(self):
        # enough heads: shard heads over model
        s = kv_cache_spec(MESH, CFG, (4, 128, 32768, 16, 128), 128, 16)
        assert s[3] == "model"
        # MQA baseline: replicate over model (no seq sharding by default)
        s = kv_cache_spec(MESH, CFG, (4, 1, 524288, 1, 256), 1, 1)
        assert s[2] is None and s[3] is None
        # opt-in SP cache for the shard_map flash-decode path
        s = kv_cache_spec(MESH, CFG, (4, 1, 524288, 1, 256), 1, 1,
                          seq_fallback=True)
        assert s[2] == "model"


# ---------------------------------------------------------------------------
# multi-device behaviours in subprocesses
# ---------------------------------------------------------------------------
def run_with_devices(n, code):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n}"
    env["PYTHONPATH"] = "src"
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, env=env,
                       cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
    assert r.returncode == 0, r.stdout + r.stderr
    return r.stdout


def test_pipeline_matches_sequential():
    out = run_with_devices(4, """
        import numpy as np, jax, jax.numpy as jnp
        from repro.distributed.pipeline import pipeline_apply, make_stage_mesh
        mesh = make_stage_mesh(4)
        def stage_fn(w, x):
            return jnp.tanh(x @ w)
        ws = jax.random.normal(jax.random.PRNGKey(0), (4, 16, 16)) * 0.5
        x = jax.random.normal(jax.random.PRNGKey(1), (8, 4, 16))  # 8 micro x 4
        out = pipeline_apply(stage_fn, ws, x, mesh=mesh)
        ref = x
        for i in range(4):
            ref = jnp.tanh(ref @ ws[i])
        err = float(jnp.abs(out - ref).max())
        assert err < 1e-5, err
        print("PIPELINE_OK", err)
    """)
    assert "PIPELINE_OK" in out


def test_fsdp_train_step_multi_device():
    """2x2 mesh: sharded params + batch, one train step runs and agrees with
    the single-device result."""
    out = run_with_devices(4, """
        import numpy as np, jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.configs import get_arch
        from repro.models.registry import Arch
        from repro.launch.steps import make_train_step, shardings_for
        from repro.optim.adamw import AdamWCfg, adamw_init
        from repro.distributed.sharding import ShardCfg, param_shardings, batch_spec

        spec = get_arch("gemma3-1b", reduced=True)
        arch = Arch(spec)
        key = jax.random.PRNGKey(0)
        params = arch.init(key)
        opt_cfg = AdamWCfg(warmup_steps=1, total_steps=4)
        batch = {"tokens": jax.random.randint(key, (4, 32), 0, 100),
                 "labels": jax.random.randint(key, (4, 32), 0, 100)}
        step = make_train_step(arch, opt_cfg)

        # single-device reference
        opt0 = adamw_init(params, opt_cfg)
        p_ref, _, m_ref = jax.jit(step)(params, opt0, batch)

        mesh = jax.make_mesh((2, 2), ("data", "model"))
        cfg = ShardCfg()
        psh = param_shardings(params, mesh, cfg)
        params_d = jax.device_put(params, psh)
        opt_d = adamw_init(params_d, opt_cfg)
        bsh = {k: NamedSharding(mesh, batch_spec(mesh, cfg, v.ndim, 4))
               for k, v in batch.items()}
        batch_d = jax.device_put(batch, bsh)
        with mesh:
            p_new, o_new, m = jax.jit(step)(params_d, opt_d, batch_d)
        l1, l2 = float(m_ref["loss"]), float(m["loss"])
        assert abs(l1 - l2) < 1e-4, (l1, l2)
        d = max(float(jnp.abs(a - b).max()) for a, b in
                zip(jax.tree.leaves(p_ref), jax.tree.leaves(p_new)))
        assert d < 1e-4, d
        print("FSDP_OK", l1, l2, d)
    """)
    assert "FSDP_OK" in out


def test_elastic_remesh_restart():
    """The same checkpoint restores under a different device count/mesh —
    elastic re-meshing (DESIGN.md §4)."""
    out = run_with_devices(8, """
        import numpy as np, jax, jax.numpy as jnp, tempfile, os
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.configs import get_arch
        from repro.models.registry import Arch
        from repro.distributed.sharding import ShardCfg, param_shardings
        from repro.ckpt.store import CheckpointStore

        spec = get_arch("gemma3-1b", reduced=True)
        arch = Arch(spec)
        params = arch.init(jax.random.PRNGKey(0))
        d = tempfile.mkdtemp()
        store = CheckpointStore(d)
        mesh1 = jax.make_mesh((4, 2), ("data", "model"))
        p1 = jax.device_put(params, param_shardings(params, mesh1, ShardCfg()))
        store.save(1, p1, blocking=True)
        # "restart" on a different mesh shape
        mesh2 = jax.make_mesh((2, 4), ("data", "model"))
        like = jax.tree.map(
            lambda l, s: jax.ShapeDtypeStruct(l.shape, l.dtype, sharding=s),
            params, param_shardings(params, mesh2, ShardCfg()))
        step, restored = store.restore_latest(like)
        assert step == 1
        err = max(float(jnp.abs(a - b).max()) for a, b in
                  zip(jax.tree.leaves(params), jax.tree.leaves(restored)))
        assert err == 0.0, err
        print("REMESH_OK")
    """)
    assert "REMESH_OK" in out

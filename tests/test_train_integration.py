"""End-to-end integration: training loss decreases, checkpoint restart is
exact, and the diffusion pipeline trains + samples."""
import os
import tempfile

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_arch, get_diffusion
from repro.models.registry import Arch
from repro.launch.steps import make_train_step, make_diffusion_train_step
from repro.optim.adamw import AdamWCfg, adamw_init
from repro.ckpt.store import CheckpointStore
from repro.data.pipeline import TokenPipeline, MixturePipeline


def _run_steps(arch, params, opt, step_fn, pipe, start, n):
    it = pipe.iterator(start)
    losses = []
    for _ in range(n):
        b = next(it)
        params, opt, m = step_fn(params, opt, {"tokens": b["tokens"],
                                               "labels": b["labels"]})
        losses.append(float(m["loss"]))
    return params, opt, losses


def test_lm_loss_decreases():
    spec = get_arch("gemma3-1b", reduced=True)
    arch = Arch(spec)
    opt_cfg = AdamWCfg(lr=1e-3, warmup_steps=5, total_steps=60,
                       weight_decay=0.0)
    params = arch.init(jax.random.PRNGKey(0))
    opt = adamw_init(params, opt_cfg)
    pipe = TokenPipeline(vocab=spec.cfg.vocab, seq_len=32, global_batch=8)
    step_fn = jax.jit(make_train_step(arch, opt_cfg))
    _, _, losses = _run_steps(arch, params, opt, step_fn, pipe, 0, 40)
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.3, losses[::8]


def test_checkpoint_restart_exact():
    """train 6 steps == (train 3, save, restore, train 3) bit-for-bit."""
    spec = get_arch("deepseek-coder-33b", reduced=True)
    arch = Arch(spec)
    opt_cfg = AdamWCfg(lr=1e-3, warmup_steps=2, total_steps=10,
                       weight_decay=0.0)
    params0 = arch.init(jax.random.PRNGKey(1))
    opt0 = adamw_init(params0, opt_cfg)
    pipe = TokenPipeline(vocab=spec.cfg.vocab, seq_len=16, global_batch=4)
    step_fn = jax.jit(make_train_step(arch, opt_cfg))

    pA, oA, _ = _run_steps(arch, params0, opt0, step_fn, pipe, 0, 6)

    with tempfile.TemporaryDirectory() as d:
        store = CheckpointStore(d)
        pB, oB, _ = _run_steps(arch, params0, opt0, step_fn, pipe, 0, 3)
        store.save(3, (pB, oB), blocking=True)
        step, (pR, oR) = store.restore_latest((pB, oB))
        assert step == 3
        pC, oC, _ = _run_steps(arch, pR, oR, step_fn, pipe, 3, 3)

    for a, b in zip(jax.tree.leaves(pA), jax.tree.leaves(pC)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert int(oA.step) == int(oC.step) == 6


def test_diffusion_trains_and_samples():
    spec = get_diffusion("cifar10-cld", reduced=True)
    opt_cfg = AdamWCfg(lr=2e-3, warmup_steps=5, total_steps=80,
                       weight_decay=0.0)
    params = spec.init(jax.random.PRNGKey(0))
    opt = adamw_init(params, opt_cfg)
    means = np.zeros((1,) + tuple(spec.data_shape))
    means[0, :4, :4] = 0.8
    pipe = MixturePipeline(means=means, stds=np.array([0.05]),
                           weights=np.array([1.0]), global_batch=32)
    step_fn = jax.jit(make_diffusion_train_step(spec, opt_cfg))
    losses = []
    it = pipe.iterator(0)
    for i in range(60):
        b = next(it)
        params, opt, m = step_fn(params, opt, {"x0": b["x0"]},
                                 jax.random.fold_in(jax.random.PRNGKey(1), i))
        losses.append(float(m["loss"]))
    assert np.mean(losses[-10:]) < np.mean(losses[:10]) - 0.05
    x = spec.sample(params, jax.random.PRNGKey(2), n=8, nfe=10, q=1)
    assert x.shape == (8,) + tuple(spec.data_shape)
    assert np.isfinite(np.asarray(x)).all()


def test_serve_driver_runs():
    from repro.launch import serve
    rc = serve.main(["--arch", "gemma3-1b", "--reduced", "--batch", "2",
                     "--requests", "3", "--prompt-len", "4", "--max-new", "4",
                     "--max-len", "16"])
    assert rc == 0


def test_train_driver_runs_and_resumes():
    from repro.launch import train as train_mod
    with tempfile.TemporaryDirectory() as d:
        rc = train_mod.main(["--arch", "rwkv6-7b", "--reduced", "--steps", "4",
                             "--batch", "2", "--seq-len", "16",
                             "--ckpt-dir", d, "--ckpt-every", "2",
                             "--log-every", "0"])
        assert rc == 0
        rc = train_mod.main(["--arch", "rwkv6-7b", "--reduced", "--steps", "6",
                             "--batch", "2", "--seq-len", "16",
                             "--ckpt-dir", d, "--resume", "--log-every", "0"])
        assert rc == 0

"""Seeded SC105 violations: donated buffers referenced after the call."""
import jax


def _make_step():
    def step(state, x):
        return state + x
    return jax.jit(step, donate_argnums=(0,))


_step = _make_step()


def use_after_donate(state, x):
    new = _step(state, x)
    return new + state                      # SC105 fires here: stale read


def loop_donate(state, xs):
    for x in xs:
        _ = _step(state, x)                 # SC105 fires here: loop donate
    return state                            # SC105 fires here: stale read


def reassign_ok(state, xs):
    # NOT a violation: the donated path is re-stored by the call statement
    for x in xs:
        state = _step(state, x)
    return state

# staticcheck: module=coeff-critical
"""Seeded SC104 violations: Python float literals promoting the (modeled)
coefficient graph outside Stage-I float64 quadrature."""
import numpy as np
import jax.numpy as jnp


def leaky_coeff(bank):
    scaled = jnp.exp(bank.psi) * 0.5        # SC104 fires here: literal*jnp
    shifted = jnp.asarray(1.5)              # SC104 fires here: literal arg
    return scaled + shifted


def stage1_ok(ts):
    # NOT violations: Stage-I quadrature is host-side float64 numpy
    h = np.diff(ts) * 0.5
    return np.exp(-h) * 2.0

"""Fixture: host syncs under the online-serving hot-path registration.

No module pragma comment in this file on purpose — test_staticcheck.py
lints this source under the *registered path suffixes*
(src/repro/serve/traffic.py, src/repro/serve/parking.py), so the thing
under test is the LintConfig registration itself.  Linted at its real
path this file is silent.
"""
import jax
import numpy as np


def park_without_allowlist(tree):
    return jax.device_get(tree)  # SC103 fires here


def peek_progress(counter):
    return counter.item()  # SC103 fires here


def snapshot_to_host(mask):
    return np.asarray(mask)  # SC103 fires here


def fine_on_host(values):
    # NOT a violation: float on a literal constant-folds, no device sync
    return float("1.5"), len(values)

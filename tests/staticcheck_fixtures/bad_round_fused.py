"""Layer-2 fixture: the REAL fused-round kernel body behind a broken
launch — the padding/divisibility contract `ops.round_fused` maintains
(pad D up to a block_d multiple, grid covers exactly the padded extent)
is deliberately dropped, so PL201 and PL202 must fire on the state
streams while the SMEM scalar operands stay exempt.

Traced by tests/test_staticcheck.py — never executed.  The clean control
for the same kernel is `round_fused.ops.staticcheck_entries()`, which
tools/staticcheck/menu.py feeds to the sanitizer.
"""
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.round_fused.kernel import N_INTS, _make_round_kernel

_SMEM = pltpu.SMEM

# one vpsde-like slot class, but a ragged last tile: 32 does not divide
# D=48, and the 2-step d-axis grid walks the second tile off the array
_B, _K, _KF, _QB, _D, _PB, _BLK = 2, 1, 1, 1, 48, 4, 32
_C = 3 + _QB                       # [psi, B, P_chol, pC_0]


def bad_round_fused_trace():
    """The megakernel launched without `_pad_last`: block_d=32 on D=48
    (PL201) and grid (B, 2) whose second d-tile spans [32, 64) (PL202)."""
    kernel = _make_round_kernel(
        kf=_KF, K=_K, Qb=_QB, D=_D, n=_KF * _D, block_d=_BLK,
        with_corrector=False, gen_noise=False)

    def launch(ints, keys, blks, dis, pool, u, hist, eps, noise):
        return pl.pallas_call(
            kernel,
            grid=(_B, 2),
            in_specs=[
                pl.BlockSpec((1, N_INTS), lambda b, d: (b, 0),
                             memory_space=_SMEM),
                pl.BlockSpec((1, 2), lambda b, d: (b, 0),
                             memory_space=_SMEM),
                pl.BlockSpec((1, _C, _KF, _KF), lambda b, d: (b, 0, 0, 0),
                             memory_space=_SMEM),
                pl.BlockSpec((1, _C), lambda b, d: (b, 0),
                             memory_space=_SMEM),
                pl.BlockSpec((_PB, _BLK), lambda b, d: (0, d)),
                pl.BlockSpec((1, _K, _BLK), lambda b, d: (b, 0, d)),
                pl.BlockSpec((1, _QB, _K, _BLK), lambda b, d: (b, 0, 0, d)),
                pl.BlockSpec((1, _KF, _BLK), lambda b, d: (b, 0, d)),
                pl.BlockSpec((1, _KF, _BLK), lambda b, d: (b, 0, d)),
            ],
            out_specs=[
                pl.BlockSpec((1, _K, _BLK), lambda b, d: (b, 0, d)),
                pl.BlockSpec((1, _QB, _K, _BLK), lambda b, d: (b, 0, 0, d)),
                pl.BlockSpec((1,), lambda b, d: (b,), memory_space=_SMEM),
                pl.BlockSpec((1,), lambda b, d: (b,), memory_space=_SMEM),
            ],
            out_shape=[
                jax.ShapeDtypeStruct((_B, _K, _D), jnp.float32),
                jax.ShapeDtypeStruct((_B, _QB, _K, _D), jnp.float32),
                jax.ShapeDtypeStruct((_B,), jnp.int32),
                jax.ShapeDtypeStruct((_B,), jnp.int32),
            ],
            interpret=True,
        )(ints, keys, blks, dis, pool, u, hist, eps, noise)

    return jax.make_jaxpr(launch)(
        jnp.zeros((_B, N_INTS), jnp.int32),
        jnp.zeros((_B, 2), jnp.uint32),
        jnp.zeros((_B, _C, _KF, _KF), jnp.float32),
        jnp.zeros((_B, _C), jnp.int32),
        jnp.zeros((_PB, _D), jnp.float32),
        jnp.zeros((_B, _K, _D), jnp.float32),
        jnp.zeros((_B, _QB, _K, _D), jnp.float32),
        jnp.zeros((_B, _KF, _D), jnp.float32),
        jnp.zeros((_B, _KF, _D), jnp.float32))

# staticcheck: module=library
"""Seeded SC102 violation: constant-seed PRNGKey in (modeled) library
code.  The pragma above opts this file out of the tests/ exemption."""
import jax


def library_entry(n):
    key = jax.random.PRNGKey(0)             # SC102 fires here
    return jax.random.normal(key, (n,))


def threaded_ok(key, n):
    # NOT a violation: the key is threaded in by the caller
    return jax.random.normal(key, (n,))

"""Fixture: host syncs under the router-tier hot-path registration.

No module pragma comment in this file on purpose — test_staticcheck.py
lints this source under the *registered path suffixes*
(src/repro/serve/api.py, src/repro/serve/router.py), so the thing under
test is the LintConfig registration itself: the router's plan/assign loop
runs per arrival and must stay pure host Python, and the request type's
wire path must not smuggle device fetches into admission.  Linted at its
real path this file is silent.
"""
import jax
import numpy as np


def harvest_result_inline(slot_output):
    return np.asarray(slot_output)  # SC103 fires here


def wait_for_replica(state):
    return state.block_until_ready()  # SC103 fires here


def peek_done_count(done_mask):
    return done_mask.item()  # SC103 fires here


def drain_to_host(tree):
    return jax.device_get(tree)  # SC103 fires here


def route_key(wire, n):
    # NOT a violation: pure host arithmetic on wire scalars — exactly what
    # the router loop is allowed to do per arrival
    return (int(wire["rid"]) % n, float("0.5"), len(wire))

"""Layer-2 fixtures: a Pallas launch with a non-divisible BlockSpec and
out-of-bounds index map (PL201/PL202), a host-callback step (JX101), and
a jit whose donation XLA must drop (JX103).

These are traced by tests/test_staticcheck.py — never executed.
"""
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _copy_kernel(x_ref, o_ref):
    o_ref[...] = x_ref[...]


def bad_blockspec_trace():
    """block 32 does not divide dim 48; the index map overshoots."""
    def launch(x):
        return pl.pallas_call(
            _copy_kernel, grid=(2,),
            in_specs=[pl.BlockSpec((48, 32), lambda i: (0, i))],
            out_specs=pl.BlockSpec((48, 32), lambda i: (0, i)),
            out_shape=jax.ShapeDtypeStruct((48, 48), jnp.float32))(x)
    return jax.make_jaxpr(launch)(jnp.zeros((48, 48), jnp.float32))


def callback_step_trace():
    """A steady-state step that round-trips through Python."""
    def step(x):
        jax.debug.callback(lambda v: None, x)
        return x * 2
    return jax.make_jaxpr(step)(jnp.zeros((4,), jnp.float32))


def dropped_donation_artifacts():
    """Donating an input no output can alias: XLA silently drops it.
    Returns (lowered_text, compiled_text) for the JX103 audit."""
    def reduce_all(big):
        return jnp.sum(big)                   # scalar out: nothing to alias

    traced = jax.jit(reduce_all, donate_argnums=(0,)).trace(
        jnp.zeros((64, 64), jnp.float32))
    lowered = traced.lower()
    return lowered.as_text(), lowered.compile().as_text()


def honored_donation_artifacts():
    """Control: a same-shaped output keeps the donation honored."""
    def bump(state):
        return state + 1.0

    traced = jax.jit(bump, donate_argnums=(0,)).trace(
        jnp.zeros((64, 64), jnp.float32))
    lowered = traced.lower()
    return lowered.as_text(), lowered.compile().as_text()

# staticcheck: module=hot-path
"""Seeded SC103 violations: host syncs in a (modeled) serve hot-path
module."""
import numpy as np
import jax


def leaky_round(state):
    mask = np.asarray(state.active)         # SC103 fires here: d2h copy
    loss = state.loss.item()                # SC103 fires here: sync
    state.u.block_until_ready()             # SC103 fires here: sync
    lr = float(state.lr)                    # SC103 fires here: sync
    return mask, loss, lr


def clean_round(state):
    # NOT violations: jnp.asarray is h2d, float on a literal is host math
    import jax.numpy as jnp
    ids = jnp.asarray([0, 1])
    scale = float(0.5)
    return jax.device_put(ids), scale

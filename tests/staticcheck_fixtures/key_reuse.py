"""Seeded SC101 violations: PRNG key consumed twice / reused in a loop.

Each violation line carries a fires-here comment so the test can assert
the finding anchors exactly where expected.
"""
import jax
import jax.numpy as jnp


def double_consume(key):
    k1, k2 = jax.random.split(key)
    a = jax.random.normal(k1, (4,))
    b = jax.random.uniform(k1, (4,))        # SC101 fires here: k1 reused
    return a + b + jax.random.normal(k2, (4,))


def loop_reuse(key):
    sub = jax.random.fold_in(key, 7)
    total = jnp.zeros((4,))
    for _ in range(3):
        total += jax.random.normal(sub, (4,))   # SC101 fires here: loop
    return total


def branch_ok(key, flag):
    # NOT a violation: the two consumptions are mutually exclusive
    k1, _ = jax.random.split(key)
    if flag:
        return jax.random.normal(k1, (4,))
    return jax.random.uniform(k1, (4,))


def rebind_ok(key):
    # NOT a violation: the key is re-derived every iteration
    out = jnp.zeros((4,))
    for i in range(3):
        key, sub = jax.random.split(key)
        out += jax.random.normal(sub, (4,))
    return out

"""Low-precision serving: the differential tolerance tier of PR 8.

The precision axis splits by layer (models/quantize docstring):

  * state-update layer — BITWISE at every precision.  The round commit
    consumes the net's f32 eps output and never touches the params, so
    an engine serving precision p equals "p-precision eval + the f32
    stitched chain" bit for bit, and solo == mixed stays bitwise within
    a precision class.  The f32 class itself is untouched by the
    refactor: `wrap_eps_model(..., 'f32')` is the identity, so an
    all-f32 engine and the f32 slots of a mixed-precision engine run the
    byte-identical warmed graphs.
  * net layer — bounded error vs the f32 eval, with the documented
    `NET_TOLERANCES` (bf16 ~2^-8 relative; int8 ~scale/2 per weight,
    depth-amplified).

Plus the serving contract: warming every precision class once means
later traffic — any mix of precisions and in-bucket configs — compiles
NOTHING (`recompiles_after_warmup == 0` stays gated in perf_guard).
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_diffusion
from repro.models import quantize as qtz
from repro.serve import DiffusionEngine, SampleRequest


@pytest.fixture(scope="module")
def spec_params():
    spec = get_diffusion("cifar10-ddpm", reduced=True)
    return spec, spec.init(jax.random.PRNGKey(0))


# ---------------------------------------------------------------------------
# the residency transform itself
# ---------------------------------------------------------------------------
def test_f32_is_identity(spec_params):
    spec, params = spec_params
    assert qtz.quantize_tree(params, "f32") is params
    model = spec.eps_model
    assert qtz.wrap_eps_model(model, "f32") is model


def test_bf16_casts_every_float_leaf(spec_params):
    _, params = spec_params
    q = qtz.quantize_tree(params, "bf16")
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(q)):
        if jnp.issubdtype(a.dtype, jnp.floating):
            assert b.dtype == jnp.bfloat16
            np.testing.assert_array_equal(
                np.asarray(a.astype(jnp.bfloat16), np.float32),
                np.asarray(b, np.float32))
        else:
            assert b.dtype == a.dtype


def test_int8_quantizes_matrices_within_half_scale(spec_params):
    _, params = spec_params
    q = qtz.quantize_tree(params, "int8")
    n_qt = 0
    for a, b in zip(jax.tree.leaves(params),
                    jax.tree.leaves(q, is_leaf=lambda x:
                                    isinstance(x, qtz.QTensor))):
        if isinstance(b, qtz.QTensor):
            n_qt += 1
            assert b.q.dtype == jnp.int8 and a.ndim >= 2
            err = np.abs(np.asarray(b.dequant()) - np.asarray(a))
            half = 0.5 * np.asarray(b.scale) + 1e-12
            assert (err <= half + 1e-7 * np.abs(np.asarray(a))).all()
        else:
            # vectors/scalars ride in f32 (weight-only quantization)
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert n_qt > 0


def test_unknown_precision_rejected(spec_params):
    spec, params = spec_params
    with pytest.raises(ValueError, match="unknown precision"):
        DiffusionEngine(spec, params, batch_size=2, nfe=4, precision="fp4")
    eng = DiffusionEngine(spec, params, batch_size=2, nfe=4)
    with pytest.raises(ValueError, match="unknown precision"):
        eng.serve([SampleRequest(rid=0, precision="fp4")])


# ---------------------------------------------------------------------------
# net layer: bounded error vs the f32 eval (the documented tolerances)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("precision", ["bf16", "int8"])
def test_net_eval_within_documented_tolerance(spec_params, precision):
    spec, params = spec_params
    shape = (4,) + tuple(spec.data_shape)
    u = jax.random.normal(jax.random.PRNGKey(1), shape)
    t = jnp.full((4,), 0.5)
    ref = np.asarray(spec.eps_model(params, u, t))
    lo = np.asarray(qtz.wrap_eps_model(spec.eps_model, precision)(
        qtz.quantize_tree(params, precision), u, t))
    assert lo.dtype == np.float32
    tol = qtz.NET_TOLERANCES[precision]
    np.testing.assert_allclose(
        lo, ref, rtol=tol["rtol"], atol=tol["atol"] * np.abs(ref).max(),
        err_msg=f"{precision} eval beyond its documented tolerance")


# ---------------------------------------------------------------------------
# state-update layer: bitwise, solo == mixed, f32 untouched
# ---------------------------------------------------------------------------
def test_solo_equals_mixed_within_precision_class(spec_params):
    spec, params = spec_params
    reqs = [SampleRequest(rid=0, seed=0),
            SampleRequest(rid=1, seed=1, precision="bf16"),
            SampleRequest(rid=2, seed=2, precision="int8"),
            SampleRequest(rid=3, seed=3, precision="bf16", nfe=5)]
    mixed = DiffusionEngine(spec, params, batch_size=2, nfe=6).serve(reqs)
    assert set(mixed) == {0, 1, 2, 3}
    for r in reqs:
        solo = DiffusionEngine(spec, params, batch_size=2,
                               nfe=6).serve([r])
        np.testing.assert_array_equal(
            mixed[r.rid], solo[r.rid],
            err_msg=f"rid {r.rid} ({r.precision or 'f32'}): solo != mixed")


def test_f32_class_unperturbed_by_lowprec_neighbours(spec_params):
    """The f32 request in a mixed-precision batch is bitwise what an
    all-f32 engine serves: the low-precision classes ride their own
    variants and masks, never the f32 slots' arithmetic."""
    spec, params = spec_params
    r = SampleRequest(rid=0, seed=7)
    base = DiffusionEngine(spec, params, batch_size=2, nfe=6).serve([r])
    mixed = DiffusionEngine(spec, params, batch_size=2, nfe=6).serve(
        [r, SampleRequest(rid=1, seed=8, precision="int8")])
    np.testing.assert_array_equal(mixed[0], base[0])


def test_lowprec_equals_lowprec_eval_plus_f32_chain(spec_params):
    """The tolerance split made operational: engine(precision=p) must
    reproduce, bitwise, a stitched-chain engine whose ONLY change is the
    p-precision score eval — i.e. the whole error budget of low-precision
    serving lives in the net layer; the state-update layer contributes
    exactly zero."""
    from repro.launch.steps import make_diffusion_round_step_stitched
    from repro.serve.engine import _jit_state_update
    spec, params = spec_params

    class _PrecSpec:
        """spec with the eval swapped for its p-precision wrapper."""
        def __init__(self, spec, precision):
            self._spec = spec
            self.eps_model = qtz.wrap_eps_model(spec.eps_model, precision)

        def __getattr__(self, name):
            return getattr(self._spec, name)

    for precision in ("bf16", "int8"):
        r = SampleRequest(rid=0, seed=3, precision=precision)
        out = DiffusionEngine(spec, params, batch_size=2, nfe=5).serve([r])

        oracle = DiffusionEngine(spec, params, batch_size=2, nfe=5,
                                 precision=precision)
        oracle._steps = {
            (n, precision): _jit_state_update(
                make_diffusion_round_step_stitched(
                    _PrecSpec(s, precision),
                    fam_index=oracle.cache.fam_index(n)),
                (1,), oracle._state_sh,
                static_argnames=("with_corrector",))
            for n, s in oracle.specs.items()}
        ref = oracle.serve([SampleRequest(rid=0, seed=3)])
        np.testing.assert_array_equal(
            out[0], ref[0],
            err_msg=f"{precision}: state-update layer leaked error")


# ---------------------------------------------------------------------------
# serving contract: zero recompiles after a full-precision warmup
# ---------------------------------------------------------------------------
def test_zero_recompiles_after_precision_warmup(spec_params):
    spec, params = spec_params
    eng = DiffusionEngine(spec, params, batch_size=2, nfe=6)
    eng.serve([SampleRequest(rid=0, seed=0),
               SampleRequest(rid=1, seed=1, precision="bf16"),
               SampleRequest(rid=2, seed=2, precision="int8")])
    warm = eng.compile_stats()
    assert warm["step"] == 3            # one variant per warmed class
    eng.serve([SampleRequest(rid=10 + i, seed=i,
                             precision=["int8", "f32", "bf16"][i % 3],
                             nfe=[6, 5, 4][i % 3])
               for i in range(6)])
    assert eng.compile_stats() == warm, "post-warmup traffic recompiled"

"""Tier: serve-router — the deterministic front-tier (serve/router.py).

Three contracts:

  * **Replayable plans.**  `Router.plan` is a pure function of (trace,
    replicas, config): two plans from the same inputs agree on every
    assignment — replica, timestamp, requeue count — and every counter.
  * **Health + backpressure semantics.**  Fault windows steer traffic off
    a replica *at probe granularity*; a full fleet requeues arrivals
    `requeue_delay` apart up to `max_requeues`, then sheds, with every
    hop in the audited log.
  * **Bitwise solo == routed.**  Two replica engines serving the router's
    sub-traces produce samples byte-identical to ONE engine serving the
    whole trace — the serving stack's purity invariant (result = f(seed,
    config)) surviving the fleet split.  Mirrors tests/test_serve_mesh.py
    at the tier above the mesh.
"""
import jax
import numpy as np
import pytest

from repro.configs import get_diffusion
from repro.serve import (Arrival, DiffusionEngine, ReplicaSpec, Router,
                        RouterConfig, SampleRequest, ServeRequest,
                        TraceTraffic, VirtualClock, poisson_trace)


def _trace(n=8, rate=0.8, seed=23, nfe=None):
    return poisson_trace(
        lambda i, rng: SampleRequest(rid=i, seed=i, nfe=nfe),
        n=n, rate=rate, seed=seed)


def _router(n=2, **cfg_kw):
    cfg = dict(max_queue_depth=3, probe_every=4.0, requeue_delay=1.0,
               max_requeues=8, default_nfe=10)
    cfg.update(cfg_kw)
    return Router([ReplicaSpec(index=i) for i in range(n)],
                  RouterConfig(**cfg))


class TestPlanDeterminism:
    def test_replay_is_identical(self):
        p1 = _router().plan(_trace())
        p2 = _router().plan(_trace())
        assert p1.assignments == p2.assignments   # replica AND timestamps
        assert p1.sub_traces == p2.sub_traces     # wire dicts compare ==
        assert p1.counters == p2.counters
        assert p1.shed == p2.shed

    def test_every_arrival_accounted(self):
        plan = _router().plan(_trace(n=12))
        assert plan.counters["requests_routed"] + plan.counters["n_shed"] \
            == 12
        routed = sorted(a["rid"] for a in plan.assignments)
        shed = sorted(s["rid"] for s in plan.shed)
        assert sorted(routed + shed) == list(range(12))

    def test_wire_only_ingress(self):
        # sub-traces hold plain wire dicts; replica_trace restores requests
        router = _router()
        plan = router.plan(_trace(n=6))
        for sub in plan.sub_traces:
            for _, wire in sub:
                assert isinstance(wire, dict) and "v" in wire
        restored = [a.request
                    for i in range(2)
                    for a in router.replica_trace(plan, i).due(float("inf"))]
        assert all(isinstance(r, ServeRequest) for r in restored)
        assert sorted(r.rid for r in restored) \
            == sorted(a["rid"] for a in plan.assignments)

    def test_health_probe_count_is_golden(self):
        # arrivals at t=0 and t=9 with probe_every=4: ticks at 0,4,8 fire
        # before the last event -> 3 ticks x 2 replicas = 6 probes, plus
        # the t=12 tick fires only if an event lands at/after it (none)
        trace = TraceTraffic([Arrival(0.0, SampleRequest(rid=0, seed=0)),
                              Arrival(9.0, SampleRequest(rid=1, seed=1))])
        plan = _router(probe_every=4.0).plan(trace)
        assert plan.counters["health_probes"] == 6


class TestHealthAndBackpressure:
    def test_fault_window_steers_traffic(self):
        # replica 1 down for the whole trace window: everything that its
        # probes cover lands on replica 0
        router = Router([ReplicaSpec(index=0),
                         ReplicaSpec(index=1, fault_windows=((0.0, 1e9),))],
                        RouterConfig(max_queue_depth=8, default_nfe=10))
        plan = router.plan(_trace(n=6))
        assert plan.counters["n_shed"] == 0
        assert all(a["replica"] == 0 for a in plan.assignments)

    def test_health_is_probe_granular(self):
        # the fault begins at t=1 but the next probe is at t=4: the t=2
        # arrival still routes to the (stale-healthy) replica — the real
        # front-tier failure mode, deterministically reproduced
        router = Router([ReplicaSpec(index=0, fault_windows=((1.0, 1e9),))],
                        RouterConfig(probe_every=4.0, max_requeues=0,
                                     default_nfe=10))
        trace = TraceTraffic([Arrival(2.0, SampleRequest(rid=0, seed=0)),
                              Arrival(5.0, SampleRequest(rid=1, seed=1))])
        plan = router.plan(trace)
        assert [a["rid"] for a in plan.assignments] == [0]
        assert [s["rid"] for s in plan.shed] == [1]

    def test_backpressure_requeues_then_assigns(self):
        # one replica, depth 1, cost 10: the second t=0 arrival requeues
        # once per virtual unit until the first drains at t=10
        router = Router([ReplicaSpec(index=0)],
                        RouterConfig(max_queue_depth=1, requeue_delay=1.0,
                                     max_requeues=20, default_nfe=10))
        trace = TraceTraffic([Arrival(0.0, SampleRequest(rid=0, seed=0)),
                              Arrival(0.0, SampleRequest(rid=1, seed=1))])
        plan = router.plan(trace)
        assert plan.counters["n_shed"] == 0
        assert plan.counters["requeues"] == 10
        second = plan.assignments[1]
        assert (second["rid"], second["t"], second["n_requeues"]) \
            == (1, 10.0, 10)

    def test_exhausted_requeues_shed_with_audit(self):
        router = Router([ReplicaSpec(index=0)],
                        RouterConfig(max_queue_depth=1, requeue_delay=1.0,
                                     max_requeues=2, default_nfe=10))
        trace = TraceTraffic([Arrival(0.0, SampleRequest(rid=0, seed=0)),
                              Arrival(0.0, SampleRequest(rid=1, seed=1))])
        plan = router.plan(trace)
        assert plan.counters == {"requests_routed": 1, "requeues": 2,
                                 "health_probes": 1, "n_shed": 1}
        assert plan.shed == [{"t": 2.0, "rid": 1, "n_requeues": 2}]

    def test_least_loaded_lowest_index_tiebreak(self):
        plan = _router(n=3).plan(TraceTraffic(
            [Arrival(0.0, SampleRequest(rid=i, seed=i)) for i in range(3)]))
        assert [a["replica"] for a in plan.assignments] == [0, 1, 2]


@pytest.mark.slow
class TestRoutedBitwiseEqualsSolo:
    """2 replica engines serving the router's sub-traces == 1 engine
    serving the whole trace, byte for byte, zero recompiles after warmup.
    """

    def _engine(self, spec, params):
        engine = DiffusionEngine(spec, params, batch_size=4, nfe=10)
        engine.serve([SampleRequest(rid=-1, seed=0)])   # warm the bucket
        return engine

    def test_solo_equals_routed(self):
        spec = get_diffusion("cifar10-ddpm", reduced=True)
        params = spec.init(jax.random.PRNGKey(0))
        trace = _trace(n=8, nfe=10)

        solo = self._engine(spec, params)
        want = solo.serve_stream(_trace(n=8, nfe=10), clock=VirtualClock())

        engines = [self._engine(spec, params) for _ in range(2)]
        warm = [sum(e.compile_stats().values()) for e in engines]
        results, plan = _router().serve(trace, engines)

        assert plan.counters["n_shed"] == 0
        assert sorted(results) == sorted(want)
        for rid in want:
            a, b = np.asarray(results[rid]), np.asarray(want[rid])
            assert a.tobytes() == b.tobytes(), f"rid {rid} diverged"
        for e, w in zip(engines, warm):
            assert sum(e.compile_stats().values()) == w, \
                "replica recompiled after warmup"

"""Per-kernel validation: Pallas (interpret=True) vs pure-jnp ref oracles,
swept over shapes and dtypes (assignment deliverable (c))."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.kernels.attention.ref import attention_ref
from repro.kernels.attention.kernel import flash_attention
from repro.kernels.attention.ops import blocked_attention
from repro.kernels.decode_attention.ref import decode_attention_ref
from repro.kernels.decode_attention.kernel import decode_attention
from repro.kernels.ei_update.ref import ei_update_ref
from repro.kernels.ei_update.kernel import ei_update
from repro.kernels.dct2 import ref as dct_ref
from repro.kernels.dct2 import kernel as dct_kernel


def tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else \
        dict(rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------
ATTN_CASES = [
    # (B, Sq, Sk, Hq, Hkv, Dh, causal, window, q_off, block_q, block_k)
    (1, 128, 128, 4, 4, 32, True, None, 0, 64, 64),
    (2, 128, 128, 8, 2, 64, True, None, 0, 128, 64),     # GQA
    (1, 256, 256, 4, 1, 32, True, 64, 0, 64, 64),        # MQA + window
    (1, 128, 128, 2, 2, 32, False, None, 0, 64, 64),     # bidirectional
    (2, 64, 256, 4, 2, 32, True, None, 192, 64, 64),     # offset (chunked)
]


@pytest.mark.parametrize("case", ATTN_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_matches_ref(case, dtype):
    B, Sq, Sk, Hq, Hkv, Dh, causal, window, q_off, bq, bk = case
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, Sq, Hq, Dh), dtype)
    k = jax.random.normal(ks[1], (B, Sk, Hkv, Dh), dtype)
    v = jax.random.normal(ks[2], (B, Sk, Hkv, Dh), dtype)
    ref = attention_ref(q, k, v, causal=causal, window=window, q_offset=q_off)
    out = flash_attention(q, k, v, causal=causal, window=window,
                          q_offset=q_off, block_q=bq, block_k=bk,
                          interpret=True)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **tol(dtype))


@pytest.mark.parametrize("case", ATTN_CASES[:3])
def test_blocked_attention_matches_ref(case):
    B, Sq, Sk, Hq, Hkv, Dh, causal, window, q_off, bq, bk = case
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (B, Sq, Hq, Dh))
    k = jax.random.normal(ks[1], (B, Sk, Hkv, Dh))
    v = jax.random.normal(ks[2], (B, Sk, Hkv, Dh))
    ref = attention_ref(q, k, v, causal=causal, window=window, q_offset=q_off)
    out = blocked_attention(q, k, v, causal=causal, window=window,
                            q_offset=q_off, block_k=64)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# decode attention
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("Hq,Hkv,Dh,S,clen,bk", [
    (8, 2, 32, 256, 100, 64),
    (4, 4, 64, 512, 511, 128),
    (4, 1, 32, 128, 1, 64),
    (16, 8, 64, 256, 256, 256),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_attention_matches_ref(Hq, Hkv, Dh, S, clen, bk, dtype):
    B = 2
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    q = jax.random.normal(ks[0], (B, Hq, Dh), dtype)
    k = jax.random.normal(ks[1], (B, S, Hkv, Dh), dtype)
    v = jax.random.normal(ks[2], (B, S, Hkv, Dh), dtype)
    cl = jnp.int32(clen)
    ref = decode_attention_ref(q, k, v, cl)
    out = decode_attention(q, k, v, cl, block_k=bk, interpret=True)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **tol(dtype))


def test_decode_attention_skips_invalid_blocks():
    """cache_len = 0 -> fully masked -> zeros (not NaN)."""
    B, Hq, Hkv, Dh, S = 1, 2, 2, 32, 128
    q = jnp.ones((B, Hq, Dh))
    k = jnp.ones((B, S, Hkv, Dh))
    v = jnp.ones((B, S, Hkv, Dh))
    out = decode_attention(q, k, v, jnp.int32(0), block_k=64, interpret=True)
    assert np.isfinite(np.asarray(out)).all()
    np.testing.assert_allclose(np.asarray(out), 0.0)


@pytest.mark.parametrize("window", [None, 32, 64, 300])
def test_decode_attention_per_row_clen_and_window(window):
    """Continuous-batching paths of the Pallas kernel: per-row (B,)
    cache_len vectors (each row masks/skips at its own valid length) and
    sliding-window masking, against the jnp oracle in interpret mode."""
    B, Hq, Hkv, Dh, S = 4, 8, 2, 32, 256
    ks = jax.random.split(jax.random.PRNGKey(5), 3)
    q = jax.random.normal(ks[0], (B, Hq, Dh))
    k = jax.random.normal(ks[1], (B, S, Hkv, Dh))
    v = jax.random.normal(ks[2], (B, S, Hkv, Dh))
    clen = jnp.array([3, 64, 129, 256], jnp.int32)      # straddles blocks
    ref = decode_attention_ref(q, k, v, clen, window=window)
    out = decode_attention(q, k, v, clen, block_k=64, window=window,
                           interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# ei_update (fused gDDIM state update)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("B,k,D,q", [
    (2, 1, 128, 1), (2, 1, 2048, 3), (3, 2, 300, 2), (1, 2, 4096, 4),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ei_update_matches_ref(B, k, D, q, dtype):
    ks = jax.random.split(jax.random.PRNGKey(3), 4)
    u = jax.random.normal(ks[0], (B, k, D), dtype)
    eh = jax.random.normal(ks[1], (q, B, k, D), dtype)
    psi = jax.random.normal(ks[2], (k, k))
    C = jax.random.normal(ks[3], (q, k, k))
    ref = ei_update_ref(u, eh, psi, C)
    out = ei_update(u, eh, psi, C, block_d=256, interpret=True)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **tol(dtype))


@pytest.mark.parametrize("B,k,D", [
    (2, 1, 128), (2, 1, 2048), (3, 2, 300), (1, 2, 4096),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_apply_factored_kernel_matches_ref(B, k, D, dtype):
    """The fused factored-coefficient kernel (the FactoredBank's gather
    form: per-example block factor applied in VREGs + diagonal scale, one
    VMEM pass) against the reference two-contraction path."""
    from repro.kernels.ei_update.kernel import apply_factored
    from repro.kernels.ei_update.ref import apply_factored_ref
    ks = jax.random.split(jax.random.PRNGKey(11), 3)
    z = jax.random.normal(ks[0], (B, k, D), dtype)
    blk = jax.random.normal(ks[1], (B, k, k))
    diag = jax.random.normal(ks[2], (B, D))
    ref = apply_factored_ref(blk, diag.astype(dtype), z)
    out = apply_factored(blk, diag.astype(dtype), z, block_d=256,
                         interpret=True)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **tol(dtype))


# ---------------------------------------------------------------------------
# dct2 + fused BDM update
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("H,W,Ch", [(8, 8, 3), (16, 16, 1), (32, 32, 3), (16, 8, 2)])
def test_dct2_roundtrip_and_ref(H, W, Ch):
    x = jax.random.normal(jax.random.PRNGKey(4), (2, H, W, Ch))
    y = dct_kernel.dct2(x, interpret=True)
    np.testing.assert_allclose(np.asarray(y), np.asarray(dct_ref.dct2_ref(x)),
                               rtol=1e-5, atol=1e-5)
    back = dct_kernel.dct2(y, inverse=True, interpret=True)
    np.testing.assert_allclose(np.asarray(back), np.asarray(x),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("q", [1, 2, 3])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_bdm_ei_update_matches_ref(q, dtype):
    B, H, W, Ch = 2, 16, 16, 3
    ks = jax.random.split(jax.random.PRNGKey(5), 4)
    u = jax.random.normal(ks[0], (B, H, W, Ch), dtype)
    eh = jax.random.normal(ks[1], (q, B, H, W, Ch), dtype)
    psi = jax.random.normal(ks[2], (H, W, 1))
    C = jax.random.normal(ks[3], (q, H, W, 1))
    ref = dct_ref.bdm_ei_update_ref(u, eh, psi, C)
    out = dct_kernel.bdm_ei_update(u, eh, psi, C, interpret=True)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **tol(dtype))


def test_ei_update_is_the_gddim_step():
    """The kernel reproduces one sample_gddim predictor step on CLD."""
    from repro.sde import CLD, GaussianMixture, ExactScore
    from repro.core import build_sampler_coeffs, time_grid
    sde = CLD()
    ts = time_grid(sde, 6)
    co = build_sampler_coeffs(sde, ts, q=2)
    mix = GaussianMixture(np.array([[0.4, -0.2]]), np.array([0.05]), np.array([1.0]))
    oracle = ExactScore(sde, mix)
    eps_fn, _ = oracle.eps_fn_for_grid(ts)
    u = sde.prior_sample(jax.random.PRNGKey(0), 4, (2,))   # (B, 2, 2)
    N = co.psi.shape[0]
    k = 0
    i = N - k
    e0 = eps_fn(u, jnp.int32(i))
    hist = jnp.stack([e0, jnp.zeros_like(e0)])             # q=2, warm start
    # reference step
    u_ref = sde.apply(co.psi[k], u) + sde.apply(co.pC[k, 0], hist[0]) \
        + sde.apply(co.pC[k, 1], hist[1])
    # kernel step (pack channel axis)
    from repro.kernels.ei_update.ops import pack_state, unpack_state
    up, shape = pack_state(u, 2)
    ep = jnp.stack([pack_state(h, 2)[0] for h in hist])
    out = ei_update(up, ep, co.psi[k], co.pC[k], interpret=True)
    np.testing.assert_allclose(np.asarray(unpack_state(out, shape)),
                               np.asarray(u_ref), rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# round_fused (the whole post-score-eval round commit, one launch)
# ---------------------------------------------------------------------------
def _round_fused_parts():
    import functools
    from repro.core import CoeffCache, SamplerConfig
    from repro.sde import VPSDE, CLD, BDM
    if not hasattr(_round_fused_parts, "_cache"):
        shape = (4, 4, 3)
        cache = CoeffCache({"vpsde": VPSDE(), "cld": CLD(),
                            "bdm": BDM(data_shape=shape)},
                           data_shape=shape)
        cfgs = [SamplerConfig(nfe=4), SamplerConfig(nfe=5, q=2),
                SamplerConfig(nfe=6, lam=0.7),
                SamplerConfig(nfe=4, family="cld"),
                SamplerConfig(nfe=4, family="cld", q=2, corrector=True),
                SamplerConfig(nfe=5, family="cld", lam=0.5),
                SamplerConfig(nfe=4, family="bdm", q=2, corrector=True),
                SamplerConfig(nfe=3, family="bdm", lam=0.5)]
        idx = [cache.index_of(c) for c in cfgs]
        _round_fused_parts._cache = (cache, cfgs, idx, shape)
    return _round_fused_parts._cache


# corners: family x q x corrector x stochastic — each case's slot list
# cycles the matching configs, so every case also mixes q/nfe per slot
ROUND_CASES = [
    # (family, want_q2, with_corrector, want_stochastic)
    ("vpsde", False, False, False),
    ("vpsde", True, False, False),
    ("vpsde", False, False, True),
    ("vpsde", True, True, True),
    ("cld", False, False, False),
    ("cld", True, True, False),
    ("cld", False, False, True),
    ("bdm", True, False, False),
    ("bdm", True, True, True),
]


@pytest.mark.parametrize("family,q2,corr,sto", ROUND_CASES)
def test_round_fused_kernel_matches_ref(family, q2, corr, sto):
    """One interpret-mode launch of the fused round commit vs the jitted
    reference chain: BITWISE for the kf=1 families (VPSDE/BDM — the
    in-kernel threefry/erf_inv noise draw reproduces the stitched
    fold_in draw exactly), and within one rounding of the CLD kf=2 block
    contraction (the ref einsum lowers to an FMA dot_general; see
    apply_factored_ref's docstring — same gap class as the
    `test_apply_factored_kernel_matches_ref` tolerance)."""
    import functools
    from repro.kernels.round_fused import ops as rf
    cache, cfgs, idx, shape = _round_fused_parts()
    bank = cache.factored_bank
    sde = cache.sdes[family]
    kf, fi = sde.packed_k, cache.fam_index(family)
    K, D = cache.k_max, int(np.prod(shape))
    Qb = bank.pC_blk.shape[2]
    slots = [c for c, cfg in zip(idx, cfgs)
             if cache.resolve(cfg) == family
             and (cfg.q == 2) == q2 and (cfg.lam > 0) == sto] \
        or [c for c, cfg in zip(idx, cfgs) if cache.resolve(cfg) == family]
    B = 3
    rng = np.random.default_rng(
        abs(hash((family, q2, corr, sto))) % 99991)
    cfg_ids = jnp.asarray([slots[i % len(slots)] for i in range(B)],
                          jnp.int32)
    k = jnp.asarray(rng.integers(0, 5, B), jnp.int32)
    kc = jnp.clip(k, 0, bank.n_steps[cfg_ids] - 1)
    u = jnp.asarray(rng.standard_normal((B, K, D)), jnp.float32)
    hist = jnp.asarray(rng.standard_normal((B, Qb, K, D)), jnp.float32)
    eps_c = jnp.asarray(rng.standard_normal((B, kf, D)), jnp.float32)
    eps_n_c = jnp.asarray(rng.standard_normal((B, kf, D)), jnp.float32)
    keys = jnp.asarray(rng.integers(0, 2**32, (B, 2), dtype=np.uint64),
                       jnp.uint32)
    args = (u, hist, k, kc, cfg_ids, jnp.full((B,), fi, jnp.int32),
            jnp.zeros((B,), jnp.int32), keys,
            jnp.asarray([True, True, False]), bank, eps_c)
    call = functools.partial(
        rf.round_update, sde=sde, state_shape=sde.state_shape(shape),
        kf=kf, fam_index=fi, prec_index=0, with_corrector=corr)
    out_ref = jax.jit(functools.partial(call, impl="ref"))(
        *args, eps_n_c=eps_n_c)
    out_pl = call(*args, eps_n_c=eps_n_c, impl="pallas_interpret",
                  block_d=64)
    p_ref = jax.jit(functools.partial(rf.round_predict, kf=kf, impl="ref"))(
        u, hist, kc, cfg_ids, bank, eps_c)
    p_pl = rf.round_predict(u, hist, kc, cfg_ids, bank, eps_c, kf=kf,
                            impl="pallas_interpret", block_d=64)
    for nm, a, b in list(zip(("u", "hist", "k", "active"),
                             out_ref, out_pl)) + [("u_pred", p_ref, p_pl)]:
        a, b = np.asarray(a), np.asarray(b)
        if kf == 1:
            np.testing.assert_array_equal(
                a, b,
                err_msg=f"{family} q2={q2} corr={corr} sto={sto} {nm}: "
                        "kf=1 must be bitwise")
        else:
            np.testing.assert_allclose(
                a, b, rtol=1e-5, atol=1e-5,
                err_msg=f"{family} {nm}: beyond the kf=2 FMA gap")

"""The PR-4 dense `PackedBank` layer, preserved verbatim as a test oracle.

The production bank is the exact *factored* form
(`repro.core.coeffs.FactoredBank`: a (K, K) block factor times a pooled
(D,) diagonal factor per coefficient row, applied as two contractions).
Its correctness story is differential — factored == dense == family-native,
bit-exact — so the dense builder and the dense bank-mode serve step the
engine used through PR 4 live on here, under tests/, as the comparison
point (tests/test_factored_bank.py, tests/test_coeff_cache.py,
tests/test_properties.py).  Nothing in src/ imports this module; if the
production layer ever drifts from this oracle the differential tier fails,
and a reintroduced dense path fails the perf guard's `bank_bytes` gate.
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.coeffs import (ALGORITHMS, CoeffCache, SamplerConfig,
                               algorithm_coeff_stacks, effective_q)
from repro.kernels.ei_update.ops import apply_packed, pad_channels
from repro.kernels.round_fused.ref import draw_step_noise

Array = jax.Array


def pack_coeff(ops, coeff, data_shape: Tuple[int, ...],
               k_max: int) -> np.ndarray:
    """Embed a family coefficient into the dense canonical (k_max, k_max, D)
    form acting on the packed (B, k, D) slot state (PR-4 layout):

      scalar   c        ->  c at [0, 0, :]            (c * u, k = 1)
      block    M (k,k)  ->  M broadcast over D        (M (x) I_D, k rows)
      freqdiag d        ->  diag over D at [0, 0, :]  (elementwise in the
                            DCT basis the BDM state is resident in)
    """
    D = int(np.prod(data_shape))
    out = np.zeros((k_max, k_max, D), np.float64)
    coeff = np.asarray(coeff, np.float64)
    if ops.family == "scalar":
        out[0, 0, :] = float(coeff)
    elif ops.family == "block":
        k = coeff.shape[-1]
        out[:k, :k, :] = coeff[..., None]
    elif ops.family == "freqdiag":
        out[0, 0, :] = np.broadcast_to(coeff, data_shape).reshape(-1)
    else:
        raise ValueError(f"unknown coeff family {ops.family!r}")
    return out


class DensePackedBank(NamedTuple):
    """The PR-4 dense multi-family bank: every coefficient embedded into
    (k_max, k_max, D) — K*K*D floats per row, the layout `FactoredBank`
    replaced."""
    t_cur: jnp.ndarray
    t_nxt: jnp.ndarray
    psi: jnp.ndarray
    pC: jnp.ndarray
    cC: jnp.ndarray
    B: jnp.ndarray
    P_chol: jnp.ndarray
    n_steps: jnp.ndarray
    stochastic: jnp.ndarray
    corrector: jnp.ndarray
    fam: jnp.ndarray
    alg: jnp.ndarray


def build_dense_bank(cache: CoeffCache) -> DensePackedBank:
    """Stack every registered config of `cache` into the PR-4 dense layout
    (verbatim port of the retired `CoeffCache._build_packed_bank`)."""
    if cache.data_shape is None:
        raise ValueError("dense reference bank needs data_shape=")
    Cb, Nb, Qb = cache._bucket_shapes()
    K = cache.k_max
    D = int(np.prod(cache.data_shape))
    kk = (K, K, D)

    t_cur = np.zeros((Cb, Nb), np.float64)
    t_nxt = np.zeros((Cb, Nb), np.float64)
    psi = np.zeros((Cb, Nb) + kk, np.float64)
    pC = np.zeros((Cb, Nb, Qb) + kk, np.float64)
    cC = np.zeros((Cb, Nb, Qb) + kk, np.float64)
    B = np.zeros((Cb, Nb) + kk, np.float64)
    P_chol = np.zeros((Cb, Nb) + kk, np.float64)
    n_steps = np.ones((Cb,), np.int32)
    stoch = np.zeros((Cb,), bool)
    corr = np.zeros((Cb,), bool)
    fam = np.zeros((Cb,), np.int32)
    alg = np.zeros((Cb,), np.int32)

    for c, cfg in enumerate(cache.configs):
        co = cache.get(cfg)
        name = cache.resolve(cfg)
        ops = cache.sdes[name].ops
        pk = lambda x: pack_coeff(ops, x, cache.data_shape, K)
        coeff_shape = np.shape(np.asarray(ops.eye()))
        # the algorithm axis shares ONE coefficient generator with the
        # production bank (core/coeffs.algorithm_coeff_stacks), so the
        # dense oracle embeds the identical transformed f64 stacks
        pC_a, cC_a, P_a = algorithm_coeff_stacks(co, cfg, coeff_shape)
        N, q = cfg.nfe, effective_q(cfg)
        ts = np.asarray(co.ts)
        t_cur[c, :N] = ts[N - np.arange(N)]
        t_cur[c, N:] = ts[1]
        t_nxt[c, :N] = ts[N - 1 - np.arange(N)]
        t_nxt[c, N:] = ts[0]
        for k in range(N):
            psi[c, k] = pk(np.asarray(co.psi)[k])
            B[c, k] = pk(np.asarray(co.B)[k])
            P_chol[c, k] = pk(P_a[k])
            for j in range(q):
                pC[c, k, j] = pk(pC_a[k, j])
                cC[c, k, j] = pk(cC_a[k, j])
        n_steps[c] = N
        stoch[c] = cfg.lam > 0.0
        corr[c] = cfg.corrector
        fam[c] = cache.fam_index(name)
        alg[c] = ALGORITHMS.index(cfg.algorithm)

    f32 = lambda x: jnp.asarray(x, jnp.float32)
    return DensePackedBank(
        t_cur=f32(t_cur), t_nxt=f32(t_nxt), psi=f32(psi), pC=f32(pC),
        cC=f32(cC), B=f32(B), P_chol=f32(P_chol),
        n_steps=jnp.asarray(n_steps),
        stochastic=jnp.asarray(stoch), corrector=jnp.asarray(corr),
        fam=jnp.asarray(fam), alg=jnp.asarray(alg))


def make_dense_bank_step(spec):
    """The PR-4 bank-mode gDDIM serve step: identical arithmetic to the
    production `make_diffusion_serve_step` bank mode, but gathering dense
    (B, kf, kf, D) coefficient rows and applying them via `apply_packed`'s
    single einsum."""
    sde = spec.sde
    kf = sde.packed_k
    data_shape = tuple(spec.data_shape)
    state_shape = sde.state_shape(data_shape)

    def bank_step(params, u, hist, k, cfg, keys, bank, with_corrector=False):
        K = u.shape[1]
        kc = jnp.clip(jnp.asarray(k), 0, bank.n_steps[cfg] - 1)
        t = bank.t_cur[cfg, kc]
        ub = u[:, :kf]
        gat = lambda leaf: leaf[cfg, kc][:, :kf, :kf, :]
        gatq = lambda leaf, j: leaf[cfg, kc, j][:, :kf, :kf, :]
        pad = lambda z: pad_channels(z, K)

        eps = spec.eps_model(params, sde.decanonicalize(ub, data_shape), t)
        eps_c = sde.canonicalize(eps)
        hist = jnp.concatenate([pad(eps_c)[:, None], hist[:, :-1]], axis=1)
        Qb = hist.shape[1]

        u_lin = apply_packed(gat(bank.psi), ub)
        u_pred = u_lin
        for j in range(Qb):
            u_pred = u_pred + apply_packed(gatq(bank.pC, j),
                                           hist[:, j, :kf])
        noise = draw_step_noise(sde, keys, kc, bank.alg[cfg],
                                state_shape, u.dtype)
        u_sto = u_lin + apply_packed(gat(bank.B), eps_c) \
            + apply_packed(gat(bank.P_chol), sde.canonicalize(noise))
        bmask = lambda m: m.reshape((-1, 1, 1))
        u_next = jnp.where(bmask(bank.stochastic[cfg]), u_sto, u_pred)

        if with_corrector:
            eps_n = spec.eps_model(
                params, sde.decanonicalize(u_pred, data_shape),
                bank.t_nxt[cfg, kc])
            u_corr = u_lin + apply_packed(gatq(bank.cC, 0),
                                          sde.canonicalize(eps_n))
            for j in range(1, Qb):
                u_corr = u_corr + apply_packed(gatq(bank.cC, j),
                                               hist[:, j - 1, :kf])
            use_c = bank.corrector[cfg] & (kc < bank.n_steps[cfg] - 1)
            u_next = jnp.where(bmask(use_c), u_corr, u_next)
        return jnp.concatenate([u_next, u[:, kf:]], axis=1), hist

    return bank_step


NOISE_SALT = 0x5EED          # DiffusionEngine._NOISE_SALT


def dense_reference_sample(spec, params, cache: CoeffCache,
                           bank: DensePackedBank, cfg: SamplerConfig,
                           seed: int, batch: int = 1) -> np.ndarray:
    """One request served by a PR-4 dense-bank 'engine': the exact per-slot
    data flow of `DiffusionEngine` (prior from PRNGKey(seed), noise key
    fold_in(seed, NOISE_SALT), one bank step per round, final projection)
    against the dense bank.  `cfg` must already be registered in `cache`.
    `batch` pads the step to the engine's slot-batch width (row 0 carries
    the request, the rest are dead rows) so the comparison also covers any
    batch-width dependence of the score net."""
    sde = spec.sde
    K = bank.psi.shape[2]
    D = bank.psi.shape[4]
    Qb = bank.pC.shape[2]
    ci = cache.index_of(cfg)
    dshape = tuple(spec.data_shape)

    base = jax.random.PRNGKey(seed)
    prior = jax.jit(lambda key: pad_channels(
        sde.canonicalize(sde.prior_sample(key, 1, dshape)), K))
    u = jnp.zeros((batch, K, D), jnp.float32).at[0].set(prior(base)[0])
    hist = jnp.zeros((batch, Qb, K, D), jnp.float32)
    keys = jnp.broadcast_to(jax.random.fold_in(base, NOISE_SALT),
                            (batch, 2))
    step = jax.jit(make_dense_bank_step(spec),
                   static_argnames=("with_corrector",))
    for k in range(cfg.nfe):
        u, hist = step(params, u, hist,
                       jnp.full((batch,), k, jnp.int32),
                       jnp.full((batch,), ci, jnp.int32), keys, bank,
                       with_corrector=cfg.corrector)
    out = sde.project_data(
        sde.decanonicalize(u[:1, :sde.packed_k], dshape))
    return np.asarray(out[0])

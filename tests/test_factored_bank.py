"""Differential lockdown of the sampler-coefficient layer (the bank tier).

The production bank is the exact *factored* form
(`repro.core.coeffs.FactoredBank`): every structured coefficient a (K, K)
block factor times a pooled (D,) diagonal factor, applied as two
contractions.  Its correctness story is differential, at three levels,
all **bit-exact**:

  1. coefficient level — `apply_factored(*factor_coeff(...))` equals the
     dense `apply_packed(pack_coeff(...))` einsum it replaced *and* the
     family-native `sde.apply`, for arbitrary coefficients of every family;
  2. bank level — `FactoredBank` rows materialize to the PR-4 dense
     `PackedBank` rows (tests/dense_reference.py), and one factored
     bank-mode serve step equals one dense bank step on the same state;
  3. engine level — a mixed VPSDE/CLD/BDM serve on the factored-bank
     engine is bitwise-identical per request to a PR-4 dense-bank engine
     (the lockstep `dense_reference_sample`).

The parametrized classes run everywhere (tier-1); the hypothesis classes
re-run the same checks over arbitrary family x K x data_shape x q x
corrector draws under the profile in tests/conftest.py (the CI
hypothesis job pins the larger derandomized `ci` budget).
"""
import functools
import zlib

import numpy as np
import jax
import jax.numpy as jnp
import pytest

import dense_reference
from repro.core import CoeffCache, SamplerConfig, factor_coeff
from repro.core.coeffs import DIAG_BUCKET_MIN, bucket_size
from repro.kernels.ei_update.ops import (apply_factored, apply_packed,
                                         pad_channels)
from repro.launch.steps import make_diffusion_serve_step
from repro.sde import BDM, CLD, VPSDE

try:
    from hypothesis import given, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

FAMILIES = ["vpsde", "cld", "bdm"]
SHAPES = [(6,), (3, 5), (4, 4, 3), (2, 3, 2, 2)]
DATA_SHAPE = (4, 4, 3)                 # the bank-level shared shape


def make_sde(family, data_shape):
    if family == "vpsde":
        return VPSDE()
    if family == "cld":
        return CLD()
    return BDM(data_shape=tuple(data_shape))


def _raw_coeff(sde, rng):
    """A random coefficient in the family's native structured shape."""
    if sde.ops.family == "scalar":
        return np.float64(rng.standard_normal())
    if sde.ops.family == "block":
        return rng.standard_normal((2, 2))
    return rng.standard_normal(sde.ops.freq_shape)


# ---------------------------------------------------------------------------
# level 1: factored == dense == family-native, per coefficient
# ---------------------------------------------------------------------------
def _check_coeff_differential(family, pad, data_shape, B, seed):
    sde = make_sde(family, data_shape)
    rng = np.random.default_rng(seed)
    coeff = _raw_coeff(sde, rng)
    kf = sde.packed_k
    K = kf + pad
    D = int(np.prod(data_shape))

    u = jnp.asarray(rng.standard_normal(
        (B,) + sde.state_shape(tuple(data_shape))), jnp.float32)
    z = pad_channels(sde.canonicalize(u), K)

    dense = jnp.asarray(
        dense_reference.pack_coeff(sde.ops, coeff, data_shape, K),
        jnp.float32)
    blk64, diag64 = factor_coeff(sde.ops, coeff, data_shape, K)
    blk = jnp.asarray(blk64, jnp.float32)
    diag = jnp.ones((D,), jnp.float32) if diag64 is None \
        else jnp.asarray(diag64, jnp.float32)

    # the factored pair IS the dense embedding
    np.testing.assert_array_equal(
        np.asarray(blk)[..., None] * np.asarray(diag), np.asarray(dense))

    # kernel level: two contractions == one dense einsum, bitwise
    out_dense = apply_packed(jnp.broadcast_to(dense, (B,) + dense.shape), z)
    blk_b = jnp.broadcast_to(blk, (B, K, K))
    diag_b = jnp.broadcast_to(diag, (B, D))
    out_fact = apply_factored(blk_b, diag_b, z, impl="ref")
    np.testing.assert_array_equal(np.asarray(out_fact), np.asarray(out_dense),
                                  err_msg=f"{family}: factored != dense")
    # the Pallas kernel path (interpret mode off-TPU) computes the same op
    out_pallas = apply_factored(blk_b, diag_b, z, impl="pallas_interpret")
    np.testing.assert_allclose(np.asarray(out_pallas), np.asarray(out_fact),
                               rtol=1e-6, atol=1e-6,
                               err_msg=f"{family}: pallas != ref")

    # family-native level: sde.apply_factored vs sde.apply.  Bitwise for
    # scalar/freq-diagonal families; for block (CLD) the native einsum
    # lowers to a dot_general whose FMA contraction differs in the last
    # ulp from the multiply-reduce bank program — a property the dense
    # PR-4 bank had too, so the differential contract there is
    # tight-allclose native + bitwise vs the dense path.
    out_native = sde.apply(jnp.asarray(np.asarray(coeff, np.float32)), u)
    out_fact_native = sde.apply_factored(blk, diag, u)
    if sde.ops.family == "block":
        np.testing.assert_allclose(
            np.asarray(out_fact_native), np.asarray(out_native),
            rtol=1e-6, atol=1e-6,
            err_msg=f"{family}: factored != native sde.apply")
    else:
        np.testing.assert_array_equal(
            np.asarray(out_fact_native), np.asarray(out_native),
            err_msg=f"{family}: factored != native sde.apply")

    if sde.ops.family != "freqdiag":
        # pixel-basis families: the canonical bank path IS the native-basis
        # factored application — bitwise at matching channel width (the
        # serve path always compares same-K programs); with extra padding
        # rows XLA may reassociate the wider reduce, so ulp-tight there
        got = np.asarray(out_fact[:, :kf]).reshape(out_fact_native.shape)
        if pad == 0:
            np.testing.assert_array_equal(got, np.asarray(out_fact_native))
        else:
            np.testing.assert_allclose(got, np.asarray(out_fact_native),
                                       rtol=1e-6, atol=1e-6)


class TestFactoredCoeffDifferential:
    @pytest.mark.parametrize("family", FAMILIES)
    @pytest.mark.parametrize("pad", [0, 1])
    @pytest.mark.parametrize("data_shape", SHAPES)
    def test_factored_equals_dense_equals_native(self, family, pad,
                                                 data_shape):
        # process-stable seed (python's hash() is salted per run)
        seed = zlib.crc32(repr((family, pad, data_shape)).encode()) % 997
        _check_coeff_differential(family, pad, data_shape, B=2, seed=seed)

    def test_zero_freqdiag_collapses_to_zero_block(self):
        sde = make_sde("bdm", DATA_SHAPE)
        blk, diag = factor_coeff(sde.ops, np.zeros(sde.ops.freq_shape),
                                 DATA_SHAPE, 2)
        assert diag is None and not blk.any()


# ---------------------------------------------------------------------------
# level 2: FactoredBank rows / serve step vs the PR-4 dense bank
# ---------------------------------------------------------------------------
@functools.lru_cache(maxsize=1)
def _bank_parts():
    """One multi-family cache (all families, q/corrector/stochastic configs)
    with both its factored bank and the dense oracle bank."""
    cache = CoeffCache({"vpsde": VPSDE(), "cld": CLD(),
                        "bdm": BDM(data_shape=DATA_SHAPE)},
                       data_shape=DATA_SHAPE)
    cfgs = [SamplerConfig(nfe=4),
            SamplerConfig(nfe=5, q=2),
            SamplerConfig(nfe=4, family="cld"),
            SamplerConfig(nfe=4, family="cld", q=2, corrector=True),
            SamplerConfig(nfe=4, family="bdm"),
            SamplerConfig(nfe=4, family="bdm", q=2, corrector=True),
            SamplerConfig(nfe=6, lam=0.7),
            SamplerConfig(nfe=3, family="bdm", lam=0.5),
            # PR-10 algorithm axis: accel widens rows to effective q=2,
            # gmm scales P_chol — both must materialize to the dense
            # oracle's rows (which embed the same transformed stacks)
            SamplerConfig(nfe=4, algorithm="accel"),
            SamplerConfig(nfe=6, lam=0.7, algorithm="gmm"),
            SamplerConfig(nfe=3, family="bdm", lam=0.5, algorithm="gmm"),
            SamplerConfig(nfe=5, family="cld", algorithm="accel")]
    idx = [cache.index_of(c) for c in cfgs]
    return cache, cfgs, idx, cache.factored_bank, \
        dense_reference.build_dense_bank(cache)


class _ToySpec:
    """Minimal DiffusionSpec stand-in: a cheap deterministic eps model so
    the step differential isolates the bank arithmetic."""

    def __init__(self, sde, data_shape):
        self.sde = sde
        self.data_shape = tuple(data_shape)

    def eps_model(self, params, u, t):
        tb = t.reshape((-1,) + (1,) * (u.ndim - 1)).astype(u.dtype)
        return jnp.tanh(u) * (0.5 + tb)


def _family_slots(fam):
    cache, cfgs, idx, _, _ = _bank_parts()
    return [(c, cfg) for c, cfg in zip(idx, cfgs)
            if cache.resolve(cfg) == fam]


def _check_bank_step(fam, with_corrector, B, seed):
    cache, cfgs, idx, fbank, dbank = _bank_parts()
    sde = cache.sdes[fam]
    spec = _ToySpec(sde, DATA_SHAPE)
    step_f = make_diffusion_serve_step(spec)
    step_d = dense_reference.make_dense_bank_step(spec)

    rng = np.random.default_rng(seed)
    K = cache.k_max
    D = int(np.prod(DATA_SHAPE))
    Qb = fbank.pC_blk.shape[2]
    slots = _family_slots(fam)
    rows = [slots[i % len(slots)] for i in range(B)]
    cfg_ids = jnp.asarray([c for c, _ in rows], jnp.int32)
    # mix of in-range and clipped step indices
    k = jnp.asarray(rng.integers(0, 7, B), jnp.int32)
    u = jnp.asarray(rng.standard_normal((B, K, D)), jnp.float32)
    hist = jnp.asarray(rng.standard_normal((B, Qb, K, D)), jnp.float32)
    keys = jnp.asarray(rng.integers(0, 2**32, (B, 2), dtype=np.uint64),
                       jnp.uint32)

    uf, hf = step_f(None, u, hist, k, cfg_ids, keys, fbank,
                    with_corrector=with_corrector)
    ud, hd = step_d(None, u, hist, k, cfg_ids, keys, dbank,
                    with_corrector=with_corrector)
    np.testing.assert_array_equal(np.asarray(uf), np.asarray(ud),
                                  err_msg=f"{fam}: factored step != dense")
    np.testing.assert_array_equal(np.asarray(hf), np.asarray(hd))


class TestFactoredBankDifferential:
    def test_bank_rows_materialize_to_dense_rows(self):
        cache, cfgs, idx, fbank, dbank = _bank_parts()
        from repro.core.coeffs import effective_q
        for c, cfg in zip(idx, cfgs):
            N, q = cfg.nfe, effective_q(cfg)
            assert int(fbank.n_steps[c]) == int(dbank.n_steps[c]) == N
            assert bool(fbank.stochastic[c]) == bool(dbank.stochastic[c])
            assert bool(fbank.corrector[c]) == bool(dbank.corrector[c])
            assert int(fbank.fam[c]) == int(dbank.fam[c])
            assert int(fbank.alg[c]) == int(dbank.alg[c])
            for k in range(N):
                np.testing.assert_array_equal(
                    fbank.materialize("psi", c, k), np.asarray(dbank.psi[c, k]))
                for j in range(q):
                    np.testing.assert_array_equal(
                        fbank.materialize("pC", c, k, j),
                        np.asarray(dbank.pC[c, k, j]))
                    np.testing.assert_array_equal(
                        fbank.materialize("cC", c, k, j),
                        np.asarray(dbank.cC[c, k, j]))
                if cfg.lam > 0.0:
                    np.testing.assert_array_equal(
                        fbank.materialize("B", c, k),
                        np.asarray(dbank.B[c, k]))
                    np.testing.assert_array_equal(
                        fbank.materialize("P_chol", c, k),
                        np.asarray(dbank.P_chol[c, k]))
                else:
                    # deterministic configs store zero B/P factors: the
                    # Eq. 22 branch is masked off (observationally exact)
                    assert not fbank.materialize("B", c, k).any()
                    assert not fbank.materialize("P_chol", c, k).any()
        np.testing.assert_array_equal(np.asarray(fbank.t_cur),
                                      np.asarray(dbank.t_cur))
        np.testing.assert_array_equal(np.asarray(fbank.t_nxt),
                                      np.asarray(dbank.t_nxt))

    def test_diag_pool_is_deduplicated(self):
        """Scalar/block rows all share pool row 0 (ones); only freqdiag
        rows occupy real slots, so the pool stays far below the dense
        row-slot count and the bank wins ~D-fold."""
        cache, cfgs, idx, fbank, dbank = _bank_parts()
        np.testing.assert_array_equal(np.asarray(fbank.diag[0]), 1.0)
        bdm_rows = sum(cfg.nfe * (1 + 2 * cfg.q) + 2 * cfg.nfe * (cfg.lam > 0)
                       for cfg in cfgs if cache.resolve(cfg) == "bdm")
        assert fbank.diag.shape[0] == bucket_size(
            len(cache._pool), DIAG_BUCKET_MIN)
        assert len(cache._pool) <= 1 + bdm_rows
        # non-BDM index leaves all point at the shared ones row
        for c, cfg in zip(idx, cfgs):
            if cache.resolve(cfg) != "bdm":
                assert not np.asarray(fbank.psi_di[c]).any()
        assert fbank.nbytes * 10 < fbank.dense_equiv_nbytes

    @pytest.mark.parametrize("fam", FAMILIES)
    @pytest.mark.parametrize("with_corrector", [False, True])
    def test_bank_step_matches_dense_step(self, fam, with_corrector):
        seed = zlib.crc32(repr((fam, with_corrector)).encode()) % 997
        _check_bank_step(fam, with_corrector, B=3, seed=seed)


# ---------------------------------------------------------------------------
# level 3: the factored-bank engine == a PR-4 dense-bank engine, end to end
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def family_parts():
    from repro.configs import get_diffusion
    specs, params = {}, {}
    for i, (fam, name) in enumerate((("vpsde", "cifar10-ddpm"),
                                     ("cld", "cifar10-cld"),
                                     ("bdm", "cifar10-bdm"))):
        specs[fam] = get_diffusion(name, reduced=True)
        params[fam] = specs[fam].init(jax.random.PRNGKey(100 + i))
    return specs, params


def test_mixed_family_serve_bitwise_equals_dense_reference(family_parts):
    """End to end: a mixed VPSDE/CLD/BDM serve (staggered admission,
    co-residency, q=2 multistep, corrector, stochastic lambda) through the
    factored-bank engine must reproduce, bitwise per request, what the
    PR-4 dense-bank engine computed (the lockstep dense reference)."""
    from repro.serve import DiffusionEngine, SampleRequest
    specs, params = family_parts
    reqs = [SampleRequest(rid=0, seed=0),                          # vpsde
            SampleRequest(rid=1, seed=1, family="cld", nfe=5),
            SampleRequest(rid=2, seed=2, family="bdm", nfe=4),
            SampleRequest(rid=3, seed=3, family="cld", nfe=6, q=2,
                          corrector=True),
            SampleRequest(rid=4, seed=4, family="vpsde", nfe=8, lam=0.5),
            SampleRequest(rid=5, seed=5, family="bdm", nfe=3, lam=0.5),
            SampleRequest(rid=6, seed=6, algorithm="accel"),
            SampleRequest(rid=7, seed=7, nfe=8, lam=0.5, algorithm="gmm")]
    engine = DiffusionEngine(specs, params, batch_size=2, nfe=6)
    out = engine.serve(reqs)
    assert set(out) == {r.rid for r in reqs}

    dbank = dense_reference.build_dense_bank(engine.cache)
    for r in reqs:
        cfg = engine.config_of(r)
        ref = dense_reference.dense_reference_sample(
            specs[cfg.family], params[cfg.family], engine.cache, dbank,
            cfg, r.seed, batch=engine.batch_size)
        np.testing.assert_array_equal(
            out[r.rid], ref,
            err_msg=f"rid {r.rid} ({cfg.family}): factored engine != "
                    "PR-4 dense-bank reference")


# ---------------------------------------------------------------------------
# hypothesis tier: same checks over arbitrary draws (CI pins profile `ci`)
# ---------------------------------------------------------------------------
if not HAVE_HYPOTHESIS:
    def test_hypothesis_tier_skipped():
        pytest.skip("hypothesis not installed (optional dev dependency, "
                    "see requirements-dev.txt); the differential tier "
                    "still ran via the parametrized classes above")
else:
    shapes_st = st.lists(st.integers(min_value=1, max_value=5),
                         min_size=1, max_size=4).map(tuple)

    # settings (budget, deadline, health checks) come entirely from the
    # active profile registered in tests/conftest.py
    class TestHypothesisCoeffDifferential:
        @given(family=st.sampled_from(FAMILIES),
               pad=st.integers(min_value=0, max_value=2),
               data_shape=shapes_st,
               B=st.integers(min_value=1, max_value=3),
               seed=st.integers(min_value=0, max_value=2**30))
        def test_factored_equals_dense_equals_native(self, family, pad,
                                                     data_shape, B, seed):
            _check_coeff_differential(family, pad, data_shape, B, seed)

    class TestHypothesisBankStepDifferential:
        @given(fam=st.sampled_from(FAMILIES),
               with_corrector=st.booleans(),
               B=st.integers(min_value=1, max_value=4),
               seed=st.integers(min_value=0, max_value=2**30))
        def test_bank_step_matches_dense_step(self, fam, with_corrector,
                                              B, seed):
            _check_bank_step(fam, with_corrector, B, seed)

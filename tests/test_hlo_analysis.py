"""The roofline instrument itself: trip-aware HLO stats must be exact on
controlled programs (XLA's own cost_analysis counts while bodies once —
verified here — which is why hlo_program_stats exists)."""
import jax
import jax.numpy as jnp
import pytest

from repro.launch import hlo_analysis as ha


def _scan10(x, ws):
    def body(c, w):
        return jnp.tanh(c @ w), None
    y, _ = jax.lax.scan(body, x, ws)
    return y


X = jax.ShapeDtypeStruct((128, 256), jnp.float32)
W10 = jax.ShapeDtypeStruct((10, 256, 256), jnp.float32)
FWD_FLOPS = 10 * 2 * 128 * 256 * 256


def test_xla_cost_analysis_misses_trip_counts():
    c = jax.jit(_scan10).lower(X, W10).compile()
    ca = c.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    assert ca["flops"] < FWD_FLOPS / 5          # counts ~1 of 10 trips


def test_program_stats_forward_exact():
    c = jax.jit(_scan10).lower(X, W10).compile()
    s = ha.hlo_program_stats(c.as_text())
    assert s["flops"] == pytest.approx(FWD_FLOPS, rel=1e-6)
    # traffic: per trip ~ read w slice + read/write x few times; must be
    # within 3x of the 13 MB hand count and far from the 37 MB naive count
    assert 8e6 < s["bytes"] < 3e7


def test_program_stats_backward_3x():
    def loss(x, ws):
        return _scan10(x, ws).sum()
    c = jax.jit(jax.grad(loss, argnums=1)).lower(X, W10).compile()
    s = ha.hlo_program_stats(c.as_text())
    assert s["flops"] == pytest.approx(3 * FWD_FLOPS, rel=1e-6)


def test_plain_matmul_exact():
    a = jax.ShapeDtypeStruct((1024, 1024), jnp.float32)
    c = jax.jit(lambda a, b: a @ b).lower(a, a).compile()
    s = ha.hlo_program_stats(c.as_text())
    assert s["flops"] == pytest.approx(2 * 1024**3, rel=1e-6)
    assert s["bytes"] == pytest.approx(3 * 1024 * 1024 * 4, rel=1e-6)


def test_collective_parse_synthetic():
    """Byte conventions on hand-written HLO (no multi-device needed)."""
    hlo = """
HloModule test

%wide.body (arg: (s32[], f32[16,128])) -> (s32[], f32[16,128]) {
  %arg = (s32[], f32[16,128]{1,0}) parameter(0)
  %gte = f32[16,128]{1,0} get-tuple-element(%arg), index=1
  %ag = f32[64,128]{1,0} all-gather(%gte), replica_groups={{0,1,2,3}}, dimensions={0}
  %ar = f32[16,128]{1,0} all-reduce(%gte), replica_groups={{0,1,2,3}}, to_apply=%sum
  %i = s32[] get-tuple-element(%arg), index=0
  ROOT %t = (s32[], f32[16,128]{1,0}) tuple(%i, %ar)
}

%wide.cond (arg: (s32[], f32[16,128])) -> pred[] {
  %arg = (s32[], f32[16,128]{1,0}) parameter(0)
  %i = s32[] get-tuple-element(%arg), index=0
  %c = s32[] constant(5)
  ROOT %lt = pred[] compare(%i, %c), direction=LT
}

ENTRY %main (x: f32[16,128]) -> f32[16,128] {
  %x = f32[16,128]{1,0} parameter(0)
  %c0 = s32[] constant(0)
  %t0 = (s32[], f32[16,128]{1,0}) tuple(%c0, %x)
  %w = (s32[], f32[16,128]{1,0}) while(%t0), condition=%wide.cond, body=%wide.body
  %rs = f32[4,128]{1,0} reduce-scatter(%x), replica_groups={{0,1,2,3}}, dimensions={0}, to_apply=%sum
  ROOT %out = f32[16,128]{1,0} get-tuple-element(%w), index=1
}
"""
    s = ha.hlo_program_stats(hlo)
    f32 = 4
    ag = 64 * 128 * f32 * 5                     # result bytes x 5 trips
    ar = 16 * 128 * f32 * 2 * 5                 # 2x result x trips
    rs = 4 * 128 * f32 * 4                      # result x group size
    assert s["collectives"]["all-gather"] == ag
    assert s["collectives"]["all-reduce"] == ar
    assert s["collectives"]["reduce-scatter"] == rs


def test_roofline_terms_bottleneck():
    r = ha.roofline_terms(197e12, 0.0, 0.0)
    assert r["bottleneck"] == "compute" and r["t_compute_s"] == pytest.approx(1.0)
    r = ha.roofline_terms(0.0, 819e9, 100e9)
    assert r["bottleneck"] == "collective"

"""Continuous-batching engine: slot isolation, retire-and-refill compile
stability, batched prefill, and the gDDIM sampling service.

The load-bearing property is *slot isolation*: a request's output stream
must be token-for-token (bitwise) identical whether it runs alone or
interleaved with arbitrary neighbours.  This is the regression test for the
two bugs in the old demo loop — `_merge_slot` accepting the new cache
wholesale (prefilling one slot clobbered every other slot's KV rows) and
`pos` computed as a max over slots (a refilled slot decoded at another
request's position).  Covered for a KV-cache arch (gemma3: GQA + sliding
window) and a recurrent-state arch (rwkv6), plus the diffusion service.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_arch, get_diffusion
from repro.models.registry import Arch
from repro.serve import DiffusionEngine, Request, SampleRequest, TokenEngine

MAX_LEN = 48


def _arch_and_params(name):
    spec = get_arch(name, reduced=True)
    arch = Arch(spec)
    params = arch.init(jax.random.PRNGKey(0))
    return arch, params


def _requests(vocab, lens, max_news, seed=0):
    rng = np.random.default_rng(seed)
    return [Request(rid=i,
                    tokens=rng.integers(2, vocab, size=L).astype(np.int32),
                    max_new=m)
            for i, (L, m) in enumerate(zip(lens, max_news))]


# ---------------------------------------------------------------------------
# slot isolation: interleaved == solo, bitwise
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("name", ["gemma3-1b", "rwkv6-7b"])
def test_slot_isolation_interleaved_equals_solo(name):
    arch, params = _arch_and_params(name)
    B = 3
    # mixed prompt lengths (separate prefill groups => staggered admission)
    # and mixed budgets (staggered retirement => refills land next to
    # mid-flight neighbours at different absolute positions)
    reqs = _requests(arch.cfg.vocab, lens=[6, 6, 9, 9, 6], max_news=[7, 4, 6, 3, 5])

    engine = TokenEngine(arch, params, batch_size=B, max_len=MAX_LEN)
    interleaved = engine.serve(reqs)
    assert set(interleaved) == {r.rid for r in reqs}
    # engine actually interleaved: more requests than slots, single decode jit
    assert engine.n_decode_steps < sum(r.max_new - 1 for r in reqs)

    for r in reqs:
        solo = TokenEngine(arch, params, batch_size=B,
                           max_len=MAX_LEN).serve([r])
        np.testing.assert_array_equal(
            interleaved[r.rid], solo[r.rid],
            err_msg=f"{name}: request {r.rid} output depends on neighbours")


# ---------------------------------------------------------------------------
# retire-and-refill reuses the warmed compiles
# ---------------------------------------------------------------------------
def test_retire_refill_no_recompile():
    arch, params = _arch_and_params("gemma3-1b")
    engine = TokenEngine(arch, params, batch_size=2, max_len=MAX_LEN)
    reqs = _requests(arch.cfg.vocab, lens=[8] * 8, max_news=[5] * 8)

    engine.serve(reqs[:2])                       # warmup: prefill + decode
    warm = engine.compile_stats()
    assert warm["decode"] == 1 and warm["prefill"] == 1

    engine.serve(reqs[2:])                       # 3 retire-and-refill waves
    assert engine.compile_stats() == warm, \
        "retire-and-refill must not trigger recompilation after warmup"


def test_prefill_is_batched():
    """A same-length admission group runs ONE prefill forward (the old loop
    fed prompt tokens one at a time through the decode step)."""
    arch, params = _arch_and_params("rwkv6-7b")
    engine = TokenEngine(arch, params, batch_size=4, max_len=MAX_LEN)
    reqs = _requests(arch.cfg.vocab, lens=[10] * 4, max_news=[4] * 4)
    engine.serve(reqs)
    assert engine.n_prefill_calls == 1


# ---------------------------------------------------------------------------
# gDDIM sampling service
# ---------------------------------------------------------------------------
def test_diffusion_engine_isolation_and_reference():
    spec = get_diffusion("cifar10-ddpm", reduced=True)
    params = spec.init(jax.random.PRNGKey(0))
    nfe, B = 6, 2
    reqs = [SampleRequest(rid=i, seed=i) for i in range(3)]

    engine = DiffusionEngine(spec, params, batch_size=B, nfe=nfe)
    batched = engine.serve(reqs)
    assert engine.compile_stats()["step"] == 1

    # solo == interleaved, bitwise
    for r in reqs:
        solo = DiffusionEngine(spec, params, batch_size=B, nfe=nfe).serve([r])
        np.testing.assert_array_equal(batched[r.rid], solo[r.rid])

    # matches the lockstep reference sampler (sample_gddim, q=1) — the
    # continuous-batching service computes the same gDDIM update
    from repro.core import sample_gddim
    for r in reqs:
        uT = spec.sde.prior_sample(jax.random.PRNGKey(r.seed), 1,
                                   tuple(spec.data_shape))
        eps_fn = spec.make_eps_fn(params, np.asarray(engine.coeffs.ts))
        ref = spec.sde.project_data(
            sample_gddim(spec.sde, engine.coeffs, eps_fn, uT, q=1))
        np.testing.assert_allclose(batched[r.rid], np.asarray(ref[0]),
                                   rtol=1e-5, atol=1e-5)


def test_diffusion_engine_staggered_step_indices():
    """Slots at different sampler step indices k in the same batch: admit a
    second request mid-flight and check both still match their solo runs."""
    spec = get_diffusion("cifar10-ddpm", reduced=True)
    params = spec.init(jax.random.PRNGKey(0))
    nfe, B = 6, 2

    engine = DiffusionEngine(spec, params, batch_size=B, nfe=nfe)
    results = {}
    engine.scheduler.submit(SampleRequest(rid=0, seed=0))
    engine._admit()
    for _ in range(3):                          # slot 0 advances to k=3
        engine._step_round(results)
    engine.scheduler.submit(SampleRequest(rid=1, seed=1))
    engine._admit()                             # slot 1 enters at k=0
    ks = sorted(s.data["k"] for s in engine.slots.active())
    assert ks == [0, 3], ks
    while engine.slots.active_ids():
        engine._step_round(results)

    for rid, seed in ((0, 0), (1, 1)):
        solo = DiffusionEngine(spec, params, batch_size=B,
                               nfe=nfe).serve([SampleRequest(rid=rid,
                                                             seed=seed)])
        np.testing.assert_array_equal(results[rid], solo[rid])

"""Continuous-batching engine: slot isolation, retire-and-refill compile
stability, batched (width-bucketed) prefill, the device-resident round loop,
and the gDDIM sampling service.

The load-bearing property is *slot isolation*: a request's output stream
must be token-for-token (bitwise) identical whether it runs alone or
interleaved with arbitrary neighbours.  This is the regression test for the
two bugs in the old demo loop — `_merge_slot` accepting the new cache
wholesale (prefilling one slot clobbered every other slot's KV rows) and
`pos` computed as a max over slots (a refilled slot decoded at another
request's position).  Covered for a KV-cache arch (gemma3: GQA + sliding
window) and a recurrent-state arch (rwkv6), plus the diffusion service —
where isolation extends to the *sampler config*: a request's sample may not
depend on the NFE/q/corrector/lambda of its neighbours, and serving a new
config after warmup may not recompile (the coefficient bank is a bucketed
argument of the step, see repro.core.coeffs.CoeffCache).

Since the `EngineState` refactor the loop itself is a property under test:
the steady-state round must move *no* per-slot metadata host->device (the
state lives on device and is updated inside the donated round step), which
`test_steady_state_rounds_are_transfer_free` locks in with a
`jax.transfer_guard`.  The mesh-sharded counterparts of these properties
live in tests/test_serve_mesh.py.
"""
import numpy as np
import jax
import pytest

from repro.configs import get_arch, get_diffusion
from repro.models.registry import Arch
from repro.serve import (DiffusionEngine, Request, SampleRequest, Scheduler,
                         SlotTable, TokenEngine)

MAX_LEN = 48


def _arch_and_params(name):
    spec = get_arch(name, reduced=True)
    arch = Arch(spec)
    params = arch.init(jax.random.PRNGKey(0))
    return arch, params


def _requests(vocab, lens, max_news, seed=0):
    rng = np.random.default_rng(seed)
    return [Request(rid=i,
                    tokens=rng.integers(2, vocab, size=L).astype(np.int32),
                    max_new=m)
            for i, (L, m) in enumerate(zip(lens, max_news))]


# ---------------------------------------------------------------------------
# slot isolation: interleaved == solo, bitwise
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("name", ["gemma3-1b", "rwkv6-7b"])
def test_slot_isolation_interleaved_equals_solo(name):
    arch, params = _arch_and_params(name)
    B = 3
    # mixed prompt lengths (separate prefill groups => staggered admission)
    # and mixed budgets (staggered retirement => refills land next to
    # mid-flight neighbours at different absolute positions)
    reqs = _requests(arch.cfg.vocab, lens=[6, 6, 9, 9, 6], max_news=[7, 4, 6, 3, 5])

    engine = TokenEngine(arch, params, batch_size=B, max_len=MAX_LEN)
    interleaved = engine.serve(reqs)
    assert set(interleaved) == {r.rid for r in reqs}
    # engine actually interleaved: more requests than slots, single decode jit
    assert engine.n_decode_steps < sum(r.max_new - 1 for r in reqs)

    for r in reqs:
        solo = TokenEngine(arch, params, batch_size=B,
                           max_len=MAX_LEN).serve([r])
        np.testing.assert_array_equal(
            interleaved[r.rid], solo[r.rid],
            err_msg=f"{name}: request {r.rid} output depends on neighbours")


def test_single_token_request_retires_at_admission():
    """max_new=1 is satisfied by the prefill token alone: the slot is born
    inactive on device and the first poll retires it without a decode."""
    arch, params = _arch_and_params("gemma3-1b")
    engine = TokenEngine(arch, params, batch_size=2, max_len=MAX_LEN)
    reqs = _requests(arch.cfg.vocab, lens=[8, 8], max_news=[1, 3])
    results = engine.serve(reqs)
    assert len(results[0]) == 1
    assert len(results[1]) == 3
    solo = TokenEngine(arch, params, batch_size=2, max_len=MAX_LEN).serve(
        [reqs[0]])
    np.testing.assert_array_equal(results[0], solo[0])


# ---------------------------------------------------------------------------
# retire-and-refill reuses the warmed compiles
# ---------------------------------------------------------------------------
def test_retire_refill_no_recompile():
    arch, params = _arch_and_params("gemma3-1b")
    engine = TokenEngine(arch, params, batch_size=2, max_len=MAX_LEN)
    reqs = _requests(arch.cfg.vocab, lens=[8] * 8, max_news=[5] * 8)

    engine.serve(reqs[:2])                       # warmup: prefill + decode
    warm = engine.compile_stats()
    assert warm["decode"] == 1 and warm["prefill"] == 1

    engine.serve(reqs[2:])                       # 3 retire-and-refill waves
    assert engine.compile_stats() == warm, \
        "retire-and-refill must not trigger recompilation after warmup"


def test_prefill_is_batched():
    """A same-length admission group runs ONE prefill forward (the old loop
    fed prompt tokens one at a time through the decode step)."""
    arch, params = _arch_and_params("rwkv6-7b")
    engine = TokenEngine(arch, params, batch_size=4, max_len=MAX_LEN)
    reqs = _requests(arch.cfg.vocab, lens=[10] * 4, max_news=[4] * 4)
    engine.serve(reqs)
    assert engine.n_prefill_calls == 1
    assert list(engine.prefill_widths) == [4]


def test_prefill_width_bucketed():
    """Prefill width is the admission wave's power-of-two bucket, not the
    full batch: a 3-request wave on an 8-slot engine pays 4 rows of FLOPs,
    a 1-request refill pays 1 — small waves stop paying full-batch cost."""
    arch, params = _arch_and_params("gemma3-1b")
    engine = TokenEngine(arch, params, batch_size=8, max_len=MAX_LEN)
    reqs = _requests(arch.cfg.vocab, lens=[8] * 3, max_news=[3] * 3)
    engine.serve(reqs)
    assert list(engine.prefill_widths) == [4]
    engine.serve(_requests(arch.cfg.vocab, lens=[8], max_news=[3], seed=1))
    assert list(engine.prefill_widths) == [4, 1]


# ---------------------------------------------------------------------------
# the steady-state loop is device-resident
# ---------------------------------------------------------------------------
def test_steady_state_rounds_are_transfer_free():
    """After warmup, a serving round moves NOTHING host->device: slot
    metadata (positions, step indices, active masks, PRNG keys) lives in
    the donated EngineState and is updated inside the jitted step.  The
    transfer guard turns any host->device transfer into an error."""
    # diffusion engine
    spec = get_diffusion("cifar10-ddpm", reduced=True)
    params = spec.init(jax.random.PRNGKey(0))
    eng = DiffusionEngine(spec, params, batch_size=2, nfe=8)
    eng.scheduler.submit_all([SampleRequest(rid=0, seed=0),
                              SampleRequest(rid=1, seed=1)])
    eng._admit()
    eng._round()                                   # warm the round program
    with jax.transfer_guard_host_to_device("disallow"):
        for _ in range(3):
            eng._round()
    results = {}
    while eng.slots.active_ids():
        eng._round()
        eng._poll(results)
    assert sorted(results) == [0, 1]

    # token engine
    arch, aparams = _arch_and_params("gemma3-1b")
    t = TokenEngine(arch, aparams, batch_size=2, max_len=MAX_LEN)
    t.scheduler.submit_all(_requests(arch.cfg.vocab, lens=[8, 8],
                                     max_news=[16, 16]))
    t._admit()
    t._round()                                     # warm
    with jax.transfer_guard_host_to_device("disallow"):
        for _ in range(5):
            t._round()
    results = {}
    while t.slots.active_ids():
        t._round()
        t._poll(results)
    assert sorted(results) == [0, 1]


def test_poll_cadence_bounded_by_sync_every():
    """The host polls at most every `sync_every` rounds, and exactly at the
    predicted retirement when the bound is tight (diffusion progress is
    exactly predictable): an NFE-8 batch at sync_every=4 costs 2 polls."""
    spec = get_diffusion("cifar10-ddpm", reduced=True)
    params = spec.init(jax.random.PRNGKey(0))
    eng = DiffusionEngine(spec, params, batch_size=2, nfe=8, sync_every=4)
    eng.serve([SampleRequest(rid=0, seed=0), SampleRequest(rid=1, seed=1)])
    assert eng.n_steps == 8
    assert eng.n_polls == 2


# ---------------------------------------------------------------------------
# gDDIM sampling service
# ---------------------------------------------------------------------------
def test_diffusion_engine_isolation_and_reference():
    spec = get_diffusion("cifar10-ddpm", reduced=True)
    params = spec.init(jax.random.PRNGKey(0))
    nfe, B = 6, 2
    reqs = [SampleRequest(rid=i, seed=i) for i in range(3)]

    engine = DiffusionEngine(spec, params, batch_size=B, nfe=nfe)
    batched = engine.serve(reqs)
    assert engine.compile_stats()["step"] == 1

    # solo == interleaved, bitwise
    for r in reqs:
        solo = DiffusionEngine(spec, params, batch_size=B, nfe=nfe).serve([r])
        np.testing.assert_array_equal(batched[r.rid], solo[r.rid])

    # matches the lockstep reference sampler (sample_gddim, q=1) — the
    # continuous-batching service computes the same gDDIM update
    from repro.core import sample_gddim
    for r in reqs:
        uT = spec.sde.prior_sample(jax.random.PRNGKey(r.seed), 1,
                                   tuple(spec.data_shape))
        eps_fn = spec.make_eps_fn(params, np.asarray(engine.coeffs.ts))
        ref = spec.sde.project_data(
            sample_gddim(spec.sde, engine.coeffs, eps_fn, uT, q=1))
        np.testing.assert_allclose(batched[r.rid], np.asarray(ref[0]),
                                   rtol=1e-5, atol=1e-5)


def test_diffusion_engine_mixed_configs_bitwise_and_reference():
    """One engine, one batch, >= 3 sampler configs (different NFE / q /
    corrector, plus a stochastic lambda): every request's output must be
    bitwise identical to a solo-engine run of that config, and the
    deterministic configs must match the lockstep reference sampler."""
    spec = get_diffusion("cifar10-ddpm", reduced=True)
    params = spec.init(jax.random.PRNGKey(0))
    B = 2
    reqs = [SampleRequest(rid=0, seed=0),                        # default 6
            SampleRequest(rid=1, seed=1, nfe=4),                 # preview
            SampleRequest(rid=2, seed=2, nfe=5, q=2, corrector=True),
            SampleRequest(rid=3, seed=3, nfe=8, lam=0.5)]        # stochastic

    engine = DiffusionEngine(spec, params, batch_size=B, nfe=6)
    mixed = engine.serve(reqs)
    assert set(mixed) == {r.rid for r in reqs}
    assert len(engine.cache) == 4

    # bitwise solo == mixed, per config
    for r in reqs:
        solo = DiffusionEngine(spec, params, batch_size=B, nfe=6).serve([r])
        np.testing.assert_array_equal(
            mixed[r.rid], solo[r.rid],
            err_msg=f"request {r.rid} output depends on neighbour configs")

    # deterministic configs match the lockstep Stage-II reference
    from repro.core import sample_gddim
    for r in reqs[:3]:
        cfg = engine.config_of(r)
        co = engine.cache.get(cfg)
        uT = spec.sde.prior_sample(jax.random.PRNGKey(r.seed), 1,
                                   tuple(spec.data_shape))
        eps_fn = spec.make_eps_fn(params, np.asarray(co.ts))
        ref = spec.sde.project_data(sample_gddim(
            spec.sde, co, eps_fn, uT, q=cfg.q, corrector=cfg.corrector))
        np.testing.assert_allclose(mixed[r.rid], np.asarray(ref[0]),
                                   rtol=1e-4, atol=1e-4)


def test_diffusion_engine_zero_recompiles_across_nfe():
    """After warmup, serving new NFE values (and re-serving old ones) must
    not recompile: the coefficient bank is an argument of the jitted step,
    and every NFE inside the warmed bucket shares its padded shapes."""
    spec = get_diffusion("cifar10-ddpm", reduced=True)
    params = spec.init(jax.random.PRNGKey(0))
    engine = DiffusionEngine(spec, params, batch_size=2, nfe=6)

    engine.serve([SampleRequest(rid=0, seed=0)])          # warmup
    warm = engine.compile_stats()
    assert warm["step"] == 1

    # three NFE values the engine has never seen, all within the N bucket
    engine.serve([SampleRequest(rid=1, seed=1, nfe=4),
                  SampleRequest(rid=2, seed=2, nfe=5),
                  SampleRequest(rid=3, seed=3, nfe=8)])
    assert engine.compile_stats() == warm, \
        "new NFE values inside the warmed bucket must not recompile"
    assert len(engine.cache) == 4


def test_diffusion_engine_staggered_step_indices():
    """Slots at different sampler step indices k in the same batch: admit a
    second request mid-flight and check both still match their solo runs."""
    spec = get_diffusion("cifar10-ddpm", reduced=True)
    params = spec.init(jax.random.PRNGKey(0))
    nfe, B = 6, 2

    engine = DiffusionEngine(spec, params, batch_size=B, nfe=nfe)
    results = {}
    engine.scheduler.submit(SampleRequest(rid=0, seed=0))
    engine._admit()
    for _ in range(3):                          # slot 0 advances to k=3
        engine._round()
    engine.scheduler.submit(SampleRequest(rid=1, seed=1))
    engine._admit()                             # slot 1 enters at k=0
    ks = sorted(s.data["k"] for s in engine.slots.active())
    assert ks == [0, 3], ks
    while engine.slots.active_ids():
        engine._round()
        engine._poll(results)

    for rid, seed in ((0, 0), (1, 1)):
        solo = DiffusionEngine(spec, params, batch_size=B,
                               nfe=nfe).serve([SampleRequest(rid=rid,
                                                             seed=seed)])
        np.testing.assert_array_equal(results[rid], solo[rid])


# ---------------------------------------------------------------------------
# multi-family serving: VPSDE + CLD + BDM on ONE engine (ISSUE 4 tentpole)
# ---------------------------------------------------------------------------
FAMILY_CONFIGS = {"vpsde": "cifar10-ddpm", "cld": "cifar10-cld",
                  "bdm": "cifar10-bdm"}


@pytest.fixture(scope="module")
def family_parts():
    """Reduced specs + params for all three SDE families (shared across the
    multi-family tests; params differ per family like real deployments)."""
    specs, params = {}, {}
    for i, (fam, name) in enumerate(FAMILY_CONFIGS.items()):
        specs[fam] = get_diffusion(name, reduced=True)
        params[fam] = specs[fam].init(jax.random.PRNGKey(100 + i))
    return specs, params


def _solo_request(r):
    """The same request without the family tag (for a single-family solo
    engine of that family)."""
    import dataclasses
    return dataclasses.replace(r, family=None)


def test_multi_family_mixed_bitwise_equals_solo(family_parts):
    """One engine, one slot pool, requests across all three SDE families
    (plus corrector / stochastic variants): every request's sample must be
    bitwise identical to a solo single-family engine of its family."""
    specs, params = family_parts
    reqs = [SampleRequest(rid=0, seed=0),                          # vpsde
            SampleRequest(rid=1, seed=1, family="cld", nfe=5),
            SampleRequest(rid=2, seed=2, family="bdm", nfe=4),
            SampleRequest(rid=3, seed=3, family="cld", nfe=6, q=2,
                          corrector=True),
            SampleRequest(rid=4, seed=4, family="vpsde", nfe=8, lam=0.5)]
    engine = DiffusionEngine(specs, params, batch_size=2, nfe=6)
    assert engine.families == ["vpsde", "cld", "bdm"]
    mixed = engine.serve(reqs)
    assert set(mixed) == {r.rid for r in reqs}

    for r in reqs:
        fam = r.family or "vpsde"
        solo = DiffusionEngine(specs[fam], params[fam], batch_size=2, nfe=6)
        out = solo.serve([_solo_request(r)])
        np.testing.assert_array_equal(
            mixed[r.rid], out[r.rid],
            err_msg=f"rid {r.rid} ({fam}): mixed-family engine != solo "
                    f"single-family engine")


def test_multi_family_matches_lockstep_reference(family_parts):
    """Deterministic configs of every family must match the lockstep
    Stage-II reference sampler (sample_gddim over the family-native coeff
    shapes) — the packed canonical path computes the same update."""
    from repro.core import sample_gddim
    specs, params = family_parts
    reqs = [SampleRequest(rid=0, seed=0, nfe=6),                   # vpsde
            SampleRequest(rid=1, seed=1, family="cld", nfe=5),
            SampleRequest(rid=2, seed=2, family="bdm", nfe=4),
            SampleRequest(rid=3, seed=3, family="cld", nfe=6, q=2,
                          corrector=True)]
    engine = DiffusionEngine(specs, params, batch_size=2, nfe=6)
    mixed = engine.serve(reqs)
    for r in reqs:
        fam = r.family or "vpsde"
        spec = specs[fam]
        cfg = engine.config_of(r)
        co = engine.cache.get(cfg)
        uT = spec.sde.prior_sample(jax.random.PRNGKey(r.seed), 1,
                                   tuple(spec.data_shape))
        eps_fn = spec.make_eps_fn(params[fam], np.asarray(co.ts))
        ref = spec.sde.project_data(sample_gddim(
            spec.sde, co, eps_fn, uT, q=cfg.q, corrector=cfg.corrector))
        # BDM's engine path is frequency-resident (one dct/idct pair per
        # model eval instead of per apply), so agreement is to f32
        # round-trip accuracy rather than bitwise
        np.testing.assert_allclose(mixed[r.rid], np.asarray(ref[0]),
                                   rtol=5e-4, atol=5e-4,
                                   err_msg=f"rid {r.rid} ({fam})")


def test_multi_family_co_resident_slots(family_parts):
    """Slots of different families co-resident in one batch: admit a vpsde
    render, advance it, admit a cld request mid-flight.  Both must match
    their solo runs (the per-family round-step variants only commit their
    own family's rows), and the round must dispatch once per resident
    family (n_steps > n_rounds)."""
    specs, params = family_parts
    engine = DiffusionEngine(specs, params, batch_size=2, nfe=6)
    engine.scheduler.submit(SampleRequest(rid=0, seed=0))          # vpsde
    engine._admit()
    for _ in range(2):
        engine._round()
    engine.scheduler.submit(SampleRequest(rid=1, seed=1, family="cld",
                                          nfe=5))
    engine._admit()
    fams = sorted(s.data["family"] for s in engine.slots.active())
    assert fams == ["cld", "vpsde"], fams
    results = {}
    while engine.slots.active_ids():
        engine._round()
        engine._poll(results)
    assert sorted(results) == [0, 1]
    assert engine.n_steps > engine.n_rounds, \
        "co-resident families must each dispatch their own step variant"

    for rid, fam, kw in ((0, "vpsde", {}), (1, "cld", dict(nfe=5))):
        solo = DiffusionEngine(specs[fam], params[fam], batch_size=2, nfe=6)
        out = solo.serve([SampleRequest(rid=rid, seed=rid, **kw)])
        np.testing.assert_array_equal(results[rid], out[rid],
                                      err_msg=f"rid {rid} ({fam})")


def test_multi_family_zero_recompiles_after_variant_warmup(family_parts):
    """After a one-time warmup of the (family, corrector) variants — and of
    the coefficient-bank buckets live traffic will occupy — fresh mixed
    traffic with unseen NFE values and new config mixes must not compile
    anything new."""
    specs, params = family_parts
    engine = DiffusionEngine(specs, params, batch_size=2, nfe=6)
    # warmup: every (family, corrector) variant in traffic, a 5th config
    # to push the config bucket to 8 so live traffic can register new
    # configs without overflowing it, and a tall-NFE BDM config so the
    # factored bank's diag-pool bucket has headroom for the unseen BDM
    # NFE below (only freq-diagonal configs occupy pool rows; a pool
    # bucket overflow recompiles like any other bucket overflow)
    engine.serve([SampleRequest(rid=-1, seed=0),
                  SampleRequest(rid=-2, seed=1, family="cld"),
                  SampleRequest(rid=-3, seed=2, family="bdm", nfe=16),
                  SampleRequest(rid=-4, seed=3, family="cld",
                                corrector=True),
                  SampleRequest(rid=-5, seed=4, nfe=4)])
    warm = engine.compile_stats()
    # exactly the 3 predictor-only variants + cld's with-corrector variant:
    # serve() registers the whole call's configs up front (`_prepare`), so
    # even though the 5th config overflows the C bucket, the bank is at its
    # final shapes before any variant compiles
    assert warm["step"] == 4, warm

    engine.serve([SampleRequest(rid=0, seed=5, nfe=5),             # new cfg
                  SampleRequest(rid=1, seed=6, family="bdm", nfe=5),
                  SampleRequest(rid=2, seed=7, family="cld", nfe=4),
                  SampleRequest(rid=3, seed=8, family="cld", nfe=6,
                                corrector=True)])
    assert engine.compile_stats() == warm, \
        ("mixed-family traffic recompiled after warmup", warm,
         engine.compile_stats())
    assert len(engine.cache) == 8


def test_multi_family_requires_shared_data_shape(family_parts):
    specs, params = family_parts
    other = get_diffusion("cifar10-ddpm", reduced=False)   # (32, 32, 3)
    with pytest.raises(ValueError, match="data_shape"):
        DiffusionEngine({"vpsde": other, "cld": specs["cld"]},
                        {"vpsde": params["vpsde"], "cld": params["cld"]},
                        batch_size=2, nfe=4)


def test_unknown_family_rejected(family_parts):
    specs, params = family_parts
    engine = DiffusionEngine(specs, params, batch_size=2, nfe=4)
    with pytest.raises(ValueError, match="family"):
        engine.serve([SampleRequest(rid=0, seed=0, family="edm")])


# ---------------------------------------------------------------------------
# scheduler: admission-wave grouping under mixed cost classes
# ---------------------------------------------------------------------------
class TestSchedulerGrouping:
    def _sched(self):
        # group by corrector cost class, like the DiffusionEngine does
        return Scheduler(group_key=lambda r: bool(r.corrector))

    def test_waves_are_class_homogeneous(self):
        s = self._sched()
        s.submit_all([SampleRequest(rid=0),
                      SampleRequest(rid=1, corrector=True),
                      SampleRequest(rid=2),
                      SampleRequest(rid=3)])
        waves = []
        while s.has_pending():
            waves.append([r.rid for r in s.take_group(8)])
        # FIFO with head-of-line grouping: rid 2/3 queue behind the
        # corrector request rather than being reordered around it
        assert waves == [[0], [1], [2, 3]]

    def test_wave_size_capped_by_free_slots(self):
        s = self._sched()
        s.submit_all([SampleRequest(rid=i) for i in range(5)])
        assert [r.rid for r in s.take_group(2)] == [0, 1]
        assert [r.rid for r in s.take_group(2)] == [2, 3]
        assert [r.rid for r in s.take_group(2)] == [4]
        assert s.take_group(2) == []

    def test_zero_free_slots_takes_nothing(self):
        s = self._sched()
        s.submit(SampleRequest(rid=0))
        assert s.take_group(0) == []
        assert s.n_pending == 1

    def test_engine_admits_one_cost_class_wave_per_cycle(self):
        """The diffusion engine admits ONE class-homogeneous wave per
        admission cycle: a queued corrector render does not land next to
        the predictor-only wave just admitted (which would drag it
        through the 2-eval program for its whole lifetime) — it waits for
        the next poll cycle."""
        spec = get_diffusion("cifar10-ddpm", reduced=True)
        params = spec.init(jax.random.PRNGKey(0))
        engine = DiffusionEngine(spec, params, batch_size=4, nfe=4)
        engine.scheduler.submit_all([
            SampleRequest(rid=0, seed=0),
            SampleRequest(rid=1, seed=1, nfe=4, corrector=True),
            SampleRequest(rid=2, seed=2)])
        engine._admit()
        # head-of-line grouping: only rid 0 admitted (rid 1 breaks the
        # class; rid 2 waits behind it rather than being reordered around)
        assert [s.request.rid for s in engine.slots.active()] == [0]
        engine._admit()                 # next cycle: the corrector wave
        assert sorted(s.request.rid
                      for s in engine.slots.active()) == [0, 1]
        results = engine.serve([])      # drain everything (rid 2 admits
        assert sorted(results) == [0, 1, 2]   # on the next cycle inside)


def test_family_corrector_wave_grouping(family_parts):
    """Admission waves are homogeneous in the generalized (family,
    corrector, precision) cost class: FIFO with head-of-line grouping,
    so a wave never mixes classes (a cld render would otherwise drag
    vpsde neighbours through its score net's rounds from round one, and
    an int8 request would drag f32 neighbours onto the quantized net)."""
    specs, params = family_parts
    engine = DiffusionEngine(specs, params, batch_size=8, nfe=4)
    reqs = [SampleRequest(rid=0, seed=0),                      # (vpsde, F)
            SampleRequest(rid=1, seed=1),                      # (vpsde, F)
            SampleRequest(rid=2, seed=2, family="cld"),
            SampleRequest(rid=3, seed=3, family="cld", corrector=True),
            SampleRequest(rid=4, seed=4, family="cld"),
            SampleRequest(rid=5, seed=5, family="bdm")]
    engine.scheduler.submit_all(reqs)
    waves = []
    while engine.scheduler.has_pending():
        waves.append([engine._class_of(r)
                      for r in engine.scheduler.take_group(8)])
    for w in waves:
        assert len(set(w)) == 1, (
            waves, "a wave mixed (family, corrector, precision) classes")
    assert [w[0] for w in waves] == [
        ("vpsde", False, "f32"), ("cld", False, "f32"),
        ("cld", True, "f32"), ("cld", False, "f32"),
        ("bdm", False, "f32")]


# ---------------------------------------------------------------------------
# slot table: shard-aware free-slot ordering
# ---------------------------------------------------------------------------
def test_slot_table_round_robin_across_shards():
    t = SlotTable(8, n_shards=2)
    assert t.free_ids() == [0, 4, 1, 5, 2, 6, 3, 7]
    t.assign(0, object())
    t.assign(4, object())
    assert t.free_ids() == [1, 5, 2, 6, 3, 7]
    t.release(4)
    assert t.free_ids() == [4, 1, 5, 2, 6, 3, 7]
    with pytest.raises(ValueError):
        SlotTable(6, n_shards=4)

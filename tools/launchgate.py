#!/usr/bin/env python
"""Launch-wait-harvest-gate harness for the multi-replica serving tier.

    PYTHONPATH=src python tools/launchgate.py --replicas 2 --check-solo

The reframe pattern (ROADMAP open item 1), applied to the router fleet:

  1. **Launch** — the parent computes the deterministic route plan
     (serve/router.py) for the canonical router-benchmark trace, writes
     each replica's wire-form sub-trace to the workdir, and spawns N
     replica processes.  Each replica joins a real `jax.distributed`
     fleet (repro.distributed.multihost — process 0 hosts the
     coordinator), builds + warms its engine, and clears the shared
     readiness barrier.
  2. **Wait** — the parent polls per-replica readiness sentinels (each
     written only after the fleet-wide barrier clears, i.e. after every
     replica is warmed), then waits for the serves to finish, with a
     hard timeout so a wedged replica fails the job instead of hanging
     it.
  3. **Harvest** — every replica writes `replica_<i>.json`: its engine's
     deterministic BENCH counters (rounds / dispatches / polls /
     recompiles-after-warmup) plus a sha256 digest of every served
     sample.  The parent merges them with the route-plan counters into
     the `gddim_router_R2` record.
  4. **Gate** — nonzero exit if any replica fails, any replica
     recompiled after warmup, the merged counters disagree with the
     route plan, a routed sample's digest differs from the single-host
     solo engine's (`--check-solo`: the bitwise acceptance), or the
     deterministic counters drift from the committed `BENCH_serving.json`
     row.  On success the record is merged into `--bench-json` (the
     in-process benchmark, `python -m benchmarks.run serving`, produces
     the identical record via `run_in_process()` below — both modes
     route the same plan and serve the same sub-traces, so the counters
     agree by construction, and tools/perf_guard.py EXACT-gates them).

In CI this runs N local processes on one machine (the `serve-router`
job).  On a real cluster the same three-phase shape maps onto k8s: one
headless Service + StatefulSet of N replicas, each pod running
`tools/launchgate.py --worker --replica $POD_ORDINAL --coordinator
<pod-0-dns>:12355`, with the parent's wait/harvest/gate phases as a Job
reading the per-replica JSON from a shared volume — see
docs/serving.md#multi-host-serving-and-the-router-front-tier for the
manifest sketch.
"""
from __future__ import annotations

import argparse
import hashlib
import json
import os
import socket
import subprocess
import sys
import tempfile
import time
from typing import Any, Dict, List, Optional, Tuple

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
if os.path.join(REPO_ROOT, "src") not in sys.path:
    sys.path.insert(0, os.path.join(REPO_ROOT, "src"))

# ---------------------------------------------------------------------------
# the canonical router-benchmark scenario (shared with benchmarks/serving.py
# so the in-process record and the multi-process harvest agree EXACTLY)
# ---------------------------------------------------------------------------
N_REPLICAS = 2
BATCH = 4
NFE = 10
PREVIEW_NFE = 5
N_REQUESTS = 12
TRACE_SEED = 23
TRACE_RATE = 0.8
RECORD_CONFIG = f"gddim_router_R{N_REPLICAS}"
# replica 1 is down for a deterministic window mid-trace: probes at the
# 4.0 cadence catch it, traffic shifts to replica 0, and the backpressure
# bound forces requeues — so the gated counters exercise the whole policy
FAULT_WINDOWS_R1 = ((6.0, 14.0),)


def record_config(n_replicas: int = N_REPLICAS) -> str:
    return f"gddim_router_R{n_replicas}"


def build_router(n_replicas: int = N_REPLICAS):
    from repro.serve import ReplicaSpec, Router, RouterConfig
    specs = [ReplicaSpec(i, batch=BATCH,
                         fault_windows=FAULT_WINDOWS_R1 if i == 1 else ())
             for i in range(n_replicas)]
    return Router(specs, RouterConfig(
        max_queue_depth=3, probe_every=4.0, requeue_delay=1.0,
        max_requeues=8, default_nfe=NFE))


def build_trace():
    from repro.serve import SampleRequest, poisson_trace

    def make_request(i, rng):
        return SampleRequest(rid=i, seed=i,
                             nfe=PREVIEW_NFE if i % 3 == 0 else None)

    return poisson_trace(make_request, n=N_REQUESTS, rate=TRACE_RATE,
                         seed=TRACE_SEED)


def build_engine():
    """One warmed replica engine.  The warmup serves both NFE buckets the
    trace draws from, so the measured routed serve compiles nothing."""
    import jax
    from repro.configs import get_diffusion
    from repro.serve import DiffusionEngine, SampleRequest

    spec = get_diffusion("cifar10-ddpm", reduced=True)
    params = spec.init(jax.random.PRNGKey(0))  # staticcheck: disable=SC102 (fixed scenario seed on purpose: every replica AND the solo reference must init identical params for the bitwise gate)
    engine = DiffusionEngine(spec, params, batch_size=BATCH, nfe=NFE)
    engine.serve([SampleRequest(rid=-1, seed=0),
                  SampleRequest(rid=-2, seed=0, nfe=PREVIEW_NFE)])
    warm_stats = sum(engine.compile_stats().values())
    return engine, warm_stats


def replica_counters(engine, warm_stats: int, served: Dict[int, Any],
                     marks: Tuple[int, int, int]) -> Dict[str, Any]:
    """The per-replica BENCH counter JSON: deterministic engine counters
    for the measured (post-warmup) serve plus per-sample digests."""
    r0, s0, p0 = marks
    return {
        "rounds": engine.n_rounds - r0,
        "dispatches": engine.n_steps - s0,
        "polls": engine.n_polls - p0,
        "recompiles_after_warmup":
            sum(engine.compile_stats().values()) - warm_stats,
        "n_served": len(served),
        "digests": {str(rid): hashlib.sha256(x.tobytes()).hexdigest()
                    for rid, x in sorted(served.items())},
    }


def serve_wire_arrivals(engine, arrivals
                        ) -> Tuple[Dict[int, Any], Dict[str, Any]]:
    """Drain one replica's wire-form sub-trace — a list of
    (t, wire-request-dict) pairs, straight off a RoutePlan or a JSON file
    — through a warmed engine; returns (results, counter JSON).  The
    in-process benchmark and a spawned replica process both enter here,
    so their counters agree by construction."""
    from repro.serve import (Arrival, ServeRequest, TraceTraffic,
                             VirtualClock)
    warm_stats = sum(engine.compile_stats().values())
    marks = (engine.n_rounds, engine.n_steps, engine.n_polls)
    served: Dict[int, Any] = {}
    if arrivals:
        trace = TraceTraffic([Arrival(t, ServeRequest.from_wire(w))
                              for t, w in arrivals])
        served = engine.serve_stream(trace, clock=VirtualClock())
    return served, replica_counters(engine, warm_stats, served, marks)


def merge_record(plan, reports: List[Dict[str, Any]],
                 wall_dt: float) -> Dict[str, Any]:
    """The `gddim_router_R2` BENCH record from a route plan + per-replica
    counter reports.  Every field except the two wall-time columns is a
    pure function of (trace, router config, seeds) — EXACT/BOUNDED-gated
    by tools/perf_guard.py."""
    rounds = sum(r["rounds"] for r in reports)
    return {
        "workload": "diffusion",
        "config": record_config(len(reports)),
        "traffic": "routed-poisson",
        "n_replicas": len(reports),
        "batch": BATCH, "nfe": NFE,
        "n_requests": N_REQUESTS,
        **plan.counters,               # requests_routed / requeues /
                                       # health_probes / n_shed
        "rounds": rounds,
        "dispatches": sum(r["dispatches"] for r in reports),
        "polls": sum(r["polls"] for r in reports),
        "recompiles_after_warmup":
            sum(r["recompiles_after_warmup"] for r in reports),
        "per_replica_rounds": [r["rounds"] for r in reports],
        "us_per_round": round(1e6 * wall_dt / max(rounds, 1), 1),
        "samples_per_s": round(
            plan.counters["requests_routed"] / max(wall_dt, 1e-9), 3),
    }


def run_in_process(n_replicas: int = N_REPLICAS
                   ) -> Tuple[Dict[str, Any], Dict[int, Any], Any]:
    """The whole scenario in one process (used by benchmarks/serving.py):
    plan the routes, serve every sub-trace on its own warmed engine,
    merge.  Returns (record, merged results, plan)."""
    plan = build_router(n_replicas).plan(build_trace())
    reports, results = [], {}
    t0 = time.perf_counter()
    for i in range(n_replicas):
        engine, _ = build_engine()
        served, counters = serve_wire_arrivals(engine, plan.sub_traces[i])
        results.update(served)
        reports.append(counters)
    wall_dt = time.perf_counter() - t0
    return merge_record(plan, reports, wall_dt), results, plan


# ---------------------------------------------------------------------------
# replica worker (one process of the fleet)
# ---------------------------------------------------------------------------
def worker_main(args) -> int:
    from repro.distributed import multihost

    ctx = multihost.initialize(coordinator_address=args.coordinator,
                               num_processes=args.replicas,
                               process_id=args.replica)
    engine, _ = build_engine()                       # warm before 'ready'
    multihost.kv_set(f"launchgate/warm/{ctx.process_id}", "1")
    multihost.barrier("launchgate-ready", timeout_s=args.timeout)
    ready = os.path.join(args.workdir, f"ready_{ctx.process_id}")
    with open(ready, "w") as f:
        f.write("ready\n")

    # the sub-trace crosses the process boundary ONLY in wire form: the
    # parent wrote the plan's (t, ServeRequest.to_wire()) pairs, the
    # worker deserializes at its ingress
    with open(os.path.join(args.workdir,
                           f"subtrace_{ctx.process_id}.json")) as f:
        arrivals = json.load(f)["arrivals"]
    _, counters = serve_wire_arrivals(engine, arrivals)
    counters["replica"] = ctx.process_id
    out = os.path.join(args.workdir, f"replica_{ctx.process_id}.json")
    with open(out + ".tmp", "w") as f:
        json.dump(counters, f, indent=2, sort_keys=True)
    os.replace(out + ".tmp", out)
    multihost.barrier("launchgate-done", timeout_s=args.timeout)
    return 0


# ---------------------------------------------------------------------------
# parent: launch -> wait -> harvest -> gate
# ---------------------------------------------------------------------------
def _free_port() -> int:
    with socket.socket() as s:
        s.bind(("127.0.0.1", 0))
        return s.getsockname()[1]


def _gate(errors: List[str], ok: bool, message: str) -> None:
    print(("ok   " if ok else "FAIL ") + message)
    if not ok:
        errors.append(message)


def parent_main(args) -> int:
    workdir = args.workdir or tempfile.mkdtemp(prefix="launchgate_")
    os.makedirs(workdir, exist_ok=True)
    coordinator = f"127.0.0.1:{_free_port()}"
    plan = build_router(args.replicas).plan(build_trace())
    for i in range(args.replicas):
        with open(os.path.join(workdir, f"subtrace_{i}.json"), "w") as f:
            json.dump({"replica": i, "arrivals": plan.sub_traces[i]},
                      f, indent=2, sort_keys=True)
    print(f"route plan: {plan.counters} -> "
          f"{[len(s) for s in plan.sub_traces]} requests per replica")

    # -- launch -----------------------------------------------------------
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO_ROOT, "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    procs = []
    t0 = time.perf_counter()
    for i in range(args.replicas):
        log = open(os.path.join(workdir, f"replica_{i}.log"), "w")
        procs.append((subprocess.Popen(
            [sys.executable, os.path.abspath(__file__), "--worker",
             "--replica", str(i), "--replicas", str(args.replicas),
             "--coordinator", coordinator, "--workdir", workdir,
             "--timeout", str(args.timeout)],
            stdout=log, stderr=subprocess.STDOUT, env=env, cwd=REPO_ROOT),
            log))
    print(f"launched {args.replicas} replica processes "
          f"(coordinator {coordinator}, workdir {workdir})")

    # -- wait: readiness sentinels, then completion -----------------------
    errors: List[str] = []
    deadline = time.monotonic() + args.timeout
    ready = [os.path.join(workdir, f"ready_{i}")
             for i in range(args.replicas)]
    while time.monotonic() < deadline:
        if all(os.path.exists(p) for p in ready):
            break
        if any(p.poll() is not None and p.returncode != 0
               for p, _ in procs):
            break
        time.sleep(0.2)
    _gate(errors, all(os.path.exists(p) for p in ready),
          f"fleet ready ({sum(os.path.exists(p) for p in ready)}"
          f"/{args.replicas} replicas warmed + barrier cleared)")

    for i, (p, log) in enumerate(procs):
        try:
            code = p.wait(timeout=max(deadline - time.monotonic(), 1.0))
        except subprocess.TimeoutExpired:
            p.kill()
            code = -9
        log.close()
        _gate(errors, code == 0, f"replica {i} exited {code}")
    wall_dt = time.perf_counter() - t0

    # -- harvest ----------------------------------------------------------
    reports: List[Dict[str, Any]] = []
    for i in range(args.replicas):
        path = os.path.join(workdir, f"replica_{i}.json")
        if not os.path.exists(path):
            _gate(errors, False, f"replica {i}: no counter JSON harvested")
            with open(os.path.join(workdir, f"replica_{i}.log")) as f:
                tail = f.read().splitlines()[-12:]
            print("      " + "\n      ".join(tail))
            continue
        with open(path) as f:
            reports.append(json.load(f))
    if len(reports) != args.replicas:
        print(f"\nLAUNCHGATE FAILED: {errors}")
        return 1

    # -- gate -------------------------------------------------------------
    record = merge_record(plan, reports, wall_dt)
    for i, rep in enumerate(reports):
        _gate(errors, rep["recompiles_after_warmup"] == 0,
              f"replica {i}: recompiles_after_warmup == 0 "
              f"(got {rep['recompiles_after_warmup']})")
    _gate(errors,
          sum(r["n_served"] for r in reports) == record["requests_routed"],
          f"served {sum(r['n_served'] for r in reports)} == "
          f"routed {record['requests_routed']}")

    if args.check_solo:
        solo = _solo_digests()
        routed = {rid: d for r in reports for rid, d in r["digests"].items()}
        bad = [rid for rid, d in routed.items() if solo.get(rid) != d]
        _gate(errors, not bad,
              "routed samples bitwise == single-host solo engine "
              + (f"(mismatched rids: {bad})" if bad
                 else f"({len(routed)} digests)"))

    merged = _merge_bench_json(args.bench_json, record, errors)
    print(f"\n{record['config']}: " + json.dumps(
        {k: v for k, v in record.items()
         if k not in ("us_per_round", "samples_per_s")}, sort_keys=True))
    if errors:
        print(f"\nLAUNCHGATE FAILED ({len(errors)} gate(s)):")
        for e in errors:
            print(f"  {e}")
            if os.environ.get("GITHUB_ACTIONS") == "true":
                print(f"::error title=launchgate::{e}")
        return 1
    print(f"\nlaunchgate passed: {args.replicas} replicas, "
          f"record {'merged into ' + merged if merged else 'gated (no merge)'}")
    return 0


def _solo_digests() -> Dict[str, str]:
    """Single-host reference: ONE engine serves the whole trace; digests
    keyed by rid.  Per-request purity makes these the bitwise truth every
    routed replica must reproduce."""
    from repro.serve import VirtualClock
    engine, _ = build_engine()
    results = engine.serve_stream(build_trace(), clock=VirtualClock())
    return {str(rid): hashlib.sha256(bytes(x.data)).hexdigest()
            for rid, x in sorted(results.items())}


def _merge_bench_json(path: Optional[str], record: Dict[str, Any],
                      errors: List[str]) -> Optional[str]:
    """Gate the deterministic counters against an existing router record
    in `path` (the committed baseline in CI), then merge the fresh record
    in (replacing any previous row with the same config)."""
    if not path:
        return None
    gated = ("requests_routed", "requeues", "health_probes", "n_shed",
             "n_requests", "n_replicas", "batch", "nfe",
             "recompiles_after_warmup")
    doc = {"table": "serving", "records": []}
    if os.path.exists(path):
        with open(path) as f:
            doc = json.load(f)
        prev = next((r for r in doc.get("records", [])
                     if r.get("config") == record["config"]), None)
        if prev is not None:
            drift = {k: (prev.get(k), record.get(k)) for k in gated
                     if k in prev and prev.get(k) != record.get(k)}
            _gate(errors, not drift,
                  f"deterministic counters match committed {path}"
                  + (f" (drift: {drift})" if drift else ""))
    # replace in place (or append), preserving the benchmark writer's
    # record order so a gate-passing merge is a minimal diff
    recs = doc.get("records", [])
    idx = [i for i, r in enumerate(recs) if r.get("config")
           == record["config"]]
    if idx:
        recs[idx[0]] = record
        for i in reversed(idx[1:]):
            del recs[i]
    else:
        recs.append(record)
    doc["records"] = recs
    with open(path + ".tmp", "w") as f:
        json.dump(doc, f, indent=2, sort_keys=True)
        f.write("\n")
    os.replace(path + ".tmp", path)
    return path


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="launch-wait-harvest-gate harness for the router fleet")
    ap.add_argument("--replicas", type=int, default=N_REPLICAS)
    ap.add_argument("--workdir", default=None,
                    help="scratch dir for sub-traces / sentinels / harvested"
                         " JSON (default: a fresh tempdir)")
    ap.add_argument("--bench-json",
                    default=os.path.join(REPO_ROOT, "BENCH_serving.json"),
                    help="BENCH file to gate against and merge the "
                         f"{RECORD_CONFIG} record into ('' disables)")
    ap.add_argument("--check-solo", action="store_true",
                    help="also serve the whole trace on one single-host "
                         "engine and require bitwise-equal digests")
    ap.add_argument("--timeout", type=float, default=600.0,
                    help="readiness + completion timeout, seconds")
    # worker mode (one replica of the fleet; spawned by the parent or by a
    # k8s pod — not user-facing)
    ap.add_argument("--worker", action="store_true", help=argparse.SUPPRESS)
    ap.add_argument("--replica", type=int, default=0, help=argparse.SUPPRESS)
    ap.add_argument("--coordinator", default=None, help=argparse.SUPPRESS)
    args = ap.parse_args(argv)
    if args.replicas != N_REPLICAS:
        print(f"note: scenario counters are committed for "
              f"--replicas {N_REPLICAS}; {args.replicas} replicas will "
              "gate against the plan only", file=sys.stderr)
    if args.worker:
        return worker_main(args)
    return parent_main(args)


if __name__ == "__main__":
    raise SystemExit(main())

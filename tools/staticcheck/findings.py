"""Finding type, inline allowlist parsing, and the output surfaces.

Every rule emits `Finding` records with a stable rule ID (SCxxx for the
AST layer, JXxxx for the jaxpr sanitizer, PLxxx for the Pallas kernel
checks).  A finding can be suppressed at its line (or, for whole-module
waivers, at the line the rule anchors on) with an inline comment that
must carry a justification:

    self.state.active  # staticcheck: disable=SC103 (the one steady-state fetch)

Multiple IDs are comma-separated (`disable=SC103,SC101`).  A disable
comment *without* a parenthesized reason is itself a finding (SC000):
allowlisting is cheap, silent allowlisting is how invariants rot.

Outputs: human-readable lines, structured JSON (`--json`), and GitHub
`::error file=...,line=...` workflow annotations (`--github`, auto-enabled
under `GITHUB_ACTIONS`) so CI findings surface inline on the PR diff.
"""
from __future__ import annotations

import dataclasses
import json
import re
import sys
from typing import Dict, Iterable, List, Optional, Set, Tuple

DISABLE_RE = re.compile(
    r"#\s*staticcheck:\s*disable=([A-Z]{2}\d{3}(?:\s*,\s*[A-Z]{2}\d{3})*)"
    r"(\s*\(.+\))?")


@dataclasses.dataclass(frozen=True)
class Finding:
    rule: str                 # stable ID, e.g. "SC101"
    path: str                 # repo-relative file path ("" for menu-level)
    line: int                 # 1-indexed anchor line (0 = whole file)
    message: str
    col: int = 0

    def text(self) -> str:
        loc = f"{self.path}:{self.line}" if self.path else "<menu>"
        return f"{loc}: {self.rule}: {self.message}"

    def github(self) -> str:
        """One GitHub workflow-command annotation line."""
        msg = self.message.replace("%", "%25").replace("\r", "%0D") \
                          .replace("\n", "%0A")
        if self.path:
            return (f"::error file={self.path},line={max(self.line, 1)},"
                    f"title={self.rule}::{msg}")
        return f"::error title={self.rule}::{msg}"


def parse_allowlist(source: str, path: str
                    ) -> Tuple[Dict[int, Set[str]], List[Finding]]:
    """Per-line disabled rule IDs from inline `# staticcheck: disable=...`
    comments, plus SC000 findings for disables with no justification."""
    disabled: Dict[int, Set[str]] = {}
    bad: List[Finding] = []
    for i, text in enumerate(source.splitlines(), start=1):
        m = DISABLE_RE.search(text)
        if not m:
            continue
        rules = {r.strip() for r in m.group(1).split(",")}
        disabled[i] = disabled.get(i, set()) | rules
        if not m.group(2):
            bad.append(Finding(
                "SC000", path, i,
                f"allowlist comment for {sorted(rules)} carries no "
                "justification — append one in parentheses: "
                "# staticcheck: disable=RULE (why this is safe)"))
    return disabled, bad


def apply_allowlist(findings: Iterable[Finding],
                    disabled: Dict[int, Set[str]]) -> List[Finding]:
    """Drop findings whose (line, rule) is inline-disabled."""
    return [f for f in findings
            if f.rule not in disabled.get(f.line, ())]


def emit(findings: List[Finding], json_path: Optional[str] = None,
         github: bool = False, stream=None) -> None:
    stream = stream if stream is not None else sys.stdout
    for f in sorted(findings, key=lambda f: (f.path, f.line, f.rule)):
        print(f.text(), file=stream)
        if github:
            print(f.github(), file=stream)
    if json_path:
        doc = {"tool": "staticcheck",
               "n_findings": len(findings),
               "findings": [dataclasses.asdict(f) for f in findings]}
        with open(json_path, "w") as fh:
            json.dump(doc, fh, indent=2, sort_keys=True)
            fh.write("\n")

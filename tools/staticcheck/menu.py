"""Layer 2 menu: build the serve config menu and capture every compiled
variant — round steps, merges, admits, prefills — with the *actual*
arguments the engines pass, so the sanitizer traces exactly what serves.

Mechanism: the engines' jitted callables (`_steps[fam]`, `_decode`,
`_merge`, `_admit_state`, `_prefill`, ...) are wrapped in recording
proxies, then a warmup request menu covering every (family, corrector)
cost class is served.  Each recorded (callable, args, kwargs) becomes a
`Variant` the checks re-`trace()` — abstract evaluation only; nothing
extra executes on device.

The mixed-config stability probe serves a *second* menu of different
sampler configs (other NFE budgets / orders / lambdas) through the same
engine and re-records: if any round-step's structural hash drifts between
the two passes, a config escaped its coefficient-bank bucket and steady
state would recompile (JX105).
"""
from __future__ import annotations

import dataclasses
import os
import sys
from typing import Dict, List, Tuple

_SRC = os.path.join(os.path.dirname(os.path.dirname(
    os.path.dirname(os.path.abspath(__file__)))), "src")


def _ensure_path() -> None:
    try:
        import repro  # noqa: F401
    except ImportError:
        sys.path.insert(0, _SRC)


@dataclasses.dataclass
class Variant:
    label: str
    jitted: object
    args: tuple
    kwargs: dict
    donating: bool = False        # expect donation marks in the lowering
    steady_state: bool = False    # subject to the host-transfer audit
    f32_only: bool = False        # coefficient-apply dtype walk


class _Recorder:
    """Transparent proxy that records every (args, kwargs) an engine
    passes to a jitted callable."""

    def __init__(self, inner, name: str, sink: list):
        self._inner = inner
        self._name = name
        self._sink = sink

    def __call__(self, *args, **kwargs):
        self._sink.append((self._name, self._inner, args, kwargs))
        return self._inner(*args, **kwargs)


def _dedup(calls: list, keyf) -> Dict[str, Tuple]:
    """First recorded call per variant key (later calls re-dispatch the
    same compiled program)."""
    out: Dict[str, Tuple] = {}
    for name, inner, args, kwargs in calls:
        key = keyf(name, args, kwargs)
        if key not in out:
            out[key] = (inner, args, kwargs)
    return out


def _shape_sig(args, kwargs) -> str:
    """Compact stable signature of the call's leaf shapes (scalars and
    python ints collapse to '()' so value-only differences dedup)."""
    import hashlib
    import jax
    leaves = jax.tree.leaves((args, kwargs))
    sig = ",".join(str(getattr(l, "shape", "()")) for l in leaves)
    if len(sig) > 48:
        return f"{len(leaves)}leaves:{hashlib.md5(sig.encode()).hexdigest()[:8]}"
    return sig


# ---------------------------------------------------------------------------
# diffusion menu
# ---------------------------------------------------------------------------
def build_diffusion_variants(quick: bool = False
                             ) -> Tuple[List[Variant], Dict[str, str]]:
    """Serve a menu covering every (family, corrector) cost class through
    one multi-tenant DiffusionEngine; returns the captured variants plus
    {variant label: structural hash} for the stability probe."""
    _ensure_path()
    import jax
    from repro.configs import get_diffusion
    from repro.serve import DiffusionEngine, SampleRequest
    from .jaxprcheck import jaxpr_hash

    fam_names = {"vpsde": "cifar10-ddpm"} if quick else \
        {"vpsde": "cifar10-ddpm", "cld": "cifar10-cld", "bdm": "cifar10-bdm"}
    specs, params = {}, {}
    for i, (fam, name) in enumerate(fam_names.items()):
        specs[fam] = get_diffusion(name, reduced=True)
        params[fam] = specs[fam].init(jax.random.PRNGKey(i))
    B, nfe = (2, 4) if quick else (4, 6)
    engine = DiffusionEngine(specs, params, batch_size=B, nfe=nfe)

    calls: list = []
    # _steps is keyed (family, precision) since the fused-round refactor
    engine._steps = {n: _Recorder(s, f"step:{n[0]}/{n[1]}", calls)
                     for n, s in engine._steps.items()}
    engine._admit_state = _Recorder(engine._admit_state, "admit", calls)
    engine._prior1 = {n: _Recorder(p, f"prior:{n}", calls)
                      for n, p in engine._prior1.items()}
    engine._project_row = {n: _Recorder(p, f"project:{n}", calls)
                           for n, p in engine._project_row.items()}

    def menu(scale: int) -> List[dict]:
        kinds = [dict(nfe=nfe), dict(nfe=max(nfe // scale, 2), q=2),
                 dict(nfe=nfe, corrector=True), dict(nfe=nfe, lam=0.5),
                 dict(nfe=nfe, lam=0.5, algorithm="gmm"),
                 dict(nfe=nfe, algorithm="accel")]
        if "cld" in specs:
            kinds += [dict(family="cld", nfe=nfe),
                      dict(family="cld", nfe=nfe, corrector=True)]
        if "bdm" in specs:
            kinds += [dict(family="bdm", nfe=nfe)]
        return kinds

    def key(name, args, kwargs):
        if name.startswith("step:"):
            return f"{name},corr={kwargs.get('with_corrector', False)}"
        return f"{name}[{_shape_sig(args, kwargs)}]"

    engine.serve([SampleRequest(rid=-1 - i, seed=i, **kw)
                  for i, kw in enumerate(menu(2))])
    first = _dedup(calls, key)
    hashes0 = {k: jaxpr_hash(j.trace(*a, **kw).jaxpr)
               for k, (j, a, kw) in first.items()
               if k.startswith("step:")}

    # mixed-config stability probe: new configs, same buckets expected
    calls.clear()
    engine.serve([SampleRequest(rid=-100 - i, seed=i, **kw)
                  for i, kw in enumerate(menu(3))])
    second = _dedup(calls, key)
    hashes1 = {k: jaxpr_hash(j.trace(*a, **kw).jaxpr)
               for k, (j, a, kw) in second.items()
               if k.startswith("step:")}

    variants = []
    for k, (jitted, args, kwargs) in sorted(first.items()):
        is_step = k.startswith("step:")
        is_admit = k.startswith("admit")
        variants.append(Variant(
            label=f"diffusion/{k}", jitted=jitted, args=args, kwargs=kwargs,
            donating=is_step or is_admit,
            steady_state=is_step))
    return variants, {"before": hashes0, "after": hashes1}


# ---------------------------------------------------------------------------
# token menu
# ---------------------------------------------------------------------------
def build_token_variants(quick: bool = False) -> List[Variant]:
    _ensure_path()
    import numpy as np
    import jax
    from repro.configs import get_arch
    from repro.models.registry import Arch
    from repro.serve import Request, TokenEngine

    archs = ("gemma3-1b",) if quick else ("gemma3-1b", "rwkv6-7b")
    variants: List[Variant] = []
    for arch_name in archs:
        spec = get_arch(arch_name, reduced=True)
        arch = Arch(spec)
        # deterministic trace-menu init; never serves real traffic
        params = arch.init(
            jax.random.PRNGKey(0))  # staticcheck: disable=SC102 (fixed seed keeps menu hashes reproducible)
        engine = TokenEngine(arch, params, batch_size=2, max_len=48)
        engine.eos_id = -1

        calls: list = []
        engine._decode = _Recorder(engine._decode, "decode", calls)
        engine._merge = _Recorder(engine._merge, "merge", calls)
        engine._admit_state = _Recorder(engine._admit_state, "admit", calls)
        engine._prefill = _Recorder(engine._prefill, "prefill", calls)

        rng = np.random.default_rng(0)
        reqs = [Request(rid=i,
                        tokens=rng.integers(2, arch.cfg.vocab, 8)
                        .astype(np.int32),
                        max_new=4)
                for i in range(3)]
        engine.serve(reqs)

        def key(name, args, kwargs):
            return f"{name}[{_shape_sig(args, kwargs)}]"

        for k, (jitted, args, kwargs) in sorted(_dedup(calls, key).items()):
            variants.append(Variant(
                label=f"token/{arch_name}/{k}", jitted=jitted,
                args=args, kwargs=kwargs,
                donating=k.startswith(("decode", "merge", "admit")),
                steady_state=k.startswith("decode")))
    return variants


# ---------------------------------------------------------------------------
# coefficient-apply + kernel entries
# ---------------------------------------------------------------------------
def coeff_apply_traces() -> List[Tuple[str, object]]:
    """The coefficient-apply subgraph in both impls, at serve shapes —
    subject to the strict f32-only dtype walk."""
    _ensure_path()
    import jax
    import jax.numpy as jnp
    from repro.kernels.ei_update import ops

    B, k, D = 4, 2, 3072
    blk = jnp.zeros((B, k, k), jnp.float32)
    diag = jnp.zeros((B, D), jnp.float32)
    z = jnp.zeros((B, k, D), jnp.float32)
    return [
        ("coeff_apply/ref",
         jax.make_jaxpr(lambda b, d, s: ops.apply_factored(
             b, d, s, impl="ref"))(blk, diag, z)),
        ("coeff_apply/pallas",
         jax.make_jaxpr(lambda b, d, s: ops.apply_factored(
             b, d, s, impl="pallas"))(blk, diag, z)),
    ]


def kernel_entries() -> List[Tuple[str, object]]:
    _ensure_path()
    from repro.kernels.dct2 import ops as dct2_ops
    from repro.kernels.decode_attention import ops as da_ops
    from repro.kernels.ei_update import ops as ei_ops
    from repro.kernels.round_fused import ops as rf_ops

    out: List[Tuple[str, object]] = []
    for mod in (ei_ops, dct2_ops, da_ops, rf_ops):
        out.extend(mod.staticcheck_entries())
    return out

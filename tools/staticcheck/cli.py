"""Command-line entry: `python -m tools.staticcheck [paths] [--sanitize]`.

  python -m tools.staticcheck src/                 # Layer 1: AST lint
  python -m tools.staticcheck --sanitize           # Layer 2: full menu
  python -m tools.staticcheck --sanitize --quick   # reduced menu (tests)
  python -m tools.staticcheck src/ --sanitize --json OUT.json --github

Exit status: 0 = clean, 1 = findings, 2 = usage error.  `--github` (auto
under GITHUB_ACTIONS) adds `::error file=...,line=...` workflow commands
so findings annotate the PR diff inline.
"""
from __future__ import annotations

import argparse
import os
import sys
from typing import List, Optional

from .findings import Finding, emit


def run_sanitizer(quick: bool = False, verbose: bool = True
                  ) -> tuple:
    """Layer 2 over the serve menu + kernel entries.  Returns (findings,
    {label: structural hash})."""
    from . import jaxprcheck as jx
    from . import pallas_check as plc
    from .menu import (build_diffusion_variants, build_token_variants,
                       coeff_apply_traces, kernel_entries)

    findings: List[Finding] = []
    hashes = {}

    variants, step_hashes = build_diffusion_variants(quick=quick)
    variants += build_token_variants(quick=quick)
    for v in variants:
        traced = v.jitted.trace(*v.args, **v.kwargs)
        jaxpr = traced.jaxpr
        findings += jx.check_no_callbacks(jaxpr, v.label)
        findings += jx.check_dtypes(jaxpr, v.label, f32_only=v.f32_only)
        findings += plc.check_if_present(jaxpr, v.label)
        lowered_text = traced.lower().as_text()
        compiled_text = traced.lower().compile().as_text()
        if v.donating:
            findings += jx.check_donation(lowered_text, compiled_text,
                                          v.label)
        if v.steady_state:
            findings += jx.check_no_host_transfers(compiled_text, v.label)
        hashes[v.label] = jx.jaxpr_hash(jaxpr)
        if verbose:
            print(f"  sanitized {v.label}  hash={hashes[v.label]}",
                  file=sys.stderr)

    findings += jx.check_hash_stability(step_hashes["before"],
                                        step_hashes["after"],
                                        "diffusion mixed-config menu")

    for label, jaxpr in coeff_apply_traces():
        findings += jx.check_no_callbacks(jaxpr, label)
        findings += jx.check_dtypes(jaxpr, label, f32_only=True)
        hashes[label] = jx.jaxpr_hash(jaxpr)

    for label, jaxpr in kernel_entries():
        findings += jx.check_no_callbacks(jaxpr, label)
        findings += jx.check_dtypes(jaxpr, label)
        findings += plc.check_traced(jaxpr, label)
        hashes[label] = jx.jaxpr_hash(jaxpr)
        if verbose:
            print(f"  sanitized {label}  hash={hashes[label]}",
                  file=sys.stderr)

    return findings, hashes


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m tools.staticcheck",
        description="two-layer static analysis: AST lint + jaxpr sanitizer")
    ap.add_argument("paths", nargs="*", help="files/dirs for the AST lint")
    ap.add_argument("--sanitize", action="store_true",
                    help="trace + audit the full serve menu (Layer 2)")
    ap.add_argument("--quick", action="store_true",
                    help="reduced Layer 2 menu (single family/arch)")
    ap.add_argument("--json", metavar="PATH",
                    help="write findings as structured JSON")
    ap.add_argument("--github", action="store_true",
                    default=bool(os.environ.get("GITHUB_ACTIONS")),
                    help="emit ::error workflow annotations")
    args = ap.parse_args(argv)
    if not args.paths and not args.sanitize:
        ap.print_usage(sys.stderr)
        print("error: give paths to lint and/or --sanitize",
              file=sys.stderr)
        return 2

    findings: List[Finding] = []
    if args.paths:
        from .astlint import lint_paths
        findings += lint_paths(args.paths)
    if args.sanitize:
        sfindings, _hashes = run_sanitizer(quick=args.quick)
        findings += sfindings

    emit(findings, json_path=args.json, github=args.github)
    layers = [l for l, on in (("ast", bool(args.paths)),
                              ("sanitizer", args.sanitize)) if on]
    print(f"staticcheck [{'+'.join(layers)}]: "
          f"{'FAIL' if findings else 'ok'} ({len(findings)} finding(s))")
    return 1 if findings else 0

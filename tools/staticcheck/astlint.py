"""Layer 1: repo-specific AST lint over Python sources (stdlib `ast`).

Rules (stable IDs — documented in docs/static_analysis.md):

  SC000  allowlist comment without a justification (findings.py)
  SC001  unused import (module scope; `__init__.py` re-export files exempt)
  SC101  PRNG key reuse: a key bound from `jax.random.split` / `fold_in` /
         `PRNGKey` is *consumed* (passed to anything that is not
         split/fold_in — deriving a subkey is not consuming) at most once
         per binding, and never across loop iterations it does not rebind
         in.  Key reuse silently correlates noise draws — the exact
         failure mode gDDIM's pure-function-of-(seed, config) sampling
         contract exists to prevent.
  SC102  raw `jax.random.PRNGKey(<int literal>)` outside tests/examples:
         a constant seed in library code aliases every caller onto one
         noise stream.
  SC103  host-sync call (`np.asarray`, `np.array`, `jax.device_get`,
         `.item()`, `.block_until_ready()`, non-literal `float(...)`)
         inside a serve hot-path module.  The steady-state loop's
         contract is one sanctioned fetch per poll; anything else stalls
         the device pipeline.
  SC104  Python float literal mixed into a `jnp` expression inside a
         coefficient-critical module (core/coeffs.py): the bitwise
         factored==dense guarantee rides on the coefficient graph being
         built in Stage-I float64 numpy and converted once — a stray
         literal in the jnp graph re-derives values under weak-type
         promotion and breaks bit-exactness silently.
  SC105  donation safety: an array passed at a `donate_argnums` position
         of a jitted callable is dead after the call — referencing it
         later in the same function reads a buffer XLA may already have
         reused.  Donating factories are resolved transitively within the
         module (e.g. `_jit_state_update` -> `jax.jit(donate_argnums=...)`).

Module scoping: hot-path / coefficient-critical module sets are path
suffixes in `LintConfig`; a file can also opt itself in with a pragma
comment (used by the test fixtures):

    # staticcheck: module=hot-path
    # staticcheck: module=coeff-critical
"""
from __future__ import annotations

import ast
import dataclasses
from typing import Dict, List, Optional, Sequence, Set, Tuple

from .findings import Finding, apply_allowlist, parse_allowlist

MODULE_PRAGMA = "# staticcheck: module="

KEY_SOURCES = ("jax.random.split", "jax.random.fold_in", "jax.random.PRNGKey")
KEY_DERIVERS = ("jax.random.split", "jax.random.fold_in")
HOST_SYNC_CALLS = ("numpy.asarray", "numpy.array", "jax.device_get")
HOST_SYNC_METHODS = ("item", "block_until_ready")


@dataclasses.dataclass(frozen=True)
class LintConfig:
    """Which repo paths each path-scoped rule applies to (suffix match on
    the POSIX path)."""
    hot_path_suffixes: Tuple[str, ...] = (
        "src/repro/serve/loop.py",
        "src/repro/serve/engine.py",
        "src/repro/serve/traffic.py",
        "src/repro/serve/parking.py",
        "src/repro/serve/api.py",
        "src/repro/serve/router.py",
        "src/repro/launch/steps.py",
    )
    coeff_critical_suffixes: Tuple[str, ...] = (
        "src/repro/core/coeffs.py",
    )
    raw_key_exempt_parts: Tuple[str, ...] = ("tests", "examples", "benchmarks")


DEFAULT_CONFIG = LintConfig()


# ---------------------------------------------------------------------------
# name resolution through import aliases
# ---------------------------------------------------------------------------
class _Aliases:
    """Maps local names to canonical dotted module paths via the module's
    imports (`import numpy as np` -> np: numpy; `from jax import random as
    jr` -> jr: jax.random; `from jax.random import split` ->
    split: jax.random.split)."""

    def __init__(self, tree: ast.Module):
        self.map: Dict[str, str] = {}
        for node in ast.walk(tree):
            if isinstance(node, ast.Import):
                for a in node.names:
                    self.map[a.asname or a.name.split(".")[0]] = \
                        a.name if a.asname else a.name.split(".")[0]
            elif isinstance(node, ast.ImportFrom) and node.module \
                    and node.level == 0:
                for a in node.names:
                    self.map[a.asname or a.name] = f"{node.module}.{a.name}"

    def dotted(self, node: ast.AST) -> Optional[str]:
        """Canonical dotted path of a Name/Attribute chain, or None."""
        parts: List[str] = []
        while isinstance(node, ast.Attribute):
            parts.append(node.attr)
            node = node.value
        if not isinstance(node, ast.Name):
            return None
        root = self.map.get(node.id, node.id)
        return ".".join([root] + list(reversed(parts)))


def _attr_path(node: ast.AST) -> Optional[str]:
    """Syntactic dotted path of a Name / self.attr chain (no alias
    resolution — used for tracking value identity, e.g. `self.state`)."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if not isinstance(node, ast.Name):
        return None
    return ".".join([node.id] + list(reversed(parts)))


def _pos(node: ast.AST) -> Tuple[int, int]:
    return (node.lineno, node.col_offset)


def _end(node: ast.AST) -> Tuple[int, int]:
    return (node.end_lineno or node.lineno,
            node.end_col_offset or node.col_offset)


def _functions(tree: ast.Module):
    """Every function/lambda-free scope: the module itself plus each
    (async) function def, outermost first."""
    yield tree
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            yield node


def _enclosing_loops(scope: ast.AST) -> Dict[ast.AST, List[ast.AST]]:
    """node -> stack of For/While loops (within `scope`) that enclose it,
    not descending into nested function defs."""
    out: Dict[ast.AST, List[ast.AST]] = {}

    def visit(node, stack):
        out[node] = list(stack)
        is_loop = isinstance(node, (ast.For, ast.While))
        if is_loop:
            stack = stack + [node]
        for child in ast.iter_child_nodes(node):
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            visit(child, stack)

    visit(scope, [])
    return out


def _branch_map(scope: ast.AST) -> Dict[ast.AST, Tuple]:
    """node -> chain of (branching-node id, arm) pairs, so two uses that
    live in mutually exclusive arms (if/else, except handlers) are not
    counted as sequential."""
    out: Dict[ast.AST, Tuple] = {}

    def visit(node, chain):
        out[node] = chain
        for child in ast.iter_child_nodes(node):
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            sub = chain
            if isinstance(node, ast.If):
                if any(child is s for s in node.body):
                    sub = chain + ((id(node), "body"),)
                elif any(child is s for s in node.orelse):
                    sub = chain + ((id(node), "orelse"),)
            elif isinstance(node, ast.Try):
                for arm, stmts in (("body", node.body),
                                   ("orelse", node.orelse),
                                   ("final", node.finalbody)):
                    if any(child is s for s in stmts):
                        sub = chain + ((id(node), arm),)
                if any(child is h for h in node.handlers):
                    sub = chain + ((id(node), "handlers"),)
            elif isinstance(node, ast.IfExp):
                if child is node.body:
                    sub = chain + ((id(node), "body"),)
                elif child is node.orelse:
                    sub = chain + ((id(node), "orelse"),)
            visit(child, sub)

    visit(scope, ())
    return out


def _exclusive(a: Tuple, b: Tuple) -> bool:
    """True when the two branch chains put the nodes in different arms of
    the same if/try — at most one of them executes."""
    da, db = dict(a), dict(b)
    return any(k in db and db[k] != arm for k, arm in da.items())


def _scope_walk(scope: ast.AST):
    """Walk a scope without descending into nested function defs (the
    nested def is its own scope)."""
    stack = [scope]
    while stack:
        node = stack.pop()
        yield node
        for child in ast.iter_child_nodes(node):
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            stack.append(child)


# ---------------------------------------------------------------------------
# SC001 unused imports
# ---------------------------------------------------------------------------
def _check_unused_imports(tree: ast.Module, path: str) -> List[Finding]:
    if path.endswith("__init__.py"):
        return []
    exported: Set[str] = set()
    for node in tree.body:
        if isinstance(node, ast.Assign):
            for t in node.targets:
                if isinstance(t, ast.Name) and t.id == "__all__" \
                        and isinstance(node.value, (ast.List, ast.Tuple)):
                    exported |= {e.value for e in node.value.elts
                                 if isinstance(e, ast.Constant)}
    bindings: Dict[str, Tuple[int, str]] = {}
    for node in tree.body:
        if isinstance(node, ast.Import):
            for a in node.names:
                name = a.asname or a.name.split(".")[0]
                bindings[name] = (node.lineno, a.name)
        elif isinstance(node, ast.ImportFrom):
            if node.module == "__future__":
                continue
            for a in node.names:
                if a.name == "*":
                    continue
                name = a.asname or a.name
                bindings[name] = (node.lineno, a.name)
    used: Set[str] = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.Name) and isinstance(node.ctx, ast.Load):
            used.add(node.id)
        elif isinstance(node, ast.Constant) and isinstance(node.value, str):
            pass
    out = []
    for name, (line, target) in sorted(bindings.items()):
        if name in used or name in exported or name == "_":
            continue
        out.append(Finding("SC001", path, line,
                           f"import '{name}' ({target}) is never used"))
    return out


# ---------------------------------------------------------------------------
# SC101 / SC102: PRNG key discipline
# ---------------------------------------------------------------------------
def _check_keys(tree: ast.Module, aliases: _Aliases, path: str,
                config: LintConfig, force_library: bool = False
                ) -> List[Finding]:
    out: List[Finding] = []
    posix = path.replace("\\", "/")
    parts = set(posix.split("/"))
    key_exempt = (bool(parts & set(config.raw_key_exempt_parts))
                  or posix.rsplit("/", 1)[-1].startswith("test_")) \
        and not force_library

    for scope in _functions(tree):
        loops = _enclosing_loops(scope)
        branches = _branch_map(scope)
        # events per name, in source order
        bindings: List[Tuple[Tuple[int, int], str, ast.AST]] = []   # key binds
        stores: List[Tuple[Tuple[int, int], str, Optional[ast.AST]]] = []
        consumes: List[Tuple[Tuple[int, int], str, ast.AST]] = []

        for node in _scope_walk(scope):
            if isinstance(node, ast.Assign):
                is_key_src = isinstance(node.value, ast.Call) and \
                    aliases.dotted(node.value.func) in KEY_SOURCES
                for t in node.targets:
                    targets = t.elts if isinstance(t, (ast.Tuple, ast.List)) \
                        else [t]
                    for el in targets:
                        if isinstance(el, ast.Name):
                            stores.append((_pos(node), el.id, node))
                            if is_key_src:
                                bindings.append((_pos(node), el.id, node))
            elif isinstance(node, ast.Call):
                callee = aliases.dotted(node.func)
                if callee in KEY_DERIVERS:
                    continue                      # deriving != consuming
                for arg in list(node.args) + [kw.value for kw in
                                              node.keywords]:
                    if isinstance(arg, ast.Name):
                        consumes.append((_pos(arg), arg.id, node))
                # SC102: raw constant seed
                if callee == "jax.random.PRNGKey" and not key_exempt \
                        and node.args \
                        and isinstance(node.args[0], ast.Constant):
                    out.append(Finding(
                        "SC102", path, node.lineno,
                        "raw jax.random.PRNGKey("
                        f"{node.args[0].value!r}) in library code: a "
                        "constant seed aliases every caller onto one "
                        "noise stream — thread a key in (tests/examples "
                        "are exempt)"))

        # consumptions inside a `return` terminate their path: a guard
        # clause (`if p: return f(k)`) is exclusive with later code
        ret_of: Dict[int, int] = {}
        for node in _scope_walk(scope):
            if isinstance(node, (ast.Return, ast.Raise)):
                for sub in ast.walk(node):
                    if isinstance(sub, ast.Call):
                        ret_of[id(sub)] = id(node)

        bindings.sort()
        stores.sort()
        consumes.sort()
        for bpos, name, bnode in bindings:
            # window: from this binding to the next store of `name`
            nxt = next((p for p, n, _ in stores if n == name and p > bpos),
                       (1 << 30, 0))
            window = [(p, cnode) for p, n, cnode in consumes
                      if n == name and bpos < p < nxt]
            # a second consumption counts only when it can execute in the
            # same run as an earlier one (exclusive if/else arms are fine)
            for i, (p, cnode) in enumerate(window):
                def _can_follow(prev):
                    if _exclusive(branches.get(cnode, ()),
                                  branches.get(prev, ())):
                        return False
                    prev_ret = ret_of.get(id(prev))
                    if prev_ret is not None \
                            and prev_ret != ret_of.get(id(cnode)):
                        return False          # earlier path returned
                    return True

                if i >= 1 and any(_can_follow(prev)
                                  for _, prev in window[:i]):
                    out.append(Finding(
                        "SC101", path, p[0],
                        f"PRNG key '{name}' (bound at line {bpos[0]}) is "
                        "consumed more than once in this scope — derive a "
                        "fresh subkey with split/fold_in instead of "
                        "reusing the key"))
                    break
            # loop reuse: one consumption inside a loop the binding is
            # outside of, with no rebind of `name` inside that loop
            for p, cnode in window[:1]:
                for loop in loops.get(cnode, []):
                    binding_inside = loop in loops.get(bnode, [])
                    if binding_inside:
                        continue
                    loop_span = (_pos(loop), _end(loop))
                    rebound = any(loop_span[0] <= sp <= loop_span[1]
                                  for sp, n, _ in stores if n == name)
                    if not rebound:
                        out.append(Finding(
                            "SC101", path, p[0],
                            f"PRNG key '{name}' (bound at line {bpos[0]}, "
                            "outside this loop) is consumed inside the "
                            "loop without being rebound — every "
                            "iteration reuses the same key"))
    return out


# ---------------------------------------------------------------------------
# SC103 host syncs in hot-path modules
# ---------------------------------------------------------------------------
def _check_host_sync(tree: ast.Module, aliases: _Aliases,
                     path: str) -> List[Finding]:
    out: List[Finding] = []
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        callee = aliases.dotted(node.func)
        if callee in HOST_SYNC_CALLS:
            out.append(Finding(
                "SC103", path, node.lineno,
                f"host-sync call {callee}() in a serve hot-path module: "
                "the steady-state loop's contract is one sanctioned "
                "device fetch per poll — move this off the hot path or "
                "allowlist the sanctioned fetch with a justification"))
        elif isinstance(node.func, ast.Attribute) \
                and node.func.attr in HOST_SYNC_METHODS and not node.args:
            out.append(Finding(
                "SC103", path, node.lineno,
                f".{node.func.attr}() in a serve hot-path module forces a "
                "device sync"))
        elif isinstance(node.func, ast.Name) and node.func.id == "float" \
                and node.args and not isinstance(node.args[0], ast.Constant):
            out.append(Finding(
                "SC103", path, node.lineno,
                "float(...) on a non-literal in a serve hot-path module "
                "blocks on the device value"))
    return out


# ---------------------------------------------------------------------------
# SC104 float literals in the jnp coefficient graph
# ---------------------------------------------------------------------------
def _roots_jnp(node: ast.AST, aliases: _Aliases) -> bool:
    for sub in ast.walk(node):
        if isinstance(sub, (ast.Name, ast.Attribute)):
            dotted = aliases.dotted(sub)
            if dotted and (dotted == "jax.numpy"
                           or dotted.startswith("jax.numpy.")):
                return True
    return False


def _has_float_literal(node: ast.AST) -> Optional[ast.Constant]:
    for sub in ast.walk(node):
        if isinstance(sub, ast.Constant) and isinstance(sub.value, float):
            return sub
    return None


def _check_coeff_literals(tree: ast.Module, aliases: _Aliases,
                          path: str) -> List[Finding]:
    out: List[Finding] = []
    msg = ("Python float literal in a jnp expression of a coefficient-"
           "critical module: coefficients must be built in Stage-I "
           "float64 numpy and converted once — a literal in the device "
           "graph re-derives the value under weak-type promotion and "
           "silently breaks the bitwise factored==dense contract")
    for node in ast.walk(tree):
        if isinstance(node, ast.BinOp):
            pairs = ((node.left, node.right), (node.right, node.left))
            for a, b in pairs:
                lit = _has_float_literal(a)
                if lit is not None and _roots_jnp(b, aliases):
                    out.append(Finding("SC104", path, lit.lineno, msg))
                    break
        elif isinstance(node, ast.Call) and _roots_jnp(node.func, aliases):
            for arg in node.args:
                if isinstance(arg, ast.Constant) \
                        and isinstance(arg.value, float):
                    out.append(Finding("SC104", path, arg.lineno, msg))
    return out


# ---------------------------------------------------------------------------
# SC105 donation safety
# ---------------------------------------------------------------------------
def _donate_literal(node: ast.AST) -> Optional[Tuple[int, ...]]:
    if isinstance(node, ast.Tuple):
        vals = []
        for e in node.elts:
            if not (isinstance(e, ast.Constant) and isinstance(e.value, int)):
                return None
            vals.append(e.value)
        return tuple(vals)
    if isinstance(node, ast.Constant) and isinstance(node.value, int):
        return (node.value,)
    return None


def _resolve_factories(tree: ast.Module, aliases: _Aliases
                       ) -> Dict[str, object]:
    """Functions in this module that return a donating jit: name ->
    donate tuple, or the parameter *index* the tuple is passed through
    (transitively resolved, e.g. _make_token_admit -> _jit_state_update
    -> jax.jit)."""
    factories: Dict[str, object] = {}
    fdefs = {n.name: n for n in ast.walk(tree)
             if isinstance(n, ast.FunctionDef)}

    def returns_of(fn: ast.FunctionDef):
        for node in _scope_walk(fn):
            if isinstance(node, ast.Return) and node.value is not None:
                yield node.value

    changed = True
    while changed:
        changed = False
        for name, fn in fdefs.items():
            if name in factories:
                continue
            params = [a.arg for a in fn.args.args]
            for ret in returns_of(fn):
                if not isinstance(ret, ast.Call):
                    continue
                callee = aliases.dotted(ret.func)
                if callee == "jax.jit":
                    for kw in ret.keywords:
                        if kw.arg != "donate_argnums":
                            continue
                        lit = _donate_literal(kw.value)
                        if lit is not None:
                            factories[name] = lit
                        elif isinstance(kw.value, ast.Name) \
                                and kw.value.id in params:
                            factories[name] = ("param",
                                               params.index(kw.value.id))
                        changed = name in factories
                elif isinstance(ret.func, ast.Name) \
                        and ret.func.id in factories:
                    inner = factories[ret.func.id]
                    if isinstance(inner, tuple) and inner[:1] == ("param",):
                        idx = inner[1]
                        if idx < len(ret.args):
                            lit = _donate_literal(ret.args[idx])
                            if lit is not None:
                                factories[name] = lit
                                changed = True
                    else:
                        factories[name] = inner
                        changed = True
                if name in factories:
                    break
    return factories


def _donating_value(call: ast.Call, aliases: _Aliases,
                    factories: Dict[str, object]) -> Optional[Tuple[int, ...]]:
    """Donate tuple of the callable produced by `call`, if resolvable."""
    callee = aliases.dotted(call.func)
    if callee == "jax.jit":
        for kw in call.keywords:
            if kw.arg == "donate_argnums":
                return _donate_literal(kw.value)
        return None
    if isinstance(call.func, ast.Name) and call.func.id in factories:
        spec = factories[call.func.id]
        if isinstance(spec, tuple) and spec[:1] == ("param",):
            idx = spec[1]
            if idx < len(call.args):
                return _donate_literal(call.args[idx])
            return None
        return spec  # fixed tuple
    return None


def _check_donation(tree: ast.Module, aliases: _Aliases,
                    path: str) -> List[Finding]:
    out: List[Finding] = []
    factories = _resolve_factories(tree, aliases)

    # donating-callable bindings: `self._decode = <jit/factory>(...)` or
    # `step = jax.jit(..., donate_argnums=...)`; dict literals /
    # comprehensions of factory calls bind the attribute as subscripted
    donors: Dict[str, Tuple[int, ...]] = {}          # "_decode" / "step_fn"
    subscripted: Dict[str, Tuple[int, ...]] = {}     # "_steps"
    for node in ast.walk(tree):
        if not isinstance(node, ast.Assign) or len(node.targets) != 1:
            continue
        target = node.targets[0]
        tpath = _attr_path(target)
        if tpath is None:
            continue
        key = tpath.split(".")[-1]
        value = node.value
        if isinstance(value, ast.Call):
            donate = _donating_value(value, aliases, factories)
            if donate:
                donors[key] = donate
        elif isinstance(value, ast.DictComp) \
                and isinstance(value.value, ast.Call):
            donate = _donating_value(value.value, aliases, factories)
            if donate:
                subscripted[key] = donate
        elif isinstance(value, ast.Dict):
            for v in value.values:
                if isinstance(v, ast.Call):
                    donate = _donating_value(v, aliases, factories)
                    if donate:
                        subscripted[key] = donate
                        break

    def call_donate(call: ast.Call) -> Optional[Tuple[int, ...]]:
        func = call.func
        if isinstance(func, ast.Call):
            # immediately-invoked: jax.jit(f, donate_argnums=...)(x, ...)
            return _donating_value(func, aliases, factories)
        if isinstance(func, ast.Subscript):
            base = _attr_path(func.value)
            if base is not None and base.split(".")[-1] in subscripted:
                return subscripted[base.split(".")[-1]]
            return None
        fpath = _attr_path(func)
        if fpath is not None and fpath.split(".")[-1] in donors:
            return donors[fpath.split(".")[-1]]
        # note: a bare `jax.jit(...)` / factory call *constructs* the
        # donating callable — it is not itself a donating call site
        return None

    for scope in _functions(tree):
        if isinstance(scope, ast.Module):
            continue
        loops = _enclosing_loops(scope)
        # statements of this scope in source order, with accesses
        accesses: List[Tuple[Tuple[int, int], str, bool]] = []  # (pos, path, is_store)
        for node in _scope_walk(scope):
            if isinstance(node, (ast.Name, ast.Attribute)):
                p = _attr_path(node)
                if p is None:
                    continue
                if isinstance(node.ctx, ast.Store):
                    accesses.append((_pos(node), p, True))
                elif isinstance(node.ctx, ast.Load):
                    accesses.append((_pos(node), p, False))
        accesses.sort()

        for node in _scope_walk(scope):
            if not isinstance(node, ast.Call):
                continue
            donate = call_donate(node)
            if not donate:
                continue
            stmt = _enclosing_stmt(scope, node)
            if stmt is None:
                continue
            stmt_end = _end(stmt)
            for pos in donate:
                if pos >= len(node.args):
                    continue
                dpath = _attr_path(node.args[pos])
                if dpath is None:
                    continue
                # `x = step(x)` — the donated path is re-stored by the
                # call statement itself, the canonical safe pattern
                stored_here = _stmt_stores(stmt, dpath)
                if not stored_here:
                    later = [(p, ap, st) for p, ap, st in accesses
                             if p > stmt_end
                             and (ap == dpath
                                  or ap.startswith(dpath + "."))]
                    for p, ap, is_store in later:
                        if is_store and ap == dpath:
                            break
                        if not is_store:
                            out.append(Finding(
                                "SC105", path, p[0],
                                f"'{dpath}' was donated to a jitted call "
                                f"at line {node.lineno} (donate_argnums) "
                                "and is read again here — the buffer may "
                                "already be reused by XLA; reassign from "
                                "the call result or copy first"))
                            break
                # loop reuse: donated in a loop without re-storing it
                if not stored_here:
                    for loop in loops.get(node, []):
                        span = (_pos(loop), _end(loop))
                        rebound = any(span[0] <= p <= span[1] and st
                                      and ap == dpath
                                      for p, ap, st in accesses)
                        if not rebound:
                            out.append(Finding(
                                "SC105", path, node.lineno,
                                f"'{dpath}' is donated inside this loop "
                                "but never reassigned in it — the next "
                                "iteration donates a dead buffer"))
                            break
    return out


def _enclosing_stmt(scope: ast.AST, node: ast.AST) -> Optional[ast.stmt]:
    """Innermost simple statement of `scope` containing `node`."""
    best = None
    np_, ne = _pos(node), _end(node)
    for cand in _scope_walk(scope):
        if not isinstance(cand, ast.stmt) or isinstance(
                cand, (ast.For, ast.While, ast.If, ast.With, ast.Try,
                       ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        if _pos(cand) <= np_ and ne <= _end(cand):
            if best is None or _pos(cand) >= _pos(best):
                best = cand
    return best


def _stmt_stores(stmt: ast.stmt, dpath: str) -> bool:
    for node in ast.walk(stmt):
        if isinstance(node, (ast.Name, ast.Attribute)) \
                and isinstance(node.ctx, ast.Store) \
                and _attr_path(node) == dpath:
            return True
    return False


# ---------------------------------------------------------------------------
# entry point
# ---------------------------------------------------------------------------
def lint_source(source: str, path: str,
                config: LintConfig = DEFAULT_CONFIG) -> List[Finding]:
    try:
        tree = ast.parse(source, filename=path)
    except SyntaxError as e:
        return [Finding("SC900", path, e.lineno or 1,
                        f"syntax error: {e.msg}")]
    aliases = _Aliases(tree)
    posix = path.replace("\\", "/")
    pragma_modes = {line.split(MODULE_PRAGMA, 1)[1].strip()
                    for line in source.splitlines()
                    if MODULE_PRAGMA in line}
    hot = any(posix.endswith(s) for s in config.hot_path_suffixes) \
        or "hot-path" in pragma_modes
    coeff = any(posix.endswith(s) for s in config.coeff_critical_suffixes) \
        or "coeff-critical" in pragma_modes
    # `module=library` opts a file *out* of the tests/examples raw-key
    # exemption (fixtures under tests/ that model library code)
    library = "library" in pragma_modes

    findings: List[Finding] = []
    findings += _check_unused_imports(tree, path)
    findings += _check_keys(tree, aliases, path, config,
                            force_library=library)
    if hot:
        findings += _check_host_sync(tree, aliases, path)
    if coeff:
        findings += _check_coeff_literals(tree, aliases, path)
    findings += _check_donation(tree, aliases, path)

    disabled, bad_allowlist = parse_allowlist(source, path)
    return apply_allowlist(findings, disabled) + bad_allowlist


def lint_paths(paths: Sequence[str],
               config: LintConfig = DEFAULT_CONFIG) -> List[Finding]:
    import os
    files: List[str] = []
    for p in paths:
        if os.path.isdir(p):
            for root, _dirs, names in os.walk(p):
                if "__pycache__" in root:
                    continue
                files += [os.path.join(root, n) for n in sorted(names)
                          if n.endswith(".py")]
        else:
            files.append(p)
    out: List[Finding] = []
    for f in sorted(set(files)):
        with open(f, encoding="utf-8") as fh:
            out += lint_source(fh.read(), f, config)
    return out

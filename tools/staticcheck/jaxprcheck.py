"""Layer 2 core: jaxpr walks, compiled-artifact audits, structural hash.

Everything here operates on artifacts of `jitted.trace(*args)` — the
ClosedJaxpr, the lowered StableHLO text, and the compiled HLO text — so
the properties it checks are facts about *what will run*, not about what
the Python source looks like.

Rules:

  JX101  callback / host op in the jaxpr (pure_callback, io_callback,
         debug_callback, infeed/outfeed, ...): a steady-state step with a
         host round-trip silently serializes the device pipeline.
  JX102  dtype discipline: any float64 / complex128 / int64 abstract
         value anywhere in the program (the repo computes in f32 with
         Stage-I quadrature confined to *host* numpy float64), and — over
         the coefficient-apply subgraph — any floating dtype that is not
         exactly f32 (a bf16 detour through the coefficient path breaks
         the bitwise factored==dense contract).
  JX103  dropped donation: the lowered module marks every donated
         argument (`tf.aliasing_output` / `jax.buffer_donor`); the
         compiled executable's `input_output_alias` table records what
         XLA actually honored.  Marks without alias entries mean XLA
         silently fell back to copying — the in-place state update the
         serve loop relies on no longer happens.
  JX104  host transfer op (infeed/outfeed/send/recv/host custom-call) in
         a compiled steady-state program.
  JX105  recompile hazard: the canonical structural hash of a serve
         variant changed after the mixed-config menu was registered —
         some config escaped its coefficient-bank bucket, so steady-state
         traffic would retrace.
"""
from __future__ import annotations

import hashlib
import re
from typing import Iterator, List, Tuple

from .findings import Finding

# jaxpr primitive names that imply a host round-trip
_CALLBACK_TOKENS = ("callback", "infeed", "outfeed", "host_callback")
# HLO text tokens that imply host traffic in the compiled program
_HLO_HOST_RE = re.compile(
    r"\b(infeed|outfeed)\b|custom_call_target=\"(xla_python[^\"]*|[^\"]*host[^\"]*)\"")
_ALIAS_BLOCK_RE = re.compile(r"input_output_alias=\{")
_DISALLOWED_DTYPES = ("float64", "complex64", "complex128", "int64")


def iter_eqns(jaxpr) -> Iterator:
    """Every equation in a (Closed)Jaxpr, recursing into sub-jaxprs held
    in equation params (pjit bodies, scan/while/cond branches, ...)."""
    inner = getattr(jaxpr, "jaxpr", jaxpr)
    for eqn in inner.eqns:
        yield eqn
        for sub in _sub_jaxprs(eqn.params):
            yield from iter_eqns(sub)


def _sub_jaxprs(params: dict) -> Iterator:
    for v in params.values():
        yield from _as_jaxprs(v)


def _as_jaxprs(v) -> Iterator:
    if hasattr(v, "eqns") or hasattr(v, "jaxpr"):
        yield v
    elif isinstance(v, (tuple, list)):
        for item in v:
            yield from _as_jaxprs(item)


def _all_avals(jaxpr) -> Iterator[Tuple[str, object]]:
    """(context, aval) for every var in the program, including sub-jaxprs."""
    inner = getattr(jaxpr, "jaxpr", jaxpr)
    for var in list(inner.invars) + list(inner.outvars):
        if hasattr(var, "aval"):
            yield "interface", var.aval
    for eqn in iter_eqns(jaxpr):
        for var in list(eqn.invars) + list(eqn.outvars):
            if hasattr(var, "aval"):
                yield eqn.primitive.name, var.aval


# ---------------------------------------------------------------------------
# JX101 callbacks / host ops in the jaxpr
# ---------------------------------------------------------------------------
def check_no_callbacks(jaxpr, label: str) -> List[Finding]:
    out = []
    for eqn in iter_eqns(jaxpr):
        name = eqn.primitive.name
        if any(tok in name for tok in _CALLBACK_TOKENS):
            out.append(Finding(
                "JX101", "", 0,
                f"[{label}] host op '{name}' in the traced program — a "
                "steady-state step must not round-trip through Python"))
    return out


# ---------------------------------------------------------------------------
# JX102 dtype discipline
# ---------------------------------------------------------------------------
def check_dtypes(jaxpr, label: str, f32_only: bool = False) -> List[Finding]:
    """No f64/c128/i64 anywhere; with `f32_only` (the coefficient-apply
    subgraph) additionally no floating dtype other than float32."""
    out, seen = [], set()
    for ctx, aval in _all_avals(jaxpr):
        dt = str(getattr(aval, "dtype", ""))
        if not dt:
            continue
        key = (ctx, dt)
        if key in seen:
            continue
        if any(dt == bad for bad in _DISALLOWED_DTYPES):
            seen.add(key)
            out.append(Finding(
                "JX102", "", 0,
                f"[{label}] {dt} value reaches the compiled program "
                f"(at '{ctx}') — compute is f32; float64 lives only in "
                "host-side Stage-I quadrature"))
        elif f32_only and dt.startswith(("float", "bfloat")) \
                and dt != "float32":
            seen.add(key)
            out.append(Finding(
                "JX102", "", 0,
                f"[{label}] {dt} value in the coefficient-apply subgraph "
                f"(at '{ctx}') — the bitwise factored==dense contract "
                "requires exact f32 end to end"))
    return out


# ---------------------------------------------------------------------------
# JX103 donation audit over the compiled executable
# ---------------------------------------------------------------------------
def count_requested_donations(lowered_text: str) -> int:
    """Donation marks in the lowered StableHLO: `tf.aliasing_output` is a
    donation XLA intends to alias; `jax.buffer_donor` is donated but not
    yet pinned to an output."""
    return (lowered_text.count("tf.aliasing_output")
            + lowered_text.count("jax.buffer_donor"))


def count_granted_aliases(compiled_text: str) -> int:
    """Entries in the executable's input_output_alias table."""
    m = _ALIAS_BLOCK_RE.search(compiled_text)
    if not m:
        return 0
    # the block nests braces: count alias kinds up to the closing '}' of
    # the table, conservatively scanning a bounded window
    window = compiled_text[m.end():m.end() + 4096]
    end = window.find("}\n")
    body = window[:end] if end >= 0 else window
    return body.count("may-alias") + body.count("must-alias")


def check_donation(lowered_text: str, compiled_text: str, label: str,
                   expect_donation: bool = True) -> List[Finding]:
    requested = count_requested_donations(lowered_text)
    granted = count_granted_aliases(compiled_text)
    out = []
    if expect_donation and requested == 0:
        out.append(Finding(
            "JX103", "", 0,
            f"[{label}] no donation marks in the lowered module — "
            "donate_argnums was dropped before lowering (all-copy state "
            "update)"))
    if granted < requested:
        out.append(Finding(
            "JX103", "", 0,
            f"[{label}] XLA honored {granted}/{requested} requested "
            "donations — the executable copies buffers the serve loop "
            "expects to update in place"))
    return out


# ---------------------------------------------------------------------------
# JX104 host transfers in the compiled program
# ---------------------------------------------------------------------------
def check_no_host_transfers(compiled_text: str, label: str) -> List[Finding]:
    out = []
    for m in _HLO_HOST_RE.finditer(compiled_text):
        out.append(Finding(
            "JX104", "", 0,
            f"[{label}] host-transfer construct '{m.group(0)}' in the "
            "compiled steady-state program — the zero-transfer serving "
            "contract is broken at compile time"))
    return out[:4]          # one program rarely needs more than a sample


# ---------------------------------------------------------------------------
# JX105 structural hash
# ---------------------------------------------------------------------------
_ADDR_RE = re.compile(r"0x[0-9a-fA-F]+")
# params that carry source locations / debug info, not program structure
_HASH_SKIP_PARAMS = ("name_and_src_info", "debug", "cost_estimate",
                     "backend", "name", "debug_info", "symbol_name",
                     "metadata", "interpret", "compiler_params")


def jaxpr_hash(jaxpr) -> str:
    """Canonical structural hash: variables renamed in order of first
    appearance, equations serialized as (primitive, in, out, params) with
    sub-jaxprs hashed recursively and debug/source params dropped.  Two
    traces of the same cost class — same shapes/dtypes/statics, any
    config values — produce the same hash."""
    h = hashlib.sha256()
    h.update(_serialize(jaxpr).encode())
    return h.hexdigest()[:16]


def _serialize(jaxpr) -> str:
    inner = getattr(jaxpr, "jaxpr", jaxpr)
    names: dict = {}

    def nm(var) -> str:
        if hasattr(var, "val"):               # Literal (unhashable)
            return f"lit:{_ADDR_RE.sub('0xX', repr(var.val))}"
        if var not in names:
            names[var] = f"v{len(names)}"
        return f"{names[var]}:{var.aval.str_short()}"

    parts = ["in(" + ",".join(nm(v) for v in inner.invars) + ")"]
    for eqn in inner.eqns:
        ps = []
        for k in sorted(eqn.params):
            if k in _HASH_SKIP_PARAMS:
                continue
            v = eqn.params[k]
            subs = list(_as_jaxprs(v))
            if subs:
                ps.append(f"{k}=[" + ",".join(_serialize(s) for s in subs)
                          + "]")
            else:
                ps.append(f"{k}={_ADDR_RE.sub('0xX', repr(v))}")
        parts.append(f"{eqn.primitive.name}(" +
                     ",".join(nm(v) for v in eqn.invars) + ")->(" +
                     ",".join(nm(v) for v in eqn.outvars) + "){" +
                     ";".join(ps) + "}")
    parts.append("out(" + ",".join(nm(v) for v in inner.outvars) + ")")
    return "|".join(parts)


def check_hash_stability(before: dict, after: dict,
                         label: str) -> List[Finding]:
    """`before`/`after`: variant-name -> hash, traced pre/post registering
    the mixed-config menu.  Any drift means a config escaped its bucket
    and steady-state traffic would recompile."""
    out = []
    for name in sorted(before):
        if name in after and after[name] != before[name]:
            out.append(Finding(
                "JX105", "", 0,
                f"[{label}] structural hash of '{name}' changed after the "
                f"mixed menu was registered ({before[name]} -> "
                f"{after[name]}) — a sampler config escaped its "
                "coefficient-bank bucket; steady state would recompile"))
    return out

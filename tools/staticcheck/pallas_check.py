"""Layer 2: static checks over Pallas kernel launches.

Works on the `pallas_call` equations found in a traced jaxpr (tracing —
not lowering — so it runs on any backend, including the CPU CI runner):

  PL201  BlockSpec divisibility: every blocked dimension must divide the
         array dimension it tiles.  A ragged tile means the kernel reads
         or writes out-of-bounds lanes on the last grid step (masked on
         TPU, garbage in interpret mode — either way not the contract the
         kernels document).
  PL202  index-map bounds: evaluating each BlockSpec's index map at every
         corner of the grid must keep `block_index * block_shape` inside
         the array for every dimension.
  PL203  memory budget: the per-grid-step working set — all VMEM blocks
         double-buffered, plus scratch — must fit the per-core VMEM
         budget, and SMEM operands the SMEM budget (conservative TPU
         figures; see /opt/skills/guides/pallas_guide.md).

Entry points are discovered via each kernel package's
`staticcheck_entries()` (ops.py), which returns named example traces at
representative serve shapes.
"""
from __future__ import annotations

import itertools
from typing import List

from .findings import Finding
from .jaxprcheck import iter_eqns

VMEM_BUDGET = 16 * 2 ** 20        # ~16 MiB/core (v4/v5 class)
SMEM_BUDGET = 1 * 2 ** 20         # conservative scalar-memory ceiling


def find_pallas_eqns(jaxpr) -> List:
    return [e for e in iter_eqns(jaxpr) if e.primitive.name == "pallas_call"]


def _dtype_bytes(aval) -> int:
    import numpy as np
    return int(np.dtype(aval.dtype).itemsize)


def _eval_index_map(bm, idx) -> List[int]:
    import jax.core as jcore
    imj = bm.index_map_jaxpr
    out = jcore.eval_jaxpr(imj.jaxpr, imj.consts, *idx)
    return [int(v) for v in out]


def _grid_corners(grid):
    axes = [sorted({0, max(int(g) - 1, 0)}) for g in grid]
    return itertools.product(*axes)


def check_pallas_eqn(eqn, label: str) -> List[Finding]:
    out: List[Finding] = []
    gm = eqn.params["grid_mapping"]
    grid = tuple(int(g) for g in gm.grid)
    shapes = [tuple(s.shape) for s in gm.in_shapes] + \
             [tuple(s.shape) for s in gm.out_shapes]
    bms = list(gm.block_mappings)
    if len(bms) != len(shapes):          # index operands offset the zip
        shapes = shapes[len(bms) - len(shapes):] if len(shapes) > len(bms) \
            else shapes

    vmem_bytes = 0
    smem_bytes = 0
    for op, (bm, ashape) in enumerate(zip(bms, shapes)):
        bshape = tuple(bm.block_shape)
        is_smem = "smem" in str(bm.block_aval).lower()
        nbytes = _dtype_bytes(bm.block_aval.inner_aval
                              if hasattr(bm.block_aval, "inner_aval")
                              else bm.block_aval)
        for d in bshape:
            nbytes *= int(d) if isinstance(d, int) else 1
        if is_smem:
            smem_bytes += nbytes
        else:
            vmem_bytes += 2 * nbytes          # double-buffered pipeline

        if is_smem or len(bshape) != len(ashape):
            continue                           # unblocked operand
        # PL201: divisibility
        for dim, (bd, ad) in enumerate(zip(bshape, ashape)):
            if isinstance(bd, int) and bd > 0 and ad % bd:
                out.append(Finding(
                    "PL201", "", 0,
                    f"[{label}] operand {op}: block shape {bshape} does "
                    f"not divide array shape {ashape} at dim {dim} "
                    f"({ad} % {bd} != 0) — the last grid step tiles out "
                    "of bounds"))
        # PL202: index-map bounds at the grid corners
        for idx in _grid_corners(grid):
            try:
                bidx = _eval_index_map(bm, idx)
            except Exception as e:           # index map not evaluable
                out.append(Finding(
                    "PL202", "", 0,
                    f"[{label}] operand {op}: index map failed to "
                    f"evaluate at grid index {idx}: {e}"))
                break
            for dim, (bi, bd, ad) in enumerate(zip(bidx, bshape, ashape)):
                if not isinstance(bd, int):
                    continue
                start = bi * bd
                if start < 0 or start + bd > ad:
                    out.append(Finding(
                        "PL202", "", 0,
                        f"[{label}] operand {op}: index map at grid "
                        f"{idx} selects block {bidx} -> elements "
                        f"[{start}, {start + bd}) outside dim {dim} of "
                        f"{ashape}"))
                    break

    # scratch operands live in VMEM for the whole call (not double-buffered)
    body = eqn.params.get("jaxpr")
    if body is not None and getattr(gm, "num_scratch_operands", 0):
        inner = getattr(body, "jaxpr", body)
        for var in inner.invars[-gm.num_scratch_operands:]:
            aval = getattr(var.aval, "inner_aval", var.aval)
            n = _dtype_bytes(aval)
            for d in getattr(aval, "shape", ()):
                n *= int(d)
            vmem_bytes += n

    if vmem_bytes > VMEM_BUDGET:
        out.append(Finding(
            "PL203", "", 0,
            f"[{label}] per-step VMEM working set ~{vmem_bytes} B "
            f"(double-buffered blocks + scratch) exceeds the "
            f"{VMEM_BUDGET} B budget"))
    if smem_bytes > SMEM_BUDGET:
        out.append(Finding(
            "PL203", "", 0,
            f"[{label}] SMEM operands ~{smem_bytes} B exceed the "
            f"{SMEM_BUDGET} B budget"))
    return out


def check_if_present(jaxpr, label: str) -> List[Finding]:
    """Pallas checks over any pallas_call the trace happens to contain
    (serve variants on the CPU ref path legitimately contain none)."""
    out: List[Finding] = []
    for eqn in find_pallas_eqns(jaxpr):
        out += check_pallas_eqn(eqn, label)
    return out


def check_traced(jaxpr, label: str) -> List[Finding]:
    """All Pallas checks over every pallas_call in a traced program."""
    out: List[Finding] = []
    eqns = find_pallas_eqns(jaxpr)
    if not eqns:
        out.append(Finding(
            "PL200", "", 0,
            f"[{label}] expected a pallas_call in this entry's trace but "
            "found none — the staticcheck entry no longer exercises the "
            "kernel"))
    for eqn in eqns:
        out += check_pallas_eqn(eqn, label)
    return out

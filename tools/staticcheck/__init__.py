"""Two-layer static analysis for the serving invariants.

Layer 1 (`astlint`): repo-specific AST lint — PRNG key discipline,
hot-path host-sync bans, coefficient-graph float-literal hygiene,
donation safety.  Layer 2 (`jaxprcheck` + `pallas_check` over `menu`):
trace/lower/compile every serve variant and statically verify no host
ops, dtype discipline, honored donations, no steady-state transfers,
Pallas BlockSpec/grid/memory sanity, and recompile-freedom via structural
jaxpr hashes.

Run: `python -m tools.staticcheck src/ --sanitize` (see docs/
static_analysis.md).
"""
from .findings import Finding, emit, parse_allowlist  # noqa: F401

#!/usr/bin/env python
"""CI perf guard over the serving benchmark's *deterministic* counters.

    python tools/perf_guard.py BASELINE.json FRESH.json

Compares a fresh `BENCH_serving.json` (written by `python -m benchmarks.run
serving`) against the committed baseline and fails on regressions in the
counters that are pure functions of the request schedule — recompiles after
warmup, serving rounds / step dispatches / polls per schedule, prefill-wave
count — so the job is timing-free and stable on shared CI runners (wall
times in the records are reported but never gated).

Rules, per record matched by `config`:

  * `recompiles_after_warmup`, `rounds`, `dispatches`, `polls`,
    `n_prefills`, `bank_bytes`, `bank_restack_rows` — must not exceed the
    baseline (a decrease is an improvement and passes; commit the fresh
    JSON to ratchet it in).  `bank_bytes` is the device-resident size of
    the factored coefficient bank — a reintroduced dense bank layout
    blows it up ~D-fold and fails here.
  * `n_requests`, `n_configs`, `batch`, `nfe`, `bank_bytes_dense`,
    `n_variants` — schedule/layout identity; any drift means the benchmark
    no longer measures the same thing and the baseline must be regenerated
    deliberately, so a mismatch fails.  (`bank_bytes_dense` is the
    analytic dense-equivalent byte count — the denominator of the
    factored bank's committed >= 100x residency win.  `n_variants` is the
    jaxpr structural-hash-set cardinality of the multi-family engine's
    round-step compile buckets — a new bucket is a new compile in steady
    state, which is a reviewed event, not an accident; the per-bucket
    `variant_hashes` are gated exactly too, so a swapped program body
    with the same bucket count still fails loudly.)
    The online record's preemption counters (`n_preemptions`, `n_resumes`,
    `deadline_misses`) are exact too: at a fixed seed the virtual-clock
    replay is deterministic, so any drift means the schedule changed.
    The roofline record's `kernel_launches_per_round` (pallas_call count
    in the traced fused round commit — the megakernel's 1-launch
    contract) and `round_bytes_moved` (the analytic single-pass byte
    model of that launch) are pure functions of static shapes: a second
    launch sneaking into the round, or an extra stream read, fails here.
    The `gddim_alg_quality_*` records' `sw2_milli` / `n_samples`
    (benchmarks/quality.py: per-algorithm quality vs NFE, seeded
    lockstep sampling on the exact-score oracle) are exact at a fixed
    platform — quality drift in a sampler algorithm is a reviewed
    event, same as a new compile bucket.
  * a baseline config missing from the fresh run fails (a silently dropped
    row is how perf coverage rots); fresh-only configs are reported but
    pass (new rows land with their own baseline in the same PR).

Under GitHub Actions every failure is also emitted as an `::error`
workflow command so regressions annotate the PR run directly.
"""
from __future__ import annotations

import json
import os
import sys
from typing import Dict, List

BOUNDED = ("recompiles_after_warmup", "rounds", "dispatches", "polls",
           "n_prefills", "bank_bytes", "bank_restack_rows")
EXACT = ("n_requests", "n_configs", "batch", "nfe", "bank_bytes_dense",
         "n_variants", "variant_hashes",
         "n_preemptions", "n_resumes", "deadline_misses",
         "kernel_launches_per_round", "round_bytes_moved",
         "requests_routed", "requeues", "health_probes", "n_shed",
         "n_replicas", "n_samples", "sw2_milli")


def _records(path: str) -> Dict[str, dict]:
    with open(path) as f:
        doc = json.load(f)
    out = {}
    for rec in doc.get("records", []):
        out[rec["config"]] = rec
    return out


def compare(baseline: Dict[str, dict], fresh: Dict[str, dict]) -> List[str]:
    errors = []
    for config, base in sorted(baseline.items()):
        got = fresh.get(config)
        if got is None:
            errors.append(f"{config}: present in baseline, missing from the "
                          "fresh run")
            continue
        for key in EXACT:
            if key in base and base.get(key) != got.get(key):
                errors.append(f"{config}: schedule field {key} drifted "
                              f"({base.get(key)} -> {got.get(key)}); "
                              "regenerate the baseline deliberately")
        for key in BOUNDED:
            if key not in base:
                continue
            if key not in got:
                errors.append(f"{config}: counter {key} missing from the "
                              "fresh run")
            elif got[key] > base[key]:
                errors.append(f"{config}: {key} regressed "
                              f"({base[key]} -> {got[key]})")
    return errors


def main(argv: List[str]) -> int:
    if len(argv) != 2:
        print(__doc__)
        return 2
    baseline, fresh = _records(argv[0]), _records(argv[1])
    errors = compare(baseline, fresh)
    extra = sorted(set(fresh) - set(baseline))
    if extra:
        print(f"new configs (no baseline yet, not gated): {extra}")
    for config in sorted(baseline):
        if config in fresh and not any(e.startswith(config + ":")
                                       for e in errors):
            counters = {k: fresh[config][k] for k in BOUNDED
                        if k in fresh[config]}
            print(f"ok {config}: {counters}")
    if errors:
        github = os.environ.get("GITHUB_ACTIONS") == "true"
        print(f"\nPERF GUARD FAILED ({len(errors)} regression(s)):")
        for e in errors:
            print(f"  {e}")
            if github:
                msg = e.replace("%", "%25").replace("\r", "%0D") \
                       .replace("\n", "%0A")
                print(f"::error title=perf-guard::{msg}")
        return 1
    print(f"\nperf guard passed: {len(baseline)} configs, "
          "deterministic counters no worse than baseline")
    return 0


if __name__ == "__main__":
    raise SystemExit(main(sys.argv[1:]))

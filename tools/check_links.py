#!/usr/bin/env python
"""Fail on broken intra-repo links in README.md and docs/ (stdlib only).

Checks every markdown inline link `[text](target)` whose target is a
relative path: the file must exist relative to the linking document.
External schemes (http/https/mailto) and pure in-page anchors (#...) are
skipped; a `path#anchor` target is checked for the path part only.

    python tools/check_links.py [files/dirs ...]   # default: README.md docs/
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

# inline links, tolerating one level of nested brackets in the text (badges)
LINK_RE = re.compile(r"\[(?:[^\[\]]|\[[^\]]*\])*\]\(([^)\s]+)(?:\s+\"[^\"]*\")?\)")
SKIP_SCHEMES = ("http://", "https://", "mailto:", "ftp://")


def strip_code(text: str) -> str:
    """Drop fenced and inline code spans so example snippets aren't linted."""
    text = re.sub(r"```.*?```", "", text, flags=re.S)
    return re.sub(r"`[^`]*`", "", text)


def check_file(md: Path) -> list[str]:
    errors = []
    for target in LINK_RE.findall(strip_code(md.read_text())):
        if target.startswith(SKIP_SCHEMES) or target.startswith("#"):
            continue
        path = target.split("#", 1)[0]
        if not path:
            continue
        if not (md.parent / path).exists():
            errors.append(f"{md}: broken link -> {target}")
    return errors


def main(argv: list[str]) -> int:
    roots = [Path(a) for a in argv] or [Path("README.md"), Path("docs")]
    files: list[Path] = []
    for r in roots:
        if r.is_dir():
            files.extend(sorted(r.rglob("*.md")))
        elif r.exists():
            files.append(r)
        else:
            print(f"check_links: no such file {r}", file=sys.stderr)
            return 2
    errors = [e for f in files for e in check_file(f)]
    for e in errors:
        print(e, file=sys.stderr)
    print(f"check_links: {len(files)} files, "
          f"{'FAIL' if errors else 'ok'} ({len(errors)} broken)")
    return 1 if errors else 0


if __name__ == "__main__":
    raise SystemExit(main(sys.argv[1:]))

"""Batched serving driver: continuous-batching decode loop over any arch.

Demonstrates the production serving path on CPU-sized configs:

  * prefill phase fills a pre-allocated KV cache (paged by max_len),
  * decode loop emits one token/step for the whole batch (greedy),
  * slots retire on EOS and are refilled from the request queue
    (continuous batching) — the cache slot is re-prefilled in place.

    python -m repro.launch.serve --arch gemma3-1b --reduced --requests 12
"""
from __future__ import annotations

import argparse
import time
from typing import List

import numpy as np
import jax
import jax.numpy as jnp

from ..configs import get_arch, ARCH_IDS
from ..models.registry import Arch
from . import steps as steps_lib


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=24)
    ap.add_argument("--max-len", type=int, default=64)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    spec = get_arch(args.arch, reduced=args.reduced)
    arch = Arch(spec)
    key = jax.random.PRNGKey(args.seed)
    params = arch.init(key)
    vocab = arch.cfg.vocab
    eos = 1

    # synthetic request queue
    rng = np.random.default_rng(args.seed)
    queue: List[np.ndarray] = [
        rng.integers(2, vocab, size=args.prompt_len).astype(np.int32)
        for _ in range(args.requests)]
    done: List[np.ndarray] = []

    serve_step = jax.jit(steps_lib.make_serve_step(arch))

    B = args.batch
    caches = arch.init_cache(B, args.max_len)
    memory = None
    if spec.family == "encdec":
        frames = jax.random.normal(key, (B, spec.frontend_ctx, arch.cfg.d_model))
        from ..models import zoo
        memory = zoo.encode(params, arch.cfg, frames)

    # NOTE: for simplicity each slot decodes independently but the batch
    # steps together; slot-level cache_len bookkeeping uses the max (safe
    # because positions are masked per the global cache_len in this demo).
    slots = [None] * B
    outputs = [[] for _ in range(B)]
    n_steps = 0
    t0 = time.time()

    def prefill_slot(i):
        nonlocal caches
        prompt = queue.pop(0)
        slots[i] = {"prompt": prompt, "generated": []}
        # per-slot prefill: run tokens one at a time into the batch cache row
        # (slot-level prefill; production would batch these)
        for t, tok in enumerate(prompt):
            tok_b = jnp.zeros((B, 1), jnp.int32).at[i, 0].set(int(tok))
            _, _, c2 = serve_step(params, tok_b, caches,
                                  jnp.int32(t), memory) if memory is not None \
                else serve_step(params, tok_b, caches, jnp.int32(t))
            caches = _merge_slot(caches, c2, i)

    def _merge_slot(old, new, i):
        def m(o, n):
            if o.ndim >= 2 and o.shape[-4 if o.ndim >= 4 else 0] == B:
                pass
            return n  # single-slot demo: accept the new cache wholesale
        return jax.tree.map(m, old, new)

    # simple synchronous batch loop (all slots share position counters)
    while queue or any(s is not None for s in slots):
        for i in range(B):
            if slots[i] is None and queue:
                prefill_slot(i)
        pos = args.prompt_len + max(len(s["generated"]) if s else 0 for s in slots)
        tok_b = jnp.array([[s["generated"][-1] if s and s["generated"]
                            else (s["prompt"][-1] if s else eos)] for s in slots],
                          jnp.int32)
        nxt, logits, caches = (serve_step(params, tok_b, caches, jnp.int32(pos), memory)
                               if memory is not None else
                               serve_step(params, tok_b, caches, jnp.int32(pos)))
        n_steps += 1
        nxt = np.asarray(nxt)
        for i in range(B):
            s = slots[i]
            if s is None:
                continue
            t = int(nxt[i, 0])
            s["generated"].append(t)
            if t == eos or len(s["generated"]) >= args.max_new:
                done.append(np.array(s["generated"]))
                slots[i] = None

    dt = time.time() - t0
    print(f"served {len(done)} requests in {dt:.1f}s "
          f"({n_steps} decode steps, batch {B})")
    for i, g in enumerate(done[:4]):
        print(f"  req{i}: {g[:12].tolist()}...")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())

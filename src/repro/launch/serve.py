"""Serving CLI: a thin driver over the `repro.serve` continuous-batching
engines.

Two workloads share the same scheduler/slot machinery:

  * token decoding (any Arch family) — batched prefill, per-slot positions,
    retire-and-refill without recompilation:

        python -m repro.launch.serve --arch gemma3-1b --reduced --requests 12

  * gDDIM sampling as a service — slots are samples, each at its own
    sampler step index:

        python -m repro.launch.serve --diffusion cifar10-ddpm --reduced \\
            --requests 8 --nfe 20

All engine logic (slot isolation, cache scatter, admission grouping) lives
in `repro.serve.engine`; this module only parses flags, builds a synthetic
request stream, and reports throughput.
"""
from __future__ import annotations

import argparse
import time

import numpy as np
import jax

from ..configs import get_arch, get_diffusion, ARCH_IDS, DIFFUSION_MODULES
from ..models.registry import Arch
from ..serve import DiffusionEngine, Request, SampleRequest, TokenEngine


def _serve_tokens(args) -> int:
    spec = get_arch(args.arch, reduced=args.reduced)
    arch = Arch(spec)
    key = jax.random.PRNGKey(args.seed)
    params = arch.init(key)

    rng = np.random.default_rng(args.seed)
    requests = []
    for rid in range(args.requests):
        req = Request(
            rid=rid,
            tokens=rng.integers(2, arch.cfg.vocab,
                                size=args.prompt_len).astype(np.int32),
            max_new=args.max_new)
        if spec.family == "encdec":
            req.frames = rng.standard_normal(
                (spec.frontend_ctx, arch.cfg.d_model)).astype(np.float32)
        requests.append(req)

    engine = TokenEngine(arch, params, batch_size=args.batch,
                         max_len=args.max_len)
    t0 = time.time()
    results = engine.serve(requests)
    dt = time.time() - t0
    tps = engine.n_tokens_out / max(dt, 1e-9)
    print(f"served {len(results)} requests in {dt:.1f}s "
          f"({engine.n_decode_steps} decode rounds, "
          f"{engine.n_prefill_calls} prefill calls, batch {args.batch}, "
          f"{tps:.1f} tok/s)  compile={engine.compile_stats()}")
    for rid in sorted(results)[:4]:
        print(f"  req{rid}: {results[rid][:12].tolist()}...")
    return 0


def _serve_samples(args) -> int:
    spec = get_diffusion(args.diffusion, reduced=args.reduced)
    params = spec.init(jax.random.PRNGKey(args.seed))
    engine = DiffusionEngine(spec, params, batch_size=args.batch,
                             nfe=args.nfe)
    requests = [SampleRequest(rid=i, seed=args.seed + i)
                for i in range(args.requests)]
    t0 = time.time()
    results = engine.serve(requests)
    dt = time.time() - t0
    sps = engine.n_samples_out / max(dt, 1e-9)
    print(f"sampled {len(results)} requests in {dt:.1f}s "
          f"({engine.n_steps} gDDIM rounds @ NFE {args.nfe}, "
          f"batch {args.batch}, {sps:.2f} samples/s)  "
          f"compile={engine.compile_stats()}")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--diffusion", choices=list(DIFFUSION_MODULES))
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=24)
    ap.add_argument("--max-len", type=int, default=64)
    ap.add_argument("--nfe", type=int, default=20)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)
    if (args.arch is None) == (args.diffusion is None):
        ap.error("pass exactly one of --arch / --diffusion")
    return _serve_samples(args) if args.diffusion else _serve_tokens(args)


if __name__ == "__main__":
    raise SystemExit(main())

"""Serving CLI: a thin driver over the `repro.serve` continuous-batching
engines (all engine logic — slot isolation, cache scatter, admission
grouping, the sampler-coefficient cache — lives in `repro.serve` and
`repro.core.coeffs`; this module only parses flags, builds a synthetic
request stream, and reports throughput).

Two workloads share the same scheduler/slot machinery:

  * token decoding (any Arch family) — batched prefill, per-slot positions,
    retire-and-refill without recompilation:

        python -m repro.launch.serve --arch gemma3-1b --reduced --requests 12

  * gDDIM sampling as a service — slots are samples, each at its own
    sampler step index *and* its own sampler config.  Homogeneous traffic
    uses the engine defaults (--nfe/--q/--corrector/--lam); heterogeneous
    traffic cycles requests through --mix specs, one comma-separated
    key=value config per spec:

        python -m repro.launch.serve --diffusion cifar10-ddpm --reduced \\
            --requests 9 --batch 3 \\
            --mix nfe=10 nfe=50,q=2,corrector nfe=20,lam=0.5

    The sampler *algorithm* is a per-request axis too (gddim | gmm |
    accel — see docs/sampler_math.md), so one engine serves a
    mixed-algorithm batch from the same warmed programs:

        python -m repro.launch.serve --diffusion cifar10-ddpm --reduced \\
            --requests 9 --batch 3 \\
            --mix algorithm=gddim algorithm=accel algorithm=gmm,lam=0.5

    One engine serves the whole mix from one warmed set of compiled step
    programs (`compile_stats` is printed so you can see it).  Passing a
    comma-separated list to --diffusion builds a *multi-family* engine
    (first entry = default family) and --mix specs may then pick their
    SDE family per request:

        python -m repro.launch.serve --reduced --requests 9 --batch 3 \\
            --diffusion cifar10-ddpm,cifar10-cld,cifar10-bdm \\
            --mix family=vpsde,nfe=10 family=cld,nfe=8 family=bdm,nfe=8

Both workloads take `--mesh` to shard the engine over a (data, model)
device mesh (slot batch and caches over `data`, params via the repo's
TP/FSDP rules) — e.g. on a CPU host:

        XLA_FLAGS=--xla_force_host_platform_device_count=2 \\
        python -m repro.launch.serve --diffusion cifar10-ddpm --reduced \\
            --requests 8 --batch 4 --mesh data=2

and `--sync-every` to bound how many device-resident rounds run between
host polls of the retire mask (see repro.serve.ServeLoop).

Flags are grouped to mirror `repro.serve.ServeRequest` (serve/api.py):
each --mix spec parses directly into `ServeRequest` field values, so the
CLI surface and the wire surface are the same vocabulary.  `--replicas N`
routes the request stream through the front-tier (`repro.serve.Router`)
over N engine replicas instead of one engine — the routed results are
bitwise-identical to the single-engine serve (see docs/serving.md,
"Multi-host serving and the router front-tier"):

        python -m repro.launch.serve --diffusion cifar10-ddpm --reduced \\
            --requests 12 --batch 4 --replicas 2

For the multi-process / multi-host version of the same fleet (spawned
replica processes, readiness barriers, harvested counters, CI gates) see
tools/launchgate.py and repro.distributed.multihost.
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import numpy as np
import jax

from ..configs import get_arch, get_diffusion, ARCH_IDS, DIFFUSION_MODULES
from ..core import SamplerConfig
from ..models.registry import Arch
from ..serve import (DiffusionEngine, ReplicaSpec, Router, RouterConfig,
                     ServeRequest, TokenEngine)
from .mesh import make_serve_mesh


def parse_sampler_spec(spec: str) -> dict:
    """Parse one --mix item:
    'family=cld,nfe=50,q=2,corrector,lam=0.5,grid=uniform,algorithm=gmm'.

    Bare flags ('corrector') mean True; 'lambda' is accepted for 'lam'.
    Returns a kwargs dict of `ServeRequest` sampler-config fields (the
    --mix vocabulary IS the wire vocabulary — serve/api.py); `main()`
    validates the merged `SamplerConfig` (defaults + spec) before any
    device work."""
    def parse_bool(v: str) -> bool:
        v = v.strip().lower()
        if v in ("", "1", "true", "yes", "on"):
            return True
        if v in ("0", "false", "no", "off"):
            return False
        raise ValueError(v)

    convert = {"nfe": int, "q": int, "lam": float, "grid": str.strip,
               "corrector": parse_bool, "family": str.strip,
               "algorithm": str.strip}
    out: dict = {}
    for part in spec.split(","):
        part = part.strip()
        if not part:
            continue
        key, _, val = part.partition("=")
        key = key.strip().replace("-", "_")
        if key == "lambda":
            key = "lam"
        if key not in convert:
            raise ValueError(f"unknown sampler-config key {key!r} in {spec!r}")
        try:
            out[key] = convert[key](val)
        except ValueError:
            raise ValueError(
                f"bad value {val!r} for {key} in {spec!r}") from None
    return out


def _mesh_banner(engine) -> str:
    if engine.mesh is None:
        return "single-device"
    return (f"mesh {dict(engine.mesh.shape)} "
            f"({engine.n_shards} slot shard{'s' if engine.n_shards > 1 else ''})")


def _serve_tokens(args) -> int:
    spec = get_arch(args.arch, reduced=args.reduced)
    arch = Arch(spec)
    key = jax.random.PRNGKey(args.seed)
    params = arch.init(key)

    rng = np.random.default_rng(args.seed)
    requests = []
    for rid in range(args.requests):
        # requests are frozen (serve/api.py) — every field, including the
        # encdec conditioning frames, is set at construction
        frames = None
        if spec.family == "encdec":
            frames = rng.standard_normal(
                (spec.frontend_ctx, arch.cfg.d_model)).astype(np.float32)
        requests.append(ServeRequest(
            rid=rid, workload="token",
            tokens=rng.integers(2, arch.cfg.vocab,
                                size=args.prompt_len).astype(np.int32),
            max_new=args.max_new, frames=frames))

    engine = TokenEngine(arch, params, batch_size=args.batch,
                         max_len=args.max_len, mesh=make_serve_mesh(args.mesh),
                         sync_every=args.sync_every)
    t0 = time.time()
    results = engine.serve(requests)
    dt = time.time() - t0
    tps = engine.n_tokens_out / max(dt, 1e-9)
    print(f"served {len(results)} requests in {dt:.1f}s "
          f"({engine.n_decode_steps} decode rounds, "
          f"{engine.n_prefill_calls} prefill calls, {engine.n_polls} polls, "
          f"batch {args.batch}, {_mesh_banner(engine)}, "
          f"{tps:.1f} tok/s)  compile={engine.compile_stats()}")
    for rid in sorted(results)[:4]:
        print(f"  req{rid}: {results[rid][:12].tolist()}...")
    return 0


def _serve_samples(args) -> int:
    from ..sde.base import family_name

    names = [n.strip() for n in args.diffusion.split(",") if n.strip()]
    specs = {}
    for n in names:
        spec = get_diffusion(n, reduced=args.reduced)
        fam = family_name(spec.sde)
        if fam in specs:
            raise SystemExit(f"--diffusion lists family {fam!r} twice")
        specs[fam] = spec
    default, mix = args.default_config, args.mix_parsed
    # reject --mix family typos while startup is still cheap (before any
    # score-net init / device work)
    for kw in mix:
        if kw.get("family") not in (None, *specs):
            raise SystemExit(
                f"--mix family {kw['family']!r} is not served; "
                f"--diffusion provides {list(specs)}")
    params = {fam: spec.init(jax.random.PRNGKey(args.seed))
              for fam, spec in specs.items()}
    if len(specs) == 1:
        specs, params = next(iter(specs.values())), next(iter(params.values()))

    def build_engine():
        return DiffusionEngine(specs, params, batch_size=args.batch,
                               default_config=default,
                               mesh=make_serve_mesh(args.mesh),
                               sync_every=args.sync_every)

    requests = []
    for i in range(args.requests):
        kw = mix[i % len(mix)] if mix else {}
        requests.append(ServeRequest(rid=i, workload="diffusion",
                                     seed=args.seed + i, **kw))

    if args.replicas > 1:
        return _serve_routed(args, build_engine, requests, default)

    engine = build_engine()
    t0 = time.time()
    results = engine.serve(requests)
    dt = time.time() - t0
    sps = engine.n_samples_out / max(dt, 1e-9)
    kinds = ("mixed traffic, "
             f"{len(engine.cache)} sampler configs, "
             f"families {engine.families}") if mix else \
        f"homogeneous @ NFE {default.nfe}"
    print(f"sampled {len(results)} requests in {dt:.1f}s "
          f"({engine.n_rounds} gDDIM rounds / {engine.n_steps} step "
          f"dispatches, {kinds}, "
          f"batch {args.batch}, {_mesh_banner(engine)}, "
          f"{sps:.2f} samples/s)  "
          f"compile={engine.compile_stats()}")
    if mix:
        for cfg in engine.cache.configs:
            print(f"  config: family={cfg.family} nfe={cfg.nfe} q={cfg.q} "
                  f"corrector={cfg.corrector} lam={cfg.lam} grid={cfg.grid} "
                  f"algorithm={cfg.algorithm}")
    return 0


def _serve_routed(args, build_engine, requests, default) -> int:
    """--replicas N: the in-process router fleet.  Deterministic arrival
    times (request i at virtual time i), one warmed engine per replica,
    the plan fully replayable from (requests, replica config, seeds)."""
    from ..serve import Arrival, TraceTraffic

    router = Router(
        [ReplicaSpec(index=i, batch=args.batch)
         for i in range(args.replicas)],
        RouterConfig(default_nfe=default.nfe))
    trace = TraceTraffic([Arrival(float(i), r)
                          for i, r in enumerate(requests)])
    engines = [build_engine() for _ in range(args.replicas)]
    t0 = time.time()
    results, plan = router.serve(trace, engines)
    dt = time.time() - t0
    sps = len(results) / max(dt, 1e-9)
    per_replica = [len(s) for s in plan.sub_traces]
    print(f"routed {len(results)} requests over {args.replicas} replicas "
          f"in {dt:.1f}s ({per_replica} per replica, "
          f"counters {plan.counters}, batch {args.batch}, "
          f"{sps:.2f} samples/s)")
    for a in plan.assignments[:6]:
        print(f"  t={a['t']:.1f} req{a['rid']} -> replica {a['replica']}"
              + (f" after {a['n_requeues']} requeues"
                 if a["n_requeues"] else ""))
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="Serving CLI over the repro.serve engines; request "
                    "flags mirror the fields of repro.serve.ServeRequest "
                    "(the wire-level request type, serve/api.py)")
    g_model = ap.add_argument_group(
        "model / engine", "what is being served, and the engine shape")
    g_model.add_argument("--arch", choices=ARCH_IDS)
    g_model.add_argument("--diffusion", metavar="NAME[,NAME...]",
                         help="diffusion config(s) to serve, from "
                              f"{list(DIFFUSION_MODULES)}; a comma-separated "
                              "list builds one multi-family engine (first "
                              "entry = default family)")
    g_model.add_argument("--reduced", action="store_true")
    g_model.add_argument("--batch", type=int, default=4)
    g_model.add_argument("--max-len", type=int, default=64)

    g_req = ap.add_argument_group(
        "request stream (ServeRequest fields)",
        "how many requests, and their non-sampler ServeRequest fields "
        "(rid/seed are derived: rid=i, seed=--seed+i)")
    g_req.add_argument("--requests", type=int, default=8)
    g_req.add_argument("--prompt-len", type=int, default=16,
                       help="token workload: synthetic `tokens` prompt "
                            "length")
    g_req.add_argument("--max-new", type=int, default=24,
                       help="token workload: ServeRequest.max_new")
    g_req.add_argument("--seed", type=int, default=0)

    g_cfg = ap.add_argument_group(
        "sampler config (ServeRequest sampler fields)",
        "engine defaults for nfe/q/corrector/lam/grid; --mix overrides "
        "them per request with the same key=value vocabulary")
    g_cfg.add_argument("--nfe", type=int, default=20,
                       help="default sampler NFE (grid steps)")
    g_cfg.add_argument("--q", type=int, default=1,
                       help="default multistep order (Eq. 19)")
    g_cfg.add_argument("--corrector", action="store_true",
                       help="default: run the Eq. 45 corrector")
    g_cfg.add_argument("--lam", "--lambda", type=float, default=0.0,
                       dest="lam",
                       help="default stochasticity lambda (Eq. 22)")
    g_cfg.add_argument("--grid", choices=("quadratic", "uniform"),
                       default="quadratic")
    g_cfg.add_argument("--algorithm", choices=("gddim", "gmm", "accel"),
                       default="gddim",
                       help="default sampler update rule: gddim (Eq. 19), "
                            "gmm (moment-matched 2-component mixture "
                            "reverse kernel; needs lam>0), accel "
                            "(first-moment-corrected deterministic "
                            "update; needs q=1, lam=0)")
    g_cfg.add_argument("--mix", nargs="+", metavar="SPEC",
                       help="per-request sampler configs to cycle through, "
                            "e.g. --mix nfe=10 nfe=50,q=2,corrector "
                            "nfe=20,lam=0.5 family=cld,nfe=8 — each spec "
                            "is ServeRequest sampler fields as key=value "
                            "(keys not named fall back to the defaults "
                            "above; family= needs a multi-family "
                            "--diffusion list)")

    g_place = ap.add_argument_group(
        "placement", "mesh sharding, host-poll pacing, and the router "
                     "front-tier")
    g_place.add_argument("--mesh", default=None, metavar="SPEC",
                         help="shard the engine over a (data, model) device "
                              "mesh: 'data=2', 'data=2,model=1', '2x1', or "
                              "'auto' (all devices on the data axis).  Slot "
                              "batch and caches shard over data; params "
                              "follow the repo's TP/FSDP rules.  Default: "
                              "single device")
    g_place.add_argument("--sync-every", type=int, default=8,
                         help="max rounds between host polls of the done "
                              "mask (R); the loop polls sooner when a "
                              "retirement is provably near")
    g_place.add_argument("--replicas", type=int, default=1,
                         help="route the stream over N in-process engine "
                              "replicas via repro.serve.Router (diffusion "
                              "only; bitwise-identical results to "
                              "--replicas 1 — see docs/serving.md).  For "
                              "spawned-process replicas see "
                              "tools/launchgate.py")
    args = ap.parse_args(argv)
    if (args.arch is None) == (args.diffusion is None):
        ap.error("pass exactly one of --arch / --diffusion")
    if args.mix and args.diffusion is None:
        ap.error("--mix only applies to --diffusion serving")
    if args.replicas < 1:
        ap.error("--replicas must be >= 1")
    if args.replicas > 1 and args.diffusion is None:
        ap.error("--replicas routing currently applies to --diffusion "
                 "serving")
    if args.diffusion:
        for n in args.diffusion.split(","):
            if n.strip() not in DIFFUSION_MODULES:
                ap.error(f"unknown diffusion config {n.strip()!r}; known: "
                         f"{list(DIFFUSION_MODULES)}")
        # validate the full merged configs (defaults + every --mix spec)
        # here, before any model init / device work
        try:
            args.default_config = SamplerConfig(
                nfe=args.nfe, q=args.q, corrector=args.corrector,
                lam=args.lam, grid=args.grid, algorithm=args.algorithm)
            args.mix_parsed = [parse_sampler_spec(s)
                               for s in (args.mix or [])]
            for kw in args.mix_parsed:
                SamplerConfig(**{**dataclasses.asdict(args.default_config),
                                 **kw})
        except ValueError as e:
            ap.error(str(e))
    return _serve_samples(args) if args.diffusion else _serve_tokens(args)


if __name__ == "__main__":
    raise SystemExit(main())

"""Step factories: the jitted programs the launcher/dry-run lower.

  make_train_step(arch, opt_cfg)   full train step: loss -> grad -> clip ->
                                   AdamW (mixed precision; bf16 grads =
                                   compressed collectives) -> new params.
                                   Donation-safe on (params, opt_state):
                                   launch/train.py jits it with
                                   donate_argnums=(0, 1) so the optimizer
                                   update is in-place at the XLA level
  make_prefill_step(arch, S)       forward + KV-cache fill (inference prefill;
                                   the serving engine runs whole admission
                                   groups through one call, width-bucketed
                                   to the group's power-of-two size)
  make_serve_step(arch)            one-token decode against a fixed cache;
                                   cache_len is scalar or per-slot (B,).
                                   Lowering/reference surface — the engine
                                   runs make_token_round_step instead
  make_token_round_step(arch)      one full serve *round*: decode + the
                                   device-resident TokenState update
                                   (append/advance/retire masking).  The
                                   engine jits it with state+caches donated
  make_diffusion_train_step(spec)  DSM/HSM step for the paper's DMs
  make_diffusion_serve_step(spec)  one gDDIM step (the sampler's inner loop
                                   body — what a sampling service executes
                                   NFE times); single-config mode closes
                                   over one Stage-I bank (scalar or (B,)
                                   step index k), bank mode operates on the
                                   canonical packed (B, K, D) slot state
                                   and takes a stacked multi-family
                                   FactoredBank argument plus per-slot
                                   (k, cfg) indices so one compiled program
                                   per family serves mixed family/NFE/q/
                                   corrector/lambda traffic
  make_diffusion_round_step(spec,  bank-mode gDDIM step over a
                            fam)   DiffusionState pytree: the update is
                                   masked by active & (fam == this family)
                                   & (prec == this precision class)
                                   (retired and foreign rows freeze) and k
                                   advances on device.  The whole
                                   post-score-eval update runs through the
                                   kernels/round_fused megakernel (one
                                   Pallas launch on TPU; bitwise-equal ref
                                   chain elsewhere).  The engine jits one
                                   variant per (family, precision,
                                   corrector) cost class with the state
                                   donated, so u/hist update in place
  make_diffusion_round_step_stitched(spec, fam)
                                   the pre-fusion XLA-stitched assembly of
                                   the same round — the bitwise
                                   differential oracle + roofline baseline

`shardings_for(...)` produces (params, opt, inputs) NamedShardings for any
(arch x shape x mesh) cell from the rules in distributed/sharding.py.
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..models.registry import Arch, SHAPES
from ..optim.adamw import AdamWCfg, AdamWState, adamw_init, adamw_update
from ..distributed import sharding as shd

Array = jax.Array


# ---------------------------------------------------------------------------
# step functions
# ---------------------------------------------------------------------------
def make_train_step(arch: Arch, opt_cfg: AdamWCfg, grad_shardings=None):
    """grad_shardings: optional pytree of NamedShardings (== param
    shardings).  Constraining the gradients to the FSDP layout at the
    autodiff boundary lets GSPMD emit reduce-scatters into the shard
    instead of full all-reduces (ZeRO-2; §Perf iter B2 — measured 2x on
    the dominant backward collective of llama3-405b train_4k)."""
    def train_step(params, opt_state: AdamWState, batch):
        loss, grads = jax.value_and_grad(arch.loss)(params, batch)
        if grad_shardings is not None:
            grads = jax.tree.map(jax.lax.with_sharding_constraint,
                                 grads, grad_shardings)
        new_params, new_state, metrics = adamw_update(grads, opt_state, params, opt_cfg)
        metrics["loss"] = loss
        return new_params, new_state, metrics

    return train_step


def make_prefill_step(arch: Arch, max_len: int):
    def prefill_step(params, batch):
        return arch.prefill(params, batch, max_len)

    return prefill_step


def make_serve_step(arch: Arch):
    """One-token greedy decode.  `cache_len` is a scalar (all rows at one
    shared position) or a (B,) per-slot vector — the continuous-batching
    engine (repro.serve) always passes the vector form so every slot decodes
    at its own absolute position."""
    def serve_step(params, token, caches, cache_len, memory=None):
        logits, caches = arch.decode(params, token, caches, cache_len,
                                     memory=memory)
        next_token = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
        return next_token, logits, caches

    return serve_step


def make_token_round_step(arch: Arch):
    """One full serving *round* over a device-resident `TokenState`: decode
    every slot at its own position, then apply the per-slot bookkeeping the
    host loop used to do in numpy — append the token to the slot's output
    ring, advance `pos`/`n_out`, and retire (clear `active`) on eos or
    budget exhaustion.  Retired rows are frozen: every update is masked by
    `state.active`, so a finished slot's outputs survive verbatim until the
    host fetches them (decode still runs on frozen rows — row-local garbage
    that admission overwrites).

    `eos` is a device scalar argument (not a closure constant) so changing
    the eos id never recompiles.  The engine jits this with `state` and
    `caches` donated: the round is in-place at the XLA level and the
    steady-state loop moves no per-slot metadata host->device.
    """
    def round_step(params, state, caches, eos, memory=None):
        from ..serve.state import TokenState
        logits, caches = arch.decode(params, state.last, caches, state.pos,
                                     memory=memory)
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)        # (B,)
        act = state.active
        rows = jnp.arange(state.out.shape[0])
        # inactive rows write at an out-of-range column and are dropped
        col = jnp.where(act, state.n_out, state.out.shape[1])
        out = state.out.at[rows, col].set(nxt, mode="drop")
        n_out = jnp.where(act, state.n_out + 1, state.n_out)
        done_now = act & ((nxt == eos) | (n_out >= state.budget))
        return TokenState(
            last=jnp.where(act[:, None], nxt[:, None], state.last),
            pos=jnp.where(act, state.pos + 1, state.pos),
            n_out=n_out, budget=state.budget, out=out,
            active=act & ~done_now), caches

    return round_step


def make_mask_snapshot():
    """Fresh device copies of the token done/progress mask, for the
    double-buffered online poll (`ServeLoop.serve_stream`): the loop
    dispatches this *before* enqueueing the look-ahead round, then blocks
    on the snapshot — not on `state.active` itself, whose buffer the next
    round's donation invalidates.  Round k+1 therefore executes while the
    host waits on round k's mask, and the copy is device->device: the
    steady-state no-host-transfer contract (JX104) is untouched.

    The ops are identity-shaped but not identities (`| False` / `+ 0`), so
    XLA materializes output buffers distinct from the round state's."""
    def snap(active, n_out):
        return active | False, n_out + 0

    return snap


def make_diffusion_round_step(spec, fam_index: int = 0, prec_index: int = 0,
                              impl: str = "auto", eps_model=None):
    """Bank-mode gDDIM step over a device-resident `DiffusionState`: the
    Eq. 19/22/45 update of `make_diffusion_serve_step` plus the per-slot
    bookkeeping — advance `k`, retire (clear `active`) when a slot reaches
    its config's NFE, and freeze retired rows so the finished sample `u`
    survives until the host fetches it.  The engine jits this with `state`
    donated (`u`/`hist` update in place) and the bank as a non-donated
    argument (it is reused every round).

    The whole post-score-eval state update — factor gathers + applies,
    eps-history shift, Eq. 22 noise, stochastic/corrector selects, retire
    masking, k-advance — runs through `kernels/round_fused`: ONE Pallas
    launch per round after the model eval on TPU (`impl='auto'`/'pallas'),
    and on other backends a ref path that is BITWISE equal to the
    historical XLA-stitched chain, which survives as
    `make_diffusion_round_step_stitched` (the differential oracle and the
    roofline gap's baseline — tests/test_round_fused.py).

    `fam_index`/`prec_index` are this variant's family id and precision
    class (closure constants, so they cost no per-round transfer): the
    step evaluates this spec's score net — `eps_model` overrides it for
    the low-precision variants, e.g. `models.quantize.wrap_eps_model` —
    over the packed batch and commits the update only to active slots
    whose `state.fam` and `state.prec` match; co-resident slots of other
    (family, precision) classes are left frozen for their own variant,
    which the engine dispatches in the same round.  One compiled variant
    per (family, precision, corrector) cost class serves any traffic mix.
    """
    from ..kernels.round_fused import ops as rf

    sde = spec.sde
    kf = sde.packed_k
    data_shape = tuple(spec.data_shape)
    state_shape = sde.state_shape(data_shape)
    model = spec.eps_model if eps_model is None else eps_model

    def round_step(params, state, bank, with_corrector=False):
        from ..serve.state import DiffusionState
        kc = jnp.clip(jnp.asarray(state.k), 0,
                      bank.n_steps[state.cfg] - 1)
        t = bank.t_cur[state.cfg, kc]
        ub = state.u[:, :kf]
        eps = model(params, sde.decanonicalize(ub, data_shape), t)
        eps_c = sde.canonicalize(eps)
        eps_n_c = None
        if with_corrector:
            # Eq. 45: second eval at the predictor iterate (recomputed
            # inside the commit with the identical ops — bitwise agreement)
            u_pred = rf.round_predict(state.u, state.hist, kc, state.cfg,
                                      bank, eps_c, kf=kf, impl=impl)
            eps_n = model(params, sde.decanonicalize(u_pred, data_shape),
                          bank.t_nxt[state.cfg, kc])
            eps_n_c = sde.canonicalize(eps_n)
        u2, h2, k2, a2 = rf.round_update(
            state.u, state.hist, state.k, kc, state.cfg, state.fam,
            state.prec, state.keys, state.active, bank, eps_c,
            sde=sde, state_shape=state_shape, kf=kf, fam_index=fam_index,
            prec_index=prec_index, with_corrector=with_corrector,
            eps_n_c=eps_n_c, impl=impl)
        return DiffusionState(u=u2, hist=h2, k=k2, cfg=state.cfg,
                              fam=state.fam, prec=state.prec,
                              keys=state.keys, active=a2)

    return round_step


def make_diffusion_round_step_stitched(spec, fam_index: int = 0):
    """The PRE-FUSION round step: `make_diffusion_serve_step`'s bank-mode
    chain of XLA-stitched pieces plus the retire masking, exactly as the
    engine ran it before `kernels/round_fused`.  Kept as (a) the bitwise
    differential oracle the fused step is locked against at the round and
    engine levels (tests/test_round_fused.py), and (b) the baseline whose
    compiled-HLO byte traffic the roofline's serving mode compares the
    fused launch's analytic bytes to (benchmarks/roofline.py)."""
    bank_step = make_diffusion_serve_step(spec)

    def round_step(params, state, bank, with_corrector=False):
        from ..serve.state import DiffusionState
        u_next, hist_next = bank_step(
            params, state.u, state.hist, state.k, state.cfg, state.keys,
            bank, with_corrector=with_corrector)
        mine = state.active & (state.fam == fam_index)
        rmask = lambda x: mine.reshape((-1,) + (1,) * (x.ndim - 1))
        k = jnp.where(mine, state.k + 1, state.k)
        return DiffusionState(
            u=jnp.where(rmask(state.u), u_next, state.u),
            hist=jnp.where(rmask(state.hist), hist_next, state.hist),
            k=k, cfg=state.cfg, fam=state.fam, prec=state.prec,
            keys=state.keys,
            active=jnp.where(mine, k < bank.n_steps[state.cfg],
                             state.active))

    return round_step


def make_diffusion_train_step(spec, opt_cfg: AdamWCfg):
    tables = spec.tables

    def train_step(params, opt_state: AdamWState, batch, key):
        def loss_fn(p):
            from ..train import losses
            return losses.dsm_loss(spec.sde, tables,
                                   lambda u, t: spec.eps_model(p, u, t),
                                   batch["x0"], key)
        loss, grads = jax.value_and_grad(loss_fn)(params)
        new_params, new_state, metrics = adamw_update(grads, opt_state, params, opt_cfg)
        metrics["loss"] = loss
        return new_params, new_state, metrics

    return train_step


def make_diffusion_serve_step(spec, coeffs=None):
    """One gDDIM step — the inner loop of a sampling service (executed NFE
    times per request batch).  Two modes:

    * **single-config** (Stage-I `coeffs` given): the historical surface —
      a deterministic q=1 predictor step closed over one coefficient bank.
      `k` is the step index 0..N-1 (advancing t_{N-k} -> t_{N-k-1}): a
      scalar when the whole batch steps in lockstep (the dry-run lowers
      this form), or a (B,) vector of per-slot indices.

    * **bank mode** (`coeffs=None`): the heterogeneous-config step used by
      `repro.serve.DiffusionEngine`, over the *canonical packed* slot
      layout (`kernels/ei_update/ops.py`): `u` (B, K, D) with K = k_max
      over the engine's resident families (VPSDE/BDM occupy row 0, CLD
      rows 0-1; BDM rows hold DCT coefficients — the dct2 path), `hist`
      (B, Qb, K, D).  The stacked `FactoredBank` is an *argument* (not a
      closure constant), so refreshing the bank with new configs never
      recompiles as long as its bucketed shapes are stable.  Every slot b
      gathers its psi/pC/cC/B/P_chol rows as *factor pairs* by
      (cfg[b], k[b]) — a (kf, kf) block factor sliced statically to this
      family's width plus a (D,) diagonal row fetched from the bank's
      deduplicated pool — and applies them via `apply_factored` (two
      contractions; the ref path is bitwise equal to the dense einsum it
      replaced, the TPU Pallas kernel is pinned to ref), so the
      arithmetic per slot is identical whatever K the co-resident
      families force:

          u, hist = step(params, u, hist, k, cfg, keys, bank,
                         with_corrector=...)

      with `k`/`cfg` (B,) int32, and `keys` (B, 2) uint32 per-slot
      PRNG keys for the Eq. 22 stochastic branch (noise is keyed by
      fold_in(fold_in(key, algorithm), k) and drawn in state space by the
      shared algorithm-aware law — `round_fused.ref.draw_step_noise` — so
      a slot's trajectory is a pure function of its request seed and
      merged config).  `with_corrector` must be
      static under jit: the False variant is the 1-eval predictor program,
      the True variant adds the Eq. 45 corrector re-evaluation and applies
      it only to slots whose config asks for it (and never on a slot's
      final step, matching Alg. 1's NFE accounting).  Deterministic /
      stochastic configs mix freely per-slot; slots of *other* families
      ride along (their rows compute garbage under this family's model and
      coefficients) and are discarded by the round step's family mask.
      Inactive slots may carry any k — indices are clipped and their rows
      ignored by the engine."""
    if coeffs is not None:
        N = coeffs.psi.shape[0]

        def serve_step(params, u, k):
            k = jnp.asarray(k)
            if k.ndim == 0:
                i = N - k
                t = jnp.full((u.shape[0],), 1.0, jnp.float32) * coeffs.ts[i]
                eps = spec.eps_model(params, u, t)
                return spec.sde.apply(coeffs.psi[k], u) + \
                    spec.sde.apply(coeffs.pC[k, 0], eps)
            kc = jnp.clip(k, 0, N - 1)
            t = coeffs.ts[N - kc]
            eps = spec.eps_model(params, u, t)
            return spec.sde.apply_batched(coeffs.psi[kc], u) + \
                spec.sde.apply_batched(coeffs.pC[kc, 0], eps)

        return serve_step

    from ..kernels.ei_update.ops import apply_factored, pad_channels
    from ..kernels.round_fused import ref as rf_ref

    sde = spec.sde
    kf = sde.packed_k                       # this family's channel rows
    data_shape = tuple(spec.data_shape)
    state_shape = sde.state_shape(data_shape)

    def bank_step(params, u, hist, k, cfg, keys, bank, with_corrector=False):
        K = u.shape[1]
        kc = jnp.clip(jnp.asarray(k), 0, bank.n_steps[cfg] - 1)
        t = bank.t_cur[cfg, kc]
        # this family's slice of the packed state / gathered coefficients:
        # static k x k sub-block, so the per-slot arithmetic (and its
        # bitwise result) does not depend on the co-resident K.  Each
        # coefficient arrives as a factor pair: (B, kf, kf) block + the
        # (B, D) diagonal row its pool id points at
        ub = u[:, :kf]                                        # (B, kf, D)
        gat = lambda nm: (getattr(bank, nm + "_blk")[cfg, kc][:, :kf, :kf],
                          bank.diag[getattr(bank, nm + "_di")[cfg, kc]])
        gatq = lambda nm, j: (
            getattr(bank, nm + "_blk")[cfg, kc, j][:, :kf, :kf],
            bank.diag[getattr(bank, nm + "_di")[cfg, kc, j]])
        pad = lambda z: pad_channels(z, K)

        eps = spec.eps_model(params, sde.decanonicalize(ub, data_shape), t)
        eps_c = sde.canonicalize(eps)                         # (B, kf, D)
        hist = jnp.concatenate([pad(eps_c)[:, None], hist[:, :-1]], axis=1)
        Qb = hist.shape[1]

        u_lin = apply_factored(*gat("psi"), ub)
        # predictor (Eq. 19a): slots with q_c < Qb hit zero-padded pC rows
        # (zero block factor), so the extra terms vanish identically
        u_pred = u_lin
        for j in range(Qb):
            u_pred = u_pred + apply_factored(*gatq("pC", j),
                                             hist[:, j, :kf])
        # stochastic branch (Eq. 22/23); deterministic configs carry zero
        # B/P_chol factors but the branch is still computed so every
        # traffic mix runs the identical program (bitwise solo ==
        # interleaved).  The draw is the shared algorithm-aware noise law
        # (keyed key -> alg -> kc, 'gmm' mixture transform per slot)
        noise = rf_ref.draw_step_noise(sde, keys, kc, bank.alg[cfg],
                                       state_shape, u.dtype)
        u_sto = u_lin + apply_factored(*gat("B"), eps_c) \
            + apply_factored(*gat("P_chol"), sde.canonicalize(noise))
        bmask = lambda m: m.reshape((-1, 1, 1))
        u_next = jnp.where(bmask(bank.stochastic[cfg]), u_sto, u_pred)

        if with_corrector:
            eps_n = spec.eps_model(
                params, sde.decanonicalize(u_pred, data_shape),
                bank.t_nxt[cfg, kc])
            u_corr = u_lin + apply_factored(*gatq("cC", 0),
                                            sde.canonicalize(eps_n))
            for j in range(1, Qb):
                u_corr = u_corr + apply_factored(*gatq("cC", j),
                                                 hist[:, j - 1, :kf])
            # Alg. 1: no corrector on the final step (k == N_c - 1)
            use_c = bank.corrector[cfg] & (kc < bank.n_steps[cfg] - 1)
            u_next = jnp.where(bmask(use_c), u_corr, u_next)
        # re-attach the padding rows (zero for this family's slots;
        # co-resident families' live rows pass through frozen — the round
        # step discards non-matching rows wholesale anyway)
        return jnp.concatenate([u_next, u[:, kf:]], axis=1), hist

    return bank_step


# ---------------------------------------------------------------------------
# shardings per (arch x shape x mesh)
# ---------------------------------------------------------------------------
def shardings_for(arch: Arch, mesh: Mesh, shape: str,
                  cfg: shd.ShardCfg = shd.ShardCfg()):
    """Returns dict with 'params', 'opt', and per-input shardings for the
    step kind this shape lowers."""
    cell = SHAPES[shape]
    pshapes = arch.param_shapes()
    psh = shd.param_shardings(pshapes, mesh, cfg)
    out: Dict[str, Any] = {"params": psh, "param_shapes": pshapes}
    B = cell.global_batch

    if cell.kind == "train":
        opt_shapes = jax.eval_shape(lambda p: adamw_init(p, AdamWCfg()), pshapes)
        # opt state inherits the param spec leaf-for-leaf (m, v, master);
        # scalars replicated
        def opt_leaf_sharding(path, leaf):
            return NamedSharding(
                mesh, shd.param_spec(shd._path_str(path[1:]), tuple(leaf.shape),
                                     mesh, cfg)) if leaf.ndim else \
                NamedSharding(mesh, P())
        osh = AdamWState(
            step=NamedSharding(mesh, P()),
            m=shd.param_shardings(opt_shapes.m, mesh, cfg),
            v=shd.param_shardings(opt_shapes.v, mesh, cfg),
            master=shd.param_shardings(opt_shapes.master, mesh, cfg),
        )
        out["opt"] = osh
        out["opt_shapes"] = opt_shapes

    specs = arch.input_specs(shape)
    in_sh: Dict[str, Any] = {}
    for name, s in specs.items():
        if name == "caches":
            n_kv = getattr(arch.cfg, "n_kv_heads", 0)
            d_head = getattr(arch.cfg, "d_head", -1)
            def cache_sh(leaf):
                # ssm/conv/aux states shard their batch dim only; KV-shaped
                # leaves also head-shard (shared rule with the serve engine)
                return NamedSharding(mesh, shd.cache_leaf_spec(
                    mesh, cfg, tuple(leaf.shape),
                    _find_batch_dim(leaf.shape, B), B, n_kv, d_head))
            in_sh[name] = jax.tree.map(cache_sh, s)
        elif name == "cache_len" or (hasattr(s, "ndim") and s.ndim == 0):
            in_sh[name] = NamedSharding(mesh, P())
        else:
            extra = None
            if cfg.seq_shard_activations and s.ndim >= 2 \
                    and cell.kind != "decode" \
                    and s.shape[1] % mesh.shape[cfg.tp_axis] == 0:
                extra = {1: cfg.tp_axis}   # context parallelism (§Perf A2)
            in_sh[name] = NamedSharding(
                mesh, shd.batch_spec(mesh, cfg, s.ndim, B, extra=extra))
    out["inputs"] = in_sh
    out["input_specs"] = specs
    return out


def _find_batch_dim(shape, B) -> Optional[int]:
    for d, n in enumerate(shape):
        if n == B:
            return d
    return None

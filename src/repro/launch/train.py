"""End-to-end training driver (LM archs and paper diffusion configs).

Fault-tolerant by construction: atomic checkpoints every --ckpt-every steps
(async writer), ``--resume latest`` restarts exactly (data stream is a pure
function of step), and shardings are recomputed from the *present* device
count at startup — elastic re-meshing needs no config change.

Examples (CPU-sized):
    python -m repro.launch.train --arch gemma3-1b --reduced --steps 50
    python -m repro.launch.train --diffusion cifar10-cld --reduced --steps 200
"""
from __future__ import annotations

import argparse
import time

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import Mesh

from ..configs import get_arch, get_diffusion, ARCH_IDS
from ..models.registry import Arch
from ..optim.adamw import AdamWCfg, adamw_init
from ..distributed.sharding import ShardCfg, param_shardings
from ..ckpt.store import CheckpointStore
from ..data.pipeline import TokenPipeline, MixturePipeline
from . import steps as steps_lib


def make_auto_mesh() -> Mesh:
    """Largest (data, model) mesh over the devices actually present."""
    n = jax.device_count()
    model = 1
    for m in (16, 8, 4, 2, 1):
        if n % m == 0 and m <= n:
            model = m
            break
    return jax.make_mesh((n // model, model), ("data", "model"))


def train_lm(args) -> dict:
    spec = get_arch(args.arch, reduced=args.reduced)
    arch = Arch(spec)
    mesh = make_auto_mesh()
    scfg = ShardCfg()
    opt_cfg = AdamWCfg(lr=args.lr, total_steps=args.steps,
                       warmup_steps=max(args.steps // 20, 5))

    key = jax.random.PRNGKey(args.seed)
    params = arch.init(key)
    psh = param_shardings(params, mesh, scfg)
    params = jax.device_put(params, psh)
    opt_state = adamw_init(params, opt_cfg)

    pipe = TokenPipeline(vocab=arch.cfg.vocab, seq_len=args.seq_len,
                         global_batch=args.batch, seed=args.seed)
    store = CheckpointStore(args.ckpt_dir) if args.ckpt_dir else None

    start_step = 0
    if store and args.resume:
        latest, restored = store.restore_latest((params, opt_state))
        if latest is not None:
            params, opt_state = restored
            start_step = latest
            print(f"resumed from step {start_step}")

    # donate (params, opt_state): the AdamW update is in-place at the XLA
    # level — no per-step copy of the two largest buffers in the job.
    # Safe because the loop rebinds both every step and CheckpointStore
    # copies leaves to host before the next step can donate them
    step_fn = jax.jit(steps_lib.make_train_step(arch, opt_cfg),
                      donate_argnums=(0, 1))

    losses = []
    t0 = time.time()
    it = pipe.iterator(start_step)
    for step in range(start_step, args.steps):
        batch = next(it)
        db = {"tokens": batch["tokens"], "labels": batch["labels"]}
        if spec.input_mode == "embeddings":
            emb = jax.random.normal(jax.random.fold_in(key, step),
                                    batch["tokens"].shape + (arch.cfg.d_model,),
                                    jnp.float32) * 0.02
            db = {"embeddings": emb, "labels": batch["labels"]}
        if spec.family == "encdec":
            db["frames"] = jax.random.normal(
                jax.random.fold_in(key, step),
                (args.batch, spec.frontend_ctx, arch.cfg.d_model)) * 0.02
        params, opt_state, metrics = step_fn(params, opt_state, db)
        losses.append(float(metrics["loss"]))
        if args.log_every and step % args.log_every == 0:
            print(f"step {step:5d} loss {losses[-1]:.4f} "
                  f"lr {float(metrics['lr']):.2e} "
                  f"gnorm {float(metrics['grad_norm']):.3f}", flush=True)
        if store and args.ckpt_every and (step + 1) % args.ckpt_every == 0:
            store.save(step + 1, (params, opt_state))
    if store:
        store.save(args.steps, (params, opt_state), blocking=True)
    dt = time.time() - t0
    print(f"done: {args.steps - start_step} steps in {dt:.1f}s; "
          f"loss {losses[0]:.4f} -> {losses[-1]:.4f}")
    return {"first_loss": losses[0] if losses else None,
            "last_loss": losses[-1] if losses else None}


def train_diffusion(args) -> dict:
    spec = get_diffusion(args.diffusion, reduced=args.reduced)
    opt_cfg = AdamWCfg(lr=args.lr, total_steps=args.steps,
                       warmup_steps=max(args.steps // 20, 5),
                       weight_decay=0.0)
    key = jax.random.PRNGKey(args.seed)
    params = spec.init(key)
    opt_state = adamw_init(params, opt_cfg)

    shp = spec.data_shape
    rng = np.random.default_rng(args.seed)
    means = rng.uniform(-1, 1, size=(4,) + tuple(shp))
    pipe = MixturePipeline(means=means, stds=np.full(4, 0.05),
                           weights=np.ones(4), global_batch=args.batch,
                           seed=args.seed)
    store = CheckpointStore(args.ckpt_dir) if args.ckpt_dir else None
    start_step = 0
    if store and args.resume:
        latest, restored = store.restore_latest((params, opt_state))
        if latest is not None:
            params, opt_state = restored
            start_step = latest

    step_fn = jax.jit(steps_lib.make_diffusion_train_step(spec, opt_cfg),
                      donate_argnums=(0, 1))
    losses = []
    it = pipe.iterator(start_step)
    for step in range(start_step, args.steps):
        batch = next(it)
        k = jax.random.fold_in(key, step)
        params, opt_state, metrics = step_fn(params, opt_state,
                                             {"x0": batch["x0"]}, k)
        losses.append(float(metrics["loss"]))
        if args.log_every and step % args.log_every == 0:
            print(f"step {step:5d} dsm-loss {losses[-1]:.4f}", flush=True)
        if store and args.ckpt_every and (step + 1) % args.ckpt_every == 0:
            store.save(step + 1, (params, opt_state))
    if store:
        store.save(args.steps, (params, opt_state), blocking=True)
    print(f"done: loss {losses[0]:.4f} -> {losses[-1]:.4f}")
    return {"first_loss": losses[0] if losses else None,
            "last_loss": losses[-1] if losses else None, "params": params}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--diffusion")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=0)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)
    if args.diffusion:
        train_diffusion(args)
    elif args.arch:
        train_lm(args)
    else:
        ap.error("--arch or --diffusion required")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())

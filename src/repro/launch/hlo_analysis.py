"""Post-SPMD HLO analysis: collective-traffic accounting for the roofline.

`cost_analysis()` gives FLOPs / bytes but no collective breakdown, so we
parse `compiled.as_text()` (the optimized, partitioned per-device module):

  * every computation's direct collective ops are sized from their inline
    result shapes (+ replica_groups for reduce-scatter operand sizing);
  * `while` loops (scanned layer stacks!) are resolved recursively — the
    trip count is read from the loop condition's compare-against-constant,
    so a collective inside a 126-layer scan body counts 126 times;
  * per-op-type byte conventions approximate ring-algorithm per-device
    traffic (documented in EXPERIMENTS.md §Roofline):
        all-gather          result_bytes           (~F moved per device)
        reduce-scatter      result_bytes * group   (operand size)
        all-reduce          2 * result_bytes       (RS + AG phases)
        all-to-all          result_bytes
        collective-permute  result_bytes
"""
from __future__ import annotations

import re
from typing import Dict, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_SHAPE_RE = re.compile(r"(pred|s8|u8|s16|u16|bf16|f16|s32|u32|f32|s64|u64|f64|c64|c128)\[([0-9,]*)\]")
_COLL_RE = re.compile(
    r"=\s*(\([^)]*\)|[a-z0-9]+\[[0-9,]*\][^ ]*)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(-start|-done)?\(")
_GROUPS_RE = re.compile(r"replica_groups=\{?\{([0-9,]+)\}")
_WHILE_RE = re.compile(r"while\(.*?\), condition=%?([\w.\-]+), body=%?([\w.\-]+)")
_COMP_HDR = re.compile(r"^(?:ENTRY )?%?([\w.\-]+)\s*(?:\([^)]*\))?\s*->.*\{\s*$")
_CONST_CMP = re.compile(r"compare\([^)]*\)")
_S32_CONST = re.compile(r"s32\[\]\s+constant\((\d+)\)")


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


_HDR_NAME = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*[({]")


def split_computations(hlo: str) -> Dict[str, str]:
    """Computations start at column 0 (`%name (args) -> type {` or
    `ENTRY %name ...{`); body ops are indented.  Split on that."""
    comps: Dict[str, str] = {}
    cur_name, cur_lines = None, []
    for line in hlo.splitlines():
        starts_comp = (line and not line[0].isspace()
                       and (line.startswith("%") or line.startswith("ENTRY"))
                       and line.rstrip().endswith("{"))
        if starts_comp:
            if cur_name is not None:
                comps[cur_name] = "\n".join(cur_lines)
            m = _HDR_NAME.match(line)
            cur_name = m.group(1) if m else None
            cur_lines = [line]
        elif cur_name is not None:
            cur_lines.append(line)
            if line.startswith("}"):
                comps[cur_name] = "\n".join(cur_lines)
                cur_name = None
                cur_lines = []
    if cur_name is not None:
        comps[cur_name] = "\n".join(cur_lines)
    return comps


def _direct_collectives(body: str) -> Dict[str, int]:
    out: Dict[str, int] = {}
    seen_started = set()
    for line in body.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        shape_txt, op, phase = m.group(1), m.group(2), m.group(3)
        if phase == "-done":
            continue  # counted at -start
        nbytes = _shape_bytes(shape_txt)
        if op == "all-reduce":
            nbytes *= 2
        elif op == "reduce-scatter":
            g = 1
            gm = _GROUPS_RE.search(line)
            if gm:
                g = len(gm.group(1).split(","))
            nbytes *= g
        out[op] = out.get(op, 0) + nbytes
    return out


def _trip_count(cond_body: str) -> int:
    """Read the compare-against-constant bound of a counted loop."""
    consts = [int(x) for x in _S32_CONST.findall(cond_body)]
    return max(consts) if consts else 1


def collective_bytes(hlo: str) -> Tuple[Dict[str, int], Dict[str, int]]:
    """Returns (per_op_type_bytes, diagnostics)."""
    comps = split_computations(hlo)
    memo: Dict[str, Dict[str, int]] = {}
    n_while = 0

    def total(name: str, stack=()) -> Dict[str, int]:
        if name in memo:
            return memo[name]
        if name in stack or name not in comps:
            return {}
        body = comps[name]
        acc = dict(_direct_collectives(body))
        for wm in _WHILE_RE.finditer(body):
            cond, wbody = wm.group(1), wm.group(2)
            trips = _trip_count(comps.get(cond, ""))
            sub = total(wbody, stack + (name,))
            for k, v in sub.items():
                acc[k] = acc.get(k, 0) + trips * v
        # non-while called computations (fusions/conditionals) — count once
        memo[name] = acc
        return acc

    entry = None
    for line in hlo.splitlines():
        if line.startswith("ENTRY"):
            m = re.match(r"ENTRY\s+%?([\w.\-]+)", line)
            if m:
                entry = m.group(1)
            break
    if entry is None:
        # fall back: largest computation
        entry = max(comps, key=lambda k: len(comps[k])) if comps else ""
    per_op = total(entry)
    n_while = hlo.count(" while(")
    diag = {"n_computations": len(comps), "n_while": n_while}
    return per_op, diag


# ---------------------------------------------------------------------------
# trip-aware whole-program stats
# ---------------------------------------------------------------------------
# XLA's HloCostAnalysis counts a while-loop body ONCE (verified on this
# container: a 10-step scanned matmul reports the flops of one step).  Every
# assigned arch scans its layer stack, so cost_analysis under-counts flops
# and bytes by ~n_layers.  hlo_program_stats re-derives both with loop
# trip-count multiplication, mirroring the collective accounting above:
#
#   flops : every `dot` op contributes 2 * result_elems * contracted_elems
#           (found via lhs_contracting_dims + the lhs operand's dims),
#           wherever it appears (top level or inside fusion bodies);
#   bytes : at the top level of the entry / while bodies, each op moves
#           (sum of operand sizes + result size) of HBM traffic — in
#           optimized HLO the top-level ops are fusions/dots/copies whose
#           operands and results are real buffers.  Plumbing ops
#           (parameter/constant/tuple/gte/bitcast/while) are free.

_OP_LINE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*"
    r"((?:\((?:[^()]|\([^()]*\))*\))|(?:[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?))\s*"
    r"([\w\-]+)\((.*?)\)(.*)$")
_OPERAND = re.compile(r"%([\w.\-]+)")
_DIMS = re.compile(r"\[([0-9,]*)\]")
_LHS_C = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_CALLS = re.compile(r"(?:calls|to_apply)=%?([\w.\-]+)")
_BRANCHES = re.compile(r"branch_computations=\{([^}]*)\}")

_FREE_OPS = {"parameter", "constant", "tuple", "get-tuple-element", "bitcast",
             "after-all", "iota", "while", "conditional", "custom-call"}


def _dims_of(shape_txt: str):
    m = _DIMS.search(shape_txt)
    if not m:
        return []
    return [int(d) for d in m.group(1).split(",") if d]


def _op_operands(args_txt: str):
    return _OPERAND.findall(args_txt)


def hlo_program_stats(hlo: str):
    """Returns dict(flops=..., bytes=..., collectives={type: bytes}, n_while=...).
    All trip-count aware; per-device (the module is the partitioned program)."""
    comps = split_computations(hlo)

    # per-computation parse: symbol sizes, op records
    parsed = {}
    for name, body in comps.items():
        sizes = {}
        dims = {}
        ops = []
        for line in body.splitlines():
            m = _OP_LINE.match(line)
            if not m:
                continue
            oname, shape_txt, kind, args, attrs = m.groups()
            sizes[oname] = _shape_bytes(shape_txt)
            dims[oname] = _dims_of(shape_txt)
            ops.append((oname, shape_txt, kind, args, attrs, line))
        parsed[name] = (sizes, dims, ops)

    def dot_flops(comp_name: str, args: str, attrs: str, result_dims) -> float:
        sizes, dims, _ = parsed[comp_name]
        opnds = _op_operands(args)
        if not opnds:
            return 0.0
        lhs = opnds[0]
        lc = _LHS_C.search(attrs)
        contract = 1
        if lc and lhs in dims:
            for d in lc.group(1).split(","):
                if d:
                    contract *= dims[lhs][int(d)]
        n_out = 1
        for d in result_dims:
            n_out *= d
        return 2.0 * n_out * contract

    memo_flops = {}

    def comp_flops(name: str, stack=()) -> float:
        """dot flops of a computation incl. fusion bodies (once per call)."""
        if name in memo_flops:
            return memo_flops[name]
        if name not in parsed or name in stack:
            return 0.0
        sizes, dims, ops = parsed[name]
        total = 0.0
        for oname, shape_txt, kind, args, attrs, line in ops:
            if kind == "dot":
                total += dot_flops(name, args, attrs, _dims_of(shape_txt))
            elif kind in ("fusion", "call", "map", "reduce", "sort", "scatter",
                          "reduce-window", "select-and-scatter"):
                cm = _CALLS.search(attrs)
                if cm:
                    total += comp_flops(cm.group(1), stack + (name,))
            elif kind == "conditional":
                bm = _BRANCHES.search(attrs)
                if bm:
                    for b in _OPERAND.findall(bm.group(1)):
                        total += comp_flops(b, stack + (name,))
        memo_flops[name] = total
        return total

    # ---- fusion/call operand traffic: a parameter consumed only by
    # dynamic-slice reads only the slice; a ROOT dynamic-update-slice writes
    # only the update (in-place aliasing).  This matters enormously for
    # scanned layer stacks, where every step slices one layer out of an
    # (L, ...) stacked weight: the real read is |layer|, not L*|layer|.
    # XLA CPU wraps the slice as  call -> wrapper-computation -> fusion ->
    # dynamic-slice  (outer_dimension_partitions), so the resolution walks
    # through pass-through wrappers recursively.
    def operand_read_bytes(called: str, op_idx: int, full: float,
                           stack=()) -> float:
        """Bytes a fusion/call actually reads from operand `op_idx`."""
        if called not in parsed or called in stack:
            return full
        sizes_c, dims_c, ops_c = parsed[called]
        pidx = {}
        root_dus_dest = None
        for oname, shape_txt, kind, args, attrs, line in ops_c:
            if kind == "parameter":
                try:
                    pidx[int(args.strip())] = oname
                except ValueError:
                    pass
            elif kind == "dynamic-update-slice" and "ROOT" in line:
                opnds = _op_operands(args)
                if opnds:
                    root_dus_dest = opnds[0]   # aliased destination
        pname = pidx.get(op_idx)
        if pname is None:
            return full
        uses = [(k, _op_operands(a), at) for (_, _, k, a, at, _) in ops_c
                if pname in _op_operands(a)]
        if not uses:
            return 0.0
        if all(k == "dynamic-slice" and o and o[0] == pname
               for k, o, _ in uses):
            # read only the slices
            return sum(sizes_c.get(n, 0)
                       for (n, _, k, a, _, _) in ops_c
                       if k == "dynamic-slice" and _op_operands(a)
                       and _op_operands(a)[0] == pname)
        if pname == root_dus_dest:
            return 0.0   # update counted via the result convention
        if all(k in ("fusion", "call") for k, o, _ in uses):
            total = 0.0
            for k, o, at in uses:
                cm = _CALLS.search(at)
                if cm is None:
                    return full
                total += sum(
                    operand_read_bytes(cm.group(1), i, full,
                                       stack + (called,))
                    for i, nm in enumerate(o) if nm == pname)
            return total
        return full

    def fusion_operand_bytes(called: str, operand_names, caller: str) -> float:
        sizes_caller = parsed[caller][0]
        return sum(
            operand_read_bytes(called, i, sizes_caller.get(op, 0))
            for i, op in enumerate(operand_names))

    def fusion_result_bytes(called: str, oname: str, caller: str) -> float:
        full = parsed[caller][0].get(oname, 0)
        if called not in parsed:
            return full
        sizes_c, _, ops_c = parsed[called]
        for n, shape_txt, kind, args, attrs, line in ops_c:
            if kind == "dynamic-update-slice" and "ROOT" in line:
                opnds = _op_operands(args)
                if len(opnds) > 1:
                    return 2.0 * sizes_c.get(opnds[1], 0)
        return full

    memo_stats = {}

    def comp_stats(name: str, stack=()):
        if name in memo_stats:
            return memo_stats[name]
        if name not in parsed or name in stack:
            return (0.0, 0.0, {})
        sizes, dims, ops = parsed[name]
        flops = 0.0
        nbytes = 0.0
        coll = {}
        body = comps[name]
        for oname, shape_txt, kind, args, attrs, line in ops:
            base_kind = kind.replace("-start", "").replace("-done", "")
            if base_kind in ("all-gather", "all-reduce", "reduce-scatter",
                             "all-to-all", "collective-permute"):
                if kind.endswith("-done"):
                    continue
                cb = _shape_bytes(shape_txt)
                if base_kind == "all-reduce":
                    cb *= 2
                elif base_kind == "reduce-scatter":
                    gm = _GROUPS_RE.search(line)
                    cb *= len(gm.group(1).split(",")) if gm else 1
                coll[base_kind] = coll.get(base_kind, 0) + cb
                nbytes += _shape_bytes(shape_txt) * 2
                continue
            if kind == "while":
                cm = re.search(r"condition=%?([\w.\-]+),\s*body=%?([\w.\-]+)", attrs)
                if cm:
                    trips = _trip_count(comps.get(cm.group(1), ""))
                    f, b, c = comp_stats(cm.group(2), stack + (name,))
                    flops += trips * f
                    nbytes += trips * b
                    for k, v in c.items():
                        coll[k] = coll.get(k, 0) + trips * v
                continue
            if kind == "conditional":
                bm = _BRANCHES.search(attrs)
                if bm:
                    branches = _OPERAND.findall(bm.group(1))
                    sub = [comp_stats(b, stack + (name,)) for b in branches]
                    if sub:  # worst-case branch
                        f, b, c = max(sub, key=lambda t: t[0] + t[1])
                        flops += f
                        nbytes += b
                        for k, v in c.items():
                            coll[k] = coll.get(k, 0) + v
                # fall through: operands+result counted below
            called = None
            if kind == "dot":
                flops += dot_flops(name, args, attrs, _dims_of(shape_txt))
            elif kind in ("fusion", "call", "map", "reduce", "sort", "scatter",
                          "reduce-window", "select-and-scatter"):
                cm = _CALLS.search(attrs)
                if cm:
                    called = cm.group(1)
                    flops += comp_flops(called, stack + (name,))
            if kind in _FREE_OPS and kind != "conditional" and kind != "custom-call":
                continue
            # HBM traffic: operands (reads) + result (write).  Slicing ops
            # touch only the slice, not the buffer they index into.
            if kind == "dynamic-slice":
                nbytes += 2 * sizes.get(oname, 0)
                continue
            if kind == "dynamic-update-slice":
                opnds = _op_operands(args)
                upd = sizes.get(opnds[1], 0) if len(opnds) > 1 else 0
                nbytes += 2 * upd
                continue
            if kind in ("fusion", "call") and called is not None:
                nbytes += fusion_result_bytes(called, oname, name)
                nbytes += fusion_operand_bytes(called, _op_operands(args), name)
                continue
            nbytes += sizes.get(oname, 0)
            for op in _op_operands(args):
                nbytes += sizes.get(op, 0)
        memo_stats[name] = (flops, nbytes, coll)
        return memo_stats[name]

    entry = None
    for line in hlo.splitlines():
        if line.startswith("ENTRY"):
            m = re.match(r"ENTRY\s+%?([\w.\-]+)", line)
            if m:
                entry = m.group(1)
            break
    if entry is None and comps:
        entry = max(comps, key=lambda k: len(comps[k]))
    flops, nbytes, coll = comp_stats(entry) if entry else (0.0, 0.0, {})
    return {"flops": flops, "bytes": nbytes, "collectives": coll,
            "n_while": hlo.count(" while("), "n_computations": len(comps)}


_META_RE = re.compile(r'op_name="([^"]*)"')


def top_collectives(hlo: str, k: int = 12):
    """The k largest individual collective ops, trip-count multiplied, with
    their jax op_name metadata — the hillclimb's 'where is it coming from'."""
    comps = split_computations(hlo)
    # trip multiplier per computation (product over the while-nest path)
    mult = {name: 0 for name in comps}
    entry = None
    for line in hlo.splitlines():
        if line.startswith("ENTRY"):
            m = re.match(r"ENTRY\s+%?([\w.\-]+)", line)
            entry = m.group(1) if m else None
            break
    if entry is None:
        return []

    def walk(name, m, seen):
        if name not in comps or name in seen:
            return
        mult[name] = max(mult[name], m)
        for wm in _WHILE_RE.finditer(comps[name]):
            cond, body = wm.group(1), wm.group(2)
            trips = _trip_count(comps.get(cond, ""))
            walk(body, m * trips, seen | {name})

    walk(entry, 1, set())
    out = []
    for name, body in comps.items():
        m = mult.get(name, 0)
        if m <= 0:
            continue
        for line in body.splitlines():
            cm = _COLL_RE.search(line)
            if not cm or (cm.group(3) == "-done"):
                continue
            nbytes = _shape_bytes(cm.group(1))
            op = cm.group(2)
            if op == "all-reduce":
                nbytes *= 2
            elif op == "reduce-scatter":
                gm = _GROUPS_RE.search(line)
                nbytes *= len(gm.group(1).split(",")) if gm else 1
            meta = _META_RE.search(line)
            out.append({"op": op, "bytes": nbytes * m, "trips": m,
                        "where": (meta.group(1)[:120] if meta else "?")})
    out.sort(key=lambda r: -r["bytes"])
    return out[:k]


# ---------------------------------------------------------------------------
# roofline terms (TPU v5e constants per the assignment)
# ---------------------------------------------------------------------------
PEAK_FLOPS = 197e12          # bf16 FLOP/s per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link


def roofline_terms(flops_per_dev: float, bytes_per_dev: float,
                   coll_bytes_per_dev: float) -> Dict[str, float]:
    t_comp = flops_per_dev / PEAK_FLOPS
    t_mem = bytes_per_dev / HBM_BW
    t_coll = coll_bytes_per_dev / ICI_BW
    dom = max(("compute", t_comp), ("memory", t_mem), ("collective", t_coll),
              key=lambda kv: kv[1])[0]
    return {"t_compute_s": t_comp, "t_memory_s": t_mem, "t_collective_s": t_coll,
            "bottleneck": dom}

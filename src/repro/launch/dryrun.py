import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

The two lines above MUST stay first: jax locks the device count at first
initialization, and the production meshes need 512 placeholder host devices.
(Only this entry point does that — tests/benchmarks see the real 1 device.)

Per cell:
    with mesh:
        lowered  = jit(step, in_shardings=..., out_shardings=...).lower(**specs)
        compiled = lowered.compile()
        memory_analysis / cost_analysis / collective-byte parse  -> JSON line

Usage:
    python -m repro.launch.dryrun --arch gemma3-1b --shape train_4k
    python -m repro.launch.dryrun --all --mesh single --out results.jsonl
    python -m repro.launch.dryrun --list
"""
import argparse
import json
import sys
import time
import traceback
from typing import Any, Dict, Optional

import numpy as np
import jax
import jax.numpy as jnp

from ..configs import get_arch, get_diffusion, ARCH_IDS
from ..models.registry import Arch, SHAPES
from ..optim.adamw import AdamWCfg, adamw_init
from ..distributed.sharding import ShardCfg
from . import steps as steps_lib
from . import hlo_analysis
from .mesh import make_production_mesh

SHAPE_IDS = list(SHAPES)


def model_flops(arch: Arch, shape: str) -> float:
    """MODEL_FLOPS = 6*N*D (dense) / 6*N_active*D (MoE) for train shapes;
    2*N*D per generated token for decode; 2*N*D*S_prompt for prefill."""
    cell = SHAPES[shape]
    tokens = cell.global_batch * (cell.seq_len if cell.kind != "decode" else 1)
    n_params = arch.param_count()
    cfg = arch.cfg
    moe = getattr(cfg, "moe", None)
    if moe is not None:
        # active params: replace the expert stack with top_k experts (+shared)
        per_expert = (3 if getattr(cfg, "gated_mlp", True) else 2) * cfg.d_model * moe.d_ff
        n_moe_layers = sum(cfg.layer_moe[i % cfg.pattern] for i in range(cfg.n_layers))
        n_params = n_params - n_moe_layers * (moe.n_experts - moe.top_k) * per_expert
    mult = 6.0 if cell.kind == "train" else 2.0
    return mult * n_params * tokens


def make_shard_cfg(arch, opts: tuple = ()) -> ShardCfg:
    """Baseline ShardCfg, or a §Perf variant via opt flags:
    head_tp   — head-aligned attention TP gating (kills QK^T all-reduce)
    seq_shard — context parallelism (sequence-sharded activations)
    no_fsdp   — TP-only params (weight-stationary serving)
    """
    kw: Dict[str, Any] = {}
    if "head_tp" in opts:
        kw["n_heads"] = getattr(arch.cfg, "n_heads", 0)
        kw["n_kv_heads"] = getattr(arch.cfg, "n_kv_heads",
                                   getattr(arch.cfg, "n_heads", 0))
    if "seq_shard" in opts:
        kw["seq_shard_activations"] = True
    if "no_fsdp" in opts:
        kw["fsdp_params"] = False
    return ShardCfg(**kw)


def run_cell(arch_name: str, shape: str, multi_pod: bool,
             shard_cfg: Optional[ShardCfg] = None,
             dtype=jnp.bfloat16, extra_tag: str = "",
             opts: tuple = ()) -> Dict[str, Any]:
    t_start = time.time()
    rec: Dict[str, Any] = {
        "arch": arch_name, "shape": shape,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "tag": extra_tag or "+".join(opts),
    }
    spec = get_arch(arch_name, dtype=dtype)
    arch = Arch(spec)
    ok, why = spec.shape_applicable(shape)
    if not ok:
        rec.update(status="skipped", reason=why)
        return rec

    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = int(np.prod(list(mesh.shape.values())))
    rec["devices"] = n_dev
    scfg = shard_cfg or make_shard_cfg(arch, opts)
    cell = SHAPES[shape]

    from ..kernels.attention import ops as attn_ops
    attn_ops.FORCE_IMPL = "traffic_stub" if "flash_stub" in opts else None
    from ..distributed import sharding as shd_mod
    if "act_sp" in opts and cell.kind != "decode":
        from jax.sharding import PartitionSpec as PS
        batch_ax = tuple(a for a in scfg.batch_axes if a in mesh.axis_names)
        batch_ax = batch_ax if len(batch_ax) > 1 else (batch_ax[0] if batch_ax else None)
        shd_mod.set_activation_spec(PS(batch_ax, scfg.tp_axis, None))
    else:
        shd_mod.set_activation_spec(None)

    sh = steps_lib.shardings_for(arch, mesh, shape, scfg)
    specs = sh["input_specs"]

    with mesh:
        if cell.kind == "train":
            opt_cfg = AdamWCfg()
            gsh = sh["params"] if "grad_rs" in opts else None
            step = steps_lib.make_train_step(arch, opt_cfg, grad_shardings=gsh)
            fn = jax.jit(step, in_shardings=(sh["params"], sh["opt"], sh["inputs"]),
                         out_shardings=(sh["params"], sh["opt"], None))
            args = (sh["param_shapes"], sh["opt_shapes"], specs)
        elif cell.kind == "prefill":
            step = steps_lib.make_prefill_step(arch, cell.seq_len)
            fn = jax.jit(step, in_shardings=(sh["params"], sh["inputs"]))
            args = (sh["param_shapes"], specs)
        else:  # decode
            step = steps_lib.make_serve_step(arch)
            in_sh = [sh["params"], sh["inputs"]["token"], sh["inputs"]["caches"],
                     sh["inputs"]["cache_len"]]
            args = [sh["param_shapes"], specs["token"], specs["caches"],
                    specs["cache_len"]]
            if "memory" in specs:
                in_sh.append(sh["inputs"]["memory"])
                args.append(specs["memory"])
            fn = jax.jit(step, in_shardings=tuple(in_sh))
            args = tuple(args)

        t0 = time.time()
        lowered = fn.lower(*args)
        t1 = time.time()
        compiled = lowered.compile()
        t2 = time.time()

    rec["lower_s"] = round(t1 - t0, 2)
    rec["compile_s"] = round(t2 - t1, 2)

    ma = compiled.memory_analysis()
    if ma is not None:
        rec["memory"] = {
            "argument_bytes": int(ma.argument_size_in_bytes),
            "output_bytes": int(ma.output_size_in_bytes),
            "temp_bytes": int(ma.temp_size_in_bytes),
            "code_bytes": int(ma.generated_code_size_in_bytes),
        }
        # per-device HBM estimate: args are already per-device shards on a
        # real TPU; temp is the partitioned executable's scratch.
        rec["memory"]["total_bytes"] = (
            rec["memory"]["argument_bytes"] + rec["memory"]["temp_bytes"])

    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    # NOTE: XLA's cost_analysis counts while bodies ONCE (verified on this
    # container) — kept for reference only; the roofline uses the trip-aware
    # hlo_program_stats.
    rec["xla_cost"] = {"flops": float(ca.get("flops", 0.0)),
                       "bytes": float(ca.get("bytes accessed", 0.0))}

    hlo = compiled.as_text()
    stats = hlo_analysis.hlo_program_stats(hlo)
    flops = stats["flops"]
    bytes_acc = stats["bytes"]
    coll = stats["collectives"]
    rec["cost"] = {"flops_per_dev": flops, "bytes_per_dev": bytes_acc}
    rec["collectives"] = coll
    rec["top_collectives"] = hlo_analysis.top_collectives(hlo, k=8)
    rec["hlo_diag"] = {"n_while": stats["n_while"],
                       "n_computations": stats["n_computations"]}
    coll_total = float(sum(coll.values()))
    rec["roofline"] = hlo_analysis.roofline_terms(flops, bytes_acc, coll_total)

    mf = model_flops(arch, shape)
    rec["model_flops_global"] = mf
    rec["model_flops_per_dev"] = mf / n_dev
    rec["useful_flop_ratio"] = (mf / n_dev) / flops if flops else None
    # roofline fraction: ideal time on the dominant term if all flops were
    # useful, over the achievable step time max(terms)
    t_ideal = (mf / n_dev) / hlo_analysis.PEAK_FLOPS
    t_bound = max(rec["roofline"]["t_compute_s"], rec["roofline"]["t_memory_s"],
                  rec["roofline"]["t_collective_s"])
    rec["roofline_fraction"] = t_ideal / t_bound if t_bound else None
    rec["status"] = "ok"
    rec["wall_s"] = round(time.time() - t_start, 2)
    return rec


def run_diffusion_cell(name: str, multi_pod: bool, global_batch: int = 256,
                       opts: tuple = ()) -> Dict[str, Any]:
    """Dry-run the paper's diffusion train step (DiT score net, full size)."""
    from ..distributed.sharding import param_shardings, batch_spec
    from ..distributed import sharding as shd_mod
    from jax.sharding import NamedSharding, PartitionSpec as PS
    rec: Dict[str, Any] = {"arch": name, "shape": f"diffusion_b{global_batch}",
                           "mesh": "2x16x16" if multi_pod else "16x16",
                           "tag": "+".join(opts)}
    t_start = time.time()
    spec = get_diffusion(name)
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = int(np.prod(list(mesh.shape.values())))
    rec["devices"] = n_dev
    scfg = ShardCfg(
        n_heads=spec.score_cfg.n_heads if "head_tp" in opts else 0,
        n_kv_heads=spec.score_cfg.n_heads if "head_tp" in opts else 0,
        fsdp_params="no_fsdp" not in opts)
    from ..kernels.attention import ops as attn_ops
    attn_ops.FORCE_IMPL = "traffic_stub" if "flash_stub" in opts else None
    if "act_sp" in opts:
        batch_ax = tuple(a for a in scfg.batch_axes if a in mesh.axis_names)
        batch_ax = batch_ax if len(batch_ax) > 1 else (batch_ax[0] if batch_ax else None)
        shd_mod.set_activation_spec(PS(batch_ax, scfg.tp_axis, None))
    else:
        shd_mod.set_activation_spec(None)
    pshapes = spec.param_shapes()
    psh = param_shardings(pshapes, mesh, scfg)
    opt_cfg = AdamWCfg()
    opt_shapes = jax.eval_shape(lambda p: adamw_init(p, opt_cfg), pshapes)
    from ..optim.adamw import AdamWState
    osh = AdamWState(step=NamedSharding(mesh, jax.sharding.PartitionSpec()),
                     m=param_shardings(opt_shapes.m, mesh, scfg),
                     v=param_shardings(opt_shapes.v, mesh, scfg),
                     master=param_shardings(opt_shapes.master, mesh, scfg))
    ispecs = spec.input_specs(global_batch)
    ish = {k: NamedSharding(mesh, batch_spec(mesh, scfg, v.ndim, global_batch))
           for k, v in ispecs.items()}
    step = steps_lib.make_diffusion_train_step(spec, opt_cfg)
    key_spec = jax.ShapeDtypeStruct((2,), jnp.uint32)
    with mesh:
        fn = jax.jit(step, in_shardings=(psh, osh, ish,
                                         NamedSharding(mesh, jax.sharding.PartitionSpec())))
        lowered = fn.lower(pshapes, opt_shapes, ispecs, key_spec)
        compiled = lowered.compile()
    ma = compiled.memory_analysis()
    stats = hlo_analysis.hlo_program_stats(compiled.as_text())
    flops, bytes_acc, coll = stats["flops"], stats["bytes"], stats["collectives"]
    rec["memory"] = {"argument_bytes": int(ma.argument_size_in_bytes),
                     "temp_bytes": int(ma.temp_size_in_bytes)} if ma else None
    rec["cost"] = {"flops_per_dev": flops, "bytes_per_dev": bytes_acc}
    rec["collectives"] = coll
    rec["roofline"] = hlo_analysis.roofline_terms(flops, bytes_acc,
                                                  float(sum(coll.values())))
    n_params = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(pshapes))
    tokens = global_batch  # one image = one "token" unit for 6ND accounting
    rec["model_flops_global"] = 6.0 * n_params * tokens
    rec["status"] = "ok"
    rec["wall_s"] = round(time.time() - t_start, 2)
    return rec


def run_diffusion_serve_cell(name: str, multi_pod: bool,
                             global_batch: int = 512, nfe: int = 50,
                             opts: tuple = ()) -> Dict[str, Any]:
    """The paper's technique as a deployed service: one gDDIM predictor
    step of the full-size DiT score net (executed NFE times per batch).
    Inference profile: weight-stationary TP (no FSDP gathers)."""
    from ..distributed.sharding import param_shardings, batch_spec
    from ..distributed import sharding as shd_mod
    from jax.sharding import NamedSharding, PartitionSpec as PS
    from ..core import build_sampler_coeffs, time_grid
    rec: Dict[str, Any] = {"arch": name, "shape": f"gddim_serve_b{global_batch}",
                           "mesh": "2x16x16" if multi_pod else "16x16",
                           "tag": "+".join(opts) or "serve"}
    t_start = time.time()
    spec = get_diffusion(name)
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = int(np.prod(list(mesh.shape.values())))
    rec["devices"] = n_dev
    scfg = ShardCfg(fsdp_params=False,
                    n_heads=spec.score_cfg.n_heads,
                    n_kv_heads=spec.score_cfg.n_heads)
    from ..kernels.attention import ops as attn_ops
    attn_ops.FORCE_IMPL = "traffic_stub" if "flash_stub" in opts else None
    if "act_sp" in opts:
        batch_ax = tuple(a for a in scfg.batch_axes if a in mesh.axis_names)
        batch_ax = batch_ax if len(batch_ax) > 1 else (batch_ax[0] if batch_ax else None)
        shd_mod.set_activation_spec(PS(batch_ax, scfg.tp_axis, None))
    else:
        shd_mod.set_activation_spec(None)
    ts = time_grid(spec.sde, nfe)
    coeffs = build_sampler_coeffs(spec.sde, ts, q=1, kt=spec.kt)
    pshapes = spec.param_shapes()
    psh = param_shardings(pshapes, mesh, scfg)
    u_spec = jax.ShapeDtypeStruct(
        (global_batch,) + spec.sde.state_shape(tuple(spec.data_shape)),
        jnp.float32)
    u_sh = NamedSharding(mesh, batch_spec(mesh, scfg, u_spec.ndim, global_batch))
    step = steps_lib.make_diffusion_serve_step(spec, coeffs)
    with mesh:
        fn = jax.jit(step, in_shardings=(psh, u_sh,
                                         NamedSharding(mesh, PS())),
                     out_shardings=u_sh)
        compiled = fn.lower(pshapes, u_spec,
                            jax.ShapeDtypeStruct((), jnp.int32)).compile()
    stats = hlo_analysis.hlo_program_stats(compiled.as_text())
    rec["cost"] = {"flops_per_dev": stats["flops"], "bytes_per_dev": stats["bytes"]}
    rec["collectives"] = stats["collectives"]
    rec["roofline"] = hlo_analysis.roofline_terms(
        stats["flops"], stats["bytes"], float(sum(stats["collectives"].values())))
    ma = compiled.memory_analysis()
    rec["memory"] = {"argument_bytes": int(ma.argument_size_in_bytes),
                     "temp_bytes": int(ma.temp_size_in_bytes)} if ma else None
    rec["nfe"] = nfe
    rec["status"] = "ok"
    rec["wall_s"] = round(time.time() - t_start, 2)
    return rec


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default=None, help="architecture id (or 'diffusion:NAME')")
    ap.add_argument("--shape", default=None, choices=SHAPE_IDS)
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true", help="run every applicable cell")
    ap.add_argument("--out", default=None, help="append JSON lines here")
    ap.add_argument("--list", action="store_true")
    ap.add_argument("--opt", default="", help="comma list: head_tp,seq_shard,no_fsdp")
    args = ap.parse_args(argv)

    if args.list:
        for a in ARCH_IDS:
            spec = get_arch(a, reduced=True)
            cells = [s for s in SHAPE_IDS if spec.shape_applicable(s)[0]]
            print(f"{a:28s} {', '.join(cells)}")
        return 0

    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]
    cells = []
    if args.all:
        for a in ARCH_IDS:
            for s in SHAPE_IDS:
                cells.append((a, s))
    else:
        if not args.arch or (not args.shape
                             and not args.arch.startswith(("diffusion:",
                                                           "diffusion-serve:"))):
            ap.error("--arch and --shape required unless --all/--list")
        cells.append((args.arch, args.shape))

    rc = 0
    for (a, s) in cells:
        for mp in meshes:
            try:
                opts = tuple(o for o in args.opt.split(",") if o)
                if a.startswith("diffusion:"):
                    rec = run_diffusion_cell(a.split(":", 1)[1], mp, opts=opts)
                elif a.startswith("diffusion-serve:"):
                    rec = run_diffusion_serve_cell(a.split(":", 1)[1], mp,
                                                   opts=opts)
                else:
                    rec = run_cell(a, s, mp, opts=opts)
            except Exception as e:  # a failed cell is a bug in the system
                rec = {"arch": a, "shape": s,
                       "mesh": "2x16x16" if mp else "16x16",
                       "status": "error", "error": repr(e),
                       "trace": traceback.format_exc()[-2000:]}
                rc = 1
            line = json.dumps(rec)
            print(line if rec.get("status") != "error" else
                  f"ERROR {a} {s}: {rec['error']}", flush=True)
            if args.out:
                with open(args.out, "a") as f:
                    f.write(line + "\n")
            jax.clear_caches()  # 80-cell sweeps in one process: drop the jit cache
    return rc


if __name__ == "__main__":
    sys.exit(main())

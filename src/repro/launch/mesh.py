"""Production mesh construction.

Importing this module never touches jax device state; every helper is a
function so the dry-run can set XLA_FLAGS before any jax initialization
(see dryrun.py, which must set --xla_force_host_platform_device_count=512
in its very first lines).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single-pod (256 chips) or 2x16x16 two-pod (512 chips) mesh."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(data: int = 1, model: int = 1):
    """Small mesh over the actually-present devices (tests / smoke runs)."""
    return jax.make_mesh((data, model), ("data", "model"))


def parse_mesh_spec(spec: str) -> dict:
    """Parse a ``--mesh`` flag value into {"data": int, "model": int}.

    Accepted forms (axis names are the serving mesh's ``data``/``model``):

        "data=2"            2-way data parallel, model replicated
        "data=2,model=4"    explicit both axes
        "auto"              all present devices on the data axis
        "2"  / "2x4"        positional shorthand for data(/model)
    """
    spec = spec.strip().lower()
    if spec == "auto":
        return {"data": jax.device_count(), "model": 1}
    out = {"data": 1, "model": 1}
    if "=" in spec:
        for part in spec.split(","):
            part = part.strip()
            if not part:
                continue
            key, _, val = part.partition("=")
            key = key.strip()
            if key not in out:
                raise ValueError(f"unknown mesh axis {key!r} in {spec!r} "
                                 "(serving meshes have axes data, model)")
            try:
                out[key] = int(val)
            except ValueError:
                raise ValueError(
                    f"bad size {val!r} for mesh axis {key} in {spec!r}") \
                    from None
    else:
        sizes = spec.replace("x", ",").split(",")
        try:
            out["data"] = int(sizes[0])
            if len(sizes) > 1:
                out["model"] = int(sizes[1])
            if len(sizes) > 2:
                raise ValueError
        except ValueError:
            raise ValueError(f"bad --mesh spec {spec!r}; try 'data=2', "
                             "'data=2,model=1', '2x1' or 'auto'") from None
    if out["data"] < 1 or out["model"] < 1:
        raise ValueError(f"mesh axis sizes must be >= 1, got {out}")
    return out


def make_serve_mesh(spec):
    """(data, model) mesh for the serving engines from a ``--mesh`` flag
    value; None (or empty) means single-device (no mesh)."""
    if spec is None or (isinstance(spec, str) and not spec.strip()):
        return None
    axes = parse_mesh_spec(spec)
    need = axes["data"] * axes["model"]
    have = jax.device_count()
    if need > have:
        raise ValueError(
            f"--mesh {spec!r} needs {need} devices, {have} present "
            "(hint: XLA_FLAGS=--xla_force_host_platform_device_count=N "
            "forces N virtual host devices)")
    return make_local_mesh(data=axes["data"], model=axes["model"])

"""Production mesh construction.

Importing this module never touches jax device state; both helpers are
functions so the dry-run can set XLA_FLAGS before any jax initialization
(see dryrun.py, which must set --xla_force_host_platform_device_count=512
in its very first lines).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single-pod (256 chips) or 2x16x16 two-pod (512 chips) mesh."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(data: int = 1, model: int = 1):
    """Small mesh over the actually-present devices (tests / smoke runs)."""
    return jax.make_mesh((data, model), ("data", "model"))

"""Fault-tolerant checkpointing: atomic sharded npz, async writer, resume.

Layout:

    <dir>/step_<N>/shard_<p>.npz     one file per host process (host-sharded)
    <dir>/step_<N>/MANIFEST.json     tree structure + shapes + dtypes
    <dir>/step_<N>/COMMITTED         sentinel written LAST (atomic commit)
    <dir>/latest                     text file -> "step_<N>"

Crash-safety: a step directory without COMMITTED is ignored by
`latest_step` and garbage-collected on the next save — a writer killed
mid-flight (preemption) can never corrupt restart.  The async writer runs
in a daemon thread; `wait()` joins it (called before the next save and at
exit).  Restore is exact: training is a pure function of
(params, opt_state, data_state), all of which are stored.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Optional, Tuple

import numpy as np
import jax
import jax.numpy as jnp

SENTINEL = "COMMITTED"


def _flatten(tree: Any):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


class CheckpointStore:
    def __init__(self, directory: str, keep: int = 3, process_index: int = 0):
        self.dir = directory
        self.keep = keep
        self.proc = process_index
        self._thread: Optional[threading.Thread] = None
        os.makedirs(directory, exist_ok=True)

    # ---- save ---------------------------------------------------------------
    def save(self, step: int, tree: Any, *, blocking: bool = False) -> None:
        """Snapshot `tree` (pytree of arrays) for `step`.  Device arrays are
        fetched to host *before* the async thread starts, so training can
        continue while the write happens."""
        self.wait()
        leaves, treedef = _flatten(tree)
        # copy=True: on CPU backends np.asarray can alias the device buffer,
        # and the training loop donates params/opt into the next step — an
        # aliased view would let that step scribble over the snapshot while
        # the async writer reads it
        host_leaves = [np.array(l, copy=True) for l in leaves]
        manifest = {
            "step": step,
            "n_leaves": len(host_leaves),
            "treedef": str(treedef),
        }

        def write():
            path = os.path.join(self.dir, f"step_{step}")
            tmp = path + f".tmp_{self.proc}"
            os.makedirs(tmp, exist_ok=True)
            np.savez(os.path.join(tmp, f"shard_{self.proc}.npz"),
                     **{f"leaf_{i}": a for i, a in enumerate(host_leaves)})
            with open(os.path.join(tmp, "MANIFEST.json"), "w") as f:
                json.dump(manifest, f)
            # atomic commit: rename then sentinel then latest pointer
            if os.path.exists(path):
                shutil.rmtree(path)
            os.rename(tmp, path)
            with open(os.path.join(path, SENTINEL), "w") as f:
                f.write("ok")
            lat_tmp = os.path.join(self.dir, ".latest_tmp")
            with open(lat_tmp, "w") as f:
                f.write(f"step_{step}")
            os.replace(lat_tmp, os.path.join(self.dir, "latest"))
            self._gc()

        if blocking:
            write()
        else:
            self._thread = threading.Thread(target=write, daemon=True)
            self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    # ---- restore --------------------------------------------------------------
    def latest_step(self) -> Optional[int]:
        lat = os.path.join(self.dir, "latest")
        if not os.path.exists(lat):
            return None
        with open(lat) as f:
            name = f.read().strip()
        path = os.path.join(self.dir, name)
        if not os.path.exists(os.path.join(path, SENTINEL)):
            # crashed mid-commit: scan for the newest committed step
            return self._scan_latest()
        return int(name.split("_")[1])

    def _scan_latest(self) -> Optional[int]:
        steps = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and os.path.exists(
                    os.path.join(self.dir, name, SENTINEL)):
                steps.append(int(name.split("_")[1]))
        return max(steps) if steps else None

    def restore(self, step: int, like: Any) -> Any:
        """Restore into the structure (and shardings) of `like`."""
        self.wait()
        path = os.path.join(self.dir, f"step_{step}")
        if not os.path.exists(os.path.join(path, SENTINEL)):
            raise FileNotFoundError(f"no committed checkpoint at step {step}")
        data = np.load(os.path.join(path, f"shard_{self.proc}.npz"))
        leaves, treedef = _flatten(like)
        out = []
        for i, l in enumerate(leaves):
            arr = data[f"leaf_{i}"]
            if hasattr(l, "sharding"):
                out.append(jax.device_put(arr.astype(l.dtype), l.sharding))
            else:
                out.append(jnp.asarray(arr, getattr(l, "dtype", None)))
        return jax.tree_util.tree_unflatten(treedef, out)

    def restore_latest(self, like: Any) -> Tuple[Optional[int], Any]:
        step = self.latest_step()
        if step is None:
            return None, like
        return step, self.restore(step, like)

    # ---- gc ---------------------------------------------------------------------
    def _gc(self) -> None:
        steps = sorted(
            int(n.split("_")[1]) for n in os.listdir(self.dir)
            if n.startswith("step_") and not n.endswith((".tmp_0",))
            and os.path.exists(os.path.join(self.dir, n, SENTINEL)))
        for s in steps[:-self.keep] if self.keep else []:
            shutil.rmtree(os.path.join(self.dir, f"step_{s}"), ignore_errors=True)
        # sweep uncommitted debris
        for n in os.listdir(self.dir):
            p = os.path.join(self.dir, n)
            if ".tmp_" in n and os.path.isdir(p):
                shutil.rmtree(p, ignore_errors=True)
            elif n.startswith("step_") and os.path.isdir(p) and \
                    not os.path.exists(os.path.join(p, SENTINEL)):
                shutil.rmtree(p, ignore_errors=True)

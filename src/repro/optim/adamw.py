"""AdamW with mixed precision, global-norm clipping, EMA, and LR schedules.

Mixed-precision contract (the "bf16 gradient compression" of DESIGN.md §4):
compute params may be bf16 — gradients then *are* bf16 end to end, so every
cross-device reduce-scatter/all-reduce moves half the bytes (this is how
gradient compression is expressed jax-natively: the collective dtype follows
the tensor dtype, no NCCL hooks).  The optimizer keeps f32 master weights +
f32 (m, v); `update` consumes bf16 grads, updates the masters in f32, and
re-casts to the compute dtype.

All state is a pytree congruent with params, so the ZeRO sharding rules in
distributed/sharding.py apply verbatim (opt state inherits the param spec).
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class AdamWCfg:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    schedule: str = "cosine"          # cosine | linear | constant
    min_lr_frac: float = 0.1
    master_f32: bool = True


class AdamWState(NamedTuple):
    step: Array
    m: Any
    v: Any
    master: Any                       # f32 master params (or None-like empty)


def adamw_init(params: Any, cfg: AdamWCfg) -> AdamWState:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    # master must be a *distinct* buffer: astype is an alias for f32 params,
    # and an aliased master breaks donated train steps (the same buffer
    # would be donated twice via params and opt_state)
    master = jax.tree.map(
        lambda p: jnp.copy(p) if p.dtype == jnp.float32
        else p.astype(jnp.float32), params) \
        if cfg.master_f32 else jax.tree.map(lambda p: jnp.zeros((), jnp.float32), params)
    return AdamWState(step=jnp.zeros((), jnp.int32), m=zeros,
                      v=jax.tree.map(jnp.copy, zeros), master=master)


def lr_at(cfg: AdamWCfg, step: Array) -> Array:
    s = step.astype(jnp.float32)
    warm = jnp.minimum(s / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    frac = jnp.clip((s - cfg.warmup_steps) /
                    jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    if cfg.schedule == "cosine":
        decay = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (1 + jnp.cos(jnp.pi * frac))
    elif cfg.schedule == "linear":
        decay = 1.0 - (1 - cfg.min_lr_frac) * frac
    else:
        decay = jnp.float32(1.0)
    return cfg.lr * warm * decay


def global_norm(tree: Any) -> Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves))


def clip_by_global_norm(grads: Any, max_norm: float) -> Tuple[Any, Array]:
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype),
                        grads), norm


def adamw_update(grads: Any, state: AdamWState, params: Any,
                 cfg: AdamWCfg) -> Tuple[Any, AdamWState, dict]:
    """Returns (new_params, new_state, metrics)."""
    if cfg.clip_norm:
        grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    else:
        gnorm = global_norm(grads)
    step = state.step + 1
    lr = lr_at(cfg, step)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(g, m, v, mst, p):
        g32 = g.astype(jnp.float32)
        m = b1 * m + (1 - b1) * g32
        v = b2 * v + (1 - b2) * jnp.square(g32)
        mhat = m / bc1
        vhat = v / bc2
        base = mst if cfg.master_f32 else p.astype(jnp.float32)
        new = base - lr * (mhat / (jnp.sqrt(vhat) + cfg.eps)
                           + cfg.weight_decay * base)
        return m, v, new

    flat_g, treedef = jax.tree.flatten(grads)
    flat_m = treedef.flatten_up_to(state.m)
    flat_v = treedef.flatten_up_to(state.v)
    flat_mst = treedef.flatten_up_to(state.master)
    flat_p = treedef.flatten_up_to(params)
    out = [upd(*t) for t in zip(flat_g, flat_m, flat_v, flat_mst, flat_p)]
    new_m = treedef.unflatten([o[0] for o in out])
    new_v = treedef.unflatten([o[1] for o in out])
    new_master = treedef.unflatten([o[2] for o in out])
    new_params = treedef.unflatten(
        [o[2].astype(p.dtype) for o, p in zip(out, flat_p)])
    new_state = AdamWState(step=step, m=new_m, v=new_v,
                           master=new_master if cfg.master_f32 else state.master)
    return new_params, new_state, {"lr": lr, "grad_norm": gnorm}


# ---------------------------------------------------------------------------
# EMA (paper Tab. 4 uses EMA rate 0.9999 on CIFAR10)
# ---------------------------------------------------------------------------
def ema_init(params: Any) -> Any:
    return jax.tree.map(lambda p: p.astype(jnp.float32), params)


def ema_update(ema: Any, params: Any, rate: float) -> Any:
    return jax.tree.map(
        lambda e, p: rate * e + (1.0 - rate) * p.astype(jnp.float32), ema, params)

"""Stage I — offline computation of every gDDIM sampler coefficient.

Mirrors the paper's App. C.3 pipeline exactly:

  Step 1  pick the (decreasing) sampling grid {t_i}, i = 0..N, t_0 = t_min,
          t_N = T.
  Step 2  transition matrices Psi(t_{i-1}, t_i)            (closed form/expm)
  Step 3  R_t via Eq. 17                                   (from the SDE)
  Step 4  EI multistep predictor/corrector constants pC/cC (Eqs. 41/46,
          composite-Simpson quadrature), and for stochastic gDDIM the
          lambda-family transition Psi_hat (Eq. 81) and injected covariance
          P_st (Eq. 23) via RK4 per step.

All math is family-generic: coefficients are numpy arrays whose shape is the
SDE family's coeff shape (scalar () / CLD (2,2) / BDM freq-grid), manipulated
through `sde.ops`.  The result is a `SamplerCoeffs` pytree of *stacked* jnp
arrays consumed by the lax.scan samplers in repro.core.gddim (Stage II).

Warm-start handling: at step i the usable history is q_cur = min(q, N-i+1)
points (Alg. 1); we bake this in by computing the *lower-order* Lagrange
coefficients for the first steps and zero-padding to q slots, so the device
loop is branch-free.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Mapping, NamedTuple, Optional, \
    Sequence, Tuple, Union

import numpy as np
import jax.numpy as jnp

from ..sde.base import LinearSDE, family_name
from ..sde import solve


class SamplerCoeffs(NamedTuple):
    """Stacked per-step coefficients (device arrays).  Axis 0: step k = 0..N-1,
    where step k advances t_i -> t_{i-1} with i = N - k."""
    ts: jnp.ndarray            # (N+1,) the grid, ts[0]=t_min .. ts[N]=T (increasing)
    psi: jnp.ndarray           # (N, *coeff)  Psi(t_{i-1}, t_i)
    pC: jnp.ndarray            # (N, q, *coeff)  predictor coeffs, slot j ~ eps(t_{i+j})
    cC: jnp.ndarray            # (N, q, *coeff)  corrector coeffs, slot 0 ~ eps(t_{i-1}),
                               #                  slot j>=1 ~ eps(t_{i+j-1})
    psi_hat: jnp.ndarray       # (N, *coeff)  lambda-family transition Psi_hat(t_{i-1}, t_i)
    B: jnp.ndarray             # (N, *coeff)  (Psi_hat - Psi) R_{t_i}   (Eq. 22 mean)
    P_chol: jnp.ndarray        # (N, *coeff)  chol of injected covariance P (Eq. 23)
    R: jnp.ndarray             # (N+1, *coeff) R_{t_i} on the grid
    R_invT: jnp.ndarray        # (N+1, *coeff) R_{t_i}^{-T} (score <-> eps conversion)
    Sigma: jnp.ndarray         # (N+1, *coeff)
    lam: float = 0.0
    pM: jnp.ndarray = None     # (N, *coeff)  first-moment EI quadrature
                               #   int ei_core(t_{i-1}, tau) (tau - t_i) dtau
                               #   — the accel correction's building block


def time_grid(sde: LinearSDE, n_steps: int, kind: str = "quadratic") -> np.ndarray:
    """Sampling grid t_min..T (increasing).  'quadratic' concentrates steps
    near t_min like the DDIM/EDM conventions; 'uniform' is linear."""
    x = np.linspace(0.0, 1.0, n_steps + 1)
    if kind == "quadratic":
        x = x**2
    elif kind != "uniform":
        raise ValueError(kind)
    return sde.t_min + (sde.T - sde.t_min) * x


def _K_fn(sde: LinearSDE, kt: str) -> Callable[[float], np.ndarray]:
    """The paper's K_t choices: 'R' (gDDIM), 'L' (Cholesky), 'sqrt' (sym-sqrt)."""
    if kt == "R":
        return sde.R_np
    if kt == "L":
        return sde.L_np
    if kt == "sqrt":
        return lambda t: sde.ops.sqrt_psd(sde.Sigma_np(t))
    raise ValueError(kt)


def build_sampler_coeffs(
    sde: LinearSDE,
    ts: Sequence[float],
    q: int = 2,
    lam: float = 0.0,
    kt: str = "R",
    quad_points: int = 48,
    rk_substeps: int = 32,
) -> SamplerCoeffs:
    """Compute all Stage-I constants for grid `ts` (increasing, len N+1)."""
    ops = sde.ops
    ts = np.asarray(ts, np.float64)
    N = len(ts) - 1
    K = _K_fn(sde, kt)

    def KinvT(tau: float) -> np.ndarray:
        # K^{-T} = Sigma^{-1} K exactly (K K^T = Sigma), which keeps the
        # interpolation error of the gridded R_t *linear* instead of
        # amplified through an explicit inverse near the stiff origin.
        return ops.mul(ops.inv(sde.Sigma_np(tau)), K(tau))

    # integrand core 1/2 Psi(t_e, tau) G2(tau) K(tau)^{-T}
    def ei_core(t_end: float, tau: float) -> np.ndarray:
        return 0.5 * ops.mul(ops.mul(sde.Psi_np(t_end, tau), sde.G2_np(tau)), KinvT(tau))

    coeff_shape = np.shape(np.asarray(ops.eye()))
    psi, pC, cC, pM = [], [], [], []
    psi_hat, B, P_chol = [], [], []

    # generator of the lambda-family SDE (Eq. 51): F_hat = F + (1+lam^2)/2 G2 Sigma^{-1}
    def F_hat(tau: float) -> np.ndarray:
        return sde.F_np(tau) + 0.5 * (1.0 + lam * lam) * ops.mul(
            sde.G2_np(tau), ops.inv(sde.Sigma_np(tau)))

    for k in range(N):
        i = N - k                      # step from t_i down to t_{i-1}
        t_i, t_im1 = float(ts[i]), float(ts[i - 1])
        psi.append(np.asarray(sde.Psi_np(t_im1, t_i), np.float64))

        # ---- predictor coefficients (Eq. 41), history nodes t_i..t_{i+q_cur-1}
        q_cur = min(q, N - i + 1)
        nodes_p = [float(ts[min(i + j, N)]) for j in range(q_cur)]
        row_p = np.zeros((q,) + coeff_shape)
        for j in range(q_cur):
            ell = solve.lagrange_basis(nodes_p, j)
            row_p[j] = solve.quad_coeff(
                lambda tau: ei_core(t_im1, tau) * ell(tau), t_i, t_im1, quad_points)
        pC.append(row_p)

        # ---- first moment of the EI kernel about t_i (accel correction):
        #      pM = int ei_core(t_{i-1}, tau) (tau - t_i) dtau.  Always
        #      computed (cheap, one more quadrature) so every cached
        #      Stage-I result can serve any algorithm= choice.
        pM.append(solve.quad_coeff(
            lambda tau: ei_core(t_im1, tau) * (tau - t_i), t_i, t_im1,
            quad_points))

        # ---- corrector coefficients (Eq. 46), nodes t_{i-1}, t_i, .., t_{i+q_cur-2}
        q_corr = min(q, N - i + 2)
        nodes_c = [t_im1] + [float(ts[min(i + j, N)]) for j in range(q_corr - 1)]
        row_c = np.zeros((q,) + coeff_shape)
        for j in range(q_corr):
            ell = solve.lagrange_basis(nodes_c, j)
            row_c[j] = solve.quad_coeff(
                lambda tau: ei_core(t_im1, tau) * ell(tau), t_i, t_im1, quad_points)
        cC.append(row_c)

        # ---- stochastic pieces: Psi_hat (Eq. 81) and P (Eq. 23) over [t_i, t_im1]
        def psi_hat_rhs(tau, Y):
            return ops.mul(F_hat(tau), Y)

        ph = solve.integrate_ode(psi_hat_rhs, ops.eye() + 0.0, t_i, t_im1, rk_substeps)
        psi_hat.append(np.asarray(ph, np.float64))
        B.append(ops.mul(ph - psi[-1], np.asarray(K(t_i), np.float64)))

        if lam > 0.0:
            # Eq. 23 in the reverse-time parameterization sigma = s - tau
            # (the sampler runs backward; variance grows moving away from s):
            #   dP/dsigma = -(F_hat P + P F_hat^T) + lam^2 G2,  P(0) = 0.
            G2c = lam * lam

            def p_rhs(sig, P):
                tau = t_i - sig
                fh = F_hat(tau)
                return -(ops.mul(fh, P) + ops.mul(P, ops.transpose(fh))) \
                    + G2c * sde.G2_np(tau)

            P = solve.integrate_ode(p_rhs, ops.zeros() + 0.0, 0.0, t_i - t_im1,
                                    rk_substeps)
            # integrating backward in time leaves tiny asymmetry/negativity
            if ops.family == "block":
                P = 0.5 * (P + ops.transpose(P))
                P = P + 1e-14 * np.trace(P) * np.eye(P.shape[-1])
            else:
                P = np.maximum(P, 0.0)
            P_chol.append(ops.chol(P))
        else:
            P_chol.append(np.zeros(coeff_shape))

    R_stack = np.stack([np.asarray(K(float(t)), np.float64) for t in ts])
    RinvT_stack = np.stack([np.asarray(KinvT(float(t)), np.float64) for t in ts])
    Sig_stack = np.stack([np.asarray(sde.Sigma_np(float(t)), np.float64) for t in ts])

    f32 = lambda x: jnp.asarray(np.asarray(x), jnp.float32)
    return SamplerCoeffs(
        ts=f32(ts),
        psi=f32(np.stack(psi)),
        pC=f32(np.stack(pC)),
        cC=f32(np.stack(cC)),
        psi_hat=f32(np.stack(psi_hat)),
        B=f32(np.stack(B)),
        P_chol=f32(np.stack(P_chol)),
        R=f32(R_stack),
        R_invT=f32(RinvT_stack),
        Sigma=f32(Sig_stack),
        lam=float(lam),
        pM=f32(np.stack(pM)),
    )


# ---------------------------------------------------------------------------
# Sampler-config cache: many sampler families, one compiled step.
# ---------------------------------------------------------------------------
# Bucket minima for the stacked bank.  A bank whose (configs, steps, order)
# all fit inside the warmed bucket reuses the compiled step program verbatim:
# the bank is an *argument* of the jitted step, so only a bucket overflow
# (which doubles the padded axis) changes shapes and triggers one new
# compilation.
C_BUCKET_MIN = 4      # config slots
N_BUCKET_MIN = 8      # sampler steps (NFE)
Q_BUCKET_MIN = 2      # multistep order


def bucket_size(n: int, minimum: int) -> int:
    """Smallest power-of-two multiple of `minimum` that holds `n`."""
    b = minimum
    while b < n:
        b *= 2
    return b


# ---------------------------------------------------------------------------
# The sampler-algorithm axis: per-request update rules beyond gDDIM.
# ---------------------------------------------------------------------------
# Every algorithm is, at serving time, a transform of the Stage-I stacks
# into different FactoredBank coefficient rows (plus, for 'gmm', a
# different in-step noise law) — the bank layout, the compiled step and
# the (family, corrector, precision) variant classes are untouched, so
# mixed-algorithm batches serve with zero recompiles after warmup.
#
#   gddim  the paper's update family (Eqs. 19/22/45) — the identity
#          transform.
#   gmm    Gabbur's moment-matched GMM reverse kernel (arXiv:2311.04938):
#          the Eq. 22 Gaussian innovation is replaced by a K=2 symmetric
#          per-coordinate mixture with the SAME first two moments —
#          noise' = sqrt(1 - rho^2) (z + c s), z ~ N(0,1),
#          s = +-1 Rademacher, c = rho / sqrt(1 - rho^2), so
#          E[noise'] = 0 and Var[noise'] = (1-rho^2)(1+c^2) = 1 exactly.
#          The sqrt(1-rho^2) lands in the P_chol rows (host, f64); the
#          (z + c s) part is the per-slot noise transform keyed by
#          GMM_SALT.  Requires lam > 0 (it reshapes the injected noise).
#   accel  Li et al.'s provably-accelerated sampler (arXiv:2403.03852):
#          a half-damped backward-difference correction of the eps slope,
#          eps(tau) ~ eps_i + (tau - t_i)(eps_i - eps_{i+1})/(t_i - t_{i+1}),
#          taken at half weight.  Its exact EI quadrature is the first
#          moment pM = int ei_core (tau - t_i) dtau (SamplerCoeffs.pM),
#          landing as one extra per-step coefficient row: with
#          delta = t_i - t_{i+1}, slot0 += pM/(2 delta), slot1 = -pM/(2 delta)
#          (first step has no history — plain single-step row).  Requires
#          q == 1 / lam == 0 / corrector off; consumes 2 history slots.
ALGORITHMS = ("gddim", "gmm", "accel")
ALG_GDDIM, ALG_GMM, ALG_ACCEL = 0, 1, 2

GMM_RHO = 0.5                                  # mixture separation rho
GMM_SCALE = float(np.sqrt(1.0 - GMM_RHO * GMM_RHO))   # f64, host-side
GMM_C = np.float32(GMM_RHO / np.sqrt(1.0 - GMM_RHO * GMM_RHO))
GMM_SALT = 0x6A66                              # second-stream fold ('jf')


def effective_q(cfg: "SamplerConfig") -> int:
    """History slots the device step actually consumes for `cfg`: the
    accel correction spends one extra slot on the previous step's eps
    (cfg.q stays 1 — the request surface's order knob is untouched)."""
    return 2 if cfg.algorithm == "accel" else cfg.q


def algorithm_coeff_stacks(co: SamplerCoeffs, cfg: "SamplerConfig",
                           coeff_shape: Tuple[int, ...]
                           ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Per-algorithm transform of the Stage-I stacks into the rows the
    bank actually stores: float64 (pC, cC, P_chol) shaped
    (N, q_eff, *coeff) / (N, q_eff, *coeff) / (N, *coeff).

    This is THE coefficient generator of the algorithm axis — shared by
    `CoeffCache._factor_rows` and the dense differential oracle
    (tests/dense_reference.py) so the two stay transform-for-transform
    identical, keeping factored == dense bitwise after the f32 casts.
    """
    N, q, qe = cfg.nfe, cfg.q, effective_q(cfg)
    pC = np.asarray(co.pC, np.float64)
    cC = np.asarray(co.cC, np.float64)
    P = np.asarray(co.P_chol, np.float64)
    if cfg.algorithm == "gddim":
        return pC, cC, P
    if cfg.algorithm == "gmm":
        # moment matching: the mixture draw (z + c s) has variance
        # 1 + c^2 = 1/(1 - rho^2); scaling its Cholesky rows by
        # sqrt(1 - rho^2) restores Var = P exactly (see GMM_SCALE)
        return pC, cC, GMM_SCALE * P
    if cfg.algorithm == "accel":
        ts = np.asarray(co.ts, np.float64)
        pM = np.asarray(co.pM, np.float64)
        out = np.zeros((N, qe) + coeff_shape, np.float64)
        out[:, 0] = pC[:, 0]          # k = 0 (i = N): no history yet
        for k in range(1, N):
            i = N - k
            delta = float(ts[i] - ts[i + 1])     # t_i - t_{i+1} (< 0)
            corr = 0.5 * pM[k] / delta
            out[k, 0] = pC[k, 0] + corr
            out[k, 1] = -corr
        cc = np.zeros((N, qe) + coeff_shape, np.float64)
        cc[:, :q] = cC
        return out, cc, P
    raise ValueError(f"unknown algorithm {cfg.algorithm!r}")


@dataclasses.dataclass(frozen=True)
class SamplerConfig:
    """One point in gDDIM's sampler family (the per-request surface).

    nfe        number of grid steps N (= model evaluations for the
               predictor; the corrector adds N-1 more — see
               `sample_gddim`'s NFE accounting)
    q          exponential-multistep order (Eq. 19/41); stochastic
               sampling is single-step, so q must be 1 when lam > 0
    corrector  run the Eq. 45 corrector after every predictor step but
               the last (Alg. 1)
    lam        stochasticity level lambda of Eq. 22 (0 = deterministic)
    grid       time-grid kind ('quadratic' | 'uniform', see `time_grid`)
    family     SDE family to sample from ('vpsde' | 'cld' | 'bdm', the
               `repro.sde.base.family_name` keys of the engine's resident
               families).  None means "the engine/cache default family";
               the name itself is validated where families are known
               (`CoeffCache.resolve`)
    algorithm  sampler update rule ('gddim' | 'gmm' | 'accel', see the
               `ALGORITHMS` block above).  'gmm' reshapes the injected
               noise so it requires lam > 0; 'accel' is a deterministic
               single-step correction so it requires q == 1, lam == 0,
               corrector off
    """
    nfe: int
    q: int = 1
    corrector: bool = False
    lam: float = 0.0
    grid: str = "quadratic"
    family: Optional[str] = None
    algorithm: str = "gddim"

    def __post_init__(self):
        if self.nfe < 1:
            raise ValueError(f"nfe must be >= 1, got {self.nfe}")
        if self.q < 1:
            raise ValueError(f"q must be >= 1, got {self.q}")
        if self.lam < 0.0:
            raise ValueError(f"lam must be >= 0, got {self.lam}")
        if self.lam > 0.0 and (self.q != 1 or self.corrector):
            raise ValueError(
                "stochastic gDDIM (lam > 0, Eq. 22) is single-step: "
                "q must be 1 and corrector off")
        if self.grid not in ("quadratic", "uniform"):
            raise ValueError(f"unknown grid kind {self.grid!r}")
        if self.algorithm not in ALGORITHMS:
            raise ValueError(f"unknown algorithm {self.algorithm!r}; "
                             f"choose from {ALGORITHMS}")
        if self.algorithm == "gmm" and self.lam <= 0.0:
            raise ValueError(
                "algorithm='gmm' reshapes the injected Eq. 22 noise, so "
                "it needs a stochastic config (lam > 0)")
        if self.algorithm == "accel" and (
                self.q != 1 or self.lam > 0.0 or self.corrector):
            raise ValueError(
                "algorithm='accel' is a deterministic single-step "
                "correction: q must be 1, lam 0, corrector off")


class CoeffBank(NamedTuple):
    """Stacked, bucket-padded Stage-I coefficients for >= 1 sampler configs.

    Axis 0 is the config slot c, axis 1 the step index k (a step advances
    t_i -> t_{i-1} with i = N_c - k).  Real data occupies [:C, :N_c(, :q_c)]
    of each leaf; the padding is zeros (coefficients) or edge values (times)
    and is never read because the serve step clips k to n_steps[c] - 1 and
    zero coefficient rows annihilate their term.

      t_cur   (C, Nb)             t_i   — model-eval time at step k
      t_nxt   (C, Nb)             t_{i-1} — corrector-eval time at step k
      psi     (C, Nb, *coeff)     transition Psi(t_{i-1}, t_i)
      pC      (C, Nb, Qb, *coeff) predictor coeffs (Eq. 41)
      cC      (C, Nb, Qb, *coeff) corrector coeffs (Eq. 46)
      B       (C, Nb, *coeff)     (Psi_hat - Psi) R_{t_i} (Eq. 22 mean)
      P_chol  (C, Nb, *coeff)     chol of injected covariance (Eq. 23)
      n_steps (C,) int32          true N_c per config
      stochastic (C,) bool        lam > 0 (selects the Eq. 22 update)
      corrector  (C,) bool        Eq. 45 corrector enabled
    """
    t_cur: jnp.ndarray
    t_nxt: jnp.ndarray
    psi: jnp.ndarray
    pC: jnp.ndarray
    cC: jnp.ndarray
    B: jnp.ndarray
    P_chol: jnp.ndarray
    n_steps: jnp.ndarray
    stochastic: jnp.ndarray
    corrector: jnp.ndarray

    @property
    def shape_key(self) -> Tuple[int, int, int]:
        """(Cb, Nb, Qb) — two banks with equal shape_key share one compiled
        step program."""
        return (self.psi.shape[0], self.psi.shape[1], self.pC.shape[2])


# ---------------------------------------------------------------------------
# Canonical factored coefficients: one bank for EVERY SDE family.
# ---------------------------------------------------------------------------
DIAG_BUCKET_MIN = 1   # diag-pool rows (same power-of-two doubling as C/N/q)


def factor_coeff(ops, coeff, data_shape: Tuple[int, ...],
                 k_max: int) -> Tuple[np.ndarray, Optional[np.ndarray]]:
    """Exact factored form of a family coefficient: a (k_max, k_max) block
    factor and an optional (D,) diagonal factor whose outer product is the
    dense canonical embedding, dense[i, j, d] = blk[i, j] * diag[d]:

      scalar   c        ->  c e00       x  None  (the all-ones diagonal)
      block    M (k,k)  ->  M (padded)  x  None
      freqdiag d        ->  e00         x  d broadcast over data_shape
                            (elementwise in the DCT basis the BDM state
                            is resident in; an all-zero d collapses to
                            the zero block x None)

    Exactly one side of the product is always trivial (the all-ones
    diagonal, or the 1-at-[0,0] block), so applying the two factors in
    sequence — block contraction, then elementwise diagonal — is *bitwise*
    equal to the dense (k_max, k_max, D) einsum the pre-factored bank used
    (multiplying by 1.0 is exact), at k_max^2 + D floats instead of
    k_max^2 * D.  `None` means the shared all-ones pool row (slot 0 of
    `FactoredBank.diag`).
    """
    blk = np.zeros((k_max, k_max), np.float64)
    coeff = np.asarray(coeff, np.float64)
    if ops.family == "scalar":
        blk[0, 0] = float(coeff)
        return blk, None
    if ops.family == "block":
        k = coeff.shape[-1]
        blk[:k, :k] = coeff
        return blk, None
    if ops.family == "freqdiag":
        if not np.any(coeff):
            return blk, None                   # zero block annihilates
        blk[0, 0] = 1.0
        diag = np.broadcast_to(coeff, data_shape).reshape(-1)
        return blk, np.ascontiguousarray(diag, np.float64)
    raise ValueError(f"unknown coeff family {ops.family!r}")


class FactoredBank(NamedTuple):
    """Multi-family coefficient bank in the exact *factored* form: every
    structured coefficient (VPSDE scalar / CLD 2x2 block / BDM
    freq-diagonal) is a (K, K) block factor times a (D,) diagonal factor
    (`factor_coeff`), applied as two contractions
    (`kernels/ei_update.apply_factored`) instead of one dense
    (K, K, D) einsum.  This replaces the PR-4 dense `PackedBank`, which
    tiled scalar/block coefficients D-fold — hundreds of MB device-resident
    at CIFAR scale and a full host-side float64 restack per first-seen
    config; the dense layout survives only as the differential-test oracle
    (tests/dense_reference.py).

    Block factors are stored per coefficient row; diagonal factors live in
    a *deduplicated pool* indexed by small int32 leaves — scalar and block
    coefficients all share pool row 0 (the all-ones diagonal), so only
    freq-diagonal (BDM) rows occupy real pool slots and the bank costs
    O(K^2) per row + O(D) per *distinct* diagonal, a ~D-fold cut
    (`nbytes` vs `dense_equiv_nbytes`, gated by tools/perf_guard.py).

      t_cur/t_nxt  (C, Nb)             model-eval / corrector-eval times
      psi_blk      (C, Nb, K, K)       transition Psi(t_{i-1}, t_i)
      pC_blk       (C, Nb, Qb, K, K)   predictor coeffs (Eq. 41)
      cC_blk       (C, Nb, Qb, K, K)   corrector coeffs (Eq. 46)
      B_blk        (C, Nb, K, K)       (Psi_hat - Psi) R_{t_i} (Eq. 22)
      P_chol_blk   (C, Nb, K, K)       chol of injected covariance (Eq. 23)
      *_di         int32, shaped like the matching *_blk leaf minus the
                                       (K, K) dims — diag-pool row ids
      diag         (Pb, D)             the deduplicated diagonal pool;
                                       row 0 is all-ones, padding rows are
                                       never indexed
      n_steps      (C,) int32          true N_c per config
      stochastic   (C,) bool           lam > 0 (selects the Eq. 22 update)
      corrector    (C,) bool           Eq. 45 corrector enabled
      fam          (C,) int32          family index of each config row
      alg          (C,) int32          algorithm id of each config row
                                       (index into `ALGORITHMS`; selects
                                       the per-slot noise law and keys
                                       the per-step PRNG stream)

    Deterministic configs (lam = 0) store *zero* B/P_chol factors: the
    Eq. 22 branch is masked off for them in the serve step, so the zero
    rows are observationally exact and keep their freq-diagonal values out
    of the pool.  Zero-coefficient padding (k >= N_c, j >= q_c) is a zero
    block factor indexing pool row 0, so padded terms annihilate exactly
    as they did densely.
    """
    t_cur: jnp.ndarray
    t_nxt: jnp.ndarray
    psi_blk: jnp.ndarray
    psi_di: jnp.ndarray
    pC_blk: jnp.ndarray
    pC_di: jnp.ndarray
    cC_blk: jnp.ndarray
    cC_di: jnp.ndarray
    B_blk: jnp.ndarray
    B_di: jnp.ndarray
    P_chol_blk: jnp.ndarray
    P_chol_di: jnp.ndarray
    diag: jnp.ndarray
    n_steps: jnp.ndarray
    stochastic: jnp.ndarray
    corrector: jnp.ndarray
    fam: jnp.ndarray
    alg: jnp.ndarray

    @property
    def shape_key(self) -> Tuple[int, int, int, int, int, int]:
        """(Cb, Nb, Qb, K, D, Pb) — banks with equal shape_key share
        compiled step programs.  Pb is the diag-pool bucket: scalar/block
        configs never grow it, a first-seen freq-diagonal config may
        (one recompile per overflow, like the other buckets — warm the
        config menu up front via `ServeLoop._prepare`)."""
        return (self.psi_blk.shape[0], self.psi_blk.shape[1],
                self.pC_blk.shape[2], self.psi_blk.shape[2],
                self.diag.shape[1], self.diag.shape[0])

    @property
    def nbytes(self) -> int:
        """Device-resident bytes of the whole bank (every leaf)."""
        return int(sum(leaf.nbytes for leaf in self))

    @property
    def dense_equiv_nbytes(self) -> int:
        """Bytes the PR-4 dense (C, Nb[, Qb], K, K, D) layout would occupy
        for the same bucketed bank — the denominator of the bank-residency
        win tracked in BENCH_serving.json."""
        Cb, Nb, Qb, K, D, _ = self.shape_key
        coeff = Cb * Nb * (3 + 2 * Qb) * K * K * D * 4
        meta = (self.t_cur.nbytes + self.t_nxt.nbytes + self.n_steps.nbytes
                + self.stochastic.nbytes + self.corrector.nbytes
                + self.fam.nbytes + self.alg.nbytes)
        return coeff + meta

    def materialize(self, kind: str, c: int, k: int,
                    j: Optional[int] = None) -> np.ndarray:
        """Dense (K, K, D) embedding of one coefficient row (host-side;
        tests/introspection only — the serve step never densifies)."""
        blk = getattr(self, kind + "_blk")
        di = getattr(self, kind + "_di")
        idx = (c, k) if j is None else (c, k, j)
        row = int(np.asarray(di[idx]))
        return (np.asarray(blk[idx])[..., None]
                * np.asarray(self.diag[row])[None, None, :])


class CoeffCache:
    """Host-side Stage-I coefficient cache keyed by
    (sde family, grid kind, NFE, q, corrector, lambda).

    `get(cfg)` memoizes `build_sampler_coeffs` per key (a hit returns the
    identical `SamplerCoeffs` object; the corrector toggle is excluded from
    this key because Stage I always computes both predictor and corrector
    rows).  `index_of(cfg)` additionally assigns
    the config a stable slot in the stacked `bank`, which pads every entry
    to shared bucketed shapes so one compiled serve step handles any mix of
    cached configs — heterogeneous NFE/q/corrector/lambda traffic in one
    batch (repro.serve.DiffusionEngine).

    Multi-family mode: construct with a mapping of `family_name -> LinearSDE`
    (and optionally per-family `kt`) and a shared `data_shape`, and the
    cache stacks configs from *different SDE families* into one
    `factored_bank` — every coefficient in the exact factored form of
    `factor_coeff` (a (K, K) block factor times a pooled (D,) diagonal
    factor), with `bank.fam` recording each config row's family.  The
    family-native `bank` stays available in single-family mode (the
    historical surface).

    Growth model: slots are never evicted (stability of `index_of` is what
    lets in-flight requests keep their index), and registration is
    *incremental* — per-config factored rows are memoized
    (`_factor_rows`), so a first-seen config appends its rows into the
    padded host mirror instead of re-stacking the whole bank, and only a
    bucket overflow re-pads every row.  `bank_restack_rows` counts the
    config-rows (re)written since construction (a deterministic counter
    the perf guard gates).  A front end that lets clients pick *arbitrary*
    floats for lam / any NFE should still quantize them to a menu first:
    every distinct value permanently widens the bank and each config-
    bucket overflow recompiles the step.
    """

    def __init__(self, sdes: Union[LinearSDE, Mapping[str, LinearSDE]],
                 kt: Union[str, Mapping[str, str]] = "R",
                 quad_points: int = 48, rk_substeps: int = 32,
                 data_shape: Optional[Tuple[int, ...]] = None):
        if isinstance(sdes, LinearSDE):
            sdes = {family_name(sdes): sdes}
        self.sdes: Dict[str, LinearSDE] = dict(sdes)
        if not self.sdes:
            raise ValueError("CoeffCache needs at least one SDE family")
        if not isinstance(kt, str):
            kt = dict(kt)
            missing = set(self.sdes) - set(kt)
            if missing:
                raise ValueError(f"kt mapping missing families {sorted(missing)}")
        self.kt = kt
        self.data_shape = None if data_shape is None else tuple(data_shape)
        self.quad_points = quad_points
        self.rk_substeps = rk_substeps
        self._coeffs: Dict[tuple, SamplerCoeffs] = {}
        self._configs: List[SamplerConfig] = []
        self._slots: Dict[tuple, int] = {}
        self._bank: CoeffBank | None = None
        # factored-bank state: memoized per-config rows, the deduplicated
        # diag pool (row 0 = all-ones), padded host mirrors written
        # incrementally, and the deterministic restack counter
        self._row_memo: Dict[tuple, dict] = {}
        self._pool: List[np.ndarray] = []
        self._pool_ids: Dict[bytes, int] = {}
        self._fa_host: Dict[str, np.ndarray] | None = None
        self._fa_built = 0
        self._fa_pool_built = 0
        self._fa_pool_cap = 0
        self._factored: FactoredBank | None = None
        self.bank_restack_rows = 0

    # ---- family plumbing ----------------------------------------------------
    @property
    def families(self) -> List[str]:
        """Resident family names, in registration order (index = the
        engine-visible family id, `FactoredBank.fam`)."""
        return list(self.sdes)

    @property
    def default_family(self) -> str:
        return next(iter(self.sdes))

    @property
    def sde(self) -> LinearSDE:
        """Single-family convenience accessor (the historical surface)."""
        return next(iter(self.sdes.values()))

    @property
    def k_max(self) -> int:
        """Canonical packed channel width over the resident families."""
        return max(s.packed_k for s in self.sdes.values())

    def fam_index(self, name: str) -> int:
        return self.families.index(name)

    def resolve(self, cfg: SamplerConfig) -> str:
        """Concrete family name of `cfg` (validates against the residents)."""
        name = cfg.family if cfg.family is not None else self.default_family
        if name not in self.sdes:
            raise ValueError(f"unknown SDE family {name!r}; resident "
                             f"families: {self.families}")
        return name

    def sde_of(self, cfg: SamplerConfig) -> LinearSDE:
        return self.sdes[self.resolve(cfg)]

    def _kt_of(self, name: str) -> str:
        return self.kt if isinstance(self.kt, str) else self.kt[name]

    # ---- Stage-I memoization ------------------------------------------------
    def key_of(self, cfg: SamplerConfig) -> tuple:
        """Full config key (the bank-slot identity)."""
        return (self.resolve(cfg), cfg.grid, cfg.nfe, cfg.q,
                cfg.corrector, cfg.lam, cfg.algorithm)

    def _coeff_key(self, cfg: SamplerConfig) -> tuple:
        """Stage-I memo key: `build_sampler_coeffs` always computes both
        predictor and corrector rows (and the accel first moment pM), so
        the corrector and algorithm toggles share one coefficient
        computation — the algorithm axis is a *transform* of the shared
        Stage-I result (`algorithm_coeff_stacks`), not a new quadrature."""
        return (self.resolve(cfg), cfg.grid, cfg.nfe, cfg.q, cfg.lam)

    def __len__(self) -> int:
        return len(self._configs)

    @property
    def configs(self) -> List[SamplerConfig]:
        return list(self._configs)

    def get(self, cfg: SamplerConfig) -> SamplerCoeffs:
        """Stage-I coefficients for `cfg`; computed once per key."""
        key = self._coeff_key(cfg)
        if key not in self._coeffs:
            name = self.resolve(cfg)
            sde = self.sdes[name]
            ts = time_grid(sde, cfg.nfe, cfg.grid)
            self._coeffs[key] = build_sampler_coeffs(
                sde, ts, q=cfg.q, lam=cfg.lam, kt=self._kt_of(name),
                quad_points=self.quad_points, rk_substeps=self.rk_substeps)
        return self._coeffs[key]

    def index_of(self, cfg: SamplerConfig) -> int:
        """Config slot of `cfg` in the bank (registers the config if new).
        Configs that differ only in an unresolved-vs-explicit default
        family share one slot (the key stores the resolved name)."""
        key = self.key_of(cfg)
        if key not in self._slots:
            self.get(cfg)                       # build coefficients eagerly
            self._slots[key] = len(self._configs)
            self._configs.append(cfg)
            self._bank = None                   # native bank is stale; the
                                                # factored bank appends
                                                # (see `factored_bank`)
        return self._slots[key]

    # ---- stacked banks ------------------------------------------------------
    @property
    def bank(self) -> CoeffBank:
        if len(self.sdes) > 1:
            raise ValueError(
                "CoeffCache.bank is single-family (family-native coeff "
                "shapes); a multi-family cache stacks into `factored_bank`")
        if self._bank is None:
            self._bank = self._build_bank()
        return self._bank

    def _bucket_shapes(self) -> Tuple[int, int, int]:
        if not self._configs:
            raise ValueError("CoeffCache bank: no configs registered "
                             "(call index_of first)")
        Cb = bucket_size(len(self._configs), C_BUCKET_MIN)
        Nb = bucket_size(max(c.nfe for c in self._configs), N_BUCKET_MIN)
        Qb = bucket_size(max(effective_q(c) for c in self._configs),
                         Q_BUCKET_MIN)
        return Cb, Nb, Qb

    def _bank_rows(self):
        """Per-config (slot, cfg, coeffs) in registration order."""
        for c, cfg in enumerate(self._configs):
            yield c, cfg, self.get(cfg)

    def _build_bank(self) -> CoeffBank:
        for cfg in self._configs:
            if cfg.algorithm != "gddim":
                raise ValueError(
                    "the family-native CoeffBank predates the algorithm "
                    "axis ('gmm' needs the per-slot noise transform only "
                    "the factored-bank step implements); use "
                    "`factored_bank` for algorithm= configs")
        coeff_shape = np.shape(np.asarray(self.sde.ops.eye()))
        Cb, Nb, Qb = self._bucket_shapes()

        t_cur = np.zeros((Cb, Nb), np.float64)
        t_nxt = np.zeros((Cb, Nb), np.float64)
        psi = np.zeros((Cb, Nb) + coeff_shape, np.float64)
        pC = np.zeros((Cb, Nb, Qb) + coeff_shape, np.float64)
        cC = np.zeros((Cb, Nb, Qb) + coeff_shape, np.float64)
        B = np.zeros((Cb, Nb) + coeff_shape, np.float64)
        P_chol = np.zeros((Cb, Nb) + coeff_shape, np.float64)
        n_steps = np.ones((Cb,), np.int32)
        stoch = np.zeros((Cb,), bool)
        corr = np.zeros((Cb,), bool)

        for c, cfg, co in self._bank_rows():
            N, q = cfg.nfe, cfg.q
            ts = np.asarray(co.ts)
            # step k advances i = N - k -> i - 1
            t_cur[c, :N] = ts[N - np.arange(N)]
            t_cur[c, N:] = ts[1]
            t_nxt[c, :N] = ts[N - 1 - np.arange(N)]
            t_nxt[c, N:] = ts[0]
            psi[c, :N] = np.asarray(co.psi)
            pC[c, :N, :q] = np.asarray(co.pC)
            cC[c, :N, :q] = np.asarray(co.cC)
            B[c, :N] = np.asarray(co.B)
            P_chol[c, :N] = np.asarray(co.P_chol)
            n_steps[c] = N
            stoch[c] = cfg.lam > 0.0
            corr[c] = cfg.corrector

        f32 = lambda x: jnp.asarray(x, jnp.float32)
        return CoeffBank(
            t_cur=f32(t_cur), t_nxt=f32(t_nxt), psi=f32(psi), pC=f32(pC),
            cC=f32(cC), B=f32(B), P_chol=f32(P_chol),
            n_steps=jnp.asarray(n_steps),
            stochastic=jnp.asarray(stoch), corrector=jnp.asarray(corr))

    # ---- factored multi-family bank -----------------------------------------
    def _diag_slot(self, diag: Optional[np.ndarray]) -> int:
        """Pool slot of a diagonal factor (None -> the shared all-ones row
        0; real rows are deduplicated by float32 value, never evicted)."""
        if not self._pool:
            ones = np.ones((int(np.prod(self.data_shape)),), np.float32)
            self._pool.append(ones)
            self._pool_ids[ones.tobytes()] = 0
        if diag is None:
            return 0
        row = np.ascontiguousarray(diag, np.float32)
        key = row.tobytes()
        slot = self._pool_ids.get(key)
        if slot is None:
            slot = len(self._pool)
            self._pool.append(row)
            self._pool_ids[key] = slot
        return slot

    def _factor_rows(self, cfg: SamplerConfig) -> dict:
        """Memoized per-config factored rows (float32 block factors + pool
        ids).  Factoring — and its pool registration — runs once per bank
        slot; re-pads after a bucket overflow reuse these rows verbatim."""
        key = self.key_of(cfg)
        got = self._row_memo.get(key)
        if got is not None:
            return got
        co = self.get(cfg)
        name = self.resolve(cfg)
        ops = self.sdes[name].ops
        coeff_shape = np.shape(np.asarray(ops.eye()))
        K, N, q = self.k_max, cfg.nfe, effective_q(cfg)
        pC_alg, cC_alg, P_alg = algorithm_coeff_stacks(co, cfg, coeff_shape)

        def rows(stack, n_lead):
            """Factor a stacked f64 coeff array into (blk f32, di i32)."""
            blk = np.zeros(n_lead + (K, K), np.float32)
            di = np.zeros(n_lead, np.int32)
            for idx in np.ndindex(*n_lead):
                b, d = factor_coeff(ops, stack[idx], self.data_shape, K)
                blk[idx] = b
                di[idx] = self._diag_slot(d)
            return blk, di

        psi_blk, psi_di = rows(np.asarray(co.psi, np.float64), (N,))
        pC_blk, pC_di = rows(pC_alg, (N, q))
        cC_blk, cC_di = rows(cC_alg, (N, q))
        if cfg.lam > 0.0:
            B_blk, B_di = rows(np.asarray(co.B, np.float64), (N,))
            P_blk, P_di = rows(P_alg, (N,))
        else:
            # Eq. 22 branch is masked off for deterministic configs: zero
            # factors are observationally exact and keep freq-diagonal
            # B/P values out of the pool (see FactoredBank docstring)
            B_blk = np.zeros((N, K, K), np.float32)
            B_di = np.zeros((N,), np.int32)
            P_blk, P_di = B_blk, B_di
        ts = np.asarray(co.ts)
        row = dict(
            t_cur=ts[N - np.arange(N)], t_nxt=ts[N - 1 - np.arange(N)],
            psi_blk=psi_blk, psi_di=psi_di, pC_blk=pC_blk, pC_di=pC_di,
            cC_blk=cC_blk, cC_di=cC_di, B_blk=B_blk, B_di=B_di,
            P_chol_blk=P_blk, P_chol_di=P_di)
        self._row_memo[key] = row
        return row

    def _alloc_factored_host(self, Cb: int, Nb: int, Qb: int
                             ) -> Dict[str, np.ndarray]:
        K = self.k_max
        return dict(
            t_cur=np.zeros((Cb, Nb), np.float32),
            t_nxt=np.zeros((Cb, Nb), np.float32),
            psi_blk=np.zeros((Cb, Nb, K, K), np.float32),
            psi_di=np.zeros((Cb, Nb), np.int32),
            pC_blk=np.zeros((Cb, Nb, Qb, K, K), np.float32),
            pC_di=np.zeros((Cb, Nb, Qb), np.int32),
            cC_blk=np.zeros((Cb, Nb, Qb, K, K), np.float32),
            cC_di=np.zeros((Cb, Nb, Qb), np.int32),
            B_blk=np.zeros((Cb, Nb, K, K), np.float32),
            B_di=np.zeros((Cb, Nb), np.int32),
            P_chol_blk=np.zeros((Cb, Nb, K, K), np.float32),
            P_chol_di=np.zeros((Cb, Nb), np.int32),
            n_steps=np.ones((Cb,), np.int32),
            stochastic=np.zeros((Cb,), bool),
            corrector=np.zeros((Cb,), bool),
            fam=np.zeros((Cb,), np.int32),
            alg=np.zeros((Cb,), np.int32))

    def _write_factored_row(self, H: Dict[str, np.ndarray], c: int,
                            cfg: SamplerConfig, row: dict) -> None:
        # q from the memoized row itself: the accel transform widens the
        # stored rows to effective_q(cfg) slots while cfg.q stays 1
        N, q = cfg.nfe, row["pC_blk"].shape[1]
        H["t_cur"][c, :N] = row["t_cur"]
        H["t_cur"][c, N:] = row["t_cur"][-1]
        H["t_nxt"][c, :N] = row["t_nxt"]
        H["t_nxt"][c, N:] = row["t_nxt"][-1]
        for name in ("psi", "B", "P_chol"):
            H[name + "_blk"][c, :N] = row[name + "_blk"]
            H[name + "_di"][c, :N] = row[name + "_di"]
        for name in ("pC", "cC"):
            H[name + "_blk"][c, :N, :q] = row[name + "_blk"]
            H[name + "_di"][c, :N, :q] = row[name + "_di"]
        H["n_steps"][c] = N
        H["stochastic"][c] = cfg.lam > 0.0
        H["corrector"][c] = cfg.corrector
        H["fam"][c] = self.fam_index(self.resolve(cfg))
        H["alg"][c] = ALGORITHMS.index(cfg.algorithm)

    @property
    def factored_bank(self) -> FactoredBank:
        """The canonical multi-family bank (requires `data_shape`).
        Incremental: first-seen configs append rows into the padded host
        mirror; only a bucket overflow (C/N/q, or the diag pool) re-pads
        every row.  Returns the identical object while nothing changed,
        so the engine's placement check (`bank is placed_src`) is cheap."""
        if self.data_shape is None:
            raise ValueError("CoeffCache.factored_bank needs data_shape= "
                             "(the shared per-sample data shape)")
        Cb, Nb, Qb = self._bucket_shapes()
        rows = [self._factor_rows(cfg) for cfg in self._configs]
        Pb = bucket_size(len(self._pool), DIAG_BUCKET_MIN)

        H = self._fa_host
        if H is None or H["psi_blk"].shape[:2] != (Cb, Nb) \
                or H["pC_blk"].shape[2] != Qb:
            H = self._fa_host = self._alloc_factored_host(Cb, Nb, Qb)
            self._fa_built = 0
        for c in range(self._fa_built, len(rows)):
            self._write_factored_row(H, c, self._configs[c], rows[c])
            self.bank_restack_rows += 1
        appended = len(rows) - self._fa_built
        self._fa_built = len(rows)

        pool_stale = (self._fa_pool_built != len(self._pool)
                      or self._fa_pool_cap != Pb)
        if not appended and not pool_stale and self._factored is not None:
            return self._factored
        pool = np.zeros((Pb, int(np.prod(self.data_shape))), np.float32)
        for i, r in enumerate(self._pool):
            pool[i] = r
        self._fa_pool_built, self._fa_pool_cap = len(self._pool), Pb

        f32 = lambda x: jnp.asarray(x, jnp.float32)
        i32 = lambda x: jnp.asarray(x, jnp.int32)
        self._factored = FactoredBank(
            t_cur=f32(H["t_cur"]), t_nxt=f32(H["t_nxt"]),
            psi_blk=f32(H["psi_blk"]), psi_di=i32(H["psi_di"]),
            pC_blk=f32(H["pC_blk"]), pC_di=i32(H["pC_di"]),
            cC_blk=f32(H["cC_blk"]), cC_di=i32(H["cC_di"]),
            B_blk=f32(H["B_blk"]), B_di=i32(H["B_di"]),
            P_chol_blk=f32(H["P_chol_blk"]), P_chol_di=i32(H["P_chol_di"]),
            diag=f32(pool), n_steps=i32(H["n_steps"]),
            stochastic=jnp.asarray(H["stochastic"]),
            corrector=jnp.asarray(H["corrector"]), fam=i32(H["fam"]),
            alg=i32(H["alg"]))
        return self._factored


def ddim_closed_form_check(sde, ts) -> np.ndarray:
    """Closed-form deterministic-DDIM eps coefficient on VPSDE (paper Eq. 12):
    sqrt(1-a_{t-1}) - sqrt(1-a_t) sqrt(a_{t-1}/a_t) — used by tests to verify
    the quadrature path reproduces DDIM exactly (Prop 2)."""
    out = []
    N = len(ts) - 1
    for k in range(N):
        i = N - k
        t, s = float(ts[i]), float(ts[i - 1])
        a_t, a_s = sde.alpha(t), sde.alpha(s)
        out.append(np.sqrt(1 - a_s) - np.sqrt(1 - a_t) * np.sqrt(a_s / a_t))
    return np.asarray(out)

"""Stage I — offline computation of every gDDIM sampler coefficient.

Mirrors the paper's App. C.3 pipeline exactly:

  Step 1  pick the (decreasing) sampling grid {t_i}, i = 0..N, t_0 = t_min,
          t_N = T.
  Step 2  transition matrices Psi(t_{i-1}, t_i)            (closed form/expm)
  Step 3  R_t via Eq. 17                                   (from the SDE)
  Step 4  EI multistep predictor/corrector constants pC/cC (Eqs. 41/46,
          composite-Simpson quadrature), and for stochastic gDDIM the
          lambda-family transition Psi_hat (Eq. 81) and injected covariance
          P_st (Eq. 23) via RK4 per step.

All math is family-generic: coefficients are numpy arrays whose shape is the
SDE family's coeff shape (scalar () / CLD (2,2) / BDM freq-grid), manipulated
through `sde.ops`.  The result is a `SamplerCoeffs` pytree of *stacked* jnp
arrays consumed by the lax.scan samplers in repro.core.gddim (Stage II).

Warm-start handling: at step i the usable history is q_cur = min(q, N-i+1)
points (Alg. 1); we bake this in by computing the *lower-order* Lagrange
coefficients for the first steps and zero-padding to q slots, so the device
loop is branch-free.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple, Sequence

import numpy as np
import jax.numpy as jnp

from ..sde.base import LinearSDE
from ..sde import solve


class SamplerCoeffs(NamedTuple):
    """Stacked per-step coefficients (device arrays).  Axis 0: step k = 0..N-1,
    where step k advances t_i -> t_{i-1} with i = N - k."""
    ts: jnp.ndarray            # (N+1,) the grid, ts[0]=t_min .. ts[N]=T (increasing)
    psi: jnp.ndarray           # (N, *coeff)  Psi(t_{i-1}, t_i)
    pC: jnp.ndarray            # (N, q, *coeff)  predictor coeffs, slot j ~ eps(t_{i+j})
    cC: jnp.ndarray            # (N, q, *coeff)  corrector coeffs, slot 0 ~ eps(t_{i-1}),
                               #                  slot j>=1 ~ eps(t_{i+j-1})
    psi_hat: jnp.ndarray       # (N, *coeff)  lambda-family transition Psi_hat(t_{i-1}, t_i)
    B: jnp.ndarray             # (N, *coeff)  (Psi_hat - Psi) R_{t_i}   (Eq. 22 mean)
    P_chol: jnp.ndarray        # (N, *coeff)  chol of injected covariance P (Eq. 23)
    R: jnp.ndarray             # (N+1, *coeff) R_{t_i} on the grid
    R_invT: jnp.ndarray        # (N+1, *coeff) R_{t_i}^{-T} (score <-> eps conversion)
    Sigma: jnp.ndarray         # (N+1, *coeff)
    lam: float = 0.0


def time_grid(sde: LinearSDE, n_steps: int, kind: str = "quadratic") -> np.ndarray:
    """Sampling grid t_min..T (increasing).  'quadratic' concentrates steps
    near t_min like the DDIM/EDM conventions; 'uniform' is linear."""
    x = np.linspace(0.0, 1.0, n_steps + 1)
    if kind == "quadratic":
        x = x**2
    elif kind != "uniform":
        raise ValueError(kind)
    return sde.t_min + (sde.T - sde.t_min) * x


def _K_fn(sde: LinearSDE, kt: str) -> Callable[[float], np.ndarray]:
    """The paper's K_t choices: 'R' (gDDIM), 'L' (Cholesky), 'sqrt' (sym-sqrt)."""
    if kt == "R":
        return sde.R_np
    if kt == "L":
        return sde.L_np
    if kt == "sqrt":
        return lambda t: sde.ops.sqrt_psd(sde.Sigma_np(t))
    raise ValueError(kt)


def build_sampler_coeffs(
    sde: LinearSDE,
    ts: Sequence[float],
    q: int = 2,
    lam: float = 0.0,
    kt: str = "R",
    quad_points: int = 48,
    rk_substeps: int = 32,
) -> SamplerCoeffs:
    """Compute all Stage-I constants for grid `ts` (increasing, len N+1)."""
    ops = sde.ops
    ts = np.asarray(ts, np.float64)
    N = len(ts) - 1
    K = _K_fn(sde, kt)

    def KinvT(tau: float) -> np.ndarray:
        # K^{-T} = Sigma^{-1} K exactly (K K^T = Sigma), which keeps the
        # interpolation error of the gridded R_t *linear* instead of
        # amplified through an explicit inverse near the stiff origin.
        return ops.mul(ops.inv(sde.Sigma_np(tau)), K(tau))

    # integrand core 1/2 Psi(t_e, tau) G2(tau) K(tau)^{-T}
    def ei_core(t_end: float, tau: float) -> np.ndarray:
        return 0.5 * ops.mul(ops.mul(sde.Psi_np(t_end, tau), sde.G2_np(tau)), KinvT(tau))

    coeff_shape = np.shape(np.asarray(ops.eye()))
    psi, pC, cC = [], [], []
    psi_hat, B, P_chol = [], [], []

    # generator of the lambda-family SDE (Eq. 51): F_hat = F + (1+lam^2)/2 G2 Sigma^{-1}
    def F_hat(tau: float) -> np.ndarray:
        return sde.F_np(tau) + 0.5 * (1.0 + lam * lam) * ops.mul(
            sde.G2_np(tau), ops.inv(sde.Sigma_np(tau)))

    for k in range(N):
        i = N - k                      # step from t_i down to t_{i-1}
        t_i, t_im1 = float(ts[i]), float(ts[i - 1])
        psi.append(np.asarray(sde.Psi_np(t_im1, t_i), np.float64))

        # ---- predictor coefficients (Eq. 41), history nodes t_i..t_{i+q_cur-1}
        q_cur = min(q, N - i + 1)
        nodes_p = [float(ts[min(i + j, N)]) for j in range(q_cur)]
        row_p = np.zeros((q,) + coeff_shape)
        for j in range(q_cur):
            ell = solve.lagrange_basis(nodes_p, j)
            row_p[j] = solve.quad_coeff(
                lambda tau: ei_core(t_im1, tau) * ell(tau), t_i, t_im1, quad_points)
        pC.append(row_p)

        # ---- corrector coefficients (Eq. 46), nodes t_{i-1}, t_i, .., t_{i+q_cur-2}
        q_corr = min(q, N - i + 2)
        nodes_c = [t_im1] + [float(ts[min(i + j, N)]) for j in range(q_corr - 1)]
        row_c = np.zeros((q,) + coeff_shape)
        for j in range(q_corr):
            ell = solve.lagrange_basis(nodes_c, j)
            row_c[j] = solve.quad_coeff(
                lambda tau: ei_core(t_im1, tau) * ell(tau), t_i, t_im1, quad_points)
        cC.append(row_c)

        # ---- stochastic pieces: Psi_hat (Eq. 81) and P (Eq. 23) over [t_i, t_im1]
        def psi_hat_rhs(tau, Y):
            return ops.mul(F_hat(tau), Y)

        ph = solve.integrate_ode(psi_hat_rhs, ops.eye() + 0.0, t_i, t_im1, rk_substeps)
        psi_hat.append(np.asarray(ph, np.float64))
        B.append(ops.mul(ph - psi[-1], np.asarray(K(t_i), np.float64)))

        if lam > 0.0:
            # Eq. 23 in the reverse-time parameterization sigma = s - tau
            # (the sampler runs backward; variance grows moving away from s):
            #   dP/dsigma = -(F_hat P + P F_hat^T) + lam^2 G2,  P(0) = 0.
            G2c = lam * lam

            def p_rhs(sig, P):
                tau = t_i - sig
                fh = F_hat(tau)
                return -(ops.mul(fh, P) + ops.mul(P, ops.transpose(fh))) \
                    + G2c * sde.G2_np(tau)

            P = solve.integrate_ode(p_rhs, ops.zeros() + 0.0, 0.0, t_i - t_im1,
                                    rk_substeps)
            # integrating backward in time leaves tiny asymmetry/negativity
            if ops.family == "block":
                P = 0.5 * (P + ops.transpose(P))
                P = P + 1e-14 * np.trace(P) * np.eye(P.shape[-1])
            else:
                P = np.maximum(P, 0.0)
            P_chol.append(ops.chol(P))
        else:
            P_chol.append(np.zeros(coeff_shape))

    R_stack = np.stack([np.asarray(K(float(t)), np.float64) for t in ts])
    RinvT_stack = np.stack([np.asarray(KinvT(float(t)), np.float64) for t in ts])
    Sig_stack = np.stack([np.asarray(sde.Sigma_np(float(t)), np.float64) for t in ts])

    f32 = lambda x: jnp.asarray(np.asarray(x), jnp.float32)
    return SamplerCoeffs(
        ts=f32(ts),
        psi=f32(np.stack(psi)),
        pC=f32(np.stack(pC)),
        cC=f32(np.stack(cC)),
        psi_hat=f32(np.stack(psi_hat)),
        B=f32(np.stack(B)),
        P_chol=f32(np.stack(P_chol)),
        R=f32(R_stack),
        R_invT=f32(RinvT_stack),
        Sigma=f32(Sig_stack),
        lam=float(lam),
    )


def ddim_closed_form_check(sde, ts) -> np.ndarray:
    """Closed-form deterministic-DDIM eps coefficient on VPSDE (paper Eq. 12):
    sqrt(1-a_{t-1}) - sqrt(1-a_t) sqrt(a_{t-1}/a_t) — used by tests to verify
    the quadrature path reproduces DDIM exactly (Prop 2)."""
    out = []
    N = len(ts) - 1
    for k in range(N):
        i = N - k
        t, s = float(ts[i]), float(ts[i - 1])
        a_t, a_s = sde.alpha(t), sde.alpha(s)
        out.append(np.sqrt(1 - a_s) - np.sqrt(1 - a_t) * np.sqrt(a_s / a_t))
    return np.asarray(out)

"""Stage I — offline computation of every gDDIM sampler coefficient.

Mirrors the paper's App. C.3 pipeline exactly:

  Step 1  pick the (decreasing) sampling grid {t_i}, i = 0..N, t_0 = t_min,
          t_N = T.
  Step 2  transition matrices Psi(t_{i-1}, t_i)            (closed form/expm)
  Step 3  R_t via Eq. 17                                   (from the SDE)
  Step 4  EI multistep predictor/corrector constants pC/cC (Eqs. 41/46,
          composite-Simpson quadrature), and for stochastic gDDIM the
          lambda-family transition Psi_hat (Eq. 81) and injected covariance
          P_st (Eq. 23) via RK4 per step.

All math is family-generic: coefficients are numpy arrays whose shape is the
SDE family's coeff shape (scalar () / CLD (2,2) / BDM freq-grid), manipulated
through `sde.ops`.  The result is a `SamplerCoeffs` pytree of *stacked* jnp
arrays consumed by the lax.scan samplers in repro.core.gddim (Stage II).

Warm-start handling: at step i the usable history is q_cur = min(q, N-i+1)
points (Alg. 1); we bake this in by computing the *lower-order* Lagrange
coefficients for the first steps and zero-padding to q slots, so the device
loop is branch-free.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Mapping, NamedTuple, Optional, \
    Sequence, Tuple, Union

import numpy as np
import jax.numpy as jnp

from ..sde.base import LinearSDE, family_name
from ..sde import solve


class SamplerCoeffs(NamedTuple):
    """Stacked per-step coefficients (device arrays).  Axis 0: step k = 0..N-1,
    where step k advances t_i -> t_{i-1} with i = N - k."""
    ts: jnp.ndarray            # (N+1,) the grid, ts[0]=t_min .. ts[N]=T (increasing)
    psi: jnp.ndarray           # (N, *coeff)  Psi(t_{i-1}, t_i)
    pC: jnp.ndarray            # (N, q, *coeff)  predictor coeffs, slot j ~ eps(t_{i+j})
    cC: jnp.ndarray            # (N, q, *coeff)  corrector coeffs, slot 0 ~ eps(t_{i-1}),
                               #                  slot j>=1 ~ eps(t_{i+j-1})
    psi_hat: jnp.ndarray       # (N, *coeff)  lambda-family transition Psi_hat(t_{i-1}, t_i)
    B: jnp.ndarray             # (N, *coeff)  (Psi_hat - Psi) R_{t_i}   (Eq. 22 mean)
    P_chol: jnp.ndarray        # (N, *coeff)  chol of injected covariance P (Eq. 23)
    R: jnp.ndarray             # (N+1, *coeff) R_{t_i} on the grid
    R_invT: jnp.ndarray        # (N+1, *coeff) R_{t_i}^{-T} (score <-> eps conversion)
    Sigma: jnp.ndarray         # (N+1, *coeff)
    lam: float = 0.0


def time_grid(sde: LinearSDE, n_steps: int, kind: str = "quadratic") -> np.ndarray:
    """Sampling grid t_min..T (increasing).  'quadratic' concentrates steps
    near t_min like the DDIM/EDM conventions; 'uniform' is linear."""
    x = np.linspace(0.0, 1.0, n_steps + 1)
    if kind == "quadratic":
        x = x**2
    elif kind != "uniform":
        raise ValueError(kind)
    return sde.t_min + (sde.T - sde.t_min) * x


def _K_fn(sde: LinearSDE, kt: str) -> Callable[[float], np.ndarray]:
    """The paper's K_t choices: 'R' (gDDIM), 'L' (Cholesky), 'sqrt' (sym-sqrt)."""
    if kt == "R":
        return sde.R_np
    if kt == "L":
        return sde.L_np
    if kt == "sqrt":
        return lambda t: sde.ops.sqrt_psd(sde.Sigma_np(t))
    raise ValueError(kt)


def build_sampler_coeffs(
    sde: LinearSDE,
    ts: Sequence[float],
    q: int = 2,
    lam: float = 0.0,
    kt: str = "R",
    quad_points: int = 48,
    rk_substeps: int = 32,
) -> SamplerCoeffs:
    """Compute all Stage-I constants for grid `ts` (increasing, len N+1)."""
    ops = sde.ops
    ts = np.asarray(ts, np.float64)
    N = len(ts) - 1
    K = _K_fn(sde, kt)

    def KinvT(tau: float) -> np.ndarray:
        # K^{-T} = Sigma^{-1} K exactly (K K^T = Sigma), which keeps the
        # interpolation error of the gridded R_t *linear* instead of
        # amplified through an explicit inverse near the stiff origin.
        return ops.mul(ops.inv(sde.Sigma_np(tau)), K(tau))

    # integrand core 1/2 Psi(t_e, tau) G2(tau) K(tau)^{-T}
    def ei_core(t_end: float, tau: float) -> np.ndarray:
        return 0.5 * ops.mul(ops.mul(sde.Psi_np(t_end, tau), sde.G2_np(tau)), KinvT(tau))

    coeff_shape = np.shape(np.asarray(ops.eye()))
    psi, pC, cC = [], [], []
    psi_hat, B, P_chol = [], [], []

    # generator of the lambda-family SDE (Eq. 51): F_hat = F + (1+lam^2)/2 G2 Sigma^{-1}
    def F_hat(tau: float) -> np.ndarray:
        return sde.F_np(tau) + 0.5 * (1.0 + lam * lam) * ops.mul(
            sde.G2_np(tau), ops.inv(sde.Sigma_np(tau)))

    for k in range(N):
        i = N - k                      # step from t_i down to t_{i-1}
        t_i, t_im1 = float(ts[i]), float(ts[i - 1])
        psi.append(np.asarray(sde.Psi_np(t_im1, t_i), np.float64))

        # ---- predictor coefficients (Eq. 41), history nodes t_i..t_{i+q_cur-1}
        q_cur = min(q, N - i + 1)
        nodes_p = [float(ts[min(i + j, N)]) for j in range(q_cur)]
        row_p = np.zeros((q,) + coeff_shape)
        for j in range(q_cur):
            ell = solve.lagrange_basis(nodes_p, j)
            row_p[j] = solve.quad_coeff(
                lambda tau: ei_core(t_im1, tau) * ell(tau), t_i, t_im1, quad_points)
        pC.append(row_p)

        # ---- corrector coefficients (Eq. 46), nodes t_{i-1}, t_i, .., t_{i+q_cur-2}
        q_corr = min(q, N - i + 2)
        nodes_c = [t_im1] + [float(ts[min(i + j, N)]) for j in range(q_corr - 1)]
        row_c = np.zeros((q,) + coeff_shape)
        for j in range(q_corr):
            ell = solve.lagrange_basis(nodes_c, j)
            row_c[j] = solve.quad_coeff(
                lambda tau: ei_core(t_im1, tau) * ell(tau), t_i, t_im1, quad_points)
        cC.append(row_c)

        # ---- stochastic pieces: Psi_hat (Eq. 81) and P (Eq. 23) over [t_i, t_im1]
        def psi_hat_rhs(tau, Y):
            return ops.mul(F_hat(tau), Y)

        ph = solve.integrate_ode(psi_hat_rhs, ops.eye() + 0.0, t_i, t_im1, rk_substeps)
        psi_hat.append(np.asarray(ph, np.float64))
        B.append(ops.mul(ph - psi[-1], np.asarray(K(t_i), np.float64)))

        if lam > 0.0:
            # Eq. 23 in the reverse-time parameterization sigma = s - tau
            # (the sampler runs backward; variance grows moving away from s):
            #   dP/dsigma = -(F_hat P + P F_hat^T) + lam^2 G2,  P(0) = 0.
            G2c = lam * lam

            def p_rhs(sig, P):
                tau = t_i - sig
                fh = F_hat(tau)
                return -(ops.mul(fh, P) + ops.mul(P, ops.transpose(fh))) \
                    + G2c * sde.G2_np(tau)

            P = solve.integrate_ode(p_rhs, ops.zeros() + 0.0, 0.0, t_i - t_im1,
                                    rk_substeps)
            # integrating backward in time leaves tiny asymmetry/negativity
            if ops.family == "block":
                P = 0.5 * (P + ops.transpose(P))
                P = P + 1e-14 * np.trace(P) * np.eye(P.shape[-1])
            else:
                P = np.maximum(P, 0.0)
            P_chol.append(ops.chol(P))
        else:
            P_chol.append(np.zeros(coeff_shape))

    R_stack = np.stack([np.asarray(K(float(t)), np.float64) for t in ts])
    RinvT_stack = np.stack([np.asarray(KinvT(float(t)), np.float64) for t in ts])
    Sig_stack = np.stack([np.asarray(sde.Sigma_np(float(t)), np.float64) for t in ts])

    f32 = lambda x: jnp.asarray(np.asarray(x), jnp.float32)
    return SamplerCoeffs(
        ts=f32(ts),
        psi=f32(np.stack(psi)),
        pC=f32(np.stack(pC)),
        cC=f32(np.stack(cC)),
        psi_hat=f32(np.stack(psi_hat)),
        B=f32(np.stack(B)),
        P_chol=f32(np.stack(P_chol)),
        R=f32(R_stack),
        R_invT=f32(RinvT_stack),
        Sigma=f32(Sig_stack),
        lam=float(lam),
    )


# ---------------------------------------------------------------------------
# Sampler-config cache: many sampler families, one compiled step.
# ---------------------------------------------------------------------------
# Bucket minima for the stacked bank.  A bank whose (configs, steps, order)
# all fit inside the warmed bucket reuses the compiled step program verbatim:
# the bank is an *argument* of the jitted step, so only a bucket overflow
# (which doubles the padded axis) changes shapes and triggers one new
# compilation.
C_BUCKET_MIN = 4      # config slots
N_BUCKET_MIN = 8      # sampler steps (NFE)
Q_BUCKET_MIN = 2      # multistep order


def bucket_size(n: int, minimum: int) -> int:
    """Smallest power-of-two multiple of `minimum` that holds `n`."""
    b = minimum
    while b < n:
        b *= 2
    return b


@dataclasses.dataclass(frozen=True)
class SamplerConfig:
    """One point in gDDIM's sampler family (the per-request surface).

    nfe        number of grid steps N (= model evaluations for the
               predictor; the corrector adds N-1 more — see
               `sample_gddim`'s NFE accounting)
    q          exponential-multistep order (Eq. 19/41); stochastic
               sampling is single-step, so q must be 1 when lam > 0
    corrector  run the Eq. 45 corrector after every predictor step but
               the last (Alg. 1)
    lam        stochasticity level lambda of Eq. 22 (0 = deterministic)
    grid       time-grid kind ('quadratic' | 'uniform', see `time_grid`)
    family     SDE family to sample from ('vpsde' | 'cld' | 'bdm', the
               `repro.sde.base.family_name` keys of the engine's resident
               families).  None means "the engine/cache default family";
               the name itself is validated where families are known
               (`CoeffCache.resolve`)
    """
    nfe: int
    q: int = 1
    corrector: bool = False
    lam: float = 0.0
    grid: str = "quadratic"
    family: Optional[str] = None

    def __post_init__(self):
        if self.nfe < 1:
            raise ValueError(f"nfe must be >= 1, got {self.nfe}")
        if self.q < 1:
            raise ValueError(f"q must be >= 1, got {self.q}")
        if self.lam < 0.0:
            raise ValueError(f"lam must be >= 0, got {self.lam}")
        if self.lam > 0.0 and (self.q != 1 or self.corrector):
            raise ValueError(
                "stochastic gDDIM (lam > 0, Eq. 22) is single-step: "
                "q must be 1 and corrector off")
        if self.grid not in ("quadratic", "uniform"):
            raise ValueError(f"unknown grid kind {self.grid!r}")


class CoeffBank(NamedTuple):
    """Stacked, bucket-padded Stage-I coefficients for >= 1 sampler configs.

    Axis 0 is the config slot c, axis 1 the step index k (a step advances
    t_i -> t_{i-1} with i = N_c - k).  Real data occupies [:C, :N_c(, :q_c)]
    of each leaf; the padding is zeros (coefficients) or edge values (times)
    and is never read because the serve step clips k to n_steps[c] - 1 and
    zero coefficient rows annihilate their term.

      t_cur   (C, Nb)             t_i   — model-eval time at step k
      t_nxt   (C, Nb)             t_{i-1} — corrector-eval time at step k
      psi     (C, Nb, *coeff)     transition Psi(t_{i-1}, t_i)
      pC      (C, Nb, Qb, *coeff) predictor coeffs (Eq. 41)
      cC      (C, Nb, Qb, *coeff) corrector coeffs (Eq. 46)
      B       (C, Nb, *coeff)     (Psi_hat - Psi) R_{t_i} (Eq. 22 mean)
      P_chol  (C, Nb, *coeff)     chol of injected covariance (Eq. 23)
      n_steps (C,) int32          true N_c per config
      stochastic (C,) bool        lam > 0 (selects the Eq. 22 update)
      corrector  (C,) bool        Eq. 45 corrector enabled
    """
    t_cur: jnp.ndarray
    t_nxt: jnp.ndarray
    psi: jnp.ndarray
    pC: jnp.ndarray
    cC: jnp.ndarray
    B: jnp.ndarray
    P_chol: jnp.ndarray
    n_steps: jnp.ndarray
    stochastic: jnp.ndarray
    corrector: jnp.ndarray

    @property
    def shape_key(self) -> Tuple[int, int, int]:
        """(Cb, Nb, Qb) — two banks with equal shape_key share one compiled
        step program."""
        return (self.psi.shape[0], self.psi.shape[1], self.pC.shape[2])


# ---------------------------------------------------------------------------
# Canonical packed coefficients: one bank for EVERY SDE family.
# ---------------------------------------------------------------------------
def pack_coeff(ops, coeff, data_shape: Tuple[int, ...],
               k_max: int) -> np.ndarray:
    """Embed a family coefficient into the dense canonical (k_max, k_max, D)
    form that acts on the packed (B, k, D) slot state
    (`repro.kernels.ei_update.ops.apply_packed`):

      scalar   c        ->  c at [0, 0, :]            (c * u, k = 1)
      block    M (k,k)  ->  M broadcast over D        (M ⊗ I_D, k rows)
      freqdiag d        ->  diag over D at [0, 0, :]  (elementwise in the
                            DCT basis the BDM state is resident in)

    Entries outside the family's own k x k block are zero; the padded state
    rows they would act on are identically zero too, so the embedding is
    exact (same arithmetic as the family-native `sde.apply`).
    """
    D = int(np.prod(data_shape))
    out = np.zeros((k_max, k_max, D), np.float64)
    coeff = np.asarray(coeff, np.float64)
    if ops.family == "scalar":
        out[0, 0, :] = float(coeff)
    elif ops.family == "block":
        k = coeff.shape[-1]
        out[:k, :k, :] = coeff[..., None]
    elif ops.family == "freqdiag":
        out[0, 0, :] = np.broadcast_to(coeff, data_shape).reshape(-1)
    else:
        raise ValueError(f"unknown coeff family {ops.family!r}")
    return out


class PackedBank(NamedTuple):
    """Multi-family `CoeffBank`: same per-config rows, but every coefficient
    is embedded into the canonical packed form (`pack_coeff`), so one bank
    stacks VPSDE, CLD and BDM configs side by side and the serve step's
    linear algebra is family-agnostic (`apply_packed` on (B, k, D) states).

    The embedding is deliberately *dense* over D: scalar and block
    coefficients are tiled D-fold, which keeps the step a single einsum and
    every family bit-exact, at K*K*D floats per coefficient row.  That adds
    up: at full CIFAR scale (D=3072, K=2) with large warmed buckets (Cb=8,
    Nb=64, Qb=4) the bank is hundreds of MB device-resident, and each
    first-seen config registration rebuilds it host-side in float64
    (`_build_packed_bank`) on the admission path — acceptable for a
    curated config menu registered up front (`ServeLoop._prepare`), not
    for unbounded config churn.  The exact factored form — a (K, K) block
    factor times a (D,) diagonal factor, applied as two contractions, cut
    ~D-fold in size — is the known follow-up if bank residency, restack
    stalls, or gather bandwidth show up in profiles (ROADMAP).

      t_cur/t_nxt (C, Nb)                 as in `CoeffBank`
      psi/B/P_chol(C, Nb, K, K, D)        K = k_max over resident families
      pC/cC       (C, Nb, Qb, K, K, D)
      n_steps     (C,) int32
      stochastic  (C,) bool
      corrector   (C,) bool
      fam         (C,) int32              family index of each config row
                                          (the engine's per-slot `state.fam`
                                          gathers this at admission)
    """
    t_cur: jnp.ndarray
    t_nxt: jnp.ndarray
    psi: jnp.ndarray
    pC: jnp.ndarray
    cC: jnp.ndarray
    B: jnp.ndarray
    P_chol: jnp.ndarray
    n_steps: jnp.ndarray
    stochastic: jnp.ndarray
    corrector: jnp.ndarray
    fam: jnp.ndarray

    @property
    def shape_key(self) -> Tuple[int, int, int, int, int]:
        """(Cb, Nb, Qb, K, D) — banks with equal shape_key share compiled
        step programs."""
        return (self.psi.shape[0], self.psi.shape[1], self.pC.shape[2],
                self.psi.shape[2], self.psi.shape[4])


class CoeffCache:
    """Host-side Stage-I coefficient cache keyed by
    (sde family, grid kind, NFE, q, corrector, lambda).

    `get(cfg)` memoizes `build_sampler_coeffs` per key (a hit returns the
    identical `SamplerCoeffs` object; the corrector toggle is excluded from
    this key because Stage I always computes both predictor and corrector
    rows).  `index_of(cfg)` additionally assigns
    the config a stable slot in the stacked `bank`, which pads every entry
    to shared bucketed shapes so one compiled serve step handles any mix of
    cached configs — heterogeneous NFE/q/corrector/lambda traffic in one
    batch (repro.serve.DiffusionEngine).

    Multi-family mode: construct with a mapping of `family_name -> LinearSDE`
    (and optionally per-family `kt`) and a shared `data_shape`, and the
    cache stacks configs from *different SDE families* into one
    `packed_bank` — every coefficient embedded into the canonical
    (k_max, k_max, D) form of `pack_coeff`, with `bank.fam` recording each
    config row's family.  The family-native `bank` stays available in
    single-family mode (the historical surface).

    Growth model, deliberately simple: slots are never evicted (stability
    of `index_of` is what lets in-flight requests keep their index), and
    registering a new config re-stacks the whole bank host-side.  That is
    the right trade for a deployment serving a curated menu of configs
    (tens, not thousands); a front end that lets clients pick *arbitrary*
    floats for lam / any NFE should quantize them to a menu first, or
    every distinct value permanently widens the bank and each config-
    bucket overflow recompiles the step.
    """

    def __init__(self, sdes: Union[LinearSDE, Mapping[str, LinearSDE]],
                 kt: Union[str, Mapping[str, str]] = "R",
                 quad_points: int = 48, rk_substeps: int = 32,
                 data_shape: Optional[Tuple[int, ...]] = None):
        if isinstance(sdes, LinearSDE):
            sdes = {family_name(sdes): sdes}
        self.sdes: Dict[str, LinearSDE] = dict(sdes)
        if not self.sdes:
            raise ValueError("CoeffCache needs at least one SDE family")
        if not isinstance(kt, str):
            kt = dict(kt)
            missing = set(self.sdes) - set(kt)
            if missing:
                raise ValueError(f"kt mapping missing families {sorted(missing)}")
        self.kt = kt
        self.data_shape = None if data_shape is None else tuple(data_shape)
        self.quad_points = quad_points
        self.rk_substeps = rk_substeps
        self._coeffs: Dict[tuple, SamplerCoeffs] = {}
        self._configs: List[SamplerConfig] = []
        self._slots: Dict[tuple, int] = {}
        self._bank: CoeffBank | None = None
        self._packed: PackedBank | None = None

    # ---- family plumbing ----------------------------------------------------
    @property
    def families(self) -> List[str]:
        """Resident family names, in registration order (index = the
        engine-visible family id, `PackedBank.fam`)."""
        return list(self.sdes)

    @property
    def default_family(self) -> str:
        return next(iter(self.sdes))

    @property
    def sde(self) -> LinearSDE:
        """Single-family convenience accessor (the historical surface)."""
        return next(iter(self.sdes.values()))

    @property
    def k_max(self) -> int:
        """Canonical packed channel width over the resident families."""
        return max(s.packed_k for s in self.sdes.values())

    def fam_index(self, name: str) -> int:
        return self.families.index(name)

    def resolve(self, cfg: SamplerConfig) -> str:
        """Concrete family name of `cfg` (validates against the residents)."""
        name = cfg.family if cfg.family is not None else self.default_family
        if name not in self.sdes:
            raise ValueError(f"unknown SDE family {name!r}; resident "
                             f"families: {self.families}")
        return name

    def sde_of(self, cfg: SamplerConfig) -> LinearSDE:
        return self.sdes[self.resolve(cfg)]

    def _kt_of(self, name: str) -> str:
        return self.kt if isinstance(self.kt, str) else self.kt[name]

    # ---- Stage-I memoization ------------------------------------------------
    def key_of(self, cfg: SamplerConfig) -> tuple:
        """Full config key (the bank-slot identity)."""
        return (self.resolve(cfg), cfg.grid, cfg.nfe, cfg.q,
                cfg.corrector, cfg.lam)

    def _coeff_key(self, cfg: SamplerConfig) -> tuple:
        """Stage-I memo key: `build_sampler_coeffs` always computes both
        predictor and corrector rows, so the corrector toggle shares one
        coefficient computation."""
        return (self.resolve(cfg), cfg.grid, cfg.nfe, cfg.q, cfg.lam)

    def __len__(self) -> int:
        return len(self._configs)

    @property
    def configs(self) -> List[SamplerConfig]:
        return list(self._configs)

    def get(self, cfg: SamplerConfig) -> SamplerCoeffs:
        """Stage-I coefficients for `cfg`; computed once per key."""
        key = self._coeff_key(cfg)
        if key not in self._coeffs:
            name = self.resolve(cfg)
            sde = self.sdes[name]
            ts = time_grid(sde, cfg.nfe, cfg.grid)
            self._coeffs[key] = build_sampler_coeffs(
                sde, ts, q=cfg.q, lam=cfg.lam, kt=self._kt_of(name),
                quad_points=self.quad_points, rk_substeps=self.rk_substeps)
        return self._coeffs[key]

    def index_of(self, cfg: SamplerConfig) -> int:
        """Config slot of `cfg` in the bank (registers the config if new).
        Configs that differ only in an unresolved-vs-explicit default
        family share one slot (the key stores the resolved name)."""
        key = self.key_of(cfg)
        if key not in self._slots:
            self.get(cfg)                       # build coefficients eagerly
            self._slots[key] = len(self._configs)
            self._configs.append(cfg)
            self._bank = None                   # banks are stale
            self._packed = None
        return self._slots[key]

    # ---- stacked banks ------------------------------------------------------
    @property
    def bank(self) -> CoeffBank:
        if len(self.sdes) > 1:
            raise ValueError(
                "CoeffCache.bank is single-family (family-native coeff "
                "shapes); a multi-family cache stacks into `packed_bank`")
        if self._bank is None:
            self._bank = self._build_bank()
        return self._bank

    @property
    def packed_bank(self) -> PackedBank:
        """The canonical multi-family bank (requires `data_shape`)."""
        if self._packed is None:
            self._packed = self._build_packed_bank()
        return self._packed

    def _bucket_shapes(self) -> Tuple[int, int, int]:
        if not self._configs:
            raise ValueError("CoeffCache bank: no configs registered "
                             "(call index_of first)")
        Cb = bucket_size(len(self._configs), C_BUCKET_MIN)
        Nb = bucket_size(max(c.nfe for c in self._configs), N_BUCKET_MIN)
        Qb = bucket_size(max(c.q for c in self._configs), Q_BUCKET_MIN)
        return Cb, Nb, Qb

    def _bank_rows(self):
        """Per-config (slot, cfg, coeffs) in registration order."""
        for c, cfg in enumerate(self._configs):
            yield c, cfg, self.get(cfg)

    def _build_bank(self) -> CoeffBank:
        coeff_shape = np.shape(np.asarray(self.sde.ops.eye()))
        Cb, Nb, Qb = self._bucket_shapes()

        t_cur = np.zeros((Cb, Nb), np.float64)
        t_nxt = np.zeros((Cb, Nb), np.float64)
        psi = np.zeros((Cb, Nb) + coeff_shape, np.float64)
        pC = np.zeros((Cb, Nb, Qb) + coeff_shape, np.float64)
        cC = np.zeros((Cb, Nb, Qb) + coeff_shape, np.float64)
        B = np.zeros((Cb, Nb) + coeff_shape, np.float64)
        P_chol = np.zeros((Cb, Nb) + coeff_shape, np.float64)
        n_steps = np.ones((Cb,), np.int32)
        stoch = np.zeros((Cb,), bool)
        corr = np.zeros((Cb,), bool)

        for c, cfg, co in self._bank_rows():
            N, q = cfg.nfe, cfg.q
            ts = np.asarray(co.ts)
            # step k advances i = N - k -> i - 1
            t_cur[c, :N] = ts[N - np.arange(N)]
            t_cur[c, N:] = ts[1]
            t_nxt[c, :N] = ts[N - 1 - np.arange(N)]
            t_nxt[c, N:] = ts[0]
            psi[c, :N] = np.asarray(co.psi)
            pC[c, :N, :q] = np.asarray(co.pC)
            cC[c, :N, :q] = np.asarray(co.cC)
            B[c, :N] = np.asarray(co.B)
            P_chol[c, :N] = np.asarray(co.P_chol)
            n_steps[c] = N
            stoch[c] = cfg.lam > 0.0
            corr[c] = cfg.corrector

        f32 = lambda x: jnp.asarray(x, jnp.float32)
        return CoeffBank(
            t_cur=f32(t_cur), t_nxt=f32(t_nxt), psi=f32(psi), pC=f32(pC),
            cC=f32(cC), B=f32(B), P_chol=f32(P_chol),
            n_steps=jnp.asarray(n_steps),
            stochastic=jnp.asarray(stoch), corrector=jnp.asarray(corr))

    def _build_packed_bank(self) -> PackedBank:
        if self.data_shape is None:
            raise ValueError("CoeffCache.packed_bank needs data_shape= "
                             "(the shared per-sample data shape)")
        Cb, Nb, Qb = self._bucket_shapes()
        K = self.k_max
        D = int(np.prod(self.data_shape))
        kk = (K, K, D)

        t_cur = np.zeros((Cb, Nb), np.float64)
        t_nxt = np.zeros((Cb, Nb), np.float64)
        psi = np.zeros((Cb, Nb) + kk, np.float64)
        pC = np.zeros((Cb, Nb, Qb) + kk, np.float64)
        cC = np.zeros((Cb, Nb, Qb) + kk, np.float64)
        B = np.zeros((Cb, Nb) + kk, np.float64)
        P_chol = np.zeros((Cb, Nb) + kk, np.float64)
        n_steps = np.ones((Cb,), np.int32)
        stoch = np.zeros((Cb,), bool)
        corr = np.zeros((Cb,), bool)
        fam = np.zeros((Cb,), np.int32)

        for c, cfg, co in self._bank_rows():
            name = self.resolve(cfg)
            ops = self.sdes[name].ops
            pk = lambda x: pack_coeff(ops, x, self.data_shape, K)
            N, q = cfg.nfe, cfg.q
            ts = np.asarray(co.ts)
            t_cur[c, :N] = ts[N - np.arange(N)]
            t_cur[c, N:] = ts[1]
            t_nxt[c, :N] = ts[N - 1 - np.arange(N)]
            t_nxt[c, N:] = ts[0]
            for k in range(N):
                psi[c, k] = pk(np.asarray(co.psi)[k])
                B[c, k] = pk(np.asarray(co.B)[k])
                P_chol[c, k] = pk(np.asarray(co.P_chol)[k])
                for j in range(q):
                    pC[c, k, j] = pk(np.asarray(co.pC)[k, j])
                    cC[c, k, j] = pk(np.asarray(co.cC)[k, j])
            n_steps[c] = N
            stoch[c] = cfg.lam > 0.0
            corr[c] = cfg.corrector
            fam[c] = self.fam_index(name)

        f32 = lambda x: jnp.asarray(x, jnp.float32)
        return PackedBank(
            t_cur=f32(t_cur), t_nxt=f32(t_nxt), psi=f32(psi), pC=f32(pC),
            cC=f32(cC), B=f32(B), P_chol=f32(P_chol),
            n_steps=jnp.asarray(n_steps),
            stochastic=jnp.asarray(stoch), corrector=jnp.asarray(corr),
            fam=jnp.asarray(fam))


def ddim_closed_form_check(sde, ts) -> np.ndarray:
    """Closed-form deterministic-DDIM eps coefficient on VPSDE (paper Eq. 12):
    sqrt(1-a_{t-1}) - sqrt(1-a_t) sqrt(a_{t-1}/a_t) — used by tests to verify
    the quadrature path reproduces DDIM exactly (Prop 2)."""
    out = []
    N = len(ts) - 1
    for k in range(N):
        i = N - k
        t, s = float(ts[i]), float(ts[i - 1])
        a_t, a_s = sde.alpha(t), sde.alpha(s)
        out.append(np.sqrt(1 - a_s) - np.sqrt(1 - a_t) * np.sqrt(a_s / a_t))
    return np.asarray(out)

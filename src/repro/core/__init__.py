"""gDDIM core: Stage-I coefficient pipeline + Stage-II samplers."""
from .coeffs import SamplerCoeffs, build_sampler_coeffs, time_grid, ddim_closed_form_check
from .gddim import (sample_gddim, sample_gddim_stochastic, sample_em,
                    sample_heun, sample_ancestral_bdm, sample_rk45_np)

__all__ = [
    "SamplerCoeffs", "build_sampler_coeffs", "time_grid", "ddim_closed_form_check",
    "sample_gddim", "sample_gddim_stochastic", "sample_em", "sample_heun",
    "sample_ancestral_bdm", "sample_rk45_np",
]

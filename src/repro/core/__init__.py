"""gDDIM core: Stage-I coefficient pipeline + Stage-II samplers."""
from .coeffs import (SamplerCoeffs, SamplerConfig, CoeffBank, CoeffCache,
                     FactoredBank, factor_coeff,
                     build_sampler_coeffs, bucket_size, time_grid,
                     ddim_closed_form_check,
                     ALGORITHMS, ALG_GDDIM, ALG_GMM, ALG_ACCEL,
                     GMM_RHO, GMM_SCALE, GMM_C, GMM_SALT,
                     effective_q, algorithm_coeff_stacks)
from .gddim import (sample_gddim, sample_gddim_stochastic, sample_em,
                    sample_heun, sample_ancestral_bdm, sample_rk45_np)

__all__ = [
    "SamplerCoeffs", "SamplerConfig", "CoeffBank", "CoeffCache",
    "FactoredBank", "factor_coeff",
    "build_sampler_coeffs", "bucket_size", "time_grid", "ddim_closed_form_check",
    "ALGORITHMS", "ALG_GDDIM", "ALG_GMM", "ALG_ACCEL",
    "GMM_RHO", "GMM_SCALE", "GMM_C", "GMM_SALT",
    "effective_q", "algorithm_coeff_stacks",
    "sample_gddim", "sample_gddim_stochastic", "sample_em", "sample_heun",
    "sample_ancestral_bdm", "sample_rk45_np",
]

"""gDDIM core: Stage-I coefficient pipeline + Stage-II samplers."""
from .coeffs import (SamplerCoeffs, SamplerConfig, CoeffBank, CoeffCache,
                     FactoredBank, factor_coeff,
                     build_sampler_coeffs, bucket_size, time_grid,
                     ddim_closed_form_check)
from .gddim import (sample_gddim, sample_gddim_stochastic, sample_em,
                    sample_heun, sample_ancestral_bdm, sample_rk45_np)

__all__ = [
    "SamplerCoeffs", "SamplerConfig", "CoeffBank", "CoeffCache",
    "FactoredBank", "factor_coeff",
    "build_sampler_coeffs", "bucket_size", "time_grid", "ddim_closed_form_check",
    "sample_gddim", "sample_gddim_stochastic", "sample_em", "sample_heun",
    "sample_ancestral_bdm", "sample_rk45_np",
]

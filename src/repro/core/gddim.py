"""Stage II — device-side gDDIM samplers (paper Sec. 4, Alg. 1).

All samplers share the same contract:

    eps_fn(u, i) -> epsilon prediction at grid index i (i in 0..N, ts[i])

where `eps_fn` is either the exact-score oracle (repro.sde.mixture) or a
neural score network wrapper (repro.train.wrappers).  The step loop is a
`lax.scan` over stacked Stage-I coefficients, so one compilation serves any
grid length and the whole sampler fuses into a single XLA program (on TPU the
per-step state update additionally dispatches to the fused Pallas `ei_update`
kernel — see repro.kernels.ei_update).

Implemented:
  * deterministic gDDIM, q-step exponential multistep predictor (Eq. 19)
  * optional q-step corrector (Eq. 45; PC = predictor-corrector, Alg. 1)
  * stochastic gDDIM for any lambda (Eq. 22, covariance Eq. 23)
  * baselines: Euler--Maruyama on the lambda-SDE (Eq. 6), probability-flow
    Euler & Heun (2nd order, Karras-style), BDM ancestral sampling
    (Hoogeboom & Salimans), and host-side RK45 probability flow.
"""
from __future__ import annotations

from typing import Callable

import numpy as np
import jax
import jax.numpy as jnp

from ..sde.base import LinearSDE
from .coeffs import SamplerCoeffs

Array = jax.Array
EpsFn = Callable[[Array, Array], Array]


def _apply(sde: LinearSDE, coeff: Array, u: Array) -> Array:
    return sde.apply(coeff, u)


# ---------------------------------------------------------------------------
# Deterministic gDDIM: exponential multistep predictor(-corrector)
# ---------------------------------------------------------------------------
def sample_gddim(
    sde: LinearSDE,
    coeffs: SamplerCoeffs,
    eps_fn: EpsFn,
    u_T: Array,
    q: int,
    corrector: bool = False,
) -> Array:
    """Run the full sampling loop from u(T) to u(t_min).

    NFE = N for predictor-only, 2N - 1 for predictor-corrector (the final
    corrector re-evaluation at t_0 is skipped, matching Alg. 1 / Tab. 8).
    """
    N = coeffs.psi.shape[0]
    hist0 = jnp.zeros((q,) + u_T.shape, u_T.dtype)

    def step(carry, k):
        u, hist = carry
        i = N - k
        eps_i = eps_fn(u, i)
        hist = jnp.concatenate([eps_i[None], hist[:-1]], axis=0)
        # predictor (Eq. 19a): u_pred = Psi u + sum_j pC[k,j] eps(t_{i+j})
        u_pred = _apply(sde, coeffs.psi[k], u)
        for j in range(q):
            u_pred = u_pred + _apply(sde, coeffs.pC[k, j], hist[j])
        if corrector:
            eps_im1 = eps_fn(u_pred, i - 1)
            u_corr = _apply(sde, coeffs.psi[k], u)
            u_corr = u_corr + _apply(sde, coeffs.cC[k, 0], eps_im1)
            for j in range(1, q):
                u_corr = u_corr + _apply(sde, coeffs.cC[k, j], hist[j - 1])
            # Alg. 1 runs the corrector after every predictor step except the
            # last (which would waste an NFE on t_0 output refinement).
            u_next = jnp.where(k == N - 1, u_pred, u_corr)
        else:
            u_next = u_pred
        return (u_next, hist), None

    (u, _), _ = jax.lax.scan(step, (u_T, hist0), jnp.arange(N))
    return u


# ---------------------------------------------------------------------------
# Stochastic gDDIM (Eq. 22)
# ---------------------------------------------------------------------------
def sample_gddim_stochastic(
    sde: LinearSDE,
    coeffs: SamplerCoeffs,
    eps_fn: EpsFn,
    u_T: Array,
    key: Array,
) -> Array:
    """u(t) ~ N(Psi u(s) + (Psi_hat - Psi) R_s eps_theta(u(s), s),  P_st)."""
    N = coeffs.psi.shape[0]

    def step(carry, k):
        u, key = carry
        i = N - k
        key, sub = jax.random.split(key)
        eps_i = eps_fn(u, i)
        mean = _apply(sde, coeffs.psi[k], u) + _apply(sde, coeffs.B[k], eps_i)
        noise = sde.noise_like(sub, u.shape, u.dtype)
        u_next = mean + _apply(sde, coeffs.P_chol[k], noise)
        return (u_next, key), None

    (u, _), _ = jax.lax.scan(step, (u_T, key), jnp.arange(N))
    return u


# ---------------------------------------------------------------------------
# Baseline: Euler--Maruyama on the lambda-family SDE (Eq. 6)
# ---------------------------------------------------------------------------
def sample_em(
    sde: LinearSDE,
    coeffs: SamplerCoeffs,
    eps_fn: EpsFn,
    u_T: Array,
    key: Array,
    lam: float,
) -> Array:
    """du = [F u - (1+lam^2)/2 G2 s_theta] dt + lam G dw, Euler discretized
    on the same grid (reverse time; dt < 0)."""
    N = coeffs.psi.shape[0]
    ts = coeffs.ts

    # family coeffs F(t_i), G2(t_i) stacked host-side
    F_stack = jnp.asarray(
        np.stack([np.asarray(sde.F_np(float(t)), np.float64) for t in np.asarray(ts)]),
        jnp.float32)
    G2_stack = jnp.asarray(
        np.stack([np.asarray(sde.G2_np(float(t)), np.float64) for t in np.asarray(ts)]),
        jnp.float32)

    def step(carry, k):
        u, key = carry
        i = N - k
        key, sub = jax.random.split(key)
        dt = ts[i - 1] - ts[i]                      # negative
        eps_i = eps_fn(u, i)
        score = -_apply(sde, coeffs.R_invT[i], eps_i)
        drift = _apply(sde, F_stack[i], u) - 0.5 * (1.0 + lam * lam) * _apply(
            sde, G2_stack[i], score)
        u_next = u + drift * dt
        if lam > 0.0:
            noise = sde.noise_like(sub, u.shape, u.dtype)
            # lam * G * sqrt(|dt|) * noise; G = sqrt(G2) family-wise
            g = jnp.sqrt(jnp.maximum(G2_stack[i], 0.0))
            u_next = u_next + lam * jnp.sqrt(-dt) * _apply(sde, g, noise)
        return (u_next, key), None

    (u, _), _ = jax.lax.scan(step, (u_T, key), jnp.arange(N))
    return u


# ---------------------------------------------------------------------------
# Baseline: probability-flow Euler / Heun (2nd order)
# ---------------------------------------------------------------------------
def sample_heun(
    sde: LinearSDE,
    coeffs: SamplerCoeffs,
    eps_fn: EpsFn,
    u_T: Array,
    second_order: bool = True,
) -> Array:
    """Explicit Euler / Heun on du/dt = F u - 1/2 G2 score (Eq. 7).

    NFE = N (Euler) or 2N - 1 (Heun; final step falls back to Euler)."""
    N = coeffs.psi.shape[0]
    ts = coeffs.ts
    F_stack = jnp.asarray(
        np.stack([np.asarray(sde.F_np(float(t)), np.float64) for t in np.asarray(ts)]),
        jnp.float32)
    G2_stack = jnp.asarray(
        np.stack([np.asarray(sde.G2_np(float(t)), np.float64) for t in np.asarray(ts)]),
        jnp.float32)

    def ode_rhs(u, i):
        score = -_apply(sde, coeffs.R_invT[i], eps_fn(u, i))
        return _apply(sde, F_stack[i], u) - 0.5 * _apply(sde, G2_stack[i], score)

    def step(u, k):
        i = N - k
        dt = ts[i - 1] - ts[i]
        d1 = ode_rhs(u, i)
        u_euler = u + dt * d1
        if second_order:
            d2 = ode_rhs(u_euler, i - 1)
            u_heun = u + dt * 0.5 * (d1 + d2)
            u = jnp.where(k == N - 1, u_euler, u_heun)
        else:
            u = u_euler
        return u, None

    u, _ = jax.lax.scan(step, u_T, jnp.arange(N))
    return u


# ---------------------------------------------------------------------------
# Baseline: BDM ancestral sampling (Hoogeboom & Salimans 2022)
# ---------------------------------------------------------------------------
def sample_ancestral_bdm(sde, eps_fn, u_T: Array, ts: np.ndarray, key: Array) -> Array:
    """Frequency-space DDPM-style ancestral sampler — the original (slow)
    BDM sampler the paper accelerates >20x (Tab. 3)."""
    coef_ut, coef_u0, a_t, sig_t, std = [jnp.asarray(c, jnp.float32)
                                         for c in sde.ancestral_coeffs(ts[::-1])]
    N = coef_ut.shape[0]
    ts_inc = np.asarray(ts)

    def step(carry, k):
        u, key = carry
        i = N - k  # grid index into increasing ts
        key, sub = jax.random.split(key)
        eps = eps_fn(u, i)
        y = sde.to_freq(u)
        ehat = sde.to_freq(eps)
        y0 = (y - sig_t[k] * ehat) / a_t[k]
        mean = coef_ut[k] * y + coef_u0[k] * y0
        noise = jax.random.normal(sub, u.shape, u.dtype)
        y_next = mean + std[k] * sde.to_freq(noise)
        return (sde.from_freq(y_next), key), None

    (u, _), _ = jax.lax.scan(step, (u_T, key), jnp.arange(N))
    return u


# ---------------------------------------------------------------------------
# Baseline: host-side adaptive RK45 on the probability flow (exact score)
# ---------------------------------------------------------------------------
def sample_rk45_np(sde, score_np, u_T: np.ndarray, rtol=1e-4, atol=1e-4):
    """scipy RK45 over the probability-flow ODE with a host score oracle.
    Returns (samples, nfe).  Used for the 'Prob.Flow, RK45' rows of Tab. 3."""
    import scipy.integrate

    shape = u_T.shape
    nfe = [0]

    def rhs(t, y):
        nfe[0] += 1
        u = y.reshape(shape)
        sc = score_np(u, float(t))
        F = sde.F_np(float(t))
        G2 = sde.G2_np(float(t))
        if sde.ops.family == "block":
            du = np.einsum("ij,bj...->bi...", F, u) - 0.5 * np.einsum(
                "ij,bj...->bi...", G2, sc)
        elif sde.ops.family == "scalar":
            du = F * u - 0.5 * G2 * sc
        else:  # freqdiag — host numpy DCT path lives on the oracle
            du = sde_apply_np_freq(sde, F, u) - 0.5 * sde_apply_np_freq(sde, G2, sc)
        return du.reshape(-1)

    sol = scipy.integrate.solve_ivp(
        rhs, (sde.T, sde.t_min), np.asarray(u_T, np.float64).reshape(-1),
        method="RK45", rtol=rtol, atol=atol)
    return sol.y[:, -1].reshape(shape), nfe[0]


def sde_apply_np_freq(sde, coeff, u):
    from ..sde.base import dct_matrix
    axes = tuple(a + 1 for a in sde.spatial_axes_in_data)
    y = np.asarray(u, np.float64)
    for ax in axes:
        c = dct_matrix(y.shape[ax])
        y = np.moveaxis(np.tensordot(c, np.moveaxis(y, ax, 0), axes=1), 0, ax)
    y = y * coeff
    for ax in axes:
        c = dct_matrix(y.shape[ax]).T
        y = np.moveaxis(np.tensordot(c, np.moveaxis(y, ax, 0), axes=1), 0, ax)
    return y

"""Probability-flow log-likelihood (paper App. C.8).

Integrating the instantaneous change-of-variables along Eq. (7):

    log p_0(u_0) = log p_T(u_T) + int_0^T div f(u_t, t) dt,
    f(u, t) = F_t u - 1/2 G_t G_t^T s_theta(u, t)

For low-dimensional states the divergence is exact via jacfwd (the toy
validation path — ground truth available from the mixture oracle); for
image-scale states `hutchinson=True` uses the Skilling-Hutchinson
Rademacher estimator.  For CLD this yields log p(x0, v0); the paper's
marginal bound log p(x0) >= E_v0[log p(x0, v0)] + H(p(v0)) is provided by
`cld_nll_bound`.
"""
from __future__ import annotations

from typing import Callable, Optional

import numpy as np
import jax
import jax.numpy as jnp

from ..sde.base import LinearSDE

Array = jax.Array


def _flow_rhs(sde: LinearSDE, score_fn: Callable, u: Array, t: float) -> Array:
    F = jnp.asarray(sde.F_np(float(t)), u.dtype)
    G2 = jnp.asarray(sde.G2_np(float(t)), u.dtype)
    return sde.apply(F, u) - 0.5 * sde.apply(G2, score_fn(u, float(t)))


def log_likelihood(
    sde: LinearSDE,
    score_fn: Callable[[Array, float], Array],
    u0: Array,
    n_steps: int = 200,
    hutchinson: bool = False,
    key: Optional[Array] = None,
) -> Array:
    """log p_0(u0) via Heun integration of the flow + divergence.

    score_fn(u, t) -> grad log p_t(u); `u0`: (B, *state).  Exact divergence
    (jacfwd per example) unless `hutchinson`.
    """
    B = u0.shape[0]
    state_shape = u0.shape[1:]
    D = int(np.prod(state_shape))
    ts = np.linspace(sde.t_min, sde.T, n_steps + 1)
    if hutchinson and key is None:
        key = jax.random.PRNGKey(0)  # staticcheck: disable=SC102 (deterministic Hutchinson probes when the caller passes key=None — an explicit, documented fallback)

    def div_f(u: Array, t: float, eps: Optional[Array]) -> Array:
        if not hutchinson:
            def f_single(x):
                return _flow_rhs(sde, score_fn, x[None], t)[0].reshape(-1)
            jac = jax.vmap(jax.jacfwd(lambda x: f_single(x.reshape(state_shape))))(
                u.reshape(B, -1))
            return jnp.trace(jac, axis1=-2, axis2=-1)
        # Skilling-Hutchinson: E_eps[eps^T J eps]
        def f_flat(x_flat):
            return _flow_rhs(sde, score_fn, x_flat.reshape((B,) + state_shape),
                             t).reshape(B, -1)
        _, jvp = jax.jvp(f_flat, (u.reshape(B, -1),), (eps,))
        return jnp.sum(jvp * eps, axis=-1)

    u = u0
    logdet = jnp.zeros((B,), jnp.float32)
    for i in range(n_steps):
        t0, t1 = float(ts[i]), float(ts[i + 1])
        dt = t1 - t0
        eps = None
        if hutchinson:
            key, sub = jax.random.split(key)
            eps = jax.random.rademacher(sub, (B, D), jnp.float32)
        k1 = _flow_rhs(sde, score_fn, u, t0)
        d1 = div_f(u, t0, eps)
        u_mid = u + dt * k1
        k2 = _flow_rhs(sde, score_fn, u_mid, t1)
        d2 = div_f(u_mid, t1, eps)
        u = u + 0.5 * dt * (k1 + k2)
        logdet = logdet + 0.5 * dt * (d1 + d2)

    # prior at T: N(0, Sigma_T) with the SDE's structured covariance
    sig = sde.Sigma_np(sde.T)
    ops = sde.ops
    sinv = ops.inv(sig)
    from ..sde.mixture import _quad_form, _logdet
    qf = _quad_form(sde, sinv, u)
    ld = _logdet(sde, sig, u.shape[1:] if sde.state_ndim_prefix == 0
                 else u.shape[2:])
    if sde.state_ndim_prefix == 1:
        ld = _logdet(sde, sig, u.shape[2:])
    logpT = -0.5 * qf - 0.5 * ld - 0.5 * D * np.log(2 * np.pi)
    return logpT + logdet


def cld_nll_bound(sde, score_fn, x0: Array, key, n_v: int = 4,
                  n_steps: int = 200) -> Array:
    """Paper App. C.8: log p(x0) >= E_{v0~N(0,gamma M)}[log p(x0,v0)] + H(p(v0))."""
    d = int(np.prod(x0.shape[1:]))
    v_var = sde.gamma / sde.M_inv
    ent = 0.5 * d * (1.0 + np.log(2 * np.pi * v_var))
    vals = []
    for i in range(n_v):
        key, sub = jax.random.split(key)
        v0 = jnp.sqrt(v_var) * jax.random.normal(sub, x0.shape, x0.dtype)
        u0 = jnp.stack([x0, v0], axis=1)
        vals.append(log_likelihood(sde, score_fn, u0, n_steps=n_steps))
    return jnp.mean(jnp.stack(vals), axis=0) + ent

"""Architecture & paper-config registry.

`get_arch(name, reduced=False)` returns the ArchSpec for any of the 10
assigned architectures (``--arch <id>``); `ARCH_IDS` lists them.  Paper
diffusion configs (CLD / BDM / DDPM on CIFAR-shaped data + toy mixtures)
live in `paper_*` modules and are returned by `get_diffusion(name)`.
"""
from __future__ import annotations

import importlib
from typing import Dict, List

ARCH_MODULES: Dict[str, str] = {
    "zamba2-2.7b": "zamba2_2p7b",
    "deepseek-coder-33b": "deepseek_coder_33b",
    "llama3-405b": "llama3_405b",
    "gemma3-1b": "gemma3_1b",
    "nemotron-4-15b": "nemotron_4_15b",
    "rwkv6-7b": "rwkv6_7b",
    "qwen3-moe-30b-a3b": "qwen3_moe_30b_a3b",
    "llama4-maverick-400b-a17b": "llama4_maverick_400b_a17b",
    "whisper-base": "whisper_base",
    "internvl2-1b": "internvl2_1b",
}

ARCH_IDS: List[str] = list(ARCH_MODULES)

DIFFUSION_MODULES: Dict[str, str] = {
    "cifar10-cld": "paper_cld",
    "cifar10-bdm": "paper_bdm",
    "cifar10-ddpm": "paper_ddpm",
}


def get_arch(name: str, reduced: bool = False, **kw):
    if name not in ARCH_MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {ARCH_IDS}")
    mod = importlib.import_module(f".{ARCH_MODULES[name]}", __package__)
    return mod.make(reduced=reduced, **kw)


def get_diffusion(name: str, reduced: bool = False, **kw):
    if name not in DIFFUSION_MODULES:
        raise KeyError(f"unknown diffusion config {name!r}; known: {list(DIFFUSION_MODULES)}")
    mod = importlib.import_module(f".{DIFFUSION_MODULES[name]}", __package__)
    return mod.make(reduced=reduced, **kw)

"""gemma3-1b [dense]: 26L d_model=1152 4H (MQA kv=1) d_ff=6912 vocab=262144 —
5:1 local:global sliding-window pattern (window 512), 128k rope
[hf:google/gemma-3-1b-pt; unverified].

TP note: 4 Q heads / 1 KV head cannot split over the 16-way model axis; the
sharding rules fall back to FFN+vocab TP (d_ff=6912 and vocab=262144 both
divide 16), and the decode KV cache falls back to sequence sharding.
"""
import jax.numpy as jnp

from ..models.registry import ArchSpec
from ..models.transformer import TransformerCfg

_WINDOWS = (512, 512, 512, 512, 512, None)   # 5 local : 1 global


def make(reduced: bool = False, dtype=jnp.bfloat16) -> ArchSpec:
    if reduced:
        cfg = TransformerCfg(name="gemma3-1b-smoke", n_layers=6, d_model=64,
                             n_heads=4, n_kv_heads=1, d_head=16, d_ff=128,
                             vocab=256, layer_windows=(16, 16, 16, 16, 16, None),
                             layer_moe=(False,) * 6,
                             dtype=jnp.float32, remat=False)
    else:
        cfg = TransformerCfg(name="gemma3-1b", n_layers=26, d_model=1152,
                             n_heads=4, n_kv_heads=1, d_head=256, d_ff=6912,
                             vocab=262144, layer_windows=_WINDOWS,
                             layer_moe=(False,) * 6, rope_theta=1_000_000.0,
                             dtype=dtype)
    return ArchSpec(name="gemma3-1b", family="transformer", cfg=cfg,
                    subquadratic=True,
                    notes="sliding layers are O(S*W); the 1-in-6 global "
                          "layers are O(1)/token at decode, so long_500k "
                          "decode runs (global-layer KV cache is the cost)")

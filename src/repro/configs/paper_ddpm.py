"""Paper config: continuous-time DDPM / VPSDE baseline (paper Tab. 3 DDPM
rows; gDDIM reduces exactly to DDIM here — Thm 1)."""
import jax.numpy as jnp

from ..sde import VPSDE
from ..models.score_net import DiTCfg
from ..train.diffusion import DiffusionSpec


def make(reduced: bool = False, kt: str = "R") -> DiffusionSpec:
    if reduced:
        score = DiTCfg(img_size=8, channels=3, state_mult=1, patch=4,
                       d_model=64, n_layers=2, n_heads=2, remat=False)
        shape = (8, 8, 3)
    else:
        score = DiTCfg(img_size=32, channels=3, state_mult=1, patch=2,
                       d_model=768, n_layers=24, n_heads=12, dtype=jnp.bfloat16)
        shape = (32, 32, 3)
    return DiffusionSpec(name="cifar10-ddpm", sde=VPSDE(), data_shape=shape,
                         score_family="dit", score_cfg=score, kt=kt)

"""rwkv6-7b [ssm]: 32L d_model=4096 (attn-free) d_ff=14336 vocab=65536 —
Finch, data-dependent decay [arXiv:2404.05892; hf]."""
import jax.numpy as jnp

from ..models.registry import ArchSpec
from ..models.zoo import RWKV6LMCfg


def make(reduced: bool = False, dtype=jnp.bfloat16) -> ArchSpec:
    if reduced:
        cfg = RWKV6LMCfg(name="rwkv6-7b-smoke", n_layers=2, d_model=64,
                         n_heads=4, d_ff=128, vocab=256, chunk=16,
                         dtype=jnp.float32, remat=False)
    else:
        cfg = RWKV6LMCfg(name="rwkv6-7b", n_layers=32, d_model=4096,
                         n_heads=64, d_ff=14336, vocab=65536, chunk=16,
                         dtype=dtype)
    return ArchSpec(name="rwkv6-7b", family="rwkv", cfg=cfg,
                    subquadratic=True,
                    notes="attention-free; decode state is (x_prev, S, x_prev_c) "
                          "per layer — O(1) in context length")

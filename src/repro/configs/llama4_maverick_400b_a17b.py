"""llama4-maverick-400b-a17b [moe]: 48L d_model=5120 40H (GQA kv=8) expert
d_ff=8192 vocab=202048, MoE 128e top-1 + shared expert, alternating
dense/MoE layers, early fusion [hf:meta-llama/Llama-4-*; unverified]."""
import jax.numpy as jnp

from ..models.registry import ArchSpec
from ..models.transformer import TransformerCfg, MoECfg


def make(reduced: bool = False, dtype=jnp.bfloat16) -> ArchSpec:
    if reduced:
        cfg = TransformerCfg(name="llama4-maverick-smoke", n_layers=4,
                             d_model=64, n_heads=4, n_kv_heads=2, d_head=16,
                             d_ff=128, vocab=256,
                             layer_windows=(None, None), layer_moe=(False, True),
                             moe=MoECfg(n_experts=8, top_k=1, d_ff=32,
                                        n_shared=1, d_ff_shared=32),
                             dtype=jnp.float32, remat=False)
    else:
        cfg = TransformerCfg(name="llama4-maverick-400b-a17b", n_layers=48,
                             d_model=5120, n_heads=40, n_kv_heads=8, d_head=128,
                             d_ff=16384, vocab=202048,
                             layer_windows=(None, None), layer_moe=(False, True),
                             moe=MoECfg(n_experts=128, top_k=1, d_ff=8192,
                                        n_shared=1, d_ff_shared=8192,
                                        impl="sorted"),
                             dtype=dtype)
    return ArchSpec(name="llama4-maverick-400b-a17b", family="transformer",
                    cfg=cfg, subquadratic=False,
                    notes="alternating dense/MoE; top-1 routing + shared "
                          "expert; early fusion = text+image share the "
                          "backbone (image frontend stubbed per assignment)")

"""whisper-base [audio]: 6L (enc) + 6L (dec) d_model=512 8H d_ff=2048
vocab=51865 — enc-dec, conv frontend STUB: input_specs() supplies
precomputed frame embeddings (B, 1500, d) [arXiv:2212.04356; unverified].

long_500k is skipped: the decoder is full-attention and whisper's context is
bounded by design (DESIGN.md §5).  vocab 51865 is not divisible by 16 —
embedding TP falls back to replication (FSDP only), by the divisibility rule.
"""
import jax.numpy as jnp

from ..models.registry import ArchSpec
from ..models.zoo import EncDecCfg


def make(reduced: bool = False, dtype=jnp.bfloat16) -> ArchSpec:
    if reduced:
        cfg = EncDecCfg(name="whisper-base-smoke", n_enc_layers=2,
                        n_dec_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
                        d_head=16, d_ff=128, vocab=256, n_audio_ctx=32,
                        dtype=jnp.float32, remat=False)
    else:
        cfg = EncDecCfg(name="whisper-base", n_enc_layers=6, n_dec_layers=6,
                        d_model=512, n_heads=8, n_kv_heads=8, d_head=64,
                        d_ff=2048, vocab=51865, n_audio_ctx=1500, dtype=dtype)
    return ArchSpec(name="whisper-base", family="encdec", cfg=cfg,
                    input_mode="tokens", subquadratic=False,
                    frontend_ctx=cfg.n_audio_ctx,
                    gddim_applicable=False,
                    notes="audio frontend stubbed; decoder AR -> gDDIM N/A")

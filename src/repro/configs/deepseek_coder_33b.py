"""deepseek-coder-33b [dense]: 62L d_model=7168 56H (GQA kv=8) d_ff=19200
vocab=32256 — llama-arch [arXiv:2401.14196; hf]."""
import jax.numpy as jnp

from ..models.registry import ArchSpec
from ..models.transformer import TransformerCfg


def make(reduced: bool = False, dtype=jnp.bfloat16) -> ArchSpec:
    if reduced:
        cfg = TransformerCfg(name="deepseek-coder-33b-smoke", n_layers=4,
                             d_model=64, n_heads=8, n_kv_heads=2, d_head=8,
                             d_ff=128, vocab=256, dtype=jnp.float32, remat=False)
    else:
        cfg = TransformerCfg(name="deepseek-coder-33b", n_layers=62,
                             d_model=7168, n_heads=56, n_kv_heads=8, d_head=128,
                             d_ff=19200, vocab=32256, dtype=dtype)
    return ArchSpec(name="deepseek-coder-33b", family="transformer", cfg=cfg,
                    subquadratic=False)

"""llama3-405b [dense]: 126L d_model=16384 128H (GQA kv=8) d_ff=53248
vocab=128256 — GQA 128k vocab [arXiv:2407.21783; unverified]."""
import jax.numpy as jnp

from ..models.registry import ArchSpec
from ..models.transformer import TransformerCfg


def make(reduced: bool = False, dtype=jnp.bfloat16) -> ArchSpec:
    if reduced:
        cfg = TransformerCfg(name="llama3-405b-smoke", n_layers=4, d_model=64,
                             n_heads=8, n_kv_heads=2, d_head=8, d_ff=192,
                             vocab=512, dtype=jnp.float32, remat=False)
    else:
        cfg = TransformerCfg(name="llama3-405b", n_layers=126, d_model=16384,
                             n_heads=128, n_kv_heads=8, d_head=128,
                             d_ff=53248, vocab=128256, rope_theta=500000.0,
                             dtype=dtype)
    return ArchSpec(name="llama3-405b", family="transformer", cfg=cfg,
                    subquadratic=False)

"""nemotron-4-15b [dense]: 32L d_model=6144 48H (GQA kv=8) d_ff=24576
vocab=256000 — GQA, squared-ReLU, ungated FFN [arXiv:2402.16819; unverified]."""
import jax.numpy as jnp

from ..models.registry import ArchSpec
from ..models.transformer import TransformerCfg


def make(reduced: bool = False, dtype=jnp.bfloat16) -> ArchSpec:
    if reduced:
        cfg = TransformerCfg(name="nemotron-4-15b-smoke", n_layers=4,
                             d_model=64, n_heads=8, n_kv_heads=2, d_head=8,
                             d_ff=128, vocab=256, act="relu2", gated_mlp=False,
                             dtype=jnp.float32, remat=False)
    else:
        cfg = TransformerCfg(name="nemotron-4-15b", n_layers=32, d_model=6144,
                             n_heads=48, n_kv_heads=8, d_head=128, d_ff=24576,
                             vocab=256000, act="relu2", gated_mlp=False,
                             dtype=dtype)
    return ArchSpec(name="nemotron-4-15b", family="transformer", cfg=cfg,
                    subquadratic=False)

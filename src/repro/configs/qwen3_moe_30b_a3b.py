"""qwen3-moe-30b-a3b [moe]: 48L d_model=2048 32H (GQA kv=4) expert d_ff=768
vocab=151936, MoE 128 experts top-8 [hf:Qwen/Qwen3-30B-A3B; hf]."""
import jax.numpy as jnp

from ..models.registry import ArchSpec
from ..models.transformer import TransformerCfg, MoECfg


def make(reduced: bool = False, dtype=jnp.bfloat16) -> ArchSpec:
    if reduced:
        cfg = TransformerCfg(name="qwen3-moe-smoke", n_layers=2, d_model=64,
                             n_heads=4, n_kv_heads=2, d_head=16, d_ff=128,
                             vocab=256, layer_windows=(None,), layer_moe=(True,),
                             moe=MoECfg(n_experts=8, top_k=2, d_ff=32),
                             dtype=jnp.float32, remat=False)
    else:
        cfg = TransformerCfg(name="qwen3-moe-30b-a3b", n_layers=48,
                             d_model=2048, n_heads=32, n_kv_heads=4, d_head=128,
                             d_ff=768, vocab=151936,
                             layer_windows=(None,), layer_moe=(True,),
                             moe=MoECfg(n_experts=128, top_k=8, d_ff=768, impl="sorted"),
                             dtype=dtype)
    return ArchSpec(name="qwen3-moe-30b-a3b", family="transformer", cfg=cfg,
                    subquadratic=False,
                    notes="EP: 128 experts / 16-way model axis = 8 per device; "
                          "dispatch/combine einsums lower to all-to-all")

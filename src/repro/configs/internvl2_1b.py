"""internvl2-1b [vlm]: 24L d_model=896 14H (GQA kv=2) d_ff=4864 vocab=151655
— InternViT + InternLM2 backbone; the ViT frontend is a STUB: train/prefill
shapes feed precomputed patch embeddings (B, S, d) [arXiv:2404.16821; hf].

vocab 151655 is not divisible by 16 — embedding TP falls back to replication
(FSDP only) per the divisibility rule; 14 heads likewise (FFN TP only, 4864
divides 16).
"""
import jax.numpy as jnp

from ..models.registry import ArchSpec
from ..models.transformer import TransformerCfg


def make(reduced: bool = False, dtype=jnp.bfloat16) -> ArchSpec:
    if reduced:
        cfg = TransformerCfg(name="internvl2-1b-smoke", n_layers=2, d_model=64,
                             n_heads=4, n_kv_heads=2, d_head=16, d_ff=128,
                             vocab=256, input_mode="embeddings",
                             dtype=jnp.float32, remat=False)
    else:
        cfg = TransformerCfg(name="internvl2-1b", n_layers=24, d_model=896,
                             n_heads=14, n_kv_heads=2, d_head=64, d_ff=4864,
                             vocab=151655, input_mode="embeddings", dtype=dtype)
    return ArchSpec(name="internvl2-1b", family="transformer", cfg=cfg,
                    input_mode="embeddings", subquadratic=False,
                    gddim_applicable=False,
                    notes="patch-embedding frontend stubbed; decode shapes "
                          "drive the LM decoder on tokens")

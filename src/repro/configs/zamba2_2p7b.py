"""zamba2-2.7b [hybrid]: 54L d_model=2560 32H (kv=32) d_ff=10240 vocab=32000,
ssm_state=64 — Mamba2 backbone + shared attention blocks [arXiv:2411.15242; hf]."""
import jax.numpy as jnp

from ..models.registry import ArchSpec
from ..models.zoo import Zamba2Cfg


def make(reduced: bool = False, dtype=jnp.bfloat16) -> ArchSpec:
    if reduced:
        cfg = Zamba2Cfg(name="zamba2-2.7b-smoke", n_layers=4, d_model=64,
                        n_heads=4, n_kv_heads=4, d_head=16, d_ff=128,
                        vocab=256, ssm_state=16, share_every=2, chunk=32,
                        dtype=jnp.float32, remat=False)
    else:
        cfg = Zamba2Cfg(name="zamba2-2.7b", n_layers=54, d_model=2560,
                        n_heads=32, n_kv_heads=32, d_head=80, d_ff=10240,
                        vocab=32000, ssm_state=64, share_every=6, chunk=128,
                        dtype=dtype)
    return ArchSpec(name="zamba2-2.7b", family="zamba", cfg=cfg,
                    subquadratic=True,
                    notes="hybrid: O(1)/token SSM decode; shared attn KV "
                          "cache only every share_every layers")

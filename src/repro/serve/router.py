"""Front-tier router: shard an arrival stream over N engine replicas.

One engine saturates at its slot batch; heavy traffic needs a fleet.  The
router is the tier in front of that fleet, and it is built on the same
discipline as the rest of the serving stack — **a whole multi-replica run
is a pure function of (trace, config, seeds)**:

  * Arrivals come from the PR-7 traffic machinery (`TraceTraffic` on a
    `VirtualClock`): the router routes each request at its arrival time
    on the virtual clock, never wall time.
  * Requests cross the router **only in wire form** (`ServeRequest.
    to_wire()` dicts — serve/api.py): the router's ingress is exactly the
    process boundary the multi-host tier (tools/launchgate.py) ships
    sub-traces across, so the in-process benchmark and the spawned-
    process harness route byte-identical plans.
  * Routing is two-phase: `plan()` deterministically assigns every
    arrival to a replica (an explicit, replayable assignment log), then
    the per-replica sub-traces execute on real engines — in this process
    (`serve()`), or in N spawned processes (launchgate).  Because every
    sample/decode is a pure function of (seed, config), the routed
    results are bitwise-identical to a single-host engine serving the
    same trace, whichever replica served them.

Health and backpressure (all virtual-time-deterministic):

  * **Health probes** fire every `probe_every` virtual units against
    every replica; a replica's health comes from its `ReplicaSpec.
    fault_windows` (deterministic fault injection for tests/benchmarks —
    a real deployment feeds its liveness signal in here).  Routing sees
    the *last probed* state, so a replica that dies mid-window keeps
    taking traffic until the next probe — the real failure mode a
    front-tier has.
  * **Admission backpressure**: each replica serves at most
    `max_queue_depth` in-flight requests under the router's service
    model (`batch` engine slots draining `nfe`/`max_new` rounds per
    request).  An arrival with no healthy, un-full replica is requeued
    `requeue_delay` later, up to `max_requeues` times, then shed — the
    assignment log records every hop, so sheds are an audited decision,
    not silent loss.

The deterministic counters (`requests_routed`, `requeues`,
`health_probes`, `n_shed`) land in the `gddim_router_R2` benchmark record
and are EXACT-gated by tools/perf_guard.py.
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Any, Dict, List, Optional, Sequence, Tuple

from .api import ServeRequest
from .traffic import Arrival, TraceTraffic, VirtualClock


@dataclasses.dataclass(frozen=True)
class ReplicaSpec:
    """One engine replica as the router sees it: an index, the slot
    capacity its service model drains with, and deterministic fault
    windows [a, b) during which health probes report it down."""
    index: int
    batch: int = 4
    fault_windows: Tuple[Tuple[float, float], ...] = ()

    def healthy_at(self, t: float) -> bool:
        return not any(a <= t < b for a, b in self.fault_windows)


@dataclasses.dataclass(frozen=True)
class RouterConfig:
    """Routing policy knobs.  Everything is denominated in virtual-clock
    units (one predictor round — see traffic.py), so a config + trace +
    seeds replays to an identical plan on any host."""
    max_queue_depth: int = 8        # in-flight bound per replica (backpressure)
    probe_every: float = 4.0        # health-probe cadence
    requeue_delay: float = 1.0      # retry delay when no replica admits
    max_requeues: int = 8           # retries before a request is shed
    round_cost: float = 1.0         # virtual cost of one engine round
    default_nfe: int = 10           # service-model cost when nfe is None
    default_max_new: int = 16       # service-model cost when max_new absent


@dataclasses.dataclass
class RoutePlan:
    """The deterministic output of `Router.plan`: per-replica wire-form
    sub-traces plus the audited assignment log and counters."""
    sub_traces: List[List[Tuple[float, Dict[str, Any]]]]
    assignments: List[Dict[str, Any]]   # {t, rid, replica, n_requeues}
    shed: List[Dict[str, Any]]          # {t, rid, n_requeues}
    counters: Dict[str, int]            # requests_routed / requeues /
                                        # health_probes / n_shed


class Router:
    """Deterministic front-tier over N replicas.  `plan()` computes the
    full assignment ahead of execution; `serve()` additionally drains the
    per-replica sub-traces through in-process engines and merges results.
    """

    def __init__(self, replicas: Sequence[ReplicaSpec],
                 config: Optional[RouterConfig] = None):
        if not replicas:
            raise ValueError("router needs at least one replica")
        indices = [r.index for r in replicas]
        if indices != list(range(len(replicas))):
            raise ValueError(f"replica indices must be 0..N-1, got {indices}")
        self.replicas = list(replicas)
        self.config = config if config is not None else RouterConfig()

    # -- service-model cost of one request, in virtual-clock units --------
    def _cost(self, wire: Dict[str, Any]) -> float:
        cfg = self.config
        if wire.get("workload") == "token":
            rounds = wire.get("max_new") or cfg.default_max_new
        else:
            rounds = wire.get("nfe") or cfg.default_nfe
        return rounds * cfg.round_cost

    def plan(self, trace: TraceTraffic,
             clock: Optional[VirtualClock] = None) -> RoutePlan:
        """Route every arrival of `trace` to a replica.  Pure function of
        (trace, self.replicas, self.config): replaying the same inputs
        yields an identical plan, assignment log and counters."""
        cfg = self.config
        n = len(self.replicas)
        clock = clock if clock is not None else VirtualClock()

        # event heap: (t, seq, wire_request, n_requeues); seq is the
        # ingress order, so simultaneous events resolve deterministically
        events: List[Tuple[float, int, Dict[str, Any], int]] = []
        seq = 0
        for a in trace.due(float("inf")):
            req = a.request
            wire = req if isinstance(req, dict) else req.to_wire()
            heapq.heappush(events, (max(a.t, clock.now()), seq, wire, 0))
            seq += 1

        healthy = [True] * n            # last *probed* state per replica
        probe_t = clock.now()           # next probe tick
        busy_until: List[List[float]] = [
            [clock.now()] * r.batch for r in self.replicas]
        done_times: List[List[float]] = [[] for _ in range(n)]

        sub_traces: List[List[Tuple[float, Dict[str, Any]]]] = \
            [[] for _ in range(n)]
        assignments: List[Dict[str, Any]] = []
        shed: List[Dict[str, Any]] = []
        health_probes = requeues = 0

        def load(i: int, now: float) -> int:
            dt = done_times[i]
            while dt and dt[0] <= now:
                heapq.heappop(dt)
            return len(dt)

        while events:
            t, _, wire, hops = heapq.heappop(events)
            clock.advance_to(t)
            while probe_t <= t:          # probes due before this event
                for i, spec in enumerate(self.replicas):
                    healthy[i] = spec.healthy_at(probe_t)
                    health_probes += 1
                probe_t += cfg.probe_every

            candidates = [(load(i, t), i) for i in range(n)
                          if healthy[i] and load(i, t) < cfg.max_queue_depth]
            if not candidates:
                if hops >= cfg.max_requeues:
                    shed.append({"t": t, "rid": wire["rid"],
                                 "n_requeues": hops})
                    continue
                requeues += 1
                seq += 1
                heapq.heappush(events,
                               (t + cfg.requeue_delay, seq, wire, hops + 1))
                continue

            _, i = min(candidates)      # least-loaded, lowest index ties
            start = max(t, heapq.heappop(busy_until[i]))
            done = start + self._cost(wire)
            heapq.heappush(busy_until[i], done)
            heapq.heappush(done_times[i], done)
            sub_traces[i].append((t, wire))
            assignments.append({"t": t, "rid": wire["rid"], "replica": i,
                                "n_requeues": hops})

        return RoutePlan(
            sub_traces=sub_traces, assignments=assignments, shed=shed,
            counters={"requests_routed": len(assignments),
                      "requeues": requeues,
                      "health_probes": health_probes,
                      "n_shed": len(shed)})

    def replica_trace(self, plan: RoutePlan, index: int) -> TraceTraffic:
        """Replica `index`'s sub-trace, deserialized from wire form —
        exactly what that replica's engine `serve_stream`s, in-process or
        in its own spawned process."""
        return TraceTraffic([Arrival(t, ServeRequest.from_wire(w))
                             for t, w in plan.sub_traces[index]])

    def serve(self, trace: TraceTraffic, engines: Sequence[Any]):
        """Plan, then drain every sub-trace through the in-process
        `engines` (one per replica, each on its own virtual clock) and
        merge the per-request results.  Returns (results, plan)."""
        if len(engines) != len(self.replicas):
            raise ValueError(f"{len(self.replicas)} replicas but "
                             f"{len(engines)} engines")
        plan = self.plan(trace)
        results: Dict[int, Any] = {}
        for i, engine in enumerate(engines):
            if plan.sub_traces[i]:
                results.update(engine.serve_stream(
                    self.replica_trace(plan, i), clock=VirtualClock()))
        return results, plan

"""Streaming traffic for the online serving loop: seeded arrival traces,
the virtual clock they are replayed against, and per-request latency
accounting.

`ServeLoop.serve()` consumes a pre-submitted request menu; the online path
(`ServeLoop.serve_stream`) instead pulls an open-ended stream of arrivals
from a `TraceTraffic` as a clock reaches their arrival times.  Nothing in
this module reads wall time: the clock is an explicit object, and the
default `VirtualClock` advances only when the loop dispatches rounds (one
`round_cost` per round) or deliberately skips ahead to the next arrival.
That makes an online run a pure function of (trace, engine config, seeds):
the simulation tier in tests/test_serve_online.py replays seeded traces on
CI and asserts latency percentiles, goodput and the preemption counters
*exactly*, and the online benchmark records are deterministic enough for
tools/perf_guard.py to gate.

Time unit: one predictor round of the engine (`round_cost`, default 1.0).
Deadlines and the latency columns are denominated in the same unit, so a
diffusion request admitted at t with NFE n and an idle engine completes at
exactly t + n.

Traffic shapes:

  * `TraceTraffic([Arrival(t, request), ...])` — an explicit hand-written
    trace (the golden tests hand-compute p50/p99/goodput from these).
  * `poisson_trace(make_request, n, rate, seed)` — seeded Poisson arrivals:
    interarrival gaps are exponential(1/rate) draws from a
    `numpy.random.default_rng(seed)`, so the same seed always yields the
    same trace (the benchmark's online records replay bit-identically).

Deadlines/priorities ride on the *request* (`Request.deadline/.priority`,
`SampleRequest.deadline/.priority` — scheduler.py): the traffic layer only
decides arrival times; urgency policy lives in `DeadlineScheduler`.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional

import numpy as np


class VirtualClock:
    """Explicit simulation time.  The online loop advances it one
    `round_cost` per dispatched round and jumps it to the next arrival when
    the engine is idle; tests construct one directly and read `now()` to
    hand-check the schedule.  Monotone by construction (`advance` rejects
    negative steps, `advance_to` is a no-op for past times)."""

    def __init__(self, t0: float = 0.0):
        self._t = t0

    def now(self) -> float:
        return self._t

    def advance(self, dt: float) -> None:
        if dt < 0:
            raise ValueError(f"clock cannot run backwards (dt={dt})")
        self._t += dt

    def advance_to(self, t: float) -> None:
        if t > self._t:
            self._t = t


@dataclasses.dataclass
class Arrival:
    """One scheduled arrival: `request` becomes visible to the loop once
    the clock reaches `t` (never before — the loop cannot peek)."""
    t: float
    request: Any


class TraceTraffic:
    """An arrival trace consumed in time order.  `due(now)` pops every
    arrival with t <= now; `next_time()` is the earliest remaining arrival
    (None once drained) — the loop uses it to bound a round window so an
    arrival is never overrun by more than one round, and to skip the clock
    forward over idle gaps."""

    def __init__(self, arrivals: List[Arrival]):
        self._queue = sorted(arrivals, key=lambda a: a.t)
        self._head = 0

    def due(self, now: float) -> List[Arrival]:
        start = self._head
        while self._head < len(self._queue) \
                and self._queue[self._head].t <= now:
            self._head += 1
        return self._queue[start:self._head]

    def next_time(self) -> Optional[float]:
        if self._head >= len(self._queue):
            return None
        return self._queue[self._head].t

    def remaining(self) -> int:
        return len(self._queue) - self._head


def poisson_trace(make_request: Callable[[int, np.random.Generator], Any],
                  n: int, rate: float, seed: int,
                  start: float = 0.0) -> TraceTraffic:
    """Seeded Poisson arrival process: `n` arrivals at exponential(1/rate)
    gaps from `start`, each request built by `make_request(i, rng)` (the
    rng is the same seeded generator, so request attributes drawn from it
    — priorities, deadline slack, config choice — replay with the trace).
    Arrival times are converted to host floats at construction: the whole
    trace is plain Python data, nothing numpy leaks into the clock."""
    if rate <= 0:
        raise ValueError(f"arrival rate must be > 0, got {rate}")
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(1.0 / rate, size=n).tolist()
    arrivals, t = [], start
    for i in range(n):
        t += gaps[i]
        arrivals.append(Arrival(t=t, request=make_request(i, rng)))
    return TraceTraffic(arrivals)


# ---------------------------------------------------------------------------
# per-request latency accounting
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class RequestTiming:
    """arrival -> admission -> completion timestamps for one request, all
    in virtual-clock units.  `t_admit` is stamped at *first* admission;
    `n_preempted` counts suspensions (each resume restores the slot row
    bitwise, so preemption moves these timestamps, never the sample)."""
    t_arrival: float
    deadline: Optional[float] = None
    priority: int = 0
    t_admit: Optional[float] = None
    t_done: Optional[float] = None
    n_preempted: int = 0

    @property
    def latency(self) -> Optional[float]:
        if self.t_done is None:
            return None
        return self.t_done - self.t_arrival

    @property
    def met_slo(self) -> bool:
        """Completed within its deadline (no deadline = always met)."""
        return self.t_done is not None and (
            self.deadline is None or self.t_done <= self.deadline)


def percentile(xs: List[float], p: float) -> float:
    """Linear-interpolation percentile (numpy's default method), in pure
    host Python so the golden tests can hand-compute the expected value
    and the result is a plain float for the benchmark JSON."""
    if not xs:
        raise ValueError("percentile of an empty sample")
    xs = sorted(xs)
    rank = (len(xs) - 1) * (p / 100.0)
    lo = int(rank)
    hi = min(lo + 1, len(xs) - 1)
    frac = rank - lo
    return xs[lo] * (1.0 - frac) + xs[hi] * frac


def serving_metrics(log: Dict[int, RequestTiming]) -> Dict[str, Any]:
    """Latency/goodput summary of one online run, from the loop's
    `request_log`.  All values are deterministic at a fixed trace:

      p50_latency / p99_latency — arrival->completion percentiles, in
                                  virtual rounds; None when the run
                                  completed nothing (a percentile of an
                                  empty sample has no value — reporting
                                  0.0 here read as "instant completion"
                                  on shed-everything runs)
      deadline_misses           — completed requests whose t_done exceeded
                                  their deadline (unfinished requests with
                                  a deadline also count: a shed or
                                  still-queued request has already lost
                                  its SLO)
      goodput_slo               — SLO-met completions per virtual round,
                                  over the span from the first arrival to
                                  the last completion; 0.0 when nothing
                                  completed

    A zero-completion log is a valid input (e.g. every request shed):
    the latency percentiles are None, goodput is 0.0, and deadline
    misses still count the unfinished-with-deadline requests.
    """
    timings = list(log.values())
    done = [t for t in timings if t.t_done is not None]
    lats = [t.latency for t in done]
    misses = sum(1 for t in timings
                 if t.deadline is not None and not t.met_slo)
    n_ok = sum(1 for t in done if t.met_slo)
    span = 0.0
    if done:
        span = max(t.t_done for t in done) - \
            min(t.t_arrival for t in timings)
    return {
        "n_arrived": len(timings),
        "n_done": len(done),
        "p50_latency": percentile(lats, 50.0) if lats else None,
        "p99_latency": percentile(lats, 99.0) if lats else None,
        "deadline_misses": misses,
        "goodput_slo": (n_ok / span) if span > 0 else 0.0,
        "span": span,
    }

"""The unified, wire-level request surface: one `ServeRequest` for every
workload the serving tier accepts.

Before this module the request surface was three overlapping in-process
dataclasses — `Request` (token decoding), `SampleRequest` (diffusion
sampling, both scheduler.py) and the sampler-config fields duplicated from
`repro.core.coeffs.SamplerConfig` — none of which could cross a process
boundary.  The multi-host tier (distributed/multihost.py, serve/router.py,
tools/launchgate.py) forces serialization, so the surface is now ONE
frozen, versioned dataclass with an exact JSON round-trip:

    req  = ServeRequest(rid=3, workload="diffusion", seed=3, nfe=20, q=2)
    wire = req.to_wire()          # plain-JSON dict, schema-versioned
    assert ServeRequest.from_wire(wire) == req     # exact, ndarrays included

Design rules:

  * **Frozen.**  A request is immutable after construction: engines,
    schedulers, the parking table and the router all hold references to
    the same object, and the online path re-admits parked requests — a
    mutable request would let a resume observe different fields than the
    original admission.  (`__post_init__` normalizes the two ndarray
    fields to their canonical dtypes via `object.__setattr__`, the one
    sanctioned write.)
  * **Versioned wire form.**  `to_wire()` emits a dict of JSON scalars /
    lists only (ndarrays become nested lists — exact for int32 tokens and
    f32 frames, since every f32 is exactly representable as a Python
    float) plus the `"v"` schema tag.  `from_wire()` rejects unknown
    versions and unknown keys instead of guessing: a router fleet running
    mixed schema versions must fail loudly at the boundary, not corrupt a
    request mid-flight.  The router and launchgate harness speak ONLY
    this form.
  * **Workload is a field, not a type.**  `workload="token" | "diffusion"`
    selects the engine family; `Request` / `SampleRequest` survive as
    thin aliases (deprecated spelling, same fields, same semantics) so
    existing call sites and `dataclasses.replace` keep working.  New code
    should construct `ServeRequest` directly.
  * **Value equality, array-aware.**  `==` compares field values with
    `np.array_equal` on the ndarray fields (dataclass-generated equality
    would raise on arrays), ignoring the alias class — a request that
    round-trips the wire compares equal to the original whichever alias
    built it.

Sampler-config fields (`nfe`/`q`/`corrector`/`lam`/`grid`/`family`/
`algorithm`/`precision`) mirror `repro.core.coeffs.SamplerConfig`; `None` means "use
the engine default", and the *merged* config is validated by the engine
(`DiffusionEngine.config_of`) exactly as before — the request type does
not second-guess the engine's menu.  `priority`/`deadline` ride along for
the online path and never enter the sampler config (urgency changes when
a sample is computed, not what — see scheduler.py).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional

import numpy as np

# Bump when a field is added/renamed/retyped.  `from_wire` accepts exactly
# this version: cross-version traffic is a deploy error, not a soft case.
# v2: added the per-request sampler `algorithm` field.
WIRE_VERSION = 2

WORKLOADS = ("token", "diffusion")

# ndarray fields and their canonical wire dtypes (the only non-scalar
# fields; everything else is a JSON scalar or None)
_ARRAY_FIELDS = {"tokens": np.int32, "frames": np.float32}


@dataclasses.dataclass(frozen=True, eq=False)
class ServeRequest:
    """One serving request — token decoding or gDDIM sampling — in the
    form every tier speaks: engines in-process, the router and the
    multi-host launch harness over the wire (`to_wire`/`from_wire`)."""

    rid: int
    workload: str = "diffusion"         # member of WORKLOADS

    # --- seeding: the result is a pure function of (seed, merged config),
    #     independent of admission order, neighbours, replica or host
    seed: int = 0

    # --- sampler config (diffusion; None = engine default) --------------
    nfe: Optional[int] = None           # grid steps N
    q: Optional[int] = None             # multistep order (Eq. 19)
    corrector: Optional[bool] = None    # Eq. 45 / Alg. 1 corrector
    lam: Optional[float] = None         # stochasticity lambda (Eq. 22)
    grid: Optional[str] = None          # 'quadratic' | 'uniform'
    family: Optional[str] = None        # SDE family ('vpsde'|'cld'|'bdm')
    algorithm: Optional[str] = None     # sampler update rule
                                        # ('gddim'|'gmm'|'accel')
    precision: Optional[str] = None     # score-net precision class
                                        # ('f32'|'bf16'|'int8')

    # --- token workload --------------------------------------------------
    tokens: Optional[np.ndarray] = None  # (L,) int32 prompt
    max_new: int = 16                    # decode budget incl. prefill token
    frames: Optional[np.ndarray] = None  # (ctx, d_model) f32, encdec archs

    # --- urgency (online path; never enters the sampler config) ---------
    priority: int = 0                    # higher = more urgent
    deadline: Optional[float] = None     # absolute virtual-clock time

    def __post_init__(self):
        if self.workload not in WORKLOADS:
            raise ValueError(f"request {self.rid}: workload must be one of "
                             f"{WORKLOADS}, got {self.workload!r}")
        if self.workload == "token" and self.tokens is None:
            raise ValueError(f"request {self.rid}: token workload needs "
                             "a tokens prompt")
        for name, dtype in _ARRAY_FIELDS.items():
            val = getattr(self, name)
            if val is not None:
                object.__setattr__(
                    self, name,
                    np.asarray(val, dtype=dtype))  # staticcheck: disable=SC103 (construction-time dtype normalization of a host-side wire payload — never device data, never in the round loop)

    # -- surface shared with the engines ----------------------------------
    @property
    def prompt_len(self) -> int:
        return int(len(self.tokens))

    # -- wire form ---------------------------------------------------------
    def to_wire(self) -> Dict[str, Any]:
        """Plain-JSON dict (scalars, lists, None) with the schema tag.
        Exact: `from_wire(to_wire(r)) == r` for every constructible r."""
        wire: Dict[str, Any] = {"v": WIRE_VERSION}
        for f in dataclasses.fields(self):
            val = getattr(self, f.name)
            if isinstance(val, np.ndarray):
                val = val.tolist()
            wire[f.name] = val
        return wire

    @classmethod
    def from_wire(cls, wire: Dict[str, Any]) -> "ServeRequest":
        """Inverse of `to_wire`.  Rejects unknown schema versions and
        unknown keys — the process boundary is where a fleet running
        mixed code must fail, not deep inside an engine."""
        version = wire.get("v")
        if version != WIRE_VERSION:
            raise ValueError(f"wire schema version {version!r} != "
                             f"{WIRE_VERSION} (this build)")
        names = {f.name for f in dataclasses.fields(cls)}
        kw = {k: v for k, v in wire.items() if k != "v"}
        unknown = sorted(set(kw) - names)
        if unknown:
            raise ValueError(f"unknown wire fields {unknown}; known: "
                             f"{sorted(names)}")
        return cls(**kw)    # __post_init__ restores the ndarray dtypes

    # -- value equality, array-aware ---------------------------------------
    def __eq__(self, other: Any) -> bool:
        if not isinstance(other, ServeRequest):
            return NotImplemented
        for f in dataclasses.fields(self):
            a, b = getattr(self, f.name), getattr(other, f.name)
            if f.name in _ARRAY_FIELDS:
                if (a is None) != (b is None):
                    return False
                if a is not None and not np.array_equal(a, b):
                    return False
            elif a != b:
                return False
        return True

    def __hash__(self) -> int:
        return hash((self.rid, self.workload, self.seed))


@dataclasses.dataclass(frozen=True, eq=False)
class Request(ServeRequest):
    """Deprecated alias: a token-decoding `ServeRequest`.  Same fields,
    `workload` defaults to 'token'; construct `ServeRequest` directly in
    new code."""
    workload: str = "token"


@dataclasses.dataclass(frozen=True, eq=False)
class SampleRequest(ServeRequest):
    """Deprecated alias: a diffusion-sampling `ServeRequest`.  Same
    fields, `workload` defaults to 'diffusion'; construct `ServeRequest`
    directly in new code."""
    workload: str = "diffusion"

"""Request types + FIFO scheduler with head-of-line shape grouping.

The scheduler is workload-agnostic: the same instance admits token-decoding
requests (grouped by prompt length so one `make_prefill_step` call serves
the whole group with a single shape — essential for the recurrent-state
archs, whose prefill cannot tolerate right-padding) and diffusion sampling
requests (grouped by **family x corrector** cost class: every sample shares
one packed state shape, but the `DiffusionEngine` keys admission on which
(SDE family, corrector) round-step variant a config rides — each family is
one score-net evaluation per round, the corrector doubles it — so admission
waves are class-homogeneous and runs of same-class traffic tend to share
rounds; classes can still co-reside after retire-and-refill — see the
engine docstring).

Admission is FIFO with head-of-line grouping: `take_group(n)` pops up to
`n` requests from the front whose group key equals the head's key.  A
request with a new prompt length therefore waits for the current length
run to drain rather than being reordered around — simple, starvation-free,
and it keeps the number of distinct prefill shapes (→ compilations) at one
per prompt length actually seen.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Any, Callable, List, Optional

import numpy as np


@dataclasses.dataclass
class Request:
    """One token-decoding request: greedy-decode up to `max_new` tokens
    (counting the one emitted by prefill) or until `eos`."""
    rid: int
    tokens: np.ndarray                  # (L,) int32 prompt
    max_new: int = 16
    frames: Optional[np.ndarray] = None  # (ctx, d_model) f32, encdec archs

    @property
    def prompt_len(self) -> int:
        return int(len(self.tokens))


@dataclasses.dataclass
class SampleRequest:
    """One diffusion sampling request: one gDDIM sample, seeded so the
    result is a pure function of `seed` and the sampler config
    (independent of admission order and of neighbouring slots).

    The sampler-config fields select a member of gDDIM's sampler family
    (see `repro.core.coeffs.SamplerConfig`); `None` means "use the
    engine's default".  One `DiffusionEngine` serves any mix of configs —
    and, when built multi-family, any mix of SDE *families* — in the same
    batch: a 10-NFE VPSDE preview can share slots with a 50-NFE CLD
    predictor-corrector render and a BDM sample."""
    rid: int
    seed: int = 0
    nfe: Optional[int] = None           # grid steps N
    q: Optional[int] = None             # multistep order (Eq. 19)
    corrector: Optional[bool] = None    # Eq. 45 / Alg. 1 corrector
    lam: Optional[float] = None         # stochasticity lambda (Eq. 22)
    grid: Optional[str] = None          # 'quadratic' | 'uniform'
    family: Optional[str] = None        # SDE family ('vpsde'|'cld'|'bdm')


class Scheduler:
    def __init__(self, group_key: Callable[[Any], Any] = lambda r: None):
        self._queue: deque = deque()
        self._group_key = group_key

    def submit(self, request: Any) -> None:
        self._queue.append(request)

    def submit_all(self, requests) -> None:
        for r in requests:
            self.submit(r)

    @property
    def n_pending(self) -> int:
        return len(self._queue)

    def has_pending(self) -> bool:
        return bool(self._queue)

    def take_group(self, n: int) -> List[Any]:
        """Pop up to `n` front requests sharing the head's group key."""
        if n <= 0 or not self._queue:
            return []
        key = self._group_key(self._queue[0])
        group = []
        while self._queue and len(group) < n \
                and self._group_key(self._queue[0]) == key:
            group.append(self._queue.popleft())
        return group

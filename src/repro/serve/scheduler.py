"""FIFO scheduler with head-of-line shape grouping (+ the online
urgency-ordered variant).

The request types live in `repro.serve.api`: one frozen, wire-versioned
`ServeRequest` covers both workloads, and the historical `Request` /
`SampleRequest` spellings are thin aliases of it (re-exported here so old
imports keep working).  The schedulers are agnostic to all of it — they
order opaque request objects by group key and urgency fields only.

The scheduler is workload-agnostic: the same instance admits token-decoding
requests (grouped by prompt length so one `make_prefill_step` call serves
the whole group with a single shape — essential for the recurrent-state
archs, whose prefill cannot tolerate right-padding) and diffusion sampling
requests (grouped by **family x corrector** cost class: every sample shares
one packed state shape, but the `DiffusionEngine` keys admission on which
(SDE family, corrector) round-step variant a config rides — each family is
one score-net evaluation per round, the corrector doubles it — so admission
waves are class-homogeneous and runs of same-class traffic tend to share
rounds; classes can still co-reside after retire-and-refill — see the
engine docstring).

Admission is FIFO with head-of-line grouping: `take_group(n)` pops up to
`n` requests from the front whose group key equals the head's key.  A
request with a new prompt length therefore waits for the current length
run to drain rather than being reordered around — simple, starvation-free,
and it keeps the number of distinct prefill shapes (→ compilations) at one
per prompt length actually seen.

The online path (`ServeLoop.serve_stream`) swaps in `DeadlineScheduler`:
same queue surface, but admission order is urgency — priority first
(higher preempts strictly lower, see loop.py), earliest deadline within a
priority, arrival order as the tie-break so no-deadline requests cannot
starve.  Waves stay (group key)-homogeneous: the wave is built from the
most urgent request's class, in urgency order, skipping over other
classes instead of stopping at them (an online mix should not make an
urgent request wait for an unrelated class run to drain).
"""
from __future__ import annotations

from collections import deque
from typing import Any, Callable, List, Optional

from .api import Request, SampleRequest, ServeRequest  # noqa: F401  # staticcheck: disable=SC001 (re-export: historical import site for the request types)


class Scheduler:
    def __init__(self, group_key: Callable[[Any], Any] = lambda r: None):
        self._queue: deque = deque()
        self._group_key = group_key

    def submit(self, request: Any) -> None:
        self._queue.append(request)

    def submit_all(self, requests) -> None:
        for r in requests:
            self.submit(r)

    @property
    def n_pending(self) -> int:
        return len(self._queue)

    def has_pending(self) -> bool:
        return bool(self._queue)

    def take_group(self, n: int) -> List[Any]:
        """Pop up to `n` front requests sharing the head's group key."""
        if n <= 0 or not self._queue:
            return []
        key = self._group_key(self._queue[0])
        group = []
        while self._queue and len(group) < n \
                and self._group_key(self._queue[0]) == key:
            group.append(self._queue.popleft())
        return group


def urgency_key(request: Any):
    """Total order on pending requests for the online path: priority
    strictly first (higher = smaller key = more urgent), earliest deadline
    within a priority (no deadline sorts last), and submission order as
    the final tie-break — FIFO among equals, so a request can only be
    overtaken by one that is strictly more urgent, never starved by
    churn.  (The submission sequence number is appended by the scheduler;
    this helper orders the (priority, deadline) prefix.)"""
    deadline = getattr(request, "deadline", None)
    has_deadline = deadline is not None
    return (-getattr(request, "priority", 0),
            not has_deadline, deadline if has_deadline else 0.0)


class DeadlineScheduler(Scheduler):
    """Urgency-ordered admission for `ServeLoop.serve_stream` (see the
    module docstring).  Same `submit`/`take_group` surface as the FIFO
    scheduler so the engines' admission machinery is reused unchanged;
    `peek()` additionally exposes the most urgent pending request so the
    loop can decide whether it justifies a preemption."""

    def __init__(self, group_key: Callable[[Any], Any] = lambda r: None):
        super().__init__(group_key)
        self._seq = 0

    def submit(self, request: Any) -> None:
        self._queue.append((self._seq, request))
        self._seq += 1

    def _order(self) -> List[Any]:
        return sorted(self._queue,
                      key=lambda e: urgency_key(e[1]) + (e[0],))

    def peek(self) -> Optional[Any]:
        if not self._queue:
            return None
        return self._order()[0][1]

    def take_group(self, n: int) -> List[Any]:
        """Up to `n` pending requests sharing the *most urgent* request's
        group key, in urgency order (other classes are skipped over, not
        waited behind)."""
        if n <= 0 or not self._queue:
            return []
        ordered = self._order()
        key = self._group_key(ordered[0][1])
        group = [e for e in ordered
                 if self._group_key(e[1]) == key][:n]
        for e in group:
            self._queue.remove(e)
        return [r for _, r in group]

"""`ServeLoop`: the shared continuous-batching core behind both engines.

`TokenEngine` and `DiffusionEngine` used to duplicate the admit/round/retire
machinery and rebuild per-slot metadata in numpy every round, blocking on a
device fetch per step.  `ServeLoop` factors the skeleton out and inverts the
data flow: the per-slot state the step consumes lives on device in an
`EngineState` pytree (state.py), and the host keeps only a cheap *shadow* of
it in the `SlotTable` — enough to pace the loop, never shipped back to the
device.

The steady-state loop is::

    while pending or busy:
        _admit()                         # fill free slots (host -> device:
                                         #   prefill / prior scatter — the
                                         #   only h2d traffic, off the
                                         #   steady-state path)
        n = _rounds_until_poll()         # min over busy slots of a host-
                                         #   side lower bound on rounds
                                         #   until the next retirement,
                                         #   capped at sync_every (R)
        n x _round()                     # donated, device-resident steps;
                                         #   async dispatch, no sync
        _poll(results)                   # ONE small device fetch (token
                                         #   done/progress mask) or pure
                                         #   host arithmetic (diffusion,
                                         #   whose retirement round is
                                         #   exactly predictable), plus
                                         #   output fetches for retirees

so a round moves *no* per-slot metadata host->device (locked in by a
`jax.transfer_guard` test) and the host syncs at most once every
`sync_every` rounds.  For workloads whose progress is exactly predictable
(diffusion: a slot admitted at k=0 with NFE n retires after exactly n
rounds; token decode with eos disabled) the bound is tight and the loop
never runs a wasted round; an early eos retirement is simply observed at
the next poll.

`serve()` consumes a pre-submitted menu with the FIFO scheduler;
`serve_stream()` is the *online* path — arrivals stream in from a
`TraceTraffic` against a virtual clock, admission is deadline/priority
urgency (`DeadlineScheduler`) with preemption into a host-side
`ParkingTable`, and the poll is double-buffered (the look-ahead round is
enqueued before the host blocks on the previous round's done-mask
snapshot).  Both paths share the admit/round/poll machinery and hooks.

Mesh awareness also lives here: constructed with a `Mesh`, the loop derives
the slot-batch shard count (for round-robin free-slot placement across
shards, see `SlotTable`) and runs every device call inside the mesh context
so in-model `constrain_batch` constraints resolve.  Engines place their
params / caches / state via the serve rules in `distributed.sharding`.
"""
from __future__ import annotations

import contextlib
import math
from typing import Any, Dict, List, Optional

import numpy as np

from ..distributed import sharding as shd
from .parking import ParkingTable
from .scheduler import DeadlineScheduler
from .slots import SlotTable
from .traffic import RequestTiming, VirtualClock

Mesh = Any


def check_unique_rids(requests) -> None:
    seen = set()
    for r in requests:
        if r.rid in seen:
            raise ValueError(f"duplicate request rid {r.rid}: results are "
                             "keyed by rid, a duplicate would be dropped")
        seen.add(r.rid)


def bucket_pow2(n: int, cap: int) -> int:
    """Smallest power of two >= n, capped at `cap` (prefill width buckets;
    same doubling policy as the coefficient-bank buckets)."""
    from ..core.coeffs import bucket_size
    return min(bucket_size(n, 1), cap)


class ServeLoop:
    """Continuous-batching skeleton.  Subclasses provide:

      _validate(req)            raise ValueError on a bad request
      _admit_wave(group, free)  prefill/scatter one admission wave into
                                device state (may consume `free` in order)
      _round()                  dispatch one jitted, donated round step
      _poll(results)            observe device progress, retire finished
                                slots into `results`, return retire count
      _remaining_lb(slot)       host-side lower bound on rounds until this
                                slot can retire (0 = may already be done)
    """

    #: greedy engines fill every free slot per admission cycle (token:
    #: waves are shape buckets, nothing is gained by spacing them out);
    #: non-greedy engines admit ONE head-of-line wave per cycle, so a
    #: queued wave of a more expensive cost class does not land next to
    #: the cheap wave just admitted (diffusion: a corrector render would
    #: drag predictor-only neighbours through the 2-eval program for
    #: their whole lifetime — admitted one poll cycle later, it only
    #: co-resides after a natural retire-and-refill)
    greedy_admit = True

    def __init__(self, batch_size: int, scheduler,
                 mesh: Optional[Mesh] = None,
                 shard_cfg: Optional[shd.ShardCfg] = None,
                 sync_every: int = 8):
        if sync_every < 1:
            raise ValueError(f"sync_every must be >= 1, got {sync_every}")
        self.batch_size = batch_size
        self.scheduler = scheduler
        self.mesh = mesh
        self.shard_cfg = shard_cfg if shard_cfg is not None else shd.ShardCfg()
        self.sync_every = sync_every
        n_shards = 1
        if mesh is not None:
            entry = shd.batch_axes_entry(mesh, self.shard_cfg, batch_size)
            axes = entry if isinstance(entry, tuple) else \
                (() if entry is None else (entry,))
            for a in axes:
                n_shards *= mesh.shape[a]
            want = 1
            for a in self.shard_cfg.present(mesh, self.shard_cfg.batch_axes):
                want *= mesh.shape[a]
            if want > 1 and n_shards == 1:
                raise ValueError(
                    f"batch_size {batch_size} is not divisible by any prefix "
                    f"of the mesh batch axes (sizes to {want}): the slot "
                    "batch would silently replicate instead of shard — "
                    "pick a divisible batch_size or a smaller data axis")
        self.n_shards = n_shards
        self.slots = SlotTable(batch_size, n_shards=n_shards)
        self.n_polls = 0
        # online-serving surface (serve_stream): parked rows of preempted
        # slots, preemption counters, per-request latency log, and the
        # per-call wave/preemption traces the property tests assert over
        self.parking = ParkingTable()
        self.n_preemptions = 0
        self.n_resumes = 0
        self.request_log: Dict[int, RequestTiming] = {}
        self.wave_log: List[tuple] = []
        self.preemption_log: List[tuple] = []

    # ---- public API ---------------------------------------------------------
    def serve(self, requests: List[Any]) -> Dict[int, np.ndarray]:
        check_unique_rids(requests)
        for r in requests:
            self._validate(r)
        self._prepare(requests)
        self.scheduler.submit_all(requests)
        self.wave_log = []
        results: Dict[int, np.ndarray] = {}
        while self.scheduler.has_pending() or self.slots.active_ids():
            self._admit()
            if not self.slots.active_ids():
                continue
            n = self._rounds_until_poll()
            for _ in range(n):
                self._round()
            retired = self._poll(results)
            self.n_polls += 1
            if n == 0 and not retired:
                # a zero lower bound that retires nothing would spin; the
                # engines' bounds make this unreachable (a slot at bound 0
                # is provably device-inactive), but a round is always safe
                self._round()                           # pragma: no cover
        return results

    def serve_stream(self, traffic, clock: Optional[VirtualClock] = None,
                     round_cost: float = 1.0) -> Dict[int, np.ndarray]:
        """Online serving: pull an open-ended arrival stream from `traffic`
        (serve/traffic.py) as `clock` reaches each arrival time, admit by
        deadline/priority urgency with preemption, and double-buffer the
        poll so round k+1 is enqueued before the host blocks on round k's
        done mask.  Returns results keyed by rid, like `serve`; per-request
        arrival/admission/completion timestamps land in `request_log`
        (summarized by `traffic.serving_metrics`).

        The clock is virtual by default: it advances exactly one
        `round_cost` per dispatched round and jumps over idle gaps, so a
        run is a pure function of (trace, engine, seeds) and the
        simulation tier replays it deterministically on CI.

        Scheduling contract (asserted by tests/test_properties.py):

          * admission order is urgency — priority, then earliest deadline,
            then arrival (`DeadlineScheduler`); waves never mix (family,
            corrector) classes, preemption or not;
          * a pending request preempts only a *strictly lower priority*
            active slot (lowest priority first, most remaining work as the
            tie-break); the victim's state row is parked host-side and
            restored bitwise on resume, so preemption changes when a
            result is computed, never the result;
          * polls happen only when a retirement is possible (the host
            lower bound reached zero) or `sync_every` rounds have run —
            an arrival-dense trace does not degrade to per-round syncing
            (the poll-cadence counter in the online benchmark gates this).
        """
        clock = VirtualClock() if clock is None else clock
        if round_cost <= 0:
            raise ValueError(f"round_cost must be > 0, got {round_cost}")
        results: Dict[int, np.ndarray] = {}
        self.request_log = {}
        self.wave_log = []
        self.preemption_log = []
        seen: set = set()
        fifo = self.scheduler
        self.scheduler = DeadlineScheduler(group_key=fifo._group_key)
        since_poll = 0          # rounds dispatched since the last poll
        try:
            while True:
                for arr in traffic.due(clock.now()):
                    r = arr.request
                    if r.rid in seen:
                        raise ValueError(
                            f"duplicate request rid {r.rid} in trace")
                    seen.add(r.rid)
                    self._validate(r)
                    self._prepare([r])
                    self.scheduler.submit(r)
                    self.request_log[r.rid] = RequestTiming(
                        t_arrival=arr.t,
                        deadline=getattr(r, "deadline", None),
                        priority=getattr(r, "priority", 0))
                if not (self.slots.active_ids()
                        or self.scheduler.has_pending()):
                    nxt = traffic.next_time()
                    if nxt is None:
                        break                     # drained: stream is done
                    clock.advance_to(nxt)         # idle: skip to the next
                    continue                      # arrival
                self._admit_stream(now=clock.now())
                active = self.slots.active()
                if not active:                              # pragma: no cover
                    nxt = traffic.next_time()     # defensive: pending but
                    if nxt is None:               # unadmittable cannot
                        break                     # happen (free slots exist
                    clock.advance_to(nxt)         # whenever nothing is
                    continue                      # active)
                # window: rounds until the earliest of (possible
                # retirement, forced poll, next arrival) — each dispatched
                # round advances the clock, look-ahead rounds included,
                # so virtual time == rounds in flight
                lb = min(self._remaining_lb(s) for s in active)
                n = min(lb, self.sync_every - since_poll)
                nxt = traffic.next_time()
                if nxt is not None:
                    gap = int(math.ceil((nxt - clock.now()) / round_cost))
                    n = min(n, max(gap, 1))
                for _ in range(max(n, 0)):
                    self._round()
                    clock.advance(round_cost)
                    since_poll += 1
                # poll-cadence fix: an arrival-capped window ends with no
                # slot at its retirement bound — skip the poll instead of
                # regressing to per-round syncing (frozen rows make a late
                # observation safe; `sync_every` still forces one)
                if (lb - max(n, 0)) > 0 and since_poll < self.sync_every:
                    continue
                # double-buffered poll: snapshot the done mask, enqueue
                # the look-ahead round, then block on the snapshot — round
                # k+1 executes while the host waits on round k
                t_mark = clock.now()
                snap = self._poll_snapshot()
                nxt = traffic.next_time()
                lag = 0
                if (nxt is None or nxt > clock.now()) \
                        and any(self._remaining_lb(s) > 0
                                for s in self.slots.active()):
                    self._round()
                    clock.advance(round_cost)
                    lag = 1
                before = set(results)
                self._poll(results, snap=snap, lag=lag)
                self.n_polls += 1
                since_poll = lag
                for rid in set(results) - before:
                    timing = self.request_log.get(rid)
                    if timing is not None:
                        timing.t_done = t_mark
            assert len(self.parking) == 0   # parked ⊆ pending, both drained
            return results
        finally:
            self.scheduler = fifo

    # ---- shared loop pieces -------------------------------------------------
    def _admit(self) -> None:
        """Fill free slots from the queue in class-homogeneous waves (one
        `take_group` run each) — every wave for greedy engines, a single
        head-of-line wave per cycle otherwise (see `greedy_admit`)."""
        while True:
            free = self.slots.free_ids()
            group = self.scheduler.take_group(len(free))
            if not group:
                return
            self._place_group(group, free)
            if not self.greedy_admit:
                return

    def _admit_stream(self, now: float) -> int:
        """Online admission: fill free slots in urgency order, then let the
        most urgent pending request preempt strictly-lower-priority active
        slots while the batch is full.  Each eviction parks the victim's
        state row host-side and re-queues it (it competes again by its own
        urgency), so every iteration admits the pending head that justified
        it and eviction chains strictly descend in priority — no cycles,
        no starvation by churn."""
        admitted = 0
        while True:
            free = self.slots.free_ids()
            group = self.scheduler.take_group(len(free))
            if not group:
                break
            self._place_group(group, free, now=now)
            admitted += len(group)
            if not self.greedy_admit:
                break
        while True:
            head = self.scheduler.peek()
            if head is None or self.slots.free_ids():
                break
            prio = getattr(head, "priority", 0)
            victims = [s for s in self.slots.active()
                       if getattr(s.request, "priority", 0) < prio]
            if not victims:
                break
            victim = min(victims, key=lambda s: (
                getattr(s.request, "priority", 0),
                -self._remaining_lb(s), s.index))
            self.preemption_log.append(
                (head.rid, prio, victim.request.rid,
                 getattr(victim.request, "priority", 0)))
            self._suspend(victim)
            free = self.slots.free_ids()
            group = self.scheduler.take_group(len(free))
            if group:
                self._place_group(group, free, now=now)
                admitted += len(group)
        return admitted

    def _place_group(self, group, free, now: Optional[float] = None) -> None:
        """Land one class-homogeneous wave: fresh requests through the
        engine's admission scatter, parked ones through the bitwise row
        restore.  `free` is consumed left-to-right (fresh first), matching
        the engines' wave layout."""
        self.wave_log.append(
            tuple(self.scheduler._group_key(r) for r in group))
        fresh = [r for r in group if r.rid not in self.parking]
        parked = [r for r in group if r.rid in self.parking]
        if fresh:
            self._admit_wave(fresh, list(free[:len(fresh)]))
        for j, r in enumerate(parked):
            payload, shadow, _ = self.parking.pop(r.rid)
            index = free[len(fresh) + j]
            self._resume_slot(r, shadow, payload, index)
            self.slots.assign(index, r, **shadow)
            self.n_resumes += 1
        if now is not None:
            for r in group:
                timing = self.request_log.get(r.rid)
                if timing is not None and timing.t_admit is None:
                    timing.t_admit = now

    def _suspend(self, slot) -> None:
        """Preempt one active slot: park its device row(s) host-side (the
        engine's `_suspend_slot` gathers them and deactivates the device
        row), free the slot, and re-queue the request — its restored run
        is bitwise the uninterrupted one."""
        req = slot.request
        payload = self._suspend_slot(slot)
        self.parking.park(req.rid, payload, slot.data, req)
        self.slots.release(slot.index)
        self.scheduler.submit(req)
        self.n_preemptions += 1
        timing = self.request_log.get(req.rid)
        if timing is not None:
            timing.n_preempted += 1

    def _rounds_until_poll(self) -> int:
        lb = min(self._remaining_lb(s) for s in self.slots.active())
        return max(0, min(lb, self.sync_every))

    def _ctx(self):
        """Mesh context for every device call (constrain_batch resolves the
        ambient mesh); nullcontext single-device."""
        return self.mesh if self.mesh is not None else contextlib.nullcontext()

    # ---- engine hooks -------------------------------------------------------
    def _validate(self, req) -> None:
        raise NotImplementedError

    def _prepare(self, requests) -> None:
        """Batch-level hook before any request is queued: a place to size
        shared resources for the whole call at once (the diffusion engine
        registers every request's sampler config here, so the coefficient
        bank restacks/buckets once up front instead of growing — and
        recompiling warmed variants — wave by wave)."""

    def _admit_wave(self, group, free) -> None:
        raise NotImplementedError

    def _round(self) -> None:
        raise NotImplementedError

    def _poll(self, results, snap=None, lag: int = 0) -> int:
        """Observe device progress, retire finished slots into `results`.
        `snap` (from `_poll_snapshot`) is the done-mask snapshot the
        double-buffered online poll blocks on instead of the live state;
        `lag` is how many rounds were dispatched after that snapshot (the
        look-ahead), so shadow resyncs can stay exact."""
        raise NotImplementedError

    def _poll_snapshot(self):
        """Device snapshot of whatever `_poll` fetches, dispatched before
        the look-ahead round is enqueued (whose donation invalidates the
        live state's buffers).  None for engines whose poll is pure host
        arithmetic (diffusion: retirement is exactly predictable)."""
        return None

    def _suspend_slot(self, slot):
        """Gather slot `slot.index`'s device row(s) for parking and
        deactivate the device row; returns the device payload the
        `ParkingTable` will fetch host-side."""
        raise NotImplementedError

    def _resume_slot(self, request, shadow, payload, index: int) -> None:
        """Restore a parked payload into slot row `index`, bitwise."""
        raise NotImplementedError

    def _remaining_lb(self, slot) -> int:
        raise NotImplementedError

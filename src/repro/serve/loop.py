"""`ServeLoop`: the shared continuous-batching core behind both engines.

`TokenEngine` and `DiffusionEngine` used to duplicate the admit/round/retire
machinery and rebuild per-slot metadata in numpy every round, blocking on a
device fetch per step.  `ServeLoop` factors the skeleton out and inverts the
data flow: the per-slot state the step consumes lives on device in an
`EngineState` pytree (state.py), and the host keeps only a cheap *shadow* of
it in the `SlotTable` — enough to pace the loop, never shipped back to the
device.

The steady-state loop is::

    while pending or busy:
        _admit()                         # fill free slots (host -> device:
                                         #   prefill / prior scatter — the
                                         #   only h2d traffic, off the
                                         #   steady-state path)
        n = _rounds_until_poll()         # min over busy slots of a host-
                                         #   side lower bound on rounds
                                         #   until the next retirement,
                                         #   capped at sync_every (R)
        n x _round()                     # donated, device-resident steps;
                                         #   async dispatch, no sync
        _poll(results)                   # ONE small device fetch (token
                                         #   done/progress mask) or pure
                                         #   host arithmetic (diffusion,
                                         #   whose retirement round is
                                         #   exactly predictable), plus
                                         #   output fetches for retirees

so a round moves *no* per-slot metadata host->device (locked in by a
`jax.transfer_guard` test) and the host syncs at most once every
`sync_every` rounds.  For workloads whose progress is exactly predictable
(diffusion: a slot admitted at k=0 with NFE n retires after exactly n
rounds; token decode with eos disabled) the bound is tight and the loop
never runs a wasted round; an early eos retirement is simply observed at
the next poll.

Mesh awareness also lives here: constructed with a `Mesh`, the loop derives
the slot-batch shard count (for round-robin free-slot placement across
shards, see `SlotTable`) and runs every device call inside the mesh context
so in-model `constrain_batch` constraints resolve.  Engines place their
params / caches / state via the serve rules in `distributed.sharding`.
"""
from __future__ import annotations

import contextlib
from typing import Any, Dict, List, Optional

import numpy as np

from ..distributed import sharding as shd
from .slots import SlotTable

Mesh = Any


def check_unique_rids(requests) -> None:
    seen = set()
    for r in requests:
        if r.rid in seen:
            raise ValueError(f"duplicate request rid {r.rid}: results are "
                             "keyed by rid, a duplicate would be dropped")
        seen.add(r.rid)


def bucket_pow2(n: int, cap: int) -> int:
    """Smallest power of two >= n, capped at `cap` (prefill width buckets;
    same doubling policy as the coefficient-bank buckets)."""
    from ..core.coeffs import bucket_size
    return min(bucket_size(n, 1), cap)


class ServeLoop:
    """Continuous-batching skeleton.  Subclasses provide:

      _validate(req)            raise ValueError on a bad request
      _admit_wave(group, free)  prefill/scatter one admission wave into
                                device state (may consume `free` in order)
      _round()                  dispatch one jitted, donated round step
      _poll(results)            observe device progress, retire finished
                                slots into `results`, return retire count
      _remaining_lb(slot)       host-side lower bound on rounds until this
                                slot can retire (0 = may already be done)
    """

    #: greedy engines fill every free slot per admission cycle (token:
    #: waves are shape buckets, nothing is gained by spacing them out);
    #: non-greedy engines admit ONE head-of-line wave per cycle, so a
    #: queued wave of a more expensive cost class does not land next to
    #: the cheap wave just admitted (diffusion: a corrector render would
    #: drag predictor-only neighbours through the 2-eval program for
    #: their whole lifetime — admitted one poll cycle later, it only
    #: co-resides after a natural retire-and-refill)
    greedy_admit = True

    def __init__(self, batch_size: int, scheduler,
                 mesh: Optional[Mesh] = None,
                 shard_cfg: Optional[shd.ShardCfg] = None,
                 sync_every: int = 8):
        if sync_every < 1:
            raise ValueError(f"sync_every must be >= 1, got {sync_every}")
        self.batch_size = batch_size
        self.scheduler = scheduler
        self.mesh = mesh
        self.shard_cfg = shard_cfg if shard_cfg is not None else shd.ShardCfg()
        self.sync_every = sync_every
        n_shards = 1
        if mesh is not None:
            entry = shd.batch_axes_entry(mesh, self.shard_cfg, batch_size)
            axes = entry if isinstance(entry, tuple) else \
                (() if entry is None else (entry,))
            for a in axes:
                n_shards *= mesh.shape[a]
            want = 1
            for a in self.shard_cfg.present(mesh, self.shard_cfg.batch_axes):
                want *= mesh.shape[a]
            if want > 1 and n_shards == 1:
                raise ValueError(
                    f"batch_size {batch_size} is not divisible by any prefix "
                    f"of the mesh batch axes (sizes to {want}): the slot "
                    "batch would silently replicate instead of shard — "
                    "pick a divisible batch_size or a smaller data axis")
        self.n_shards = n_shards
        self.slots = SlotTable(batch_size, n_shards=n_shards)
        self.n_polls = 0

    # ---- public API ---------------------------------------------------------
    def serve(self, requests: List[Any]) -> Dict[int, np.ndarray]:
        check_unique_rids(requests)
        for r in requests:
            self._validate(r)
        self._prepare(requests)
        self.scheduler.submit_all(requests)
        results: Dict[int, np.ndarray] = {}
        while self.scheduler.has_pending() or self.slots.active_ids():
            self._admit()
            if not self.slots.active_ids():
                continue
            n = self._rounds_until_poll()
            for _ in range(n):
                self._round()
            retired = self._poll(results)
            self.n_polls += 1
            if n == 0 and not retired:
                # a zero lower bound that retires nothing would spin; the
                # engines' bounds make this unreachable (a slot at bound 0
                # is provably device-inactive), but a round is always safe
                self._round()                           # pragma: no cover
        return results

    # ---- shared loop pieces -------------------------------------------------
    def _admit(self) -> None:
        """Fill free slots from the queue in class-homogeneous waves (one
        `take_group` run each) — every wave for greedy engines, a single
        head-of-line wave per cycle otherwise (see `greedy_admit`)."""
        while True:
            free = self.slots.free_ids()
            group = self.scheduler.take_group(len(free))
            if not group:
                return
            self._admit_wave(group, free)
            if not self.greedy_admit:
                return

    def _rounds_until_poll(self) -> int:
        lb = min(self._remaining_lb(s) for s in self.slots.active())
        return max(0, min(lb, self.sync_every))

    def _ctx(self):
        """Mesh context for every device call (constrain_batch resolves the
        ambient mesh); nullcontext single-device."""
        return self.mesh if self.mesh is not None else contextlib.nullcontext()

    # ---- engine hooks -------------------------------------------------------
    def _validate(self, req) -> None:
        raise NotImplementedError

    def _prepare(self, requests) -> None:
        """Batch-level hook before any request is queued: a place to size
        shared resources for the whole call at once (the diffusion engine
        registers every request's sampler config here, so the coefficient
        bank restacks/buckets once up front instead of growing — and
        recompiling warmed variants — wave by wave)."""

    def _admit_wave(self, group, free) -> None:
        raise NotImplementedError

    def _round(self) -> None:
        raise NotImplementedError

    def _poll(self, results) -> int:
        raise NotImplementedError

    def _remaining_lb(self, slot) -> int:
        raise NotImplementedError

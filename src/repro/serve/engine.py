"""Continuous-batching engines: token decoding and gDDIM sampling.

Both engines are specializations of one `ServeLoop` core (loop.py): a fixed
device batch of `batch_size` slots, FIFO admission through a `Scheduler`,
host shadow bookkeeping in a `SlotTable`, and — since the `EngineState`
refactor — *device-resident* per-slot state updated inside a donated,
jitted round step (state.py, `make_token_round_step` /
`make_diffusion_round_step` in launch/steps.py).  What a "round" is differs:

  * `TokenEngine`  — one greedy decode token for every active slot.
    Admission runs a *batched* prefill through `make_prefill_step` (width-
    bucketed to the group's power-of-two size, so a 2-request wave on a
    16-slot engine pays 2 rows of FLOPs, not 16) and scatters the resulting
    cache rows slot-wise.  The round step decodes at the per-slot position
    `state.pos`, appends to the per-slot output ring, and retires on
    eos/budget — all on device.
  * `DiffusionEngine` — one gDDIM update for every active slot, each at its
    own step index k *and* its own sampler config (SDE family, NFE,
    multistep order q, corrector, stochasticity lambda); per-slot
    Psi/pC/cC/B/P_chol factor pairs are gathered from a stacked
    multi-family `FactoredBank` by (state.cfg[b], state.k[b]), slots live
    in the canonical packed (K, D) layout shared by every family, and a
    round dispatches one compiled variant per (family, corrector) class
    present in the batch.

Steady-state data flow: the round step consumes and returns the EngineState
(donated, so u/hist/caches update in place with no per-step copy) and the
host transfers *nothing* to the device per round — no slot metadata, no
token ids, no step indices.  The host polls a small done/progress mask at
most every `sync_every` rounds (exactly at the next possible retirement
when that is predictable) and fetches outputs only for retiring slots.
`tests/test_serve_engine.py` locks this in with a `jax.transfer_guard`.

Mesh mode: pass `mesh=` (e.g. `launch.mesh.make_local_mesh(data=2)`) and
the engine places params via the `distributed.sharding` param rules and the
slot batch — EngineState, caches, encoder memory — sharded over the `data`
axes (`serve_state_shardings` / `cache_shardings`).  Admission targets
free slots round-robin across shards.  Outputs are bitwise identical to
the single-device engine (per-row computation is row-independent), which
`tests/test_serve_mesh.py` asserts on a forced 2-device host.

Compile behaviour: after warmup the round programs are reused for every
round regardless of which slots retire or refill, and — for the diffusion
engine — regardless of which sampler configs the traffic mixes, because
the coefficient bank is a bucket-padded *argument* of the step
(`compile_stats()` exposes the jit cache sizes; the sampler step has at
most two entries, the predictor-only and with-corrector variants).
Prefill compiles once per (prompt length, width bucket) actually seen.

Determinism: slots are batch rows and every per-row computation in the
model stack is row-independent, so a request's output stream is bitwise
identical whether it runs alone or interleaved with arbitrary neighbours.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Any, Dict, List, Optional, Tuple

import numpy as np
import jax
import jax.numpy as jnp

from ..launch import steps as steps_lib
from ..models.registry import Arch
from ..models import quantize as qtz
from ..core import CoeffCache, SamplerConfig
from ..sde.base import family_name
from ..distributed import sharding as shd
from .loop import ServeLoop, bucket_pow2
from .parking import row_fetch, row_restore
from .api import ServeRequest
from .scheduler import Scheduler
from .state import (DiffusionState, TokenState, diffusion_state_init,
                    token_state_init)

Array = jax.Array


def _cache_size(jitted) -> int:
    try:
        return int(jitted._cache_size())
    except Exception:                                   # pragma: no cover
        return -1


def _make_row_scatter(batch_axes: List[int], out_shardings=None):
    """jitted (dst_tree, src_tree, slot_ids) -> dst_tree with src's batch
    rows written at `slot_ids`.  `slot_ids` is padded to the source batch
    size with an out-of-range sentinel; those rows are dropped, so one
    compilation serves every admission-wave width bucket.  The destination
    is donated: the scatter updates the engine cache in place.  In mesh
    mode `out_shardings` pins the result to the engine's canonical cache
    layout so the downstream round step never sees a second sharding."""

    def scatter(dst_tree, src_tree, slot_ids):
        dst_leaves, treedef = jax.tree.flatten(dst_tree)
        src_leaves, _ = jax.tree.flatten(src_tree)
        out = []
        for d, s, ax in zip(dst_leaves, src_leaves, batch_axes):
            dm = jnp.moveaxis(d, ax, 0)
            sm = jnp.moveaxis(s, ax, 0).astype(d.dtype)
            dm = dm.at[slot_ids].set(sm, mode="drop")
            out.append(jnp.moveaxis(dm, 0, ax))
        return jax.tree.unflatten(treedef, out)

    if out_shardings is None:
        return jax.jit(scatter, donate_argnums=(0,))
    return jax.jit(scatter, donate_argnums=(0,), out_shardings=out_shardings)


def _jit_state_update(fn, donate, out_shardings=None, **kw):
    """jit with the state donated and (mesh mode) the output pinned to the
    engine's canonical shardings — sharding stability is what keeps the
    round program's jit cache at one entry per variant."""
    if out_shardings is None:
        return jax.jit(fn, donate_argnums=donate, **kw)
    return jax.jit(fn, donate_argnums=donate, out_shardings=out_shardings,
                   **kw)


def _make_token_admit(out_shardings=None):
    """jitted admission scatter into a TokenState: writes the prefill token
    and per-slot counters for one wave.  Rows whose `slot_ids` carry the
    out-of-range sentinel are dropped.  A slot born done (budget 1, or the
    prefill token is already eos) starts inactive; the first poll retires
    it without a decode round.  The state is donated."""

    def admit(state, logits_last, slot_ids, budgets, pos, eos):
        first = jnp.argmax(logits_last, axis=-1).astype(jnp.int32)   # (G,)
        born_active = (budgets > 1) & (first != eos)
        return TokenState(
            last=state.last.at[slot_ids, 0].set(first, mode="drop"),
            pos=state.pos.at[slot_ids].set(pos, mode="drop"),
            n_out=state.n_out.at[slot_ids].set(1, mode="drop"),
            budget=state.budget.at[slot_ids].set(budgets, mode="drop"),
            out=state.out.at[slot_ids, 0].set(first, mode="drop"),
            active=state.active.at[slot_ids].set(born_active, mode="drop"))

    return _jit_state_update(admit, (0,), out_shardings)


def _make_row_gather(batch_axes: List[int]):
    """jitted (tree, i) -> row `i` of every leaf, each taken along its own
    batch axis (the cache twin of parking.row_fetch, whose leaves are all
    batch-leading).  `i` is a traced argument: one compiled gather serves
    every slot index, so repeated preemptions never recompile."""

    def gather(tree, i):
        leaves, treedef = jax.tree.flatten(tree)
        rows = [jnp.moveaxis(x, ax, 0)[i]
                for x, ax in zip(leaves, batch_axes)]
        return jax.tree.unflatten(treedef, rows)

    return jax.jit(gather)


def _make_deactivate(out_shardings=None):
    """jitted (state, i) -> state with slot `i`'s active flag cleared: a
    suspended slot's device row must stop advancing the moment its payload
    is parked (the frozen row is overwritten at re-admission either way —
    this just stops the round step from burning FLOPs on a parked row).
    The state is donated, like every state update."""

    def deactivate(state, i):
        return state._replace(active=state.active.at[i].set(False))

    return _jit_state_update(deactivate, (0,), out_shardings)


def _make_diffusion_admit(out_shardings=None):
    """jitted admission scatter into a DiffusionState: one slot row —
    packed prior sample, zeroed eps history, k=0, config index, family id,
    precision class, PRNG key.  The state is donated."""

    def admit(state, u_row, key_row, i, ci, fi, pi):
        return DiffusionState(
            u=state.u.at[i].set(u_row[0]),
            hist=state.hist.at[i].set(0.0),
            k=state.k.at[i].set(0),
            cfg=state.cfg.at[i].set(ci),
            fam=state.fam.at[i].set(fi),
            prec=state.prec.at[i].set(pi),
            keys=state.keys.at[i].set(key_row),
            active=state.active.at[i].set(True))

    return _jit_state_update(admit, (0,), out_shardings)


# ===========================================================================
# Token decoding
# ===========================================================================
class TokenEngine(ServeLoop):
    """Continuous-batching greedy decode over any `Arch` family.

    Usage:
        engine = TokenEngine(arch, params, batch_size=8, max_len=256)
        results = engine.serve([ServeRequest(rid=0, workload="token",
                                   tokens=prompt, max_new=32), ...])
        # results[rid] -> np.ndarray of generated token ids

    The engine is persistent: repeated `serve()` calls reuse the allocated
    cache and the compiled steps (retire-and-refill, no recompilation).
    Pass `mesh=` to shard the slot batch over the mesh's data axes (see the
    module docstring).
    """

    def __init__(self, arch: Arch, params: Any, batch_size: int, max_len: int,
                 eos_id: int = 1, mesh: Any = None,
                 shard_cfg: Optional[shd.ShardCfg] = None,
                 sync_every: int = 8):
        super().__init__(batch_size,
                         Scheduler(group_key=lambda r: r.prompt_len),
                         mesh=mesh, shard_cfg=shard_cfg,
                         sync_every=sync_every)
        self.arch = arch
        self.max_len = max_len

        caches = arch.init_cache(batch_size, max_len)
        axes_tree = arch.cache_batch_axes(max_len)
        state = token_state_init(batch_size, max_len)
        memory = None
        if arch.spec.family == "encdec":
            ctx, d = arch.spec.frontend_ctx, arch.cfg.d_model
            memory = jnp.zeros((batch_size, ctx, d), jnp.float32)

        caches_sh = state_sh = memory_sh = None
        if mesh is not None:
            scfg = self.shard_cfg
            params = jax.device_put(params,
                                    shd.param_shardings(params, mesh, scfg))
            caches_sh = shd.cache_shardings(
                caches, axes_tree, mesh, scfg, batch_size,
                getattr(arch.cfg, "n_kv_heads", 0),
                getattr(arch.cfg, "d_head", -1))
            caches = jax.device_put(caches, caches_sh)
            state_sh = shd.serve_state_shardings(state, mesh, scfg)
            state = jax.device_put(state, state_sh)
            if memory is not None:
                memory_sh = shd.logical_to_sharding(
                    mesh, shd.batch_spec(mesh, scfg, memory.ndim, batch_size))
                memory = jax.device_put(memory, memory_sh)
        self.params = params
        self.caches = caches
        self.state = state
        self.memory = memory

        self._merge = _make_row_scatter(jax.tree.leaves(axes_tree),
                                        out_shardings=caches_sh)
        self._admit_state = _make_token_admit(out_shardings=state_sh)
        self._cache_axes = jax.tree.leaves(axes_tree)
        # preemption machinery (serve_stream): gather a slot's state +
        # cache rows for parking, deactivate the parked device row, and
        # restore the parked bits into a free row on resume.  All take the
        # slot index as a traced argument — one compile each, warmed by
        # the first preemption
        self._fetch_row = jax.jit(row_fetch)
        self._fetch_cache_row = _make_row_gather(self._cache_axes)
        self._deactivate = _make_deactivate(out_shardings=state_sh)
        self._restore = _jit_state_update(row_restore, (0,), state_sh)
        self._snapshot = jax.jit(steps_lib.make_mask_snapshot())
        # the round step is donated on (state, caches): in-place at the XLA
        # level, no per-step copy of the KV/recurrent cache.  Output
        # shardings are pinned in mesh mode so retire-and-refill cycles
        # keep one compiled program
        self._decode = _jit_state_update(
            steps_lib.make_token_round_step(arch), (1, 2),
            None if mesh is None else (state_sh, caches_sh))
        self._prefill = jax.jit(steps_lib.make_prefill_step(arch, max_len))
        self._encode = None
        if arch.spec.family == "encdec":
            self._encode = jax.jit(arch.encode_memory)
            self._merge_memory = _make_row_scatter([0],
                                                   out_shardings=memory_sh)

        self.eos_id = eos_id

        # throughput counters (benchmarks read these)
        self.n_decode_steps = 0
        self.n_prefill_calls = 0
        self.n_tokens_out = 0
        # recent admission-wave widths (bounded: the engine is persistent)
        self.prefill_widths: deque = deque(maxlen=256)

    # eos is a *device* scalar argument of the round step (not a closure
    # constant), so changing it never recompiles and never transfers
    # per-round; the setter keeps the device copy in sync
    @property
    def eos_id(self) -> int:
        return self._eos_id

    @eos_id.setter
    def eos_id(self, v: int) -> None:
        self._eos_id = int(v)
        eos = jnp.int32(v)
        if self.mesh is not None:
            eos = jax.device_put(eos, shd.replicated(self.mesh))
        self._eos = eos

    def compile_stats(self) -> Dict[str, int]:
        stats = {"decode": _cache_size(self._decode),
                 "prefill": _cache_size(self._prefill),
                 "merge": _cache_size(self._merge),
                 "park": _cache_size(self._fetch_row)
                 + _cache_size(self._fetch_cache_row)
                 + _cache_size(self._deactivate),
                 "resume": _cache_size(self._restore),
                 "snapshot": _cache_size(self._snapshot)}
        if self._encode is not None:
            stats["encode"] = _cache_size(self._encode)
        return stats

    # ---- ServeLoop hooks ----------------------------------------------------
    def _validate(self, r: ServeRequest) -> None:
        if r.prompt_len < 1:
            raise ValueError(f"request {r.rid}: empty prompt")
        if r.max_new < 1:
            raise ValueError(f"request {r.rid}: max_new must be >= 1 "
                             f"(got {r.max_new})")
        if r.prompt_len + r.max_new > self.max_len:
            raise ValueError(
                f"request {r.rid}: prompt_len {r.prompt_len} + max_new "
                f"{r.max_new} exceeds max_len {self.max_len}")
        if self._encode is not None and r.frames is None:
            raise ValueError(f"request {r.rid}: encdec arch needs frames")

    def _admit_wave(self, group: List[ServeRequest], free: List[int]) -> None:
        # prefill width-bucketed to the group's power-of-two size: a small
        # admission wave no longer pays full-batch prefill FLOPs
        L = group[0].prompt_len
        G = bucket_pow2(len(group), self.batch_size)
        toks = np.zeros((G, L), np.int32)
        for g, req in enumerate(group):
            toks[g] = req.tokens
        batch = {"tokens": jnp.asarray(toks)}
        mem_g = None
        if self._encode is not None:
            shape = (G,) + self.memory.shape[1:]
            frames = np.zeros(shape, np.float32)
            for g, req in enumerate(group):
                frames[g] = req.frames
            with self._ctx():
                mem_g = self._encode(self.params, jnp.asarray(frames))
            batch["memory"] = mem_g

        with self._ctx():
            logits_last, caches_g = self._prefill(self.params, batch)
        self.n_prefill_calls += 1
        self.prefill_widths.append(G)

        # slot-wise scatter: row g of the wave -> free[g]; padded rows carry
        # the batch-size sentinel and are dropped (never touch a live slot)
        slot_ids = np.full((G,), self.batch_size, np.int32)
        budgets = np.ones((G,), np.int32)
        for g, req in enumerate(group):
            slot_ids[g] = free[g]
            budgets[g] = req.max_new
        ids = jnp.asarray(slot_ids)
        with self._ctx():
            self.caches = self._merge(self.caches, caches_g, ids)
            if mem_g is not None:
                self.memory = self._merge_memory(self.memory, mem_g, ids)
            self.state = self._admit_state(
                self.state, logits_last, ids, jnp.asarray(budgets),
                jnp.full((G,), L, jnp.int32), self._eos)
        for g, req in enumerate(group):
            # host shadow: n_out paces polls (it may overshoot the device
            # count after an early eos — resynced at every poll, and an
            # overshoot only makes the next poll earlier, never later)
            self.slots.assign(free[g], req, n_out=1, budget=req.max_new)

    def _round(self) -> None:
        with self._ctx():
            self.state, self.caches = self._decode(
                self.params, self.state, self.caches, self._eos, self.memory)
        self.n_decode_steps += 1
        for s in self.slots.active():
            s.data["n_out"] += 1

    def _poll(self, results: Dict[int, np.ndarray], snap=None,
              lag: int = 0) -> int:
        busy = self.slots.active()
        if not busy:
            return 0
        if snap is None:
            snap = (self.state.active, self.state.n_out)
        # the one steady-state device fetch: the done/progress mask (in
        # the double-buffered online poll, a snapshot taken before the
        # look-ahead round — blocking here overlaps that round's compute)
        active, n_out = jax.device_get(snap)  # staticcheck: disable=SC103 (the one sanctioned steady-state fetch: done/progress mask, once per poll)
        finished = [s for s in busy if not active[s.index]]
        if finished:
            # retired rows are frozen, so reading the *live* out buffer is
            # exact even with a look-ahead round in flight
            out = jax.device_get(self.state.out)  # staticcheck: disable=SC103 (terminal drain: runs only when a request finished, not steady-state)
            for s in finished:
                n = int(n_out[s.index])
                results[s.request.rid] = out[s.index, :n].astype(np.int32)
                self.n_tokens_out += n
                self.slots.release(s.index)
        for s in self.slots.active():
            # resync the shadow from the snapshot, plus the rounds
            # dispatched after it (`lag`: the online look-ahead)
            s.data["n_out"] = int(n_out[s.index]) + lag
        return len(finished)

    def _poll_snapshot(self):
        with self._ctx():
            return self._snapshot(self.state.active, self.state.n_out)

    def _suspend_slot(self, slot):
        i = np.int32(slot.index)
        with self._ctx():
            state_row = self._fetch_row(self.state, i)
            cache_row = self._fetch_cache_row(self.caches, i)
            mem_row = None if self.memory is None \
                else self._fetch_row(self.memory, i)
            self.state = self._deactivate(self.state, i)
        return (state_row, cache_row, mem_row)

    def _resume_slot(self, request: ServeRequest, shadow: dict, payload,
                     index: int) -> None:
        state_row, cache_row, mem_row = payload
        ids = jnp.asarray([index], np.int32)
        # the cache scatter expects source rows in the caches' own layout
        # (batch axis in place, size 1) — the merge is the same program
        # width-1 admission waves warm
        leaves, treedef = jax.tree.flatten(cache_row)
        src = jax.tree.unflatten(treedef, [
            np.expand_dims(x, ax)
            for x, ax in zip(leaves, self._cache_axes)])
        with self._ctx():
            self.caches = self._merge(self.caches, src, ids)
            if mem_row is not None:
                self.memory = self._merge_memory(
                    self.memory, mem_row[None], ids)
            self.state = self._restore(self.state, state_row,
                                       np.int32(index))

    def _remaining_lb(self, slot) -> int:
        return slot.data["budget"] - slot.data["n_out"]


# ===========================================================================
# gDDIM sampling service
# ===========================================================================
class DiffusionEngine(ServeLoop):
    """Continuous-batching gDDIM sampling over a *heterogeneous* sampler
    family: slots are samples, the per-slot position is the sampler step
    index k, and every slot additionally carries its own sampler config —
    SDE family, NFE budget, multistep order q, Eq. 45 corrector toggle,
    and Eq. 22 stochasticity lambda.  One resident engine, a handful of
    compiled step variants, many scenarios: a 10-NFE VPSDE preview batches
    with a 50-NFE CLD predictor-corrector render and a BDM sample.

    Usage (single family — the historical surface):
        engine = DiffusionEngine(spec, params, batch_size=16, nfe=50)
        results = engine.serve([
            SampleRequest(rid=0, seed=0),                    # engine default
            SampleRequest(rid=1, seed=1, nfe=10),            # fast preview
            SampleRequest(rid=2, seed=2, nfe=50, q=2, corrector=True),
            SampleRequest(rid=3, seed=3, nfe=20, lam=0.5),   # stochastic
        ])
        # results[rid] -> np.ndarray sample in data space

    Multi-family: pass ordered mappings `{family_name: spec}` /
    `{family_name: params}` (names per `repro.sde.base.family_name`; the
    first entry is the default family) and requests pick their family:

        engine = DiffusionEngine({"vpsde": spec_v, "cld": spec_c,
                                  "bdm": spec_b},
                                 {"vpsde": pv, "cld": pc, "bdm": pb},
                                 batch_size=16, nfe=20)
        engine.serve([SampleRequest(rid=0, seed=0),              # vpsde
                      SampleRequest(rid=1, seed=1, family="cld"),
                      SampleRequest(rid=2, seed=2, family="bdm", nfe=10)])

    All families must share one `data_shape`; every slot lives in the
    canonical packed (K, D) layout of `kernels/ei_update/ops.py`
    (K = max family channel width: VPSDE/BDM 1, CLD 2; BDM slots hold DCT
    coefficients and ride the dct2 kernel path), so one slot pool, one
    mesh, one `DiffusionState` serve the whole mix.  Each family's
    score-net params are placed on device once at construction and stay
    resident; a serving round dispatches one jitted round-step variant per
    (family, corrector) cost class *present among active slots* — each
    variant evaluates its family's score net over the packed batch and
    commits updates only to its own slots — so homogeneous traffic pays
    exactly the single-family cost and a mixed batch pays one model
    evaluation per resident family per round.

    Coefficients come from a host-side `CoeffCache` (Stage-I quadrature run
    once per distinct config) whose stacked multi-family `FactoredBank` —
    (K, K) block factors plus a deduplicated (D,) diagonal pool, ~D-fold
    smaller device-resident than the dense layout it replaced — is padded
    to bucketed shapes and passed to the jitted step as an argument, so
    admitting a config the engine has never seen refreshes the bank
    *contents* without recompiling, as long as the new config fits the
    warmed buckets (`FactoredBank.shape_key`; a bucket overflow — incl.
    the diag pool's, which only first-seen BDM-family configs can grow —
    costs one recompile, then the doubled bucket absorbs further growth;
    registration appends factored rows instead of restacking the bank).
    The
    corrector needs a second model evaluation per step, so each family has
    two jit variants (static `with_corrector`); each round dispatches per
    family on whether any of *its* active slots wants the corrector —
    known host-side from the admission shadow, so dispatch costs no device
    fetch.  The scheduler keeps admission waves homogeneous in the
    (family, corrector) cost class, which biases runs of same-class
    traffic into sharing rounds — it cannot prevent classes from
    co-residing after retire-and-refill, so a VPSDE slot admitted next to
    a mid-flight CLD render shares its rounds with both models' dispatches
    (correct, just not cheaper) until the render retires.

    A sampler slot's retirement round is *exactly* predictable (a slot
    admitted at k=0 with NFE n retires after n rounds), so the loop's
    host shadow paces polls with zero device fetches for metadata; the only
    device->host traffic is the finished sample itself.

    Samples are a pure function of (request seed, sampler config): the
    stochastic branch keys its per-step noise by fold_in(seed-derived key,
    k), so admission order and neighbouring slots — whatever their family —
    cannot change a result (per-row independence plus static per-family
    sub-block arithmetic, locked in bitwise by tests/test_serve_engine.py).
    """

    _NOISE_SALT = 0x5EED              # separates step noise from the prior
    greedy_admit = False              # one cost-class wave per admission
                                      # cycle (see ServeLoop.greedy_admit)

    def __init__(self, spec: Any, params: Any, batch_size: int,
                 nfe: Optional[int] = None, grid: Optional[str] = None,
                 default_config: Optional[SamplerConfig] = None,
                 precision: str = "f32",
                 mesh: Any = None,
                 shard_cfg: Optional[shd.ShardCfg] = None,
                 sync_every: int = 8):
        if isinstance(spec, dict):
            specs = dict(spec)
            if not isinstance(params, dict) or set(params) != set(specs):
                raise ValueError("multi-family DiffusionEngine needs params "
                                 "as a dict with the same family names as "
                                 "spec")
            params = {n: params[n] for n in specs}     # align orders
        else:
            name = family_name(spec.sde)
            specs, params = {name: spec}, {name: params}
        shapes = {n: tuple(s.data_shape) for n, s in specs.items()}
        if len(set(shapes.values())) != 1:
            raise ValueError("all families of one engine must share a "
                             f"data_shape; got {shapes}")

        if default_config is None:
            default_config = SamplerConfig(
                nfe=20 if nfe is None else nfe,
                grid="quadratic" if grid is None else grid)
        elif nfe is not None or grid is not None:
            raise ValueError("pass either nfe/grid or default_config, "
                             "not both")
        self.default_config = default_config
        self.nfe = default_config.nfe
        super().__init__(
            batch_size,
            Scheduler(group_key=lambda r: self._class_of(r)),
            mesh=mesh, shard_cfg=shard_cfg, sync_every=sync_every)
        self.specs = specs
        self.spec = next(iter(specs.values()))         # default family spec
        self._data_shape = next(iter(shapes.values()))

        self.cache = CoeffCache({n: s.sde for n, s in specs.items()},
                                kt={n: s.kt for n, s in specs.items()},
                                data_shape=self._data_shape)
        if default_config.family is not None \
                and default_config.family not in specs:
            raise ValueError(f"default_config.family "
                             f"{default_config.family!r} is not resident; "
                             f"families: {list(specs)}")
        if default_config.family is None:
            default_config = dataclasses.replace(
                default_config, family=self.cache.default_family)
            self.default_config = default_config
        self.cache.index_of(default_config)
        # single-config Stage-I bank of the default config (reference /
        # introspection surface; the serve loop reads the stacked bank)
        self.coeffs = self.cache.get(default_config)

        k_max = self.cache.k_max
        data_dim = int(np.prod(self._data_shape))
        state = diffusion_state_init(batch_size, k_max, data_dim,
                                     self.cache.factored_bank.pC_blk.shape[2])
        state_sh = None
        if mesh is not None:
            params = {n: jax.device_put(
                p, shd.param_shardings(p, mesh, self.shard_cfg))
                for n, p in params.items()}
            state_sh = shd.serve_state_shardings(state, mesh, self.shard_cfg)
            state = jax.device_put(state, state_sh)
        self.params = params
        self.state = state
        self._state_sh = state_sh       # NamedShardings are shape-free:
                                        # still valid after hist regrowth
        self._bank_src = None
        self._bank = None
        self._refresh_bank()

        # low-precision serving: requests pick a score-net precision class
        # (engine default `precision`); each class keeps its own lazily-
        # quantized device-resident copy of the family's params
        # (models/quantize — bf16 cast / int8 QTensor residency) and its
        # own compiled round variants, masked per-slot by `state.prec`
        self.precision = qtz.check_precision(precision)
        self._params_prec: Dict[Any, Any] = {
            (n, "f32"): p for n, p in params.items()}

        # one round-step program per (family, precision) class (x2
        # with_corrector variants), donated on the state: u/hist update in
        # place.  The family index and precision class baked into each
        # variant are the closure constants that keep the steady-state
        # round transfer-free; unused precision classes never trace, so
        # they cost nothing until traffic asks for them
        self._steps = {
            (n, prec): _jit_state_update(
                steps_lib.make_diffusion_round_step(
                    s, fam_index=self.cache.fam_index(n),
                    prec_index=pi,
                    eps_model=qtz.wrap_eps_model(s.eps_model, prec)),
                (1,), state_sh, static_argnames=("with_corrector",))
            for n, s in specs.items()
            for pi, prec in enumerate(qtz.PRECISIONS)}
        self._admit_state = _make_diffusion_admit(out_shardings=state_sh)
        # preemption machinery (serve_stream): every DiffusionState leaf is
        # batch-leading, so the generic parking row fetch/restore covers the
        # whole per-slot row (u, hist, k, cfg, fam, keys, active) — a
        # resumed slot continues mid-trajectory, mid-multistep-history, on
        # exactly the bits it was suspended with
        self._fetch_row = jax.jit(row_fetch)
        self._deactivate = _make_deactivate(out_shardings=state_sh)
        self._restore = _jit_state_update(row_restore, (0,), state_sh)

        def make_prior(s):
            from ..kernels.ei_update.ops import pad_channels
            sde, dshape = s.sde, tuple(s.data_shape)
            kf = sde.packed_k

            def prior(key):                       # (1, K, D) packed row
                u = sde.canonicalize(sde.prior_sample(key, 1, dshape))
                return pad_channels(u, k_max)

            def project(u, i):                    # packed row -> data space
                return sde.project_data(
                    sde.decanonicalize(u[i][None, :kf], dshape))[0]

            return jax.jit(prior), jax.jit(project)

        self._prior1, self._project_row = {}, {}
        for n, s in specs.items():
            self._prior1[n], self._project_row[n] = make_prior(s)

        self.n_steps = 0                # step-program dispatches
        self.n_rounds = 0               # serving rounds (>= 1 dispatch each)
        self.n_samples_out = 0

    @property
    def families(self) -> List[str]:
        return list(self.specs)

    def compile_stats(self) -> Dict[str, int]:
        # step counts every (family, corrector) jit variant; after warmup
        # it stays put across any traffic mix whose configs fit the warmed
        # coefficient buckets
        return {"step": sum(_cache_size(s) for s in self._steps.values()),
                "prior": sum(_cache_size(p) for p in self._prior1.values()),
                "park": _cache_size(self._fetch_row)
                + _cache_size(self._deactivate),
                "resume": _cache_size(self._restore)}

    def config_of(self, req: ServeRequest) -> SamplerConfig:
        d = self.default_config
        pick = lambda v, dv: dv if v is None else v
        fam = pick(req.family, pick(d.family, self.cache.default_family))
        if fam not in self.specs:
            raise ValueError(f"unknown SDE family {fam!r}; resident "
                             f"families: {list(self.specs)}")
        return SamplerConfig(
            nfe=pick(req.nfe, d.nfe), q=pick(req.q, d.q),
            corrector=pick(req.corrector, d.corrector),
            lam=pick(req.lam, d.lam), grid=pick(req.grid, d.grid),
            family=fam, algorithm=pick(req.algorithm, d.algorithm))

    def precision_of(self, req: ServeRequest) -> str:
        """The request's score-net precision class (engine default when
        unset) — never part of the SamplerConfig: coefficients stay f32
        and bitwise at every precision (models/quantize docstring)."""
        return qtz.check_precision(
            self.precision if req.precision is None else req.precision)

    def _class_of(self, req: ServeRequest):
        """The admission-wave cost class: (family, corrector, precision)."""
        cfg = self.config_of(req)
        return (cfg.family, cfg.corrector, self.precision_of(req))

    def _params_for(self, fam: str, prec: str):
        """This (family, precision) class's device-resident params —
        quantized from the placed f32 copy on first use, then cached
        (resident next to the f32 copy; the round program reads the
        low-precision buffers directly)."""
        key = (fam, prec)
        if key not in self._params_prec:
            self._params_prec[key] = qtz.quantize_tree(self.params[fam],
                                                       prec)
        return self._params_prec[key]

    # ---- coefficient-bank placement ----------------------------------------
    def _refresh_bank(self) -> None:
        """Re-place the factored bank on device when the CoeffCache grew it
        (a new config appended rows / pool entries), and grow the state's
        eps-history bucket if the bank's Qb bucket grew (one-time warmup
        shape change)."""
        bank = self.cache.factored_bank
        if bank is self._bank_src:
            return
        self._bank_src = bank
        if self.mesh is not None:
            bank = jax.device_put(
                bank, shd.bank_shardings(self.mesh, self.shard_cfg, bank))
        self._bank = bank
        qb = bank.pC_blk.shape[2]
        hist = self.state.hist
        if hist.shape[1] < qb:
            pad = jnp.zeros((self.batch_size, qb - hist.shape[1])
                            + hist.shape[2:], jnp.float32)
            hist = jnp.concatenate([hist, pad], axis=1)
            if self._state_sh is not None:
                hist = jax.device_put(hist, self._state_sh.hist)
            self.state = self.state._replace(hist=hist)

    # ---- ServeLoop hooks ----------------------------------------------------
    def _validate(self, r: ServeRequest) -> None:
        try:
            self.config_of(r)           # fail fast, before any device work
            self.precision_of(r)
        except ValueError as e:
            raise ValueError(f"request {r.rid}: {e}") from None

    def _prepare(self, requests: List[ServeRequest]) -> None:
        """Register every request's config before anything is admitted, so
        the bank restacks (and, if the call introduces a bucket overflow,
        re-buckets) exactly once up front — a warmup call that covers the
        deployment's config menu then compiles every (family, corrector)
        variant at the final bank shapes, and later traffic inside those
        buckets never recompiles."""
        for r in requests:
            self.cache.index_of(self.config_of(r))

    def _admit_wave(self, group: List[ServeRequest], free: List[int]) -> None:
        # register the whole wave's configs before touching the bank, so it
        # restacks at most once per wave (not once per new config; mid-call
        # this is a no-op after `_prepare`, but direct scheduler submits —
        # tests, streaming admission — still land here first)
        cfgs = [self.config_of(req) for req in group]
        idx = [self.cache.index_of(cfg) for cfg in cfgs]
        self._refresh_bank()
        for req, cfg, ci in zip(group, cfgs, idx):
            i = free.pop(0)
            fi = self.cache.fam_index(cfg.family)
            prec = self.precision_of(req)
            pi = qtz.prec_index(prec)
            base = jax.random.PRNGKey(req.seed)
            with self._ctx():
                row = self._prior1[cfg.family](base)
                key_row = jax.random.fold_in(base, self._NOISE_SALT)
                self.state = self._admit_state(self.state, row, key_row,
                                               np.int32(i), np.int32(ci),
                                               np.int32(fi), np.int32(pi))
            self.slots.assign(i, req, k=0, cfg=ci, nfe=cfg.nfe,
                              family=cfg.family, pc=cfg.corrector, prec=prec)

    def _round(self) -> None:
        # dispatch one variant per (family, precision, corrector) class
        # present among active slots — a host-shadow read, no device fetch.
        # Iteration follows (family registration order) x (PRECISIONS
        # order) so a round's dispatch sequence is deterministic
        want: Dict[Tuple[str, str], bool] = {}
        for s in self.slots.active():
            cls = (s.data["family"], s.data["prec"])
            want[cls] = want.get(cls, False) or s.data["pc"]
        for fam in self.families:
            for prec in qtz.PRECISIONS:
                cls = (fam, prec)
                if cls not in want:
                    continue
                with self._ctx():
                    self.state = self._steps[cls](
                        self._params_for(fam, prec), self.state, self._bank,
                        with_corrector=want[cls])
                self.n_steps += 1
        self.n_rounds += 1
        for s in self.slots.active():
            s.data["k"] += 1

    def _poll(self, results: Dict[int, np.ndarray], snap=None,
              lag: int = 0) -> int:
        # retirement is exactly predictable from the host shadow (k reaches
        # the config's NFE after exactly NFE rounds): no device fetch at
        # all for metadata, only the finished samples themselves.  There is
        # no device mask to snapshot, so the online poll's observation
        # point is reconstructed by discounting `lag` (rounds dispatched
        # after it — the look-ahead): a slot that finishes *inside* the
        # look-ahead round retires at the next poll, exactly like the
        # token engine's snapshot semantics, so its completion stamp never
        # predates the round that produced it.  The round step freezes a
        # finished row on device (active = k < nfe), so reading the live
        # `state.u` under a look-ahead round in flight is bitwise exact
        done = [s for s in self.slots.active()
                if s.data["k"] - lag >= s.data["nfe"]]
        for s in done:
            with self._ctx():
                row = self._project_row[s.data["family"]](self.state.u,
                                                          s.index)
            results[s.request.rid] = np.asarray(row)  # staticcheck: disable=SC103 (terminal result materialization at slot release, not steady-state)
            self.n_samples_out += 1
            self.slots.release(s.index)
        return len(done)

    def _suspend_slot(self, slot):
        i = np.int32(slot.index)
        with self._ctx():
            row = self._fetch_row(self.state, i)    # before the deactivate:
            self.state = self._deactivate(self.state, i)  # parked active=True
        return row

    def _resume_slot(self, request: ServeRequest, shadow: dict, payload,
                     index: int) -> None:
        qb = self.state.hist.shape[1]
        hist = payload.hist
        if hist.shape[0] < qb:
            # the bank's Qb bucket grew while the row was parked (a first-
            # seen higher-q config arrived): pad with zeros — exactly what
            # `_refresh_bank` padded every *resident* row with, so resumed
            # == never-suspended, bitwise, across the regrowth
            pad = np.zeros((qb - hist.shape[0],) + hist.shape[1:],
                           hist.dtype)
            payload = payload._replace(
                hist=np.concatenate([hist, pad], axis=0))
        with self._ctx():
            self.state = self._restore(self.state, payload, np.int32(index))

    def _remaining_lb(self, slot) -> int:
        return slot.data["nfe"] - slot.data["k"]

"""Continuous-batching engines: token decoding and gDDIM sampling.

Both engines share the same discipline (one pre-allocated device batch of
`batch_size` slots, FIFO admission through a `Scheduler`, per-slot progress
tracked in a `SlotTable`, retire-and-refill without recompilation) and
differ only in what a "step" is:

  * `TokenEngine`  — a step is one greedy decode token for every active
    slot.  Admission runs a *batched* prefill through `make_prefill_step`
    (one forward over the whole admitted group — not token-at-a-time
    through the decode step) and scatters the resulting cache rows
    slot-wise into the engine cache, so prefilling one slot can never
    touch another slot's KV rows.  Decode passes the per-slot position
    vector `cache_len[b]` to the model: a freshly refilled slot decodes at
    its own absolute position while its neighbours continue at theirs.

  * `DiffusionEngine` — a step is one gDDIM update
    (`make_diffusion_serve_step` in bank mode) for every active slot, each
    at its own step index k *and* its own sampler config (NFE, multistep
    order q, corrector, stochasticity lambda); per-slot Psi/pC/cC/B/P_chol
    rows are gathered from a stacked `CoeffBank` by (cfg[b], k[b]) and
    applied through `sde.apply_batched`.  A sampling request admitted
    mid-flight starts at k=0 next to slots at k>0, and a 10-NFE preview
    batches with a 50-NFE predictor-corrector render — continuous batching
    for diffusion sampling across gDDIM's whole sampler family.

Compile behaviour: after warmup the decode/sampler step programs are
reused for every round regardless of which slots retire or refill, and —
for the diffusion engine — regardless of which sampler configs the traffic
mixes, because the coefficient bank is a bucket-padded *argument* of the
step (`compile_stats()` exposes the jit cache sizes so tests can assert
this; the sampler step has at most two entries, the predictor-only and
with-corrector variants).  Prefill compiles once per distinct prompt
length actually seen — the
scheduler's head-of-line grouping keeps groups single-shape, which is also
a *correctness* requirement for the recurrent-state archs (right-padding a
prompt would corrupt RWKV/Mamba state; KV caches merely mask it).

Determinism: slots are batch rows and every per-row computation in the
model stack is row-independent, so a request's output stream is bitwise
identical whether it runs alone or interleaved with arbitrary neighbours
(tests/test_serve_engine.py locks this in for a KV-cache arch, a
recurrent-state arch, and the diffusion service).
"""
from __future__ import annotations

import functools
from typing import Any, Dict, List, Optional

import numpy as np
import jax
import jax.numpy as jnp

from ..launch import steps as steps_lib
from ..models.registry import Arch
from ..core import CoeffCache, SamplerConfig
from .scheduler import Request, SampleRequest, Scheduler
from .slots import SlotTable

Array = jax.Array


def _cache_size(jitted) -> int:
    try:
        return int(jitted._cache_size())
    except Exception:                                   # pragma: no cover
        return -1


def _check_unique_rids(requests) -> None:
    seen = set()
    for r in requests:
        if r.rid in seen:
            raise ValueError(f"duplicate request rid {r.rid}: results are "
                             "keyed by rid, a duplicate would be dropped")
        seen.add(r.rid)


def _make_row_scatter(batch_axes: List[int]):
    """jitted (dst_tree, src_tree, slot_ids) -> dst_tree with src's batch
    rows written at `slot_ids`.  `slot_ids` is padded to the source batch
    size with an out-of-range sentinel; those rows are dropped, so one
    compilation serves every admission group size."""

    def scatter(dst_tree, src_tree, slot_ids):
        dst_leaves, treedef = jax.tree.flatten(dst_tree)
        src_leaves, _ = jax.tree.flatten(src_tree)
        out = []
        for d, s, ax in zip(dst_leaves, src_leaves, batch_axes):
            dm = jnp.moveaxis(d, ax, 0)
            sm = jnp.moveaxis(s, ax, 0).astype(d.dtype)
            dm = dm.at[slot_ids].set(sm, mode="drop")
            out.append(jnp.moveaxis(dm, 0, ax))
        return jax.tree.unflatten(treedef, out)

    return jax.jit(scatter)


# ===========================================================================
# Token decoding
# ===========================================================================
class TokenEngine:
    """Continuous-batching greedy decode over any `Arch` family.

    Usage:
        engine = TokenEngine(arch, params, batch_size=8, max_len=256)
        results = engine.serve([Request(rid=0, tokens=prompt, max_new=32), ...])
        # results[rid] -> np.ndarray of generated token ids

    The engine is persistent: repeated `serve()` calls reuse the allocated
    cache and the compiled steps (retire-and-refill, no recompilation).
    """

    def __init__(self, arch: Arch, params: Any, batch_size: int, max_len: int,
                 eos_id: int = 1):
        self.arch = arch
        self.params = params
        self.batch_size = batch_size
        self.max_len = max_len
        self.eos_id = eos_id

        self.slots = SlotTable(batch_size)
        self.scheduler = Scheduler(group_key=lambda r: r.prompt_len)

        self.caches = arch.init_cache(batch_size, max_len)
        axes_tree = arch.cache_batch_axes(max_len)
        self._merge = _make_row_scatter(jax.tree.leaves(axes_tree))

        self._decode = jax.jit(steps_lib.make_serve_step(arch))
        self._prefill = jax.jit(steps_lib.make_prefill_step(arch, max_len))

        self.memory: Optional[Array] = None
        self._encode = None
        if arch.spec.family == "encdec":
            ctx, d = arch.spec.frontend_ctx, arch.cfg.d_model
            self.memory = jnp.zeros((batch_size, ctx, d), jnp.float32)
            self._encode = jax.jit(arch.encode_memory)
            self._merge_memory = _make_row_scatter([0])

        # throughput counters (benchmarks read these)
        self.n_decode_steps = 0
        self.n_prefill_calls = 0
        self.n_tokens_out = 0

    # ---- public API ---------------------------------------------------------
    def serve(self, requests: List[Request]) -> Dict[int, np.ndarray]:
        _check_unique_rids(requests)
        for r in requests:
            if r.prompt_len < 1:
                raise ValueError(f"request {r.rid}: empty prompt")
            if r.max_new < 1:
                raise ValueError(f"request {r.rid}: max_new must be >= 1 "
                                 f"(got {r.max_new})")
            if r.prompt_len + r.max_new > self.max_len:
                raise ValueError(
                    f"request {r.rid}: prompt_len {r.prompt_len} + max_new "
                    f"{r.max_new} exceeds max_len {self.max_len}")
            if self._encode is not None and r.frames is None:
                raise ValueError(f"request {r.rid}: encdec arch needs frames")
        self.scheduler.submit_all(requests)
        results: Dict[int, np.ndarray] = {}
        while self.scheduler.has_pending() or self.slots.active_ids():
            self._admit(results)
            if self.slots.active_ids():
                self._decode_round(results)
        return results

    def compile_stats(self) -> Dict[str, int]:
        stats = {"decode": _cache_size(self._decode),
                 "prefill": _cache_size(self._prefill),
                 "merge": _cache_size(self._merge)}
        if self._encode is not None:
            stats["encode"] = _cache_size(self._encode)
        return stats

    # ---- admission: batched prefill + slot-wise cache scatter ---------------
    def _admit(self, results: Dict[int, np.ndarray]) -> None:
        while True:
            free = self.slots.free_ids()
            group = self.scheduler.take_group(len(free))
            if not group:
                return
            self._admit_group(group, free, results)

    def _admit_group(self, group: List[Request], free: List[int],
                     results: Dict[int, np.ndarray]) -> None:
        PB, L = self.batch_size, group[0].prompt_len
        toks = np.zeros((PB, L), np.int32)
        for g, req in enumerate(group):
            toks[g] = req.tokens
        batch = {"tokens": jnp.asarray(toks)}
        mem_g = None
        if self._encode is not None:
            frames = np.zeros(self.memory.shape, np.float32)
            for g, req in enumerate(group):
                frames[g] = req.frames
            mem_g = self._encode(self.params, jnp.asarray(frames))
            batch["memory"] = mem_g

        logits_last, caches_g = self._prefill(self.params, batch)
        self.n_prefill_calls += 1
        first = np.asarray(jnp.argmax(logits_last, axis=-1)).astype(np.int32)

        # slot-wise merge: row g of the group cache -> slot_ids[g]; padded
        # rows carry the PB sentinel and are dropped (never touch the cache)
        slot_ids = np.full((PB,), PB, np.int32)
        for g, req in enumerate(group):
            slot_ids[g] = free[g]
        ids = jnp.asarray(slot_ids)
        self.caches = self._merge(self.caches, caches_g, ids)
        if mem_g is not None:
            self.memory = self._merge_memory(self.memory, mem_g, ids)

        for g, req in enumerate(group):
            i = free[g]
            self.slots.assign(i, req, pos=L, last=int(first[g]),
                              out=[int(first[g])])
            self.n_tokens_out += 1
            self._maybe_retire(i, results)

    # ---- one decode step for every active slot ------------------------------
    def _decode_round(self, results: Dict[int, np.ndarray]) -> None:
        B = self.batch_size
        tok = np.zeros((B, 1), np.int32)
        clen = np.zeros((B,), np.int32)
        for s in self.slots.active():
            tok[s.index, 0] = s.data["last"]
            clen[s.index] = s.data["pos"]
        nxt, _, self.caches = self._decode(
            self.params, jnp.asarray(tok), self.caches, jnp.asarray(clen),
            self.memory)
        self.n_decode_steps += 1
        nxt = np.asarray(nxt)
        for s in self.slots.active():
            t = int(nxt[s.index, 0])
            s.data["pos"] += 1
            s.data["last"] = t
            s.data["out"].append(t)
            self.n_tokens_out += 1
            self._maybe_retire(s.index, results)

    def _maybe_retire(self, i: int, results: Dict[int, np.ndarray]) -> None:
        s = self.slots[i]
        out = s.data["out"]
        if out[-1] == self.eos_id or len(out) >= s.request.max_new:
            results[s.request.rid] = np.asarray(out, np.int32)
            self.slots.release(i)


# ===========================================================================
# gDDIM sampling service
# ===========================================================================
class DiffusionEngine:
    """Continuous-batching gDDIM sampling over a *heterogeneous* sampler
    family: slots are samples, the per-slot position is the sampler step
    index k, and every slot additionally carries its own sampler config —
    NFE budget, multistep order q, Eq. 45 corrector toggle, and Eq. 22
    stochasticity lambda.  One trained score network, one compiled step,
    many scenarios: a 10-NFE preview batches with a 50-NFE
    predictor-corrector render.

    Usage:
        engine = DiffusionEngine(spec, params, batch_size=16, nfe=50)
        results = engine.serve([
            SampleRequest(rid=0, seed=0),                    # engine default
            SampleRequest(rid=1, seed=1, nfe=10),            # fast preview
            SampleRequest(rid=2, seed=2, nfe=50, q=2, corrector=True),
            SampleRequest(rid=3, seed=3, nfe=20, lam=0.5),   # stochastic
        ])
        # results[rid] -> np.ndarray sample in data space

    Coefficients come from a host-side `CoeffCache` (Stage-I quadrature run
    once per distinct config) whose stacked `CoeffBank` is padded to
    bucketed shapes and passed to the jitted step as an argument — so
    admitting a config the engine has never seen refreshes the bank
    *contents* without recompiling, as long as the new config fits the
    warmed buckets (`CoeffBank.shape_key`; a bucket overflow costs one
    recompile, then the doubled bucket absorbs further growth).  The
    corrector needs a second model evaluation per step, so the step has two
    jit variants (static `with_corrector`); each round dispatches on
    whether any *active* slot wants the corrector.  The scheduler keeps
    admission waves homogeneous in that cost class, which biases runs of
    same-class traffic into sharing rounds — it cannot prevent classes
    from co-residing after retire-and-refill, so a predictor-only slot
    admitted next to a mid-flight corrector render still rides the 2-eval
    program (correct, just not cheaper) until the render retires.

    Samples are a pure function of (request seed, sampler config): the
    stochastic branch keys its per-step noise by fold_in(seed-derived key,
    k), so admission order and neighbouring slots cannot change a result
    (per-row independence, locked in bitwise by tests/test_serve_engine.py).
    """

    _NOISE_SALT = 0x5EED              # separates step noise from the prior

    def __init__(self, spec: Any, params: Any, batch_size: int,
                 nfe: Optional[int] = None, grid: Optional[str] = None,
                 default_config: Optional[SamplerConfig] = None):
        self.spec = spec
        self.params = params
        self.batch_size = batch_size
        if default_config is None:
            default_config = SamplerConfig(
                nfe=20 if nfe is None else nfe,
                grid="quadratic" if grid is None else grid)
        elif nfe is not None or grid is not None:
            raise ValueError("pass either nfe/grid or default_config, "
                             "not both")
        self.default_config = default_config
        self.nfe = default_config.nfe

        self.cache = CoeffCache(spec.sde, kt=spec.kt)
        self.cache.index_of(default_config)
        # single-config Stage-I bank of the default config (reference /
        # introspection surface; the serve loop reads the stacked bank)
        self.coeffs = self.cache.get(default_config)
        self._step = jax.jit(steps_lib.make_diffusion_serve_step(spec),
                             static_argnames=("with_corrector",))

        state = spec.sde.state_shape(tuple(spec.data_shape))
        self._state = state
        self.u = jnp.zeros((batch_size,) + state, jnp.float32)
        self.hist = jnp.zeros(
            (batch_size, self.cache.bank.pC.shape[2]) + state, jnp.float32)
        self.keys = np.zeros((batch_size, 2), np.uint32)
        self.slots = SlotTable(batch_size)
        # admission waves group by corrector cost class (see class docs)
        self.scheduler = Scheduler(
            group_key=lambda r: self.config_of(r).corrector)

        self._prior1 = jax.jit(
            lambda key: spec.sde.prior_sample(key, 1, tuple(spec.data_shape)))
        self._set_row = jax.jit(lambda u, row, i: u.at[i].set(row[0]))
        self._zero_row = jax.jit(lambda h, i: h.at[i].set(0.0))
        self._project_row = jax.jit(
            lambda u, i: spec.sde.project_data(u[i][None])[0])

        self.n_steps = 0
        self.n_samples_out = 0

    def serve(self, requests: List[SampleRequest]) -> Dict[int, np.ndarray]:
        _check_unique_rids(requests)
        for r in requests:
            try:
                self.config_of(r)       # fail fast, before any device work
            except ValueError as e:
                raise ValueError(f"request {r.rid}: {e}") from None
        self.scheduler.submit_all(requests)
        results: Dict[int, np.ndarray] = {}
        while self.scheduler.has_pending() or self.slots.active_ids():
            self._admit()
            if self.slots.active_ids():
                self._step_round(results)
        return results

    def compile_stats(self) -> Dict[str, int]:
        # step counts both jit variants (predictor-only / with-corrector);
        # after warmup it stays put across any traffic mix whose configs
        # fit the warmed coefficient buckets
        return {"step": _cache_size(self._step),
                "prior": _cache_size(self._prior1)}

    def config_of(self, req: SampleRequest) -> SamplerConfig:
        d = self.default_config
        pick = lambda v, dv: dv if v is None else v
        return SamplerConfig(
            nfe=pick(req.nfe, d.nfe), q=pick(req.q, d.q),
            corrector=pick(req.corrector, d.corrector),
            lam=pick(req.lam, d.lam), grid=pick(req.grid, d.grid))

    def _admit(self) -> None:
        # one head-of-line group per round: an admission wave is
        # homogeneous in corrector cost class (the next class waits for
        # the next round rather than being reordered around)
        free = self.slots.free_ids()
        group = self.scheduler.take_group(len(free))
        if not group:
            return
        # register the whole wave's configs before touching the bank, so
        # it restacks at most once per wave (not once per new config)
        cfgs = [self.config_of(req) for req in group]
        idx = [self.cache.index_of(cfg) for cfg in cfgs]
        self._sync_hist_bucket()
        for req, cfg, ci in zip(group, cfgs, idx):
            i = free.pop(0)
            base = jax.random.PRNGKey(req.seed)
            row = self._prior1(base)
            self.u = self._set_row(self.u, row, i)
            self.hist = self._zero_row(self.hist, i)
            self.keys[i] = np.asarray(
                jax.random.fold_in(base, self._NOISE_SALT))
            self.slots.assign(i, req, k=0, cfg=ci, nfe=cfg.nfe,
                              pc=cfg.corrector)

    def _sync_hist_bucket(self) -> None:
        """Grow the per-slot eps-history buffer when the bank's multistep
        bucket Qb grows (a shape change — i.e. one-time warmup cost)."""
        qb = self.cache.bank.pC.shape[2]
        if self.hist.shape[1] < qb:
            pad = np.zeros((self.batch_size, qb - self.hist.shape[1])
                           + self._state, np.float32)
            self.hist = jnp.concatenate([self.hist, jnp.asarray(pad)], axis=1)

    def _step_round(self, results: Dict[int, np.ndarray]) -> None:
        # inactive slots step at a clipped index on garbage rows; their
        # result is never read and the row is overwritten at admission
        k = np.zeros((self.batch_size,), np.int32)
        c = np.zeros((self.batch_size,), np.int32)
        with_corr = False
        for s in self.slots.active():
            k[s.index] = s.data["k"]
            c[s.index] = s.data["cfg"]
            with_corr = with_corr or s.data["pc"]
        self.u, self.hist = self._step(
            self.params, self.u, self.hist, jnp.asarray(k), jnp.asarray(c),
            jnp.asarray(self.keys), self.cache.bank,
            with_corrector=with_corr)
        self.n_steps += 1
        for s in self.slots.active():
            s.data["k"] += 1
            if s.data["k"] >= s.data["nfe"]:
                results[s.request.rid] = np.asarray(
                    self._project_row(self.u, s.index))
                self.n_samples_out += 1
                self.slots.release(s.index)

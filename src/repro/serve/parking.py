"""Host-side parking table: suspended slot rows, restored bitwise.

Preempting a slot (loop.py `_suspend`) must not lose work: the engine
gathers the slot's row of every device pytree — the `DiffusionState` /
`TokenState` row, KV/recurrent cache rows, encoder memory — and the
parking table keeps the fetched copy on the *host*, keyed by request rid,
next to the host shadow dict the `SlotTable` was tracking.  Resuming
scatters the same bits back into whichever slot row is free at that point
(`row_restore` below, jitted with the state donated by the engine), so a
preempted request's remaining rounds compute on exactly the state it was
suspended with: solo == preempted+resumed, bitwise, which
tests/test_serve_online.py asserts per family and mid-multistep.

The row layout is the engines' existing pytree row layout — fetch and
restore are generic `tree.map`s over batch-leading leaves, there is no
parking-specific serialization — so anything the round step can consume
round-trips (a hypothesis property in tests/test_properties.py drives
arbitrary pytrees through `row_fetch`/`row_restore`).

Parking is OFF the steady-state path by construction: the device fetch
happens only at a preemption decision, the device put only at a resume —
both admission-class events, like prefill.  This module is registered as
a staticcheck hot-path module (SC103/SC105) so any host sync that is NOT
the sanctioned park fetch fails the lint.
"""
from __future__ import annotations

from typing import Any, Dict, Iterable, Tuple

import jax


def row_fetch(tree: Any, i) -> Any:
    """Row `i` of every batch-leading leaf of `tree` (jit-able; the engine
    jits one instance so repeated preemptions reuse one compiled gather
    for any slot index)."""
    return jax.tree.map(lambda x: x[i], tree)


def row_restore(tree: Any, row: Any, i) -> Any:
    """`tree` with `row` written back at batch index `i` of every leaf —
    the bitwise inverse of `row_fetch` for the written row.  The engine
    jits this with `tree` donated (and, in mesh mode, output shardings
    pinned), so a resume updates the state in place like a round does."""
    return jax.tree.map(lambda d, r: d.at[i].set(r), tree, row)


class ParkingTable:
    """rid -> (host payload, host shadow, request) for suspended slots.

    `park` materializes the device rows on the host at the moment of
    suspension (the slot is about to be overwritten by the preempting
    admission); `pop` hands them back for the resume scatter.  Counters
    are cumulative over the table's lifetime — the benchmark reports
    them next to the loop's n_preemptions/n_resumes."""

    def __init__(self):
        self._rows: Dict[int, Tuple[Any, dict, Any]] = {}
        self.n_parked_total = 0

    def park(self, rid: int, device_rows: Any, shadow: dict,
             request: Any) -> None:
        if rid in self._rows:
            raise ValueError(f"request {rid} is already parked")
        payload = jax.device_get(device_rows)  # staticcheck: disable=SC103 (the sanctioned park fetch: one slot row at a preemption decision, not steady-state)
        self._rows[rid] = (payload, dict(shadow), request)
        self.n_parked_total += 1

    def pop(self, rid: int) -> Tuple[Any, dict, Any]:
        return self._rows.pop(rid)

    def __contains__(self, rid: int) -> bool:
        return rid in self._rows

    def __len__(self) -> int:
        return len(self._rows)

    def rids(self) -> Iterable[int]:
        return tuple(self._rows)

"""Continuous-batching inference engine (the production serving layer).

gDDIM's headline result is cheap inference (FID 2.26 @ 50 NFEs on CIFAR10),
which makes the serving layer — not the sampler math — the bottleneck at
traffic scale.  This package is a real engine around one idea: everything
the per-round step consumes lives on device, sharded over the mesh, and the
host only paces the loop.

  * `EngineState` pytrees (state.py: `TokenState`, `DiffusionState`) — the
    device-resident per-slot state (positions, output rings, sampler
    state, active masks), updated inside donated jitted round steps so the
    steady-state loop moves no per-slot metadata host->device
  * `ServeLoop` (loop.py) — the shared admit/round/poll skeleton both
    engines specialize; polls a small done mask at most every `sync_every`
    rounds (or never, when retirement is exactly predictable)
  * `SlotTable` (slots.py) — the host *shadow*: which request occupies a
    slot, plus the cheap counters that pace polls; round-robin free-slot
    placement across mesh shards
  * `Scheduler` (scheduler.py) — FIFO admission with head-of-line grouping
    so prefill waves share one shape (no padding into recurrent state) and
    sampling waves share a (family, corrector) cost class;
    `DeadlineScheduler` is the online variant — urgency order (priority,
    deadline, arrival), still class-homogeneous waves
  * online serving (`ServeLoop.serve_stream`) — streaming arrivals from a
    seeded `TraceTraffic` against a `VirtualClock` (traffic.py),
    deadline/priority admission with preemption into a host-side
    `ParkingTable` (parking.py: suspended slot rows restored bitwise), a
    double-buffered poll, and per-request latency accounting
    (`RequestTiming`, `serving_metrics`)
  * `TokenEngine` — continuous-batching greedy decode over any Arch family
    (KV-cache transformers, RWKV/Mamba recurrent state, encoder-decoder
    with cross-attention memory), width-bucketed batched prefill
  * `DiffusionEngine` — the same discipline applied to batched gDDIM
    sampling: slots are samples, the per-slot position is the sampler step
    index k, and every request carries its own sampler config (SDE family
    — VPSDE, CLD and BDM co-resident in one packed slot pool — NFE /
    multistep order q / corrector / stochasticity lambda), fed by the
    host-side Stage-I coefficient cache (`repro.core.coeffs.CoeffCache`)
    whose multi-family `FactoredBank` stacks every family's coefficients
    as exact (K, K)-block x pooled-(D,)-diagonal factor pairs applied in
    the canonical (k, D) layout of `repro.kernels.ei_update`

  * the wire-level request surface (api.py) — ONE frozen, schema-versioned
    `ServeRequest` for both workloads with an exact JSON round-trip
    (`from_wire(to_wire(r)) == r`; `Request`/`SampleRequest` are thin
    aliases), so requests cross process boundaries without drift
  * the router front-tier (router.py) — `Router` shards an arrival trace
    over N `ReplicaSpec` engine replicas with deterministic health probes,
    admission backpressure and an auditable route plan, all replayable
    from (trace, config, seeds); `repro.distributed.multihost` +
    tools/launchgate.py run the same plan as N spawned processes

Both engines accept `mesh=` (see `repro.launch.mesh`) and then shard the
slot batch over the mesh's data axes via the serve rules in
`repro.distributed.sharding` — bitwise-identical outputs to the
single-device engine.  Results are bitwise-identical again when the
router splits the same trace over replicas — one invariant, three tiers.

See `repro.launch.serve` for the CLI, `docs/serving.md` for the full API
reference, and `examples/serve_batched.py` for a worked walkthrough.
"""
from .slots import Slot, SlotTable
from .api import WIRE_VERSION, Request, SampleRequest, ServeRequest
from .scheduler import DeadlineScheduler, Scheduler, urgency_key
from .loop import ServeLoop
from .parking import ParkingTable, row_fetch, row_restore
from .state import DiffusionState, TokenState
from .traffic import (Arrival, RequestTiming, TraceTraffic, VirtualClock,
                      poisson_trace, serving_metrics)
from .engine import TokenEngine, DiffusionEngine
from .router import ReplicaSpec, Router, RouterConfig

__all__ = [
    "Slot", "SlotTable",
    "ServeRequest", "WIRE_VERSION", "Request", "SampleRequest",
    "Scheduler", "DeadlineScheduler", "urgency_key",
    "ServeLoop", "TokenState", "DiffusionState",
    "ParkingTable", "row_fetch", "row_restore",
    "Arrival", "TraceTraffic", "VirtualClock", "poisson_trace",
    "RequestTiming", "serving_metrics",
    "TokenEngine", "DiffusionEngine",
    "ReplicaSpec", "Router", "RouterConfig",
]

"""Continuous-batching inference engine (the production serving layer).

gDDIM's headline result is cheap inference (FID 2.26 @ 50 NFEs on CIFAR10),
which makes the serving layer — not the sampler math — the bottleneck at
traffic scale.  This package turns the old single-slot demo loop into a real
engine:

  * `SlotTable`   — per-slot bookkeeping (the fix for the shared-position /
                    cache-clobbering bugs: every slot owns its cache rows and
                    its own absolute position)
  * `Scheduler`   — FIFO admission with head-of-line grouping so prefill
                    batches share one shape (no padding into recurrent
                    state) and sampling waves share a corrector cost class
  * `TokenEngine` — continuous-batching greedy decode over any Arch family
                    (KV-cache transformers, RWKV/Mamba recurrent state,
                    encoder-decoder with cross-attention memory)
  * `DiffusionEngine` — the same scheduling discipline applied to batched
                    gDDIM sampling: slots are samples, the per-slot position
                    is the sampler step index k, and every request carries
                    its own sampler config (NFE / multistep order q /
                    corrector / stochasticity lambda).  One jitted
                    `make_diffusion_serve_step` serves slots at different k
                    and different configs in the same batch, fed by the
                    host-side Stage-I coefficient cache
                    (`repro.core.coeffs.CoeffCache`).

See `repro.launch.serve` for the CLI, `docs/serving.md` for the full API
reference, and `examples/serve_batched.py` for a worked walkthrough.
"""
from .slots import Slot, SlotTable
from .scheduler import Request, SampleRequest, Scheduler
from .engine import TokenEngine, DiffusionEngine

__all__ = [
    "Slot", "SlotTable", "Request", "SampleRequest", "Scheduler",
    "TokenEngine", "DiffusionEngine",
]

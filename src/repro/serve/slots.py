"""Slot table: per-slot bookkeeping for the continuous-batching engines.

A slot is one row of the device batch.  The engine pre-allocates `n` slots
(the decode batch size) once; requests are admitted into free slots, run to
completion at their own per-slot position, retire, and the slot is refilled
— no reallocation, no recompilation, no cross-slot state.

The two correctness bugs this table exists to prevent (both present in the
old demo loop):

  * cache clobbering — prefilling one slot must write only that slot's
    cache rows.  The engine scatters prefill results slot-wise (see
    `TokenEngine._merge`), keyed by `Slot.index`.
  * shared positions — each slot decodes at its own `pos`; the engine
    passes the per-slot vector to the model, never a batch-wide max.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional


@dataclasses.dataclass
class Slot:
    """One batch row.  `request` is None while free; `data` holds the
    engine's per-slot state (position, last token, sampler step index...)."""
    index: int
    request: Optional[Any] = None
    data: Dict[str, Any] = dataclasses.field(default_factory=dict)

    @property
    def free(self) -> bool:
        return self.request is None


class SlotTable:
    def __init__(self, n_slots: int):
        self.slots: List[Slot] = [Slot(i) for i in range(n_slots)]

    def __len__(self) -> int:
        return len(self.slots)

    def __getitem__(self, i: int) -> Slot:
        return self.slots[i]

    def free_ids(self) -> List[int]:
        return [s.index for s in self.slots if s.free]

    def active_ids(self) -> List[int]:
        return [s.index for s in self.slots if not s.free]

    def active(self) -> List[Slot]:
        return [s for s in self.slots if not s.free]

    def assign(self, index: int, request: Any, **data) -> Slot:
        s = self.slots[index]
        assert s.free, f"slot {index} already occupied"
        s.request = request
        s.data = dict(data)
        return s

    def release(self, index: int) -> None:
        s = self.slots[index]
        s.request = None
        s.data = {}

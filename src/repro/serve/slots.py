"""Slot table: host-side bookkeeping for the continuous-batching engines.

A slot is one row of the device batch.  The engine pre-allocates `n` slots
(the decode batch size) once; requests are admitted into free slots, run to
completion at their own per-slot position, retire, and the slot is refilled
— no reallocation, no recompilation, no cross-slot state.

Since the `EngineState` refactor the table holds only the host's *shadow*
of a slot: which request occupies it (results are keyed by rid) and the
cheap progress counters the `ServeLoop` uses to pace polls (`n_out` for
token slots, `k` for sampler slots).  The authoritative per-slot state —
positions, output rings, sampler state — lives on device in the engine's
`EngineState` pytree and never round-trips through here.

Mesh mode: slots map to data shards contiguously (slot i lives on shard
i // (n // n_shards)), and `free_ids` returns free slots round-robin
*across* shards, so an admission wave scatters its rows evenly over the
mesh instead of piling onto shard 0.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional


@dataclasses.dataclass
class Slot:
    """One batch row.  `request` is None while free; `data` holds the
    host shadow of the slot's progress (see module docstring)."""
    index: int
    request: Optional[Any] = None
    data: Dict[str, Any] = dataclasses.field(default_factory=dict)

    @property
    def free(self) -> bool:
        return self.request is None


class SlotTable:
    def __init__(self, n_slots: int, n_shards: int = 1):
        if n_shards < 1 or n_slots % n_shards:
            raise ValueError(f"n_slots {n_slots} not divisible by "
                             f"n_shards {n_shards}")
        self.slots: List[Slot] = [Slot(i) for i in range(n_slots)]
        self.n_shards = n_shards
        self._per_shard = n_slots // n_shards

    def __len__(self) -> int:
        return len(self.slots)

    def __getitem__(self, i: int) -> Slot:
        return self.slots[i]

    def free_ids(self) -> List[int]:
        """Free slot indices, round-robin across shards (see module docs)."""
        free = [s.index for s in self.slots if s.free]
        if self.n_shards == 1:
            return free
        ps = self._per_shard
        return sorted(free, key=lambda i: (i % ps, i // ps))

    def active_ids(self) -> List[int]:
        return [s.index for s in self.slots if not s.free]

    def active(self) -> List[Slot]:
        return [s for s in self.slots if not s.free]

    def assign(self, index: int, request: Any, **data) -> Slot:
        s = self.slots[index]
        assert s.free, f"slot {index} already occupied"
        s.request = request
        s.data = dict(data)
        return s

    def release(self, index: int) -> None:
        s = self.slots[index]
        s.request = None
        s.data = {}

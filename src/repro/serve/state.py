"""`EngineState`: the device-resident half of a continuous-batching engine.

Everything the jitted round step *consumes or updates* per slot lives here,
as one batch-leading pytree per engine kind:

  * `TokenState`     — last emitted token, absolute cache position, the
                       per-slot output ring (`out`/`n_out`), the generation
                       budget, and the active mask.
  * `DiffusionState` — the sampler state `u`, the multistep eps history,
                       the step index `k`, the config slot `cfg`, the
                       per-slot PRNG key, and the active mask.

The point of making these explicit pytrees (instead of host-side dicts
rebuilt into fresh numpy arrays every round, which is what PR 1–2 did) is
threefold:

  * **No per-round host round-trip.**  The round step reads and writes the
    state on device; the host loop only fetches a small done/progress mask
    every R rounds (`ServeLoop` in loop.py).  After warmup the steady-state
    loop performs zero host→device transfers per round — locked in by a
    `jax.transfer_guard` test.
  * **Donation.**  The state (and the KV caches next to it) is donated into
    the round step (`donate_argnums`), so the update is in-place at the XLA
    level: no per-step copy of the caches / `u` / `hist` buffers, and peak
    device memory stays at one copy of each.
  * **Sharding.**  Every leaf is slot-batch-leading, so one rule shards the
    whole engine over the `data` mesh axis
    (`distributed.sharding.serve_state_shardings`); the same pytree works
    single-device (no mesh) and mesh-sharded without code changes.

Retired slots are *frozen*, not cleared: the round step masks every update
with `active`, so a finished slot's `out` rows / sampler state survive
verbatim until the host fetches them and re-admits into the row.

Preemption (the online path, loop.py `serve_stream`) reuses the same row
layout verbatim: suspending a slot parks row `i` of every leaf host-side
(`serve.parking.row_fetch`) and resuming scatters the identical bits into
whichever row is free (`row_restore`) — there is no separate
serialization format, so anything the round step can consume round-trips
bitwise.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

Array = jax.Array


class TokenState(NamedTuple):
    """Per-slot decode state for `TokenEngine` (all leaves batch-leading).

      last    (B, 1) int32   last emitted token (next step's input)
      pos     (B,)   int32   absolute cache position of the slot
      n_out   (B,)   int32   tokens emitted so far (incl. the prefill token)
      budget  (B,)   int32   the request's max_new
      out     (B, max_len) int32  per-slot output ring; row b holds
                                  out[b, :n_out[b]]
      active  (B,)   bool    False once retired (eos / budget) — every
                             update in the round step is masked by this
    """
    last: Array
    pos: Array
    n_out: Array
    budget: Array
    out: Array
    active: Array


class DiffusionState(NamedTuple):
    """Per-slot sampler state for `DiffusionEngine` (batch-leading), in the
    *canonical packed* layout of `kernels/ei_update/ops.py` so slots from
    different SDE families share one pool:

      u       (B, K, D) f32     the gDDIM iterate, K = k_max over resident
                                families (VPSDE/BDM row 0, CLD rows 0-1;
                                BDM rows are DCT coefficients), D =
                                prod(data_shape); padding rows stay zero
      hist    (B, Qb, K, D)     multistep eps history, hist[:, j] ~ eps(t_{i+j})
      k       (B,) int32        per-slot sampler step index
      cfg     (B,) int32        per-slot config row in the factored
                                coefficient bank (`FactoredBank`)
      fam     (B,) int32        per-slot SDE family id (`CoeffCache.families`
                                order) — with `prec`, selects which
                                round-step variant commits the slot's update
      prec    (B,) int32        per-slot score-net precision class
                                (`models.quantize.PRECISIONS` order:
                                f32/bf16/int8) — second axis of the
                                variant mask, same contract as `fam`
      keys    (B, 2) uint32     per-slot PRNG key (Eq. 22 stochastic branch)
      active  (B,) bool         False once k reached the config's NFE

    The per-family score-net params are *not* part of this pytree (they
    must survive the round step's donation); the engine keeps them
    device-resident next to it, one placed copy per family, and passes the
    right family's params into each round-step variant — already on
    device, so the steady-state loop still moves nothing host->device.
    """
    u: Array
    hist: Array
    k: Array
    cfg: Array
    fam: Array
    prec: Array
    keys: Array
    active: Array


def token_state_init(batch_size: int, max_len: int) -> TokenState:
    """All-free token state (every slot inactive, zeroed)."""
    B = batch_size
    return TokenState(
        last=jnp.zeros((B, 1), jnp.int32),
        pos=jnp.zeros((B,), jnp.int32),
        n_out=jnp.zeros((B,), jnp.int32),
        budget=jnp.ones((B,), jnp.int32),
        out=jnp.zeros((B, max_len), jnp.int32),
        active=jnp.zeros((B,), bool),
    )


def diffusion_state_init(batch_size: int, k_max: int, data_dim: int,
                         q_bucket: int) -> DiffusionState:
    """All-free diffusion state in the canonical packed (B, K, D) layout
    (K = k_max over the engine's resident families, D = prod(data_shape))
    with multistep history bucket Qb (grows with the bank's q bucket)."""
    B = batch_size
    return DiffusionState(
        u=jnp.zeros((B, k_max, data_dim), jnp.float32),
        hist=jnp.zeros((B, q_bucket, k_max, data_dim), jnp.float32),
        k=jnp.zeros((B,), jnp.int32),
        cfg=jnp.zeros((B,), jnp.int32),
        fam=jnp.zeros((B,), jnp.int32),
        prec=jnp.zeros((B,), jnp.int32),
        keys=jnp.zeros((B, 2), jnp.uint32),
        active=jnp.zeros((B,), bool),
    )

"""Pure-jnp oracle for the 2-D DCT kernels (BDM frequency basis).

dct2_ref / idct2_ref: orthonormal DCT-II / its inverse along the two leading
spatial axes of (B, H, W, C) images.

bdm_ei_update_ref: the fused BDM gDDIM q-step update done entirely in
frequency space with per-frequency diagonal coefficients:

    u_next = IDCT( psi ⊙ DCT(u) + sum_j C_j ⊙ DCT(eps_j) )

psi, C broadcast over (H, W, 1) against the channel axis.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ...sde.base import dct_nd, idct_nd

Array = jax.Array


def dct2_ref(x: Array) -> Array:
    return dct_nd(x, axes=(1, 2))


def idct2_ref(x: Array) -> Array:
    return idct_nd(x, axes=(1, 2))


def bdm_ei_update_ref(u: Array, eps_hist: Array, psi: Array, C: Array) -> Array:
    """u: (B, H, W, Ch); eps_hist: (q, B, H, W, Ch); psi: (H, W, 1); C: (q, H, W, 1)."""
    y = dct2_ref(u.astype(jnp.float32)) * psi
    for j in range(eps_hist.shape[0]):
        y = y + dct2_ref(eps_hist[j].astype(jnp.float32)) * C[j]
    return idct2_ref(y).astype(u.dtype)

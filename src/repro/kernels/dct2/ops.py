"""Dispatch wrappers for the dct2 / fused-BDM kernels."""
from __future__ import annotations

import jax

from . import ref as _ref
from . import kernel as _kernel

Array = jax.Array


def _resolve(impl: str) -> str:
    if impl == "auto":
        return "pallas" if jax.default_backend() == "tpu" else "ref"
    return impl


def dct2(x: Array, inverse: bool = False, impl: str = "auto") -> Array:
    impl = _resolve(impl)
    if impl == "pallas":
        return _kernel.dct2(x, inverse=inverse)
    if impl == "pallas_interpret":
        return _kernel.dct2(x, inverse=inverse, interpret=True)
    return _ref.idct2_ref(x) if inverse else _ref.dct2_ref(x)


def staticcheck_entries():
    """Named Pallas traces at representative serve shapes for
    tools/staticcheck's kernel checks.  Trace-only (jax.make_jaxpr of the
    pallas impl): runs on any backend, nothing is lowered or executed."""
    import jax.numpy as jnp
    B, H, W, Ch, q = 4, 32, 32, 3, 2    # CIFAR frame, q=2 multistep
    x = jnp.zeros((B, Ch, H, W), jnp.float32)
    u = jnp.zeros((B, H, W, Ch), jnp.float32)
    eps = jnp.zeros((q, B, H, W, Ch), jnp.float32)
    psi = jnp.zeros((H, W, 1), jnp.float32)
    C = jnp.zeros((q, H, W, 1), jnp.float32)
    return [
        ("kernels/dct2/dct2[B4,32x32x3]",
         jax.make_jaxpr(lambda a: dct2(a, impl="pallas"))(x)),
        ("kernels/dct2/bdm_ei_update[B4,q2,32x32x3]",
         jax.make_jaxpr(lambda a, e, p, c: bdm_ei_update(
             a, e, p, c, impl="pallas"))(u, eps, psi, C)),
    ]


def bdm_ei_update(u: Array, eps_hist: Array, psi: Array, C: Array,
                  impl: str = "auto") -> Array:
    impl = _resolve(impl)
    if impl == "pallas":
        return _kernel.bdm_ei_update(u, eps_hist, psi, C)
    if impl == "pallas_interpret":
        return _kernel.bdm_ei_update(u, eps_hist, psi, C, interpret=True)
    return _ref.bdm_ei_update_ref(u, eps_hist, psi, C)

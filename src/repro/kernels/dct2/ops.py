"""Dispatch wrappers for the dct2 / fused-BDM kernels."""
from __future__ import annotations

import jax

from . import ref as _ref
from . import kernel as _kernel

Array = jax.Array


def _resolve(impl: str) -> str:
    if impl == "auto":
        return "pallas" if jax.default_backend() == "tpu" else "ref"
    return impl


def dct2(x: Array, inverse: bool = False, impl: str = "auto") -> Array:
    impl = _resolve(impl)
    if impl == "pallas":
        return _kernel.dct2(x, inverse=inverse)
    if impl == "pallas_interpret":
        return _kernel.dct2(x, inverse=inverse, interpret=True)
    return _ref.idct2_ref(x) if inverse else _ref.dct2_ref(x)


def bdm_ei_update(u: Array, eps_hist: Array, psi: Array, C: Array,
                  impl: str = "auto") -> Array:
    impl = _resolve(impl)
    if impl == "pallas":
        return _kernel.bdm_ei_update(u, eps_hist, psi, C)
    if impl == "pallas_interpret":
        return _kernel.bdm_ei_update(u, eps_hist, psi, C, interpret=True)
    return _ref.bdm_ei_update_ref(u, eps_hist, psi, C)

"""Pallas TPU kernel: 2-D DCT as matmuls + fused BDM EI update.

Hardware adaptation (paper App. B.1 / DESIGN.md §3): BDM's frequency
transform is FFT-adjacent on GPU; the TPU has no FFT unit, but an HxW DCT is
two small dense matmuls  Y = C_h X C_w^T  which the MXU executes natively.
For CIFAR-scale images (32..64 per side) the whole image tile plus both DCT
matrices fit comfortably in VMEM, so we fuse the complete gDDIM step

    u_next = IDCT( psi ⊙ DCT(u) + Σ_j C_j ⊙ DCT(eps_j) )

into one kernel: each grid step loads one (H, W) image-channel tile of u and
its q eps-history tiles, performs 2(q+1)+2 small matmuls and the diagonal
scale in VMEM, and writes u_next once.  HBM traffic is (q + 2)·|u| — the
same roofline minimum as the isotropic ei_update kernel, versus 4(q+1)·|u|
for the unfused DCT→scale→IDCT chain.

Grid: (B, Ch).  Layout: channels-last images are transposed host-side to
(B, Ch, H, W) so the tile is a contiguous (H, W) matrix (lanes = W).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ...sde.base import dct_matrix

Array = jax.Array


def _bdm_kernel(u_ref, eps_ref, psi_ref, C_ref, ch_ref, cw_ref, o_ref, *, q: int):
    ch = ch_ref[...]                                   # (H, H) DCT-II
    cw = cw_ref[...]                                   # (W, W)
    x = u_ref[0, 0].astype(jnp.float32)                # (H, W)

    def dct2(m):
        return jax.lax.dot(ch, jax.lax.dot(m, cw.T,
                           preferred_element_type=jnp.float32),
                           preferred_element_type=jnp.float32)

    y = dct2(x) * psi_ref[0]
    for j in range(q):
        e = eps_ref[j, 0, 0].astype(jnp.float32)
        y = y + dct2(e) * C_ref[j, 0]
    out = jax.lax.dot(ch.T, jax.lax.dot(y, cw, preferred_element_type=jnp.float32),
                      preferred_element_type=jnp.float32)
    o_ref[0, 0] = out.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def bdm_ei_update(u: Array, eps_hist: Array, psi: Array, C: Array,
                  *, interpret: bool = False) -> Array:
    """u: (B, H, W, Ch); eps_hist: (q, B, H, W, Ch); psi: (H, W, 1); C: (q, H, W, 1)."""
    B, H, W, Ch = u.shape
    q = eps_hist.shape[0]
    ut = u.transpose(0, 3, 1, 2)                       # (B, Ch, H, W)
    et = eps_hist.transpose(0, 1, 4, 2, 3)             # (q, B, Ch, H, W)
    psi2 = psi[..., 0][None].astype(jnp.float32)       # (1, H, W)
    C2 = C[..., 0][:, None].astype(jnp.float32)        # (q, 1, H, W)
    ch = jnp.asarray(dct_matrix(H), jnp.float32)
    cw = jnp.asarray(dct_matrix(W), jnp.float32)

    kernel = functools.partial(_bdm_kernel, q=q)
    out = pl.pallas_call(
        kernel,
        grid=(B, Ch),
        in_specs=[
            pl.BlockSpec((1, 1, H, W), lambda b, c: (b, c, 0, 0)),
            pl.BlockSpec((q, 1, 1, H, W), lambda b, c: (0, b, c, 0, 0)),
            pl.BlockSpec((1, H, W), lambda b, c: (0, 0, 0)),
            pl.BlockSpec((q, 1, H, W), lambda b, c: (0, 0, 0, 0)),
            pl.BlockSpec((H, H), lambda b, c: (0, 0)),
            pl.BlockSpec((W, W), lambda b, c: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, H, W), lambda b, c: (b, c, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Ch, H, W), u.dtype),
        interpret=interpret,
    )(ut, et, psi2, C2, ch, cw)
    return out.transpose(0, 2, 3, 1)


def _dct_kernel(x_ref, ch_ref, cw_ref, o_ref, *, inverse: bool):
    ch = ch_ref[...]
    cw = cw_ref[...]
    x = x_ref[0, 0].astype(jnp.float32)
    if inverse:
        out = jax.lax.dot(ch.T, jax.lax.dot(x, cw, preferred_element_type=jnp.float32),
                          preferred_element_type=jnp.float32)
    else:
        out = jax.lax.dot(ch, jax.lax.dot(x, cw.T, preferred_element_type=jnp.float32),
                          preferred_element_type=jnp.float32)
    o_ref[0, 0] = out.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("inverse", "interpret"))
def dct2(x: Array, *, inverse: bool = False, interpret: bool = False) -> Array:
    """Orthonormal 2-D DCT-II (or inverse) of (B, H, W, Ch) images."""
    B, H, W, Ch = x.shape
    xt = x.transpose(0, 3, 1, 2)
    chm = jnp.asarray(dct_matrix(H), jnp.float32)
    cwm = jnp.asarray(dct_matrix(W), jnp.float32)
    kernel = functools.partial(_dct_kernel, inverse=inverse)
    out = pl.pallas_call(
        kernel,
        grid=(B, Ch),
        in_specs=[
            pl.BlockSpec((1, 1, H, W), lambda b, c: (b, c, 0, 0)),
            pl.BlockSpec((H, H), lambda b, c: (0, 0)),
            pl.BlockSpec((W, W), lambda b, c: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, H, W), lambda b, c: (b, c, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Ch, H, W), x.dtype),
        interpret=interpret,
    )(xt, chm, cwm)
    return out.transpose(0, 2, 3, 1)

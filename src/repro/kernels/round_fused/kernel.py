"""Pallas TPU megakernel: the whole post-score-eval gDDIM round in ONE pass.

The stitched serving chain pays a separate memory-bound VMEM round-trip
over the (B, K, D) state for every piece — six `apply_factored` launches,
the eps-history shift, the Eq. 22 noise draw + add, the stochastic/
corrector selects, and the retire masking each re-read and re-write state
volume.  This kernel does the entire commit in one grid pass: each grid
step (b, d) loads one (K, block_d) state tile, the (Qb, K, block_d)
history tile and the eps tile once, applies every gathered factor pair
from SMEM, draws the stochastic-branch noise *in VREGs* (threefry2x32,
keys folded with the slot's step index in-kernel), resolves the selects
and the (active, fam, prec) retire mask, and stores each output tile
once.  Per-slot k-advance and retirement land in two tiny SMEM outputs.

Layouts follow `kernels/ei_update`: grid (B, Dp // block_d); per-slot
block factors, diag-pool ids, config scalars and PRNG keys in SMEM; the
deduplicated diagonal pool streams as a (Pb, block_d) VMEM tile with
dynamic row selection.  Coefficient stacking order (the `_PSI`/`_B`/`_P`
constants + `ops._stage_factors`):

    0 psi | 1 B | 2 P_chol | 3..3+Qb-1 pC_j | 3+Qb..3+2Qb-1 cC_j (corr)

Bitwise discipline: every factor apply reassembles the dense coefficient
per term — `(blk[c, c2] * diag) * z[c2]`, left-associated sum — which is
the exact multiply-reduce graph of `apply_factored_ref`, and the noise
path replicates jax's threefry2x32 / fold_in / uniform->erf_inv normal
bit-for-bit (verified against
`jax.random.normal(fold_in(fold_in(key, alg), k), .)` across seeds, folds
and odd sizes; the 'gmm' Rademacher stream reads sign(normal) off the
uniform stage of a second GMM_SALT-folded draw — exact, erf_inv being odd
and monotone).  In interpret mode the kernel is
bitwise equal to `ref.round_update_ref`; on TPU metal the guarantee is
tight-tolerance (tests/test_kernels.py).

`gen_noise=False` takes the canonical noise as an input stream instead —
the BDM path, whose canonicalize is a DCT, not a reshape (`ops` selects
via the SDE's `canonical_noise_is_reshape`).
"""
from __future__ import annotations

import functools

import numpy as np
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ...core.coeffs import ALG_GMM, GMM_C, GMM_SALT

Array = jax.Array

# coefficient slots in the stacked (B, C, kf, kf) SMEM block-factor array
_PSI, _B, _P = 0, 1, 2
_N_FIXED = 3                       # pC_j at _N_FIXED + j; cC_j after the pCs

# per-slot int32 SMEM scalar row:
# [kc, k, n_steps, mine, stoch, use_c, active, alg]
N_INTS = 8

_U32 = jnp.uint32
_TF_MAGIC = np.uint32(0x1BD11BDA)
_ROT_A = (13, 15, 26, 6)
_ROT_B = (17, 29, 16, 24)
# key-schedule index pairs injected after each 4-round group i (i = 1..5)
_INJECT = ((1, 2), (2, 0), (0, 1), (1, 2), (2, 0))


def _rotl(x, r: int):
    return (x << r) | (x >> (32 - r))


def _threefry2x32(k0, k1, x0, x1):
    """The 20-round threefry2x32 block cipher on uint32 scalars/vectors —
    the same schedule jax's PRNG lowers (jax._src.prng), so counters
    encrypted here match `jax.random` bit-for-bit."""
    ks = (k0, k1, k0 ^ k1 ^ _TF_MAGIC)
    x0 = x0 + ks[0]
    x1 = x1 + ks[1]
    for i in range(5):
        for r in (_ROT_A if i % 2 == 0 else _ROT_B):
            x0 = x0 + x1
            x1 = _rotl(x1, r) ^ x0
        a, b = _INJECT[i]
        x0 = x0 + ks[a]
        x1 = x1 + ks[b] + np.uint32(i + 1)
    return x0, x1


def _fold_in(k0, k1, data):
    """jax.random.fold_in on a raw uint32 key pair: encrypt the pair
    (0, data) — threefry_seed of a uint32 is [0, data]."""
    return _threefry2x32(k0, k1, jnp.zeros_like(data), data)


_NORM_LO = np.float32(np.nextafter(np.float32(-1.0), np.float32(0.0)))
_NORM_SCALE = np.float32(1.0) - _NORM_LO
_SQRT2 = np.float32(np.sqrt(2.0))


def _bits_to_normal(bits):
    """uint32 random bits -> N(0, 1) f32, replicating jax.random.normal's
    uniform(-1, 1) -> sqrt(2) * erf_inv pipeline bit-for-bit."""
    fb = (bits >> 9) | np.uint32(0x3F800000)
    fl = jax.lax.bitcast_convert_type(fb, jnp.float32) - np.float32(1.0)
    un = jnp.maximum(_NORM_LO, fl * _NORM_SCALE + _NORM_LO)
    return _SQRT2 * jax.lax.erf_inv(un)


def _normal_row(fk0, fk1, f, n: int):
    """Normal draws for flat state indices `f` (int32 vector) of an n-element
    `jax.random.normal(key, shape)` call: pair i = (x0=i, x1=i+half, zero
    past n), lane 0 covers f < half, lane 1 the rest — jax's
    threefry_random_bits counter layout."""
    half = (n + 1) // 2
    i0 = jnp.where(f < half, f, f - half)
    x1i = i0 + half
    o0, o1 = _threefry2x32(fk0, fk1, i0.astype(_U32),
                           jnp.where(x1i < n, x1i, 0).astype(_U32))
    return _bits_to_normal(jnp.where(f < half, o0, o1))


def _sign_row(gk0, gk1, f, n: int):
    """sign(normal) for the same counter layout as `_normal_row`, without
    the erf_inv: the normal is sqrt(2) * erf_inv(un) with erf_inv odd and
    strictly monotone (erf_inv(0) = 0), so its sign IS the sign of the
    centered uniform `un` — bitwise the sign the ref chain reads off
    `jax.random.normal`.  Drives the Rademacher component of the 'gmm'
    mixture draw (core/coeffs ALGORITHMS block)."""
    half = (n + 1) // 2
    i0 = jnp.where(f < half, f, f - half)
    x1i = i0 + half
    o0, o1 = _threefry2x32(gk0, gk1, i0.astype(_U32),
                           jnp.where(x1i < n, x1i, 0).astype(_U32))
    bits = jnp.where(f < half, o0, o1)
    fb = (bits >> 9) | np.uint32(0x3F800000)
    fl = jax.lax.bitcast_convert_type(fb, jnp.float32) - np.float32(1.0)
    un = jnp.maximum(_NORM_LO, fl * _NORM_SCALE + _NORM_LO)
    return jnp.where(un >= 0, np.float32(1.0), np.float32(-1.0))


def _make_round_kernel(*, kf: int, K: int, Qb: int, D: int, n: int,
                       block_d: int, with_corrector: bool, gen_noise: bool):
    def kernel(ints_ref, keys_ref, blks_ref, dis_ref, pool_ref,
               u_ref, hist_ref, eps_ref, *rest):
        i = 0
        epsn_ref = None
        if with_corrector:
            epsn_ref, i = rest[0], 1
        noise_ref = None
        if not gen_noise:
            noise_ref, i = rest[i], i + 1
        u_out, hist_out, k_out, act_out = rest[i:i + 4]

        kc = ints_ref[0, 0]
        k = ints_ref[0, 1]
        nst = ints_ref[0, 2]
        mine = ints_ref[0, 3] != 0
        stoch = ints_ref[0, 4] != 0
        use_c = ints_ref[0, 5] != 0
        act = ints_ref[0, 6]
        alg = ints_ref[0, 7]

        u_rows = [u_ref[0, c] for c in range(K)]            # (bd,) each
        eps_rows = [eps_ref[0, c] for c in range(kf)]
        zero = jnp.zeros_like(u_rows[0])

        # q-step history shift: slot 0 <- pad(eps_c), the rest slide
        h2 = [[eps_rows[c] if c < kf else zero for c in range(K)]]
        for j in range(1, Qb):
            h2.append([hist_ref[0, j - 1, c] for c in range(K)])

        def dvec(ci: int):
            idx = dis_ref[0, ci]
            return pl.load(pool_ref, (pl.dslice(idx, 1), slice(None)))[0]

        def fapply(ci: int, rows):
            # (blk * diag) * z per term, left-associated sum over c2 — the
            # exact apply_factored_ref multiply-reduce, so interpret mode
            # is bitwise against the ref chain
            d = dvec(ci)
            out = []
            for c in range(kf):
                r = (blks_ref[0, ci, c, 0] * d) * rows[0]
                for c2 in range(1, kf):
                    r = r + (blks_ref[0, ci, c, c2] * d) * rows[c2]
                out.append(r)
            return out

        u_lin = fapply(_PSI, u_rows[:kf])
        u_pred = list(u_lin)
        for j in range(Qb):
            tj = fapply(_N_FIXED + j, h2[j][:kf])
            u_pred = [a + b for a, b in zip(u_pred, tj)]

        if gen_noise:
            # the ref chain's fold order: key -> alg -> kc (draw_step_noise)
            ak0, ak1 = _fold_in(keys_ref[0, 0], keys_ref[0, 1],
                                alg.astype(_U32))
            fk0, fk1 = _fold_in(ak0, ak1, kc.astype(_U32))
            lanes = jax.lax.broadcasted_iota(jnp.int32, (1, block_d), 1)[0]
            d_abs = pl.program_id(1) * block_d + lanes
            noise_rows = [_normal_row(fk0, fk1, c * D + d_abs, n)
                          for c in range(kf)]
            # 'gmm' Rademacher stream (second fold, GMM_SALT): computed
            # unconditionally (one extra threefry + compare per tile, no
            # transcendental) and selected per slot — keeps the launch
            # branch-free across mixed-algorithm batches
            gk0, gk1 = _fold_in(fk0, fk1,
                                jnp.asarray(GMM_SALT, _U32))
            sign_rows = [_sign_row(gk0, gk1, c * D + d_abs, n)
                         for c in range(kf)]
            is_gmm = alg == ALG_GMM
            noise_rows = [jnp.where(is_gmm, z + GMM_C * s, z)
                          for z, s in zip(noise_rows, sign_rows)]
        else:
            noise_rows = [noise_ref[0, c] for c in range(kf)]

        tB = fapply(_B, eps_rows)
        tP = fapply(_P, noise_rows)
        u_sto = [(u_lin[c] + tB[c]) + tP[c] for c in range(kf)]
        sel = [jnp.where(stoch, u_sto[c], u_pred[c]) for c in range(kf)]

        if with_corrector:
            epsn_rows = [epsn_ref[0, c] for c in range(kf)]
            t0 = fapply(_N_FIXED + Qb, epsn_rows)
            u_corr = [u_lin[c] + t0[c] for c in range(kf)]
            for j in range(1, Qb):
                tj = fapply(_N_FIXED + Qb + j, h2[j - 1][:kf])
                u_corr = [a + b for a, b in zip(u_corr, tj)]
            sel = [jnp.where(use_c, u_corr[c], sel[c]) for c in range(kf)]

        # retire masking: freeze rows that are not this variant's
        # (active, family, precision) class; padding rows pass through
        for c in range(K):
            u_out[0, c] = jnp.where(mine, sel[c], u_rows[c]) if c < kf \
                else u_rows[c]
        for j in range(Qb):
            for c in range(K):
                hist_out[0, j, c] = jnp.where(mine, h2[j][c],
                                              hist_ref[0, j, c])
        # k-advance + retirement (idempotent across d-tiles)
        k2 = jnp.where(mine, k + 1, k)
        k_out[0] = k2
        act_out[0] = jnp.where(mine, (k2 < nst).astype(jnp.int32), act)

    return kernel


def _make_predict_kernel(*, kf: int, K: int, Qb: int):
    def kernel(blks_ref, dis_ref, pool_ref, u_ref, hist_ref, eps_ref, o_ref):
        u_rows = [u_ref[0, c] for c in range(kf)]
        eps_rows = [eps_ref[0, c] for c in range(kf)]
        h2 = [eps_rows] + [[hist_ref[0, j - 1, c] for c in range(kf)]
                           for j in range(1, Qb)]

        def dvec(ci: int):
            idx = dis_ref[0, ci]
            return pl.load(pool_ref, (pl.dslice(idx, 1), slice(None)))[0]

        def fapply(ci: int, rows):
            d = dvec(ci)
            out = []
            for c in range(kf):
                r = (blks_ref[0, ci, c, 0] * d) * rows[0]
                for c2 in range(1, kf):
                    r = r + (blks_ref[0, ci, c, c2] * d) * rows[c2]
                out.append(r)
            return out

        u_pred = fapply(0, u_rows)                    # psi at slot 0
        for j in range(Qb):
            tj = fapply(1 + j, h2[j])                 # pC_j at 1 + j
            u_pred = [a + b for a, b in zip(u_pred, tj)]
        for c in range(kf):
            o_ref[0, c] = u_pred[c]

    return kernel


def _pad_last(x, Dp: int):
    if x is None or x.shape[-1] == Dp:
        return x
    pad = [(0, 0)] * (x.ndim - 1) + [(0, Dp - x.shape[-1])]
    return jnp.pad(x, pad)


_SMEM = pltpu.SMEM


@functools.partial(jax.jit, static_argnames=(
    "kf", "n", "with_corrector", "gen_noise", "block_d", "interpret"))
def round_fused(ints, keys, blks, dis, pool, u, hist, eps_c,
                eps_n_c=None, noise_c=None, *, kf: int, n: int,
                with_corrector: bool = False, gen_noise: bool = True,
                block_d: int = 2048, interpret: bool = False):
    """One fused launch for the whole post-score-eval round commit.

    ints (B, N_INTS) int32 [kc, k, n_steps, mine, stoch, use_c, active,
    alg];
    keys (B, 2) uint32; blks (B, C, kf, kf) stacked block factors (see
    module docstring for slot order); dis (B, C) int32 diag-pool ids;
    pool (Pb, D); u (B, K, D); hist (B, Qb, K, D); eps_c/eps_n_c/noise_c
    (B, kf, D).  Returns (u_next, hist_next, k_next, active_next_i32).
    """
    B, K, D = u.shape
    Qb = hist.shape[1]
    block_d = min(block_d, D)
    Dp = D if D % block_d == 0 else D + (block_d - D % block_d)
    u, hist, eps_c, eps_n_c, noise_c, pool = (
        _pad_last(x, Dp) for x in (u, hist, eps_c, eps_n_c, noise_c, pool))
    Pb, C = pool.shape[0], blks.shape[1]
    grid = (B, Dp // block_d)

    kernel = _make_round_kernel(
        kf=kf, K=K, Qb=Qb, D=D, n=n, block_d=block_d,
        with_corrector=with_corrector, gen_noise=gen_noise)

    in_specs = [
        pl.BlockSpec((1, N_INTS), lambda b, d: (b, 0), memory_space=_SMEM),
        pl.BlockSpec((1, 2), lambda b, d: (b, 0), memory_space=_SMEM),
        pl.BlockSpec((1, C, kf, kf), lambda b, d: (b, 0, 0, 0),
                     memory_space=_SMEM),
        pl.BlockSpec((1, C), lambda b, d: (b, 0), memory_space=_SMEM),
        pl.BlockSpec((Pb, block_d), lambda b, d: (0, d)),
        pl.BlockSpec((1, K, block_d), lambda b, d: (b, 0, d)),
        pl.BlockSpec((1, Qb, K, block_d), lambda b, d: (b, 0, 0, d)),
        pl.BlockSpec((1, kf, block_d), lambda b, d: (b, 0, d)),
    ]
    args = [ints, keys, blks.astype(jnp.float32), dis, pool, u, hist, eps_c]
    if with_corrector:
        in_specs.append(pl.BlockSpec((1, kf, block_d),
                                     lambda b, d: (b, 0, d)))
        args.append(eps_n_c)
    if not gen_noise:
        in_specs.append(pl.BlockSpec((1, kf, block_d),
                                     lambda b, d: (b, 0, d)))
        args.append(noise_c)

    u2, h2, k2, a2 = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((1, K, block_d), lambda b, d: (b, 0, d)),
            pl.BlockSpec((1, Qb, K, block_d), lambda b, d: (b, 0, 0, d)),
            pl.BlockSpec((1,), lambda b, d: (b,), memory_space=_SMEM),
            pl.BlockSpec((1,), lambda b, d: (b,), memory_space=_SMEM),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, K, Dp), u.dtype),
            jax.ShapeDtypeStruct((B, Qb, K, Dp), hist.dtype),
            jax.ShapeDtypeStruct((B,), jnp.int32),
            jax.ShapeDtypeStruct((B,), jnp.int32),
        ],
        interpret=interpret,
    )(*args)
    return u2[..., :D], h2[..., :D], k2, a2


@functools.partial(jax.jit, static_argnames=("kf", "block_d", "interpret"))
def round_predict(blks, dis, pool, u, hist, eps_c, *, kf: int,
                  block_d: int = 2048, interpret: bool = False):
    """Fused Eq. 19a predictor iterate (the corrector eval's input):
    blks (B, 1 + Qb, kf, kf) stacked [psi, pC_0..pC_{Qb-1}]; returns
    u_pred (B, kf, D)."""
    B, K, D = u.shape
    Qb = hist.shape[1]
    block_d = min(block_d, D)
    Dp = D if D % block_d == 0 else D + (block_d - D % block_d)
    u, hist, eps_c, pool = (_pad_last(x, Dp)
                            for x in (u, hist, eps_c, pool))
    Pb, C = pool.shape[0], blks.shape[1]
    grid = (B, Dp // block_d)

    out = pl.pallas_call(
        _make_predict_kernel(kf=kf, K=K, Qb=Qb),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, C, kf, kf), lambda b, d: (b, 0, 0, 0),
                         memory_space=_SMEM),
            pl.BlockSpec((1, C), lambda b, d: (b, 0), memory_space=_SMEM),
            pl.BlockSpec((Pb, block_d), lambda b, d: (0, d)),
            pl.BlockSpec((1, K, block_d), lambda b, d: (b, 0, d)),
            pl.BlockSpec((1, Qb, K, block_d), lambda b, d: (b, 0, 0, d)),
            pl.BlockSpec((1, kf, block_d), lambda b, d: (b, 0, d)),
        ],
        out_specs=pl.BlockSpec((1, kf, block_d), lambda b, d: (b, 0, d)),
        out_shape=jax.ShapeDtypeStruct((B, kf, Dp), u.dtype),
        interpret=interpret,
    )(blks.astype(jnp.float32), dis, pool, u, hist, eps_c)
    return out[..., :D]

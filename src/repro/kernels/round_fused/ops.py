"""Dispatch wrapper for the fused gDDIM round megakernel.

`round_update(...)` is the serving engine's whole post-score-eval state
update — the factored coefficient apply, q-step eps-history shift, Eq. 22
stochastic branch, corrector select, and (active, fam, prec) retire
masking + k-advance — behind one impl switch:

  * `ref`              — `ref.round_update_ref`: the historical stitched
                         chain transplanted op-for-op, BITWISE equal to it
                         under jit (the differential anchor; the CPU
                         serving path).
  * `pallas`           — one `kernel.round_fused` launch per round after
                         the score eval (TPU; noise drawn in-kernel).
  * `pallas_interpret` — the same kernel on the CPU interpreter (tests).
  * `auto`             — pallas on TPU, ref elsewhere.

`round_predict(...)` is the Eq. 19a predictor iterate the corrector's
second score eval consumes (ref / fused predict kernel under the same
switch; it runs *before* the eval, so the post-eval launch count stays 1
either way).

Families whose `canonicalize` is not a reshape (BDM: DCT) cannot draw
Eq. 22 noise inside the kernel — for those (`sde.canonical_noise_is_
reshape` False) the canonical noise is drawn outside with the exact
stitched-chain fold_in/normal draw and streamed in as an input.

`fused_round_cost(...)` is the analytic bytes/FLOPs model of one fused
launch — the deterministic `round_bytes_moved` /
`kernel_launches_per_round` counters gated in tools/perf_guard.py and
reported by benchmarks/roofline.py's serving mode.
"""
from __future__ import annotations

from typing import Optional

import numpy as np
import jax
import jax.numpy as jnp

from .kernel import N_INTS, round_fused, round_predict as _predict_pallas
from .ref import draw_step_noise, round_predict_ref, round_update_ref

Array = jax.Array


def _resolve(impl: str) -> str:
    if impl == "auto":
        return "pallas" if jax.default_backend() == "tpu" else "ref"
    return impl


def _stage_factors(bank, cfg, kc, kf: int, with_corrector: bool,
                   predict_only: bool = False):
    """Gather this round's factor pairs and stack them into the kernel's
    SMEM layout: blks (B, C, kf, kf) f32 + dis (B, C) int32 diag-pool ids,
    slot order per kernel.py (predict layout: [psi, pC_j])."""
    blk = lambda nm: getattr(bank, nm + "_blk")[cfg, kc][:, None, :kf, :kf]
    di = lambda nm: getattr(bank, nm + "_di")[cfg, kc][:, None]
    pC_b = bank.pC_blk[cfg, kc][:, :, :kf, :kf]         # (B, Qb, kf, kf)
    pC_i = bank.pC_di[cfg, kc]                          # (B, Qb)
    if predict_only:
        return (jnp.concatenate([blk("psi"), pC_b], axis=1),
                jnp.concatenate([di("psi"), pC_i], axis=1))
    parts_b = [blk("psi"), blk("B"), blk("P_chol"), pC_b]
    parts_i = [di("psi"), di("B"), di("P_chol"), pC_i]
    if with_corrector:
        parts_b.append(bank.cC_blk[cfg, kc][:, :, :kf, :kf])
        parts_i.append(bank.cC_di[cfg, kc])
    return jnp.concatenate(parts_b, axis=1), jnp.concatenate(parts_i, axis=1)


def _draw_noise_c(sde, keys, kc, alg, state_shape, dtype):
    """The stitched chain's algorithm-aware Eq. 22 noise draw
    (ref.draw_step_noise), canonicalized — used when the family's
    canonicalize is not a reshape (kernel can't draw it)."""
    return sde.canonicalize(
        draw_step_noise(sde, keys, kc, alg, state_shape, dtype))


def round_predict(u, hist, kc, cfg, bank, eps_c, *, kf: int,
                  impl: str = "auto", block_d: int = 2048) -> Array:
    """Eq. 19a predictor iterate u_pred (B, kf, D)."""
    impl = _resolve(impl)
    if impl == "ref":
        return round_predict_ref(u, hist, kc, cfg, bank, eps_c, kf=kf)
    if impl not in ("pallas", "pallas_interpret"):
        raise ValueError(impl)
    blks, dis = _stage_factors(bank, cfg, kc, kf, False, predict_only=True)
    return _predict_pallas(blks, dis, bank.diag, u, hist, eps_c, kf=kf,
                           block_d=block_d,
                           interpret=(impl == "pallas_interpret"))


def round_update(u, hist, k, kc, cfg, fam, prec, keys, active, bank, eps_c,
                 *, sde, state_shape, kf: int, fam_index: int = 0,
                 prec_index: int = 0, with_corrector: bool = False,
                 eps_n_c: Optional[Array] = None, impl: str = "auto",
                 block_d: int = 2048):
    """The whole post-score-eval round commit; returns
    (u_next, hist_next, k_next, active_next).  See ref.round_update_ref
    for argument semantics — the pallas path stages the identical gathers
    into SMEM and runs one launch."""
    impl = _resolve(impl)
    if impl == "ref":
        return round_update_ref(
            u, hist, k, kc, cfg, fam, prec, keys, active, bank, eps_c,
            sde=sde, state_shape=state_shape, kf=kf, fam_index=fam_index,
            prec_index=prec_index, with_corrector=with_corrector,
            eps_n_c=eps_n_c)
    if impl not in ("pallas", "pallas_interpret"):
        raise ValueError(impl)

    gen_noise = bool(getattr(sde, "canonical_noise_is_reshape", True))
    noise_c = None
    if not gen_noise:
        noise_c = _draw_noise_c(sde, keys, kc, bank.alg[cfg], state_shape,
                                u.dtype)

    mine = active & (fam == fam_index) & (prec == prec_index)
    use_c = (bank.corrector[cfg] & (kc < bank.n_steps[cfg] - 1)) \
        if with_corrector else jnp.zeros_like(active)
    ints = jnp.stack(
        [kc, k, bank.n_steps[cfg], mine.astype(jnp.int32),
         bank.stochastic[cfg].astype(jnp.int32), use_c.astype(jnp.int32),
         active.astype(jnp.int32), bank.alg[cfg]],
        axis=1).astype(jnp.int32)

    blks, dis = _stage_factors(bank, cfg, kc, kf, with_corrector)
    n = int(np.prod(state_shape))
    u2, h2, k2, a2 = round_fused(
        ints, keys, blks, dis, bank.diag, u, hist, eps_c,
        eps_n_c=eps_n_c, noise_c=noise_c, kf=kf, n=n,
        with_corrector=with_corrector, gen_noise=gen_noise,
        block_d=block_d, interpret=(impl == "pallas_interpret"))
    return u2, h2, k2, a2.astype(bool)


def fused_round_cost(*, B: int, K: int, Qb: int, kf: int, D: int,
                     pool_rows: int, with_corrector: bool = False,
                     gen_noise: bool = True, itemsize: int = 4) -> dict:
    """Analytic per-launch cost of one fused round commit: bytes moved
    between HBM and VMEM (every stream read/written exactly once — the
    kernel's contract) and the VPU FLOPs of the factor applies.  All
    inputs are static shapes, so both counters are deterministic — they
    are the `round_bytes_moved` EXACT gate in tools/perf_guard.py."""
    state = B * K * D
    hist = B * Qb * K * D
    eps = B * kf * D
    streams_in = state + hist + eps + pool_rows * D
    if with_corrector:
        streams_in += eps
    if not gen_noise:
        streams_in += eps
    n_coef = 3 + Qb + (Qb if with_corrector else 0)
    smem = B * (N_INTS + 2 + n_coef * (kf * kf + 1))
    bytes_moved = itemsize * (streams_in + state + hist) + 4 * 2 * B
    # per element of the kf-row output: each factor apply is 2 mul + 1 add
    # per (c, c2) term; predictor sums Qb + 1 applies, stochastic 2 more,
    # corrector Qb more; noise gen ~ const * eps elements (VPU transcendental)
    applies = (1 + Qb) + 2 + (Qb if with_corrector else 0)
    flops = B * kf * kf * D * 3 * applies + B * kf * D * 2 * (applies + 2)
    return {"bytes_moved": int(bytes_moved + itemsize * smem),
            "flops": int(flops),
            "kernel_launches": 1,
            "n_coef": n_coef}


def staticcheck_entries():
    """Named Pallas traces at representative serve shapes for
    tools/staticcheck layer 2 (PL200-203: launch present, BlockSpec
    divisibility, index-map bounds, VMEM/SMEM budgets).  Trace-only —
    nothing is lowered or executed, so it runs on the CPU CI runner."""
    B, K, kf, Qb, D, Pb = 4, 2, 2, 2, 3072, 4   # CIFAR row, CLD width
    ints = jnp.zeros((B, N_INTS), jnp.int32)
    keys = jnp.zeros((B, 2), jnp.uint32)
    dis = jnp.zeros((B, 3 + 2 * Qb), jnp.int32)
    pool = jnp.zeros((Pb, D), jnp.float32)
    u = jnp.zeros((B, K, D), jnp.float32)
    hist = jnp.zeros((B, Qb, K, D), jnp.float32)
    eps = jnp.zeros((B, kf, D), jnp.float32)

    def pred_trace(bl, di_, po, uu, hh, ee):
        return _predict_pallas(bl, di_, po, uu, hh, ee, kf=kf)

    def commit_trace(ii, kk, bl, di_, po, uu, hh, ee):
        return round_fused(ii, kk, bl, di_, po, uu, hh, ee,
                           kf=kf, n=kf * D, with_corrector=False)

    def commit_corr_trace(ii, kk, bl, di_, po, uu, hh, ee, en):
        return round_fused(ii, kk, bl, di_, po, uu, hh, ee, eps_n_c=en,
                           kf=kf, n=kf * D, with_corrector=True)

    blks_p = jnp.zeros((B, 1 + Qb, kf, kf), jnp.float32)
    blks = jnp.zeros((B, 3 + Qb, kf, kf), jnp.float32)
    blks_c = jnp.zeros((B, 3 + 2 * Qb, kf, kf), jnp.float32)
    return [
        ("kernels/round_fused/round_fused[B4,K2,q2,D3072]",
         jax.make_jaxpr(commit_trace)(
             ints, keys, blks, dis[:, :3 + Qb], pool, u, hist, eps)),
        ("kernels/round_fused/round_fused+corr[B4,K2,q2,D3072]",
         jax.make_jaxpr(commit_corr_trace)(
             ints, keys, blks_c, dis, pool, u, hist, eps, eps)),
        ("kernels/round_fused/round_predict[B4,K2,q2,D3072]",
         jax.make_jaxpr(pred_trace)(
             blks_p, dis[:, :1 + Qb], pool, u, hist, eps)),
    ]

"""Pure-jnp reference for the fused gDDIM round update.

This is the *exact* post-score-eval chain of the historical
`make_diffusion_serve_step` bank mode + `make_diffusion_round_step`
masking, transplanted op-for-op (the PR-5 `_apply_factored_canonical`
discipline, extended to the whole round): same gathers, same
`apply_factored` calls in the same order, same left-associated term sums,
same `jnp.where` masking with identical operand order.  Under jit the
graph is therefore the same program as the stitched chain it replaces,
and the result is BITWISE equal to it — which is what lets the serving
engine swap the chain for `ops.round_update` without perturbing a single
sample (tests/test_round_fused.py compares against
`make_diffusion_round_step_stitched` at the coefficient, round-step and
engine levels).

Split in two because the Eq. 45 corrector needs a *second* score eval at
the predictor iterate, which must happen between the history shift and
the commit:

  * `round_predict_ref`  — Eq. 19a predictor only: eps-history shift +
    u_lin + pC terms -> u_pred (the corrector eval's input).  Recomputed
    inside `round_update_ref` with the identical ops, so the two values
    agree bitwise under jit.
  * `round_update_ref`   — the full commit: shift, predictor, Eq. 22
    stochastic branch (noise keyed by fold_in(fold_in(key, alg), kc) via
    `draw_step_noise`, drawn in state space exactly like the stitched
    chain), corrector select, family/precision retire masking, k-advance.

The stochastic-branch noise can be passed in pre-canonicalized
(`noise_c`) — the Pallas path does this for BDM, whose canonicalize is a
DCT rather than a reshape — or drawn internally from `sde`/`keys`,
reproducing the stitched chain's `vmap(fold_in)` draw bit-for-bit.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ...core.coeffs import ALG_GMM, GMM_C, GMM_SALT
from ..ei_update.ops import apply_factored, pad_channels

Array = jax.Array


def draw_step_noise(sde, keys, kc, alg, state_shape, dtype) -> Array:
    """Per-slot Eq. 22 noise draw, algorithm-aware — THE noise law of the
    serving tier.  Shared verbatim by this ref chain, the stitched serve
    step (launch/steps.py bank mode), the outside-the-kernel BDM stream
    of the Pallas path (ops._draw_noise_c) and the dense differential
    oracle (tests/dense_reference.py), so all four stay bitwise identical.

    Chain per slot: key -> fold_in(alg) -> fold_in(kc) -> normal z.
    Folding the algorithm id FIRST keys distinct noise streams for
    same-seed different-algorithm co-residents (the PR-10 keying bugfix;
    previously only (seed, k) entered the stream).  For algorithm='gmm' a
    second stream fold_in(step_key, GMM_SALT) draws s_norm and the
    innovation becomes z + GMM_C * sign(s_norm) — Gabbur's moment-matched
    K=2 mixture, whose sqrt(1 - rho^2) scale lives in the bank's P_chol
    rows (core/coeffs.algorithm_coeff_stacks).  The in-kernel threefry
    path replicates this chain bit for bit (kernel.py reads the sign off
    the uniform stage: erf_inv is odd and monotone, so
    sign(normal) == sign(centered uniform) exactly).
    """
    def draw(key, kk, a):
        step_key = jax.random.fold_in(jax.random.fold_in(key, a), kk)
        z = sde.noise_like(step_key, state_shape, dtype)
        s_norm = sde.noise_like(jax.random.fold_in(step_key, GMM_SALT),
                                state_shape, dtype)
        s = jnp.where(s_norm >= 0, jnp.float32(1.0),
                      jnp.float32(-1.0)).astype(dtype)
        return jnp.where(a == ALG_GMM, z + GMM_C * s, z)

    return jax.vmap(draw)(keys, kc, alg)


def _gat(bank, nm, cfg, kc, kf):
    """One factor pair gathered by (cfg, kc): a (B, kf, kf) block sliced
    statically to this family's width + the (B, D) diagonal pool row its
    int32 id points at — the exact gather of the stitched serve step."""
    return (getattr(bank, nm + "_blk")[cfg, kc][:, :kf, :kf],
            bank.diag[getattr(bank, nm + "_di")[cfg, kc]])


def _gatq(bank, nm, j, cfg, kc, kf):
    return (getattr(bank, nm + "_blk")[cfg, kc, j][:, :kf, :kf],
            bank.diag[getattr(bank, nm + "_di")[cfg, kc, j]])


def _shift_hist(hist: Array, eps_c: Array, K: int) -> Array:
    """q-step eps-history shift: hist[:, 0] <- pad(eps_c), rest slide."""
    return jnp.concatenate(
        [pad_channels(eps_c, K)[:, None], hist[:, :-1]], axis=1)


def _predict(u, hist2, kc, cfg, bank, *, kf):
    """Eq. 19a on an already-shifted history: u_lin + sum_j pC_j hist_j.
    Returns (u_lin, u_pred); term order matches the stitched chain."""
    ub = u[:, :kf]
    u_lin = apply_factored(*_gat(bank, "psi", cfg, kc, kf), ub)
    u_pred = u_lin
    for j in range(hist2.shape[1]):
        u_pred = u_pred + apply_factored(
            *_gatq(bank, "pC", j, cfg, kc, kf), hist2[:, j, :kf])
    return u_lin, u_pred


def round_predict_ref(u, hist, kc, cfg, bank, eps_c, *, kf: int):
    """Predictor iterate u_pred (B, kf, D) — the corrector eval's input."""
    hist2 = _shift_hist(hist, eps_c, u.shape[1])
    _, u_pred = _predict(u, hist2, kc, cfg, bank, kf=kf)
    return u_pred


def round_update_ref(u, hist, k, kc, cfg, fam, prec, keys, active, bank,
                     eps_c, *, sde, state_shape, kf: int,
                     fam_index: int = 0, prec_index: int = 0,
                     with_corrector: bool = False, eps_n_c=None,
                     noise_c=None):
    """The full post-score-eval round commit; returns
    (u_next, hist_next, k_next, active_next).

    `eps_c` is this round's canonicalized score eval; `eps_n_c` (required
    iff `with_corrector`) the canonicalized corrector eval at
    `round_predict_ref`'s iterate.  Slots whose (active, fam, prec) do not
    match this variant are frozen verbatim — the stitched round step's
    retire masking, with the precision class as a third mask term (all
    zeros for a single-precision engine, so the masked values are
    unchanged from the two-term chain)."""
    K = u.shape[1]
    hist2 = _shift_hist(hist, eps_c, K)
    u_lin, u_pred = _predict(u, hist2, kc, cfg, bank, kf=kf)

    # stochastic branch (Eq. 22/23): noise keyed by fold_in(fold_in(key,
    # alg), kc), drawn in state space by the shared algorithm-aware law —
    # identical draw to the stitched chain — unless the caller supplies it
    # pre-canonicalized (the BDM Pallas path)
    if noise_c is None:
        noise = draw_step_noise(sde, keys, kc, bank.alg[cfg],
                                state_shape, u.dtype)
        noise_c = sde.canonicalize(noise)
    u_sto = u_lin + apply_factored(*_gat(bank, "B", cfg, kc, kf), eps_c) \
        + apply_factored(*_gat(bank, "P_chol", cfg, kc, kf), noise_c)
    bmask = lambda m: m.reshape((-1, 1, 1))
    u_next = jnp.where(bmask(bank.stochastic[cfg]), u_sto, u_pred)

    if with_corrector:
        if eps_n_c is None:
            raise ValueError("with_corrector=True needs eps_n_c (the "
                             "canonicalized corrector eval at u_pred)")
        u_corr = u_lin + apply_factored(
            *_gatq(bank, "cC", 0, cfg, kc, kf), eps_n_c)
        for j in range(1, hist2.shape[1]):
            u_corr = u_corr + apply_factored(
                *_gatq(bank, "cC", j, cfg, kc, kf), hist2[:, j - 1, :kf])
        # Alg. 1: no corrector on the final step (k == N_c - 1)
        use_c = bank.corrector[cfg] & (kc < bank.n_steps[cfg] - 1)
        u_next = jnp.where(bmask(use_c), u_corr, u_next)

    # re-attach padding rows, then freeze every slot that is not this
    # variant's (active, family, precision-class) — the stitched round
    # step's masking, op for op
    u_full = jnp.concatenate([u_next, u[:, kf:]], axis=1)
    mine = active & (fam == fam_index) & (prec == prec_index)
    rmask = lambda x: mine.reshape((-1,) + (1,) * (x.ndim - 1))
    k_next = jnp.where(mine, k + 1, k)
    return (jnp.where(rmask(u), u_full, u),
            jnp.where(rmask(hist), hist2, hist),
            k_next,
            jnp.where(mine, k_next < bank.n_steps[cfg], active))

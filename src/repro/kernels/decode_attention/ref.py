"""Pure-jnp oracle for decode attention (single query over a KV cache).

q: (B, Hq, Dh) — one new token per sequence;
k/v: (B, S, Hkv, Dh) — pre-allocated cache, `cache_len` valid entries.
`cache_len` is scalar (shared position) or (B,) — one valid length per
batch row, the continuous-batching case where every slot decodes at its
own absolute position.  Only positions < cache_len (plus the just-written
slot handled by the caller) participate; `window` additionally restricts
attention to the trailing `window` valid positions (sliding-window
layers); GQA broadcast Hq = rep * Hkv.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

Array = jax.Array


def decode_attention_ref(q: Array, k: Array, v: Array, cache_len: Array,
                         window: Optional[int] = None) -> Array:
    B, Hq, Dh = q.shape
    _, S, Hkv, _ = k.shape
    rep = Hq // Hkv
    qg = q.reshape(B, Hkv, rep, Dh).astype(jnp.float32)
    scores = jnp.einsum("bhrd,bshd->bhrs", qg, k.astype(jnp.float32))
    scores = scores / jnp.sqrt(float(Dh))
    n_valid = jnp.asarray(cache_len).reshape(-1, 1)                  # (B|1, 1)
    k_pos = jnp.arange(S)[None]                                      # (1, S)
    mask = k_pos < n_valid                                           # (B|1, S)
    if window is not None:
        # query sits at position n_valid - 1: keep the last `window` keys
        mask = mask & (k_pos >= n_valid - window)
    mask = jnp.broadcast_to(mask, (B, S))
    scores = jnp.where(mask[:, None, None, :], scores, -jnp.inf)
    w = jax.nn.softmax(scores, axis=-1)
    w = jnp.where(jnp.isfinite(scores).any(-1, keepdims=True), w, 0.0)
    out = jnp.einsum("bhrs,bshd->bhrd", w, v.astype(jnp.float32))
    return out.reshape(B, Hq, Dh).astype(q.dtype)

"""Pure-jnp oracle for decode attention (single query over a KV cache).

q: (B, Hq, Dh) — one new token per sequence;
k/v: (B, S, Hkv, Dh) — pre-allocated cache, `cache_len` valid entries.
Only positions < cache_len (plus the just-written slot handled by the
caller) participate; GQA broadcast Hq = rep * Hkv.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array


def decode_attention_ref(q: Array, k: Array, v: Array, cache_len: Array) -> Array:
    B, Hq, Dh = q.shape
    _, S, Hkv, _ = k.shape
    rep = Hq // Hkv
    qg = q.reshape(B, Hkv, rep, Dh).astype(jnp.float32)
    scores = jnp.einsum("bhrd,bshd->bhrs", qg, k.astype(jnp.float32))
    scores = scores / jnp.sqrt(float(Dh))
    mask = jnp.arange(S)[None] < jnp.asarray(cache_len).reshape(-1, 1)   # (B, S)
    scores = jnp.where(mask[:, None, None, :], scores, -jnp.inf)
    w = jax.nn.softmax(scores, axis=-1)
    w = jnp.where(jnp.isfinite(scores).any(-1, keepdims=True), w, 0.0)
    out = jnp.einsum("bhrs,bshd->bhrd", w, v.astype(jnp.float32))
    return out.reshape(B, Hq, Dh).astype(q.dtype)

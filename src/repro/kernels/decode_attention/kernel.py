"""Pallas TPU kernel: decode attention (one query against a long KV cache).

TPU analogue of flash-decoding.  On GPU, flash-decoding splits the KV cache
across SMs (split-K) and merges partial softmax statistics in a second pass.
On TPU the grid is *sequential* per core, so the merge is free: we iterate
KV blocks on the last grid axis, carrying the online-softmax running
(m, l, acc) in VMEM scratch, exactly like the prefill flash kernel but with
the q tile being the `rep` grouped-query rows of one KV head (rep = Hq/Hkv;
the GQA repeat is never materialized).  The cache beyond the valid length is
masked, and whole KV blocks past it are skipped with pl.when — decode cost
is O(cache_len), not O(S_max).

`cache_len` is scalar or per-row (B,): each batch row masks (and skips
blocks) against its own valid length, which is what the continuous-batching
engine needs — slots in one batch decode at different absolute positions.
`window` (static) additionally masks keys below the trailing window and
skips whole blocks beneath it (sliding-window layers: valid keys are the
last `window` of the `cache_len` entries).

Grid: (B, Hkv, num_k_blocks); q tile (rep, Dh), kv tiles (block_k, Dh).
VMEM per step ~ (rep + 2*block_k + rep) * Dh * 4B — tiny; the pipeline
double-buffers the sequential cache stream at full HBM bandwidth, which is
the roofline bound for decode (bytes-dominated: the whole cache is read
once per token).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

Array = jax.Array

NEG_INF = -1e30


def _decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref,
                   m_scr, l_scr, acc_scr, *,
                   block_k: int, num_k_blocks: int, sm_scale: float,
                   window: Optional[int]):
    b = pl.program_id(0)
    kb = pl.program_id(2)
    cache_len = len_ref[b]

    @pl.when(kb == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    live = kb * block_k < cache_len
    if window is not None:
        # the whole block ends before the trailing window: nothing valid in it
        live = live & ((kb + 1) * block_k > cache_len - window)

    @pl.when(live)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)                  # (rep, Dh)
        k = k_ref[0, 0].astype(jnp.float32)                  # (bk, Dh)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        s = s * sm_scale                                     # (rep, bk)
        k_pos = kb * block_k + jax.lax.broadcasted_iota(
            jnp.int32, s.shape, 1)
        valid = k_pos < cache_len
        if window is not None:
            valid = valid & (k_pos >= cache_len - window)
        s = jnp.where(valid, s, NEG_INF)

        m_prev, l_prev = m_scr[...], l_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_scr[...] = alpha * l_prev + jnp.sum(p, axis=-1, keepdims=True)
        v = v_ref[0, 0].astype(jnp.float32)                  # (bk, Dh)
        pv = jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        acc_scr[...] = acc_scr[...] * alpha + pv
        m_scr[...] = m_new

    @pl.when(kb == num_k_blocks - 1)
    def _finalize():
        l = l_scr[...]
        safe_l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0] = (acc_scr[...] / safe_l).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_k", "window", "interpret"))
def decode_attention(q: Array, k: Array, v: Array, cache_len: Array,
                     *, block_k: int = 512, window: Optional[int] = None,
                     interpret: bool = False) -> Array:
    """q: (B, Hq, Dh); k/v: (B, S, Hkv, Dh); cache_len: () or (B,) int32."""
    B, Hq, Dh = q.shape
    _, S, Hkv, _ = k.shape
    assert Hq % Hkv == 0
    rep = Hq // Hkv
    block_k = min(block_k, S)
    assert S % block_k == 0, (S, block_k)
    nk = S // block_k

    qt = q.reshape(B, Hkv, rep, Dh)
    kt = k.transpose(0, 2, 1, 3)                             # (B, Hkv, S, Dh)
    vt = v.transpose(0, 2, 1, 3)
    clen = jnp.broadcast_to(
        jnp.asarray(cache_len, jnp.int32).reshape(-1), (B,))

    kernel = functools.partial(_decode_kernel, block_k=block_k,
                               num_k_blocks=nk, sm_scale=1.0 / (Dh ** 0.5),
                               window=window)
    out = pl.pallas_call(
        kernel,
        grid=(B, Hkv, nk),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),           # cache_len (B,)
            pl.BlockSpec((1, 1, rep, Dh), lambda b, h, j: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, block_k, Dh), lambda b, h, j: (b, h, j, 0)),
            pl.BlockSpec((1, 1, block_k, Dh), lambda b, h, j: (b, h, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, rep, Dh), lambda b, h, j: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hkv, rep, Dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((rep, 1), jnp.float32),
            pltpu.VMEM((rep, 1), jnp.float32),
            pltpu.VMEM((rep, Dh), jnp.float32),
        ],
        interpret=interpret,
    )(clen, qt, kt, vt)
    return out.reshape(B, Hq, Dh)

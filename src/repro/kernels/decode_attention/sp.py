"""Sequence-parallel decode attention (shard_map): the long_500k serving path.

Baseline decode replicates MQA/GQA caches over the model axis (the GSPMD
seq-sharded cache forces involuntary full rematerialization — §Perf
prologue).  This module does it properly: the KV cache is sharded over the
`model` axis on the SEQUENCE dim, each shard runs flash-decode over its
local block carrying (m, l, acc) online-softmax statistics, and the shards
merge with three tiny collectives (pmax + 2 psum of (B, Hq)-sized stats) —
the TPU analogue of flash-decoding's split-K second pass, with the split
laid across chips instead of SMs.

Per-token traffic: each chip reads only its S/tp cache slice (16× less HBM
per chip than the replicated baseline at tp = 16), and the ICI cost is
O(B·Hq·Dh) — independent of context length.  The cache update is also
local: the writing shard is `cache_len // shard_len` (one dynamic-update in
one shard; no resharding).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map

Array = jax.Array


def _local_stats(q, k, v, lo, cache_len):
    """Partial online-softmax stats over the local KV block.
    q: (B, Hq, Dh); k/v: (B, S_loc, Hkv, Dh); lo = absolute offset."""
    B, Hq, Dh = q.shape
    S_loc, Hkv = k.shape[1], k.shape[2]
    rep = Hq // Hkv
    qg = q.reshape(B, Hkv, rep, Dh).astype(jnp.float32)
    s = jnp.einsum("bhrd,bshd->bhrs", qg, k.astype(jnp.float32))
    s = s / jnp.sqrt(float(Dh))
    pos = lo + jnp.arange(S_loc)
    s = jnp.where((pos < cache_len)[None, None, None, :], s, -jnp.inf)
    m = jnp.max(s, axis=-1)                                   # (B, Hkv, rep)
    m_safe = jnp.where(jnp.isfinite(m), m, 0.0)
    p = jnp.exp(s - m_safe[..., None])
    p = jnp.where(jnp.isfinite(s), p, 0.0)
    l = jnp.sum(p, axis=-1)
    acc = jnp.einsum("bhrs,bshd->bhrd", p, v.astype(jnp.float32))
    return m, l, acc


def sp_decode_attention(q: Array, k: Array, v: Array, cache_len: Array,
                        *, mesh: Mesh, seq_axis: str = "model") -> Array:
    """q: (B, Hq, Dh) replicated over seq_axis; k/v: (B, S, Hkv, Dh) sharded
    over seq_axis on dim 1; cache_len: () int32.  Returns (B, Hq, Dh)."""
    B, Hq, Dh = q.shape
    S = k.shape[1]
    tp = mesh.shape[seq_axis]
    assert S % tp == 0
    S_loc = S // tp

    def body(q, k, v, cache_len):
        idx = jax.lax.axis_index(seq_axis)
        lo = idx * S_loc
        m, l, acc = _local_stats(q, k[0], v[0], lo, cache_len[0])
        m = jnp.where(l > 0, m, -jnp.inf)
        m_glob = jax.lax.pmax(jnp.where(jnp.isfinite(m), m, -3e38), seq_axis)
        scale = jnp.exp(jnp.where(jnp.isfinite(m), m, -3e38) - m_glob)
        l_glob = jax.lax.psum(l * scale, seq_axis)
        acc_glob = jax.lax.psum(acc * scale[..., None], seq_axis)
        safe = jnp.where(l_glob == 0.0, 1.0, l_glob)
        out = (acc_glob / safe[..., None]).reshape(B, Hq, Dh)
        return out.astype(q.dtype)

    other = [a for a in mesh.axis_names if a != seq_axis]
    rep_spec = P()
    kv_spec = P(None, seq_axis)            # (B, S/tp, Hkv, Dh) — add lead axis below
    fn = shard_map(
        body, mesh=mesh,
        in_specs=(rep_spec, P(None, None, seq_axis), P(None, None, seq_axis),
                  P(None)),
        out_specs=rep_spec, check_rep=False)
    # shard_map wants the sharded dim explicit: add a dummy lead axis that
    # carries the (1, B, S, Hkv, Dh) layout with S sharded
    return fn(q, k[None], v[None], jnp.asarray(cache_len, jnp.int32).reshape(1))


def sp_cache_update(k_cache: Array, v_cache: Array, k_new: Array, v_new: Array,
                    cache_len: Array, *, mesh: Mesh, seq_axis: str = "model"
                    ) -> Tuple[Array, Array]:
    """Write one token's (k, v) into the seq-sharded cache without
    resharding: only the owning shard performs the dynamic update."""
    S = k_cache.shape[1]
    tp = mesh.shape[seq_axis]
    S_loc = S // tp

    def body(kc, vc, kn, vn, cl):
        idx = jax.lax.axis_index(seq_axis)
        local = cl[0] - idx * S_loc
        in_range = (local >= 0) & (local < S_loc)
        pos = jnp.clip(local, 0, S_loc - 1)
        kc0, vc0 = kc[0], vc[0]
        kc_new = jax.lax.dynamic_update_slice_in_dim(
            kc0, kn.astype(kc0.dtype)[:, None], pos, axis=1)
        vc_new = jax.lax.dynamic_update_slice_in_dim(
            vc0, vn.astype(vc0.dtype)[:, None], pos, axis=1)
        kc_out = jnp.where(in_range, kc_new, kc0)
        vc_out = jnp.where(in_range, vc_new, vc0)
        return kc_out[None], vc_out[None]

    fn = shard_map(
        body, mesh=mesh,
        in_specs=(P(None, None, seq_axis), P(None, None, seq_axis), P(), P(),
                  P(None)),
        out_specs=(P(None, None, seq_axis), P(None, None, seq_axis)),
        check_rep=False)
    return tuple(t[0] for t in fn(k_cache[None], v_cache[None], k_new, v_new,
                                  jnp.asarray(cache_len, jnp.int32).reshape(1)))

"""Dispatch wrapper for decode attention (kernel / reference).

`cache_len` may be a scalar (all rows share one position — the single
sequence / lockstep-batch case) or a (B,) vector of per-row valid lengths
(continuous batching: every slot decodes at its own absolute position).
`window` restricts attention to the trailing `window` valid positions
(sliding-window layers at decode time).
"""
from __future__ import annotations

from typing import Optional

import jax

from .ref import decode_attention_ref
from .kernel import decode_attention as decode_attention_pallas

Array = jax.Array


def staticcheck_entries():
    """Named Pallas traces at representative serve shapes for
    tools/staticcheck's kernel checks.  Trace-only (jax.make_jaxpr of the
    pallas impl): runs on any backend, nothing is lowered or executed."""
    import jax.numpy as jnp
    B, Hq, Hkv, S, Dh = 4, 8, 4, 512, 64
    q = jnp.zeros((B, Hq, Dh), jnp.float32)
    k = jnp.zeros((B, S, Hkv, Dh), jnp.float32)
    v = jnp.zeros((B, S, Hkv, Dh), jnp.float32)
    clen = jnp.zeros((B,), jnp.int32)
    return [
        ("kernels/decode_attention/decode[B4,Hq8,S512,Dh64]",
         jax.make_jaxpr(lambda *a: decode_attention(*a, impl="pallas"))
         (q, k, v, clen)),
        ("kernels/decode_attention/decode_windowed[B4,Hq8,S512,Dh64]",
         jax.make_jaxpr(lambda *a: decode_attention(*a, window=128,
                                                    impl="pallas"))
         (q, k, v, clen)),
    ]


def decode_attention(q: Array, k: Array, v: Array, cache_len,
                     window: Optional[int] = None,
                     impl: str = "auto") -> Array:
    if impl == "auto":
        impl = "pallas" if jax.default_backend() == "tpu" else "ref"
    if impl == "pallas":
        return decode_attention_pallas(q, k, v, cache_len, window=window)
    if impl == "pallas_interpret":
        return decode_attention_pallas(q, k, v, cache_len, window=window,
                                       interpret=True)
    if impl == "ref":
        return decode_attention_ref(q, k, v, cache_len, window=window)
    raise ValueError(impl)

"""Dispatch wrapper for decode attention (kernel / reference).

`cache_len` may be a scalar (all rows share one position — the single
sequence / lockstep-batch case) or a (B,) vector of per-row valid lengths
(continuous batching: every slot decodes at its own absolute position).
`window` restricts attention to the trailing `window` valid positions
(sliding-window layers at decode time).
"""
from __future__ import annotations

from typing import Optional

import jax

from .ref import decode_attention_ref
from .kernel import decode_attention as decode_attention_pallas

Array = jax.Array


def decode_attention(q: Array, k: Array, v: Array, cache_len,
                     window: Optional[int] = None,
                     impl: str = "auto") -> Array:
    if impl == "auto":
        impl = "pallas" if jax.default_backend() == "tpu" else "ref"
    if impl == "pallas":
        return decode_attention_pallas(q, k, v, cache_len, window=window)
    if impl == "pallas_interpret":
        return decode_attention_pallas(q, k, v, cache_len, window=window,
                                       interpret=True)
    if impl == "ref":
        return decode_attention_ref(q, k, v, cache_len, window=window)
    raise ValueError(impl)

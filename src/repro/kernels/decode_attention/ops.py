"""Dispatch wrapper for decode attention (kernel / reference)."""
from __future__ import annotations

import jax

from .ref import decode_attention_ref
from .kernel import decode_attention as decode_attention_pallas

Array = jax.Array


def decode_attention(q: Array, k: Array, v: Array, cache_len,
                     impl: str = "auto") -> Array:
    if impl == "auto":
        impl = "pallas" if jax.default_backend() == "tpu" else "ref"
    if impl == "pallas":
        return decode_attention_pallas(q, k, v, cache_len)
    if impl == "pallas_interpret":
        return decode_attention_pallas(q, k, v, cache_len, interpret=True)
    if impl == "ref":
        return decode_attention_ref(q, k, v, cache_len)
    raise ValueError(impl)

"""Pallas TPU flash attention (forward) with GQA, causal & sliding-window.

TPU adaptation of FlashAttention (Dao et al.): instead of GPU SM/warp
scheduling, we exploit the TPU Pallas guarantee that grid iterations execute
*sequentially* with the last grid axis fastest.  The grid is

    (batch, q_heads, num_q_blocks, num_k_blocks)

and the online-softmax running statistics (m, l) plus the f32 accumulator
live in VMEM scratch that persists across the k-block axis; the output tile
is written once, on the final k block.  GQA is handled with a BlockSpec
index_map that maps q-head h to kv-head h // (Hq // Hkv) — the repeated KV
is never materialized in HBM.

Block sizes default to (128, 128): the MXU is 128x128 and the VMEM working
set is q(128xDh) + k/v(128xDh each) + acc(128xDh f32) + stats — ~0.3 MB at
Dh=128, far under the ~16 MB/core budget, leaving room for double buffering.

Causal + sliding-window masking is computed from absolute positions, so the
same kernel serves prefill (q_offset=0) and chunked/decode attention
(q_offset = cache length).  K-blocks that are entirely outside the causal /
window band are skipped via pl.when (no MXU work, no VMEM traffic beyond the
prefetch), which makes causal attention ~2x and sliding-window O(S*W).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

Array = jax.Array

NEG_INF = -1e30


def _flash_kernel(q_off_ref, q_ref, k_ref, v_ref, o_ref,
                  m_scr, l_scr, acc_scr, *,
                  causal: bool, window: Optional[int],
                  block_q: int, block_k: int, sm_scale: float,
                  num_k_blocks: int):
    qb = pl.program_id(2)
    kb = pl.program_id(3)

    q_off = q_off_ref[0]
    q_pos = q_off + qb * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 0)
    k_pos = kb * block_k + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 1)

    @pl.when(kb == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # block-level skip: is any (q, k) pair in this tile visible?
    lo_q = q_off + qb * block_q            # first q position in tile
    hi_q = lo_q + block_q - 1              # last q position
    lo_k = kb * block_k
    hi_k = lo_k + block_k - 1
    visible = jnp.bool_(True)
    if causal:
        visible = visible & (lo_k <= hi_q)
    if window is not None:
        visible = visible & (hi_k > lo_q - window)

    @pl.when(visible)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)                 # (bq, Dh)
        k = k_ref[0, 0].astype(jnp.float32)                 # (bk, Dh)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        s = s * sm_scale                                  # (bq, bk)
        mask = jnp.ones_like(s, dtype=jnp.bool_)
        if causal:
            mask = mask & (k_pos <= q_pos)
        if window is not None:
            mask = mask & (k_pos > q_pos - window)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...]                               # (bq, 1)
        l_prev = l_scr[...]
        m_cur = jnp.max(s, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)                            # (bq, bk)
        alpha = jnp.exp(m_prev - m_new)                   # (bq, 1)
        l_new = alpha * l_prev + jnp.sum(p, axis=-1, keepdims=True)
        v = v_ref[0, 0].astype(jnp.float32)                  # (bk, Dh)
        pv = jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        acc_scr[...] = acc_scr[...] * alpha + pv
        m_scr[...] = m_new
        l_scr[...] = l_new

    @pl.when(kb == num_k_blocks - 1)
    def _finalize():
        l = l_scr[...]
        safe_l = jnp.where(l == 0.0, 1.0, l)              # fully-masked rows -> 0
        o_ref[0, 0] = (acc_scr[...] / safe_l).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "block_q", "block_k", "interpret"))
def flash_attention(q: Array, k: Array, v: Array, *,
                    q_offset: Array | int = 0,
                    causal: bool = True,
                    window: Optional[int] = None,
                    block_q: int = 128,
                    block_k: int = 128,
                    interpret: bool = False) -> Array:
    """q: (B, Sq, Hq, Dh); k/v: (B, Sk, Hkv, Dh) -> (B, Sq, Hq, Dh)."""
    B, Sq, Hq, Dh = q.shape
    _, Sk, Hkv, _ = k.shape
    assert Hq % Hkv == 0
    rep = Hq // Hkv

    block_q = min(block_q, Sq)
    block_k = min(block_k, Sk)
    assert Sq % block_q == 0 and Sk % block_k == 0, (Sq, block_q, Sk, block_k)
    nq, nk = Sq // block_q, Sk // block_k

    # layouts: (B, H, S, Dh) so the head axis is a pure grid axis
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    q_off = jnp.asarray(q_offset, jnp.int32).reshape(1)

    grid = (B, Hq, nq, nk)
    kernel = functools.partial(
        _flash_kernel, causal=causal, window=window, block_q=block_q,
        block_k=block_k, sm_scale=1.0 / (Dh ** 0.5), num_k_blocks=nk)

    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),  # q_offset scalar
            pl.BlockSpec((1, 1, block_q, Dh), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, block_k, Dh), lambda b, h, i, j: (b, h // rep, j, 0)),
            pl.BlockSpec((1, 1, block_k, Dh), lambda b, h, i, j: (b, h // rep, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, Dh), lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hq, Sq, Dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, Dh), jnp.float32),
        ],
        interpret=interpret,
    )(q_off, qt, kt, vt)
    return out.transpose(0, 2, 1, 3)

"""Dispatch layer for attention: Pallas TPU kernel / blocked-jnp / reference.

`impl` resolution:
  * "pallas"     — the Pallas flash kernel (TPU; `interpret=True` on CPU)
  * "blocked"    — jnp online-softmax over KV chunks via lax.scan.  Same
                   memory profile as flash (never materializes S x S), lowers
                   to plain XLA ops — this is what the multi-pod dry-run
                   compiles, so cost_analysis/memory_analysis reflect the
                   flash-style dataflow rather than a naive S^2 buffer.
  * "ref"        — the dense oracle (small shapes, tests)
  * "auto"       — TPU -> pallas; otherwise blocked for long sequences,
                   ref for short ones.
"""
from __future__ import annotations

from typing import Optional, Union

import jax
import jax.numpy as jnp

from .ref import attention_ref
from .kernel import flash_attention

Array = jax.Array

_BLOCKED_THRESHOLD = 1024

# Dry-run override (repro.launch.dryrun --opt flash_stub): lower attention
# as `traffic_stub`, whose HLO HBM traffic equals the Pallas flash kernel's
# true dataflow (q,k,v read once; o written once; online-softmax stats live
# in VMEM).  The blocked-jnp lowering otherwise materializes per-chunk score
# tiles and scan carries into HBM, inflating the roofline memory term by
# ~10-20x (EXPERIMENTS.md §Perf iter A3).  NUMERICS ARE WRONG by design —
# the stub exists only to measure the kernel's memory/collective profile
# from the compiled artifact; real execution always uses pallas/blocked/ref.
FORCE_IMPL: str | None = None


def _platform() -> str:
    return jax.default_backend()


def blocked_attention(q: Array, k: Array, v: Array, *, causal: bool,
                      window: Optional[int], q_offset: Union[int, Array],
                      block_k: int = 512) -> Array:
    """Online-softmax attention, scanning KV in chunks (flash dataflow in jnp)."""
    B, Sq, Hq, Dh = q.shape
    _, Sk, Hkv, _ = k.shape
    rep = Hq // Hkv
    if Sk % block_k:
        pad = block_k - Sk % block_k
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        # padded keys are masked because their positions exceed every q_pos
        Sk_p = Sk + pad
    else:
        Sk_p = Sk
    nk = Sk_p // block_k
    qg = q.reshape(B, Sq, Hkv, rep, Dh).astype(jnp.float32)
    kb = k.reshape(B, nk, block_k, Hkv, Dh).transpose(1, 0, 2, 3, 4)
    vb = v.reshape(B, nk, block_k, Hkv, Dh).transpose(1, 0, 2, 3, 4)
    q_pos = (jnp.asarray(q_offset) + jnp.arange(Sq)).astype(jnp.int32)
    scale = 1.0 / (Dh ** 0.5)

    def step(carry, blk):
        m, l, acc = carry
        kc, vc, j = blk
        s = jnp.einsum("bqhrd,bkhd->bhrqk", qg, kc.astype(jnp.float32)) * scale
        k_pos = j * block_k + jnp.arange(block_k)
        mask = jnp.ones((Sq, block_k), bool)
        mask = mask & (k_pos[None, :] < Sk)
        if causal:
            mask = mask & (k_pos[None, :] <= q_pos[:, None])
        if window is not None:
            mask = mask & (k_pos[None, :] > q_pos[:, None] - window)
        s = jnp.where(mask[None, None, None], s, -1e30)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        alpha = jnp.exp(m - m_new)
        l_new = alpha * l + p.sum(-1)
        pv = jnp.einsum("bhrqk,bkhd->bhrqd", p, vc.astype(jnp.float32))
        acc_new = acc * alpha[..., None] + pv
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, Hkv, rep, Sq), -1e30, jnp.float32)
    l0 = jnp.zeros((B, Hkv, rep, Sq), jnp.float32)
    a0 = jnp.zeros((B, Hkv, rep, Sq, Dh), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(step, (m0, l0, a0),
                                  (kb, vb, jnp.arange(nk)))
    l = jnp.where(l == 0.0, 1.0, l)
    out = (acc / l[..., None]).transpose(0, 3, 1, 2, 4).reshape(B, Sq, Hq, Dh)
    return out.astype(q.dtype)


def traffic_stub(q: Array, k: Array, v: Array) -> Array:
    """Flash-kernel HBM-traffic stand-in: reads q/k/v once, writes o once
    (reductions over S fuse into a single pass); ~zero flops.  See
    FORCE_IMPL above — measurement artifact for the dry-run only."""
    B, Sq, Hq, Dh = q.shape
    _, Sk, Hkv, _ = k.shape
    rep = Hq // Hkv
    km = jnp.mean(k.astype(jnp.float32), axis=1)           # (B, Hkv, Dh)
    vm = jnp.max(v.astype(jnp.float32), axis=1)            # (B, Hkv, Dh)
    s = jnp.tanh(km + vm)                                  # (B, Hkv, Dh)
    s = jnp.repeat(s, rep, axis=1)[:, None]                # (B, 1, Hq, Dh)
    return (q.astype(jnp.float32) * s).astype(q.dtype)


def attention(q: Array, k: Array, v: Array, *, causal: bool = True,
              window: Optional[int] = None, q_offset: Union[int, Array] = 0,
              impl: str = "auto") -> Array:
    if FORCE_IMPL is not None:
        impl = FORCE_IMPL
    if impl == "traffic_stub":
        return traffic_stub(q, k, v)
    if impl == "auto":
        if _platform() == "tpu":
            impl = "pallas"
        elif k.shape[1] >= _BLOCKED_THRESHOLD:
            impl = "blocked"
        else:
            impl = "ref"
    if impl == "pallas":
        return flash_attention(q, k, v, q_offset=q_offset, causal=causal,
                               window=window)
    if impl == "pallas_interpret":
        return flash_attention(q, k, v, q_offset=q_offset, causal=causal,
                               window=window, interpret=True)
    if impl == "blocked":
        return blocked_attention(q, k, v, causal=causal, window=window,
                                 q_offset=q_offset)
    if impl == "ref":
        return attention_ref(q, k, v, causal=causal, window=window,
                             q_offset=q_offset)
    raise ValueError(impl)

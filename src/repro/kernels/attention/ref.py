"""Pure-jnp oracle for the attention kernels (GQA + causal + sliding window).

This is the reference the Pallas kernels are allclose-tested against
(tests/test_kernels.py sweeps shapes & dtypes with interpret=True).
"""
from __future__ import annotations

from typing import Optional, Union

import jax
import jax.numpy as jnp

Array = jax.Array


def attention_ref(q: Array, k: Array, v: Array, *, causal: bool = True,
                  window: Optional[int] = None,
                  q_offset: Union[int, Array] = 0) -> Array:
    """q: (B, Sq, Hq, Dh); k/v: (B, Sk, Hkv, Dh); Hq % Hkv == 0.

    `q_offset` is the absolute position of q[0] relative to k[0] — for
    decode with a pre-allocated cache, q_offset = number of valid cache
    entries, so the causal mask also hides the unwritten tail of the cache.
    `window`: attend only to the last `window` keys (Mistral/gemma-style
    sliding window); None = unbounded.
    """
    B, Sq, Hq, Dh = q.shape
    _, Sk, Hkv, _ = k.shape
    rep = Hq // Hkv
    qg = q.reshape(B, Sq, Hkv, rep, Dh)
    scores = jnp.einsum("bqhrd,bkhd->bhrqk", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) / jnp.sqrt(float(Dh))
    q_pos = (jnp.asarray(q_offset) + jnp.arange(Sq))[:, None]   # (Sq, 1)
    k_pos = jnp.arange(Sk)[None, :]                             # (1, Sk)
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask = mask & (k_pos <= q_pos)
    if window is not None:
        mask = mask & (k_pos > q_pos - window)
    scores = jnp.where(mask[None, None, None], scores, -jnp.inf)
    w = jax.nn.softmax(scores, axis=-1)
    # fully-masked rows (can happen with tiny windows) -> zeros, not NaN
    w = jnp.where(jnp.isfinite(scores).any(-1, keepdims=True), w, 0.0)
    out = jnp.einsum("bhrqk,bkhd->bqhrd", w, v.astype(jnp.float32))
    return out.reshape(B, Sq, Hq, Dh).astype(q.dtype)

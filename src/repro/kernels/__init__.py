"""Pallas TPU kernels for the compute hot spots (each: kernel + ops + ref).

  attention/        flash attention forward (train / prefill)
  decode_attention/ flash-decoding analogue (one query vs long KV cache)
  ei_update/        fused q-step gDDIM exponential-integrator state update
  round_fused/      the whole post-score-eval serving round in ONE launch
                    (factor applies, history shift, Eq. 22 noise in-kernel,
                    retire masking + k-advance)
  dct2/             BDM DCT-as-matmul + fully fused frequency-space EI update
"""

"""Pallas TPU kernels for the compute hot spots (each: kernel + ops + ref).

  attention/        flash attention forward (train / prefill)
  decode_attention/ flash-decoding analogue (one query vs long KV cache)
  ei_update/        fused q-step gDDIM exponential-integrator state update
  dct2/             BDM DCT-as-matmul + fully fused frequency-space EI update
"""

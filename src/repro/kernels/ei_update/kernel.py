"""Pallas TPU kernel: fused q-step gDDIM exponential-integrator update.

On GPU the gDDIM update is a chain of q+1 broadcast-multiply-adds, each a
separate memory-bound pass over the full state.  The TPU adaptation fuses
everything into ONE VMEM pass: each grid step loads a (k, block_d) tile of u
and the q matching eps-history tiles, applies the tiny structured matrices
(scalar k=1 / CLD channel-block k=2) entirely in VREGs, and stores the
output tile once.  HBM traffic drops from (2 + 2q) |u| to (q + 2) |u| —
the roofline minimum for this op (it must read u and all q eps terms).

Layout: state flattened to (B, k, D); grid (B, D // block_d); coefficients
live in SMEM (they are a handful of scalars).  block_d defaults to 2048
lanes = 8 KiB/channel tile in f32 — small against ~16 MiB VMEM, so the
pipeline can double-buffer the q+1 input streams.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

Array = jax.Array


def _ei_kernel(psi_ref, C_ref, u_ref, eps_ref, o_ref, *, q: int, k: int):
    u = u_ref[0].astype(jnp.float32)                    # (k, bd)
    acc = jnp.zeros_like(u)
    for c in range(k):
        row = jnp.zeros_like(u[0])
        for c2 in range(k):
            row = row + psi_ref[c, c2] * u[c2]
        for j in range(q):
            e = eps_ref[j, 0].astype(jnp.float32)       # (k, bd)
            for c2 in range(k):
                row = row + C_ref[j, c, c2] * e[c2]
        acc = acc.at[c].set(row)
    o_ref[0] = acc.astype(o_ref.dtype)


def _factored_kernel(blk_ref, u_ref, diag_ref, o_ref, *, k: int):
    u = u_ref[0].astype(jnp.float32)                    # (k, bd)
    d = diag_ref[0].astype(jnp.float32)                 # (bd,)
    acc = jnp.zeros_like(u)
    for c in range(k):
        row = jnp.zeros_like(u[0])
        for c2 in range(k):
            row = row + blk_ref[0, c, c2] * u[c2]
        acc = acc.at[c].set(row * d)
    o_ref[0] = acc.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_d", "interpret"))
def apply_factored(blk: Array, diag: Array, z: Array,
                   *, block_d: int = 2048, interpret: bool = False) -> Array:
    """Factored-coefficient application: blk (B, k, k); diag (B, D);
    z (B, k, D) -> (B, k, D).

    Same fusion story as `ei_update`: the gathered per-example block
    factors are a handful of scalars (SMEM), so each grid step loads one
    (k, block_d) state tile plus the matching diagonal tile, applies the
    k x k block in VREGs, scales by the diagonal, and stores once — the
    two contractions of the factored bank cost ONE pass over the state
    instead of the dense path's (K, K, D)-coefficient stream (which read
    K times the state volume in coefficients alone).
    """
    B, k, D = z.shape
    block_d = min(block_d, D)
    if D % block_d:
        pad = block_d - D % block_d
        z = jnp.pad(z, ((0, 0), (0, 0), (0, pad)))
        diag = jnp.pad(diag, ((0, 0), (0, pad)))
    Dp = z.shape[-1]
    grid = (B, Dp // block_d)

    kernel = functools.partial(_factored_kernel, k=k)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, k, k), lambda b, d: (b, 0, 0),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((1, k, block_d), lambda b, d: (b, 0, d)),
            pl.BlockSpec((1, block_d), lambda b, d: (b, d)),
        ],
        out_specs=pl.BlockSpec((1, k, block_d), lambda b, d: (b, 0, d)),
        out_shape=jax.ShapeDtypeStruct((B, k, Dp), z.dtype),
        interpret=interpret,
    )(blk.astype(jnp.float32), z, diag)
    return out[..., :D]


@functools.partial(jax.jit, static_argnames=("block_d", "interpret"))
def ei_update(u: Array, eps_hist: Array, psi: Array, C: Array,
              *, block_d: int = 2048, interpret: bool = False) -> Array:
    """u: (B, k, D); eps_hist: (q, B, k, D); psi: (k, k); C: (q, k, k)."""
    B, k, D = u.shape
    q = eps_hist.shape[0]
    block_d = min(block_d, D)
    if D % block_d:
        pad = block_d - D % block_d
        u = jnp.pad(u, ((0, 0), (0, 0), (0, pad)))
        eps_hist = jnp.pad(eps_hist, ((0, 0), (0, 0), (0, 0), (0, pad)))
    Dp = u.shape[-1]
    grid = (B, Dp // block_d)

    kernel = functools.partial(_ei_kernel, q=q, k=k)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),                 # psi (k,k)
            pl.BlockSpec(memory_space=pltpu.SMEM),                 # C (q,k,k)
            pl.BlockSpec((1, k, block_d), lambda b, d: (b, 0, d)),
            pl.BlockSpec((q, 1, k, block_d), lambda b, d: (0, b, 0, d)),
        ],
        out_specs=pl.BlockSpec((1, k, block_d), lambda b, d: (b, 0, d)),
        out_shape=jax.ShapeDtypeStruct((B, k, Dp), u.dtype),
        interpret=interpret,
    )(psi.astype(jnp.float32), C.astype(jnp.float32), u, eps_hist)
    return out[..., :D]

"""Pure-jnp oracle for the fused gDDIM exponential-integrator update.

The q-step predictor update (paper Eq. 19a) for scalar/block families:

    u_next[c] = sum_c' Psi[c,c'] u[c'] + sum_j sum_c' C[j,c,c'] eps_hist[j,c']

State layout: (B, k, D) with k the structural channel count (VPSDE: k=1,
CLD: k=2) and D the flattened data dims.  eps_hist: (q, B, k, D).
Coefficients: psi (k, k); C (q, k, k).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array


def ei_update_ref(u: Array, eps_hist: Array, psi: Array, C: Array) -> Array:
    out = jnp.einsum("ck,bkd->bcd", psi.astype(jnp.float32),
                     u.astype(jnp.float32))
    out = out + jnp.einsum("jck,jbkd->bcd", C.astype(jnp.float32),
                           eps_hist.astype(jnp.float32))
    return out.astype(u.dtype)


def apply_factored_ref(blk: Array, diag: Array, z: Array) -> Array:
    """Factored per-example coefficient application: blk (B, k, k) against
    z (B, k, D), then the (B, D) diagonal factor elementwise.

    `blk[b] (x) diag[b]` is the dense coefficient, and this deliberately
    runs as the SAME program as the dense `apply_packed` einsum: the
    dense coefficient is reassembled as mul(broadcast(blk), broadcast(
    diag)) — exact, because one factor is always trivial (0/1/ones, see
    core.coeffs.factor_coeff) — and fed to the identical multiply-reduce.
    XLA keeps the broadcasts virtual inside the fusion (the k*k*D
    coefficient never exists in memory; that is the factored bank's
    point), and because the reduce sees the identical graph shape the
    result is *bitwise* equal to the dense path under jit.  The tempting
    alternatives are not: `einsum("bij,bjd->bid")` lowers to a
    dot_general whose FMA contraction differs in the last ulp for k=2
    (CLD) blocks, and scaling by the diagonal *after* the reduce invites
    the fuser to contract the surrounding multiply-adds differently."""
    coeff = jnp.broadcast_to(blk[..., None], blk.shape + (z.shape[-1],)) \
        * diag[:, None, None, :]
    return jnp.einsum("bijd,bjd->bid", coeff, z)

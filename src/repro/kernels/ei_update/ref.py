"""Pure-jnp oracle for the fused gDDIM exponential-integrator update.

The q-step predictor update (paper Eq. 19a) for scalar/block families:

    u_next[c] = sum_c' Psi[c,c'] u[c'] + sum_j sum_c' C[j,c,c'] eps_hist[j,c']

State layout: (B, k, D) with k the structural channel count (VPSDE: k=1,
CLD: k=2) and D the flattened data dims.  eps_hist: (q, B, k, D).
Coefficients: psi (k, k); C (q, k, k).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array


def ei_update_ref(u: Array, eps_hist: Array, psi: Array, C: Array) -> Array:
    out = jnp.einsum("ck,bkd->bcd", psi.astype(jnp.float32),
                     u.astype(jnp.float32))
    out = out + jnp.einsum("jck,jbkd->bcd", C.astype(jnp.float32),
                           eps_hist.astype(jnp.float32))
    return out.astype(u.dtype)

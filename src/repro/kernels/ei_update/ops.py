"""Dispatch wrapper for the fused EI-update kernel + the canonical packing
layer.

`ei_update(u, eps_hist, psi, C)` with state (B, k, D).  The SDE samplers —
and, since the multi-family serving refactor, the `DiffusionEngine`'s whole
slot pool — flatten their state into this canonical layout via
`pack_state`/`unpack_state` (VPSDE: k=1; CLD: k=2 channel axis; BDM routes
its DCT-frequency state through the dct2 path and lands here with k=1).

The packing layer is family-generic:

  * `pack_state(u, k, k_pad=None)` flattens (B, [k,] *data) to (B, k, D)
    and optionally zero-pads the channel axis to `k_pad` rows, so one slot
    pool can host families of different structural width (k_max = max over
    resident families; padding rows stay identically zero).
  * `unpack_state(z, shape, k=None)` inverts it, dropping padding rows.
  * `apply_factored(blk, diag, z)` applies a per-example *factored*
    canonical coefficient — a (B, k, k) block factor times a (B, D)
    diagonal factor, together the dense coeff[b,i,j,d] = blk[b,i,j] *
    diag[b,d] every family's structured coefficient factors into exactly
    (scalar: c e00 x 1, CLD block: M x 1, BDM freq-diag: e00 x d; see
    `repro.core.coeffs.factor_coeff`) — to a packed state (B, k, D) as
    two contractions.  This is the serving step's bank-gather form
    (`FactoredBank`); ref + Pallas paths.
  * `apply_packed(coeff, z)` applies a per-example *dense* canonical
    coefficient (B, k, k, D) — the embedded form the factored bank
    replaced.  Kept as the one-einsum oracle the differential tests
    (tests/test_factored_bank.py) compare `apply_factored` against.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from .ref import apply_factored_ref, ei_update_ref
from .kernel import apply_factored as apply_factored_pallas
from .kernel import ei_update as ei_update_pallas

Array = jax.Array


def pad_channels(z: Array, k_pad: int) -> Array:
    """Zero-pad a packed (B, k, D) state's channel axis to k_pad rows.
    The single shared implementation of canonical-layout padding (used by
    `pack_state`, the serve step's eps/noise packing, and the engine's
    prior admission)."""
    k = z.shape[1]
    if k_pad < k:
        raise ValueError(f"k_pad {k_pad} < k {k}")
    if k_pad == k:
        return z
    return jnp.concatenate(
        [z, jnp.zeros((z.shape[0], k_pad - k) + z.shape[2:], z.dtype)],
        axis=1)


def pack_state(u: Array, k: int, k_pad: Optional[int] = None,
               ) -> Tuple[Array, Tuple[int, ...]]:
    """(B, [k,] *data) -> (B, k_pad or k, D) plus the original shape for
    unpack.  Padding rows (k..k_pad) are zeros."""
    shape = u.shape
    B = shape[0]
    z = u.reshape(B, k, -1)
    if k_pad is not None:
        z = pad_channels(z, k_pad)
    return z, shape


def unpack_state(u: Array, shape: Tuple[int, ...],
                 k: Optional[int] = None) -> Array:
    """Invert `pack_state`: drop padding rows (when the packed `u` is wider
    than the original k rows) and restore `shape`."""
    if k is not None and u.shape[1] > k:
        u = u[:, :k]
    return u.reshape(shape)


def apply_packed(coeff: Array, z: Array) -> Array:
    """Per-example DENSE canonical coefficient application:
    coeff (B, k, k, D) x z (B, k, D) -> (B, k, D).  Differential-test
    oracle for `apply_factored`; the serve path gathers factor pairs."""
    return jnp.einsum("bijd,bjd->bid", coeff, z)


def apply_factored(blk: Array, diag: Array, z: Array,
                   impl: str = "auto") -> Array:
    """Per-example FACTORED canonical coefficient application (the bank-
    gather form of the serve step): blk (B, k, k), diag (B, D),
    z (B, k, D) -> (B, k, D), as two contractions.

    The ref path is *bitwise* equal to the dense `apply_packed` einsum it
    replaced (same multiply-reduce graph — see apply_factored_ref); the
    TPU Pallas kernel computes the same two contractions fused in VREGs
    and is pinned to ref at tight tolerance (its accumulation order may
    differ in the last ulp).  Engine determinism guarantees (solo ==
    mixed, mesh == single-device) compare identical programs and so hold
    on every backend; the factored == dense differential tier runs on
    the ref path."""
    if impl == "auto":
        impl = "pallas" if jax.default_backend() == "tpu" else "ref"
    if impl == "pallas":
        return apply_factored_pallas(blk, diag, z)
    if impl == "pallas_interpret":
        return apply_factored_pallas(blk, diag, z, interpret=True)
    if impl == "ref":
        return apply_factored_ref(blk, diag, z)
    raise ValueError(impl)


def staticcheck_entries():
    """Named Pallas traces at representative serve shapes for
    tools/staticcheck's kernel checks.  Trace-only (jax.make_jaxpr of the
    pallas impl): runs on any backend, nothing is lowered or executed."""
    B, k, D, q = 4, 2, 3072, 2          # CIFAR row: D = 32*32*3, CLD k=2
    z = jnp.zeros((B, k, D), jnp.float32)
    blk = jnp.zeros((B, k, k), jnp.float32)
    diag = jnp.zeros((B, D), jnp.float32)
    eps = jnp.zeros((q, B, k, D), jnp.float32)
    psi = jnp.zeros((k, k), jnp.float32)
    C = jnp.zeros((q, k, k), jnp.float32)
    return [
        ("kernels/ei_update/apply_factored[B4,k2,D3072]",
         jax.make_jaxpr(lambda b, d, s: apply_factored(b, d, s,
                                                       impl="pallas"))
         (blk, diag, z)),
        ("kernels/ei_update/ei_update[B4,k2,q2,D3072]",
         jax.make_jaxpr(lambda u, e, p, c: ei_update(u, e, p, c,
                                                     impl="pallas"))
         (z, eps, psi, C)),
    ]


def ei_update(u: Array, eps_hist: Array, psi: Array, C: Array,
              impl: str = "auto") -> Array:
    """u: (B, k, D); eps_hist: (q, B, k, D); psi (k, k); C (q, k, k)."""
    if impl == "auto":
        impl = "pallas" if jax.default_backend() == "tpu" else "ref"
    if impl == "pallas":
        return ei_update_pallas(u, eps_hist, psi, C)
    if impl == "pallas_interpret":
        return ei_update_pallas(u, eps_hist, psi, C, interpret=True)
    if impl == "ref":
        return ei_update_ref(u, eps_hist, psi, C)
    raise ValueError(impl)

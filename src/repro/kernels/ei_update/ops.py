"""Dispatch wrapper for the fused EI-update kernel + the canonical packing
layer.

`ei_update(u, eps_hist, psi, C)` with state (B, k, D).  The SDE samplers —
and, since the multi-family serving refactor, the `DiffusionEngine`'s whole
slot pool — flatten their state into this canonical layout via
`pack_state`/`unpack_state` (VPSDE: k=1; CLD: k=2 channel axis; BDM routes
its DCT-frequency state through the dct2 path and lands here with k=1).

The packing layer is family-generic:

  * `pack_state(u, k, k_pad=None)` flattens (B, [k,] *data) to (B, k, D)
    and optionally zero-pads the channel axis to `k_pad` rows, so one slot
    pool can host families of different structural width (k_max = max over
    resident families; padding rows stay identically zero).
  * `unpack_state(z, shape, k=None)` inverts it, dropping padding rows.
  * `apply_packed(coeff, z)` applies a per-example canonical coefficient
    (B, k, k, D) — the dense block-diagonal-per-entry form every family's
    structured coefficient embeds into (scalar: c I, CLD block: M ⊗ 1_D,
    BDM freq-diag: diag over D) — to a packed state (B, k, D).
"""
from __future__ import annotations

from typing import Optional, Tuple

import numpy as np
import jax
import jax.numpy as jnp

from .ref import ei_update_ref
from .kernel import ei_update as ei_update_pallas

Array = jax.Array


def pad_channels(z: Array, k_pad: int) -> Array:
    """Zero-pad a packed (B, k, D) state's channel axis to k_pad rows.
    The single shared implementation of canonical-layout padding (used by
    `pack_state`, the serve step's eps/noise packing, and the engine's
    prior admission)."""
    k = z.shape[1]
    if k_pad < k:
        raise ValueError(f"k_pad {k_pad} < k {k}")
    if k_pad == k:
        return z
    return jnp.concatenate(
        [z, jnp.zeros((z.shape[0], k_pad - k) + z.shape[2:], z.dtype)],
        axis=1)


def pack_state(u: Array, k: int, k_pad: Optional[int] = None,
               ) -> Tuple[Array, Tuple[int, ...]]:
    """(B, [k,] *data) -> (B, k_pad or k, D) plus the original shape for
    unpack.  Padding rows (k..k_pad) are zeros."""
    shape = u.shape
    B = shape[0]
    z = u.reshape(B, k, -1)
    if k_pad is not None:
        z = pad_channels(z, k_pad)
    return z, shape


def unpack_state(u: Array, shape: Tuple[int, ...],
                 k: Optional[int] = None) -> Array:
    """Invert `pack_state`: drop padding rows (when the packed `u` is wider
    than the original k rows) and restore `shape`."""
    if k is not None and u.shape[1] > k:
        u = u[:, :k]
    return u.reshape(shape)


def apply_packed(coeff: Array, z: Array) -> Array:
    """Per-example canonical coefficient application:
    coeff (B, k, k, D) x z (B, k, D) -> (B, k, D)."""
    return jnp.einsum("bijd,bjd->bid", coeff, z)


def ei_update(u: Array, eps_hist: Array, psi: Array, C: Array,
              impl: str = "auto") -> Array:
    """u: (B, k, D); eps_hist: (q, B, k, D); psi (k, k); C (q, k, k)."""
    if impl == "auto":
        impl = "pallas" if jax.default_backend() == "tpu" else "ref"
    if impl == "pallas":
        return ei_update_pallas(u, eps_hist, psi, C)
    if impl == "pallas_interpret":
        return ei_update_pallas(u, eps_hist, psi, C, interpret=True)
    if impl == "ref":
        return ei_update_ref(u, eps_hist, psi, C)
    raise ValueError(impl)

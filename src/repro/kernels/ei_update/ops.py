"""Dispatch wrapper for the fused EI-update kernel.

`ei_update(u, eps_hist, psi, C)` with state (B, k, D).  The SDE samplers
flatten their state into this canonical layout via `pack_state`/`unpack_state`
(VPSDE: k=1; CLD: k=2 channel axis).  BDM routes through the dct2 kernel
instead (frequency-diagonal coefficients).
"""
from __future__ import annotations

from typing import Tuple

import numpy as np
import jax
import jax.numpy as jnp

from .ref import ei_update_ref
from .kernel import ei_update as ei_update_pallas

Array = jax.Array


def pack_state(u: Array, k: int) -> Tuple[Array, Tuple[int, ...]]:
    """(B, [k,] *data) -> (B, k, D) plus the original shape for unpack."""
    shape = u.shape
    B = shape[0]
    if k == 1:
        return u.reshape(B, 1, -1), shape
    return u.reshape(B, k, -1), shape


def unpack_state(u: Array, shape: Tuple[int, ...]) -> Array:
    return u.reshape(shape)


def ei_update(u: Array, eps_hist: Array, psi: Array, C: Array,
              impl: str = "auto") -> Array:
    """u: (B, k, D); eps_hist: (q, B, k, D); psi (k, k); C (q, k, k)."""
    if impl == "auto":
        impl = "pallas" if jax.default_backend() == "tpu" else "ref"
    if impl == "pallas":
        return ei_update_pallas(u, eps_hist, psi, C)
    if impl == "pallas_interpret":
        return ei_update_pallas(u, eps_hist, psi, C, interpret=True)
    if impl == "ref":
        return ei_update_ref(u, eps_hist, psi, C)
    raise ValueError(impl)

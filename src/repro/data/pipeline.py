"""Deterministic synthetic data pipelines (host-sharded, restartable).

Every batch is a pure function of (seed, step, process_index), so

  * restart-from-checkpoint is exact: restoring `step` reproduces the
    stream with no host-side state files;
  * arbitrary step re-entry supports elastic re-meshing and the
    synchronous-with-backup straggler story (a backup host generates the
    *same* shard deterministically);
  * multi-host sharding is index-based (each process materializes only its
    slice of the global batch).

Pipelines:
  TokenPipeline    — Zipf-ish synthetic LM tokens with a learnable bigram
                     structure (so loss actually decreases in examples).
  MixturePipeline  — Gaussian-mixture draws for the diffusion side (the
                     paper's toy data; exact score available from sde.mixture).
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Tuple

import numpy as np
import jax
import jax.numpy as jnp

Array = jax.Array


@dataclasses.dataclass
class TokenPipeline:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    n_process: int = 1
    process_index: int = 0
    prefetch: int = 2

    @property
    def local_batch(self) -> int:
        assert self.global_batch % self.n_process == 0
        return self.global_batch // self.n_process

    def batch_at(self, step: int) -> Tuple[np.ndarray, np.ndarray]:
        """(tokens, labels) of shape (local_batch, seq_len), deterministic."""
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step, self.process_index]))
        B, S, V = self.local_batch, self.seq_len, self.vocab
        # structured stream: blockwise-repeating motifs + Zipf marginals, so a
        # model can reduce loss below uniform quickly (used by examples/).
        base = rng.zipf(1.5, size=(B, S + 1)) % V
        motif = rng.integers(0, V, size=(B, 8))
        pos = np.arange(S + 1) % 8
        mix = rng.random((B, S + 1)) < 0.5
        stream = np.where(mix, motif[:, pos], base).astype(np.int32)
        return stream[:, :-1], stream[:, 1:]

    def iterator(self, start_step: int = 0) -> Iterator[dict]:
        step = start_step
        while True:
            tokens, labels = self.batch_at(step)
            yield {"tokens": jnp.asarray(tokens), "labels": jnp.asarray(labels),
                   "step": step}
            step += 1


@dataclasses.dataclass
class MixturePipeline:
    means: np.ndarray              # (M, *data_shape)
    stds: np.ndarray               # (M,)
    weights: np.ndarray            # (M,)
    global_batch: int
    seed: int = 0
    n_process: int = 1
    process_index: int = 0

    @property
    def local_batch(self) -> int:
        return self.global_batch // self.n_process

    def batch_at(self, step: int) -> np.ndarray:
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step, self.process_index]))
        w = np.asarray(self.weights, np.float64)
        w = w / w.sum()
        idx = rng.choice(len(w), size=self.local_batch, p=w)
        mu = np.asarray(self.means)[idx]
        sd = np.asarray(self.stds)[idx].reshape((-1,) + (1,) * (mu.ndim - 1))
        return (mu + sd * rng.standard_normal(mu.shape)).astype(np.float32)

    def iterator(self, start_step: int = 0) -> Iterator[dict]:
        step = start_step
        while True:
            yield {"x0": jnp.asarray(self.batch_at(step)), "step": step}
            step += 1

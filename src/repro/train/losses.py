"""DSM / HSM training losses with the gDDIM score parameterization.

Paper Eq. 5 (DSM, eps-parameterization) and Eq. 77 (HSM for CLD with K_t =
R_t).  The weight choice is the paper's: R_t^{-1} Lambda_t R_t^{-T} = I, i.e.
a plain MSE on the predicted noise — but with the crucial twist that for CLD
both channels of eps are supervised (Eq. 80), unlike Dockhorn et al.'s
L_t-parameterization which only trains the velocity channel (Eq. 79).

Time-dependent coefficients (Psi(t,0), K_t) are precomputed on a dense table
and gathered per-example inside the jitted loss — the device never solves
ODEs (Stage-I/Stage-II split, paper App. C.3/C.4).
"""
from __future__ import annotations

from typing import Callable, NamedTuple

import numpy as np
import jax
import jax.numpy as jnp

from ..sde.base import LinearSDE

Array = jax.Array


class PerturbTables(NamedTuple):
    """Dense coefficient tables over a uniform t-grid in [t_min, T]."""
    ts: Array          # (n_table,)
    psi: Array         # (n_table, *coeff)   Psi(t, 0)
    K: Array           # (n_table, *coeff)   chosen K_t (R/L/sqrt)
    K_invT: Array      # (n_table, *coeff)   K_t^{-T} = Sigma^{-1} K
    lam_w: Array       # (n_table, *coeff)   loss weight factor (identity default)


def build_perturb_tables(sde: LinearSDE, kt: str = "R", n_table: int = 1024) -> PerturbTables:
    from ..core.coeffs import _K_fn
    ops = sde.ops
    K_fn = _K_fn(sde, kt)
    ts = np.linspace(sde.t_min, sde.T, n_table)
    psi, K, KiT = [], [], []
    for t in ts:
        t = float(t)
        psi.append(np.asarray(sde.Psi_np(t, 0.0), np.float64))
        Kt = np.asarray(K_fn(t), np.float64)
        K.append(Kt)
        KiT.append(np.asarray(ops.mul(ops.inv(sde.Sigma_np(t)), Kt), np.float64))
    f32 = lambda x: jnp.asarray(np.stack(x), jnp.float32)
    eye = jnp.asarray(np.broadcast_to(np.asarray(ops.eye()), np.stack(K).shape).copy(),
                      jnp.float32)
    return PerturbTables(jnp.asarray(ts, jnp.float32), f32(psi), f32(K), f32(KiT), eye)


def _gather(table: Array, idx: Array) -> Array:
    return table[idx]


def table_index(tables: PerturbTables, t: Array) -> Array:
    ts = tables.ts
    frac = (t - ts[0]) / (ts[-1] - ts[0])
    return jnp.clip(jnp.round(frac * (ts.shape[0] - 1)).astype(jnp.int32),
                    0, ts.shape[0] - 1)


def dsm_loss(
    sde: LinearSDE,
    tables: PerturbTables,
    eps_model: Callable[[Array, Array], Array],
    x0: Array,
    key: Array,
) -> Array:
    """E_t E_eps || eps - eps_theta(Psi_t u0 + K_t eps, t) ||^2  (Eq. 5/77).

    `eps_model(u, t)` consumes the state and the *continuous* time.  For CLD
    the data is lifted with a Gaussian velocity draw (hybrid score matching:
    the analytic v0-marginalization is what makes Sigma_0 = diag(0, gamma M)
    the correct covariance — see cld.py)."""
    k_t, k_aug, k_eps = jax.random.split(key, 3)
    B = x0.shape[0]
    t = jax.random.uniform(k_t, (B,), minval=sde.t_min, maxval=sde.T)
    u0 = sde.augment_data(x0, None)  # mean-lift: v0 noise is carried by Sigma_t
    idx = table_index(tables, t)
    psi = _gather(tables.psi, idx)
    K = _gather(tables.K, idx)
    eps = sde.noise_like(k_eps, u0.shape, u0.dtype)
    u_t = sde.apply_batched(psi, u0) + sde.apply_batched(K, eps)
    pred = eps_model(u_t, t)
    return jnp.mean(jnp.square(pred - eps))


def make_eps_fn_from_model(
    sde: LinearSDE,
    model: Callable[[Array, Array], Array],
    ts_grid: np.ndarray,
):
    """Adapt a trained eps-model to the sampler contract eps_fn(u, i)."""
    ts_dev = jnp.asarray(np.asarray(ts_grid), jnp.float32)

    def eps_fn(u: Array, i: Array) -> Array:
        t = jnp.full((u.shape[0],), 1.0, u.dtype) * ts_dev[i]
        return model(u, t)

    return eps_fn

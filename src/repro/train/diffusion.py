"""Diffusion training + sampling glue: the paper's pipeline end to end.

DiffusionSpec binds (SDE family, score network, K_t choice) into the same
uniform surface the LM archs get from models.registry:

    init(key)                      -> params
    eps_model(params, u, t)        -> eps prediction
    loss(params, batch, key)       -> DSM/HSM scalar (paper Eq. 5/77)
    make_sampler(params, ...)      -> jitted gDDIM sampler over a grid

Stage-I constants (perturbation tables for training, sampler coefficients
for inference) are built host-side once and cached on the spec.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Tuple

import numpy as np
import jax
import jax.numpy as jnp

from ..sde.base import LinearSDE
from ..core import build_sampler_coeffs, time_grid, sample_gddim, \
    sample_gddim_stochastic, sample_em, sample_heun
from ..models import score_net
from . import losses

Array = jax.Array


@dataclasses.dataclass
class DiffusionSpec:
    name: str
    sde: LinearSDE
    data_shape: Tuple[int, ...]
    score_family: str               # "mlp" | "dit"
    score_cfg: Any
    kt: str = "R"                   # the gDDIM choice; "L" = Dockhorn baseline

    def __post_init__(self):
        self._tables = None

    # ---- params ---------------------------------------------------------------
    def init(self, key) -> Any:
        if self.score_family == "mlp":
            return score_net.mlp_score_init(key, self.score_cfg)
        return score_net.dit_init(key, self.score_cfg)

    def param_shapes(self):
        return jax.eval_shape(lambda: self.init(jax.random.PRNGKey(0)))  # staticcheck: disable=SC102 (eval_shape: the key is abstract, no bits are ever drawn)

    def eps_model(self, params: Any, u: Array, t: Array) -> Array:
        if self.score_family == "mlp":
            return score_net.mlp_score_apply(params, self.score_cfg, u, t)
        return score_net.dit_apply(params, self.score_cfg, u, t)

    # ---- training ---------------------------------------------------------------
    @property
    def tables(self) -> losses.PerturbTables:
        if self._tables is None:
            self._tables = losses.build_perturb_tables(self.sde, kt=self.kt)
        return self._tables

    def loss(self, params: Any, x0: Array, key) -> Array:
        return losses.dsm_loss(self.sde, self.tables,
                               lambda u, t: self.eps_model(params, u, t),
                               x0, key)

    def input_specs(self, global_batch: int):
        """ShapeDtypeStructs for the diffusion train step (dry-run)."""
        return {"x0": jax.ShapeDtypeStruct((global_batch,) + tuple(self.data_shape),
                                           jnp.float32)}

    def serve_input_specs(self, global_batch: int):
        state = (global_batch,) + self.sde.state_shape(tuple(self.data_shape))
        return {"u": jax.ShapeDtypeStruct(state, jnp.float32),
                "i": jax.ShapeDtypeStruct((), jnp.int32)}

    # ---- sampling ------------------------------------------------------------------
    def make_eps_fn(self, params: Any, ts: np.ndarray) -> Callable:
        return losses.make_eps_fn_from_model(
            self.sde, lambda u, t: self.eps_model(params, u, t), ts)

    def sample(self, params: Any, key, n: int, nfe: int, *, q: int = 2,
               lam: float = 0.0, corrector: bool = False,
               method: str = "gddim", grid: str = "quadratic") -> Array:
        ts = time_grid(self.sde, nfe, grid)
        co = build_sampler_coeffs(self.sde, ts, q=q, lam=lam, kt=self.kt)
        eps_fn = self.make_eps_fn(params, ts)
        k1, k2 = jax.random.split(
            jax.random.PRNGKey(0) if key is None else key)  # staticcheck: disable=SC102 (opt-in deterministic default when the caller passes key=None)
        u_T = self.sde.prior_sample(k1, n, tuple(self.data_shape))
        if method == "gddim":
            if lam > 0:
                u0 = sample_gddim_stochastic(self.sde, co, eps_fn, u_T, k2)
            else:
                u0 = sample_gddim(self.sde, co, eps_fn, u_T, q=q, corrector=corrector)
        elif method == "em":
            u0 = sample_em(self.sde, co, eps_fn, u_T, k2, lam=max(lam, 1.0))
        elif method == "heun":
            u0 = sample_heun(self.sde, co, eps_fn, u_T)
        else:
            raise ValueError(method)
        return self.sde.project_data(u0)

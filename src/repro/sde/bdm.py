"""Blurring diffusion model (Hoogeboom & Salimans 2022; paper Eq. 11, App. B.1).

Forward noising in DCT frequency space with per-frequency signal schedule:

    p(y_t | y_0) = N(alpha_{t,k} y_0, sigma_t^2 I),   y = V^T x  (DCT)

with  alpha_{t,k} = a_t * exp(-lam_k * tau_t)   (blur dissipation) and the
variance-preserving scalar pair (a_t, sigma_t) = (cos, sin)(pi t / 2)
(cosine schedule), tau_t = (sigma_B_max * sin^2(pi t / 2))^2 / 2, and
heat-equation eigenvalues lam_k = pi^2 (kx^2/W^2 + ky^2/H^2).

As an SDE (paper Eq. 11):

    F_t = d log alpha_t / dt        (freq-diagonal)
    G_t^2 = d sigma_t^2/dt - 2 F_t sigma_t^2        (freq-diagonal, >= 0)

Note Sigma_t = sigma_t^2 I is *isotropic* even though the drift is not; hence
R_t = sigma_t I already satisfies Eq. 17 and K_t = R_t = L_t.  The gDDIM win
on BDM is therefore purely the exponential integrator over the non-isotropic
semi-linear drift (per-frequency Psi), versus ancestral/EM discretization —
exactly the >20x acceleration the paper reports in Table 3.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Tuple

import numpy as np
import jax
import jax.numpy as jnp

from .base import LinearSDE, FreqDiagOps, dct_nd, idct_nd

Array = jax.Array


@dataclasses.dataclass
class BDM(LinearSDE):
    data_shape: Tuple[int, ...] = (32, 32, 3)   # (H, W, C) or (N,) for 1-D toys
    sigma_blur_max: float = 3.0
    min_scale: float = 0.001                    # floor on frequency scaling (HS22 App. A)
    # T is clipped below 1 so alpha_T = cos(pi T/2) stays > 0 (the cosine
    # schedule hits exactly zero SNR at t=1, which breaks eps->x0 conversion
    # in the ancestral baseline; standard Nichol-Dhariwal-style clipping).
    T: float = 0.999
    t_min: float = 1e-3

    def __post_init__(self):
        spatial = self.data_shape[:-1] if len(self.data_shape) >= 2 else self.data_shape
        self.spatial_axes_in_data = tuple(range(len(spatial)))
        self._freq_shape = tuple(spatial) + (1,) * (len(self.data_shape) - len(spatial))
        self._ops = FreqDiagOps(self._freq_shape)

    @property
    def ops(self):
        return self._ops

    @functools.cached_property
    def lam(self) -> np.ndarray:
        """Heat-dissipation eigenvalues on the DCT grid, shaped `freq_shape`."""
        spatial = self._freq_shape[:len(self.spatial_axes_in_data)]
        grids = np.meshgrid(*[np.arange(n, dtype=np.float64) for n in spatial],
                            indexing="ij")
        lam = sum((np.pi * g / n) ** 2 for g, n in zip(grids, spatial))
        return lam.reshape(self._freq_shape)

    # ---- scalar schedule pieces ----------------------------------------------
    def a(self, t):
        return np.cos(np.pi * t / 2.0)

    def sigma2(self, t):
        return np.sin(np.pi * t / 2.0) ** 2

    def dlog_a(self, t):
        return -(np.pi / 2.0) * np.tan(np.pi * t / 2.0)

    def dsigma2(self, t):
        return np.pi * np.sin(np.pi * t / 2.0) * np.cos(np.pi * t / 2.0)

    def tau(self, t):
        s = np.sin(np.pi * t / 2.0)
        return (self.sigma_blur_max * s * s) ** 2 / 2.0

    def dtau(self, t):
        s, c = np.sin(np.pi * t / 2.0), np.cos(np.pi * t / 2.0)
        return self.sigma_blur_max ** 2 * np.pi * (s ** 3) * c

    # ---- freq-diag coefficients ------------------------------------------------
    def alpha_k(self, t) -> np.ndarray:
        """Per-frequency signal coefficient alpha_{t,k} (with min-scale floor)."""
        d = np.exp(-self.lam * self.tau(t))
        d = (1.0 - self.min_scale) * d + self.min_scale
        return self.a(t) * d

    def F_np(self, t):
        # d log alpha_k/dt = dlog a - lam * dtau * d/(d + floor-correction)
        d_raw = np.exp(-self.lam * self.tau(t))
        d = (1.0 - self.min_scale) * d_raw + self.min_scale
        dd = -(1.0 - self.min_scale) * self.lam * self.dtau(t) * d_raw
        return self.dlog_a(t) + dd / d

    def G2_np(self, t):
        g2 = self.dsigma2(t) - 2.0 * self.F_np(t) * self.sigma2(t)
        return np.maximum(g2, 0.0)

    def Psi_np(self, t, s):
        return self.alpha_k(t) / self.alpha_k(s)

    def Sigma_np(self, t):
        return np.broadcast_to(np.float64(self.sigma2(t)), self._freq_shape).copy()

    def R_np(self, t):
        # sigma_t I solves Eq. 17 here because Sigma_t is isotropic (see module doc).
        return np.sqrt(self.Sigma_np(t))

    # ---- device side -------------------------------------------------------------
    def apply(self, coeff: Array, u: Array) -> Array:
        """u: (B, *data_shape); coeff: freq_shape (or stacked ...x freq_shape)."""
        axes = tuple(a + 1 for a in self.spatial_axes_in_data)  # skip batch
        coeff = jnp.asarray(coeff, u.dtype)
        return idct_nd(dct_nd(u, axes) * coeff, axes)

    def apply_batched(self, coeff: Array, u: Array) -> Array:
        # coeff: (B, *freq_shape) broadcasts against the per-example spectrum
        return self.apply(coeff, u)

    def apply_factored(self, blk: Array, diag: Array, u: Array) -> Array:
        """Factored-coefficient application in BDM's linear basis (DCT
        frequency space): `factor_coeff` gives freq-diagonal coefficients
        the trivial e00 block and the real (D,) diagonal, so this is
        `idct(diag * dct(u))` up to the exact 1-multiplications — bitwise
        equal to `apply` (both ride the reference dct_nd path; the serving
        engine's frequency-resident dct2-kernel path is pinned against
        this oracle by tests/test_factored_bank.py)."""
        from .base import _apply_factored_canonical
        y = self.to_freq(u)
        z = y.reshape(y.shape[0], 1, -1)
        out = _apply_factored_canonical(blk, diag, z)
        return self.from_freq(out.reshape(y.shape))

    def to_freq(self, u: Array) -> Array:
        axes = tuple(a + 1 for a in self.spatial_axes_in_data)
        return dct_nd(u, axes)

    def from_freq(self, y: Array) -> Array:
        axes = tuple(a + 1 for a in self.spatial_axes_in_data)
        return idct_nd(y, axes)

    # ---- canonical packed layout: BDM is *frequency-resident* ---------------
    # The (B, 1, D) canonical state holds DCT coefficients, so every bank
    # coefficient acts elementwise over D; the serving step pays one
    # idct (model input) + one dct (eps) per evaluation instead of a
    # dct/idct round trip per `apply` (≈6 applies per gDDIM step).
    # Only these engine hooks ride the dct2 *kernel* path (Pallas on TPU;
    # its reference impl is bitwise dct_nd elsewhere) — to_freq/from_freq
    # above stay on dct_nd so the lockstep reference samplers and the
    # mixture oracle keep their exact historical numerics on every backend.
    def _dct2(self, u: Array, inverse: bool) -> Array:
        axes = tuple(a + 1 for a in self.spatial_axes_in_data)
        if axes == (1, 2) and u.ndim == 4:
            from ..kernels.dct2.ops import dct2
            return dct2(u, inverse=inverse)
        return idct_nd(u, axes) if inverse else dct_nd(u, axes)

    # canonicalize is a DCT, not a reshape: the fused round kernel cannot
    # draw this family's Eq. 22 noise in-kernel (see sde/base.py)
    canonical_noise_is_reshape = False

    def canonicalize(self, u: Array) -> Array:
        return self._dct2(u, inverse=False).reshape(u.shape[0], 1, -1)

    def decanonicalize(self, z: Array, data_shape: Tuple[int, ...]) -> Array:
        return self._dct2(z.reshape((z.shape[0],) + tuple(data_shape)),
                          inverse=True)

    def ancestral_coeffs(self, ts: np.ndarray):
        """Discrete ancestral-sampling coefficients (HS22's original sampler).

        For the Gaussian posterior q(u_s | u_t, u_0) of the discretized
        frequency-space process with s < t:
            mean = (alpha_ts * sigma_s^2 / sigma_t^2) y_t
                 + (alpha_s * (1 - alpha_ts^2 sigma_s^2/sigma_t^2) / ...) — we
        use the standard DDPM-style form per frequency.  Returns stacked
        (coef_ut, coef_eps, std) arrays for each step t_i -> t_{i-1}.
        """
        outs = []
        for t, s in zip(ts[:-1], ts[1:]):
            a_t, a_s = self.alpha_k(t), self.alpha_k(s)
            s2_t, s2_s = self.sigma2(t), self.sigma2(s)
            a_ts = a_t / a_s
            s2_ts = np.maximum(s2_t - a_ts ** 2 * s2_s, 1e-20)
            denom = np.maximum(s2_t, 1e-20)
            coef_ut = a_ts * s2_s / denom
            coef_u0 = a_s * s2_ts / denom
            var = s2_ts * s2_s / denom
            # u0-prediction from eps: u0 = (u_t - sigma_t eps)/alpha_t  (per freq)
            outs.append((coef_ut, coef_u0, a_t, np.sqrt(s2_t), np.sqrt(var)))
        return [np.stack([o[i] for o in outs]) for i in range(5)]

"""A general anisotropic 2x2-block linear SDE — gDDIM's generality witness.

The paper claims gDDIM works for ANY du = F_t u dt + G_t dw (Sec. 4).  The
three built-in families all have special structure (scalar / critically
damped / freq-diagonal).  This SDE has none: arbitrary constant F (possibly
non-normal, rotating), full-rank anisotropic G, so

  * Sigma_t is a dense 2x2 (per data dim) with no closed form,
  * L_t (Cholesky) genuinely differs from R_t (Eq. 17),
  * Psi = expm(F t) mixes channels.

Used by tests to check, away from every special case: R R^T = Sigma on the
grid, Prop-4 eps-constancy, one-step Dirac recovery, and that the L_t
parameterization is measurably worse under multistep extrapolation.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Tuple

import numpy as np
import jax
import jax.numpy as jnp
import scipy.linalg

from .base import LinearSDE, BlockOps
from . import solve

Array = jax.Array


@dataclasses.dataclass
class GeneralSDE(LinearSDE):
    """du = F u dt + G dw with arbitrary constant 2x2 F, G."""
    F: Tuple[Tuple[float, float], Tuple[float, float]] = ((-0.5, 1.2), (-1.2, -0.5))
    G: Tuple[Tuple[float, float], Tuple[float, float]] = ((0.8, 0.0), (0.3, 1.1))
    T: float = 1.0
    t_min: float = 1e-3
    grid_substeps: int = 8

    _ops = BlockOps(2)

    def __post_init__(self):
        self._F = np.asarray(self.F, np.float64)
        self._G = np.asarray(self.G, np.float64)

    @property
    def ops(self):
        return self._ops

    @property
    def state_ndim_prefix(self) -> int:
        return 1

    def state_shape(self, data_shape):
        return (2,) + tuple(data_shape)

    def F_np(self, t):
        return self._F

    def G2_np(self, t):
        return self._G @ self._G.T

    def Psi_np(self, t, s):
        return scipy.linalg.expm(self._F * (t - s))

    def Sigma0_np(self):
        return np.zeros((2, 2))

    def _sigma_exact(self, t: float) -> np.ndarray:
        # Van Loan augmented exponential (same trick as cld.py)
        Q = self.G2_np(0.0)
        B = np.zeros((4, 4))
        B[:2, :2] = self._F
        B[:2, 2:] = Q
        B[2:, 2:] = -self._F.T
        E = scipy.linalg.expm(B * t)
        return E[:2, 2:] @ E[:2, :2].T

    def Sigma_np(self, t):
        return self._sigma_exact(float(t))

    @functools.cached_property
    def _R_grid(self) -> solve.GridFn:
        grid = solve.make_grid(1e-6, self.T)
        t0 = 1e-4
        grid = grid[grid >= t0]
        grid = np.concatenate([[t0], grid]) if grid[0] > t0 else grid
        R0 = self.ops.sqrt_psd(self.Sigma_np(float(grid[0])))
        G2 = self.G2_np(0.0)

        def rhs(t, R):
            S = self._sigma_exact(float(t))
            return (self._F + 0.5 * G2 @ np.linalg.inv(S)) @ R

        return solve.solve_on_grid(rhs, R0, grid, self.grid_substeps)

    def R_np(self, t):
        t = float(t)
        if t < float(self._R_grid.ts[0]):
            return self.ops.sqrt_psd(self.Sigma_np(t))
        return self._R_grid(t)

    # ---- device side ---------------------------------------------------------
    def apply(self, coeff: Array, u: Array) -> Array:
        coeff = jnp.asarray(coeff, u.dtype)
        return jnp.einsum("ij,bj...->bi...", coeff, u)

    def apply_batched(self, coeff: Array, u: Array) -> Array:
        coeff = jnp.asarray(coeff, u.dtype)
        return jnp.einsum("bij,bj...->bi...", coeff, u)

    def augment_data(self, x: Array, key=None) -> Array:
        return jnp.stack([x, jnp.zeros_like(x)], axis=1)

    def project_data(self, u: Array) -> Array:
        return u[:, 0]

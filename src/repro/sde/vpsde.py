r"""VPSDE / continuous-time DDPM (paper Eq. 8).

    F_t = 1/2 dlog(alpha_t)/dt * I,   G_t = sqrt(-dlog(alpha_t)/dt) * I

with alpha_t = exp(-\int_0^t beta(s) ds) the *squared* signal coefficient
(paper's alpha_t == DDPM's alpha-bar).  Linear beta schedule beta(t) =
beta_0 + t (beta_1 - beta_0) (Song et al. 2020b defaults 0.1 -> 20).

Everything is closed form, so this family doubles as the oracle for the
grid-based solvers (tests compare RK4 R_t / Sigma_t / Psi against these).
On VPSDE gDDIM *is* DDIM (paper Thm 1) — checked to machine precision in
tests/test_gddim_core.py.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import numpy as np
import jax
import jax.numpy as jnp

from .base import LinearSDE, ScalarOps

Array = jax.Array


@dataclasses.dataclass
class VPSDE(LinearSDE):
    beta0: float = 0.1
    beta1: float = 20.0
    T: float = 1.0
    t_min: float = 1e-3

    _ops = ScalarOps()

    @property
    def ops(self):
        return self._ops

    # ---- schedule -----------------------------------------------------------
    def log_alpha(self, t):
        # \int_0^t beta = beta0 t + (beta1-beta0) t^2/2
        return -(self.beta0 * t + 0.5 * (self.beta1 - self.beta0) * t * t)

    def alpha(self, t):
        return np.exp(self.log_alpha(t))

    def beta(self, t):
        return self.beta0 + (self.beta1 - self.beta0) * t

    # ---- coefficients (scalar family) ---------------------------------------
    def F_np(self, t):
        return np.float64(-0.5 * self.beta(t))

    def G2_np(self, t):
        return np.float64(self.beta(t))

    def Psi_np(self, t, s):
        return np.sqrt(self.alpha(t) / self.alpha(s))

    def Sigma_np(self, t):
        return np.float64(1.0 - self.alpha(t))

    def R_np(self, t):
        # K_t = sqrt(1 - alpha_t): the unique solution of Eq. 17 from Sigma_0=0.
        return np.sqrt(1.0 - self.alpha(t))

    def L_np(self, t):
        return self.R_np(t)  # isotropic => R == L == sqrt(Sigma)

    def Psi_hat_np(self, t, s, lam: float):
        """Closed-form lambda-family transition (paper Eq. 61)."""
        at, as_ = self.alpha(t), self.alpha(s)
        return ((1.0 - at) / (1.0 - as_)) ** (0.5 * (1.0 + lam * lam)) * \
               (as_ / at) ** (0.5 * lam * lam)

    def P_np(self, s, t, lam: float):
        """Closed-form injected variance (paper Thm 1 covariance)."""
        at, as_ = self.alpha(t), self.alpha(s)
        return (1.0 - at) * (1.0 - ((1.0 - at) / (1.0 - as_)) ** (lam * lam) *
                             (as_ / at) ** (lam * lam))

    # ---- device side ---------------------------------------------------------
    def apply(self, coeff: Array, u: Array) -> Array:
        coeff = jnp.asarray(coeff, u.dtype)
        return coeff * u

    def apply_batched(self, coeff: Array, u: Array) -> Array:
        c = jnp.asarray(coeff, u.dtype).reshape((-1,) + (1,) * (u.ndim - 1))
        return c * u

    def state_shape(self, data_shape: Tuple[int, ...]) -> Tuple[int, ...]:
        return data_shape

    def ddim_step_reference(self, u, eps, t, t_prev, sigma=0.0):
        """Song et al. (2020a) DDIM update, Eq. 9 — the oracle for Thm 1 tests."""
        a_t, a_p = self.alpha(t), self.alpha(t_prev)
        c1 = np.sqrt(a_p / a_t)
        c2 = np.sqrt(max(1.0 - a_p - sigma**2, 0.0)) - np.sqrt(1.0 - a_t) * c1
        return c1 * u + c2 * eps

"""Linear-SDE substrate: the diffusion processes gDDIM generalizes over."""
from .base import LinearSDE, ScalarOps, BlockOps, FreqDiagOps, dct_nd, idct_nd, dct_matrix
from .vpsde import VPSDE
from .cld import CLD
from .bdm import BDM
from .mixture import GaussianMixture, ExactScore
from .general import GeneralSDE
from . import solve

__all__ = [
    "LinearSDE", "ScalarOps", "BlockOps", "FreqDiagOps",
    "dct_nd", "idct_nd", "dct_matrix",
    "VPSDE", "CLD", "BDM", "GeneralSDE", "GaussianMixture", "ExactScore", "solve",
]

"""Host-side numerical solvers for the Stage-I coefficient pipeline.

The paper (App. C.3) computes every sampler coefficient offline:

  Type I  — matrix ODEs: R_t (Eq. 17), Psi_hat(t, s) (Eq. 81), P_st (Eq. 23),
            Sigma_t (Lyapunov, Eq. 27) — solved with RK4 on a fine grid.
  Type II — definite integrals: the exponential-integrator predictor /
            corrector constants pC, cC (Eqs. 41/46) — composite quadrature.

Everything here is pure numpy float64 and runs once per (SDE, time grid);
results are cached and then shipped to the device as stacked jnp arrays.
The per-family coefficients are tiny (scalar / 2x2 / per-frequency diag), so
even the paper's 1e-6-step RK4 is cheap; we default to a log+linear grid with
RK4 substeps which matches the paper's accuracy at far lower cost (validated
in tests against closed forms on VPSDE).
"""
from __future__ import annotations

from typing import Callable, Sequence

import numpy as np


def rk4_step(rhs: Callable, t: float, y, h: float):
    k1 = rhs(t, y)
    k2 = rhs(t + 0.5 * h, y + 0.5 * h * k1)
    k3 = rhs(t + 0.5 * h, y + 0.5 * h * k2)
    k4 = rhs(t + h, y + h * k3)
    return y + (h / 6.0) * (k1 + 2.0 * k2 + 2.0 * k3 + k4)


def integrate_ode(rhs: Callable, y0, t0: float, t1: float, n_steps: int):
    """RK4 from t0 to t1 (t1 may be < t0) in n_steps equal steps."""
    h = (t1 - t0) / n_steps
    t, y = t0, y0
    for _ in range(n_steps):
        y = rk4_step(rhs, t, y, h)
        t += h
    return y


def make_grid(t_lo: float, t_hi: float, n_log: int = 2048, n_lin: int = 2048) -> np.ndarray:
    """Time grid dense near t_lo (where CLD's Sigma_t^{-1} is stiff, and where
    R^{-1} amplifies interpolation error) + linear body.  The log segment
    spans t_lo..0.1*t_hi so the near-origin spacing is ~1e-5."""
    knee = min(0.1 * t_hi, t_hi)
    lo = np.geomspace(max(t_lo, 1e-8), knee, n_log)
    lin = np.linspace(knee, t_hi, n_lin)
    g = np.unique(np.concatenate([lo, lin]))
    return g


class GridFn:
    """Piecewise-linear interpolant of a coeff-valued function on a grid.

    Values are stacked along axis 0; linear interpolation in t (the paper
    interpolates its RK4 output the same way, App. C.3 Type I).
    """

    def __init__(self, ts: np.ndarray, values: np.ndarray):
        self.ts = np.asarray(ts, np.float64)
        self.values = np.asarray(values, np.float64)

    def __call__(self, t):
        t = np.asarray(t, np.float64)
        idx = np.clip(np.searchsorted(self.ts, t) - 1, 0, len(self.ts) - 2)
        t0, t1 = self.ts[idx], self.ts[idx + 1]
        w = np.where(t1 > t0, (t - t0) / np.where(t1 > t0, t1 - t0, 1.0), 0.0)
        v0, v1 = self.values[idx], self.values[idx + 1]
        w = w.reshape(w.shape + (1,) * (self.values.ndim - 1 - t.ndim))
        return (1.0 - w) * v0 + w * v1


def solve_on_grid(rhs: Callable, y0, ts: np.ndarray, substeps: int = 8) -> GridFn:
    """Integrate dy/dt = rhs(t, y) across the grid, `substeps` RK4 steps/interval."""
    ys = [np.asarray(y0, np.float64)]
    y = ys[0]
    for a, b in zip(ts[:-1], ts[1:]):
        y = integrate_ode(rhs, y, float(a), float(b), substeps)
        ys.append(y)
    return GridFn(ts, np.stack(ys))


def simpson_nodes(a: float, b: float, n: int):
    """Composite-Simpson nodes & weights on [a, b] (n even panels)."""
    if n % 2:
        n += 1
    xs = np.linspace(a, b, n + 1)
    w = np.ones(n + 1)
    w[1:-1:2] = 4.0
    w[2:-1:2] = 2.0
    w *= (b - a) / (3.0 * n)
    return xs, w


def quad_coeff(integrand: Callable[[float], np.ndarray], a: float, b: float,
               n: int = 64, adaptive: bool = True, rtol: float = 1e-7,
               n_max: int = 1536) -> np.ndarray:
    """Definite integral of a coeff-valued integrand via composite Simpson.

    Used for the exponential-integrator constants pC/cC (paper Eqs. 41/46)
    and the single-step EI coefficient (Eq. 18). Signed interval (b < a ok).
    With `adaptive`, panel count doubles until the result is stable to
    `rtol` — needed on stiff intervals reaching toward t_min where the
    integrand grows like Sigma^{-1} ~ t^{-3} (CLD).
    """
    def run(m):
        xs, w = simpson_nodes(a, b, m)
        acc = None
        for x, wx in zip(xs, w):
            v = wx * np.asarray(integrand(float(x)), np.float64)
            acc = v if acc is None else acc + v
        return acc

    out = run(n)
    if not adaptive:
        return out
    while n < n_max:
        n *= 2
        nxt = run(n)
        scale = max(np.max(np.abs(nxt)), 1e-12)
        if np.max(np.abs(nxt - out)) <= rtol * scale:
            return nxt
        out = nxt
    return out


def lagrange_basis(nodes: Sequence[float], j: int) -> Callable[[float], float]:
    """The j-th Lagrange basis polynomial over `nodes` (paper Eq. 39/44)."""
    nodes = [float(x) for x in nodes]

    def ell(tau: float) -> float:
        num, den = 1.0, 1.0
        for k, tk in enumerate(nodes):
            if k == j:
                continue
            num *= tau - tk
            den *= nodes[j] - tk
        return num / den

    return ell

"""Exact scores for Gaussian-mixture data under any structured linear SDE.

The paper's analysis (Props 1-5, Fig. 2/4) is built on Dirac/Gaussian data
where the score is closed-form.  Because a Gaussian mixture stays a Gaussian
mixture under a linear SDE, we get an *exact score oracle* for all three
families (VPSDE / CLD / BDM):

    p_t(u) = sum_m w_m N(u; Psi(t,0) mu~_m, C_m(t)),
    C_m(t) = Psi(t,0) S0_m Psi(t,0)^T + Sigma_t,
    score  = sum_m gamma_m(u) * (-C_m(t)^{-1} (u - Psi mu~_m)),

with S0_m the per-mode data covariance (s_m^2 on the data channels) and
Sigma_t the SDE marginal covariance (which for CLD already includes the
gamma*M velocity initialization).  This module powers:

  * tests of Props 1-7 (epsilon-constancy, one-step recovery, score recovery),
  * the benchmark analogs of the paper's Tables 1/2/3/5/8 (exact-score
    sampling scored by sliced Wasserstein-2 against ground truth).

Time-dependent constants are computed host-side (float64) per sampling grid
and shipped to the device as stacked arrays, mirroring the paper's Stage-I /
Stage-II split.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Sequence

import numpy as np
import jax
import jax.numpy as jnp

from .base import LinearSDE

Array = jax.Array


def _quad_form(sde: LinearSDE, cinv, delta: Array) -> Array:
    """delta^T C^{-1} delta, batched over axis 0."""
    fam = sde.ops.family
    if fam == "scalar":
        return jnp.asarray(cinv, delta.dtype) * jnp.sum(
            delta * delta, axis=tuple(range(1, delta.ndim)))
    if fam == "block":
        ci = jnp.asarray(cinv, delta.dtype)
        tmp = jnp.einsum("ij,bj...->bi...", ci, delta)
        return jnp.sum(delta * tmp, axis=tuple(range(1, delta.ndim)))
    if fam == "freqdiag":
        dh = sde.to_freq(delta)
        ci = jnp.asarray(cinv, delta.dtype)
        return jnp.sum(dh * dh * ci, axis=tuple(range(1, delta.ndim)))
    raise ValueError(fam)


def _apply_sym(sde: LinearSDE, coeff, delta: Array) -> Array:
    """Apply a symmetric family coeff (e.g. C^{-1}) to a batched state."""
    fam = sde.ops.family
    if fam == "freqdiag":
        return sde.from_freq(sde.to_freq(delta) * jnp.asarray(coeff, delta.dtype))
    return sde.apply(jnp.asarray(coeff, delta.dtype), delta)


def _logdet(sde: LinearSDE, C, data_shape) -> float:
    fam = sde.ops.family
    D = int(np.prod(data_shape))
    if fam == "scalar":
        return D * float(np.log(C))
    if fam == "block":
        return D * float(np.log(np.linalg.det(C)))
    if fam == "freqdiag":
        full = np.broadcast_to(C, data_shape)
        return float(np.sum(np.log(full)))
    raise ValueError(fam)


@dataclasses.dataclass
class GaussianMixture:
    """Mixture of isotropic Gaussians in data space."""

    means: np.ndarray          # (M, *data_shape)
    stds: np.ndarray           # (M,)
    weights: np.ndarray        # (M,)

    def __post_init__(self):
        self.means = np.asarray(self.means, np.float64)
        self.stds = np.asarray(self.stds, np.float64)
        self.weights = np.asarray(self.weights, np.float64)
        self.weights = self.weights / self.weights.sum()

    @property
    def data_shape(self):
        return self.means.shape[1:]

    def sample(self, key: Array, n: int, dtype=jnp.float32) -> Array:
        km, kn = jax.random.split(key)
        idx = jax.random.choice(km, len(self.weights), (n,),
                                p=jnp.asarray(self.weights, jnp.float32))
        mu = jnp.asarray(self.means, dtype)[idx]
        sd = jnp.asarray(self.stds, dtype)[idx].reshape((n,) + (1,) * len(self.data_shape))
        return mu + sd * jax.random.normal(kn, mu.shape, dtype)


class ExactScore:
    """Exact score / epsilon oracle for GaussianMixture data under `sde`."""

    def __init__(self, sde: LinearSDE, mixture: GaussianMixture):
        self.sde = sde
        self.mix = mixture
        self.data_shape = mixture.data_shape

    # ---- host-side per-time constants ---------------------------------------
    def _mode_constants(self, t: float):
        """Per-mode (mean_state, C_inv, logdet, logw) at time t (numpy)."""
        sde, ops = self.sde, self.sde.ops
        psi = sde.Psi_np(t, 0.0)
        sig = sde.Sigma_np(t)
        out = []
        for m in range(len(self.mix.weights)):
            s2 = float(self.mix.stds[m]) ** 2
            if ops.family == "scalar":
                S0 = np.float64(s2)
            elif ops.family == "block":
                S0 = np.array([[s2, 0.0], [0.0, 0.0]])  # data channel only
            else:  # freqdiag (orthonormal DCT preserves isotropy)
                S0 = s2 * ops.eye()
            C = ops.mul(ops.mul(psi, S0), ops.transpose(psi)) + sig
            Cinv = ops.inv(C)
            # state-space mean: lift data mean, push through Psi (host numpy)
            mu = self._augment_np(self.mix.means[m][None])  # (1, *state)
            mu_state = self._apply_np(psi, mu)[0]
            logdet = _logdet(self.sde, C, self._state_data_shape())
            out.append((mu_state, Cinv, logdet, float(np.log(self.mix.weights[m]))))
        return psi, out

    def _apply_np(self, coeff, u: np.ndarray) -> np.ndarray:
        """Host-side float64 twin of sde.apply."""
        fam = self.sde.ops.family
        if fam == "scalar":
            return coeff * u
        if fam == "block":
            return np.einsum("ij,bj...->bi...", coeff, u)
        # freqdiag: numpy DCT along spatial axes
        from .base import dct_matrix
        axes = tuple(a + 1 for a in self.sde.spatial_axes_in_data)
        y = u.astype(np.float64)
        for ax in axes:
            c = dct_matrix(y.shape[ax])
            y = np.moveaxis(np.tensordot(c, np.moveaxis(y, ax, 0), axes=1), 0, ax)
        y = y * coeff
        for ax in axes:
            c = dct_matrix(y.shape[ax]).T
            y = np.moveaxis(np.tensordot(c, np.moveaxis(y, ax, 0), axes=1), 0, ax)
        return y

    def _augment_np(self, x: np.ndarray) -> np.ndarray:
        if self.sde.state_ndim_prefix == 1:
            return np.stack([x, np.zeros_like(x)], axis=1)
        return x

    def _state_data_shape(self):
        return self.sde.state_shape(self.data_shape)

    # ---- host-side float64 score (for RK45 baselines & oracle checks) --------
    def score_np(self, u: np.ndarray, t: float) -> np.ndarray:
        """Exact grad log p_t(u) in float64 numpy (batched over axis 0)."""
        _, consts = self._mode_constants(float(t))
        u = np.asarray(u, np.float64)
        logps, deltas = [], []
        for mu, Cinv, logdet, logw in consts:
            delta = u - mu[None]
            if self.sde.ops.family == "scalar":
                qf = Cinv * np.sum(delta * delta, axis=tuple(range(1, delta.ndim)))
            elif self.sde.ops.family == "block":
                tmp = np.einsum("ij,bj...->bi...", Cinv, delta)
                qf = np.sum(delta * tmp, axis=tuple(range(1, delta.ndim)))
            else:
                dh = self._dct_np(delta)
                qf = np.sum(dh * dh * Cinv, axis=tuple(range(1, delta.ndim)))
            logps.append(logw - 0.5 * qf - 0.5 * logdet)
            deltas.append(delta)
        logp = np.stack(logps)
        gam = np.exp(logp - logp.max(0, keepdims=True))
        gam = gam / gam.sum(0, keepdims=True)
        out = np.zeros_like(u)
        for m, (mu, Cinv, _, _) in enumerate(consts):
            g = gam[m].reshape((-1,) + (1,) * (u.ndim - 1))
            if self.sde.ops.family == "freqdiag":
                term = self._idct_np(self._dct_np(deltas[m]) * Cinv)
            else:
                term = self._apply_np(Cinv, deltas[m])
            out = out - g * term
        return out

    def _dct_np(self, x):
        from .base import dct_matrix
        axes = tuple(a + 1 for a in self.sde.spatial_axes_in_data)
        for ax in axes:
            c = dct_matrix(x.shape[ax])
            x = np.moveaxis(np.tensordot(c, np.moveaxis(x, ax, 0), axes=1), 0, ax)
        return x

    def _idct_np(self, x):
        from .base import dct_matrix
        axes = tuple(a + 1 for a in self.sde.spatial_axes_in_data)
        for ax in axes:
            c = dct_matrix(x.shape[ax]).T
            x = np.moveaxis(np.tensordot(c, np.moveaxis(x, ax, 0), axes=1), 0, ax)
        return x

    # ---- device-side score ----------------------------------------------------
    def score(self, u: Array, t: float) -> Array:
        """Exact grad log p_t(u).  `t` is a static python float."""
        _, consts = self._mode_constants(float(t))
        dtype = u.dtype
        logps, deltas, cinvs = [], [], []
        for mu, Cinv, logdet, logw in consts:
            delta = u - jnp.asarray(mu, dtype)[None]
            qf = _quad_form(self.sde, Cinv, delta)
            logps.append(logw - 0.5 * qf - 0.5 * logdet)
            deltas.append(delta)
            cinvs.append(Cinv)
        logp = jnp.stack(logps, axis=0)                      # (M, B)
        gam = jax.nn.softmax(logp, axis=0)                   # responsibilities
        out = jnp.zeros_like(u)
        for m, (delta, Cinv) in enumerate(zip(deltas, cinvs)):
            g = gam[m].reshape((-1,) + (1,) * (u.ndim - 1)).astype(dtype)
            out = out - g * _apply_sym(self.sde, Cinv, delta)
        return out

    def eps(self, u: Array, t: float, K_np_fn: Callable[[float], np.ndarray] | None = None) -> Array:
        """epsilon_GT(u, t) = -K_t^T score (paper Eq. 4); default K = R_t."""
        K = K_np_fn(float(t)) if K_np_fn is not None else self.sde.R_np(float(t))
        KT = self.sde.ops.transpose(K)
        return -self.sde.apply(jnp.asarray(KT, u.dtype), self.score(u, t))

    def eps_fn_for_grid(self, ts: Sequence[float],
                        K_np_fn: Callable[[float], np.ndarray] | None = None):
        """Build eps(u, i) for a static time grid: all constants precomputed.

        Returns (eps_fn, n_steps) where eps_fn(u, i) uses stacked device
        tables — safe inside lax.scan / jit.
        """
        sde = self.sde
        K_np_fn = K_np_fn or sde.R_np
        mus, cinvs, logdets, logws, KTs = [], [], [], [], []
        for t in ts:
            _, consts = self._mode_constants(float(t))
            mus.append(np.stack([c[0] for c in consts]))
            cinvs.append(np.stack([np.asarray(c[1]) for c in consts]))
            logdets.append(np.array([c[2] for c in consts]))
            logws.append(np.array([c[3] for c in consts]))
            KTs.append(np.asarray(sde.ops.transpose(K_np_fn(float(t)))))
        mus = jnp.asarray(np.stack(mus), jnp.float32)        # (N, M, *state)
        cinvs = jnp.asarray(np.stack(cinvs), jnp.float32)    # (N, M, *coeff)
        logdets = jnp.asarray(np.stack(logdets), jnp.float32)
        logws = jnp.asarray(np.stack(logws), jnp.float32)
        KTs = jnp.asarray(np.stack(KTs), jnp.float32)        # (N, *coeff)
        M = mus.shape[1]

        def eps_fn(u: Array, i: Array) -> Array:
            dtype = u.dtype
            logp, deltas = [], []
            for m in range(M):
                delta = u - mus[i, m][None].astype(dtype)
                qf = _quad_form(sde, cinvs[i, m], delta)
                logp.append(logws[i, m] - 0.5 * qf - 0.5 * logdets[i, m])
                deltas.append(delta)
            gam = jax.nn.softmax(jnp.stack(logp, 0), axis=0)
            score = jnp.zeros_like(u)
            for m in range(M):
                g = gam[m].reshape((-1,) + (1,) * (u.ndim - 1)).astype(dtype)
                score = score - g * _apply_sym(sde, cinvs[i, m], deltas[m])
            return -sde.apply(KTs[i].astype(dtype), score)

        return eps_fn, len(ts)

"""Linear-SDE substrate for gDDIM (Zhang, Tao & Chen, ICLR 2023).

Every diffusion model in the paper is a linear SDE

    du = F_t u dt + G_t dw,   t in [0, T]                      (paper Eq. 1)

whose coefficient matrices F_t, G_t are *structured*:

  * VPSDE / DDPM : scalar multiples of the identity             (paper Eq. 8)
  * CLD          : 2x2 block matrix (x, v channels) ⊗ I_d       (paper Eq. 10)
  * BDM          : diagonal in the DCT frequency basis          (paper Eq. 11)

All of the quantities gDDIM needs — the transition matrix Psi(t, s), the
marginal covariance Sigma_t, the gDDIM parameterization matrix R_t (Eq. 17),
the Cholesky factor L_t, the lambda-family transition Psi_hat and the injected
covariance P_st (Eq. 23), and the exponential-integrator quadrature
coefficients (Eqs. 19b/41/46) — close over the same structure.  We therefore
represent every coefficient as a numpy array of family-specific shape
("coeff") and give each SDE family

  * host-side float64 algebra (compose/add/invert/transpose/sqrt) used by the
    offline Stage-I pipeline (paper App. C.3), and
  * a device-side `apply(coeff, u)` used by the jitted Stage-II samplers.

Coeff shapes per family:

  scalar   : ()                      applied as  c * u
  block    : (k, k)                  applied as  einsum('ij,bj...->bi...')
             (k=2 for CLD; state u has a channel axis right after batch)
  freqdiag : data_shape-broadcastable array D, applied as V (D * (V^T u)) V
             where V^T is an orthonormal DCT along the leading spatial axes.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Sequence, Tuple

import numpy as np
import jax
import jax.numpy as jnp

Array = jax.Array


# ---------------------------------------------------------------------------
# Host-side coefficient algebra (numpy, float64).
# ---------------------------------------------------------------------------
class CoeffOps:
    """Family-specific algebra over structured coefficients.

    All methods are static-ish and operate on numpy float64 arrays whose
    shape is the family's coeff shape, possibly with leading batch axes
    (e.g. a stack over time-grid points).
    """

    family: str = "abstract"

    def mul(self, a, b):            # matrix product a @ b
        raise NotImplementedError

    def add(self, a, b):
        return a + b

    def scale(self, s, a):
        return s * a

    def inv(self, a):
        raise NotImplementedError

    def transpose(self, a):
        raise NotImplementedError

    def sqrt_psd(self, a):
        """Symmetric PSD square root (principal)."""
        raise NotImplementedError

    def chol(self, a):
        """Lower-triangular Cholesky factor (paper's L_t for CLD, Eq. 78)."""
        raise NotImplementedError

    def eye(self):
        raise NotImplementedError

    def zeros(self):
        raise NotImplementedError

    def quad_form_inv(self, sigma, delta, sum_axes):
        """delta^T Sigma^{-1} delta summed over state dims (for Gaussian logpdf)."""
        raise NotImplementedError

    def logdet(self, a, dim_mult):
        """log|det A ⊗ I| given the per-structure coeff and data multiplicity."""
        raise NotImplementedError


class ScalarOps(CoeffOps):
    family = "scalar"

    def mul(self, a, b):
        return a * b

    def inv(self, a):
        return 1.0 / a

    def transpose(self, a):
        return a

    def sqrt_psd(self, a):
        return np.sqrt(a)

    chol = sqrt_psd

    def eye(self):
        return np.float64(1.0)

    def zeros(self):
        return np.float64(0.0)


class BlockOps(CoeffOps):
    """k x k channel-block coefficients (CLD: k=2, channels (x, v))."""

    family = "block"

    def __init__(self, k: int = 2):
        self.k = k

    def mul(self, a, b):
        return a @ b

    def inv(self, a):
        return np.linalg.inv(a)

    def transpose(self, a):
        return np.swapaxes(a, -1, -2)

    def sqrt_psd(self, a):
        w, v = np.linalg.eigh(a)
        w = np.clip(w, 0.0, None)
        return (v * np.sqrt(w)[..., None, :]) @ np.swapaxes(v, -1, -2)

    def chol(self, a):
        # Guard tiny negative eigenvalues from round-off.
        jitter = 1e-30 * np.eye(self.k)
        return np.linalg.cholesky(a + jitter)

    def eye(self):
        return np.eye(self.k)

    def zeros(self):
        return np.zeros((self.k, self.k))


class FreqDiagOps(CoeffOps):
    """Diagonal-in-DCT-basis coefficients (BDM).

    Coeffs are arrays broadcastable against the frequency grid of shape
    `freq_shape` (the leading spatial dims of the data).
    """

    family = "freqdiag"

    def __init__(self, freq_shape: Tuple[int, ...]):
        self.freq_shape = tuple(freq_shape)

    def mul(self, a, b):
        return a * b

    def inv(self, a):
        return 1.0 / a

    def transpose(self, a):
        return a

    def sqrt_psd(self, a):
        return np.sqrt(a)

    chol = sqrt_psd

    def eye(self):
        return np.ones(self.freq_shape)

    def zeros(self):
        return np.zeros(self.freq_shape)


def _apply_factored_canonical(blk: Array, diag: Array, z: Array) -> Array:
    """The factored-coefficient core on a canonical (B, kf, D) state: block
    contraction as a multiply-reduce over a *virtual* broadcast of the
    block factor, then the diagonal elementwise.  This exact graph shape
    is load-bearing: it is the same program as the dense einsum it
    replaced, which is what makes the factored path bitwise-equal to the
    dense oracle (see kernels/ei_update/ref.py) — every family's
    `apply_factored` must route through this one implementation."""
    kf = z.shape[1]
    blk = jnp.asarray(blk, z.dtype)[:kf, :kf]
    coeff = jnp.broadcast_to(blk[None, :, :, None],
                             (z.shape[0], kf, kf, z.shape[-1]))
    out = jnp.einsum("bijd,bjd->bid", coeff, z)
    return out * jnp.asarray(diag, z.dtype)[None, None, :]


def family_name(sde) -> str:
    """Canonical short name of an SDE family instance ('vpsde' | 'cld' |
    'bdm' | ...): the request-surface key of multi-family serving
    (`SampleRequest.family`, `SamplerConfig.family`)."""
    return type(sde).__name__.lower()


# ---------------------------------------------------------------------------
# Orthonormal DCT-II helpers (BDM basis).  V^T = DCT, V = IDCT, V^T V = I.
# ---------------------------------------------------------------------------
@functools.lru_cache(maxsize=None)
def dct_matrix(n: int) -> np.ndarray:
    """Orthonormal DCT-II matrix of size (n, n): y = C @ x."""
    k = np.arange(n)[:, None]
    i = np.arange(n)[None, :]
    c = np.cos(np.pi * (2 * i + 1) * k / (2 * n))
    c = c * np.sqrt(2.0 / n)
    c[0] *= np.sqrt(0.5)
    return c.astype(np.float64)


def dct_nd(x: Array, axes: Sequence[int]) -> Array:
    """Orthonormal DCT-II along `axes` (jnp, matmul-based — MXU friendly)."""
    for ax in axes:
        n = x.shape[ax]
        c = jnp.asarray(dct_matrix(n), dtype=x.dtype)
        x = jnp.moveaxis(jnp.tensordot(c, jnp.moveaxis(x, ax, 0), axes=1), 0, ax)
    return x


def idct_nd(x: Array, axes: Sequence[int]) -> Array:
    for ax in axes:
        n = x.shape[ax]
        c = jnp.asarray(dct_matrix(n).T, dtype=x.dtype)
        x = jnp.moveaxis(jnp.tensordot(c, jnp.moveaxis(x, ax, 0), axes=1), 0, ax)
    return x


# ---------------------------------------------------------------------------
# The abstract linear SDE.
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class LinearSDE:
    """A linear SDE du = F_t u dt + G_t dw with structured coefficients.

    Subclasses provide host-side float64 closed forms (or ODE-grid solvers,
    see `solve.GridCoeffs`) for F, G G^T, Psi, Sigma, R, and the device-side
    `apply` for their coefficient family.
    """

    T: float = 1.0
    t_min: float = 1e-3  # training/sampling stop time (Karras-style, per paper Sec. 5)

    # ---- family plumbing ---------------------------------------------------
    @property
    def ops(self) -> CoeffOps:
        raise NotImplementedError

    @property
    def state_ndim_prefix(self) -> int:
        """Number of structural channel axes between batch and data dims (CLD: 1)."""
        return 0

    def state_shape(self, data_shape: Tuple[int, ...]) -> Tuple[int, ...]:
        return data_shape

    # ---- canonical packed layout --------------------------------------------
    # The multi-family serving engine keeps every slot's state in ONE layout,
    # the (B, k, D) canonical form of kernels/ei_update: k structural channel
    # rows (VPSDE/BDM 1, CLD 2) by D = prod(data_shape) flattened data
    # entries, expressed in the family's *linear* basis — the basis in which
    # the family's coefficients act diagonally/blockwise (pixel space for
    # VPSDE/CLD, the DCT frequency basis for BDM, which overrides these
    # hooks to route through the dct2 kernel path).

    @property
    def packed_k(self) -> int:
        """Channel rows of the canonical (B, k, D) packed state."""
        return getattr(self.ops, "k", 1)

    # True when `canonicalize` is a pure reshape (VPSDE/CLD): i.i.d. normal
    # noise drawn directly in canonical (B, k, D) layout is then the same
    # bits as noise_like(state_shape) -> canonicalize, which lets the fused
    # round kernel (kernels/round_fused) draw the Eq. 22 noise in-kernel.
    # BDM overrides this: its canonicalize is a DCT, so canonical noise is
    # a correlated transform of the state-space draw and must be computed
    # outside the kernel and streamed in.
    canonical_noise_is_reshape = True

    def canonicalize(self, u: Array) -> Array:
        """(B, *state_shape) -> (B, packed_k, D) in the linear basis."""
        return u.reshape(u.shape[0], self.packed_k, -1)

    def decanonicalize(self, z: Array, data_shape: Tuple[int, ...]) -> Array:
        """(B, packed_k, D) -> (B, *state_shape) back in state space."""
        return z.reshape((z.shape[0],) + self.state_shape(tuple(data_shape)))

    # ---- host-side coefficient functions (numpy float64) -------------------
    def F_np(self, t: float):
        raise NotImplementedError

    def G2_np(self, t: float):
        """G_t G_t^T as a family coeff."""
        raise NotImplementedError

    def Psi_np(self, t: float, s: float):
        """Transition matrix of F: dPsi/dt = F_t Psi, Psi(s,s)=I (paper Eq. 36)."""
        raise NotImplementedError

    def Sigma_np(self, t: float):
        """Marginal covariance of p_{0t}(u_t | u_0) as a family coeff."""
        raise NotImplementedError

    def R_np(self, t: float):
        """gDDIM parameterization matrix solving Eq. 17."""
        raise NotImplementedError

    def L_np(self, t: float):
        """Cholesky factor of Sigma_t (Dockhorn et al.'s K_t choice)."""
        return self.ops.chol(self.Sigma_np(t))

    def Sigma0_np(self):
        """Initial per-data-point covariance (Dirac => zeros; CLD => diag(0, gamma M))."""
        return self.ops.zeros()

    # ---- device-side application -------------------------------------------
    def apply(self, coeff: Array, u: Array) -> Array:
        """Apply a (possibly stacked) coefficient to a batched state u."""
        raise NotImplementedError

    def apply_batched(self, coeff: Array, u: Array) -> Array:
        """Apply a *per-example* coefficient (leading batch axis) to u.

        Used by the DSM/HSM losses where each example draws its own t.
        coeff: (B, *coeff_shape);  u: (B, *state_shape).
        """
        raise NotImplementedError

    def apply_factored(self, blk: Array, diag: Array, u: Array) -> Array:
        """Apply a *factored* canonical coefficient — a (k_max, k_max)
        block factor and a (D,) diagonal factor, the exact decomposition
        `repro.core.coeffs.factor_coeff` produces for this family — to a
        native-basis state u (B, *state_shape), as two contractions.

        This is the family-native oracle the differential test tier
        (tests/test_factored_bank.py) pins the serving bank against: it
        runs the block contraction as the same multiply-reduce program as
        the bank path (kernels/ei_update, over a virtual broadcast of the
        block factor), so it is *bitwise* equal to the dense embedding it
        replaced, and — because one of the two factors is always trivial —
        bitwise equal to `apply(c, u)` for scalar/freq-diagonal families
        (block families' native dot_general differs in the last ulp, a
        property the dense bank had too).  Scalar/block families act in
        their native linear basis (canonicalize is a pure reshape); BDM
        overrides to act in its DCT frequency basis via the reference
        dct_nd path.
        """
        z = self.canonicalize(u)                         # (B, kf, D)
        return _apply_factored_canonical(blk, diag, z).reshape(u.shape)

    def noise_like(self, key: Array, u_shape: Tuple[int, ...], dtype=jnp.float32) -> Array:
        return jax.random.normal(key, u_shape, dtype)

    # ---- conveniences -------------------------------------------------------
    def prior_sample(self, key: Array, batch: int, data_shape: Tuple[int, ...],
                     dtype=jnp.float32) -> Array:
        """Sample u(T) ~ N(0, Sigma_T)."""
        shape = (batch,) + self.state_shape(data_shape)
        eps = self.noise_like(key, shape, dtype)
        chol_T = jnp.asarray(self.ops.chol(self.Sigma_np(self.T)), dtype)
        return self.apply(chol_T, eps)

    def augment_data(self, x: Array, key: Array | None = None) -> Array:
        """Lift data into SDE state space (identity except CLD)."""
        return x

    def project_data(self, u: Array) -> Array:
        """Project SDE state back to data space (identity except CLD)."""
        return u

    def perturb(self, key: Array, u0: Array, t: Array, K_np_fn: Callable[[float], np.ndarray]):
        """Forward-perturb data: u_t = Psi(t,0) u0 + K_t eps; returns (u_t, eps).

        Used by the DSM/HSM losses (paper Eq. 5 / 77).  `t` must be a python
        float or 0-d array for the host-side coefficient lookup — training
        loops batch this via stacked coefficient tables instead (see
        repro.train.losses).
        """
        t = float(t)
        psi = jnp.asarray(self.Psi_np(t, 0.0), u0.dtype)
        K = jnp.asarray(K_np_fn(t), u0.dtype)
        eps = self.noise_like(key, u0.shape, u0.dtype)
        return self.apply(psi, u0) + self.apply(K, eps), eps

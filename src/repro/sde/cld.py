"""Critically-damped Langevin diffusion (Dockhorn et al. 2021; paper Eq. 10).

State u = (x, v) with velocity channel: u has shape (B, 2, *data_shape).

    dx =  beta M^{-1} v dt
    dv = -beta x dt - Gamma beta M^{-1} v dt + sqrt(2 Gamma beta) dw

so, as channel-block coefficients,

    F = beta [[0, M^{-1}], [-1, -Gamma M^{-1}]],   G G^T = [[0,0],[0, 2 Gamma beta]].

(The printed Eq. 10 in the paper has a typo in G_t; we implement the actual
CLD of Dockhorn et al., which the paper's experiments use.)  Critical damping
means Gamma^2 = 4 M; defaults Gamma=1, M^{-1}=4, beta=4, per CLD-SGM.

Key paper objects:
  * Sigma_t: solves the Lyapunov ODE (Eq. 27) from Sigma_0 = diag(0, gamma M)
    — the Gaussian velocity initialization that makes Prop 4/5 apply
    (hybrid score matching marginalizes v_0).
  * L_t: lower Cholesky of Sigma_t — Dockhorn's K_t (paper Eq. 78).
  * R_t: the gDDIM choice, solving dR/dt = (F + 1/2 G G^T Sigma^{-1}) R
    (Eq. 17).  Non-triangular; this is the paper's central delta.

Both Sigma and R are solved on a stiff-aware grid (Sigma^{-1} ~ t^{-3} near 0
by hypoellipticity) in float64, as the paper does with RK4 (App. C.3).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Tuple

import numpy as np
import jax
import jax.numpy as jnp
import scipy.linalg

from .base import LinearSDE, BlockOps
from . import solve

Array = jax.Array


@dataclasses.dataclass
class CLD(LinearSDE):
    beta: float = 4.0
    M_inv: float = 4.0
    Gamma: float = 1.0
    gamma: float = 0.04          # initial velocity variance scale: v0 ~ N(0, gamma M)
    T: float = 1.0
    t_min: float = 1e-3
    grid_substeps: int = 8

    _ops = BlockOps(2)

    @property
    def ops(self):
        return self._ops

    @property
    def state_ndim_prefix(self) -> int:
        return 1

    def state_shape(self, data_shape: Tuple[int, ...]) -> Tuple[int, ...]:
        return (2,) + tuple(data_shape)

    # ---- constant-rate generator --------------------------------------------
    @functools.cached_property
    def A(self) -> np.ndarray:
        return self.beta * np.array([[0.0, self.M_inv],
                                     [-1.0, -self.Gamma * self.M_inv]], np.float64)

    def F_np(self, t):
        return self.A

    def G2_np(self, t):
        g2 = 2.0 * self.Gamma * self.beta
        return np.array([[0.0, 0.0], [0.0, g2]], np.float64)

    def Sigma0_np(self):
        return np.array([[0.0, 0.0], [0.0, self.gamma / self.M_inv]], np.float64)

    def Psi_np(self, t, s):
        return scipy.linalg.expm(self.A * (t - s))

    # ---- grid-solved Sigma_t and R_t ----------------------------------------
    @functools.cached_property
    def _grid(self) -> np.ndarray:
        return solve.make_grid(1e-6, self.T)

    def _sigma_exact(self, t: float) -> np.ndarray:
        """Closed-form Sigma_t via the Van Loan augmented-exponential trick.

        expm(t [[A, Q], [0, -A^T]]) = [[e^{At}, e^{At} V(t)], [0, e^{-A^T t}]]
        with V(t) = int_0^t e^{-As} Q e^{-A^T s} ds, so
        Sigma_t = e^{At} Sigma_0 e^{A^T t} + F12 F11^T.  Exact (no ODE drift),
        which keeps the R-ODE's Sigma^{-1} source term honest.
        """
        Q = self.G2_np(0.0)
        B = np.zeros((4, 4))
        B[:2, :2] = self.A
        B[:2, 2:] = Q
        B[2:, 2:] = -self.A.T
        E = scipy.linalg.expm(B * t)
        F11, F12 = E[:2, :2], E[:2, 2:]
        return F11 @ self.Sigma0_np() @ F11.T + F12 @ F11.T

    @functools.cached_property
    def _sigma_grid(self) -> solve.GridFn:
        vals = np.stack([self._sigma_exact(float(t)) for t in self._grid])
        return solve.GridFn(self._grid, vals)

    @functools.cached_property
    def _R_grid(self) -> solve.GridFn:
        """Solve Eq. 17 from the hypoelliptic origin.

        Near t=0, Sigma_t^{-1} blows up like t^{-3} (x-variance grows as t^2
        from the Gaussian v_0, t^3 from injected noise), so we anchor the ODE
        at t_anchor = 1e-4 with the *principal symmetric square root* of
        Sigma there (the limit of the true solution branch: R_0 =
        diag(0, sqrt(gamma M)) is itself the symmetric sqrt of Sigma_0) and
        integrate Eq. 17 outward.  Below the anchor we return sym-sqrt(Sigma)
        — sampling and training never query t < t_min = 1e-3 anyway.  The
        invariant R R^T = Sigma is asserted on the grid in tests.
        """
        G2 = self.G2_np(0.0)
        t_anchor = 1e-4
        grid = self._grid[self._grid >= t_anchor]
        grid = np.concatenate([[t_anchor], grid]) if grid[0] > t_anchor else grid
        R0 = self.ops.sqrt_psd(self.Sigma_np(float(grid[0])))

        def rhs(t, R):
            S = self._sigma_exact(float(t))
            return (self.A + 0.5 * G2 @ np.linalg.inv(S)) @ R

        return solve.solve_on_grid(rhs, R0, grid, self.grid_substeps)

    def Sigma_np(self, t):
        return self._sigma_exact(float(t))

    def R_np(self, t):
        t = float(t)
        if t < float(self._R_grid.ts[0]):
            return self.ops.sqrt_psd(self.Sigma_np(t))
        return self._R_grid(t)

    # ---- device side ---------------------------------------------------------
    def apply(self, coeff: Array, u: Array) -> Array:
        coeff = jnp.asarray(coeff, u.dtype)
        # u: (B, 2, *data); coeff: (2, 2) or stacked (..., 2, 2)
        return jnp.einsum("ij,bj...->bi...", coeff, u)

    def apply_batched(self, coeff: Array, u: Array) -> Array:
        coeff = jnp.asarray(coeff, u.dtype)  # (B, 2, 2)
        return jnp.einsum("bij,bj...->bi...", coeff, u)

    def augment_data(self, x: Array, key: Array | None = None) -> Array:
        """(B, *data) -> (B, 2, *data) with v0 ~ N(0, gamma M) (HSM init)."""
        v_std = float(np.sqrt(self.gamma / self.M_inv))
        if key is None:
            v = jnp.zeros_like(x)
        else:
            v = v_std * jax.random.normal(key, x.shape, x.dtype)
        return jnp.stack([x, v], axis=1)

    def project_data(self, u: Array) -> Array:
        return u[:, 0]

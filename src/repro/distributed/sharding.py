"""Sharding rules: parameter/activation PartitionSpecs with divisibility fallback.

Mesh contract (launch/mesh.py):
    single-pod : (data=16, model=16)            axes ("data", "model")
    multi-pod  : (pod=2, data=16, model=16)     axes ("pod", "data", "model")

Policy (DESIGN.md §4):
  * batch / activations  -> sharded over BATCH_AXES = ("pod", "data")
  * params               -> TP over "model" on a rule-chosen dim (Megatron
                            column/row split, expert axis for MoE, vocab for
                            embeddings), then FSDP (ZeRO-3) over "data" on the
                            largest remaining dim.  Cross-pod stays pure DP
                            (params replicated over "pod"; gradients
                            all-reduce over it) so per-layer FSDP gathers
                            never cross the DCI.
  * every axis assignment is divisibility-checked; a dim that does not
    divide the axis size falls back to replication on that axis (e.g.
    gemma3-1b's 4-head wq cannot split 16 ways -> FFN-only TP).

Rules are *name-pattern based* over the params pytree paths, so any model in
the zoo (transformer / rwkv / mamba / enc-dec / DiT) shards without
per-model code.  Leading scan-stack axes (layer groups) are never sharded —
XLA then performs the FSDP all-gather on the per-iteration slice inside the
scanned layer body, which is what overlaps gather with the previous layer's
compute on real hardware.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Any, Dict, Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class ShardCfg:
    tp_axis: str = "model"
    fsdp_axes: Tuple[str, ...] = ("data",)
    batch_axes: Tuple[str, ...] = ("pod", "data")
    # sequence-parallel axis for long-context activations / KV caches
    seq_axis: str = "model"
    fsdp_params: bool = True
    tp_params: bool = True
    # head-aligned attention TP: splitting the flat (H*Dh) projection dim
    # when H % tp != 0 makes GSPMD partition the QK^T einsum on its
    # CONTRACTING dim and all-reduce every score tile (measured 119 TB on
    # deepseek prefill_32k — EXPERIMENTS.md §Perf iter A1).  Attention
    # projections therefore only TP-shard when the head count divides.
    n_heads: int = 0
    n_kv_heads: int = 0
    # context parallelism: shard the sequence dim of train/prefill
    # activations over the model axis (§Perf iter A2)
    seq_shard_activations: bool = False

    def present(self, mesh: Mesh, axes) -> Tuple[str, ...]:
        if isinstance(axes, str):
            axes = (axes,)
        return tuple(a for a in axes if a in mesh.axis_names)


def axis_size(mesh: Mesh, axes) -> int:
    if isinstance(axes, str):
        axes = (axes,)
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


# ---------------------------------------------------------------------------
# TP dim selection rules: (regex over joined path) -> dim index from the END
# of the shape (negative), or a callable(shape)->dim.  First match wins.
# ---------------------------------------------------------------------------
def _moe_expert_dim(shape):
    # (..., E, D, F) expert-stacked weights: TP over the expert axis
    return len(shape) - 3


_TP_RULES = [
    (re.compile(r"moe/(w_gate|w_up|w_down)$"), _moe_expert_dim),
    (re.compile(r"moe/router$"), lambda s: len(s) - 1),        # (D, E): split experts
    (re.compile(r"(^|/)embed$"), lambda s: len(s) - 2),        # (V, D): split vocab
    (re.compile(r"(^|/)unembed$"), lambda s: len(s) - 1),      # (D, V): split vocab
    # rwkv channel-mix: wk (D, F) col, wv (F, D) row — disambiguated by parent
    # (must precede the generic wk/wv rule)
    (re.compile(r"cmix/wk$"), lambda s: len(s) - 1),
    (re.compile(r"cmix/wv$"), lambda s: len(s) - 2),
    (re.compile(r"(wq|wk|wv|w_up|w_gate|in_proj|patch_in|wr|wg|ada_w)$"),
     lambda s: len(s) - 1),                                    # column parallel
    (re.compile(r"(wo|w_down|out_proj|patch_out)$"), lambda s: len(s) - 2),
]

_REPLICATE = re.compile(
    r"(ln|norm|bias|mu$|decay_base|dt_bias|A_log|(^|/)D$|(^|/)u$|ada_b|b_in|b_out|b1$|b2$|pos|conv_b)")


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def _n_stack_axes(path_s: str) -> int:
    """Leading scan-stack axes for stacked layer params (never sharded)."""
    return 1 if re.search(r"(layer_stacks|layers|enc_layers|dec_layers|blocks)/", path_s) else 0


def param_spec(path_s: str, shape: Tuple[int, ...], mesh: Mesh,
               cfg: ShardCfg) -> P:
    """PartitionSpec for one parameter tensor."""
    ndim = len(shape)
    spec: list = [None] * ndim
    if _REPLICATE.search(path_s) or ndim <= 1:
        return P(*spec)
    n_stack = _n_stack_axes(path_s)

    tp_dim: Optional[int] = None
    if cfg.tp_params and cfg.tp_axis in mesh.axis_names:
        tp_size = mesh.shape[cfg.tp_axis]
        for pat, dim_fn in _TP_RULES:
            if pat.search(path_s):
                d = dim_fn(shape)
                ok = d is not None and n_stack <= d < ndim and shape[d] % tp_size == 0
                # head-aligned gating for attention projections
                if ok and re.search(r"attn/(wq|wo)$|xattn/(wq|wo)$", path_s) \
                        and cfg.n_heads and cfg.n_heads % tp_size != 0:
                    ok = False
                if ok and re.search(r"attn/(wk|wv)$|xattn/(wk|wv)$", path_s) \
                        and cfg.n_kv_heads and cfg.n_kv_heads % tp_size != 0:
                    ok = False
                if ok:
                    spec[d] = cfg.tp_axis
                    tp_dim = d
                break

    if cfg.fsdp_params:
        fsdp = cfg.present(mesh, cfg.fsdp_axes)
        if fsdp:
            fs = axis_size(mesh, fsdp)
            # largest remaining dim divisible by the fsdp size
            cands = [(shape[d], d) for d in range(n_stack, ndim)
                     if d != tp_dim and shape[d] % fs == 0]
            if cands:
                _, d = max(cands)
                spec[d] = fsdp if len(fsdp) > 1 else fsdp[0]
    return P(*spec)


def param_shardings(params: Any, mesh: Mesh, cfg: ShardCfg = ShardCfg()) -> Any:
    """Pytree of NamedShardings matching `params` (works on ShapeDtypeStructs)."""
    def one(path, leaf):
        spec = param_spec(_path_str(path), tuple(leaf.shape), mesh, cfg)
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map_with_path(one, params)


# ---------------------------------------------------------------------------
# Activation / batch / cache specs
# ---------------------------------------------------------------------------
def batch_axes_entry(mesh: Mesh, cfg: ShardCfg, batch_size: int):
    """The PartitionSpec entry for a batch dim of `batch_size`: the largest
    prefix of the batch axes (pods first) whose product divides it."""
    axes = [a for a in cfg.batch_axes if a in mesh.axis_names]
    use, prod = [], 1
    for a in axes:
        if batch_size % (prod * mesh.shape[a]) == 0:
            use.append(a)
            prod *= mesh.shape[a]
    return tuple(use) if len(use) > 1 else (use[0] if use else None)


def batch_spec(mesh: Mesh, cfg: ShardCfg, ndim: int, batch_size: int,
               extra: Optional[Dict[int, Any]] = None) -> P:
    """Batch-leading activation spec; batch sharded over the batch axes that
    divide it (pods first), remaining dims per `extra` {dim: axis}."""
    spec: list = [None] * ndim
    spec[0] = batch_axes_entry(mesh, cfg, batch_size)
    for d, ax in (extra or {}).items():
        if ax in mesh.axis_names:
            spec[d] = ax
    return P(*spec)


def kv_cache_spec(mesh: Mesh, cfg: ShardCfg, cache_shape: Tuple[int, ...],
                  batch_size: int, n_kv_heads: int,
                  seq_fallback: bool = False) -> P:
    """KV cache (.., B, S, Hkv, Dh), possibly with a leading layer-stack axis.

    Heads shard over `model` when divisible; otherwise the cache replicates
    over `model` (batch sharding still applies).  Sequence-sharding the
    cache (`seq_fallback=True`) is NOT the baseline: the per-token
    dynamic-update-slice at a dynamic index forces GSPMD into involuntary
    full rematerialization (measured: ~50x collective blow-up on the
    decode_32k cells) — the production SP-cache path needs the shard_map
    flash-decode with partial-softmax merge and is tracked as a §Perf
    optimization, not a default.
    """
    ndim = len(cache_shape)
    lead = ndim - 4
    spec: list = [None] * ndim
    spec[lead] = batch_axes_entry(mesh, cfg, batch_size)
    tp = cfg.tp_axis
    if tp in mesh.axis_names:
        if n_kv_heads % mesh.shape[tp] == 0:
            spec[lead + 2] = tp
        elif seq_fallback and cache_shape[lead + 1] % mesh.shape[tp] == 0:
            spec[lead + 1] = cfg.seq_axis        # SP over cache length
    return P(*spec)


# ---------------------------------------------------------------------------
# Serving-engine state: the mesh rules for `repro.serve` (EngineState pytrees
# + engine caches).  Every EngineState leaf is slot-batch-leading, so one
# rule shards the whole engine over the data axes; cache leaves carry their
# batch/slot dim wherever the arch family put it (probed by
# `Arch.cache_batch_axes`), with KV-shaped leaves additionally head-sharded
# via `kv_cache_spec`.
# ---------------------------------------------------------------------------
def serve_state_spec(mesh: Mesh, cfg: ShardCfg, ndim: int,
                     batch_size: int) -> P:
    """Spec for one slot-batch-leading EngineState leaf: dim 0 over the
    batch axes that divide the slot count, everything else replicated."""
    return batch_spec(mesh, cfg, ndim, batch_size)


def serve_state_shardings(state: Any, mesh: Mesh,
                          cfg: ShardCfg = ShardCfg()) -> Any:
    """NamedShardings for an EngineState pytree (all leaves batch-leading)."""
    return jax.tree.map(
        lambda l: NamedSharding(
            mesh, serve_state_spec(mesh, cfg, l.ndim, l.shape[0])), state)


def cache_leaf_spec(mesh: Mesh, cfg: ShardCfg, shape: Tuple[int, ...],
                    batch_axis: Optional[int], batch_size: int,
                    n_kv_heads: int = 0, d_head: int = -1) -> P:
    """Spec for one engine-cache leaf.  KV-shaped leaves ((.., B, S, Hkv, Dh))
    go through `kv_cache_spec` (batch + head sharding); every other state
    leaf (ssm/conv/recurrent aux) shards its probed batch axis only."""
    if len(shape) >= 4 and n_kv_heads and shape[-2] == n_kv_heads \
            and shape[-1] == d_head:
        return kv_cache_spec(mesh, cfg, shape, batch_size, n_kv_heads)
    spec: list = [None] * len(shape)
    if batch_axis is not None:
        spec[batch_axis] = batch_axes_entry(mesh, cfg, batch_size)
    return P(*spec)


def cache_shardings(cache_like: Any, batch_axes: Any, mesh: Mesh,
                    cfg: ShardCfg, batch_size: int, n_kv_heads: int = 0,
                    d_head: int = -1) -> Any:
    """NamedShardings for an engine cache pytree; `batch_axes` is the
    same-structure pytree of batch-axis indices from
    `Arch.cache_batch_axes`."""
    def one(leaf, ax):
        return NamedSharding(mesh, cache_leaf_spec(
            mesh, cfg, tuple(leaf.shape), int(ax), batch_size,
            n_kv_heads, d_head))
    return jax.tree.map(one, cache_like, batch_axes)


def bank_shardings(mesh: Mesh, cfg: ShardCfg, bank: Any,
                   shard_diag: bool = False) -> Any:
    """Mesh placement for a serve coefficient bank (`FactoredBank`).

    Every block-factor / index / time / flag leaf is tiny (O(K^2) or O(1)
    per row) and replicates.  The (P, D) diagonal pool — the only
    D-scaled leaf left after the factored refactor — replicates by
    default too; `shard_diag=True` shards its D axis over the tp axis
    when divisible (pool-row gathers are along P, so each shard keeps its
    D-slice local), which only pays once D is large enough for pool
    residency to matter and costs re-gathering the rows against the
    replicated slot state.
    """
    named = {}
    for f in bank._fields:
        spec = P()
        if f == "diag" and shard_diag and cfg.tp_axis in mesh.axis_names \
                and getattr(bank, f).shape[-1] % mesh.shape[cfg.tp_axis] == 0:
            spec = P(None, cfg.tp_axis)
        named[f] = NamedSharding(mesh, spec)
    return type(bank)(**named)


# ---------------------------------------------------------------------------
# in-model activation constraints (Megatron-style SP residual stream)
# ---------------------------------------------------------------------------
# GSPMD left to itself re-replicates the sequence dim inside transformer
# blocks and contraction-partitions the FFN matmuls (measured: 20 GB/layer
# f32 all-reduce on deepseek prefill — EXPERIMENTS.md §Perf iter A4).
# Model code calls `constrain_acts` on the (B, S, D) residual stream at
# block boundaries; the launcher installs a spec via `set_activation_spec`
# (None = no-op, the default for tests/small runs).
_ACT_SPEC: Optional[P] = None


def set_activation_spec(spec: Optional[P]) -> None:
    global _ACT_SPEC
    _ACT_SPEC = spec


def constrain_acts(x: Array) -> Array:
    if _ACT_SPEC is None or x.ndim != len(_ACT_SPEC):
        return x
    return jax.lax.with_sharding_constraint(x, _ACT_SPEC)


def _ambient_mesh():
    """The mesh installed by `with mesh:` (None outside a mesh context).

    jax 0.4.x has no public ambient-mesh accessor; try the semi-public
    pxla location first, then the private module it re-exports.  If a JAX
    upgrade breaks both, constrain_batch degrades to a no-op — that
    regression is caught by test_distributed.py::test_fsdp_train_step_*,
    which asserts sharded == single-device numerics."""
    for get in (lambda: __import__("jax").interpreters.pxla.thread_resources,
                lambda: __import__("jax._src.mesh", fromlist=["thread_resources"]).thread_resources):
        try:
            m = get().env.physical_mesh
            return None if m.empty else m
        except Exception:
            continue
    return None                                         # pragma: no cover


def constrain_batch(x: Array) -> Array:
    """Pin a batch-leading activation to the data-parallel layout (dim 0
    over the batch axes, everything else replicated).

    Model code calls this right after the embedding lookup.  Left to
    itself, GSPMD propagates the vocab-sharded embedding table's gather
    sharding into the layer scan, and the CPU SPMD partitioner miscompiles
    that composition — the sharded forward diverged from the single-device
    result by O(1) logits error while each block in isolation agreed to
    1e-6 (caught by tests/test_distributed.py::test_fsdp_train_step_*).
    An explicit constraint at the lookup restores agreement up to
    reduction order.  Honors an installed activation spec first; derives
    the spec from the ambient mesh otherwise; no-op outside a mesh
    context (single-device tests, the serving engine on CPU)."""
    if _ACT_SPEC is not None and x.ndim == len(_ACT_SPEC):
        return jax.lax.with_sharding_constraint(x, _ACT_SPEC)
    mesh = _ambient_mesh()
    if mesh is None:
        return x
    spec = batch_spec(mesh, ShardCfg(), x.ndim, x.shape[0])
    if all(a is None for a in spec):
        return x
    return jax.lax.with_sharding_constraint(x, spec)


def logical_to_sharding(mesh: Mesh, spec: P) -> NamedSharding:
    return NamedSharding(mesh, spec)


def replicated(mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, P())

"""GPipe-style pipeline parallelism as a shard_map stage-scan.

For 1000+-node scale-out, depth must shard across pods; this module maps the
classic GPipe schedule onto jax-native constructs (DESIGN.md §4): the layer
stack is sharded over a `stage` mesh axis, microbatches stream through
stages via `lax.ppermute`, and the whole schedule is one `lax.scan` of
length n_micro + n_stages - 1 (the pipeline fill/drain bubble is explicit).

Every device executes the same program (SPMD); stage s works on real data
from tick s onward.  Outputs of non-final ticks are masked garbage that the
caller discards, matching the standard bubble accounting:

    efficiency = n_micro / (n_micro + n_stages - 1).

The dry-run cells use FSDP+TP only (single pod fits every cell — see
EXPERIMENTS.md memory math); this module is exercised by a unit test on a
CPU mesh and is the documented scale-out path for llama3-405b beyond 2 pods.
"""
from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map

Array = jax.Array


def pipeline_apply(
    stage_fn: Callable[[Any, Array], Array],
    stage_params: Any,
    x_micro: Array,
    *,
    mesh: Mesh,
    stage_axis: str = "stage",
) -> Array:
    """Run `stage_fn` over `n_stages` pipeline stages.

    stage_params: pytree with leading axis n_stages (sharded over stage_axis).
    x_micro: (n_micro, micro_batch, ...) microbatched input, replicated.
    Returns (n_micro, micro_batch, ...) outputs after the final stage.
    """
    n_stages = mesh.shape[stage_axis]
    n_micro = x_micro.shape[0]
    total = n_micro + n_stages - 1

    def per_stage(params_s, x_all):
        # params_s: this stage's slice (leading axis 1); x_all: all microbatches
        params_s = jax.tree.map(lambda t: t[0], params_s)
        stage_id = jax.lax.axis_index(stage_axis)
        buf = jnp.zeros_like(x_all[0])
        outs0 = jnp.zeros_like(x_all)

        def tick(carry, t):
            buf, outs = carry
            # stage 0 injects microbatch t (when valid); others use the
            # activation received on the previous tick.
            inject = jnp.where(t < n_micro, t, 0)
            x_in = jnp.where(stage_id == 0, x_all[inject], buf)
            y = stage_fn(params_s, x_in)
            # the final stage retires microbatch (t - n_stages + 1)
            out_idx = t - (n_stages - 1)
            valid = (out_idx >= 0) & (out_idx < n_micro)
            idx = jnp.clip(out_idx, 0, n_micro - 1)
            upd = jnp.where(valid & (stage_id == n_stages - 1),
                            y, outs[idx])
            outs = jax.lax.dynamic_update_index_in_dim(outs, upd, idx, 0)
            # shift activations one stage forward (ring permute)
            buf = jax.lax.ppermute(
                y, stage_axis,
                [(i, (i + 1) % n_stages) for i in range(n_stages)])
            return (buf, outs), None

        (_, outs), _ = jax.lax.scan(tick, (buf, outs0), jnp.arange(total))
        # every stage holds the same `outs` garbage except the last; broadcast
        # the last stage's buffer to all (psum of masked contributions).
        mine = jnp.where(stage_id == n_stages - 1, 1.0, 0.0)
        outs = jax.lax.psum(outs * mine.astype(outs.dtype), stage_axis)
        return outs

    spec_params = jax.tree.map(lambda _: P(stage_axis), stage_params)
    fn = shard_map(per_stage, mesh=mesh,
                   in_specs=(spec_params, P()), out_specs=P(),
                   check_rep=False)
    return fn(stage_params, x_micro)


def make_stage_mesh(n_stages: int) -> Mesh:
    devs = jax.devices()[:n_stages]
    import numpy as np
    return Mesh(np.array(devs).reshape(n_stages), ("stage",))

"""Multi-host engine bring-up: `jax.distributed` initialization, the
global serve mesh, and the process-sharded SPMD fallback.

One host stops at its own devices; ROADMAP open item 1 is the tier above
— N processes (one per host, or N local processes in CI) serving as one
fleet.  This module owns the bring-up:

  * `initialize()` wraps `jax.distributed.initialize` (coordinator
    address, process count, process id — the same triple a k8s
    StatefulSet derives from its pod ordinal) and returns a
    `MultihostContext`.  After it, `jax.devices()` is the *global* device
    view and the coordination service (barriers + key-value store) is
    live — `barrier()`, `kv_set()`, `kv_get()` below are thin wrappers
    the launch harness (tools/launchgate.py) and the multi-process tests
    use for readiness fan-in and result fan-out.

  * **Global-mesh mode** (`mode_of() == "global"`): build the
    (data, model) mesh over every global device with
    `global_serve_mesh()` and hand it to an engine exactly like a local
    mesh — params shard by the existing FSDP/TP rules and the slot batch
    by the serve rules (`repro.distributed.sharding.param_shardings` /
    `serve_state_shardings` / `cache_shardings`; the engines consume
    them via `mesh=`, unchanged).  This is the real multi-host path on
    TPU/GPU backends.

  * **Process-sharded SPMD mode** (`mode_of() == "spmd"`): the CPU
    backend cannot run multi-process XLA computations (probed:
    `Multiprocess computations aren't implemented on the CPU backend`),
    so CI runs the fleet as N coordinated processes each serving a
    deterministic *request shard* (`shard_requests`) on a local engine.
    The serving stack's core invariant — every result is a pure function
    of (seed, sampler config), slots are independent batch rows — makes
    the union of the per-process results **bitwise identical** to one
    engine serving the whole list (tests/test_multihost.py proves it in
    CI with 2 real `jax.distributed`-initialized processes).  The same
    invariant is exactly why the router tier (serve/router.py) can split
    a trace across replicas bitwise-safely.

Mode selection is a capability gate, not a flag: `mode_of()` returns
"global" only when the backend supports cross-process computations, so
the same launch code runs CI (CPU, spmd) and a real cluster (TPU/GPU,
global) without edits.
"""
from __future__ import annotations

import dataclasses
from typing import Any, List, Optional, Sequence

import jax


@dataclasses.dataclass(frozen=True)
class MultihostContext:
    """The identity of this process in the fleet, post-initialize."""
    process_id: int
    num_processes: int
    coordinator_address: Optional[str] = None

    @property
    def is_coordinator(self) -> bool:
        return self.process_id == 0


def initialize(coordinator_address: Optional[str] = None,
               num_processes: int = 1,
               process_id: int = 0) -> MultihostContext:
    """Join the fleet.  A single-process call is a no-op (local jax is
    already initialized); a multi-process call must happen before any
    device use in the process, mirrors `jax.distributed.initialize`, and
    blocks until all `num_processes` processes connect — the launch
    harness's readiness wait rides on exactly that barrier."""
    if num_processes < 1:
        raise ValueError(f"num_processes must be >= 1, got {num_processes}")
    if not (0 <= process_id < num_processes):
        raise ValueError(f"process_id {process_id} outside "
                         f"[0, {num_processes})")
    if num_processes > 1:
        if coordinator_address is None:
            raise ValueError("multi-process initialize needs a "
                             "coordinator_address (host:port)")
        jax.distributed.initialize(coordinator_address=coordinator_address,
                                   num_processes=num_processes,
                                   process_id=process_id)
    return MultihostContext(process_id=process_id,
                            num_processes=num_processes,
                            coordinator_address=coordinator_address)


def multiprocess_jit_supported() -> bool:
    """Whether this backend can run one XLA computation across processes.
    CPU cannot (no cross-process collectives runtime); TPU/GPU can."""
    return jax.default_backend() not in ("cpu",)


def mode_of(ctx: MultihostContext) -> str:
    """'global' (one engine on the global mesh) when the backend supports
    cross-process computations or the fleet is one process; 'spmd'
    (process-sharded requests on local engines) otherwise."""
    if ctx.num_processes == 1 or multiprocess_jit_supported():
        return "global"
    return "spmd"


def global_serve_mesh(data: Optional[int] = None, model: int = 1):
    """The serving (data, model) mesh over every *global* device.  After
    `initialize`, `jax.devices()` spans the fleet, so this is the
    multi-host analogue of `repro.launch.mesh.make_local_mesh` — the
    engines consume it via `mesh=` and the existing sharding rules
    (param FSDP/TP, serve-state and cache data-sharding) apply unchanged.
    """
    n = jax.device_count()
    if data is None:
        if n % model:
            raise ValueError(f"{n} devices not divisible by model={model}")
        data = n // model
    if data * model > n:
        raise ValueError(f"mesh data={data} x model={model} needs "
                         f"{data * model} devices, {n} present globally")
    return jax.make_mesh((data, model), ("data", "model"))


def shard_requests(requests: Sequence[Any], num_processes: int,
                   process_id: int) -> List[Any]:
    """This process's deterministic request shard: positions
    `process_id::num_processes` of the (stable-ordered) request list.
    Round-robin, so heterogeneous traffic (mixed NFE budgets, families)
    spreads instead of clumping onto one process.  Union-of-shards is
    bitwise equal to the unsharded serve: results are pure functions of
    (seed, config), never of neighbours or placement."""
    if not (0 <= process_id < num_processes):
        raise ValueError(f"process_id {process_id} outside "
                         f"[0, {num_processes})")
    return list(requests[process_id::num_processes])


# ---------------------------------------------------------------------------
# coordination-service helpers (readiness fan-in, small result fan-out)
# ---------------------------------------------------------------------------
def _client():
    from jax._src import distributed
    client = distributed.global_state.client
    if client is None:
        raise RuntimeError("coordination service not initialized — call "
                           "multihost.initialize(...) with num_processes>1 "
                           "first")
    return client


def barrier(name: str, timeout_s: float = 60.0) -> None:
    """Block until every process reaches `name` (readiness fan-in: the
    launch harness knows the fleet is serving when the 'ready' barrier
    clears on process 0)."""
    _client().wait_at_barrier(name, timeout_in_ms=int(timeout_s * 1000))


def kv_set(key: str, value: str) -> None:
    """Publish a small string (counter JSON, result digest) to the
    fleet-wide key-value store."""
    _client().key_value_set(key, value)


def kv_get(key: str, timeout_s: float = 60.0) -> str:
    """Blocking fetch from the fleet-wide key-value store."""
    return _client().blocking_key_value_get(
        key, timeout_in_ms=int(timeout_s * 1000))

"""Mamba2 (SSD) mixer — the state-space block used by zamba2-2.7b.

Implements the SSD (state-space dual) chunked algorithm of Mamba-2
(Dao & Gu 2024, arXiv:2405.21060): within chunks of length Q the recurrence
is computed in its quadratic "attention-like" form (MXU-friendly einsums with
a causal decay mask), and chunk boundary states are propagated by a
`lax.scan` — O(S Q) work, O(S/Q) sequential steps.  `ssd_sequential` is the
per-token oracle used in tests.

Decode carries (conv_state, ssm_state) and costs O(1)/token — this is what
makes zamba2/rwkv the `long_500k` architectures in the assignment.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

from . import common
from .common import Params

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class Mamba2Cfg:
    d_model: int
    d_state: int = 64
    d_head: int = 64
    expand: int = 2
    d_conv: int = 4
    chunk: int = 128
    dtype: Any = jnp.float32

    @property
    def d_inner(self) -> int:
        return self.expand * self.d_model

    @property
    def n_heads(self) -> int:
        return self.d_inner // self.d_head

    @property
    def conv_dim(self) -> int:
        return self.d_inner + 2 * self.d_state


# ---------------------------------------------------------------------------
# SSD core
# ---------------------------------------------------------------------------
def segsum(x: Array) -> Array:
    """x: (..., Q) -> (..., Q, Q) with out[i, j] = sum_{k=j+1..i} x[k] (i>=j),
    -inf above the diagonal."""
    Q = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    out = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((Q, Q), bool))
    return jnp.where(mask, out, -jnp.inf)


def ssd_chunked(x: Array, dt: Array, A: Array, B: Array, C: Array,
                chunk: int, state0: Optional[Array] = None
                ) -> Tuple[Array, Array]:
    """SSD recurrence  h_t = exp(A dt_t) h_{t-1} + dt_t B_t x_t;  y_t = C_t h_t.

    x: (b, s, h, p);  dt: (b, s, h);  A: (h,) (negative);
    B, C: (b, s, h, n)  (already head-expanded).
    Returns (y (b,s,h,p), final_state (b,h,n,p)).
    """
    b, s, h, p = x.shape
    n = B.shape[-1]
    assert s % chunk == 0, (s, chunk)
    nc = s // chunk

    f32 = jnp.float32
    xb = (x * dt[..., None]).astype(f32)                     # dt-weighted input
    dA = (dt.astype(f32) * A.astype(f32))                    # (b, s, h)

    def to_chunks(t, tail):
        return t.reshape((b, nc, chunk) + tail)

    xc = to_chunks(xb, (h, p))
    Bc = to_chunks(B.astype(f32), (h, n))
    Cc = to_chunks(C.astype(f32), (h, n))
    dAc = to_chunks(dA, (h,)).transpose(0, 1, 3, 2)          # (b, nc, h, Q)
    dA_cum = jnp.cumsum(dAc, axis=-1)                        # inclusive
    dA_sum = dA_cum[..., -1]                                 # (b, nc, h)

    # ---- intra-chunk (quadratic attention-like form)
    L = jnp.exp(segsum(dAc))                                 # (b, nc, h, Q, Q)
    scores = jnp.einsum("bcihn,bcjhn->bchij", Cc, Bc) * L
    Y_diag = jnp.einsum("bchij,bcjhp->bcihp", scores, xc)

    # ---- chunk summary states: sum_j exp(dA_sum - dA_cum[j]) B_j xb_j
    decay_states = jnp.exp(dA_sum[..., None] - dA_cum)       # (b, nc, h, Q)
    states = jnp.einsum("bchj,bcjhn,bcjhp->bchnp", decay_states, Bc, xc)

    # ---- inter-chunk recurrence over nc chunks
    if state0 is None:
        state0 = jnp.zeros((b, h, n, p), f32)

    def scan_fn(S, xs):
        st, dsum = xs                                        # (b,h,n,p), (b,h)
        S_new = jnp.exp(dsum)[..., None, None] * S + st
        return S_new, S                                      # emit state *entering* chunk

    final, S_prev = jax.lax.scan(
        scan_fn, state0.astype(f32),
        (states.transpose(1, 0, 2, 3, 4), dA_sum.transpose(1, 0, 2)))
    S_prev = S_prev.transpose(1, 0, 2, 3, 4)                 # (b, nc, h, n, p)

    # ---- contribution of the entering state to each position
    state_decay = jnp.exp(dA_cum)                            # (b, nc, h, Q)
    Y_off = jnp.einsum("bcihn,bchi,bchnp->bcihp", Cc, state_decay, S_prev)

    y = (Y_diag + Y_off).reshape(b, s, h, p)
    return y.astype(x.dtype), final


def ssd_sequential(x, dt, A, B, C, state0=None):
    """Per-token oracle for ssd_chunked (tests + decode reference)."""
    b, s, h, p = x.shape
    n = B.shape[-1]
    S = jnp.zeros((b, h, n, p), jnp.float32) if state0 is None else state0.astype(jnp.float32)

    def step(S, t):
        dA = jnp.exp(dt[:, t].astype(jnp.float32) * A)       # (b, h)
        S = S * dA[..., None, None] + jnp.einsum(
            "bhn,bhp->bhnp", B[:, t].astype(jnp.float32),
            (x[:, t] * dt[:, t][..., None]).astype(jnp.float32))
        y = jnp.einsum("bhn,bhnp->bhp", C[:, t].astype(jnp.float32), S)
        return S, y

    S, ys = jax.lax.scan(step, S, jnp.arange(s))
    return ys.transpose(1, 0, 2, 3).astype(x.dtype), S


# ---------------------------------------------------------------------------
# Mamba2 block
# ---------------------------------------------------------------------------
def mamba2_params(key, cfg: Mamba2Cfg) -> Params:
    k1, k2, k3, k4, k5 = jax.random.split(key, 5)
    d_in_proj = 2 * cfg.d_inner + 2 * cfg.d_state + cfg.n_heads
    dt_bias = jnp.log(jnp.expm1(
        jnp.exp(jax.random.uniform(k3, (cfg.n_heads,), jnp.float32,
                                   math.log(1e-3), math.log(1e-1)))))
    return {
        "in_proj": common.dense_init(k1, cfg.d_model, d_in_proj, cfg.dtype),
        "conv_w": (jax.random.normal(k2, (cfg.d_conv, cfg.conv_dim), jnp.float32)
                   / math.sqrt(cfg.d_conv)).astype(cfg.dtype),
        "conv_b": jnp.zeros((cfg.conv_dim,), cfg.dtype),
        "dt_bias": dt_bias,
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, cfg.n_heads)),
        "D": jnp.ones((cfg.n_heads,), jnp.float32),
        "norm": jnp.zeros((cfg.d_inner,), cfg.dtype),
        "out_proj": common.dense_init(k4, cfg.d_inner, cfg.d_model, cfg.dtype),
    }


def _causal_conv(x: Array, w: Array, b: Array,
                 conv_state: Optional[Array] = None) -> Tuple[Array, Array]:
    """Depthwise causal conv1d.  x: (B, S, C); w: (K, C).  Returns (y, new_state)
    where new_state is the last K-1 inputs (for decode)."""
    K = w.shape[0]
    if conv_state is None:
        xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    else:
        xp = jnp.concatenate([conv_state.astype(x.dtype), x], axis=1)
    y = sum(xp[:, i:i + x.shape[1], :] * w[i] for i in range(K)) + b
    new_state = xp[:, -(K - 1):, :] if K > 1 else None
    return y, new_state


def mamba2_apply(p: Params, cfg: Mamba2Cfg, x: Array,
                 cache: Optional[Tuple[Array, Array]] = None
                 ) -> Tuple[Array, Optional[Tuple[Array, Array]]]:
    """x: (B, S, D).  cache = (conv_state (B, K-1, conv_dim),
    ssm_state (B, H, N, P)) for decode (S == 1)."""
    B_, S, D = x.shape
    H, P, N = cfg.n_heads, cfg.d_head, cfg.d_state
    zxbcdt = x @ p["in_proj"]
    z, xBC, dt = jnp.split(
        zxbcdt, [cfg.d_inner, cfg.d_inner + cfg.conv_dim], axis=-1)
    conv_state = cache[0] if cache is not None else None
    xBC, new_conv = _causal_conv(xBC, p["conv_w"], p["conv_b"], conv_state)
    xBC = jax.nn.silu(xBC)
    xs, Bmat, Cmat = jnp.split(xBC, [cfg.d_inner, cfg.d_inner + N], axis=-1)
    xs = xs.reshape(B_, S, H, P)
    Bh = jnp.broadcast_to(Bmat[:, :, None, :], (B_, S, H, N))
    Ch = jnp.broadcast_to(Cmat[:, :, None, :], (B_, S, H, N))
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])

    if cache is not None:                                   # decode: one token
        ssm_state = cache[1]
        y, new_state = ssd_sequential(xs, dt, A, Bh, Ch, state0=ssm_state)
        new_cache = (new_conv, new_state)
    else:
        pad = (-S) % cfg.chunk
        if pad:
            xs = jnp.pad(xs, ((0, 0), (0, pad), (0, 0), (0, 0)))
            dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
            Bh = jnp.pad(Bh, ((0, 0), (0, pad), (0, 0), (0, 0)))
            Ch = jnp.pad(Ch, ((0, 0), (0, pad), (0, 0), (0, 0)))
        y, final = ssd_chunked(xs, dt, A, Bh, Ch, cfg.chunk)
        y = y[:, :S]
        xs = xs[:, :S]
        new_cache = None

    y = y + xs * p["D"][None, None, :, None].astype(y.dtype)
    y = y.reshape(B_, S, cfg.d_inner)
    y = common.rms_norm(y * jax.nn.silu(z), p["norm"])
    out = y @ p["out_proj"]
    return out, new_cache


def init_mamba_cache(cfg: Mamba2Cfg, batch: int, dtype=None):
    dtype = dtype or cfg.dtype
    conv = jnp.zeros((batch, cfg.d_conv - 1, cfg.conv_dim), dtype)
    state = jnp.zeros((batch, cfg.n_heads, cfg.d_state, cfg.d_head), jnp.float32)
    return (conv, state)

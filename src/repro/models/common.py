"""Shared neural components (pure-functional, raw JAX — no flax).

Conventions
-----------
* params are nested dicts of jnp arrays; layer stacks carry a leading L axis
  and are consumed by `jax.lax.scan` so the lowered HLO stays O(1) in depth
  (essential for the 512-device dry-run compiles on one CPU core).
* activations default to bf16-friendly math: norms/softmax accumulate in f32.
* attention dispatches through repro.kernels.attention.ops so the same model
  code runs the Pallas TPU kernel (interpret=True on CPU for tests) or the
  jnp reference.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import numpy as np
import jax
import jax.numpy as jnp

Array = jax.Array
Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# initializers
# ---------------------------------------------------------------------------
def dense_init(key, d_in: int, d_out: int, dtype, scale: float | None = None):
    s = scale if scale is not None else 1.0 / math.sqrt(d_in)
    return (jax.random.normal(key, (d_in, d_out), jnp.float32) * s).astype(dtype)


def embed_init(key, vocab: int, d: int, dtype):
    return (jax.random.normal(key, (vocab, d), jnp.float32) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------
def rms_norm(x: Array, gamma: Array, eps: float = 1e-6) -> Array:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    out = x32 * jax.lax.rsqrt(var + eps)
    return (out * (1.0 + gamma.astype(jnp.float32))).astype(dt)


def layer_norm(x: Array, gamma: Array, beta: Array, eps: float = 1e-5) -> Array:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    out = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return (out * gamma.astype(jnp.float32) + beta.astype(jnp.float32)).astype(dt)


# ---------------------------------------------------------------------------
# rotary position embedding
# ---------------------------------------------------------------------------
def rope_frequencies(d_head: int, theta: float = 10000.0) -> np.ndarray:
    return 1.0 / (theta ** (np.arange(0, d_head, 2, dtype=np.float64) / d_head))


def apply_rope(x: Array, positions: Array, theta: float = 10000.0) -> Array:
    """x: (B, S, H, Dh); positions: (B, S) or (S,)."""
    d_head = x.shape[-1]
    freqs = jnp.asarray(rope_frequencies(d_head, theta), jnp.float32)
    if positions.ndim == 1:
        positions = positions[None, :]
    ang = positions[..., None].astype(jnp.float32) * freqs  # (B, S, Dh/2)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# activations
# ---------------------------------------------------------------------------
def activation(name: str, x: Array) -> Array:
    if name == "silu":
        return jax.nn.silu(x)
    if name == "gelu":
        return jax.nn.gelu(x)
    if name == "relu2":  # squared ReLU (Primer / nemotron-4)
        r = jax.nn.relu(x)
        return r * r
    raise ValueError(name)


# ---------------------------------------------------------------------------
# attention dispatch (kernel or reference)
# ---------------------------------------------------------------------------
def attention(q: Array, k: Array, v: Array, *, causal: bool,
              window: Optional[int] = None, q_offset: int | Array = 0,
              use_kernel: str = "auto") -> Array:
    """Multi-head attention with GQA broadcast.

    q: (B, Sq, Hq, Dh); k/v: (B, Sk, Hkv, Dh); Hq % Hkv == 0.
    `window`: sliding-window size (None = full);  `q_offset`: absolute
    position of q[0] relative to k[0] (decode: Sk - Sq).
    """
    from ..kernels.attention import ops as attn_ops
    return attn_ops.attention(q, k, v, causal=causal, window=window,
                              q_offset=q_offset, impl=use_kernel)


def repeat_kv(k: Array, n_rep: int) -> Array:
    if n_rep == 1:
        return k
    b, s, h, d = k.shape
    return jnp.broadcast_to(k[:, :, :, None, :], (b, s, h, n_rep, d)).reshape(
        b, s, h * n_rep, d)


# ---------------------------------------------------------------------------
# GQA attention block (params + apply, stackable)
# ---------------------------------------------------------------------------
def attn_params(key, d_model: int, n_heads: int, n_kv: int, d_head: int, dtype):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "wq": dense_init(k1, d_model, n_heads * d_head, dtype),
        "wk": dense_init(k2, d_model, n_kv * d_head, dtype),
        "wv": dense_init(k3, d_model, n_kv * d_head, dtype),
        "wo": dense_init(k4, n_heads * d_head, d_model, dtype,
                         scale=1.0 / math.sqrt(n_heads * d_head)),
    }


def decode_positions(seq_len: int, cache_len: Optional[Array]) -> Array:
    """Absolute positions for a (B, S) input decoded against a cache.

    Scalar `cache_len` (all rows at one position) -> (S,); per-slot (B,)
    `cache_len` (continuous batching) -> (B, S).  None -> (S,) from zero.
    """
    if cache_len is None:
        return jnp.arange(seq_len)
    cl = jnp.asarray(cache_len)
    if cl.ndim == 1:
        return cl[:, None] + jnp.arange(seq_len)[None]
    return jnp.arange(seq_len) + cl


def attn_apply(p: Params, x: Array, *, n_heads: int, n_kv: int, d_head: int,
               causal: bool = True, window: Optional[int] = None,
               rope_theta: float = 10000.0, positions: Optional[Array] = None,
               kv_cache: Optional[Tuple[Array, Array]] = None,
               cache_len: Optional[Array] = None,
               x_kv: Optional[Array] = None) -> Tuple[Array, Optional[Tuple[Array, Array]]]:
    """Returns (out, new_kv) where new_kv is (k, v) if a cache was provided
    or requested.  Decode mode: x is (B, 1, D), kv_cache is (B, Skv, Hkv, Dh)
    pre-allocated; cache_len gives the number of valid entries — a scalar
    (all rows share one position) or a (B,) vector of per-row positions
    (continuous batching: each slot writes its KV row and masks at its own
    length; routed through the flash-decode kernel surface)."""
    B, Sq, D = x.shape
    src = x if x_kv is None else x_kv
    q = (x @ p["wq"]).reshape(B, Sq, n_heads, d_head)
    k = (src @ p["wk"]).reshape(B, src.shape[1], n_kv, d_head)
    v = (src @ p["wv"]).reshape(B, src.shape[1], n_kv, d_head)

    if positions is None:
        positions = jnp.arange(Sq)
    if rope_theta > 0:
        q = apply_rope(q, positions, rope_theta)
        k = apply_rope(k, positions if x_kv is None else jnp.arange(src.shape[1]),
                       rope_theta)

    per_slot = cache_len is not None and jnp.ndim(cache_len) == 1
    if kv_cache is not None and per_slot:
        if Sq != 1:
            raise ValueError("per-slot cache_len supports one-token decode "
                             f"only; got Sq={Sq}")
        ck, cv = kv_cache
        # slot-wise KV write: row b lands at its own position cache_len[b]
        ck = ck.at[jnp.arange(B), cache_len].set(k[:, 0].astype(ck.dtype))
        cv = cv.at[jnp.arange(B), cache_len].set(v[:, 0].astype(cv.dtype))
        from ..kernels.decode_attention import ops as decode_ops
        out = decode_ops.decode_attention(q[:, 0], ck, cv, cache_len + 1,
                                          window=window)
        out = out.reshape(B, Sq, n_heads * d_head) @ p["wo"]
        return out, (ck, cv)

    if kv_cache is not None:
        ck, cv = kv_cache
        # decode: write the new kv at cache_len, attend over the full cache
        idx = cache_len if cache_len is not None else ck.shape[1] - Sq
        ck = jax.lax.dynamic_update_slice_in_dim(ck, k.astype(ck.dtype), idx, axis=1)
        cv = jax.lax.dynamic_update_slice_in_dim(cv, v.astype(cv.dtype), idx, axis=1)
        k_full, v_full = ck, cv
        q_off = idx
        new_cache = (ck, cv)
    else:
        k_full, v_full = k, v
        q_off = 0
        new_cache = None

    n_rep = n_heads // n_kv
    out = attention(q, repeat_kv(k_full, n_rep), repeat_kv(v_full, n_rep),
                    causal=causal, window=window, q_offset=q_off)
    out = out.reshape(B, Sq, n_heads * d_head) @ p["wo"]
    return out, new_cache


# ---------------------------------------------------------------------------
# MLP blocks
# ---------------------------------------------------------------------------
def mlp_params(key, d_model: int, d_ff: int, dtype, gated: bool = True):
    k1, k2, k3 = jax.random.split(key, 3)
    p = {"w_up": dense_init(k1, d_model, d_ff, dtype),
         "w_down": dense_init(k2, d_ff, d_model, dtype)}
    if gated:
        p["w_gate"] = dense_init(k3, d_model, d_ff, dtype)
    return p


def mlp_apply(p: Params, x: Array, act: str = "silu") -> Array:
    up = x @ p["w_up"]
    if "w_gate" in p:
        up = activation(act, x @ p["w_gate"]) * up
    else:
        up = activation(act, up)
    return up @ p["w_down"]


# ---------------------------------------------------------------------------
# Mixture of Experts (top-k, one-hot dispatch => all-to-all under sharding)
# ---------------------------------------------------------------------------
def moe_params(key, d_model: int, d_ff: int, n_experts: int, dtype,
               n_shared: int = 0, d_ff_shared: int | None = None):
    k1, k2, k3, k4, k5 = jax.random.split(key, 5)
    p = {
        "router": dense_init(k1, d_model, n_experts, jnp.float32),
        "w_gate": (jax.random.normal(k2, (n_experts, d_model, d_ff), jnp.float32)
                   / math.sqrt(d_model)).astype(dtype),
        "w_up": (jax.random.normal(k3, (n_experts, d_model, d_ff), jnp.float32)
                 / math.sqrt(d_model)).astype(dtype),
        "w_down": (jax.random.normal(k4, (n_experts, d_ff, d_model), jnp.float32)
                   / math.sqrt(d_ff)).astype(dtype),
    }
    if n_shared:
        p["shared"] = mlp_params(k5, d_model, d_ff_shared or d_ff * n_shared, dtype)
    return p


def moe_apply(p: Params, x: Array, *, top_k: int, act: str = "silu",
              capacity_factor: float = 1.25) -> Array:
    """Token-choice top-k routing with dense one-hot dispatch.

    (B, S, D) -> (B, S, D).  The einsum dispatch/combine pattern lowers to
    all-to-all when experts are sharded over the model axis (EP).
    """
    B, S, D = x.shape
    E = p["router"].shape[-1]
    logits = (x.astype(jnp.float32) @ p["router"])          # (B,S,E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, top_k)        # (B,S,k)
    gate_vals = gate_vals / jnp.clip(gate_vals.sum(-1, keepdims=True), 1e-9)
    # combine weights: (B,S,E) sparse-as-dense
    combine = jnp.zeros((B, S, E), jnp.float32)
    onehot = jax.nn.one_hot(gate_idx, E, dtype=jnp.float32)  # (B,S,k,E)
    combine = (onehot * gate_vals[..., None]).sum(2)         # (B,S,E)
    mask = (combine > 0).astype(x.dtype)
    # dispatch every token to its experts (capacity-free dense form: fine for
    # dry-run/smoke; production capacity variant lives in moe_capacity_apply)
    xe = jnp.einsum("bse,bsd->ebsd", mask, x)                # (E,B,S,D)
    h = jnp.einsum("ebsd,edf->ebsf", xe, p["w_gate"])
    h = activation(act, h) * jnp.einsum("ebsd,edf->ebsf", xe, p["w_up"])
    y = jnp.einsum("ebsf,efd->ebsd", h, p["w_down"])
    out = jnp.einsum("ebsd,bse->bsd", y, combine.astype(x.dtype))
    if "shared" in p:
        out = out + mlp_apply(p["shared"], x, act)
    return out


def moe_capacity_apply(p: Params, x: Array, *, top_k: int, act: str = "silu",
                       capacity_factor: float = 1.25) -> Array:
    """GShard-style capacity-bounded dispatch (production path).

    Tokens beyond an expert's capacity are dropped (residual passes through).
    Dispatch/combine are (tokens x experts x capacity) one-hot einsums —
    the standard TPU MoE formulation that lowers to all-to-alls.
    """
    B, S, D = x.shape
    E = p["router"].shape[-1]
    N = B * S
    cap = max(1, int(capacity_factor * N * top_k / E))
    xt = x.reshape(N, D)
    logits = xt.astype(jnp.float32) @ p["router"]            # (N,E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, top_k)        # (N,k)
    gate_vals = gate_vals / jnp.clip(gate_vals.sum(-1, keepdims=True), 1e-9)

    dispatch = jnp.zeros((N, E, cap), jnp.bool_)
    combine = jnp.zeros((N, E, cap), jnp.float32)
    for kk in range(top_k):
        e = gate_idx[:, kk]                                   # (N,)
        oh = jax.nn.one_hot(e, E, dtype=jnp.int32)            # (N,E)
        pos = jnp.cumsum(oh, axis=0) * oh - 1                 # slot per token
        slot = jnp.take_along_axis(pos, e[:, None], axis=1)[:, 0]
        ok = slot < cap
        slot_oh = jax.nn.one_hot(slot, cap, dtype=jnp.float32) * ok[:, None]
        d_k = oh.astype(jnp.float32)[:, :, None] * slot_oh[:, None, :]
        dispatch = dispatch | (d_k > 0)
        combine = combine + d_k * gate_vals[:, kk][:, None, None]

    xe = jnp.einsum("nec,nd->ecd", dispatch.astype(x.dtype), xt)  # (E,cap,D)
    h = jnp.einsum("ecd,edf->ecf", xe, p["w_gate"])
    h = activation(act, h) * jnp.einsum("ecd,edf->ecf", xe, p["w_up"])
    y = jnp.einsum("ecf,efd->ecd", h, p["w_down"])
    out = jnp.einsum("nec,ecd->nd", combine.astype(x.dtype), y).reshape(B, S, D)
    if "shared" in p:
        out = out + mlp_apply(p["shared"], x, act)
    return out


def moe_sorted_apply(p: Params, x: Array, *, top_k: int, act: str = "silu",
                     capacity_factor: float = 1.25) -> Array:
    """Sort-based dispatch (production path for the large MoE configs).

    One-hot dispatch einsums cost O(N * E * cap * D) MXU flops — for the
    128-expert assigned configs that dwarfs the expert compute itself and
    would poison the roofline's MODEL_FLOPS/HLO_FLOPs ratio.  Sorting
    replaces them with O(Nk log Nk) integer work + gathers/scatters:

      1. flatten (token, k) assignments; stable-sort by expert id
      2. position-in-expert via a run-length prefix (drop beyond capacity)
      3. scatter tokens into the (E, cap, D) expert buffer
      4. batched per-expert matmuls  (E, cap, D) x (E, D, F)  — the only
         MXU work, equal to the active-parameter flops
      5. gather back + gate-weighted combine.

    Under EP the buffer is sharded over experts on the model axis; the
    scatter/gather lower to all-to-alls (same traffic as GShard dispatch).
    """
    B, S, D = x.shape
    E = p["router"].shape[-1]
    N = B * S
    NK = N * top_k
    cap = max(1, int(capacity_factor * N * top_k / E))
    xt = x.reshape(N, D)
    logits = xt.astype(jnp.float32) @ p["router"]            # (N, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, top_k)        # (N, k)
    gate_vals = gate_vals / jnp.clip(gate_vals.sum(-1, keepdims=True), 1e-9)

    eid = gate_idx.reshape(NK)                               # flat expert ids
    tok = jnp.repeat(jnp.arange(N, dtype=jnp.int32), top_k)  # owning token
    gv = gate_vals.reshape(NK)
    order = jnp.argsort(eid, stable=True)
    eid_s, tok_s, gv_s = eid[order], tok[order], gv[order]
    # position within expert run: i - start_of_run(expert)
    counts = jnp.bincount(eid_s, length=E)
    starts = jnp.cumsum(counts) - counts                     # (E,)
    pos = jnp.arange(NK, dtype=jnp.int32) - starts[eid_s].astype(jnp.int32)
    valid = pos < cap
    slot = jnp.where(valid, eid_s * cap + pos, E * cap)      # overflow -> dropped

    buf = jnp.zeros((E * cap + 1, D), x.dtype)
    buf = buf.at[slot].set(xt[tok_s], mode="drop")
    xe = buf[:-1].reshape(E, cap, D)

    h = jnp.einsum("ecd,edf->ecf", xe, p["w_gate"])
    h = activation(act, h) * jnp.einsum("ecd,edf->ecf", xe, p["w_up"])
    y = jnp.einsum("ecf,efd->ecd", h, p["w_down"]).reshape(E * cap, D)

    contrib = jnp.where(valid, gv_s, 0.0).astype(x.dtype)[:, None] * \
        y[jnp.minimum(slot, E * cap - 1)]
    out = jnp.zeros((N, D), x.dtype).at[tok_s].add(contrib, mode="drop")
    out = out.reshape(B, S, D)
    if "shared" in p:
        out = out + mlp_apply(p["shared"], x, act)
    return out


# ---------------------------------------------------------------------------
# losses
# ---------------------------------------------------------------------------
def causal_lm_loss(logits: Array, labels: Array, ignore: int = -1) -> Array:
    """Mean xent over valid positions; logits (B,S,V), labels (B,S)."""
    logits32 = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits32, axis=-1)
    tgt = jnp.take_along_axis(logits32, jnp.maximum(labels, 0)[..., None],
                              axis=-1)[..., 0]
    nll = lse - tgt
    valid = (labels != ignore).astype(jnp.float32)
    return jnp.sum(nll * valid) / jnp.maximum(jnp.sum(valid), 1.0)

"""Score networks for the diffusion side of the framework.

The paper trains UNets on CIFAR10; on this CPU container we train (and
dry-run) two TPU-idiomatic score families instead:

  * `mlp`  — small residual MLP for low-dimensional toy data (the paper's
             Fig. 4 mixture experiments; trained end-to-end in examples/).
  * `dit`  — DiT-style patchified transformer with adaLN-zero time
             conditioning (Peebles & Xie 2023) — the MXU-native analogue of
             the paper's UNet for image-shaped states, and the score model
             the multi-pod diffusion dry-run lowers.

Both consume the *state* u (CLD: (B, 2, *data); VPSDE/BDM: (B, *data)) and a
continuous time t (B,), and emit an eps prediction of the same shape as u —
i.e. both channels for CLD, the paper's Eq. 80 parameterization (the crucial
difference from Dockhorn et al.'s v-channel-only net).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Tuple

import numpy as np
import jax
import jax.numpy as jnp

from . import common
from .common import Params

Array = jax.Array


def timestep_embedding(t: Array, dim: int, max_period: float = 1e4) -> Array:
    """Sinusoidal features of continuous t in [0, 1]; (B,) -> (B, dim)."""
    half = dim // 2
    freqs = jnp.exp(-math.log(max_period) * jnp.arange(half, dtype=jnp.float32) / half)
    ang = t.astype(jnp.float32)[:, None] * freqs[None] * 1000.0
    return jnp.concatenate([jnp.cos(ang), jnp.sin(ang)], axis=-1)


# ---------------------------------------------------------------------------
# Residual MLP (toy data)
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class MLPScoreCfg:
    state_shape: Tuple[int, ...]       # full per-example state shape (e.g. (2,) or (2, 2))
    hidden: int = 256
    n_blocks: int = 4
    t_dim: int = 64
    dtype: Any = jnp.float32

    @property
    def state_dim(self) -> int:
        return int(np.prod(self.state_shape))


def mlp_score_init(key, cfg: MLPScoreCfg) -> Params:
    ks = jax.random.split(key, 2 * cfg.n_blocks + 3)
    p = {
        "w_in": common.dense_init(ks[0], cfg.state_dim + cfg.t_dim, cfg.hidden, cfg.dtype),
        "b_in": jnp.zeros((cfg.hidden,), cfg.dtype),
        "w_out": (jax.random.normal(ks[1], (cfg.hidden, cfg.state_dim), jnp.float32)
                  * 1e-3).astype(cfg.dtype),
        "b_out": jnp.zeros((cfg.state_dim,), cfg.dtype),
        "blocks": [],
    }
    blocks = []
    for i in range(cfg.n_blocks):
        blocks.append({
            "w1": common.dense_init(ks[2 + 2 * i], cfg.hidden + cfg.t_dim, cfg.hidden, cfg.dtype),
            "b1": jnp.zeros((cfg.hidden,), cfg.dtype),
            "w2": common.dense_init(ks[3 + 2 * i], cfg.hidden, cfg.hidden, cfg.dtype),
            "b2": jnp.zeros((cfg.hidden,), cfg.dtype),
        })
    p["blocks"] = blocks
    return p


def mlp_score_apply(p: Params, cfg: MLPScoreCfg, u: Array, t: Array) -> Array:
    B = u.shape[0]
    te = timestep_embedding(t, cfg.t_dim).astype(u.dtype)
    h = jnp.concatenate([u.reshape(B, -1), te], axis=-1)
    h = jax.nn.silu(h @ p["w_in"] + p["b_in"])
    for blk in p["blocks"]:
        z = jnp.concatenate([h, te], axis=-1)
        z = jax.nn.silu(z @ blk["w1"] + blk["b1"])
        h = h + z @ blk["w2"] + blk["b2"]
    out = h @ p["w_out"] + p["b_out"]
    return out.reshape(u.shape)


# ---------------------------------------------------------------------------
# DiT (image-shaped states)
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class DiTCfg:
    img_size: int = 32
    channels: int = 3                  # data channels (CLD doubles this via state_mult)
    state_mult: int = 1                # 2 for CLD (x, v stacked on channel axis)
    patch: int = 4
    d_model: int = 384
    n_layers: int = 12
    n_heads: int = 6
    dtype: Any = jnp.float32
    remat: bool = True

    @property
    def n_tokens(self) -> int:
        return (self.img_size // self.patch) ** 2

    @property
    def patch_dim(self) -> int:
        return self.patch * self.patch * self.channels * self.state_mult

    def param_count(self) -> int:
        d = self.d_model
        per = 4 * d * d + 8 * d * d + 6 * d * d  # attn + mlp(4x) + adaLN
        return self.n_layers * per + 2 * self.patch_dim * d + self.n_tokens * d


def dit_init(key, cfg: DiTCfg) -> Params:
    ks = jax.random.split(key, 6)
    d = cfg.d_model

    def layer(k):
        ka, k1, k2, k3 = jax.random.split(k, 4)
        return {
            "attn": common.attn_params(ka, d, cfg.n_heads, cfg.n_heads,
                                       d // cfg.n_heads, cfg.dtype),
            "mlp": common.mlp_params(k1, d, 4 * d, cfg.dtype, gated=False),
            # adaLN-zero: 6 modulation vectors from the time embedding
            "ada_w": (jax.random.normal(k2, (d, 6 * d), jnp.float32) * 1e-3).astype(cfg.dtype),
            "ada_b": jnp.zeros((6 * d,), cfg.dtype),
        }

    return {
        "patch_in": common.dense_init(ks[0], cfg.patch_dim, d, cfg.dtype),
        "pos": (jax.random.normal(ks[1], (cfg.n_tokens, d), jnp.float32) * 0.02
                ).astype(cfg.dtype),
        "t_mlp1": common.dense_init(ks[2], 256, d, cfg.dtype),
        "t_mlp2": common.dense_init(ks[3], d, d, cfg.dtype),
        "layers": jax.vmap(layer)(jax.random.split(ks[4], cfg.n_layers)),
        "final_ada_w": (jax.random.normal(ks[5], (d, 2 * d), jnp.float32) * 1e-3
                        ).astype(cfg.dtype),
        "final_ada_b": jnp.zeros((2 * d,), cfg.dtype),
        "patch_out": jnp.zeros((d, cfg.patch_dim), cfg.dtype),  # zero-init output
    }


def _modulate(x, shift, scale):
    return x * (1.0 + scale[:, None]) + shift[:, None]


def dit_apply(p: Params, cfg: DiTCfg, u: Array, t: Array) -> Array:
    """u: (B, [state_mult,] H, W, C) -> eps of the same shape."""
    in_shape = u.shape
    B = u.shape[0]
    P, n_side = cfg.patch, cfg.img_size // cfg.patch
    cm = cfg.channels * cfg.state_mult
    if len(in_shape) == 5:        # CLD state (B, state_mult, H, W, C)
        x = u.transpose(0, 1, 4, 2, 3).reshape(B, cm, cfg.img_size, cfg.img_size)
    else:                         # (B, H, W, C)
        x = u.transpose(0, 3, 1, 2)
    # patchify: (B, cm, H, W) -> (B, T, patch_dim)
    x = x.reshape(B, cm, n_side, P, n_side, P).transpose(0, 2, 4, 1, 3, 5)
    x = x.reshape(B, n_side * n_side, cm * P * P).astype(cfg.dtype)

    h = x @ p["patch_in"] + p["pos"][None]
    te = timestep_embedding(t, 256).astype(cfg.dtype)
    te = jax.nn.silu(te @ p["t_mlp1"])
    te = jax.nn.silu(te @ p["t_mlp2"])                         # (B, d)

    ones = jnp.ones((h.shape[-1],), cfg.dtype)
    zeros = jnp.zeros((h.shape[-1],), cfg.dtype)

    def body(h, lp):
        mod = jax.nn.silu(te) @ lp["ada_w"] + lp["ada_b"]
        sh1, sc1, g1, sh2, sc2, g2 = jnp.split(mod, 6, axis=-1)
        z = common.layer_norm(h, ones, zeros)
        z = _modulate(z, sh1, sc1)
        a, _ = common.attn_apply(lp["attn"], z, n_heads=cfg.n_heads,
                                 n_kv=cfg.n_heads, d_head=cfg.d_model // cfg.n_heads,
                                 causal=False, rope_theta=0.0,
                                 positions=jnp.arange(h.shape[1]))
        from ..distributed.sharding import constrain_acts
        h = constrain_acts(h + g1[:, None] * a)
        z = common.layer_norm(h, ones, zeros)
        z = _modulate(z, sh2, sc2)
        h = constrain_acts(h + g2[:, None] * common.mlp_apply(lp["mlp"], z, act="gelu"))
        return h, None

    fn = body
    if cfg.remat:
        fn = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)
    h, _ = jax.lax.scan(fn, h, p["layers"])

    mod = jax.nn.silu(te) @ p["final_ada_w"] + p["final_ada_b"]
    sh, sc = jnp.split(mod, 2, axis=-1)
    h = _modulate(common.layer_norm(h, ones, zeros), sh, sc)
    out = h @ p["patch_out"]                                   # (B, T, patch_dim)
    # unpatchify
    out = out.reshape(B, n_side, n_side, cm, P, P).transpose(0, 3, 1, 4, 2, 5)
    out = out.reshape(B, cm, cfg.img_size, cfg.img_size)
    if len(in_shape) == 5:
        out = out.reshape(B, cfg.state_mult, cfg.channels, cfg.img_size, cfg.img_size)
        return out.transpose(0, 1, 3, 4, 2).astype(u.dtype)
    return out.transpose(0, 2, 3, 1).astype(u.dtype)
